#!/usr/bin/env python
"""Docs link checker: no dangling file references in docs/*.md + README.md.

Checked reference forms:
  * markdown links ``[text](target)`` with relative targets (http(s) and
    pure #anchor links are skipped; a trailing #anchor is stripped);
  * inline-code path mentions (`src/...`, `docs/...`, `tests/...`,
    `examples/...`, `benchmarks/...`, `tools/...`, `.github/...`) and the
    well-known top-level files (README.md, ROADMAP.md, Makefile, ...).
    References containing globs/placeholders (*, <, {) are skipped, and a
    `path::symbol` mention checks only the path part.

Relative markdown links resolve against the file's directory; bare path
mentions resolve against the repo root. Additionally, the ``REQUIRED``
doc set must exist — a doc a subsystem's module docstrings point at
(docs/robustness.md, docs/distributed.md, ...) being deleted or renamed
without updating this list is an error, not a silent shrink of the
checked surface. Exits 1 listing every dangling reference. No
dependencies — runs before ``pip install`` in CI.
"""
from __future__ import annotations

import glob
import os
import re
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

MD_LINK = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
CODE_SPAN = re.compile(r"`([^`\n]+)`")
PATH_PREFIXES = ("src/", "docs/", "tests/", "examples/", "benchmarks/",
                 "tools/", ".github/")
TOP_LEVEL = {"README.md", "ROADMAP.md", "PAPER.md", "PAPERS.md",
             "SNIPPETS.md", "CHANGES.md", "Makefile", "requirements.txt"}
SKIP_CHARS = set("*<>{}$")

# docs that module docstrings and the README point at by name; each must
# exist (deleting/renaming one must update this list in the same PR)
REQUIRED = ("docs/architecture.md", "docs/distributed.md",
            "docs/kernels.md", "docs/robustness.md", "docs/serving.md")


def refs_in(path: str):
    text = open(path, encoding="utf-8").read()
    base = os.path.dirname(path)
    for m in MD_LINK.finditer(text):
        target = m.group(1).split("#")[0]
        if not target or "://" in target or target.startswith("mailto:"):
            continue
        yield m.group(0), os.path.normpath(os.path.join(base, target))
    for m in CODE_SPAN.finditer(text):
        ref = m.group(1).split("::")[0].strip()
        if SKIP_CHARS & set(ref) or " " in ref:
            continue
        if not (ref.startswith(PATH_PREFIXES) or ref in TOP_LEVEL):
            continue
        yield f"`{m.group(1)}`", os.path.normpath(os.path.join(ROOT, ref))


def main() -> int:
    missing = [r for r in REQUIRED
               if not os.path.exists(os.path.join(ROOT, r))]
    if missing:
        print(f"{len(missing)} required doc(s) missing:")
        for r in missing:
            print(f"  {r}")
        return 1
    files = sorted(glob.glob(os.path.join(ROOT, "docs", "*.md")))
    files.append(os.path.join(ROOT, "README.md"))
    dangling = []
    checked = 0
    for f in files:
        for shown, target in refs_in(f):
            checked += 1
            if not os.path.exists(target):
                dangling.append((os.path.relpath(f, ROOT), shown))
    if dangling:
        print(f"{len(dangling)} dangling file reference(s):")
        for src, shown in dangling:
            print(f"  {src}: {shown}")
        return 1
    print(f"docs OK: {checked} file references resolve "
          f"across {len(files)} markdown files")
    return 0


if __name__ == "__main__":
    sys.exit(main())
