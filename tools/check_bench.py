#!/usr/bin/env python
"""Bench regression gate: compare freshly produced ``BENCH_*.json`` files
against committed baseline values with explicit tolerances.

CI's ``bench`` job used to only *upload* the JSON snapshots — a PR could
silently regress the paper-mix wire fraction or the ZeRO memory fractions
and stay green. This tool turns the numbers into a failing gate:

    python -m benchmarks.run --only kernel_backward,distributed_step
    python tools/check_bench.py            # exit 1 on any regression

(`make bench-check` runs both.)

Baselines live in ``benchmarks/bench_baselines.json``::

    {
      "BENCH_distributed_step.json": {
        "all_reduce_fraction":            {"value": 0.48, "tol": 0.05},
        "zero3.residency_fraction":       {"max": 0.50},
        "zero3.n_gather_elided":          {"min": 1},
        ...
      }
    }

Each dotted path is resolved into the fresh JSON (integer components index
into lists). Three check kinds, combinable per key:

* ``value`` + ``tol`` — |fresh − value| ≤ tol (two-sided drift alarm, for
  fractions that should stay put in BOTH directions: an "improvement" the
  code cannot explain is a broken measurement);
* ``max`` — fresh ≤ max (acceptance ceilings, e.g. residency ≤ 0.5×);
* ``min`` — fresh ≥ min (counters that must stay engaged, e.g. the
  gather-elision count > 0).

``--update`` rewrites the ``value`` fields in the baselines file from the
fresh JSONs (tolerances and bounds are kept) — run it when a PR changes a
number *on purpose*, and commit the diff so the change is reviewed.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

DEFAULT_BASELINES = os.path.join(os.path.dirname(__file__), "..",
                                 "benchmarks", "bench_baselines.json")


class ResolveError(KeyError):
    """A dotted-path lookup failed; the message pinpoints WHICH component
    failed and what was available at that level, so a renamed metric or a
    stale baseline key is a one-glance diagnosis instead of a bare
    'missing'."""


def _available(node) -> str:
    if isinstance(node, dict):
        keys = sorted(node)
        shown = ", ".join(keys[:10])
        if len(keys) > 10:
            shown += f", ... ({len(keys) - 10} more)"
        return f"available keys: [{shown}]"
    if isinstance(node, list):
        return f"a list of length {len(node)} (use an integer index)"
    return f"a leaf of type {type(node).__name__}"


def resolve(doc, path: str):
    """Walk a dotted path; integer components index lists. Raises
    ``ResolveError`` naming the failing component and the keys/length
    available at that point."""
    node = doc
    walked = []
    for part in path.split("."):
        if isinstance(node, list):
            try:
                node = node[int(part)]
            except (ValueError, IndexError):
                raise ResolveError(
                    f"component {part!r} (after "
                    f"{'.'.join(walked) or '<root>'}) does not index "
                    f"{_available(node)}")
        elif isinstance(node, dict):
            if part not in node:
                raise ResolveError(
                    f"component {part!r} (after "
                    f"{'.'.join(walked) or '<root>'}) not found; "
                    f"{_available(node)}")
            node = node[part]
        else:
            raise ResolveError(
                f"component {part!r} (after "
                f"{'.'.join(walked) or '<root>'}) cannot descend into "
                f"{_available(node)}")
        walked.append(part)
    return node


def check_key(fresh, path: str, spec: dict, bench_file: str = "?"):
    """Returns (ok, message) for one baseline entry."""
    try:
        got = resolve(fresh, path)
    except ResolveError as e:
        return False, (f"{path}: MISSING from fresh {bench_file}: "
                       f"{e.args[0]}")
    if not isinstance(got, (int, float)) or isinstance(got, bool):
        return False, f"{path}: not a number ({got!r})"
    problems = []
    if "max" in spec and got > spec["max"]:
        problems.append(f"{got:.6g} > max {spec['max']:.6g}")
    if "min" in spec and got < spec["min"]:
        problems.append(f"{got:.6g} < min {spec['min']:.6g}")
    if "value" in spec:
        tol = spec.get("tol", 0.0)
        if abs(got - spec["value"]) > tol:
            problems.append(
                f"{got:.6g} drifted from {spec['value']:.6g} "
                f"by {abs(got - spec['value']):.6g} (tol {tol:.6g})")
    if problems:
        return False, f"{path}: " + "; ".join(problems)
    bounds = "/".join(
        f"{k}={spec[k]:.6g}" for k in ("value", "tol", "max", "min")
        if k in spec)
    return True, f"{path}: {got:.6g} ok ({bounds})"


def main() -> int:
    ap = argparse.ArgumentParser(
        description="fail when BENCH_*.json metrics regress past the "
                    "committed baselines")
    ap.add_argument("--baselines", default=DEFAULT_BASELINES,
                    help="committed baseline spec (JSON)")
    ap.add_argument("--dir", default=".",
                    help="directory holding the fresh BENCH_*.json files")
    ap.add_argument("--update", action="store_true",
                    help="rewrite the baseline 'value' fields from the "
                         "fresh files instead of checking")
    args = ap.parse_args()

    with open(args.baselines) as f:
        baselines = json.load(f)

    failures = 0
    for bench_file, keys in baselines.items():
        path = os.path.join(args.dir, bench_file)
        if not os.path.exists(path):
            print(f"FAIL {bench_file}: file not found (run "
                  f"`make bench-json` first)")
            failures += 1
            continue
        with open(path) as f:
            fresh = json.load(f)
        for key, spec in keys.items():
            if args.update and "value" in spec:
                try:
                    spec["value"] = resolve(fresh, key)
                    print(f"UPDATE {bench_file} {key} = {spec['value']:.6g}")
                except ResolveError as e:
                    print(f"FAIL {bench_file} {key}: not updated — "
                          f"{e.args[0]}")
                    failures += 1
                continue
            ok, msg = check_key(fresh, key, spec, bench_file)
            print(("PASS " if ok else "FAIL ") + f"{bench_file} {msg}")
            failures += 0 if ok else 1

    if args.update:
        with open(args.baselines, "w") as f:
            json.dump(baselines, f, indent=2)
            f.write("\n")
        print(f"# wrote {args.baselines}")
    if failures:
        print(f"# {failures} bench check(s) failed", file=sys.stderr)
        return 1
    print("# all bench checks passed", file=sys.stderr)
    return 0


if __name__ == "__main__":
    sys.exit(main())
