"""Serving benchmark: continuous batching over the paged KV cache.

Drives a synthetic mixed-length request trace through
``PagedServingEngine`` (serving/engine.py) and reports:

* throughput — generated tokens per second over the whole trace, and the
  per-token latency distribution (p50/p99 of per-step wall time divided by
  the live-slot count that step);
* the request-level knapsack plan (``serving/packer.py``) for the same
  trace — wave count and per-wave page/FLOP-model balance;
* page-pool behaviour — peak pages in use, peak utilization and the
  within-page token occupancy at the peak.

Everything that does not depend on the machine (token counts, step counts,
wave structure, peak page occupancy) is a deterministic function of the
trace alone — those fields are gated tightly in
``benchmarks/bench_baselines.json``; wall-clock fields get generous
one-sided bounds. Timing is a second engine run after a full warm-up run
over the same trace, so jit compilation (the decode step plus one prefill
variant per distinct prompt length) is excluded.

Runs in-process via ``python -m benchmarks.run --only serving`` or
standalone::

  PYTHONPATH=src python -m benchmarks.serving [--use-kernel]

Writes ``BENCH_serving.json`` and prints ``name,us_per_call,derived`` CSV
rows (no header) on stdout.
"""
from __future__ import annotations

import argparse
import json
import sys
import time

import numpy as np

BENCH_SERVING_JSON = "BENCH_serving.json"

# engine geometry — small enough for the CI CPU budget, large enough that
# the trace below needs several admission waves (slots and pages both bind)
PAGE_SIZE = 8
N_PAGES = 48
MAX_SLOTS = 4
MAX_SEQ_LEN = 64


def build_trace(seed: int = 0, n_requests: int = 12):
    """Deterministic mixed-length trace: short chat-like prompts, long
    document-like prompts and mid-size ones, with varying generation
    budgets. Returns (prompt_lens, max_news, prompts)."""
    rng = np.random.RandomState(seed)
    prompt_lens, max_news = [], []
    for i in range(n_requests):
        kind = i % 3
        if kind == 0:        # short prompt, longer generation
            s, m = int(rng.randint(4, 10)), int(rng.randint(10, 16))
        elif kind == 1:      # long prompt, short generation
            s, m = int(rng.randint(28, 44)), int(rng.randint(4, 8))
        else:                # mid-size both
            s, m = int(rng.randint(12, 24)), int(rng.randint(8, 12))
        prompt_lens.append(s)
        max_news.append(m)
    prompts = [rng.randint(0, 211, size=s).astype(np.int32)
               for s in prompt_lens]
    return prompt_lens, max_news, prompts


def _drive(engine, requests):
    """Run the trace through an engine step by step, timing each fused
    step launch. Returns (outputs, per_token_latency_us, wall_s,
    peak_pages, peak_util, peak_slot_util)."""
    for r in requests:
        engine.submit(r)
    lat_us = []
    peak_pages = peak_util = peak_slot = 0.0
    t_start = time.perf_counter()
    while engine.waiting or engine.live:
        t0 = time.perf_counter()
        done = engine.step()
        dt = time.perf_counter() - t0
        # one decode token per slot that took part in the fused step:
        # the still-live slots plus the ones retired this step
        produced = max(1, engine.n_live + len(done))
        lat_us.append(dt / produced * 1e6)
        u = engine.pm.utilization()
        peak_pages = max(peak_pages, u["pages_in_use"])
        peak_util = max(peak_util, u["pages_in_use"] / engine.pm.capacity)
        peak_slot = max(peak_slot, u["slot_utilization"])
    wall_s = time.perf_counter() - t_start
    return dict(engine.finished), lat_us, wall_s, peak_pages, peak_util, \
        peak_slot


def run_serving_bench(use_kernel: bool = False, seed: int = 0):
    """Build the trace, warm-compile on a throwaway engine, then time a
    fresh engine over the identical trace. Returns the JSON payload."""
    import jax

    from repro.configs.base import ModelConfig
    from repro.models.transformer import init_model
    from repro.serving.engine import PagedServingEngine, Request
    from repro.serving.packer import pack_report, plan_waves

    cfg = ModelConfig(name="serve_bench", arch_type="dense", n_layers=4,
                      d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
                      vocab_size=211)
    params = init_model(jax.random.PRNGKey(seed), cfg)
    prompt_lens, max_news, prompts = build_trace(seed)
    requests = [Request(uid=i, prompt=p, max_new_tokens=m)
                for i, (p, m) in enumerate(zip(prompts, max_news))]

    def fresh_engine():
        return PagedServingEngine(params, cfg, page_size=PAGE_SIZE,
                                  n_pages=N_PAGES, max_slots=MAX_SLOTS,
                                  max_seq_len=MAX_SEQ_LEN,
                                  use_kernel=use_kernel)

    # knapsack plan for the same trace (advisory queue shaping; the report
    # is part of the artifact, the engine below uses FIFO admission)
    sizes = list(zip(prompt_lens, max_news))
    warm = fresh_engine()
    waves = plan_waves(sizes, page_size=PAGE_SIZE,
                       page_budget=warm.pm.capacity, max_slots=MAX_SLOTS)
    pack = pack_report(sizes, waves, page_size=PAGE_SIZE)

    # warm-up run compiles the fused decode step and one prefill per
    # distinct prompt length; the timed run below hits only caches
    warm.run(requests)
    assert warm.pm.n_free == warm.pm.capacity, "warm run leaked pages"

    engine = fresh_engine()
    outputs, per_tok_us, wall_s, peak_pages, peak_util, peak_slot = \
        _drive(engine, requests)
    per_tok_us = np.asarray(per_tok_us)
    assert engine.pm.n_free == engine.pm.capacity, "timed run leaked pages"
    for r in requests:      # warm and timed runs must agree exactly
        assert np.array_equal(outputs[r.uid], warm.finished[r.uid]), \
            f"warm/timed token mismatch for request {r.uid}"

    # each request generates max_new tokens: 1 at prefill + the rest from
    # fused decode steps (no EOS in the synthetic vocab trace)
    gen_decode = sum(len(outputs[r.uid]) - r.prompt_len - 1
                     for r in requests)
    gen_total = sum(len(outputs[r.uid]) - r.prompt_len for r in requests)
    tok_per_s = gen_total / wall_s if wall_s > 0 else 0.0

    payload = {
        "bench": "serving",
        "backend": jax.default_backend(),
        "use_kernel": bool(use_kernel),
        "engine": {"page_size": PAGE_SIZE, "n_pages": N_PAGES,
                   "max_slots": MAX_SLOTS, "max_seq_len": MAX_SEQ_LEN},
        "model": {"n_layers": cfg.n_layers, "d_model": cfg.d_model,
                  "n_heads": cfg.n_heads, "n_kv_heads": cfg.n_kv_heads},
        "trace": {"n_requests": len(requests),
                  "prompt_lens": prompt_lens,
                  "max_new_tokens": max_news,
                  "prompt_tokens": int(sum(prompt_lens))},
        "pack": pack,
        "totals": {"generated_tokens": int(gen_total),
                   "decode_tokens": int(gen_decode),
                   "engine_steps": int(engine.n_steps)},
        "pages": {"capacity": int(engine.pm.capacity),
                  "peak_in_use": int(peak_pages),
                  "peak_utilization": float(peak_util),
                  "peak_slot_utilization": float(peak_slot)},
        "throughput": {"tokens_per_sec": float(tok_per_s),
                       "wall_s": float(wall_s)},
        "latency_us_per_token": {
            "p50": float(np.percentile(per_tok_us, 50)),
            "p99": float(np.percentile(per_tok_us, 99)),
            "mean": float(per_tok_us.mean())},
    }
    return payload


def main(argv=None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--use-kernel", action="store_true",
                    help="route decode attention through the paged Pallas "
                         "kernel (interpret mode on CPU)")
    ap.add_argument("--out", default=BENCH_SERVING_JSON)
    args = ap.parse_args(argv)

    payload = run_serving_bench(use_kernel=args.use_kernel)
    with open(args.out, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"# wrote {args.out}", file=sys.stderr)

    t, lat, p = payload["totals"], payload["latency_us_per_token"], \
        payload["pack"]
    print(f"serving_throughput,"
          f"{payload['latency_us_per_token']['mean']:.1f},"
          f"tokens_per_sec={payload['throughput']['tokens_per_sec']:.1f};"
          f"generated={t['generated_tokens']};steps={t['engine_steps']}")
    print(f"serving_latency,{lat['p50']:.1f},"
          f"p50_us={lat['p50']:.1f};p99_us={lat['p99']:.1f}")
    print(f"serving_pack,0.0,"
          f"n_waves={p['n_waves']};wave_pages={p['wave_pages']};"
          f"cost_max_over_mean="
          f"{p['wave_cost_max'] / max(p['wave_cost_mean'], 1e-9):.3f}")
    print(f"serving_pages,0.0,"
          f"peak_in_use={payload['pages']['peak_in_use']};"
          f"peak_utilization={payload['pages']['peak_utilization']:.3f}")


if __name__ == "__main__":
    main()
