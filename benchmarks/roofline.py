"""Roofline analysis from dry-run artifacts (EXPERIMENTS.md §Roofline).

Per (arch × shape × mesh) JSON produced by repro.launch.dryrun:
  compute term    = HLO_FLOPs_per_device / peak_FLOP/s      (197e12 bf16)
  memory term     = HLO_bytes_per_device / HBM_bw           (819e9 B/s)
  collective term = collective_bytes_per_device / link_bw   (50e9 B/s)

plus MODEL_FLOPS = 6·N·D (train) / 2·N·D (prefill/decode) with N = active
params, and the usefulness ratio MODEL_FLOPS / (HLO_FLOPs × chips) that
catches remat/redundancy waste. FLOPs/bytes are scan-depth-extrapolated by
the dry-run (XLA counts while bodies once — calibrated in tests).

  python -m benchmarks.roofline --dir experiments/dryrun --md experiments/roofline.md
"""
from __future__ import annotations

import argparse
import glob
import json
import os
from typing import Dict

import jax

from repro.configs import get_config
from repro.configs.base import INPUT_SHAPES, ModelConfig
from repro.launch.mesh import HBM_BW, ICI_BW, PEAK_FLOPS_BF16


def param_counts(cfg: ModelConfig):
    """(total, active) parameter counts via eval_shape (no allocation)."""
    import functools
    from repro.models.transformer import init_model
    shapes = jax.eval_shape(functools.partial(init_model, cfg=cfg),
                            jax.random.PRNGKey(0))
    total = active = 0

    def walk(tree, in_moe=False, name=""):
        nonlocal total, active
        if isinstance(tree, dict):
            for k, v in tree.items():
                walk(v, in_moe or k == "moe", k)
            return
        if isinstance(tree, (list, tuple)):
            for v in tree:
                walk(v, in_moe, name)
            return
        n = 1
        for d in tree.shape:
            n *= d
        total += n
        routed = in_moe and name in ("w_up", "w_gate", "w_down")
        if routed and cfg.moe is not None:
            active += n * cfg.moe.top_k / cfg.moe.n_experts
        else:
            active += n
    walk(shapes)
    return int(total), int(active)


def _attn_context_flops_per_token(cfg: ModelConfig, ctx: int) -> float:
    """QK^T + PV flops for one new token attending over a ctx-long cache,
    summed over layers (window-limited for local layers)."""
    if cfg.n_heads == 0:
        return 0.0
    hd = cfg.resolved_head_dim
    total = 0.0
    for kind in cfg.layer_kinds:
        if kind == "attn_global":
            span = ctx
        elif kind == "attn_local":
            span = min(ctx, cfg.window or ctx)
        else:
            continue
        total += 2 * 2 * span * cfg.n_heads * hd
    return total


def model_flops(cfg: ModelConfig, shape_name: str) -> float:
    """Analytic useful FLOPs for the whole step (global, all chips)."""
    shape = INPUT_SHAPES[shape_name]
    total, active = param_counts(cfg)
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * active * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        # + average causal attention context S/2
        attn = tokens * _attn_context_flops_per_token(cfg, shape.seq_len // 2)
        return 2.0 * active * tokens + attn
    # decode: one token per sequence attending over the full cache
    attn = shape.global_batch * _attn_context_flops_per_token(
        cfg, shape.seq_len)
    return 2.0 * active * shape.global_batch + attn


def analyze(rec: Dict) -> Dict:
    chips = 512 if rec["mesh"] == "2x16x16" else 256
    flops_dev = rec.get("flops", rec.get("flops_raw", 0.0))
    bytes_dev = max(rec.get("bytes", 0.0), rec.get("bytes_raw", 0.0))
    coll_dev = sum(rec.get("collectives", {}).values())
    t_comp = flops_dev / PEAK_FLOPS_BF16
    t_mem = bytes_dev / HBM_BW
    t_coll = coll_dev / ICI_BW
    cfg = get_config(rec["arch"])
    mf = model_flops(cfg, rec["shape"])
    useful = mf / max(flops_dev * chips, 1.0)
    terms = {"compute": t_comp, "memory": t_mem, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    lever = {
        "compute": "cut redundant/remat FLOPs (packed D2FT path, fused "
                   "attention) or add chips",
        "memory": "fuse elementwise chains + flash/chunked attention to cut "
                  "HBM traffic; bf16 activations",
        "collective": "reshard to reduce all-gather volume (kv-only gathers,"
                      " 2-axis vocab shard) or overlap collectives with "
                      "compute",
    }[dominant]
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "t_compute_s": t_comp, "t_memory_s": t_mem, "t_collective_s": t_coll,
        "dominant": dominant, "model_flops": mf,
        "hlo_flops_global": flops_dev * chips, "useful_ratio": useful,
        "temp_gib": rec["memory"]["temp_bytes"] / 2 ** 30,
        "collectives": rec.get("collectives", {}),
        "lever": lever,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/dryrun")
    ap.add_argument("--md", default=None)
    ap.add_argument("--csv", default=None)
    ap.add_argument("--variants", action="store_true",
                    help="include hillclimb variant artifacts (tag __*)")
    ap.add_argument("--mesh", default=None, help="filter, e.g. 16x16")
    args = ap.parse_args()
    rows = []
    for path in sorted(glob.glob(os.path.join(args.dir, "*.json"))):
        if "__" in os.path.basename(path) and not args.variants:
            continue
        with open(path) as f:
            rec = json.load(f)
        if args.mesh and rec["mesh"] != args.mesh:
            continue
        rows.append(analyze(rec))

    hdr = ("arch,shape,mesh,t_compute_s,t_memory_s,t_collective_s,dominant,"
           "useful_ratio,temp_gib")
    lines = [hdr]
    for r in rows:
        lines.append(
            f"{r['arch']},{r['shape']},{r['mesh']},{r['t_compute_s']:.4e},"
            f"{r['t_memory_s']:.4e},{r['t_collective_s']:.4e},"
            f"{r['dominant']},{r['useful_ratio']:.3f},{r['temp_gib']:.2f}")
    print("\n".join(lines))
    if args.csv:
        with open(args.csv, "w") as f:
            f.write("\n".join(lines) + "\n")
    if args.md:
        md = ["| arch | shape | mesh | compute (s) | memory (s) | "
              "collective (s) | dominant | useful ratio | lever |",
              "|---|---|---|---|---|---|---|---|---|"]
        for r in rows:
            md.append(
                f"| {r['arch']} | {r['shape']} | {r['mesh']} | "
                f"{r['t_compute_s']:.3e} | {r['t_memory_s']:.3e} | "
                f"{r['t_collective_s']:.3e} | **{r['dominant']}** | "
                f"{r['useful_ratio']:.3f} | {r['lever']} |")
        with open(args.md, "w") as f:
            f.write("\n".join(md) + "\n")
        print(f"\nwrote {args.md}")


if __name__ == "__main__":
    main()
