"""Standalone distributed-step benchmark driver (8 host CPU devices).

Must be its own process: ``--xla_force_host_platform_device_count`` is read
once, when jax initializes, so the flag is set here before any jax import.
Run directly::

  PYTHONPATH=src python -m benchmarks.dist_step [--n-devices 8] [--kernel]

or through ``python -m benchmarks.run --only distributed_step``, which
subprocesses this module so the forced device count never leaks into the
parent's jax runtime. Writes ``BENCH_distributed_step.json`` and prints
``name,us_per_call,derived`` CSV rows (no header) on stdout.
"""
import os

# append rather than setdefault: a pre-existing XLA_FLAGS value must not
# swallow the device-count flag (make_data_mesh refuses short meshes, but
# failing to even create 8 devices here should never happen silently)
_FLAG = "--xla_force_host_platform_device_count"
if _FLAG not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") + " "
                               + _FLAG + "=8").strip()
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import argparse
import json
import sys

BENCH_DISTRIBUTED_STEP_JSON = "BENCH_distributed_step.json"


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--n-devices", type=int, default=8)
    ap.add_argument("--time-steps", type=int, default=3,
                    help="executed steps per variant for wall time "
                         "(0 = lower/compile only)")
    ap.add_argument("--kernel", action="store_true",
                    help="route the local shards through the compacted "
                         "Pallas kernel path (interpret mode on CPU)")
    ap.add_argument("--out", default=BENCH_DISTRIBUTED_STEP_JSON)
    args = ap.parse_args()

    from repro.launch.diststep import measure_distributed_step
    rec = measure_distributed_step(args.n_devices, use_kernel=args.kernel,
                                   time_steps=args.time_steps)
    for name, var in rec["variants"].items():
        reb = var["rebalance"]
        print(f"distributed_step_{name},"
              f"{var.get('wall_us_per_step', 0.0):.1f},"
              f"wire_bytes={var['wire_bytes']:.3e};"
              f"all_reduce_bytes={var['all_reduce_bytes']:.3e};"
              f"sync_fraction={var['sync_plan']['fraction']:.3f};"
              f"load_spread={reb['spread']};imbalance={reb['imbalance']}")
    print(f"distributed_step_comm_saving,0.0,"
          f"all_reduce_fraction={rec['all_reduce_fraction']:.3f};"
          f"sync_model_fraction={rec['sync_model_fraction']:.3f};"
          f"paper_target<=0.60")
    z = rec["zero_sync"]
    print(f"zero_sync,0.0,"
          f"paper_mix_wire_fraction={z['paper_mix_wire_fraction']:.3f};"
          f"masked_wire_fraction={z['paper_mix_masked_wire_fraction']:.3f};"
          f"uniform_wire_fraction={z['uniform_wire_fraction']:.3f};"
          f"uniform_masked_n_skipped={z['uniform_masked_n_skipped']};"
          f"opt_memory_fraction={z['opt_memory_fraction']:.4f}")
    z3 = rec["zero3"]
    print(f"zero3,0.0,"
          f"paper_mix_wire_fraction={z3['paper_mix_wire_fraction']:.3f};"
          f"residency_fraction={z3['residency_fraction']:.3f};"
          f"n_gather_elided={z3['n_gather_elided']};"
          f"n_all_gather_ops={z3['n_all_gather_ops']};"
          f"opt_memory_fraction={z3['opt_memory_fraction']:.4f};"
          f"residency_target<=0.50")
    ov = rec["overlap"]
    print(f"overlap,0.0,"
          f"exposed_collective_fraction="
          f"{ov['exposed_collective_fraction']:.3f};"
          f"streamed_residency_fraction="
          f"{ov['streamed_residency_fraction']:.4f};"
          f"peak_agreement={ov['peak_agreement']:.4f};"
          f"double_buffer_fraction={ov['double_buffer_fraction']:.3f};"
          f"wire_ratio_vs_unstreamed={ov['wire_ratio_vs_unstreamed']:.4f};"
          f"exposed_target<1.0")
    pp = rec["pipeline"]
    print(f"pipeline,{pp.get('wall_us_per_step', 0.0):.1f},"
          f"mesh=data{pp['mesh']['data']}xstage{pp['mesh']['stage']};"
          f"boundaries={pp['boundaries']};"
          f"makespan_ratio={pp['makespan_ratio']:.3f};"
          f"bubble_fraction={pp['bubble_fraction']:.3f};"
          f"layer_count_bubble_fraction="
          f"{pp['layer_count_bubble_fraction']:.3f};"
          f"trace_ok={pp['trace']['trace_ok']};"
          f"ratio_target<0.95")
    with open(args.out, "w") as f:
        json.dump(rec, f, indent=2)
    print(f"# wrote {args.out}", file=sys.stderr)


if __name__ == "__main__":
    main()
