"""Shared harness for the paper-table benchmarks.

All accuracy benchmarks follow the paper's protocol at test scale: a ViT is
"pretrained" on a broad synthetic image task, then fine-tuned on a narrower
task under a schedule produced by D2FT or a baseline scheduler, and top-1
accuracy is compared at matched compute/communication budgets.
"""
from __future__ import annotations

import functools
import time
from typing import Callable, Optional

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs.base import D2FTConfig
from repro.core import baselines
from repro.core.d2ft import plan_schedule
from repro.core.schedule import Schedule
from repro.core.scores import compute_scores, vit_blocks, weight_magnitude
from repro.data.synthetic import image_batches, make_image_task
from repro.models.vit import ViTConfig, init_vit, vit_loss
from repro.optim.optimizers import sgd
from repro.train.loop import eval_vit, finetune_vit

VIT = ViTConfig(n_layers=2, d_model=96, n_heads=6, d_ff=192, patch=8,
                image_size=32, n_classes=10)
N_MB = 5
BATCH = 40
FT_STEPS = 16
LR = 0.05
NOISE = 1.3    # calibrated so budget orderings are visible (not saturated)

_cache = {}


def pretrained_vit():
    """Pretrain once per process on the 'upstream' synthetic task."""
    if "params" not in _cache:
        task_up = make_image_task(101, n_classes=10, image_size=32,
                                  noise=0.5)
        params = init_vit(jax.random.PRNGKey(0), VIT)
        params, _, _ = finetune_vit(params, VIT, sgd(LR),
                                    image_batches(task_up, 1, BATCH, 25),
                                    steps=25)
        _cache["params"] = params
    return jax.tree.map(jnp.copy, _cache["params"])


def downstream_task(seed=3):
    return make_image_task(seed, n_classes=10, image_size=32, noise=NOISE)


def vit_loss_fn(params, mb):
    return vit_loss(params, jnp.asarray(mb[0]), jnp.asarray(mb[1]), VIT)[0]


def vit_scores(params, images, labels, G=None,
               backward="weight_magnitude", forward="fisher", n_mb=N_MB):
    G = G or VIT.n_heads
    mbs = list(zip(np.split(images, n_mb), np.split(labels, n_mb)))
    return compute_scores(vit_loss_fn, params, vit_blocks, mbs, G,
                          backward_metric=backward, forward_metric=forward)


def d2ft_schedule_fn(d2: D2FTConfig, G=None, refresh=16, backward=None,
                     forward=None, cap_pf=None, cap_po=None):
    G = G or VIT.n_heads

    def fn(step, params, images, labels):
        if step % refresh != 0:
            return None
        bw, fw = vit_scores(params, images, labels, G,
                            backward or d2.backward_score,
                            forward or d2.forward_score,
                            n_mb=d2.n_microbatches)
        return plan_schedule(d2, bw, fw, VIT.n_layers, G, cap_pf=cap_pf,
                             cap_po=cap_po)
    return fn


def random_schedule_fn(d2: D2FTConfig, G=None, seed=0):
    G = G or VIT.n_heads
    rng = np.random.default_rng(seed)

    def fn(step, params, images, labels):
        return baselines.random_schedule(rng, VIT.n_layers, G, d2.n_microbatches,
                                         d2.n_pf, d2.n_po)
    return fn


def dpruning_schedule_fn(keep: float, mode="m", G=None, refresh=16):
    G = G or VIT.n_heads

    def fn(step, params, images, labels):
        if step % refresh != 0:
            return None
        blocks = vit_blocks(params)
        imp = weight_magnitude(blocks, G).reshape(-1)
        if mode == "mg":
            bw, _ = vit_scores(params, images, labels, G,
                               backward="gradient_magnitude",
                               forward="gradient_magnitude")
            imp = imp * bw.mean(1)
        return baselines.dpruning_schedule(imp, VIT.n_layers, G, N_MB,
                                           keep)
    return fn


def gshard_schedule_fn(capacity: int, G=None, seed=0):
    G = G or VIT.n_heads
    rng = np.random.default_rng(seed)

    def fn(step, params, images, labels):
        gate = rng.random((VIT.n_layers * G, N_MB))
        return baselines.gshard_schedule(rng, gate, VIT.n_layers, G, capacity)
    return fn


def run_finetune(schedule_fn: Optional[Callable], task=None, steps=FT_STEPS,
                 seed=5, n_mb=N_MB, cfg=None, params=None):
    cfg = cfg or VIT
    task = task or downstream_task()
    params = params if params is not None else pretrained_vit()
    t0 = time.perf_counter()
    params, _, log = finetune_vit(params, cfg, sgd(LR),
                                  image_batches(task, seed, BATCH, steps),
                                  steps=steps, schedule_fn=schedule_fn,
                                  n_microbatches=n_mb)
    wall = time.perf_counter() - t0
    acc = eval_vit(params, cfg, image_batches(task, 7, BATCH, 5))
    return acc, wall / max(steps, 1), log


def emit(name: str, us_per_call: float, derived: str):
    print(f"{name},{us_per_call:.1f},{derived}")
