"""Paper-table benchmarks. One function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV (us_per_call = wall time per
fine-tune step where applicable). Datasets are synthetic (offline
container); the deliverable is the ORDERING/BUDGET structure of each paper
table, not absolute CIFAR numbers — see EXPERIMENTS.md §Paper-validation.

  python -m benchmarks.run            # all tables
  python -m benchmarks.run --only workload_variance,po_sweep
  python -m benchmarks.run --list     # available entry names
"""
from __future__ import annotations

import argparse
import sys
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs.base import D2FTConfig
from repro.core.cost_model import comm_cost, compute_cost, workload_variance
from repro.core.knapsack import scalarized_select
from repro.core.schedule import Schedule, merge_tables
from benchmarks import common
from benchmarks.common import (VIT, N_MB, d2ft_schedule_fn,
                               dpruning_schedule_fn, emit, gshard_schedule_fn,
                               random_schedule_fn, run_finetune, vit_scores)


# ------------------------------------------------------- Table I + Table II
def bench_workload_variance():
    """Paper Table I (+ execution time, Table II) at the 60% compute budget
    (3 p_f of 5 micro-batches)."""
    d2 = D2FTConfig(n_microbatches=N_MB, n_pf=3, n_po=0)
    params = common.pretrained_vit()
    task = common.downstream_task()
    images, labels = next(common.image_batches(task, 5, common.BATCH, 1))

    rows = {}
    sched = d2ft_schedule_fn(d2)(0, params, images, labels)
    rows["D2FT"] = sched.table
    rows["Random"] = random_schedule_fn(d2)(0, params, images, labels).table
    rows["DPruning_M"] = dpruning_schedule_fn(0.6)(0, params, images,
                                                   labels).table
    rows["DPruning_MG"] = dpruning_schedule_fn(0.6, "mg")(0, params, images,
                                                          labels).table
    rows["MoE_GShard"] = gshard_schedule_fn(capacity=3)(0, params, images,
                                                        labels).table
    for name, table in rows.items():
        emit(f"table1_variance_{name}", 0.0,
             f"variance={workload_variance(table):.3f};"
             f"compute={compute_cost(table):.2f}")


def bench_execution_time():
    """Paper Table II: per-step wall time + accuracy under each scheduler."""
    d2 = D2FTConfig(n_microbatches=N_MB, n_pf=3, n_po=0)
    for name, fn in [
            ("D2FT", d2ft_schedule_fn(d2)),
            ("Random", random_schedule_fn(d2)),
            ("DPruning_M", dpruning_schedule_fn(0.6)),
            ("DPruning_MG", dpruning_schedule_fn(0.6, "mg")),
            ("MoE_GShard", gshard_schedule_fn(capacity=3))]:
        acc, per_step, _ = run_finetune(fn)
        emit(f"table2_exec_{name}", per_step * 1e6, f"top1={acc:.3f}")


# ------------------------------------------------------------- Fig. 1 and 2
def bench_accuracy_vs_cost():
    """Fig. 1/2: top-1 at matched compute budgets for all methods."""
    acc_std, per_step, _ = run_finetune(None)
    emit("fig12_Standard", per_step * 1e6, "top1=%.3f;compute=1.00" % acc_std)
    d2 = D2FTConfig(n_microbatches=N_MB, n_pf=3, n_po=1)
    for name, fn in [
            ("D2FT", d2ft_schedule_fn(d2)),
            ("Random", random_schedule_fn(d2)),
            ("DPruning_M", dpruning_schedule_fn(0.68)),
            ("MoE_GShard", gshard_schedule_fn(capacity=3))]:
        acc, per_step, _ = run_finetune(fn)
        emit(f"fig12_{name}", per_step * 1e6,
             f"top1={acc:.3f};compute=0.68")


# ------------------------------------------------------------------ Table III
def bench_score_combos():
    """Paper Table III: backward/forward score metric combinations."""
    d2 = D2FTConfig(n_microbatches=N_MB, n_pf=2, n_po=2)
    combos = [("weight_magnitude", "fisher"),
              ("fisher", "weight_magnitude"),
              ("weight_magnitude", "gradient_magnitude"),
              ("gradient_magnitude", "weight_magnitude"),
              ("weight_magnitude", "taylor"),
              ("taylor", "weight_magnitude")]
    for bw, fw in combos:
        fn = d2ft_schedule_fn(d2, backward=bw, forward=fw)
        acc, per_step, _ = run_finetune(fn)
        emit(f"table3_{bw}__{fw}", per_step * 1e6, f"top1={acc:.3f}")


# ------------------------------------------------------------------ Table IV
def bench_fwd_bwd_ratio():
    """Paper Table IV: forward cost ≈ 40% of fwd+bwd — measured here from
    compiled HLO FLOPs of the ViT instead of wall time."""
    params = common.pretrained_vit()
    task = common.downstream_task()
    images, labels = next(common.image_batches(task, 5, common.BATCH, 1))
    x, y = jnp.asarray(images), jnp.asarray(labels)

    def loss(p):
        from repro.models.vit import vit_loss
        return vit_loss(p, x, y, VIT)[0]

    fwd = jax.jit(loss).lower(params).compile().cost_analysis()
    bwd = jax.jit(jax.value_and_grad(loss)).lower(params).compile() \
        .cost_analysis()
    f, fb = float(fwd.get("flops", 0)), float(bwd.get("flops", 0))
    emit("table4_fwd_fraction", 0.0,
         f"fwd_flops={f:.3e};fwdbwd_flops={fb:.3e};ratio={f/fb:.3f}")


# ------------------------------------------------------------------- Table V
def bench_num_subnets():
    """Paper Table V: more subnets (finer granularity) >= fewer."""
    d2 = D2FTConfig(n_microbatches=N_MB, n_pf=2, n_po=2)
    for G in (6, 3, 1):          # 12, 6, 2 subnets on the 2-layer test ViT
        fn = d2ft_schedule_fn(d2, G=G)
        acc, per_step, _ = run_finetune(fn)
        emit(f"table5_subnets_{VIT.n_layers * G}", per_step * 1e6,
             f"top1={acc:.3f}")


# ------------------------------------------------------------------ Table VI
def bench_microbatch_size():
    """Paper Table VI: micro-batch size has minor impact at fixed budget."""
    for n_mb, n_pf, n_po in [(4, 2, 1), (5, 2, 2), (10, 4, 4)]:
        d2 = D2FTConfig(n_microbatches=n_mb, n_pf=n_pf, n_po=n_po)
        fn = d2ft_schedule_fn(d2)
        acc, per_step, _ = run_finetune(fn, n_mb=n_mb)
        emit(f"table6_mb{common.BATCH // n_mb}", per_step * 1e6,
             f"top1={acc:.3f}")


# ----------------------------------------------------------- Tables VII/VIII
def bench_heterogeneous():
    """Paper Tables VII/VIII: per-device capacities (memory/compute
    heterogeneity) do not hurt accuracy."""
    K = VIT.n_layers * VIT.n_heads
    for n_fast in (3, 6, 9):
        cap_pf = np.full(K, 2.0)
        cap_pf[:n_fast] = 3.0          # fast devices: 3 p_f
        cap_po = np.full(K, 0.8)
        cap_po[:n_fast] = 0.4          # fast devices trade p_o for p_f
        d2 = D2FTConfig(n_microbatches=N_MB, n_pf=2, n_po=2)
        fn = d2ft_schedule_fn(d2, cap_pf=cap_pf, cap_po=cap_po)
        acc, per_step, _ = run_finetune(fn)
        emit(f"table78_heterogeneous_fast{n_fast}", per_step * 1e6,
             f"top1={acc:.3f}")


# ------------------------------------------------------------------ Table IX
def bench_po_sweep():
    """Paper Table IX: p_o count is a cheap accuracy lever (1 p_f fixed)."""
    for n_po in range(0, 5):
        d2 = D2FTConfig(n_microbatches=N_MB, n_pf=1, n_po=n_po)
        fn = d2ft_schedule_fn(d2)
        acc, per_step, _ = run_finetune(fn)
        cost = (1.0 + 0.4 * n_po) / N_MB
        emit(f"table9_po{n_po}", per_step * 1e6,
             f"top1={acc:.3f};compute={cost:.2f}")


# ------------------------------------------------------------------- Table X
def bench_bilevel_vs_scaler():
    """Paper Table X: bi-level decoupling vs scalarized single knapsack."""
    d2 = D2FTConfig(n_microbatches=N_MB, n_pf=2, n_po=2)
    acc, per_step, _ = run_finetune(d2ft_schedule_fn(d2))
    emit("table10_bilevel", per_step * 1e6, f"top1={acc:.3f}")

    def scaler_fn(lam_mode):
        def fn(step, params, images, labels):
            if step % 16 != 0:
                return None
            bw, fw = vit_scores(params, images, labels)
            if lam_mode == "max":
                lam = 0.99 * bw.min() / max(fw.max(), 1e-9)
            elif lam_mode == "min":
                lam = 1.01 * bw.max() / max(fw.min(), 1e-9)
            else:
                lam = float(lam_mode)
            K = bw.shape[0]
            pf = np.zeros((K, N_MB), bool)
            po = np.zeros((K, N_MB), bool)
            for k in range(K):
                pf[k], po[k] = scalarized_select(bw[k], fw[k], lam, 0.4, 0.6,
                                                 cap_total=2.8)
            return Schedule(merge_tables(pf, po), VIT.n_layers, VIT.n_heads)
        return fn

    for lam in ("max", "min", 0.2, 0.1):
        acc, per_step, _ = run_finetune(scaler_fn(lam))
        emit(f"table10_scaler_{lam}", per_step * 1e6, f"top1={acc:.3f}")


# -------------------------------------------------------------------- Fig. 3
def bench_lora():
    """Fig. 3: D2FT-LoRA vs standard LoRA vs small-rank LoRA at matched
    compute. LoRA on the test ViT's QKV weights."""
    from repro.core.lora import init_lora, merge_lora
    from repro.core.schedule import gates_from_schedule
    from repro.data.synthetic import microbatch_assignment
    from repro.models.vit import vit_loss
    from repro.optim.optimizers import sgd as make_sgd
    from repro.train.loop import eval_vit

    task = common.downstream_task()
    base = common.pretrained_vit()
    d2 = D2FTConfig(n_microbatches=N_MB, n_pf=3, n_po=0)

    def lora_finetune(rank, schedule_fn=None, steps=common.FT_STEPS):
        lora = init_lora(jax.random.PRNGKey(3), base, rank=rank)
        opt = make_sgd(common.LR)
        st = opt.init(lora)
        sched = None

        @jax.jit
        def step_fn(lr, st, x, y, gates):
            def loss(lr):
                merged = merge_lora(base, lr, 1.0)
                return vit_loss(merged, x, y, VIT, gates=gates)[0]
            g = jax.grad(loss)(lr)
            return opt.update(g, st, lr)

        lora_p = lora
        for i, (images, labels) in enumerate(
                common.image_batches(task, 5, common.BATCH, steps)):
            gates = None
            if schedule_fn is not None:
                new = schedule_fn(i, merge_lora(base, lora_p, 1.0),
                                  images, labels)
                sched = new if new is not None else sched
                mb_of = microbatch_assignment(common.BATCH, N_MB)
                gates = gates_from_schedule(sched, mb_of)
            lora_p, st = step_fn(lora_p, st, jnp.asarray(images),
                                 jnp.asarray(labels), gates)
        merged = merge_lora(base, lora_p, 1.0)
        return eval_vit(merged, VIT, common.image_batches(task, 7,
                                                          common.BATCH, 5))

    acc_std = lora_finetune(rank=24)
    emit("fig3_standard_lora_r24", 0.0, f"top1={acc_std:.3f};compute=1.00")
    acc_small = lora_finetune(rank=2)
    emit("fig3_small_rank_r2", 0.0, f"top1={acc_small:.3f};compute=0.60")
    acc_d2ft = lora_finetune(rank=24, schedule_fn=d2ft_schedule_fn(d2))
    emit("fig3_d2ft_lora_r24", 0.0, f"top1={acc_d2ft:.3f};compute=0.60")


# --------------------------------------------- packed-path compiled savings
def bench_packed_flops():
    """The systems claim: compiled FLOPs of the packed D2FT step vs standard
    full fine-tuning (same model/batch). Shows the compute cut in the
    executable, not just in the cost model."""
    from repro.configs.base import ModelConfig
    from repro.core.d2ft import (mb_packed_indices, packed_forward_mb,
                                 plan_schedule)
    from repro.models.transformer import forward, init_model

    cfg = ModelConfig(name="bench", arch_type="dense", n_layers=4,
                      d_model=128, n_heads=4, n_kv_heads=4, d_ff=256,
                      vocab_size=512)
    params = init_model(jax.random.PRNGKey(0), cfg)
    B, S, M = 20, 64, 5
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, 512)
    rng = np.random.default_rng(0)
    d2 = D2FTConfig(n_microbatches=M, n_pf=3, n_po=0, head_groups=4)
    bw = np.repeat(rng.random((16, 1)) + .1, M, 1)
    fw = rng.random((16, M)) + .1
    sched = plan_schedule(d2, bw, fw, 4, 4)
    idx, bwd, val = mb_packed_indices(sched, M)
    arrays = tuple(map(jnp.asarray, (idx, bwd, val)))

    def full_loss(p):
        return jnp.mean(forward(p, cfg, tokens=toks)[0] ** 2)

    def packed_loss(p):
        return jnp.mean(packed_forward_mb(p, cfg, toks, arrays, M)[0] ** 2)

    f_full = float(jax.jit(jax.grad(full_loss)).lower(params).compile()
                   .cost_analysis().get("flops", 0))
    f_packed = float(jax.jit(jax.grad(packed_loss)).lower(params).compile()
                     .cost_analysis().get("flops", 0))
    emit("packed_flops_fraction", 0.0,
         f"full={f_full:.3e};packed={f_packed:.3e};"
         f"fraction={f_packed / f_full:.3f};cost_model=0.60")


# ------------------------------------------- gated kernel backward savings
BENCH_KERNEL_BACKWARD_JSON = "BENCH_kernel_backward.json"


def bench_kernel_backward():
    """Kernel-path fwd+bwd vs the masked jnp reference across p_f/p_o/p_s
    mixes: wall time per fwd+grad call, the executed-MXU-FLOP account of
    the gate-aware kernels (static HLO FLOP counts cannot see runtime
    ``@pl.when`` skips — the interpret-mode grid lowers to a loop whose body
    XLA counts once; see docs/kernels.md), and the dispatched-bytes
    fraction of the compaction dispatch (live-slice grids, exact live
    counts as bounds). Besides the CSV rows, writes machine-readable
    ``BENCH_kernel_backward.json`` so the perf trajectory is tracked
    across PRs (``make bench-json``)."""
    import json

    from repro.kernels.d2ft_attention import (
        BWD_MATMULS_PER_TILE, gated_attention_dispatched_bytes,
        gated_attention_flops)
    from repro.kernels.ops import gated_attention
    from repro.kernels.ref import gated_attention_ref

    B, H, S, hd = 4, 4, 256, 64
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    q, k, v, ct = (jax.random.normal(kk, (B, H, S, hd)) for kk in ks)
    rng = np.random.default_rng(0)
    ones = np.ones((B, H))
    full_fwd, full_bwd = gated_attention_flops(ones, ones, S, hd, causal=True)
    full_fb, full_bb = gated_attention_dispatched_bytes(ones, ones, S, hd)

    def timed(fn):
        jax.block_until_ready(fn(q, k, v))          # compile + warm
        n = 3
        t0 = time.perf_counter()
        for _ in range(n):
            jax.block_until_ready(fn(q, k, v))
        return (time.perf_counter() - t0) / n * 1e6

    records = []
    # micro-batch mixes as (p_f, p_o, p_s) fractions of the (B, H) subnets
    for name, probs in [("pf5_po0_ps0", (1.0, 0.0, 0.0)),
                        ("pf3_po1_ps1", (0.6, 0.2, 0.2)),
                        ("pf1_po2_ps2", (0.2, 0.4, 0.4))]:
        ops_ = rng.choice(3, size=(B, H), p=probs)
        g_f = jnp.asarray((ops_ != 2).astype(np.float32))
        g_b = jnp.asarray((ops_ == 0).astype(np.float32))
        # exact live counts double as the static compaction bounds, so the
        # benchmark measures the live-slice grids the train loop dispatches
        live_f = max(1, int((ops_ != 2).sum()))
        live_b = max(1, int((ops_ == 0).sum()))

        def loss_kernel(q, k, v):
            # interpret auto-detects: compiled on TPU, interpreter on CPU
            out = gated_attention(q, k, v, g_f, g_b, live_fwd=live_f,
                                  live_bwd=live_b)
            return (out * ct).sum()

        def loss_ref(q, k, v):
            out = gated_attention_ref(q, k, v, g_f, g_b)
            return (out * ct).sum()

        kern = jax.jit(jax.value_and_grad(loss_kernel, argnums=(0, 1, 2)))
        refp = jax.jit(jax.value_and_grad(loss_ref, argnums=(0, 1, 2)))
        kern_us, ref_us = timed(kern), timed(refp)
        e_fwd, e_bwd = gated_attention_flops(np.asarray(g_f), np.asarray(g_b),
                                             S, hd, causal=True)
        d_fwd, d_bwd = gated_attention_dispatched_bytes(
            np.asarray(g_f), np.asarray(g_b), S, hd, live_fwd=live_f,
            live_bwd=live_b)
        flop_frac = (e_fwd + e_bwd) / (full_fwd + full_bwd)
        byte_frac = (d_fwd + d_bwd) / (full_fb + full_bb)
        emit(f"kernel_bwd_{name}", kern_us,
             f"ref_us={ref_us:.1f};executed_mxu_gflop={(e_fwd + e_bwd) / 1e9:.3f};"
             f"full_mxu_gflop={(full_fwd + full_bwd) / 1e9:.3f};"
             f"executed_fraction={flop_frac:.3f};"
             f"dispatched_bytes_fraction={byte_frac:.3f}")
        records.append({
            "mix": name,
            "p_fractions": {"p_f": probs[0], "p_o": probs[1], "p_s": probs[2]},
            "wall_us_per_call": kern_us,
            "ref_wall_us_per_call": ref_us,
            "dispatched_slices": {"fwd": live_f, "bwd": live_b, "total": B * H},
            "executed_mxu_flops": e_fwd + e_bwd,
            "full_mxu_flops": full_fwd + full_bwd,
            "executed_flop_fraction": flop_frac,
            "dispatched_bytes": {"fwd": d_fwd, "bwd": d_bwd},
            "full_dispatched_bytes": {"fwd": full_fb, "bwd": full_bb},
            "dispatched_bytes_fraction": byte_frac,
        })
    # ---- SSD / RG-LRU / MoE block-kernel mixes (contract parity with the
    # attention rows: wall time vs the masked reference, executed-FLOP and
    # dispatched-bytes fractions from each kernel's analytic account).
    # Appended AFTER the attention mixes so baseline indices 0-2 are stable.
    from repro.kernels.d2ft_moe import (gated_moe_dispatched_bytes,
                                        gated_moe_flops)
    from repro.kernels.d2ft_rglru import (gated_rglru_dispatched_bytes,
                                          gated_rglru_flops)
    from repro.kernels.d2ft_ssd import (gated_ssd_dispatched_bytes,
                                        gated_ssd_flops)
    from repro.kernels.ops import gated_moe_ffn, gated_rglru_scan, \
        gated_ssd_scan
    from repro.kernels.ref import (gated_moe_ffn_ref, gated_rglru_ref,
                                   gated_ssd_ref)

    def timed_on(fn, *args):
        jax.block_until_ready(fn(*args))            # compile + warm
        n = 3
        t0 = time.perf_counter()
        for _ in range(n):
            jax.block_until_ready(fn(*args))
        return (time.perf_counter() - t0) / n * 1e6

    def mix_gates(shape, probs):
        ops_ = rng.choice(3, size=shape, p=probs)
        return (ops_, jnp.asarray((ops_ != 2).astype(np.float32)),
                jnp.asarray((ops_ == 0).astype(np.float32)),
                max(1, int((ops_ != 2).sum())), max(1, int((ops_ == 0).sum())))

    block_mixes = [("pf3_po1_ps1", (0.6, 0.2, 0.2)),
                   ("pf1_po2_ps2", (0.2, 0.4, 0.4))]

    def record(kernel, name, probs, kern_us, ref_us, disp, e_flops, f_flops,
               d_bytes, f_bytes):
        flop_frac = e_flops / f_flops
        byte_frac = sum(d_bytes) / sum(f_bytes)
        emit(f"kernel_bwd_{kernel}_{name}", kern_us,
             f"ref_us={ref_us:.1f};executed_mxu_gflop={e_flops / 1e9:.3f};"
             f"full_mxu_gflop={f_flops / 1e9:.3f};"
             f"executed_fraction={flop_frac:.3f};"
             f"dispatched_bytes_fraction={byte_frac:.3f}")
        records.append({
            "mix": f"{kernel}_{name}", "kernel": kernel,
            "p_fractions": {"p_f": probs[0], "p_o": probs[1],
                            "p_s": probs[2]},
            "wall_us_per_call": kern_us,
            "ref_wall_us_per_call": ref_us,
            "dispatched_slices": disp,
            "executed_mxu_flops": e_flops,
            "full_mxu_flops": f_flops,
            "executed_flop_fraction": flop_frac,
            "dispatched_bytes": {"fwd": d_bytes[0], "bwd": d_bytes[1]},
            "full_dispatched_bytes": {"fwd": f_bytes[0], "bwd": f_bytes[1]},
            "dispatched_bytes_fraction": byte_frac,
        })

    # SSD chunked scan: (sample, head) slices
    Bs, Hs, Ss, Ps, Ns, Qs = 4, 8, 256, 16, 16, 64
    kss = jax.random.split(jax.random.PRNGKey(1), 5)
    xs = jax.random.normal(kss[0], (Bs, Ss, Hs, Ps))
    das = -jax.nn.softplus(jax.random.normal(kss[1], (Bs, Ss, Hs)))
    Bms = jax.random.normal(kss[2], (Bs, Ss, Ns)) * 0.5
    Cms = jax.random.normal(kss[3], (Bs, Ss, Ns)) * 0.5
    cts = jax.random.normal(kss[4], (Bs, Ss, Hs, Ps))
    ones_s = np.ones((Bs, Hs))
    sflops_full = sum(gated_ssd_flops(ones_s, ones_s, Ss, Ps, Ns, chunk=Qs))
    sbytes_full = gated_ssd_dispatched_bytes(ones_s, ones_s, Ss, Ps, Ns,
                                             chunk=Qs)
    for name, probs in block_mixes:
        ops_, g_f, g_b, live_f, live_b = mix_gates((Bs, Hs), probs)
        kern = jax.jit(jax.value_and_grad(
            lambda x, da, Bm, Cm: (gated_ssd_scan(
                x, da, Bm, Cm, g_f, g_b, chunk=Qs, live_fwd=live_f,
                live_bwd=live_b) * cts).sum(), argnums=(0, 1, 2, 3)))
        refp = jax.jit(jax.value_and_grad(
            lambda x, da, Bm, Cm: (gated_ssd_ref(
                x, da, Bm, Cm, g_f, g_b, chunk=Qs) * cts).sum(),
            argnums=(0, 1, 2, 3)))
        e_flops = sum(gated_ssd_flops(np.asarray(g_f), np.asarray(g_b),
                                      Ss, Ps, Ns, chunk=Qs))
        d_bytes = gated_ssd_dispatched_bytes(
            np.asarray(g_f), np.asarray(g_b), Ss, Ps, Ns, chunk=Qs,
            live_fwd=live_f, live_bwd=live_b)
        record("ssd", name, probs, timed_on(kern, xs, das, Bms, Cms),
               timed_on(refp, xs, das, Bms, Cms),
               {"fwd": live_f, "bwd": live_b, "total": Bs * Hs},
               e_flops, sflops_full, d_bytes, sbytes_full)

    # RG-LRU recurrence: (sample, channel-band) slices
    Br, Sr, Wr, Gr, Qr = 4, 256, 256, 8, 64
    Wgr = Wr // Gr
    krs = jax.random.split(jax.random.PRNGKey(2), 3)
    lar = -jax.nn.softplus(jax.random.normal(krs[0], (Br, Sr, Wr)))
    br = jax.random.normal(krs[1], (Br, Sr, Wr))
    ctr = jax.random.normal(krs[2], (Br, Sr, Wr))
    ones_r = np.ones((Br, Gr))
    rflops_full = sum(gated_rglru_flops(ones_r, ones_r, Sr, Wgr, chunk=Qr))
    rbytes_full = gated_rglru_dispatched_bytes(ones_r, ones_r, Sr, Wgr,
                                               chunk=Qr)
    for name, probs in block_mixes:
        ops_, g_f, g_b, live_f, live_b = mix_gates((Br, Gr), probs)
        kern = jax.jit(jax.value_and_grad(
            lambda la, b: (gated_rglru_scan(
                la, b, g_f, g_b, chunk=Qr, live_fwd=live_f,
                live_bwd=live_b) * ctr).sum(), argnums=(0, 1)))
        refp = jax.jit(jax.value_and_grad(
            lambda la, b: (gated_rglru_ref(la, b, g_f, g_b,
                                           chunk=Qr) * ctr).sum(),
            argnums=(0, 1)))
        e_flops = sum(gated_rglru_flops(np.asarray(g_f), np.asarray(g_b),
                                        Sr, Wgr, chunk=Qr))
        d_bytes = gated_rglru_dispatched_bytes(
            np.asarray(g_f), np.asarray(g_b), Sr, Wgr, chunk=Qr,
            live_fwd=live_f, live_bwd=live_b)
        record("rglru", name, probs, timed_on(kern, lar, br),
               timed_on(refp, lar, br),
               {"fwd": live_f, "bwd": live_b, "total": Br * Gr},
               e_flops, rflops_full, d_bytes, rbytes_full)

    # MoE expert FFN: (expert, capacity-block) tiles, live slots packed
    # first per expert (mirrors the model's gate-aware dispatch) so the
    # live_slots capacity truncation is real
    Em, Cm_, Dm, Fm, bcm = 4, 256, 64, 128, 32
    ncb = Cm_ // bcm
    kms = jax.random.split(jax.random.PRNGKey(3), 5)
    xbm = jax.random.normal(kms[0], (Em, Cm_, Dm))
    wum = jax.random.normal(kms[1], (Em, Dm, Fm)) / np.sqrt(Dm)
    wgm = jax.random.normal(kms[2], (Em, Dm, Fm)) / np.sqrt(Dm)
    wdm = jax.random.normal(kms[3], (Em, Fm, Dm)) / np.sqrt(Fm)
    ctm = jax.random.normal(kms[4], (Em, Cm_, Dm))
    mflops_full = sum(gated_moe_flops(np.ones((Em, ncb)), np.ones((Em, ncb)),
                                      bcm, Dm, Fm))
    mbytes_full = gated_moe_dispatched_bytes(Em, ncb, bcm, Dm, Fm)
    for name, probs in block_mixes:
        ops_ = np.sort(rng.choice(3, size=(Em, ncb), p=probs), axis=1)
        fm_blk = (ops_ != 2).astype(np.float32)
        bm_blk = (ops_ == 0).astype(np.float32)
        fs = jnp.asarray(np.repeat(fm_blk, bcm, axis=1))
        bs = jnp.asarray(np.repeat(bm_blk, bcm, axis=1))
        live_slots = max(bcm, int(fm_blk.sum(axis=1).max()) * bcm)
        ncb_t = live_slots // bcm
        kern = jax.jit(jax.value_and_grad(
            lambda xb, wu, wg, wd: (gated_moe_ffn(
                xb, wu, wg, wd, fs, bs, block_c=bcm,
                live_slots=live_slots) * ctm).sum(), argnums=(0, 1, 2, 3)))
        refp = jax.jit(jax.value_and_grad(
            lambda xb, wu, wg, wd: (gated_moe_ffn_ref(
                xb, wu, wg, wd, jnp.asarray(fm_blk), jnp.asarray(bm_blk),
                act=jax.nn.silu, block_c=bcm) * ctm).sum(),
            argnums=(0, 1, 2, 3)))
        e_flops = sum(gated_moe_flops(fm_blk, bm_blk, bcm, Dm, Fm))
        d_bytes = gated_moe_dispatched_bytes(Em, ncb_t, bcm, Dm, Fm)
        record("moe", name, probs, timed_on(kern, xbm, wum, wgm, wdm),
               timed_on(refp, xbm, wum, wgm, wdm),
               {"fwd": int(fm_blk.sum()), "bwd": int(bm_blk.sum()),
                "total": Em * ncb},
               e_flops, mflops_full, d_bytes, mbytes_full)

    payload = {
        "bench": "kernel_backward",
        "shape": {"B": B, "H": H, "S": S, "head_dim": hd},
        "backward_matmuls_per_tile": BWD_MATMULS_PER_TILE,
        "backend": jax.default_backend(),
        "mixes": records,
    }
    with open(BENCH_KERNEL_BACKWARD_JSON, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"# wrote {BENCH_KERNEL_BACKWARD_JSON}", file=sys.stderr)


# ------------------------------------------- distributed-step comm savings
def bench_distributed_step():
    """Paper Eq. 4 executed: the shard_map gated train step on an
    8-host-device CPU mesh over a schedule x sync-mode matrix — paper-mix
    (40% p_f / 30% p_o / 30% p_s, concentrated) and uniform-half (spread)
    schedules under the masked psum and the ZeRO reduce-scatter/all-gather
    sync, vs the all-p_f baseline. Reports wall time per step, per-device
    collective bytes parsed from compiled HLO, the sync plan's wire-byte
    model, and the ``zero_sync`` summary (wire fractions + sharded-moment
    memory). Runs ``benchmarks/dist_step.py`` in a subprocess because the
    forced host-device count must be set before jax initializes (this
    process already locked its backend). Writes
    ``BENCH_distributed_step.json``."""
    import os
    import subprocess

    env = dict(os.environ)
    env.setdefault("JAX_PLATFORMS", "cpu")
    # dist_step.py appends the host-device-count flag to XLA_FLAGS itself
    proc = subprocess.run([sys.executable, "-m", "benchmarks.dist_step"],
                          env=env, capture_output=True, text=True)
    if proc.returncode != 0:
        sys.stderr.write(proc.stdout + proc.stderr)
        raise RuntimeError("benchmarks.dist_step failed")
    for line in proc.stdout.splitlines():
        if line.strip():
            print(line)
    sys.stderr.write(proc.stderr)


# ------------------------------------------ elastic fault-tolerance matrix
def bench_elastic():
    """The four fault scenarios of docs/robustness.md through the elastic
    loop on an 8-host-device CPU mesh: straggler-aware replanning
    (mitigation ratio of the capacity-constrained makespan), device-dropout
    recovery (steps replayed + resume-parity error vs a survivors-only
    run), the NaN-burst gradient guard (steps skipped + loss gap vs the
    fault-free run), and the lo-fi local fallback after dropped sync
    rounds (merge count + progress). Runs ``benchmarks/elastic.py`` in a
    subprocess because the forced host-device count must be set before jax
    initializes. Writes ``BENCH_elastic.json`` (gated by
    ``tools/check_bench.py``)."""
    import os
    import subprocess

    env = dict(os.environ)
    env.setdefault("JAX_PLATFORMS", "cpu")
    proc = subprocess.run([sys.executable, "-m", "benchmarks.elastic"],
                          env=env, capture_output=True, text=True)
    if proc.returncode != 0:
        sys.stderr.write(proc.stdout + proc.stderr)
        raise RuntimeError("benchmarks.elastic failed")
    for line in proc.stdout.splitlines():
        if line.strip():
            print(line)
    sys.stderr.write(proc.stderr)


# ---------------------------------------------- paged-KV serving throughput
def bench_serving():
    """Gate-aware serving: a synthetic mixed-length request trace through
    the continuous-batching engine over the paged KV cache — tokens/sec,
    per-token latency p50/p99, the request-level knapsack wave plan and
    peak page occupancy. Deterministic counters are gated tightly, wall
    clock generously. Writes ``BENCH_serving.json``; see
    benchmarks/serving.py for the trace and engine geometry."""
    from benchmarks import serving
    serving.main([])


BENCHES = {
    "workload_variance": bench_workload_variance,
    "execution_time": bench_execution_time,
    "accuracy_vs_cost": bench_accuracy_vs_cost,
    "score_combos": bench_score_combos,
    "fwd_bwd_ratio": bench_fwd_bwd_ratio,
    "num_subnets": bench_num_subnets,
    "microbatch_size": bench_microbatch_size,
    "heterogeneous": bench_heterogeneous,
    "po_sweep": bench_po_sweep,
    "bilevel_vs_scaler": bench_bilevel_vs_scaler,
    "lora": bench_lora,
    "packed_flops": bench_packed_flops,
    "kernel_backward": bench_kernel_backward,
    "distributed_step": bench_distributed_step,
    "elastic": bench_elastic,
    "serving": bench_serving,
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated benchmark names (see --list)")
    ap.add_argument("--list", action="store_true",
                    help="print the available benchmark names and exit")
    args = ap.parse_args()
    if args.list:
        print("\n".join(BENCHES))
        return
    names = list(BENCHES) if args.only is None else args.only.split(",")
    unknown = sorted(set(names) - set(BENCHES))
    if unknown:
        # fail loudly: a typo'd --only used to run zero benchmarks and
        # exit 0, which reads as "all green" in a script
        ap.error(f"unknown benchmark(s): {', '.join(unknown)}\n"
                 f"valid names: {', '.join(BENCHES)}")
    print("name,us_per_call,derived")
    for n in names:
        BENCHES[n]()


if __name__ == "__main__":
    main()
