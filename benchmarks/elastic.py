"""Standalone elastic fault-tolerance benchmark driver (8 host CPU
devices).

Must be its own process: ``--xla_force_host_platform_device_count`` is
read once, when jax initializes, so the flag is set here before any jax
import. Run directly::

  PYTHONPATH=src python -m benchmarks.elastic [--n-devices 8]

or through ``python -m benchmarks.run --only elastic``, which subprocesses
this module so the forced device count never leaks into the parent's jax
runtime. Runs the four fault scenarios of
``repro.launch.diststep.measure_elastic`` (straggler replanning, dropout
recovery, NaN-burst guard, lo-fi fallback), writes ``BENCH_elastic.json``
— gated by ``tools/check_bench.py`` — and prints
``name,us_per_call,derived`` CSV rows (no header) on stdout.
"""
import os

# append rather than setdefault: a pre-existing XLA_FLAGS value must not
# swallow the device-count flag
_FLAG = "--xla_force_host_platform_device_count"
if _FLAG not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") + " "
                               + _FLAG + "=8").strip()
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import argparse
import json
import sys

BENCH_ELASTIC_JSON = "BENCH_elastic.json"


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--n-devices", type=int, default=8)
    ap.add_argument("--out", default=BENCH_ELASTIC_JSON)
    args = ap.parse_args(argv)

    from repro.launch.diststep import measure_elastic
    rec = measure_elastic(args.n_devices)
    s = rec["straggler"]
    print(f"elastic_straggler,{s['wall_s'] * 1e6:.1f},"
          f"mitigation_ratio={s['mitigation_ratio']:.4f};"
          f"makespan={s['makespan']:.3f};"
          f"unmitigated={s['unmitigated_makespan']:.3f};"
          f"straggler_unit_time={s['straggler_unit_time']:.3f};"
          f"capacity_refreshes={s['n_capacity_refreshes']}")
    d = rec["dropout"]
    print(f"elastic_dropout,{d['wall_s'] * 1e6:.1f},"
          f"recovery_steps={d['recovery_steps']};"
          f"ckpt_step={d['ckpt_step']};"
          f"n_devices_after={d['n_devices_after']};"
          f"resume_parity_diff={d['resume_parity_diff']:.3e};"
          f"resume_opt_diff={d['resume_opt_diff']:.3e}")
    g = rec["nan_guard"]
    print(f"elastic_nan_guard,{g['wall_s'] * 1e6:.1f},"
          f"steps_skipped={g['steps_skipped']};"
          f"skip_steps={g['skip_steps']};"
          f"loss_gap={g['loss_gap']:.4f};"
          f"gap_fraction={g['gap_fraction']:.4f}")
    lo = rec["lofi"]
    print(f"elastic_lofi,{lo['wall_s'] * 1e6:.1f},"
          f"fallback_step={lo['fallback_step']};"
          f"sync_drops={lo['sync_drops']};"
          f"n_merges={lo['n_merges']};"
          f"final_mode_local={lo['final_mode_local']};"
          f"loss_drop={lo['loss_drop']:.4f}")
    with open(args.out, "w") as f:
        json.dump(rec, f, indent=2)
    print(f"# wrote {args.out}", file=sys.stderr)


if __name__ == "__main__":
    main()
