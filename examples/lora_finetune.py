"""D2FT-LoRA (paper §II-D): freeze the base model, fine-tune low-rank
adapters under a D2FT schedule; includes the fused Pallas LoRA matmul.

  PYTHONPATH=src python examples/lora_finetune.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import D2FTConfig, ModelConfig
from repro.core.lora import init_lora, merge_lora, lora_param_count
from repro.core.d2ft import plan_schedule
from repro.core.schedule import gates_from_schedule
from repro.core.scores import compute_scores, transformer_blocks
from repro.data.synthetic import lm_batches, microbatch_assignment, split_microbatches
from repro.kernels.ops import lora_linear
from repro.models.transformer import init_model, lm_loss
from repro.optim.optimizers import sgd

cfg = ModelConfig(name="base", arch_type="dense", n_layers=4, d_model=128,
                  n_heads=4, n_kv_heads=4, d_ff=256, vocab_size=1024)
base = init_model(jax.random.PRNGKey(0), cfg)
lora = init_lora(jax.random.PRNGKey(1), base, rank=8)
print(f"adapters: {lora_param_count(lora)} trainable params "
      f"({len(lora)} targets)")

# the fused kernel: x·W + s·(x·A)·B without materializing x·A in HBM
entry = lora["cycles/0/attn/wq"]
x = jax.random.normal(jax.random.PRNGKey(2), (128, cfg.d_model))
y = lora_linear(x, base["cycles"][0]["attn"]["wq"][0], entry["a"][0],
                entry["b"][0], scale=2.0)
print(f"fused lora_linear output: {y.shape}")

d2 = D2FTConfig(n_microbatches=4, n_pf=3, n_po=0, head_groups=4)
opt = sgd(0.1)
state = opt.init(lora)
batches = list(lm_batches(0, cfg.vocab_size, 8, 64, 60))

# scoring pass on merged model (weight magnitude of frozen weights backward,
# fisher of adapter grads forward)
mbs = split_microbatches(batches[0], 4)
def loss_fn(p, mb):
    return lm_loss(p, cfg, mb["tokens"], mb["labels"])[0]
bw, fw = compute_scores(loss_fn, merge_lora(base, lora, 1.0),
                        lambda t: transformer_blocks(t, cfg), mbs, G=4)
sched = plan_schedule(d2, bw, fw, cfg.n_layers, 4)
mb_of = microbatch_assignment(8, 4)
gates = gates_from_schedule(sched, mb_of)

@jax.jit
def step(lora_p, st, batch):
    def loss(lr):
        merged = merge_lora(base, lr, 1.0)
        return lm_loss(merged, cfg, batch["tokens"], batch["labels"],
                       gates=gates)[0]
    l, g = jax.value_and_grad(loss)(lora_p)
    lora_p, st = opt.update(g, st, lora_p)
    return lora_p, st, l

losses = []
for batch in batches:
    lora, state, l = step(lora, state, batch)
    losses.append(float(l))
print(f"D2FT-LoRA loss: {np.mean(losses[:5]):.3f} -> "
      f"{np.mean(losses[-5:]):.3f}")
