"""Serving example: batched prefill + greedy decode with KV caches across
block families (dense KV, ring-buffer SWA, SSM state).

  PYTHONPATH=src python examples/serve.py
"""
import time

import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.models.transformer import init_model
from repro.serving.decode import generate

for arch in ("gemma3-1b", "mamba2-130m", "recurrentgemma-2b"):
    cfg = get_smoke_config(arch)
    params = init_model(jax.random.PRNGKey(0), cfg)
    prompt = jax.random.randint(jax.random.PRNGKey(1), (4, 8), 0,
                                cfg.vocab_size)
    t0 = time.time()
    out = generate(params, cfg, prompt, n_tokens=16)
    dt = time.time() - t0
    print(f"{arch:20s} generated {out.shape} in {dt:.1f}s "
          f"(batch=4, 16 new tokens)")
