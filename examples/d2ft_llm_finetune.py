"""End-to-end driver: fine-tune a ~100M-param LLM with D2FT for a few
hundred steps on synthetic Markov data, masked vs packed execution paths.

  PYTHONPATH=src python examples/d2ft_llm_finetune.py [--steps 200]
"""
import argparse
import time

import jax
import numpy as np

from repro.configs.base import D2FTConfig, ModelConfig
from repro.data.synthetic import lm_batches
from repro.models.transformer import init_model
from repro.optim.optimizers import adamw
from repro.train.loop import finetune

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=200)
ap.add_argument("--packed", action="store_true")
ap.add_argument("--kernel", action="store_true",
                help="route attention through the Pallas gated flash kernel "
                     "(gate-aware backward; interpret mode on CPU)")
args = ap.parse_args()
if args.packed and args.kernel:
    ap.error("--packed and --kernel are mutually exclusive (the packed "
             "gather path bypasses the gated attention kernel)")

# ~100M params: 12 layers, d_model 768 (GPT-2-small-ish)
cfg = ModelConfig(name="llm100m", arch_type="dense", n_layers=12,
                  d_model=768, n_heads=12, n_kv_heads=12, d_ff=3072,
                  vocab_size=8192)
params = init_model(jax.random.PRNGKey(0), cfg)
n_params = sum(int(np.prod(x.shape)) for x in jax.tree.leaves(params))
print(f"model: {n_params/1e6:.1f}M params")

d2 = D2FTConfig(n_microbatches=4, n_pf=2, n_po=1, head_groups=12)
print(f"D2FT budget: compute {(2 + 0.4) / 4:.0%}, comm {(2 + 0.5) / 4:.0%}")

batches = lm_batches(0, cfg.vocab_size, batch=8, seq=128, steps=args.steps)
t0 = time.time()
params, _, log = finetune(params, cfg, d2, adamw(3e-4), batches,
                          steps=args.steps, packed=args.packed,
                          use_kernel=args.kernel)
path = "packed" if args.packed else ("kernel" if args.kernel else "masked")
print(f"{args.steps} steps ({path} path) in {time.time()-t0:.0f}s")
print(f"loss: {np.mean(log.losses[:10]):.3f} -> "
      f"{np.mean(log.losses[-10:]):.3f}")
