"""Quickstart: D2FT in ~60 lines.

Fine-tunes a small ViT on a synthetic task with the paper's full pipeline:
scoring pass -> bi-level knapsack schedule -> gated fine-tuning, and
compares against standard full fine-tuning at the same step count.

  PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np
import jax
import jax.numpy as jnp

from repro.configs.base import D2FTConfig
from repro.core.d2ft import plan_schedule
from repro.core.cost_model import compute_cost, comm_cost, workload_variance
from repro.core.scores import compute_scores, vit_blocks
from repro.data.synthetic import image_batches, make_image_task
from repro.models.vit import ViTConfig, init_vit, vit_loss
from repro.optim.optimizers import sgd
from repro.train.loop import eval_vit, finetune_vit

# 1. model + synthetic data (offline container: CIFAR stand-in)
cfg = ViTConfig(n_layers=2, d_model=96, n_heads=6, d_ff=192, patch=8,
                image_size=32, n_classes=4)
task = make_image_task(3, n_classes=4, image_size=32)
params = init_vit(jax.random.PRNGKey(0), cfg)

# 2. D2FT budget: 3 full + 1 forward-only of 5 micro-batches => 68% compute
d2 = D2FTConfig(n_microbatches=5, n_pf=3, n_po=1)


def schedule_fn(step, params, images, labels):
    if step % 16 != 0:
        return None                      # reuse the last schedule
    mbs = list(zip(np.split(images, 5), np.split(labels, 5)))

    def loss_fn(p, mb):
        return vit_loss(p, jnp.asarray(mb[0]), jnp.asarray(mb[1]), cfg)[0]

    bw, fw = compute_scores(loss_fn, params, vit_blocks, mbs, cfg.n_heads)
    sched = plan_schedule(d2, bw, fw, cfg.n_layers, cfg.n_heads)
    print(f"  step {step}: schedule compute={compute_cost(sched.table):.0%} "
          f"comm={comm_cost(sched.table):.0%} "
          f"variance={workload_variance(sched.table):.2f}")
    return sched


# 3. fine-tune with the D2FT schedule
print("D2FT fine-tuning (68% compute budget):")
p1, _, log = finetune_vit(jax.tree.map(jnp.copy, params), cfg, sgd(0.05),
                          image_batches(task, 5, 40, 40), steps=40,
                          schedule_fn=schedule_fn, n_microbatches=5)
acc_d2ft = eval_vit(p1, cfg, image_batches(task, 7, 40, 5))

print("standard full fine-tuning (100% compute):")
p2, _, _ = finetune_vit(jax.tree.map(jnp.copy, params), cfg, sgd(0.05),
                        image_batches(task, 5, 40, 40), steps=40)
acc_std = eval_vit(p2, cfg, image_batches(task, 7, 40, 5))

print(f"\ntop-1: D2FT@68% = {acc_d2ft:.3f}   standard@100% = {acc_std:.3f}")
