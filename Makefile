# Tier-1 verify command (ROADMAP.md) and common dev entry points.
PY ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: test test-fast test-multidevice test-faults test-kernels \
	test-serving bench bench-json bench-check docs-check quickstart

test:
	$(PY) -m pytest -x -q

# the tier-1 CI lane: everything except the slow 8-host-device subprocess
# suites (those run via test-multidevice / test-faults in their own CI
# jobs with their own wall-clock budgets)
test-fast:
	$(PY) -m pytest -x -q -m "not multidevice and not faults"

test-multidevice:
	$(PY) -m pytest -x -q -m multidevice

# the elastic fault matrix: straggler replanning, dropout recovery,
# NaN-burst guard, lo-fi fallback on 8 emulated devices
# (tests/_fault_matrix.py via tests/test_faults.py; docs/robustness.md)
test-faults:
	$(PY) -m pytest -x -q -m faults

# attention + SSD/RG-LRU/MoE gated block kernels: grad parity vs the
# reference VJPs, compaction dispatch, config-zoo no-fallback coverage
# and the cross-path parity matrix
test-kernels:
	$(PY) -m pytest -x -q tests/test_kernels.py tests/test_kernel_grads.py \
		tests/test_compaction.py tests/test_block_kernels.py \
		tests/test_config_zoo.py tests/test_parity_matrix.py

# the serving suite: paged-KV decode parity, the Pallas paged-decode
# kernel, page-manager/packer properties and engine invariants
test-serving:
	$(PY) -m pytest -x -q tests/test_serving.py \
		tests/test_serving_properties.py

bench:
	$(PY) -m benchmarks.run $(if $(ONLY),--only $(ONLY))

# machine-readable perf snapshots: BENCH_kernel_backward.json (wall time,
# executed-FLOP fraction, dispatched-bytes fraction per op mix),
# BENCH_distributed_step.json (per-device all-reduce bytes, paper-mix vs
# all-p_f, schedule x sync-mode matrix incl. ZeRO-1/ZeRO-3, on an
# 8-host-device mesh), BENCH_elastic.json (straggler mitigation ratio,
# dropout recovery parity, NaN-guard skip accounting, lo-fi fallback;
# docs/robustness.md) and BENCH_serving.json (paged-KV continuous-batching
# throughput, per-token latency, knapsack wave plan, page occupancy)
bench-json:
	$(PY) -m benchmarks.run --only kernel_backward,distributed_step,elastic,serving

# regenerate the snapshots AND gate them against the committed baselines
# (benchmarks/bench_baselines.json) — what the CI `bench` job enforces
bench-check: bench-json
	$(PY) tools/check_bench.py

# no dangling file references in docs/*.md + README (CI `docs` job)
docs-check:
	$(PY) tools/check_docs.py

quickstart:
	$(PY) examples/quickstart.py
