# Tier-1 verify command (ROADMAP.md) and common dev entry points.
PY ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: test test-kernels bench bench-json docs-check quickstart

test:
	$(PY) -m pytest -x -q

test-kernels:
	$(PY) -m pytest -x -q tests/test_kernels.py tests/test_kernel_grads.py \
		tests/test_compaction.py

bench:
	$(PY) -m benchmarks.run $(if $(ONLY),--only $(ONLY))

# machine-readable perf snapshots: BENCH_kernel_backward.json (wall time,
# executed-FLOP fraction, dispatched-bytes fraction per op mix) and
# BENCH_distributed_step.json (per-device all-reduce bytes, paper-mix vs
# all-p_f, on an 8-host-device mesh)
bench-json:
	$(PY) -m benchmarks.run --only kernel_backward,distributed_step

# no dangling file references in docs/*.md + README (CI `docs` job)
docs-check:
	$(PY) tools/check_docs.py

quickstart:
	$(PY) examples/quickstart.py
