# Tier-1 verify command (ROADMAP.md) and common dev entry points.
PY ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: test test-kernels bench quickstart

test:
	$(PY) -m pytest -x -q

test-kernels:
	$(PY) -m pytest -x -q tests/test_kernels.py tests/test_kernel_grads.py

bench:
	$(PY) -m benchmarks.run $(if $(ONLY),--only $(ONLY))

quickstart:
	$(PY) examples/quickstart.py
