# Tier-1 verify command (ROADMAP.md) and common dev entry points.
PY ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: test test-kernels bench bench-json quickstart

test:
	$(PY) -m pytest -x -q

test-kernels:
	$(PY) -m pytest -x -q tests/test_kernels.py tests/test_kernel_grads.py \
		tests/test_compaction.py

bench:
	$(PY) -m benchmarks.run $(if $(ONLY),--only $(ONLY))

# kernel-backward perf snapshot -> BENCH_kernel_backward.json (wall time,
# executed-FLOP fraction, dispatched-bytes fraction per op mix)
bench-json:
	$(PY) -m benchmarks.run --only kernel_backward

quickstart:
	$(PY) examples/quickstart.py
