"""Continuous-batching serving engine over the paged KV cache.

One engine owns: the model params, a ``PageManager`` (host-side page
accounting, serving/pages.py), the per-layer device pools
(serving/paged_decode.py) and a fixed bank of ``max_slots`` batch slots.
Requests are admitted into free slots **mid-flight** — a new sequence's
prefill lands while older sequences keep decoding — and every step advances
ALL live slots with one fused ``paged_decode_step`` launch. Finished or
evicted sequences return their pages to the free-list immediately; the next
waiting request takes the slot on the following step. This is continuous
batching in the vLLM sense, minus preemption: admission reserves the
worst-case page count (prompt + max_new_tokens), so a live sequence can
never fail to grow and nothing ever needs to be swapped out.

Slot/device contract (shared with ``paged_decode_step``):
* inactive slots keep an all-null page-table row and length 0 — the fused
  step writes their K/V into the null page sink and their logits are
  garbage the engine never reads. Recurrent (SSD/RG-LRU) slot state is
  likewise garbage for inactive slots and is overwritten at admission.
* batch-independence: a slot's logits depend only on its own row of
  (page_table, lengths) and its own pages/state — admitting or evicting a
  neighbour mid-flight cannot change another sequence's tokens. Pinned by
  ``tests/test_serving.py``. (MoE configs couple slots through router
  capacity — the engine works but exact independence holds for dense FFN.)

Prefill is the batched ``prefill_forward`` (one launch per admitted
request, jit-cached per prompt length) dumped straight into pages; the
decode loop is one jit-compiled step for the whole bank regardless of how
many slots are live. Greedy decoding only — sampling is orthogonal to the
paging/batching machinery this module pins down.
"""
from __future__ import annotations

import functools
from collections import deque
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.transformer import init_model, prefill_forward
from repro.serving.pages import PageManager, pages_needed
from repro.serving.paged_decode import (dump_prefill_to_pools,
                                        init_paged_pools, paged_decode_step)


# module-level jitted entry points with the config as a static argument:
# the compile cache is keyed on (cfg, page_size, use_kernel) and SHARED
# across engine instances — constructing a second engine for the same
# model (bench warm-up, tests) must not recompile anything
@functools.partial(jax.jit,
                   static_argnames=("cfg", "page_size", "use_kernel"))
def _jit_decode_step(params, pools, token, page_table, lengths, *,
                     cfg, page_size, use_kernel):
    return paged_decode_step(params, pools, cfg, token, page_table,
                             lengths, page_size=page_size,
                             use_kernel=use_kernel)


@functools.partial(jax.jit, static_argnames=("cfg",))
def _jit_prefill(params, tokens, *, cfg):
    return prefill_forward(params, cfg, tokens, raw_kv=True)


@dataclass(frozen=True)
class Request:
    """One generation request. ``uid`` is caller-chosen and must be unique
    among live + waiting requests."""
    uid: int
    prompt: np.ndarray                    # [S] int32
    max_new_tokens: int

    @property
    def prompt_len(self) -> int:
        return int(len(self.prompt))


@dataclass
class _Sequence:
    """Host-side state of one live slot."""
    req: Request
    slot: int
    n_cached: int                         # tokens whose KV is in pages
    generated: List[int] = field(default_factory=list)


class PagedServingEngine:
    """Continuous-batching engine. See module docstring for the design."""

    def __init__(self, params, cfg: ModelConfig, *, page_size: int = 16,
                 n_pages: int = 256, max_slots: int = 4,
                 max_seq_len: int = 512, eos_id: Optional[int] = None,
                 use_kernel: bool = False):
        assert cfg.causal, "serving needs a causal decoder"
        assert cfg.frontend == "none", "feature-frontend serving unsupported"
        self.params = params
        self.cfg = cfg
        self.page_size = int(page_size)
        self.max_slots = int(max_slots)
        self.max_seq_len = int(max_seq_len)
        self.eos_id = eos_id
        self.pm = PageManager(n_pages=n_pages, page_size=page_size)
        self.n_pmax = pages_needed(max_seq_len, page_size)
        self.pools = init_paged_pools(cfg, n_pages, page_size, max_slots)
        self.page_table = np.zeros((max_slots, self.n_pmax), np.int32)
        self.lengths = np.zeros((max_slots,), np.int32)
        self.free_slots: List[int] = list(range(max_slots - 1, -1, -1))
        self.live: Dict[int, _Sequence] = {}          # slot -> sequence
        self.waiting: deque = deque()
        self.finished: Dict[int, np.ndarray] = {}     # uid -> full tokens
        self.n_steps = 0

        self._step = functools.partial(
            _jit_decode_step, cfg=cfg, page_size=self.page_size,
            use_kernel=use_kernel)
        self._prefill = functools.partial(_jit_prefill, cfg=cfg)

    # ------------------------------------------------------------- frontend
    def submit(self, req: Request) -> None:
        worst = req.prompt_len + req.max_new_tokens
        if worst > self.max_seq_len:
            raise ValueError(
                f"request {req.uid}: prompt {req.prompt_len} + max_new "
                f"{req.max_new_tokens} exceeds max_seq_len "
                f"{self.max_seq_len}")
        if pages_needed(worst, self.page_size) > self.pm.capacity:
            raise MemoryError(
                f"request {req.uid} needs "
                f"{pages_needed(worst, self.page_size)} pages; pool has "
                f"{self.pm.capacity} — it can never be admitted")
        assert req.max_new_tokens >= 1
        self.waiting.append(req)

    def can_admit(self, req: Request) -> bool:
        return bool(self.free_slots) and \
            self.pm.can_admit(req.prompt_len + req.max_new_tokens)

    @property
    def n_live(self) -> int:
        return len(self.live)

    # ------------------------------------------------------------ admission
    def _admit(self, req: Request) -> None:
        slot = self.free_slots.pop()
        pages = self.pm.admit(req.uid, req.prompt_len,
                              req.prompt_len + req.max_new_tokens)
        prompt = jnp.asarray(req.prompt, jnp.int32)[None]
        logits, cache = self._prefill(self.params, tokens=prompt)
        self.pools = dump_prefill_to_pools(
            self.pools, cache, self.cfg, slot, pages, self.page_size,
            req.prompt_len)
        self.page_table[slot] = self.pm.table_array(req.uid, self.n_pmax)
        self.lengths[slot] = req.prompt_len
        seq = _Sequence(req=req, slot=slot, n_cached=req.prompt_len)
        first = int(jnp.argmax(logits[0, -1]))
        seq.generated.append(first)
        self.live[slot] = seq
        if self._is_finished(seq):
            self._retire(seq)

    def _is_finished(self, seq: _Sequence) -> bool:
        if len(seq.generated) >= seq.req.max_new_tokens:
            return True
        return self.eos_id is not None and seq.generated[-1] == self.eos_id

    # ------------------------------------------------------------- eviction
    def _release(self, seq: _Sequence) -> List[int]:
        freed = self.pm.free_seq(seq.req.uid)
        self.page_table[seq.slot] = 0
        self.lengths[seq.slot] = 0
        del self.live[seq.slot]
        self.free_slots.append(seq.slot)
        return freed

    def _retire(self, seq: _Sequence) -> None:
        self.finished[seq.req.uid] = np.concatenate(
            [np.asarray(seq.req.prompt, np.int32),
             np.asarray(seq.generated, np.int32)])
        self._release(seq)

    def evict(self, uid: int) -> List[int]:
        """Cancel a live or waiting request mid-flight. Returns the freed
        page ids (empty for a waiting request). The partial output is
        recorded in ``finished``."""
        for seq in list(self.live.values()):
            if seq.req.uid == uid:
                self.finished[uid] = np.concatenate(
                    [np.asarray(seq.req.prompt, np.int32),
                     np.asarray(seq.generated, np.int32)])
                return self._release(seq)
        for req in list(self.waiting):
            if req.uid == uid:
                self.waiting.remove(req)
                self.finished[uid] = np.asarray(req.prompt, np.int32)
                return []
        raise KeyError(f"request {uid} is neither live nor waiting")

    # ----------------------------------------------------------------- step
    def step(self) -> List[int]:
        """One engine step: admit what fits (FIFO, head-of-line blocking —
        the packer shapes the queue, the engine does not reorder), then
        advance every live slot by one token with a single fused launch.
        Returns the uids that finished this step."""
        while self.waiting and self.can_admit(self.waiting[0]):
            self._admit(self.waiting.popleft())
        if not self.live:
            return []

        token = np.zeros((self.max_slots, 1), np.int32)
        for slot, seq in self.live.items():
            token[slot, 0] = seq.generated[-1]
            newp = self.pm.append_token(seq.req.uid)
            if newp is not None:
                self.page_table[slot, seq.n_cached // self.page_size] = newp

        logits, self.pools = self._step(
            self.params, self.pools, token=jnp.asarray(token),
            page_table=jnp.asarray(self.page_table),
            lengths=jnp.asarray(self.lengths))
        self.n_steps += 1
        nxt = np.asarray(jnp.argmax(logits[:, 0], axis=-1), np.int32)

        done = []
        for slot, seq in list(self.live.items()):
            seq.n_cached += 1
            self.lengths[slot] = seq.n_cached
            seq.generated.append(int(nxt[slot]))
            if self._is_finished(seq):
                done.append(seq.req.uid)
                self._retire(seq)
        return done

    # ------------------------------------------------------------ batch run
    def run(self, requests: Sequence[Request]) -> Dict[int, np.ndarray]:
        """Submit all requests and step until drained. Returns
        uid -> full token array (prompt + generated)."""
        for r in requests:
            self.submit(r)
        while self.waiting or self.live:
            before = self.n_live
            self.step()
            if not self.live and self.waiting and before == 0 and \
                    not self.can_admit(self.waiting[0]):
                raise MemoryError(
                    f"deadlock: request {self.waiting[0].uid} cannot be "
                    "admitted into an empty engine")
        return dict(self.finished)

    def stats(self) -> dict:
        u = self.pm.utilization()
        u.update({"n_live": self.n_live, "n_waiting": len(self.waiting),
                  "n_finished": len(self.finished),
                  "n_steps": self.n_steps})
        return u


def make_engine(cfg: ModelConfig, *, seed: int = 0, **kw
                ) -> PagedServingEngine:
    """Init params + engine in one call (examples/bench)."""
    params = init_model(jax.random.PRNGKey(seed), cfg)
    return PagedServingEngine(params, cfg, **kw)
