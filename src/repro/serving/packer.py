"""Request-level knapsack: shape the serving queue into admission waves.

The training side solves WHERE micro-batches run with a multiple-knapsack
(``core/assignment.py``). Serving has the same shape of problem one level
up: N queued requests with known prompt lengths and generation budgets must
be packed against two hard resources — batch slots and KV pages — so that
no admission wave overflows the page pool and the waves carry near-equal
work (the pool drains wave by wave; a lopsided wave is a straggler exactly
like an overloaded device in training).

We reuse ``assign_microbatches`` verbatim by choosing the item weight to be
the request's **worst-case page count** (prompt + max_new tokens, ceil to
pages). Pages are the binding resource — the reservation-based admission in
``PageManager`` means a wave is feasible iff its summed worst-case pages fit
the pool — and page count is simultaneously a decent proxy for decode-time
attention cost, so balancing pages balances both memory and work. The
per-wave capacity is then literally the pool capacity, in the same units.

``plan_waves`` grows the wave count until the assignment is feasible (no
wave over the page budget, no wave over ``max_slots`` requests) — the
deterministic analogue of admission back-pressure. ``request_cost`` is the
finer FLOP-model cost (linear + quadratic prompt terms) used by the bench
to report imbalance, and available as an alternative weight.
"""
from __future__ import annotations

from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.core.assignment import (DeviceAssignment, assign_microbatches,
                                   rebalance_report)
from repro.serving.pages import pages_needed


def request_cost(prompt_len: int, max_new_tokens: int, *,
                 c_lin: float = 1.0, c_quad: float = 0.01) -> float:
    """FLOP-model cost of one request: prefill is linear + quadratic in the
    prompt (attention), decode adds max_new steps each attending to a
    growing history (~ S + max_new/2 average)."""
    s, m = float(prompt_len), float(max_new_tokens)
    prefill = c_lin * s + c_quad * s * s
    decode = m * (c_lin + c_quad * (s + m / 2.0))
    return prefill + decode


def worst_case_pages(prompt_len: int, max_new_tokens: int,
                     page_size: int) -> int:
    """Pages the request can ever need under reservation-based admission."""
    return pages_needed(prompt_len + max_new_tokens, page_size)


def plan_waves(requests: Sequence[Tuple[int, int]], *, page_size: int,
               page_budget: int, max_slots: int,
               max_waves: int = 1024) -> List[List[int]]:
    """Partition queued requests into admission waves.

    requests: [(prompt_len, max_new_tokens), ...]; page_budget: usable pages
    (``PageManager.capacity``); max_slots: engine batch slots. Returns a
    list of waves, each a list of request indices, such that every wave's
    summed worst-case pages fit the budget and no wave exceeds max_slots.
    Waves are balanced by the multiple-knapsack solver (pages as weights,
    budget as per-wave capacity); the wave count is the smallest feasible
    one, found by growing from the lower bound. Deterministic throughout.
    """
    n = len(requests)
    if n == 0:
        return []
    pages = np.array([worst_case_pages(s, m, page_size)
                      for s, m in requests], np.float64)
    too_big = [i for i in range(n) if pages[i] > page_budget]
    if too_big:
        raise ValueError(
            f"requests {too_big} exceed the page budget {page_budget} even "
            "alone (prompt + max_new too long for the pool)")
    lower = max(int(np.ceil(pages.sum() / page_budget)),
                int(np.ceil(n / max_slots)), 1)
    for n_waves in range(lower, max_waves + 1):
        if n_waves > n:
            break
        asg = assign_microbatches(pages, n_waves, capacities=page_budget)
        counts = asg.counts
        if rebalance_report(asg)["capacity_ok"] and \
                counts.max() <= max_slots:
            return [list(map(int, asg.items_of(k)))
                    for k in range(n_waves)]
    # one request per wave always fits (checked above)
    return [[i] for i in range(n)]


def pack_report(requests: Sequence[Tuple[int, int]],
                waves: List[List[int]], *, page_size: int) -> dict:
    """Imbalance summary for the bench artifact: per-wave pages and
    FLOP-model cost spread."""
    wave_pages = [sum(worst_case_pages(*requests[i], page_size)
                      for i in w) for w in waves]
    wave_cost = [sum(request_cost(*requests[i]) for i in w) for w in waves]
    return {
        "n_waves": len(waves),
        "wave_pages": wave_pages,
        "wave_cost_max": max(wave_cost) if wave_cost else 0.0,
        "wave_cost_mean": (sum(wave_cost) / len(wave_cost))
        if wave_cost else 0.0,
    }
