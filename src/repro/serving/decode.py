"""Serving: batched prefill + decode against the transformer KV caches.

``serve_step`` is the unit the decode dry-run shapes lower: ONE new token
per sequence against a cache of ``seq_len`` tokens. ``generate`` drives a
full prefill + N-token decode for the examples.

Prefill is production-shaped: one batched teacher-forced ``forward()`` pass
whose per-layer K/V (and SSD / RG-LRU state) is dumped straight into the
decode caches (``models.transformer.prefill_forward``) — O(1) launches.
The pre-PR sequential decode-path loop is kept as ``prefill_sequential``,
the cache-exact test oracle the parity suite pins the dump against
(``tests/test_serving.py``).

Serving is schedule-free: D2FT only changes *training* (which subnets run
a backward); the fine-tuned params decode through the ordinary dense path
here, so nothing in this module consumes a ``Schedule``. Sharded serving
reuses ``sharding.policy`` via the ``policy=`` hooks on
``decode_step``/``serve_step`` (the decode dry-run shapes exercise them).
Paged, continuously batched serving lives in ``serving/engine.py``.
See docs/serving.md for where this sits in the stack.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.transformer import (decode_step, forward, init_cache,
                                      prefill_forward)


def serve_step(params, cache, cfg: ModelConfig, token, t, policy=None):
    """One decode step: token [B,1] int32, t scalar = tokens already cached.
    Returns (next_token [B,1], logits [B,1,V], new_cache)."""
    logits, cache = decode_step(params, cache, cfg, token, t, policy=policy)
    next_tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
    return next_tok, logits, cache


def prefill(params, cfg: ModelConfig, tokens, max_len: int):
    """Batched prefill: one ``forward()`` pass + cache dump.

    Returns (logits [B,1,V] — the last position's logits, the greedy seed
    for decode — and the filled cache, positioned at t = S)."""
    logits, cache = prefill_forward(params, cfg, tokens, max_len)
    return logits[:, -1:], cache


def prefill_sequential(params, cfg: ModelConfig, tokens, max_len: int):
    """Sequential prefill through the decode path, one token at a time.

    O(S) launches — NOT the production path (that is ``prefill``); kept as
    the cache-exact oracle the serving tests compare the batched dump
    against, since it exercises exactly the kernels decode will use."""
    B, S = tokens.shape
    cache = init_cache(cfg, B, max_len)
    step = jax.jit(lambda c, tok, t: decode_step(params, c, cfg, tok, t))
    logits = None
    for i in range(S):
        logits, cache = step(cache, tokens[:, i:i + 1], jnp.int32(i))
    return logits, cache


def generate(params, cfg: ModelConfig, prompt, n_tokens: int,
             max_len: Optional[int] = None, *, sequential_prefill=False):
    """Greedy generation. prompt: [B, S] int32. Returns [B, S + n_tokens].
    ``sequential_prefill`` routes the pre-PR O(S) prefill loop (oracle)."""
    B, S = prompt.shape
    max_len = max_len or (S + n_tokens)
    fill = prefill_sequential if sequential_prefill else prefill
    logits, cache = fill(params, cfg, prompt, max_len)
    tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
    out = [prompt, tok]
    step = jax.jit(lambda c, tk, t: serve_step(params, c, cfg, tk, t))
    for i in range(n_tokens - 1):
        tok, _, cache = step(cache, tok, jnp.int32(S + i))
        out.append(tok)
    return jnp.concatenate(out, axis=1)
