"""Serving: batched prefill + decode against the transformer KV caches.

``serve_step`` is the unit the decode dry-run shapes lower: ONE new token
per sequence against a cache of ``seq_len`` tokens. ``generate`` drives a
full prefill + N-token decode for the examples.

Serving is schedule-free: D2FT only changes *training* (which subnets run
a backward); the fine-tuned params decode through the ordinary dense path
here, so nothing in this module consumes a ``Schedule``. Sharded serving
reuses ``sharding.policy`` via the ``policy=`` hooks on
``decode_step``/``serve_step`` (the decode dry-run shapes exercise them).
See docs/architecture.md for where this sits in the stack.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.transformer import decode_step, forward, init_cache


def serve_step(params, cache, cfg: ModelConfig, token, t, policy=None):
    """One decode step: token [B,1] int32, t scalar = tokens already cached.
    Returns (next_token [B,1], logits [B,1,V], new_cache)."""
    logits, cache = decode_step(params, cache, cfg, token, t, policy=policy)
    next_tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
    return next_tok, logits, cache


def prefill(params, cfg: ModelConfig, tokens, max_len: int):
    """Sequential prefill through the decode path (cache-exact; fine for
    example-scale runs — production prefill uses forward() + cache dump)."""
    B, S = tokens.shape
    cache = init_cache(cfg, B, max_len)
    step = jax.jit(lambda c, tok, t: decode_step(params, c, cfg, tok, t))
    logits = None
    for i in range(S):
        logits, cache = step(cache, tokens[:, i:i + 1], jnp.int32(i))
    return logits, cache


def generate(params, cfg: ModelConfig, prompt, n_tokens: int,
             max_len: Optional[int] = None):
    """Greedy generation. prompt: [B, S] int32. Returns [B, S + n_tokens]."""
    B, S = prompt.shape
    max_len = max_len or (S + n_tokens)
    logits, cache = prefill(params, cfg, prompt, max_len)
    tok = jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None]
    out = [prompt, tok]
    step = jax.jit(lambda c, tk, t: serve_step(params, c, cfg, tk, t))
    for i in range(n_tokens - 1):
        tok, _, cache = step(cache, tok, jnp.int32(S + i))
        out.append(tok)
    return jnp.concatenate(out, axis=1)
