"""Paged KV cache: fixed-size pages, per-sequence page tables, free-list.

The serving analogue of vLLM's block manager, host-side and deterministic
like the rest of the planning layer (``core/assignment.py``): device memory
for attention K/V is a pool of ``n_pages`` fixed-size pages per attention
layer, and each live sequence owns a *page table* — the ordered list of
page ids holding its tokens. Admitting a sequence allocates pages off an
explicit free-list; evicting it returns exactly those pages. Nothing here
touches jax: the device-side pools live in ``serving/paged_decode.py`` and
are indexed by the int32 table this module maintains.

Invariants (pinned by ``tests/test_serving_properties.py``):

* page 0 is the **null page** — permanently reserved, never handed out.
  Inactive batch slots and table padding point at it, so the fused decode
  step can write/gather unconditionally without corrupting live data;
* a page is owned by at most one sequence at a time (alloc/free round-trips
  are a bijection on the free-list);
* capacity is respected: admission *reserves* the worst-case page count
  (prompt + max new tokens) up front, so on-demand growth during decode can
  never fail mid-flight — there is no preemption path to get wrong.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional

import numpy as np


def pages_needed(n_tokens: int, page_size: int) -> int:
    """Pages required to hold n_tokens (ceil division; 0 tokens -> 0)."""
    return -(-int(n_tokens) // int(page_size))


@dataclass
class PageManager:
    """Free-list page allocator with per-sequence page tables.

    ``n_pages`` counts the whole pool including the reserved null page 0,
    matching the device pool's leading dimension. ``capacity`` (usable
    pages) is therefore ``n_pages - 1``.
    """
    n_pages: int
    page_size: int
    free: List[int] = field(default_factory=list)
    tables: Dict[int, List[int]] = field(default_factory=dict)
    lengths: Dict[int, int] = field(default_factory=dict)
    reserved: Dict[int, int] = field(default_factory=dict)

    def __post_init__(self):
        assert self.n_pages >= 2, "need at least the null page + one page"
        assert self.page_size >= 1
        # LIFO free-list, low ids first so allocation order is deterministic
        self.free = list(range(self.n_pages - 1, 0, -1))

    # ------------------------------------------------------------- queries
    @property
    def capacity(self) -> int:
        return self.n_pages - 1

    @property
    def n_free(self) -> int:
        return len(self.free)

    @property
    def n_reserved(self) -> int:
        return sum(self.reserved.values())

    def can_admit(self, n_tokens_worst_case: int) -> bool:
        """Whether a sequence whose lifetime needs at most
        ``n_tokens_worst_case`` tokens of KV can be admitted now. Counts
        *reservations*, not just live allocations, so concurrent sequences
        can always grow to their admitted worst case."""
        need = pages_needed(n_tokens_worst_case, self.page_size)
        return self.n_free - self.n_reserved >= need

    def owner_of(self, page: int) -> Optional[int]:
        for sid, tab in self.tables.items():
            if page in tab:
                return sid
        return None

    # ---------------------------------------------------------- transitions
    def admit(self, seq_id: int, n_tokens: int,
              n_tokens_worst_case: Optional[int] = None) -> List[int]:
        """Allocate pages for ``n_tokens`` of prompt KV and reserve headroom
        up to ``n_tokens_worst_case`` (default: no headroom). Returns the
        page table. Raises if the sequence exists or capacity is exceeded —
        callers gate on ``can_admit`` first."""
        if seq_id in self.tables:
            raise ValueError(f"sequence {seq_id} already admitted")
        worst = n_tokens if n_tokens_worst_case is None \
            else max(n_tokens, n_tokens_worst_case)
        if not self.can_admit(worst):
            raise MemoryError(
                f"cannot admit seq {seq_id}: needs "
                f"{pages_needed(worst, self.page_size)} pages, "
                f"{self.n_free - self.n_reserved} unreserved free")
        n = pages_needed(n_tokens, self.page_size)
        table = [self.free.pop() for _ in range(n)]
        self.tables[seq_id] = table
        self.lengths[seq_id] = int(n_tokens)
        self.reserved[seq_id] = pages_needed(worst, self.page_size) - n
        return list(table)

    def append_token(self, seq_id: int) -> Optional[int]:
        """Account one more token for ``seq_id``; allocates (and returns) a
        new page when the token crosses a page boundary, else None. Draws
        from the admission reservation, so it cannot fail."""
        table = self.tables[seq_id]
        self.lengths[seq_id] += 1
        if pages_needed(self.lengths[seq_id], self.page_size) <= len(table):
            return None
        if self.reserved[seq_id] <= 0:
            raise MemoryError(
                f"seq {seq_id} grew past its admission reservation")
        self.reserved[seq_id] -= 1
        page = self.free.pop()
        table.append(page)
        return page

    def free_seq(self, seq_id: int) -> List[int]:
        """Evict: return the sequence's pages (and reservation) to the pool.
        Returns the freed page ids."""
        table = self.tables.pop(seq_id)
        del self.lengths[seq_id]
        del self.reserved[seq_id]
        self.free.extend(reversed(table))
        return list(table)

    # ------------------------------------------------------------ integrity
    def check(self) -> None:
        """Assert the structural invariants (cheap; tests call it after
        every transition)."""
        owned = [p for tab in self.tables.values() for p in tab]
        assert len(owned) == len(set(owned)), "page owned twice"
        assert 0 not in owned and 0 not in self.free, "null page leaked"
        assert not (set(owned) & set(self.free)), "page both owned and free"
        assert len(owned) + len(self.free) == self.capacity, \
            "pages lost or duplicated"
        assert self.n_reserved <= self.n_free, "reservation exceeds free"
        for sid, tab in self.tables.items():
            assert len(tab) == pages_needed(self.lengths[sid],
                                            self.page_size), \
                f"seq {sid}: table size != pages_needed(length)"

    def table_array(self, seq_id: int, width: int) -> np.ndarray:
        """[width] int32 page table row, padded with the null page 0 (the
        decode kernels' index maps require every entry to be a valid page
        id; padded entries are masked by the sequence length)."""
        tab = self.tables[seq_id]
        assert len(tab) <= width, (len(tab), width)
        row = np.zeros(width, np.int32)
        row[:len(tab)] = tab
        return row

    def utilization(self) -> dict:
        """Occupancy counters for the bench/report path."""
        tokens = sum(self.lengths.values())
        in_use = self.capacity - self.n_free
        return {
            "pages_in_use": in_use,
            "pages_free": self.n_free,
            "pages_reserved": self.n_reserved,
            "tokens_cached": tokens,
            "slot_utilization": (tokens / (in_use * self.page_size))
            if in_use else 0.0,
        }
