"""Paged decode: one fused step over the active serving batch.

The continuous-batching engine (serving/engine.py) keeps attention K/V in
fixed-size *pages* owned by a ``PageManager`` (serving/pages.py) instead of
one contiguous [B, max_len, ...] cache per layer. This module is the device
side of that design:

* ``init_paged_pools`` — per-layer state, flat (no cycle stacking): attention
  layers get K/V page pools ``[n_pages, page_size, n_kv, hd]`` shared by
  every sequence, recurrent layers (SSD / RG-LRU) keep ordinary per-slot
  dense state ``[max_slots, ...]`` since their cache is O(1) per sequence.
* ``paged_decode_step`` — ONE jit-able step for the whole slot batch: embed
  the incoming token per slot, write this step's K/V into each sequence's
  current page via its page table, attend over the table-gathered history,
  and return next-token logits plus the updated pools.

Two attention paths:
* the jnp gather reference (default): ``jnp.take(pool, page_table)`` →
  reshape to a contiguous [B, n_pmax * page_size, ...] view → masked SDPA.
  Exact and boring; the parity oracle for the kernel.
* ``use_kernel=True`` routes ``kernels.ops.paged_decode_attention`` — the
  Pallas flash-decode kernel whose BlockSpec index map reads the page table
  from scalar prefetch, so K/V pages stream HBM→VMEM directly by page id
  with no gathered copy of the history (kernels/paged_decode.py).

Layout/semantics contract (shared with the kernel and the engine):
* ``page_table``: [max_slots, n_pmax] int32. Row b lists the page ids
  holding slot b's history in order; unused entries are 0, the reserved
  *null page* that absorbs inactive-slot writes and is never allocated.
* ``lengths``: [max_slots] int32 = tokens already cached for the slot. The
  incoming token takes position ``lengths[b]`` (its page
  ``page_table[b, lengths[b] // page_size]`` must already be allocated —
  the engine guarantees this via worst-case reservation at admission).
* Local-window layers keep their full history in pages like global ones
  (no ring buffer — pages ARE the paging scheme) and enforce the window by
  masking positions ``<= t - window``. This trades O(S) pages for the
  O(W) ring to keep one pool layout; serving windows are small multiples
  of page_size so the waste is bounded and freed at eviction.

Serving is schedule-free (D2FT gates training only), but the kernel path
accepts per-(slot, head) forward gates so gate-elided adapters serve
through the same entry (see kernels/paged_decode.py).
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ATTN_GLOBAL, ATTN_LOCAL, RGLRU, SSD, ModelConfig
from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import rglru as rglru_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import apply_embedding, apply_mlp, apply_norm, softcap
from repro.models.transformer import layer_groups


# ---------------------------------------------------------- per-layer slicing
def layer_params(params, cfg: ModelConfig, i: int):
    """Flat view of layer i's params from the cycle-stacked tree.

    Layers 0..n_cycles*P-1 live stacked in ``params["cycles"][j]`` (leading
    dim = cycle); the remainder in ``params["rest"]``. The paged engine is
    unrolled per layer (each layer owns a differently-shaped pool), so it
    needs this flat addressing rather than the scan layout."""
    n_cycles, pat, _ = layer_groups(cfg)
    P = len(pat)
    if i < n_cycles * P:
        c, j = divmod(i, P)
        return jax.tree.map(lambda a: a[c], params["cycles"][j])
    return params["rest"][i - n_cycles * P]


def layer_cache_entry(cache, cfg: ModelConfig, i: int):
    """Same flat addressing for a prefill cache (``prefill_forward`` output,
    which stacks per-cycle entries exactly like params)."""
    return layer_params(cache, cfg, i)


# ----------------------------------------------------------------- pool init
def init_paged_pools(cfg: ModelConfig, n_pages: int, page_size: int,
                     max_slots: int) -> List[Dict[str, Any]]:
    """Per-layer device state, flat list of length n_layers.

    Attention layers: ``{"k","v"}: [n_pages, page_size, n_kv, hd]`` — page 0
    is the null page (write sink for inactive slots, table padding).
    SSD / RG-LRU layers: the ordinary dense decode cache at batch=max_slots
    (their per-sequence state is O(1), nothing to page)."""
    dtype = jnp.dtype(cfg.compute_dtype)
    pools: List[Dict[str, Any]] = []
    for kind in cfg.layer_kinds:
        if kind in (ATTN_GLOBAL, ATTN_LOCAL):
            shape = (n_pages, page_size, cfg.n_kv_heads,
                     cfg.resolved_head_dim)
            pools.append({"k": jnp.zeros(shape, dtype),
                          "v": jnp.zeros(shape, dtype)})
        elif kind == SSD:
            pools.append(ssm_mod.init_ssd_cache(max_slots, cfg.d_model,
                                                cfg.ssm, dtype))
        elif kind == RGLRU:
            pools.append(rglru_mod.init_rglru_cache(max_slots, cfg.d_model,
                                                    cfg.rglru, dtype))
        else:
            raise ValueError(kind)
    return pools


# ------------------------------------------------------- reference attention
def paged_attention_ref(q, k_pages, v_pages, page_table, lengths, *,
                        window: int = 0):
    """Gather-based paged attention, the kernel's parity oracle.

    q: [B, 1, H, hd] (post-rope); pools: [n_pages, page_size, n_kv, hd];
    page_table: [B, n_pmax] int32; lengths: [B] int32 — the query token sits
    at position ``lengths[b]`` and its K/V is already written. Attends over
    positions <= lengths[b] (window-masked for local layers).
    Returns [B, 1, H, hd]."""
    B = q.shape[0]
    n_pmax = page_table.shape[1]
    ps = k_pages.shape[1]
    # [B, n_pmax, ps, n_kv, hd] -> contiguous-history view [B, L, n_kv, hd]
    keys = jnp.take(k_pages, page_table, axis=0)
    vals = jnp.take(v_pages, page_table, axis=0)
    keys = keys.reshape(B, n_pmax * ps, *k_pages.shape[2:])
    vals = vals.reshape(B, n_pmax * ps, *v_pages.shape[2:])
    pos = jnp.arange(n_pmax * ps)[None, :]
    t = lengths[:, None]
    valid = pos <= t
    if window and window > 0:
        valid &= pos > t - window
    return attn._sdpa(q, keys, vals, valid[:, None, None, :])


# ------------------------------------------------------------ the fused step
def _write_kv(pool, kv, page_table, lengths, page_size: int):
    """Scatter this step's per-slot K (or V) [B, 1, n_kv, hd] into each
    slot's current page. Inactive slots (table row all-null) write into
    page 0, the designated sink."""
    B = kv.shape[0]
    pidx = page_table[jnp.arange(B), lengths // page_size]      # [B]
    off = lengths % page_size                                    # [B]
    return pool.at[pidx, off].set(kv[:, 0])


def paged_decode_step(params, pools, cfg: ModelConfig, token, page_table,
                      lengths, *, page_size: int, use_kernel: bool = False,
                      interpret: Optional[bool] = None):
    """One decode step for the whole slot batch.

    token: [B, 1] int32 (B = max_slots); page_table: [B, n_pmax] int32;
    lengths: [B] int32 (see module docstring for the contract). Returns
    (logits [B, 1, vocab], new_pools). Slots whose table row is all-null
    produce garbage logits the engine ignores — there is no active mask on
    device, inactivity is purely a bookkeeping notion."""
    cdt = jnp.dtype(cfg.compute_dtype)
    B = token.shape[0]
    x = apply_embedding(params["embed"], token).astype(cdt)

    new_pools = []
    for i, kind in enumerate(cfg.layer_kinds):
        p = layer_params(params, cfg, i)
        h = apply_norm(p["norm1"], x, cfg.norm)
        if kind in (ATTN_GLOBAL, ATTN_LOCAL):
            window = cfg.window if kind == ATTN_LOCAL else 0
            hd = cfg.resolved_head_dim
            q, k, v = attn._project_qkv(p["attn"], h, cfg.n_heads,
                                        cfg.n_kv_heads, hd)
            if cfg.rope:
                pos = lengths[:, None]                          # [B, 1]
                q = attn.apply_rope(q, pos, cfg.rope_theta)
                k = attn.apply_rope(k, pos, cfg.rope_theta)
            kp = _write_kv(pools[i]["k"], k, page_table, lengths, page_size)
            vp = _write_kv(pools[i]["v"], v, page_table, lengths, page_size)
            if use_kernel:
                from repro.kernels.ops import paged_decode_attention
                out = paged_decode_attention(
                    q[:, 0], kp, vp, page_table, lengths,
                    window=window, interpret=interpret)[:, None]
            else:
                out = paged_attention_ref(q, kp, vp, page_table, lengths,
                                          window=window)
            y = out.reshape(B, 1, cfg.n_heads * hd) @ p["attn"]["wo"]
            new_pools.append({"k": kp, "v": vp})
        elif kind == SSD:
            y, nc = ssm_mod.decode_ssd(p["ssd"], pools[i], h, cfg.d_model,
                                       cfg.ssm)
            new_pools.append(nc)
        elif kind == RGLRU:
            y, nc = rglru_mod.decode_rglru(p["rglru"], pools[i], h,
                                           cfg.rglru)
            new_pools.append(nc)
        else:
            raise ValueError(kind)
        x = x + y
        if "norm2" in p:
            h2 = apply_norm(p["norm2"], x, cfg.norm)
            if "moe" in p:
                y2, _ = moe_mod.apply_moe(p["moe"], h2, cfg.moe,
                                          act=cfg.mlp_act)
            else:
                y2 = apply_mlp(p["mlp"], h2, cfg.mlp_act, cfg.mlp_gated)
            x = x + y2

    x = apply_norm(params["final_norm"], x, cfg.norm)
    if cfg.tie_embeddings:
        logits = x @ params["embed"]["table"].T.astype(cdt)
    else:
        logits = x @ params["unembed"].astype(cdt)
    return softcap(logits, cfg.logit_softcap), new_pools


# --------------------------------------------------------- prefill page dump
def dump_prefill_to_pools(pools, cache, cfg: ModelConfig, slot: int,
                          pages: List[int], page_size: int, seq_len: int):
    """Write one sequence's prefill cache (``prefill_forward(raw_kv=True)``,
    batch 1) into the paged pools: attention K/V chunked into the given
    pages, recurrent state into row ``slot``. Returns new pools."""
    n = len(pages)
    assert n * page_size >= seq_len, (n, page_size, seq_len)
    page_ids = jnp.asarray(pages, jnp.int32)
    new_pools = []
    for i, kind in enumerate(cfg.layer_kinds):
        entry = layer_cache_entry(cache, cfg, i)
        if kind in (ATTN_GLOBAL, ATTN_LOCAL):
            def paged(full, pool):
                # [S, n_kv, hd] -> [n, page_size, n_kv, hd], zero-padded tail
                pad = n * page_size - seq_len
                chunks = jnp.pad(full[0], ((0, pad), (0, 0), (0, 0)))
                chunks = chunks.reshape(n, page_size, *full.shape[2:])
                return pool.at[page_ids].set(chunks)
            new_pools.append({"k": paged(entry["k"], pools[i]["k"]),
                              "v": paged(entry["v"], pools[i]["v"])})
        else:
            new_pools.append(jax.tree.map(
                lambda pool, st: pool.at[slot].set(st[0]), pools[i], entry))
    return new_pools
