"""Unified multi-axis parallelism config: ``MeshSpec`` + ``ParallelConfig``.

``MeshSpec(data, stage, tensor)`` names the three parallel axes every
distributed D2FT path speaks:

* ``data``   — batch sharding; gradient sync (masked / ZeRO-1 / ZeRO-3
  / streamed) always runs over this axis.
* ``stage``  — GPipe-style pipeline stages over contiguous layer ranges,
  balanced by *live* schedule cost (``core.assignment.assign_stages``).
* ``tensor`` — Megatron-style sharding of attention heads / FFN columns
  at the same (layer, head-group) granularity the schedule gates.

``MeshSpec.build()`` is the single mesh constructor (the legacy
``make_data_mesh`` / ``make_host_mesh`` / ``make_production_mesh`` in
``launch.mesh`` are thin wrappers over it).

``ParallelConfig`` is the frozen bundle of mesh spec + execution options
that ``train.loop.make_distributed_train_step`` / ``finetune_distributed``
and ``launch/train.py`` accept in place of the historical pile of loose
kwargs (``sync_mode=`` / ``streamed=`` / ``guard=`` / ...). All
cross-option validation lives in ``ParallelConfig.validate()`` — run at
construction — instead of being scattered across call sites. The old
kwargs still work for one release through a ``DeprecationWarning`` shim
in ``train.loop``.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional, Tuple

SYNC_MODES = ("masked", "zero", "zero3", "local")

# canonical axis names, in mesh order
DATA_AXIS, STAGE_AXIS, TENSOR_AXIS = "data", "stage", "tensor"


@dataclass(frozen=True)
class MeshSpec:
    """Logical (data, stage, tensor) mesh shape. Frozen and hashable so a
    ParallelConfig can key jit caches."""
    data: int = 1
    stage: int = 1
    tensor: int = 1

    @classmethod
    def parse(cls, text: str) -> "MeshSpec":
        """Parse ``"data=4,stage=2,tensor=1"`` (unlisted axes default 1)."""
        sizes = {}
        for part in filter(None, (p.strip() for p in text.split(","))):
            if "=" not in part:
                raise ValueError(
                    f"bad --mesh entry {part!r}: expected axis=size "
                    "(e.g. data=4,stage=2,tensor=1)")
            k, v = part.split("=", 1)
            k = k.strip()
            if k not in (DATA_AXIS, STAGE_AXIS, TENSOR_AXIS):
                raise ValueError(
                    f"unknown mesh axis {k!r}: valid axes are "
                    f"{DATA_AXIS}/{STAGE_AXIS}/{TENSOR_AXIS}")
            if k in sizes:
                raise ValueError(f"mesh axis {k!r} given twice")
            sizes[k] = int(v)
        return cls(**sizes)

    @property
    def shape(self) -> Tuple[int, int, int]:
        return (self.data, self.stage, self.tensor)

    @property
    def axis_names(self) -> Tuple[str, str, str]:
        return (DATA_AXIS, STAGE_AXIS, TENSOR_AXIS)

    @property
    def size(self) -> int:
        return self.data * self.stage * self.tensor

    def validate(self):
        for name, n in zip(self.axis_names, self.shape):
            if not isinstance(n, int) or n < 1:
                raise ValueError(
                    f"mesh axis {name!r} must be a positive int, got {n!r}")

    def __post_init__(self):
        self.validate()

    def describe(self) -> str:
        return ",".join(f"{k}={v}"
                        for k, v in zip(self.axis_names, self.shape))

    def build(self, devices=None, *, axis_names=None, auto_axes=False):
        """Construct the ``jax.sharding.Mesh`` — the one mesh entry point.

        Default layout keeps all three ``("data", "stage", "tensor")``
        axes (singletons included) so step code can address any axis
        unconditionally.

        axis_names: legacy override — a tuple of 1..3 names renaming the
        (data, stage, tensor) positions in order; axes beyond its length
        must be singleton and are dropped. ``make_data_mesh`` passes
        ``("data",)``, ``make_host_mesh`` passes ``("data", "model")``.
        auto_axes: route through ``compat_make_mesh`` (jax.make_mesh with
        Auto axis types over ALL local devices) — what the GSPMD policy
        paths expect; the default builds an explicit
        ``Mesh(devices[:size])`` so a sub-mesh can be carved out of a
        larger host pool (bench/dry-run idiom).
        """
        import jax
        import numpy as np

        names = tuple(axis_names) if axis_names is not None \
            else self.axis_names
        if not 1 <= len(names) <= 3:
            raise ValueError(f"axis_names must name 1..3 axes, got {names!r}")
        for dropped_name, n in zip(self.axis_names[len(names):],
                                   self.shape[len(names):]):
            if n != 1:
                raise ValueError(
                    f"axis_names {names!r} drops the {dropped_name!r} axis "
                    f"but its size is {n} (must be 1)")
        shape = self.shape[:len(names)]
        if auto_axes:
            from repro.launch.mesh import compat_make_mesh
            return compat_make_mesh(shape, names)
        devs = list(jax.devices()) if devices is None else list(devices)
        n = int(np.prod(shape))
        if n > len(devs):
            # never truncate silently: a bench/dry-run asking for 8 devices
            # on a 1-device backend would otherwise record a bogus
            # measurement
            raise ValueError(
                f"requested a {self.describe()} mesh ({n} devices) but only "
                f"{len(devs)} local devices exist "
                "(--xla_force_host_platform_device_count must be in "
                "XLA_FLAGS before jax initializes)")
        return jax.sharding.Mesh(
            np.asarray(devs[:n]).reshape(shape), names)


@dataclass(frozen=True)
class ParallelConfig:
    """Frozen execution config for the distributed D2FT train step.

    Replaces the loose ``sync_mode= / streamed= / opt_chunk= / guard= /
    use_kernel=`` kwargs of ``make_distributed_train_step`` /
    ``finetune_distributed`` (still accepted through a DeprecationWarning
    shim). All cross-option validation happens at construction.

    microbatches: pipeline microbatches per data shard (required > 0 when
    ``mesh.stage > 1``, must be 0 otherwise).
    """
    mesh: MeshSpec = field(default_factory=MeshSpec)
    sync_mode: str = "masked"
    streamed: bool = False
    opt_chunk: Optional[int] = None
    guard: bool = False
    use_kernel: bool = False
    microbatches: int = 0

    # -- axis name helpers (None when the axis would be degenerate) -----
    @property
    def data_axis(self) -> str:
        return DATA_AXIS

    @property
    def stage_axis(self) -> Optional[str]:
        return STAGE_AXIS if self.mesh.stage > 1 else None

    @property
    def tensor_axis(self) -> Optional[str]:
        return TENSOR_AXIS if self.mesh.tensor > 1 else None

    def __post_init__(self):
        self.validate()

    def validate(self):
        """Consolidated cross-option checks (formerly scattered across
        ``make_distributed_train_step`` and its call sites)."""
        self.mesh.validate()
        if self.sync_mode not in SYNC_MODES:
            raise ValueError(f"unknown sync_mode {self.sync_mode!r}: "
                             f"valid modes are {SYNC_MODES}")
        # streamed/opt_chunk ride the ZeRO-3 shard layout (AssertionError
        # kept for back-compat with the pre-ParallelConfig step API)
        if self.streamed or self.opt_chunk:
            assert self.sync_mode == "zero3", \
                "streamed/opt_chunk require sync_mode='zero3'"
        if self.streamed and self.guard:
            raise ValueError(
                "streamed ZeRO-3 cannot guard: the guard zeroes anomalous "
                "local grads before any collective, but the streamed "
                "reduce-scatters live inside the vjp")
        S, T = self.mesh.stage, self.mesh.tensor
        if self.sync_mode == "local" and (S > 1 or T > 1):
            raise ValueError("sync_mode='local' is communication-free and "
                             "incompatible with stage/tensor axes")
        if self.streamed and (S > 1 or T > 1):
            raise ValueError(
                "streamed ZeRO-3 fuses reduce-scatters into the vjp and "
                "does not compose with stage/tensor axes yet — use "
                "streamed=False")
        if self.guard and (S > 1 or T > 1):
            raise ValueError(
                "guard zeroes whole-device local grads, which are partial "
                "contributions under stage/tensor parallelism — guard "
                "requires a pure data mesh")
        if self.use_kernel and (S > 1 or T > 1):
            raise ValueError(
                "use_kernel has no stage/tensor route yet (the pipeline "
                "and tensor-parallel paths run the masked reference)")
        if S > 1:
            if self.microbatches < 1:
                raise ValueError(
                    f"stage={S} pipeline needs microbatches >= 1, got "
                    f"{self.microbatches}")
        elif self.microbatches:
            raise ValueError(
                "microbatches is a pipeline option: set mesh.stage > 1")

    def validate_model(self, cfg):
        """Model-dependent divisibility checks (tensor axis tiling)."""
        T = self.mesh.tensor
        if T > 1:
            if cfg.n_heads % T or cfg.n_kv_heads % T:
                raise ValueError(
                    f"tensor={T} must divide n_heads={cfg.n_heads} and "
                    f"n_kv_heads={cfg.n_kv_heads}")
        if self.mesh.stage > 1 and cfg.n_layers < self.mesh.stage:
            raise ValueError(
                f"stage={self.mesh.stage} needs at least that many layers "
                f"(n_layers={cfg.n_layers})")

    def validate_mesh(self, mesh):
        """Check a built jax Mesh carries the axes this config needs."""
        shape = dict(mesh.shape)
        if shape.get(DATA_AXIS, 1) != self.mesh.data:
            raise ValueError(
                f"mesh data axis is {shape.get(DATA_AXIS, 1)}, "
                f"ParallelConfig says {self.mesh.data}")
        for name, want in ((STAGE_AXIS, self.mesh.stage),
                           (TENSOR_AXIS, self.mesh.tensor)):
            if want > 1 and shape.get(name, 1) != want:
                raise ValueError(
                    f"ParallelConfig wants {name}={want} but the mesh has "
                    f"{name}={shape.get(name, 1)} "
                    f"(mesh axes: {dict(mesh.shape)})")
