"""Deterministic fault injection for the elastic train loop.

D2FT's Eq. 4 knapsack assumes a fixed fleet of identical, reliable
devices; the commodity fleets the paper targets are exactly where that
assumption dies. This module is the *fault model* half of the elastic
layer (``train/elastic.py`` is the response half): a ``FaultPlan`` is a
frozen, seedable, JSON-round-trippable description of every failure the
loop will see, so each failure mode has a replayable regression test
instead of a flaky repro.

Four fault kinds, matching the four degradation mechanisms:

* **per-device slowdowns** — device d takes ``factor`` times longer per
  unit of assigned schedule cost, from ``slowdown_start`` on. On the CPU
  emulation a real per-device clock does not exist (SPMD runs one
  program), so the harness *synthesizes* the measurement the loop would
  take on real hardware: ``measured_time_d = load_d * unit_times()[d]``.
  The loop's EMA consumes only these measurements — it never reads the
  plan — so the mitigation path (EMA -> capacities -> knapsack) is the
  production code path end to end.
* **device dropout** — device ``dropout[1]`` dies at step ``dropout[0]``.
  The loop recovers by shrinking the mesh to the survivors and restoring
  from the last step-level checkpoint (``train/elastic.py``).
* **non-finite gradient bursts** — ``grad_faults`` entries
  ``(step, device, scale)`` multiply device d's *local gradients* by
  ``scale`` (NaN by default) before the sync, emulating a replica whose
  backward blew up. The train step's guard must neutralize it before the
  pmean or every replica is poisoned.
* **dropped sync rounds** — at each step in ``dropped_syncs`` the
  gradient sync round fails (flaky link): the loop discards that step's
  update, and past ``sync_fault_threshold`` failures it falls back to the
  communication-free ``sync_mode="local"``.

Host-side and numpy-only: the plan is consulted between steps; the only
thing that ever crosses into jit is the per-device gradient fault vector
(a traced argument of the guarded step, so replaying a plan never
re-compiles).
"""
from __future__ import annotations

import json
from dataclasses import dataclass, field
from typing import Optional, Tuple

import numpy as np


@dataclass(frozen=True)
class FaultPlan:
    """Deterministic per-run fault script (see module docstring).

    slowdowns: ((device, factor), ...) — per-unit-time multipliers >= 1,
    active from ``slowdown_start`` on.
    dropout: (step, device) or None — the device dies *before* executing
    that step.
    grad_faults: ((step, device, scale), ...) — local-grad multiplier for
    one device at one step (NaN/inf to inject a non-finite burst).
    dropped_syncs: steps whose gradient sync round is lost.
    """
    seed: int = 0
    slowdowns: Tuple[Tuple[int, float], ...] = ()
    slowdown_start: int = 0
    dropout: Optional[Tuple[int, int]] = None
    grad_faults: Tuple[Tuple[int, int, float], ...] = ()
    dropped_syncs: Tuple[int, ...] = ()

    # ---------------------------------------------------------- queries
    def unit_times(self, step: int, n_devices: int) -> np.ndarray:
        """[K] synthetic per-unit step time of each device at ``step``
        (1.0 = healthy; the straggler's factor once its slowdown is on)."""
        u = np.ones(n_devices)
        if step >= self.slowdown_start:
            for dev, factor in self.slowdowns:
                if 0 <= dev < n_devices:
                    u[dev] = float(factor)
        return u

    def grad_fault_vector(self, step: int, n_devices: int) -> np.ndarray:
        """[K] float32 multiplier applied to each device's local grads at
        ``step`` (all-ones when no burst is scheduled)."""
        v = np.ones(n_devices, np.float32)
        for s, dev, scale in self.grad_faults:
            if s == step and 0 <= dev < n_devices:
                v[dev] = np.float32(scale)
        return v

    def dropout_at(self, step: int) -> Optional[int]:
        """Device that dies at ``step``, or None."""
        if self.dropout is not None and self.dropout[0] == step:
            return int(self.dropout[1])
        return None

    def sync_dropped(self, step: int) -> bool:
        return step in self.dropped_syncs

    def any_faults(self) -> bool:
        return bool(self.slowdowns or self.dropout is not None
                    or self.grad_faults or self.dropped_syncs)

    # ------------------------------------------------------ serialization
    def to_json(self) -> str:
        return json.dumps({
            "seed": self.seed,
            "slowdowns": [[int(d), float(f)] for d, f in self.slowdowns],
            "slowdown_start": self.slowdown_start,
            "dropout": list(self.dropout) if self.dropout else None,
            "grad_faults": [[int(s), int(d), float(x)]
                            for s, d, x in self.grad_faults],
            "dropped_syncs": sorted(int(s) for s in self.dropped_syncs),
        })

    @classmethod
    def from_json(cls, text: str) -> "FaultPlan":
        d = json.loads(text)
        dropout = d.get("dropout")
        return cls(
            seed=int(d.get("seed", 0)),
            slowdowns=tuple((int(a), float(b))
                            for a, b in d.get("slowdowns", [])),
            slowdown_start=int(d.get("slowdown_start", 0)),
            dropout=(int(dropout[0]), int(dropout[1])) if dropout else None,
            grad_faults=tuple((int(s), int(dv), float(x))
                              for s, dv, x in d.get("grad_faults", [])),
            dropped_syncs=tuple(int(s)
                                for s in d.get("dropped_syncs", [])),
        )


NO_FAULTS = FaultPlan()


def random_fault_plan(seed: int, steps: int, n_devices: int, *,
                      p_slow: float = 0.25, max_factor: float = 3.0,
                      p_dropout: float = 0.0, p_nan: float = 0.1,
                      p_sync_drop: float = 0.1) -> FaultPlan:
    """Seed -> reproducible random plan (same seed, same plan, bit for
    bit) for soak/property tests. Probabilities are per device (slowdown,
    one Bernoulli each) or per step (NaN burst on a uniform device, sync
    drop). At most one dropout, placed uniformly in the middle half of
    the run so a checkpoint exists before it."""
    rng = np.random.default_rng(seed)
    slowdowns = tuple(
        (int(d), float(np.round(rng.uniform(1.5, max_factor), 3)))
        for d in range(n_devices) if rng.random() < p_slow)
    dropout = None
    if rng.random() < p_dropout and steps >= 4:
        step = int(rng.integers(steps // 4 + 1, max(3 * steps // 4, 2)))
        dropout = (step, int(rng.integers(n_devices)))
    grad_faults = tuple(
        (s, int(rng.integers(n_devices)), float("nan"))
        for s in range(steps) if rng.random() < p_nan)
    dropped = tuple(s for s in range(steps) if rng.random() < p_sync_drop)
    return FaultPlan(seed=seed, slowdowns=slowdowns, dropout=dropout,
                     grad_faults=grad_faults, dropped_syncs=dropped)
