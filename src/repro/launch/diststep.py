"""Distributed-step measurement: lower the shard_map gated train step and
price its gradient all-reduce against the all-p_f baseline.

This is the executable evidence for the paper's *distributed* claim: with a
schedule that concentrates p_f onto a subset of subnets (the paper's
"you don't need all attentions" regime — heterogeneous capacities, frozen
low-score heads), the schedule-masked psum
(``sharding.sync.apply_grad_sync``) elides the dead subnets' all-reduces
and the compiled HLO carries measurably fewer collective bytes.

No import-time side effects: callers must provide enough local devices
(``launch.dryrun`` runs under 512 host devices; ``benchmarks/dist_step.py``
forces 8 before importing jax). The comm skip is subnet-granular, so an
iid-random mix — where nearly every subnet keeps some p_f micro-batch —
shows little saving; ``paper_mix_schedule`` builds the concentrated form
(see docs/distributed.md for why both are faithful to the paper).
"""
from __future__ import annotations

import time
from typing import Optional, Tuple

import numpy as np

import jax

from repro.configs.base import ModelConfig
from repro.core.assignment import (device_sample_order,
                                   distributed_live_bounds,
                                   plan_device_assignment)
from repro.core.cost_model import comm_cost, compute_cost
from repro.core.schedule import (P_F, P_O, P_S, Schedule,
                                 gates_from_schedule, op_counts)
from repro.data.synthetic import lm_batches, microbatch_assignment
from repro.launch.hlo import collective_bytes
from repro.launch.mesh import make_data_mesh
from repro.models.transformer import init_model
from repro.optim.optimizers import adamw
from repro.sharding.sync import grad_sync_plan, sync_byte_report
from repro.train.loop import make_distributed_train_step


def small_config() -> ModelConfig:
    """Bench-scale dense config (block params dominate embed/unembed, so
    the subnet-granular psum skip is visible in the total bytes)."""
    return ModelConfig(name="diststep", arch_type="dense", n_layers=4,
                       d_model=128, n_heads=4, n_kv_heads=4, d_ff=256,
                       vocab_size=512)


def paper_mix_schedule(n_layers: int, n_groups: int, n_mb: int,
                       mix: Tuple[float, float, float] = (0.4, 0.3, 0.3),
                       seed: int = 0) -> Schedule:
    """Schedule with table-entry fractions ~= mix, p_f *concentrated*.

    round(mix[0] * K) subnets run p_f on every micro-batch (high-score
    subnets under heterogeneous capacities / full budget); the remaining
    subnets never run a backward and split their cells between p_o and p_s
    to hit the global mix. This is the regime where Eq. 4's comm claim
    lives — a subnet with no p_f anywhere has zero gradient everywhere and
    drops out of the all-reduce entirely."""
    K = n_layers * n_groups
    rng = np.random.default_rng(seed)
    n_pf_rows = int(round(mix[0] * K))
    pf_rows = np.sort(rng.permutation(K)[:n_pf_rows])
    table = np.full((K, n_mb), P_S, np.int8)
    table[pf_rows] = P_F
    rest = np.setdiff1d(np.arange(K), pf_rows)
    cells = [(r, c) for r in rest for c in range(n_mb)]
    rng.shuffle(cells)
    want_po = int(round(mix[1] * K * n_mb))
    for r, c in cells[:want_po]:
        table[r, c] = P_O
    return Schedule(table, n_layers, n_groups)


def all_pf_schedule(n_layers: int, n_groups: int, n_mb: int) -> Schedule:
    """Standard full fine-tuning as a schedule (the comm baseline)."""
    return Schedule(np.full((n_layers * n_groups, n_mb), P_F, np.int8),
                    n_layers, n_groups)


def measure_distributed_step(n_devices: int = 8, *,
                             cfg: Optional[ModelConfig] = None,
                             batch: int = 32, seq: int = 32, n_mb: int = 8,
                             mix: Tuple[float, float, float] = (.4, .3, .3),
                             seed: int = 0, use_kernel: bool = False,
                             time_steps: int = 0) -> dict:
    """Lower + compile the distributed step for the paper-mix schedule and
    the all-p_f baseline on an n-device data mesh; parse per-device
    collective bytes from the compiled HLO and cross-check them against the
    sync plan's byte model. time_steps > 0 additionally executes that many
    steps per variant for wall time."""
    cfg = cfg or small_config()
    G = cfg.n_heads
    mesh = make_data_mesh(n_devices)
    params = init_model(jax.random.PRNGKey(seed), cfg)
    opt = adamw(1e-3)
    opt_state = opt.init(params)
    data = next(lm_batches(seed, cfg.vocab_size, batch, seq, 1))
    mb_of = microbatch_assignment(batch, n_mb)

    variants = {
        "all_pf_baseline": all_pf_schedule(cfg.n_layers, G, n_mb),
        "paper_mix": paper_mix_schedule(cfg.n_layers, G, n_mb, mix, seed),
    }
    record = {
        "n_devices": n_devices, "mix": list(mix), "seed": seed,
        "model": {"name": cfg.name, "n_layers": cfg.n_layers,
                  "d_model": cfg.d_model, "n_heads": cfg.n_heads,
                  "d_ff": cfg.d_ff, "vocab": cfg.vocab_size},
        "shape": {"batch": batch, "seq": seq, "n_microbatches": n_mb},
        "use_kernel": use_kernel,
        "backend": jax.default_backend(),
        "variants": {},
    }
    for name, sched in variants.items():
        assignment, rebalance = plan_device_assignment(sched, n_devices)
        perm = device_sample_order(assignment, mb_of)
        pbatch = jax.tree.map(lambda a: a[perm], data)
        gates = gates_from_schedule(sched, mb_of[perm])
        plan = grad_sync_plan(params, cfg, sched)
        bounds = distributed_live_bounds(sched, mb_of, assignment) \
            if use_kernel else None
        step = make_distributed_train_step(cfg, opt, mesh, plan,
                                           use_kernel=use_kernel,
                                           live_bounds=bounds)
        args = (params, opt_state, pbatch, gates)
        compiled = step.lower(*args).compile()
        coll = collective_bytes(compiled.as_text())
        var = {
            "op_counts": op_counts(sched),
            "cost_model": {"compute": round(compute_cost(sched.table), 4),
                           "comm": round(comm_cost(sched.table), 4)},
            "collectives": coll,
            "all_reduce_bytes": float(coll.get("all-reduce", 0.0)),
            "sync_plan": sync_byte_report(plan, params),
            "rebalance": rebalance,
        }
        if bounds is not None:
            var["live_bounds"] = list(bounds)
        if time_steps > 0:
            # drive the AOT executable compiled above — calling the jitted
            # step again would re-trace and re-compile the same computation
            p, s, m = compiled(params, opt_state, pbatch, gates)   # warm
            jax.block_until_ready(m["loss"])
            t0 = time.perf_counter()
            for _ in range(time_steps):
                p, s, m = compiled(p, s, pbatch, gates)
            jax.block_until_ready(m["loss"])
            var["wall_us_per_step"] = (time.perf_counter() - t0) \
                / time_steps * 1e6
        record["variants"][name] = var

    base = record["variants"]["all_pf_baseline"]["all_reduce_bytes"]
    mix_b = record["variants"]["paper_mix"]["all_reduce_bytes"]
    record["all_reduce_fraction"] = mix_b / base if base else 1.0
    record["sync_model_fraction"] = \
        record["variants"]["paper_mix"]["sync_plan"]["fraction"]
    return record
