"""Distributed-step measurement: lower the shard_map gated train step and
price its gradient sync against the all-p_f baseline.

This is the executable evidence for the paper's *distributed* claim: with a
schedule that concentrates p_f onto a subset of subnets (the paper's
"you don't need all attentions" regime — heterogeneous capacities, frozen
low-score heads), the schedule-masked psum
(``sharding.sync.apply_grad_sync``) elides the dead subnets' all-reduces
and the compiled HLO carries measurably fewer collective bytes. The ZeRO-1
variants (``sync_mode="zero"``) replace the masked psum with a sliced
reduce-scatter + schedule-masked all-gather and shard the optimizer
moments; their wire bytes match the masked psum at equal masks (ring
physics — see docs/distributed.md) while per-device moment memory drops to
~1/n_devices, measured here via ``zero_state_byte_report``. The ZeRO-3
variants (``sync_mode="zero3"``) also shard the params persistently and
materialize full views only inside the step, under the schedule's forward
mask — p_s-everywhere subnets are never gathered at all — trading extra
all-gather wire for a per-device param residency window priced by
``zero3_param_byte_report``. The *streamed* ZeRO-3 variant executes that
window for real: per-unit gathers with the reduce-scatter fused into each
unit's backward (``zero3_stream_materialize``) and a chunked
shard-resident optimizer sweep; its trace-time gather counter is checked
against the model (``check_zero3_residency``) and ``zero3_overlap_report``
prices the double-buffered overlap window (exposed vs hidden collective
time) the bench's ``overlap`` summary carries.

No import-time side effects: callers must provide enough local devices
(``launch.dryrun`` runs under 512 host devices; ``benchmarks/dist_step.py``
forces 8 before importing jax). ``paper_mix_schedule`` builds the
concentrated form; ``uniform_half_schedule`` the uniformly spread form
where whole-subnet elision never fires and only sub-leaf granularity
(sliced runs / the ZeRO live-run scatter) saves bytes (see
docs/distributed.md for why both are faithful to the paper).
"""
from __future__ import annotations

import time
from typing import Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.assignment import (device_sample_order,
                                   distributed_live_bounds, layer_live_costs,
                                   plan_device_assignment,
                                   plan_stage_assignment)
from repro.core.cost_model import comm_cost, compute_cost
from repro.core.schedule import (P_F, P_O, P_S, Schedule,
                                 gates_from_schedule, op_counts)
from repro.data.synthetic import lm_batches, microbatch_assignment
from repro.launch.hlo import (collective_bytes, collective_counts,
                              compare_collective_bytes)
from repro.launch.mesh import make_data_mesh
from repro.launch.parallel import MeshSpec, ParallelConfig
from repro.models.transformer import init_model
from repro.optim.optimizers import adamw
from repro.sharding.sync import (ResidencyRecorder, check_zero3_residency,
                                 grad_sync_plan, sync_byte_report,
                                 zero3_param_byte_report,
                                 zero3_unit_schedule, zero_reshard,
                                 zero_state_byte_report)
from repro.train.loop import make_distributed_train_step
from repro.train.pipeline import PipelineRecorder, analytic_bubble_fraction


def small_config() -> ModelConfig:
    """Bench-scale dense config (block params dominate embed/unembed, so
    the subnet-granular psum skip is visible in the total bytes)."""
    return ModelConfig(name="diststep", arch_type="dense", n_layers=4,
                       d_model=128, n_heads=4, n_kv_heads=4, d_ff=256,
                       vocab_size=512)


def paper_mix_schedule(n_layers: int, n_groups: int, n_mb: int,
                       mix: Tuple[float, float, float] = (0.4, 0.3, 0.3),
                       seed: int = 0) -> Schedule:
    """Schedule with table-entry fractions ~= mix, all three ops
    *concentrated* by subnet.

    round(mix[0] * K) subnets run p_f on every micro-batch (high-score
    subnets under heterogeneous capacities / full budget); the remaining
    subnets never run a backward, and the p_o budget fills whole rows of
    them in order — so mid-score subnets are forward-only throughout and
    the lowest-score subnets are p_s on *every* micro-batch ("you don't
    need all attentions": frozen heads are frozen, not intermittently
    sampled). The backward-liveness structure — hence every masked/ZeRO-1
    wire number — is identical to an unconcentrated p_o spread (only p_f
    rows are backward-live either way); what concentration adds is the
    forward-dead population ZeRO-3's schedule-masked param gather elides."""
    K = n_layers * n_groups
    rng = np.random.default_rng(seed)
    n_pf_rows = int(round(mix[0] * K))
    pf_rows = np.sort(rng.permutation(K)[:n_pf_rows])
    table = np.full((K, n_mb), P_S, np.int8)
    table[pf_rows] = P_F
    rest = np.setdiff1d(np.arange(K), pf_rows)
    want_po = int(round(mix[1] * K * n_mb))
    filled = []
    for r in rest:
        take = min(n_mb, want_po)
        if take == 0:
            break
        # partial rows spread their p_o cells over random columns so the
        # per-micro-batch cost vector stays seed-dependent (the device
        # assigner's re-plan regression test relies on that)
        table[r, rng.permutation(n_mb)[:take]] = P_O
        want_po -= take
        filled.append(r)
    if filled and len(filled) < len(rest) \
            and bool((table[filled[-1]] == P_O).all()):
        # the p_o budget divided into whole rows, leaving no partial row —
        # swap one cell between the last full row and the next p_s row
        # (counts unchanged) so two rng-columned partial rows exist and
        # seed-dependence survives every (mix, K, n_mb) combination
        table[filled[-1], rng.integers(n_mb)] = P_S
        table[rest[len(filled)], rng.integers(n_mb)] = P_O
    return Schedule(table, n_layers, n_groups)


def all_pf_schedule(n_layers: int, n_groups: int, n_mb: int) -> Schedule:
    """Standard full fine-tuning as a schedule (the comm baseline)."""
    return Schedule(np.full((n_layers * n_groups, n_mb), P_F, np.int8),
                    n_layers, n_groups)


def uniform_half_schedule(n_layers: int, n_groups: int, n_mb: int,
                          live_frac: float = 0.5, seed: int = 0) -> Schedule:
    """Uniformly spread live subnets: every layer has round(G * live_frac)
    backward-live groups at a rotating offset, so no layer (and no leaf) is
    fully dead or fully live. This is the regime where PR 3's whole-subnet
    psum elision (`none` specs) never fires — the saving must come from
    sub-leaf granularity (sliced runs / the ZeRO live-run scatter). Live
    rows run p_f on every micro-batch; dead rows split p_o / p_s."""
    n_live = max(1, min(n_groups - 1, int(round(live_frac * n_groups))))
    rng = np.random.default_rng(seed)
    table = np.full((n_layers * n_groups, n_mb), P_S, np.int8)
    for layer in range(n_layers):
        for j in range(n_live):
            g = (layer + j * max(n_groups // n_live, 1)) % n_groups
            table[layer * n_groups + g] = P_F
    dead = np.nonzero((table != P_F).all(axis=1))[0]
    for r in dead:
        po = rng.random(n_mb) < 0.5
        table[r, po] = P_O
    return Schedule(table, n_layers, n_groups)


def zero3_overlap_report(plan, params, n_shards: int, *,
                         compute_ratio: float = 2.0) -> dict:
    """Overlap-window model of the streamed ZeRO-3 schedule.

    Units run in forward execution order (``zero3_unit_schedule``); each
    unit's all-gather time is proxied by its gathered bytes and its
    compute by ``compute_ratio`` x the same bytes (FLOP time per
    gather-byte time — a documented knob, not a measurement; the default
    2.0 says a block's forward+backward takes about twice as long as
    gathering it over the ring). Under double buffering, unit i+1's gather
    is prefetched during unit i's compute, so its *exposed* time is
    max(0, t_gather(i+1) - t_compute(i)); the first unit's gather, and any
    gather following a fully elided unit (nothing to hide behind), is
    always exposed. ``exposed_fraction`` = exposed / serialized gather
    time — < 1.0 is the overlap acceptance bar: the serialized schedule
    (gather, then compute, repeat) exposes every gather byte.

    ``double_buffer_peak_bytes`` prices the residency cost of the overlap:
    shards + fallback + the largest *adjacent pair* of gathered units
    (current + prefetched next), vs the single-unit window
    ``zero3_param_byte_report`` prices."""
    units = zero3_unit_schedule(plan, params)
    gathers = [b for _, b in units]
    exposed, prev_compute = 0.0, 0.0
    for g in gathers:
        exposed += max(0.0, g - prev_compute)
        prev_compute = g * compute_ratio
    total = sum(gathers)
    report = zero3_param_byte_report(plan, params, n_shards)
    pair = max((gathers[i] + gathers[i + 1]
                for i in range(len(gathers) - 1)),
               default=report["peak_unit_bytes"])
    peak2 = report["shard_bytes"] + report["fallback_bytes"] \
        + max(pair, report["peak_unit_bytes"])
    return {
        "n_units": len(units),
        "compute_ratio": compute_ratio,
        "serialized_gather_bytes": total,
        "exposed_gather_bytes": exposed,
        "exposed_fraction": exposed / total if total else 0.0,
        "double_buffer_peak_bytes": peak2,
        "double_buffer_fraction": (peak2 / report["replicated_bytes"]
                                   if report["replicated_bytes"] else 1.0),
    }


def measure_distributed_step(n_devices: int = 8, *,
                             cfg: Optional[ModelConfig] = None,
                             batch: int = 32, seq: int = 32, n_mb: int = 8,
                             mix: Tuple[float, float, float] = (.4, .3, .3),
                             seed: int = 0, use_kernel: bool = False,
                             time_steps: int = 0) -> dict:
    """Lower + compile the distributed step on an n-device data mesh for a
    schedule × sync-mode matrix: the all-p_f baseline, the concentrated
    paper-mix under masked psum, ZeRO-1 and ZeRO-3 sync, and the uniformly
    spread 50%-live schedule (where whole-subnet elision never fires) under
    all three. Per-device collective bytes are parsed from the compiled HLO
    and cross-checked against the sync plan's wire-byte model; the
    ``zero_sync`` summary carries the ZeRO-1 acceptance numbers (wire
    fractions, per-device optimizer-moment memory) and ``zero3`` the ZeRO-3
    ones (wire fraction incl. the forward param all-gather, the
    residency-window fraction, and the gather-elision count — the compiled
    HLO of the zero3 variants must actually contain all-gather ops, see
    ``collectives_n``). time_steps > 0 additionally executes that many
    steps per variant for wall time.

    The optimizer is decay-free AdamW: zero weight decay keeps it
    *elidable* (``Optimizer.elidable``), so the ZeRO-1 gather mask can skip
    backward-dead runs — with decay every run's params change each step and
    the ZeRO-1 gather must be dense (ZeRO-3 is indifferent: its shards are
    always updated and its elision is forward-mask-only)."""
    cfg = cfg or small_config()
    G = cfg.n_heads
    mesh = make_data_mesh(n_devices)
    params = init_model(jax.random.PRNGKey(seed), cfg)
    opt = adamw(1e-3, weight_decay=0.0)
    opt_state = opt.init(params)
    data = next(lm_batches(seed, cfg.vocab_size, batch, seq, 1))
    mb_of = microbatch_assignment(batch, n_mb)

    schedules = {
        "all_pf_baseline": all_pf_schedule(cfg.n_layers, G, n_mb),
        "paper_mix": paper_mix_schedule(cfg.n_layers, G, n_mb, mix, seed),
        "uniform_half": uniform_half_schedule(cfg.n_layers, G, n_mb,
                                              seed=seed),
    }
    variants = {
        "all_pf_baseline": ("all_pf_baseline", "masked", False),
        "paper_mix": ("paper_mix", "masked", False),
        "paper_mix_zero": ("paper_mix", "zero", False),
        "paper_mix_zero3": ("paper_mix", "zero3", False),
        "paper_mix_zero3_streamed": ("paper_mix", "zero3", True),
        "uniform_half": ("uniform_half", "masked", False),
        "uniform_half_zero": ("uniform_half", "zero", False),
        "uniform_half_zero3": ("uniform_half", "zero3", False),
    }
    # chunk size of the shard-resident optimizer sweep in the streamed
    # variant (working set O(chunk) per leaf; bit-identical to whole-shard
    # updates — tests/test_sync_properties.py proves it)
    opt_chunk = 2048
    record = {
        "n_devices": n_devices, "mix": list(mix), "seed": seed,
        "model": {"name": cfg.name, "n_layers": cfg.n_layers,
                  "d_model": cfg.d_model, "n_heads": cfg.n_heads,
                  "d_ff": cfg.d_ff, "vocab": cfg.vocab_size},
        "shape": {"batch": batch, "seq": seq, "n_microbatches": n_mb},
        "use_kernel": use_kernel,
        "backend": jax.default_backend(),
        "variants": {},
    }
    plans = {}
    for name, (sched_name, sync_mode, streamed) in variants.items():
        sched = schedules[sched_name]
        assignment, rebalance = plan_device_assignment(sched, n_devices)
        perm = device_sample_order(assignment, mb_of)
        pbatch = jax.tree.map(lambda a: a[perm], data)
        gates = gates_from_schedule(sched, mb_of[perm])
        plan = grad_sync_plan(params, cfg, sched, mode=sync_mode,
                              n_shards=n_devices,
                              elide_gather=opt.elidable)
        plans[name] = plan
        bounds = distributed_live_bounds(sched, mb_of, assignment) \
            if use_kernel else None
        recorder = ResidencyRecorder() if streamed else None
        pconf = ParallelConfig(mesh=MeshSpec(data=n_devices),
                               sync_mode=sync_mode, streamed=streamed,
                               opt_chunk=opt_chunk if streamed else None,
                               use_kernel=use_kernel)
        step = make_distributed_train_step(cfg, opt, mesh, plan,
                                           parallel=pconf,
                                           live_bounds=bounds,
                                           params=params,
                                           residency_recorder=recorder)
        # zero3 holds the params in the plan's shard layout between steps
        pvar = zero_reshard(params, None, plan) if sync_mode == "zero3" \
            else params
        args = (pvar, opt_state, pbatch, gates)
        compiled = step.lower(*args).compile()
        hlo_text = compiled.as_text()
        coll = collective_bytes(hlo_text, default_group_size=n_devices)
        var = {
            "schedule": sched_name,
            "sync_mode": sync_mode,
            "streamed": streamed,
            "op_counts": op_counts(sched),
            "cost_model": {"compute": round(compute_cost(sched.table), 4),
                           "comm": round(comm_cost(sched.table), 4)},
            "collectives": coll,
            "collectives_n": collective_counts(hlo_text),
            "all_reduce_bytes": float(coll.get("all-reduce", 0.0)),
            "wire_bytes": float(sum(coll.values())),
            "sync_plan": sync_byte_report(plan, params,
                                          n_shards=n_devices),
            "rebalance": rebalance,
        }
        if sync_mode in ("zero", "zero3"):
            var["opt_memory"] = zero_state_byte_report(
                plan, params, n_devices, n_moments=opt.n_moments)
        if sync_mode == "zero3":
            var["param_memory"] = zero3_param_byte_report(plan, params,
                                                          n_devices)
        if streamed:
            # lowering traced the streamed step, so the recorder now holds
            # the gather bytes the schedule actually emitted — fail here,
            # at the measurement site, if they disagree with the model
            var["residency_check"] = check_zero3_residency(
                recorder, plan, params, n_devices)
            var["opt_chunk"] = opt_chunk
        if bounds is not None:
            var["live_bounds"] = list(bounds)
        if time_steps > 0:
            # drive the AOT executable compiled above — calling the jitted
            # step again would re-trace and re-compile the same computation
            p, s, m = compiled(pvar, opt_state, pbatch, gates)   # warm
            jax.block_until_ready(m["loss"])
            t0 = time.perf_counter()
            for _ in range(time_steps):
                p, s, m = compiled(p, s, pbatch, gates)
            jax.block_until_ready(m["loss"])
            var["wall_us_per_step"] = (time.perf_counter() - t0) \
                / time_steps * 1e6
        record["variants"][name] = var

    v = record["variants"]
    base_ar = v["all_pf_baseline"]["all_reduce_bytes"]
    base_wire = v["all_pf_baseline"]["wire_bytes"]
    record["all_reduce_fraction"] = \
        v["paper_mix"]["all_reduce_bytes"] / base_ar if base_ar else 1.0
    record["sync_model_fraction"] = \
        v["paper_mix"]["sync_plan"]["fraction"]

    def wire_frac(name):
        return v[name]["wire_bytes"] / base_wire if base_wire else 1.0

    record["zero_sync"] = {
        # sliced RS+AG vs the same schedule's masked psum and the baseline
        "paper_mix_wire_fraction": wire_frac("paper_mix_zero"),
        "paper_mix_masked_wire_fraction": wire_frac("paper_mix"),
        # the uniformly spread schedule: whole-subnet elision never fires
        # (n_skipped == 0 below) yet the run-masked sync still saves
        "uniform_wire_fraction": wire_frac("uniform_half_zero"),
        "uniform_masked_wire_fraction": wire_frac("uniform_half"),
        "uniform_masked_n_skipped":
            v["uniform_half"]["sync_plan"]["n_skipped"],
        # per-device Adam moment memory under the ZeRO partition
        "opt_memory_fraction":
            v["paper_mix_zero"]["opt_memory"]["fraction"],
    }
    z3 = v["paper_mix_zero3"]
    record["zero3"] = {
        # honest wire accounting: the zero3 wire INCLUDES the forward param
        # all-gather the replicated modes never pay — it buys the sharded
        # residency below, it is not free
        "paper_mix_wire_fraction": wire_frac("paper_mix_zero3"),
        "uniform_wire_fraction": wire_frac("uniform_half_zero3"),
        # per-device peak param residency model (shards + largest
        # materialized unit) vs the replicated baseline; <= 0.5x is the
        # acceptance bar
        "residency_fraction": z3["param_memory"]["fraction"],
        "peak_unit": z3["param_memory"]["peak_unit"],
        # schedule-masked gather elision: forward-dead runs never gathered
        "n_gather_elided": z3["param_memory"]["n_gather_elided"],
        "elided_bytes": z3["param_memory"]["elided_bytes"],
        # the lowered evidence that the gathers exist (and were counted)
        "n_all_gather_ops": z3["collectives_n"].get("all-gather", 0),
        "opt_memory_fraction": z3["opt_memory"]["fraction"],
    }
    z3s = v["paper_mix_zero3_streamed"]
    res = z3s["residency_check"]
    ov = zero3_overlap_report(plans["paper_mix_zero3_streamed"], params,
                              n_devices)
    replicated = z3s["param_memory"]["replicated_bytes"]
    record["overlap"] = {
        # analytic overlap window: exposed vs serialized gather time under
        # double-buffered prefetch (< 1.0 = some collectives hidden)
        "exposed_collective_fraction": ov["exposed_fraction"],
        "n_units": ov["n_units"],
        "compute_ratio": ov["compute_ratio"],
        # *measured* (trace-time gather counter) streamed peak residency,
        # already asserted within 5% of zero3_param_byte_report's model by
        # check_zero3_residency above
        "streamed_residency_fraction":
            res["measured_per_device_peak_bytes"] / replicated
            if replicated else 1.0,
        "peak_agreement": res["peak_agreement"],
        "n_units_measured": res["n_units_measured"],
        # residency cost of the overlap: current + prefetched unit
        "double_buffer_fraction": ov["double_buffer_fraction"],
        # wire invariance: re-scheduling collectives against compute must
        # not change what crosses the wire
        "wire_ratio_vs_unstreamed":
            z3s["wire_bytes"] / z3["wire_bytes"]
            if z3["wire_bytes"] else 1.0,
    }
    record["pipeline"] = _measure_pipeline_variant(
        cfg, opt, opt_state, params, data, schedules["paper_mix"], mb_of,
        n_devices, time_steps=time_steps)
    return record


def _measure_pipeline_variant(cfg, opt, opt_state, params, data, sched,
                              mb_of, n_devices: int, *, n_stages: int = 2,
                              n_microbatches: int = 4,
                              time_steps: int = 0) -> dict:
    """Lower the GPipe pipeline step on a (data x stage) carve of the same
    device pool and price its balancing: the live-cost stage packing's
    makespan vs layer-count packing (``makespan_ratio`` — the acceptance
    gate, < 1 when the schedule concentrates cost unevenly across layers)
    and the analytic + trace-hook bubble accounting."""
    pipe_data = n_devices // n_stages
    spec = MeshSpec(data=pipe_data, stage=n_stages)
    mesh = spec.build()
    stage_assign, stage_rep = plan_stage_assignment(sched, n_stages)
    pconf = ParallelConfig(mesh=spec, microbatches=n_microbatches)
    recorder = PipelineRecorder()
    plan = grad_sync_plan(params, cfg, sched)
    assignment, rebalance = plan_device_assignment(sched, pipe_data)
    perm = device_sample_order(assignment, mb_of)
    pbatch = jax.tree.map(lambda a: a[perm], data)
    gates = gates_from_schedule(sched, mb_of[perm])
    step = make_distributed_train_step(cfg, opt, mesh, plan,
                                       parallel=pconf,
                                       stage_assignment=stage_assign,
                                       pipeline_recorder=recorder)
    args = (params, opt_state, pbatch, gates)
    compiled = step.lower(*args).compile()
    hlo_text = compiled.as_text()
    # layer-count packing's loads (what naive uniform splitting would run)
    costs = layer_live_costs(sched)
    ub = stage_rep["layer_count_boundaries"]
    uniform_loads = [float(sum(costs[lo:hi]))
                     for lo, hi in zip(ub, ub[1:])]
    var = {
        "mesh": {"data": pipe_data, "stage": n_stages},
        "n_microbatches": n_microbatches,
        "rebalance": rebalance,
        "boundaries": stage_rep["boundaries"],
        "loads": stage_rep["loads"],
        "makespan": stage_rep["makespan"],
        "layer_count_boundaries": list(ub),
        "layer_count_makespan": stage_rep["layer_count_makespan"],
        # live-cost packing vs layer-count packing (< 1.0 = the assigner
        # found a strictly better split; the bench gate pins < 0.95)
        "makespan_ratio": stage_rep["makespan_ratio"],
        "bubble_fraction": analytic_bubble_fraction(
            stage_assign.loads, n_microbatches),
        "layer_count_bubble_fraction": analytic_bubble_fraction(
            uniform_loads, n_microbatches),
        # trace-hook cross-check of the round/send model (lowering above
        # traced the step, so the recorder holds the real counts)
        "trace": recorder.report(),
        "collectives_n": collective_counts(hlo_text),
        "collectives": collective_bytes(hlo_text,
                                        default_group_size=n_devices),
    }
    if time_steps > 0:
        p, s, m = compiled(*args)       # warm
        jax.block_until_ready(m["loss"])
        t0 = time.perf_counter()
        for _ in range(time_steps):
            p, s, m = compiled(p, s, pbatch, gates)
        jax.block_until_ready(m["loss"])
        var["wall_us_per_step"] = (time.perf_counter() - t0) \
            / time_steps * 1e6
    return var


def measure_elastic(n_devices: int = 8, *, seed: int = 0) -> dict:
    """Execute the four elastic fault scenarios of docs/robustness.md on an
    n-device host mesh and record the acceptance metrics for
    ``BENCH_elastic.json``:

    * ``straggler`` — a 2x-slow device under per-refresh replanning: the
      mitigation ratio (capacity-constrained makespan / balanced makespan,
      both priced at the measured per-device speeds) must stay < 1;
    * ``dropout`` — a mid-run device loss: how many steps the recovery
      replays from the last step-level checkpoint, and the max param /
      optimizer-state difference vs a fresh survivors-only resume of the
      SAME checkpoint (the bit-exactness claim, <= 1e-6);
    * ``nan_guard`` — an injected NaN/inf gradient burst: steps skipped by
      the pre-sync guard and the final-loss gap vs the fault-free run,
      normalised by the clean run's total loss drop;
    * ``lofi`` — dropped sync rounds past the threshold: the fallback
      step, the lo-fi merge count, and that training still makes progress.

    Same process contract as ``measure_distributed_step``: the caller must
    provide the devices (``benchmarks/elastic.py`` forces the host device
    count before importing jax). Everything is seeded — the scenario
    outcomes are deterministic and tightly gated by
    ``benchmarks/bench_baselines.json``; only the ``wall_s`` timings vary.
    """
    import tempfile

    from repro.configs.base import D2FTConfig
    from repro.launch.faults import FaultPlan
    from repro.optim.optimizers import sgd
    from repro.train.elastic import ElasticConfig, finetune_elastic

    cfg = ModelConfig(name="elastic", arch_type="dense", n_layers=4,
                      d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
                      vocab_size=256)
    d2 = D2FTConfig(n_microbatches=16, n_pf=6, n_po=4, head_groups=4)
    B, S = 32, 16
    mesh = make_data_mesh(n_devices)
    params = init_model(jax.random.PRNGKey(seed), cfg)

    def batches(n):
        return list(lm_batches(seed, cfg.vocab_size, batch=B, seq=S,
                               steps=n))

    def copy(tree):
        return jax.tree.map(jnp.copy, tree)

    def maxdiff(a, b):
        return max(jax.tree.leaves(jax.tree.map(
            lambda x, y: float(jnp.abs(x - y).max()), a, b)))

    record = {
        "n_devices": n_devices, "seed": seed,
        "model": {"name": cfg.name, "n_layers": cfg.n_layers,
                  "d_model": cfg.d_model, "n_heads": cfg.n_heads,
                  "d_ff": cfg.d_ff, "vocab": cfg.vocab_size},
        "shape": {"batch": B, "seq": S,
                  "n_microbatches": d2.n_microbatches},
        "backend": jax.default_backend(),
    }

    # -- straggler: device 3 runs 2x slow, refresh every 2 steps ---------
    t0 = time.perf_counter()
    el = ElasticConfig(refresh_every=2, ckpt_every=0,
                       ckpt_dir=tempfile.mkdtemp())
    _, _, log_s = finetune_elastic(copy(params), cfg, d2, sgd(0.1),
                                   batches(5), steps=5, mesh=mesh,
                                   faults=FaultPlan(slowdowns=((3, 2.0),)),
                                   elastic=el)
    refreshes = log_s.extras["refreshes"]
    mitigated = [r for r in refreshes
                 if r["elastic"].get("capacities") is not None]
    m = mitigated[-1]["elastic"]
    record["straggler"] = {
        "n_refreshes": len(refreshes),
        "n_capacity_refreshes": len(mitigated),
        "straggler_unit_time": m["unit_times"][3],
        "makespan": m["makespan"],
        "unmitigated_makespan": m["unmitigated_makespan"],
        "mitigation_ratio": m["mitigation_ratio"],
        "load_spread": mitigated[-1]["rebalance"]["spread"],
        "wall_s": round(time.perf_counter() - t0, 2),
    }

    # -- dropout: lose device 3 at step 5, recover onto the survivors ----
    t0 = time.perf_counter()
    opt = adamw(1e-3)
    el = ElasticConfig(refresh_every=4, ckpt_every=2,
                       ckpt_dir=tempfile.mkdtemp())
    p_a, s_a, log_a = finetune_elastic(copy(params), cfg, d2, opt,
                                       batches(6), steps=6, mesh=mesh,
                                       faults=FaultPlan(dropout=(3, 5)),
                                       elastic=el)
    rec = [e for e in log_a.extras["elastic"]["events"]
           if e["type"] == "dropout_recovery"][0]
    el_b = ElasticConfig(refresh_every=4, ckpt_every=2,
                         ckpt_dir=tempfile.mkdtemp())
    p_b, s_b, _ = finetune_elastic(copy(params), cfg, d2, opt,
                                   batches(6), steps=6,
                                   mesh=make_data_mesh(rec["n_devices"]),
                                   elastic=el_b, resume_from=rec["ckpt"])
    record["dropout"] = {
        "recovery_steps": rec["recovery_steps"],
        "ckpt_step": rec["ckpt_step"],
        "n_devices_after": rec["n_devices"],
        "resume_parity_diff": maxdiff(p_a, p_b),
        "resume_opt_diff": maxdiff(s_a, s_b),
        "wall_s": round(time.perf_counter() - t0, 2),
    }

    # -- NaN burst: device 2 at step 1, device 3 at step 6 ---------------
    t0 = time.perf_counter()
    fp = FaultPlan(grad_faults=((2, 1, float("nan")),
                                (3, 6, float("inf"))))
    el = ElasticConfig(refresh_every=0, ckpt_every=0,
                       ckpt_dir=tempfile.mkdtemp())
    _, _, log_f = finetune_elastic(copy(params), cfg, d2, sgd(0.1),
                                   batches(8), steps=8, mesh=mesh,
                                   faults=fp, elastic=el)
    el = ElasticConfig(refresh_every=0, ckpt_every=0,
                       ckpt_dir=tempfile.mkdtemp())
    _, _, log_c = finetune_elastic(copy(params), cfg, d2, sgd(0.1),
                                   batches(8), steps=8, mesh=mesh,
                                   elastic=el)
    gap = abs(log_f.losses[-1] - log_c.losses[-1])
    drop = log_c.losses[0] - log_c.losses[-1]
    record["nan_guard"] = {
        "steps_skipped": log_f.extras["elastic"]["guard_skips"],
        "skip_steps": [e["step"]
                       for e in log_f.extras["elastic"]["events"]
                       if e["type"] == "guard_skip"],
        "final_loss_faulted": round(log_f.losses[-1], 6),
        "final_loss_clean": round(log_c.losses[-1], 6),
        "loss_gap": round(gap, 6),
        "clean_loss_drop": round(drop, 6),
        "gap_fraction": round(gap / drop, 6) if drop > 0 else 0.0,
        "wall_s": round(time.perf_counter() - t0, 2),
    }

    # -- dropped syncs: 2 lost rounds engage the lo-fi local fallback ----
    t0 = time.perf_counter()
    el = ElasticConfig(refresh_every=0, ckpt_every=0, merge_every=2,
                       sync_fault_threshold=2,
                       ckpt_dir=tempfile.mkdtemp())
    _, _, log_l = finetune_elastic(copy(params), cfg, d2, sgd(0.1),
                                   batches(8), steps=8, mesh=mesh,
                                   faults=FaultPlan(dropped_syncs=(1, 2)),
                                   elastic=el)
    ev = log_l.extras["elastic"]
    fb = [e for e in ev["events"] if e["type"] == "lofi_fallback"][0]
    record["lofi"] = {
        "fallback_step": fb["step"],
        "sync_drops": ev["sync_faults"],
        "n_merges": ev["merges"],
        "final_mode_local": 1 if ev["final_mode"] == "local" else 0,
        "loss_drop": round(log_l.losses[0] - log_l.losses[-1], 6),
        "wall_s": round(time.perf_counter() - t0, 2),
    }
    return record
