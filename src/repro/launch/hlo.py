"""Compiled-HLO collective-traffic accounting.

Shared by the dry-run (``launch/dryrun.py``) and the distributed-step
measurement (``launch/diststep.py`` / ``benchmarks/dist_step.py``). Lives
in its own module because ``dryrun.py`` must set ``XLA_FLAGS`` for 512
host devices at import time — anything that wants the parser without that
side effect imports it from here.
"""
from __future__ import annotations

import re
from collections import Counter
from typing import Dict

_DTYPE_BYTES = {"f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "s8": 1,
                "u8": 1, "pred": 1, "f64": 8, "s64": 8, "u64": 8, "c64": 8,
                "s16": 2, "u16": 2, "f8e4m3fn": 1, "f8e5m2": 1}

_COLL_RE = re.compile(
    r"=\s*(\w+)\[([\d,]*)\][^=]*?"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"[^\n]*?(?:replica_groups=\[(\d+),(\d+)\])?")


def collective_bytes(hlo_text: str) -> Dict[str, float]:
    """Per-device ICI traffic (bytes) by collective type.

    Formulas (ring algorithms, k = group size, n = result bytes/device):
      all-gather: (k-1)/k * n_out ; all-reduce: 2*(k-1)/k * n ;
      reduce-scatter: (k-1)/k * n_in ~ (k-1)*n_out ; all-to-all: (k-1)/k * n;
      collective-permute: n.
    """
    out: Dict[str, float] = Counter()
    for m in _COLL_RE.finditer(hlo_text):
        dt, dims, op, _, gsz = m.groups()
        nbytes = _DTYPE_BYTES.get(dt, 4)
        for d in dims.split(","):
            if d:
                nbytes *= int(d)
        k = int(gsz) if gsz else 2
        if op == "all-gather":
            traffic = (k - 1) / k * nbytes
        elif op == "all-reduce":
            traffic = 2 * (k - 1) / k * nbytes
        elif op == "reduce-scatter":
            traffic = (k - 1) * nbytes
        elif op == "all-to-all":
            traffic = (k - 1) / k * nbytes
        else:
            traffic = float(nbytes)
        out[op] += traffic
    return dict(out)
