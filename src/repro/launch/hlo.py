"""Compiled-HLO collective-traffic accounting.

Shared by the dry-run (``launch/dryrun.py``) and the distributed-step
measurement (``launch/diststep.py`` / ``benchmarks/dist_step.py``). Lives
in its own module because ``dryrun.py`` must set ``XLA_FLAGS`` for 512
host devices at import time — anything that wants the parser without that
side effect imports it from here.
"""
from __future__ import annotations

import re
from collections import Counter
from typing import Dict

_DTYPE_BYTES = {"f32": 4, "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "s8": 1,
                "u8": 1, "pred": 1, "f64": 8, "s64": 8, "u64": 8, "c64": 8,
                "s16": 2, "u16": 2, "f8e4m3fn": 1, "f8e5m2": 1}

# Async pairs (`-start`/`-done`, GPU backends) count once, at `-done`,
# whose result shape is the plain array (`-start` results are often
# tuple-shaped and would not parse).
_COLL_RE = re.compile(
    r"=\s*(\w+)\[([\d,]*)\][^=]*?"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(-start|-done)?\(([^\n]*)")

# replica_groups comes in two prints: explicit lists `{{0,1,2},{3,4,5}}`
# (group size = members of the first group) and the iota form
# `[n_groups,group_size]<=[...]`. An empty `replica_groups={}` means one
# group of every participant — only the caller knows that count.
_GROUPS_LIST_RE = re.compile(r"replica_groups=\{\{([\d,]+)\}")
_GROUPS_IOTA_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")
_CHANNEL_RE = re.compile(r"channel_id=(\d+)")

# `-start` halves of async pairs carry the replica_groups attribute (their
# tuple-shaped results don't parse as array instructions), so group sizes
# are collected from them by channel_id and looked up when the matching
# `-done` is priced.
_START_RE = re.compile(
    r"(?:all-gather|all-reduce|reduce-scatter|all-to-all"
    r"|collective-permute)-start\(([^\n]*)")


def _group_size(rest_of_line: str, default: int) -> int:
    m = _GROUPS_LIST_RE.search(rest_of_line)
    if m:
        return len(m.group(1).split(","))
    m = _GROUPS_IOTA_RE.search(rest_of_line)
    if m:
        return int(m.group(2))
    return default


def collective_bytes(hlo_text: str,
                     default_group_size: int = 2) -> Dict[str, float]:
    """Per-device ICI traffic (bytes) by collective type.

    Formulas (ring algorithms, k = group size, n = result bytes/device):
      all-gather: (k-1)/k * n_out ; all-reduce: 2*(k-1)/k * n ;
      reduce-scatter: (k-1)/k * n_in ~ (k-1)*n_out ; all-to-all: (k-1)/k * n;
      collective-permute: n.
    default_group_size: group size assumed when the instruction does not
    print one (`replica_groups={}` = all participants) — pass the mesh
    size when it is known.
    """
    start_groups = {}
    for m in _START_RE.finditer(hlo_text):
        ch = _CHANNEL_RE.search(m.group(1))
        k = _group_size(m.group(1), 0)
        if ch and k:
            start_groups[ch.group(1)] = k
    out: Dict[str, float] = Counter()
    for m in _COLL_RE.finditer(hlo_text):
        dt, dims, op, phase, rest = m.groups()
        if phase == "-start":
            continue                 # counted once, at the matching -done
        nbytes = _DTYPE_BYTES.get(dt, 4)
        for d in dims.split(","):
            if d:
                nbytes *= int(d)
        k = _group_size(rest, 0)
        if not k and phase == "-done":
            ch = _CHANNEL_RE.search(rest)
            k = start_groups.get(ch.group(1), 0) if ch else 0
        if not k:
            k = default_group_size
        if op == "all-gather":
            traffic = (k - 1) / k * nbytes
        elif op == "all-reduce":
            traffic = 2 * (k - 1) / k * nbytes
        elif op == "reduce-scatter":
            traffic = (k - 1) * nbytes
        elif op == "all-to-all":
            traffic = (k - 1) / k * nbytes
        else:
            traffic = float(nbytes)
        out[op] += traffic
    return dict(out)


def compare_collective_bytes(hlo_a: str, hlo_b: str, *,
                             default_group_size: int = 2) -> Dict[str, float]:
    """Total per-device collective bytes of two lowerings and their ratio.

    The wire-invariance check for streamed ZeRO-3: splitting one big param
    all-gather into per-unit (or per-cycle) gathers must not change the
    totals — every ring formula above is linear in the payload bytes — so
    the streamed/unstreamed ratio must be ~1.0 regardless of how the
    collectives are scheduled against compute."""
    a = collective_bytes(hlo_a, default_group_size)
    b = collective_bytes(hlo_b, default_group_size)
    ta, tb = float(sum(a.values())), float(sum(b.values()))
    return {"a_bytes": ta, "b_bytes": tb,
            "ratio": ta / tb if tb else (1.0 if not ta else float("inf"))}


def collective_counts(hlo_text: str) -> Dict[str, int]:
    """Instruction counts by collective type (async pairs count once, at
    ``-done``). Lets a test assert a lowering *contains* the expected ops
    — e.g. the ZeRO-3 variants must carry param all-gathers where the
    masked psum carries none — instead of inferring presence from the
    byte totals alone."""
    out: Dict[str, int] = Counter()
    for m in _COLL_RE.finditer(hlo_text):
        _, _, op, phase, _ = m.groups()
        if phase == "-start":
            continue
        out[op] += 1
    return dict(out)
