import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch × input shape × mesh).

The two lines above MUST run before any other import — jax locks the device
count on first init. Never set that flag globally (smoke tests and benches
must see 1 device).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch gemma3-1b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all            # every live pair
  ... [--multi-pod] [--out experiments/dryrun]
  PYTHONPATH=src python -m repro.launch.dryrun --distributed-step \
      [--n-devices 8]    # shard_map gated step: per-device collective bytes

Per pair this produces a JSON artifact with:
  * memory_analysis (arg/output/temp bytes per device) of the FULL-depth
    compile — proves the config fits and shards;
  * cost_analysis FLOPs with scan-depth extrapolation (XLA counts a while
    body once, so we lower 1-cycle and 2-cycle variants and extrapolate:
    total = f1 + (f2 - f1) * (n_cycles - 1));
  * per-type collective bytes parsed from compiled HLO (same extrapolation),
    converted to per-device ICI traffic;
  * the sharding-policy report (incl. fallbacks).
"""
import argparse
import json
import time
from typing import Dict, Optional

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs import INPUT_SHAPES, SKIPS, get_config, live_pairs
from repro.configs.base import InputShape, ModelConfig
from repro.launch.hlo import collective_bytes
from repro.launch.mesh import make_production_mesh
from repro.launch import specs as S
from repro.models.transformer import layer_groups
from repro.sharding.policy import ShardingPolicy


def _reduced(cfg: ModelConfig, n_cycles: int) -> ModelConfig:
    _, pat, rem = layer_groups(cfg)
    return cfg.replace(n_layers=len(pat) * n_cycles + len(rem))


def _opt_state_shardings(policy: ShardingPolicy, pspecs):
    rep = policy.ns()
    return {"m": pspecs, "v": pspecs, "step": rep}


def lower_pair(cfg: ModelConfig, shape: InputShape, mesh,
               seq_parallel: bool = True, fsdp: bool = True,
               compute_dtype: str = "bfloat16", pad_heads: bool = False,
               attn_q_chunk: int = 0, max_pad_overhead: float = 1.5,
               d2ft_packed=None, capacity_factor: float = 0.0):
    """Build (lower-ready jit, args, policy) for one (arch, shape, mesh).

    pad_heads / attn_q_chunk / d2ft_packed are the §Perf hillclimb levers;
    d2ft_packed = D2FTConfig lowers the packed D2FT train step instead of
    standard full fine-tuning.
    """
    cfg = cfg.replace(param_dtype=compute_dtype, compute_dtype=compute_dtype)
    if capacity_factor and cfg.moe is not None:
        import dataclasses
        cfg = cfg.replace(moe=dataclasses.replace(
            cfg.moe, capacity_factor=capacity_factor))
    policy = ShardingPolicy(mesh, cfg, seq_parallel=seq_parallel, fsdp=fsdp,
                            pad_heads=pad_heads, attn_q_chunk=attn_q_chunk,
                            max_pad_overhead=max_pad_overhead)
    if pad_heads and policy.head_padding() is not None:
        # Materialize the padding at "checkpoint load": zero-padded wq/wo
        # head blocks keep the function exact while letting every attention
        # weight shard head-aligned on `model` (trace-time padding leaves
        # the weights replicated and resharded per layer — measured worse,
        # EXPERIMENTS.md §Perf). The dry-run lowers the padded config.
        Hp, Hkvp = policy.head_padding()
        cfg = cfg.replace(n_heads=Hp, n_kv_heads=Hkvp,
                          head_dim=cfg.resolved_head_dim)
        policy = ShardingPolicy(mesh, cfg, seq_parallel=seq_parallel,
                                fsdp=fsdp, attn_q_chunk=attn_q_chunk)
    params = S.param_shapes(cfg)
    pspecs = policy.param_specs(params)

    if shape.kind == "train" and d2ft_packed is not None:
        step, opt = S.make_packed_train_step_fn(cfg, policy, shape,
                                                d2ft_packed)
        opt_state = jax.eval_shape(opt.init, params)
        ospecs = _opt_state_shardings(policy, pspecs)
        batch = S.batch_specs(cfg, shape)
        bspecs = {k: policy.batch_spec(v.shape) for k, v in batch.items()}
        jitted = jax.jit(step, in_shardings=(pspecs, ospecs, bspecs),
                         out_shardings=(pspecs, ospecs, None))
        return jitted, (params, opt_state, batch), policy

    if shape.kind == "train":
        step, opt = S.make_train_step_fn(cfg, policy)
        opt_state = jax.eval_shape(opt.init, params)
        ospecs = _opt_state_shardings(policy, pspecs)
        batch = S.batch_specs(cfg, shape)
        bspecs = {k: policy.batch_spec(v.shape) for k, v in batch.items()}
        jitted = jax.jit(step, in_shardings=(pspecs, ospecs, bspecs),
                         out_shardings=(pspecs, ospecs, None))
        args = (params, opt_state, batch)
    elif shape.kind == "prefill":
        step = S.make_prefill_fn(cfg, policy)
        batch = S.batch_specs(cfg, shape)
        bspecs = {k: policy.batch_spec(v.shape) for k, v in batch.items()}
        jitted = jax.jit(step, in_shardings=(pspecs, bspecs))
        args = (params, batch)
    else:  # decode
        step = S.make_serve_fn(cfg, policy)
        cache, token, t = S.decode_specs(cfg, shape)
        cspecs = policy.cache_specs(cache)
        jitted = jax.jit(step, in_shardings=(
            pspecs, cspecs, policy.batch_spec(token.shape), policy.ns()),
            out_shardings=(None, cspecs))
        args = (params, cache, token, t)
    return jitted, args, policy


def run_pair(arch: str, shape_name: str, multi_pod: bool = False,
             seq_parallel: bool = True, fsdp: bool = True,
             extrapolate: bool = True, verbose: bool = True,
             variant: str = "baseline", **lever_kw) -> Dict:
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=multi_pod)
    record: Dict = {"arch": arch, "shape": shape_name,
                    "mesh": "2x16x16" if multi_pod else "16x16",
                    "kind": shape.kind, "seq_parallel": seq_parallel,
                    "fsdp": fsdp, "variant": variant, "levers": {
                        k: str(v) for k, v in lever_kw.items()}}
    with mesh:
        # ---- full-depth compile: memory + proof of lowering
        t0 = time.time()
        jitted, args, policy = lower_pair(cfg, shape, mesh, seq_parallel,
                                          fsdp, **lever_kw)
        lowered = jitted.lower(*args)
        compiled = lowered.compile()
        record["compile_s"] = round(time.time() - t0, 1)
        mem = compiled.memory_analysis()
        record["memory"] = {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "alias_bytes": mem.alias_size_in_bytes,
        }
        ca = compiled.cost_analysis() or {}
        record["flops_raw"] = float(ca.get("flops", 0.0))
        record["bytes_raw"] = float(ca.get("bytes accessed", 0.0))
        # empty replica_groups={} prints mean "all participants": the
        # model axis is the group for the dominant tensor-parallel
        # collectives, a conservative default for the rest
        ndev_default = mesh.shape.get("model", mesh.size)
        record["collectives_raw"] = collective_bytes(
            compiled.as_text(), default_group_size=ndev_default)
        record["policy"] = policy.report()

        # ---- depth extrapolation (scan bodies counted once by XLA)
        n_cycles, pat, rem = layer_groups(cfg)
        record["n_cycles"] = n_cycles
        if extrapolate and n_cycles > 2:
            per_depth = {}
            for k in (1, 2):
                cfg_k = _reduced(cfg, k)
                jk, ak, _ = lower_pair(cfg_k, shape, mesh, seq_parallel,
                                       fsdp, **lever_kw)
                ck = jk.lower(*ak).compile()
                cak = ck.cost_analysis() or {}
                per_depth[k] = {
                    "flops": float(cak.get("flops", 0.0)),
                    "bytes": float(cak.get("bytes accessed", 0.0)),
                    "coll": collective_bytes(
                        ck.as_text(), default_group_size=ndev_default),
                }
            f1, f2 = per_depth[1]["flops"], per_depth[2]["flops"]
            b1, b2 = per_depth[1]["bytes"], per_depth[2]["bytes"]
            record["flops"] = max(f1 + (f2 - f1) * (n_cycles - 1),
                                  record["flops_raw"])
            record["bytes"] = max(b1 + (b2 - b1) * (n_cycles - 1),
                                  record["bytes_raw"])
            coll = {}
            keys = set(per_depth[1]["coll"]) | set(per_depth[2]["coll"])
            for key in keys:
                c1 = per_depth[1]["coll"].get(key, 0.0)
                c2 = per_depth[2]["coll"].get(key, 0.0)
                raw = record["collectives_raw"].get(key, 0.0)
                # clamp: depth-1/2 compiles can differ structurally
                coll[key] = max(c1 + (c2 - c1) * (n_cycles - 1), raw, 0.0)
            record["collectives"] = coll
            record["per_depth"] = per_depth
        else:
            record["flops"] = record["flops_raw"]
            record["bytes"] = record["bytes_raw"]
            record["collectives"] = record["collectives_raw"]

    if verbose:
        print(f"[{arch} × {shape_name} × {record['mesh']}] "
              f"compile {record['compile_s']}s  "
              f"temp/device {record['memory']['temp_bytes']/2**30:.2f} GiB  "
              f"args/device {record['memory']['argument_bytes']/2**30:.2f} GiB  "
              f"flops/device {record['flops']:.3e}  "
              f"coll bytes {sum(record['collectives'].values()):.3e}")
        print("  " + record["policy"].replace("\n", "\n  "))
    return record


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--no-seq-parallel", action="store_true")
    ap.add_argument("--no-fsdp", action="store_true")
    ap.add_argument("--no-extrapolate", action="store_true")
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--skip-existing", action="store_true")
    ap.add_argument("--variant", default="baseline",
                    help="tag for the output filename (hillclimb runs)")
    ap.add_argument("--pad-heads", action="store_true")
    ap.add_argument("--max-pad-overhead", type=float, default=1.5)
    ap.add_argument("--attn-q-chunk", type=int, default=0)
    ap.add_argument("--d2ft-packed", action="store_true",
                    help="lower the packed D2FT train step (3pf/1po of 5)")
    ap.add_argument("--n-pf", type=int, default=3)
    ap.add_argument("--n-po", type=int, default=1)
    ap.add_argument("--n-mb", type=int, default=4)
    ap.add_argument("--capacity-factor", type=float, default=0.0,
                    help="override MoE capacity factor (hillclimb lever)")
    ap.add_argument("--head-groups", type=int, default=0)
    ap.add_argument("--distributed-step", action="store_true",
                    help="lower the shard_map distributed D2FT step on a "
                         "data mesh carved from the host devices and report "
                         "per-device collective bytes (paper-mix schedule "
                         "vs all-p_f baseline)")
    ap.add_argument("--n-devices", type=int, default=8,
                    help="data-mesh size for --distributed-step")
    args = ap.parse_args()

    if args.distributed_step:
        from repro.launch.diststep import measure_distributed_step
        rec = measure_distributed_step(args.n_devices)
        os.makedirs(args.out, exist_ok=True)
        path = os.path.join(args.out,
                            f"distributed_step_{args.n_devices}dev.json")
        with open(path, "w") as f:
            json.dump(rec, f, indent=1)
        for name, var in rec["variants"].items():
            print(f"[distributed_step × {name} × {args.n_devices}dev] "
                  f"wire bytes {var['wire_bytes']:.3e}  "
                  f"sync-plan fraction {var['sync_plan']['fraction']:.3f}  "
                  f"load spread {var['rebalance']['spread']}")
        z = rec["zero_sync"]
        z3 = rec["zero3"]
        print(f"paper-mix all-reduce bytes at "
              f"{rec['all_reduce_fraction']:.1%} of the all-p_f baseline "
              f"(sync-plan model: {rec['sync_model_fraction']:.1%}); "
              f"zero sync: paper-mix wire {z['paper_mix_wire_fraction']:.1%}, "
              f"uniform wire {z['uniform_wire_fraction']:.1%}, "
              f"opt memory {z['opt_memory_fraction']:.1%}; "
              f"zero3: wire {z3['paper_mix_wire_fraction']:.1%}, "
              f"param residency {z3['residency_fraction']:.1%}, "
              f"{z3['n_gather_elided']} gathers elided "
              f"-> {path}")
        return

    pairs = list(live_pairs()) if args.all else [(args.arch, args.shape)]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]
    os.makedirs(args.out, exist_ok=True)
    lever_kw = {}
    if args.pad_heads:
        lever_kw["pad_heads"] = True
        lever_kw["max_pad_overhead"] = args.max_pad_overhead
    if args.attn_q_chunk:
        lever_kw["attn_q_chunk"] = args.attn_q_chunk
    if args.capacity_factor:
        lever_kw["capacity_factor"] = args.capacity_factor
    if args.d2ft_packed:
        from repro.configs.base import D2FTConfig
        lever_kw["d2ft_packed"] = D2FTConfig(
            n_microbatches=args.n_mb, n_pf=args.n_pf, n_po=args.n_po,
            head_groups=args.head_groups)
    failures = []
    for arch, shape in pairs:
        if (arch, shape) in SKIPS:
            print(f"[{arch} × {shape}] SKIP: {SKIPS[(arch, shape)]}")
            continue
        for mp in meshes:
            tag = f"{arch}_{shape}_{'2x16x16' if mp else '16x16'}"
            if args.variant != "baseline":
                tag += f"__{args.variant}"
            if args.skip_existing and os.path.exists(
                    os.path.join(args.out, tag + ".json")):
                print(f"[{tag}] exists, skipping")
                continue
            try:
                # the roofline table is single-pod; the multi-pod pass only
                # needs to prove lowering, so skip its extra compiles
                rec = run_pair(arch, shape, multi_pod=mp,
                               seq_parallel=not args.no_seq_parallel,
                               fsdp=not args.no_fsdp,
                               extrapolate=(not args.no_extrapolate)
                               and not mp,
                               variant=args.variant, **lever_kw)
                with open(os.path.join(args.out, tag + ".json"), "w") as f:
                    json.dump(rec, f, indent=1)
            except Exception as e:  # noqa: BLE001 — record and continue
                failures.append((tag, repr(e)))
                print(f"[{tag}] FAILED: {e}")
    if failures:
        print(f"\n{len(failures)} failures:")
        for tag, err in failures:
            print(" ", tag, err[:200])
        raise SystemExit(1)
    print("\nAll requested dry-runs compiled successfully.")


if __name__ == "__main__":
    main()
