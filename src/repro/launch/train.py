"""Production train launcher.

On a real TPU pod each host runs (with jax.distributed auto-init):

  python -m repro.launch.train --arch gemma3-1b --shape train_4k \
      --d2ft --n-pf 3 --n-po 1 --steps 500 --ckpt /tmp/ckpt

On this CPU container it runs the same code path on a 1-device mesh with a
reduced config unless --full is passed (the full configs only fit a pod).
"""
from __future__ import annotations

import argparse
import time

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs import INPUT_SHAPES, get_config, get_smoke_config
from repro.configs.base import D2FTConfig
from repro.data.synthetic import lm_batches
from repro.launch.mesh import make_host_mesh, make_production_mesh
from repro.launch.parallel import MeshSpec, ParallelConfig
from repro.models.transformer import init_model
from repro.optim.optimizers import adamw, sgd
from repro.sharding.policy import ShardingPolicy
from repro.train.checkpoints import save_checkpoint
from repro.train.loop import finetune, finetune_distributed


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--shape", default="train_4k")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--optimizer", choices=("sgd", "adamw"), default="adamw")
    ap.add_argument("--d2ft", action="store_true")
    ap.add_argument("--packed", action="store_true",
                    help="use the packed D2FT execution path")
    ap.add_argument("--distributed", action="store_true",
                    help="data-parallel D2FT over the mesh's data axis: "
                         "multiple-knapsack device assignment + shard_map "
                         "gated step with the schedule-masked grad psum "
                         "(requires --d2ft, excludes --packed)")
    ap.add_argument("--kernel", action="store_true",
                    help="route attention through the compacted Pallas "
                         "gated kernel path (single-device or per-shard "
                         "with --distributed; interpret mode on CPU)")
    ap.add_argument("--mesh", default=None, metavar="data=D,stage=S,tensor=T",
                    help="multi-axis device mesh (launch.parallel.MeshSpec "
                         "syntax, unlisted axes default 1): stage>1 runs "
                         "the GPipe microbatch pipeline with live-cost "
                         "stage packing, tensor>1 shards attention heads / "
                         "FFN columns Megatron-style; requires "
                         "--distributed and enough local devices "
                         "(default: all-data mesh)")
    ap.add_argument("--sync-mode",
                    choices=("masked", "zero", "zero3", "local"),
                    default="masked",
                    help="distributed gradient sync: 'masked' = schedule-"
                         "masked psum (replicated optimizer state), "
                         "'zero' = ZeRO-1 sliced reduce-scatter/all-gather "
                         "with optimizer moments sharded ~1/n_devices, "
                         "'zero3' = fully sharded params with the "
                         "schedule-masked (gate-elided) forward gather, "
                         "'local' = lo-fi zero-sync replicas merged every "
                         "--merge-every steps (requires --elastic; see "
                         "docs/robustness.md)")
    ap.add_argument("--refresh-every", type=int, default=None,
                    help="re-plan the schedule (and re-run the knapsack "
                         "device assigner, rebuild the sync plan) every "
                         "k steps")
    ap.add_argument("--n-pf", type=int, default=3)
    ap.add_argument("--n-po", type=int, default=1)
    ap.add_argument("--n-microbatches", type=int, default=4)
    ap.add_argument("--full", action="store_true",
                    help="full-size config on the production mesh "
                         "(requires a pod)")
    ap.add_argument("--ckpt", default=None)
    ap.add_argument("--elastic", action="store_true",
                    help="run the fault-tolerant elastic loop "
                         "(train.elastic.finetune_elastic): straggler-"
                         "aware replanning, dropout recovery from step-"
                         "level checkpoints, NaN-burst gradient guard, "
                         "lo-fi sync fallback (docs/robustness.md); "
                         "requires --distributed")
    ap.add_argument("--faults", default=None, metavar="PATH.json",
                    help="inject a deterministic FaultPlan from a JSON "
                         "file (launch.faults.FaultPlan.to_json) into the "
                         "elastic loop — slowdowns, a device dropout, "
                         "gradient bursts, dropped sync rounds")
    ap.add_argument("--ckpt-every", type=int, default=1,
                    help="elastic step-level checkpoint cadence (steps); "
                         "0 disables periodic checkpoints")
    ap.add_argument("--ckpt-dir", default=None,
                    help="directory for elastic step-level checkpoints "
                         "(default: a fresh temp dir)")
    ap.add_argument("--merge-every", type=int, default=4,
                    help="lo-fi local-mode weight-merge cadence (steps)")
    ap.add_argument("--resume-from", default=None, metavar="CKPT.npz",
                    help="resume the elastic loop from a step-level "
                         "checkpoint (save_train_state format), on the "
                         "original mesh size or a shrunk one")
    args = ap.parse_args()

    spec = MeshSpec.parse(args.mesh) if args.mesh else None
    if spec is not None and not args.distributed:
        raise SystemExit("--mesh only applies to the --distributed path")
    if spec is not None and args.elastic and \
            (spec.stage > 1 or spec.tensor > 1):
        raise SystemExit("--elastic runs on a pure data mesh; use "
                         "--mesh data=N (stage=tensor=1)")
    if args.full:
        cfg = get_config(args.arch)
        mesh = spec.build() if spec is not None else make_production_mesh()
    else:
        cfg = get_smoke_config(args.arch)
        mesh = spec.build() if spec is not None else make_host_mesh()
    print(f"arch={cfg.name} layers={cfg.n_layers} d_model={cfg.d_model} "
          f"mesh={dict(mesh.shape)}")

    if cfg.frontend != "none":
        raise SystemExit("text-training launcher; audio/vlm archs use the "
                         "example drivers (examples/)")
    if args.packed and args.kernel:
        raise SystemExit("--packed and --kernel are exclusive (the packed "
                         "gather path bypasses the gated attention kernel)")
    if not args.distributed and (args.sync_mode != "masked"
                                 or args.refresh_every is not None):
        raise SystemExit("--sync-mode/--refresh-every only apply to the "
                         "--distributed path")
    if not args.elastic and (args.faults or args.resume_from
                             or args.sync_mode == "local"):
        raise SystemExit("--faults/--resume-from/--sync-mode local require "
                         "--elastic (the plain distributed loop has no "
                         "fault handling)")
    if args.elastic and not args.distributed:
        raise SystemExit("--elastic requires --distributed")

    d2 = None
    if args.d2ft:
        d2 = D2FTConfig(n_microbatches=args.n_microbatches, n_pf=args.n_pf,
                        n_po=args.n_po,
                        head_groups=max(cfg.n_heads, 1))
        print(f"D2FT: {args.n_pf} p_f + {args.n_po} p_o of "
              f"{args.n_microbatches} micro-batches "
              f"(compute {100 * (args.n_pf + 0.4 * args.n_po) / args.n_microbatches:.0f}%)")

    params = init_model(jax.random.PRNGKey(0), cfg)
    opt = adamw(args.lr) if args.optimizer == "adamw" else sgd(args.lr)
    batches = lm_batches(0, cfg.vocab_size, args.batch, args.seq,
                         args.steps)
    t0 = time.time()
    if args.distributed:
        if d2 is None:
            raise SystemExit("--distributed requires --d2ft")
        if args.packed:
            raise SystemExit("--distributed and --packed are exclusive "
                             "(the shard_map step drives the gated paths)")
        if spec is None:
            spec = MeshSpec(data=int(mesh.shape["data"]))
        pconf = ParallelConfig(
            mesh=spec, sync_mode=args.sync_mode, use_kernel=args.kernel,
            microbatches=args.n_microbatches if spec.stage > 1 else 0)
        ndev = mesh.shape["data"]
        if spec.stage > 1 and (args.batch // ndev) % args.n_microbatches:
            raise SystemExit(
                f"pipeline needs the per-data-shard batch divisible by the "
                f"microbatch count: ({args.batch} / {ndev}) % "
                f"{args.n_microbatches} != 0")
        if args.n_microbatches % ndev:
            raise SystemExit(
                f"--distributed needs --n-microbatches divisible by the "
                f"data-mesh size: {args.n_microbatches} % {ndev} != 0 "
                "(equal-sized shard_map shards)")
        if args.batch % args.n_microbatches:
            raise SystemExit(
                f"--batch must be divisible by --n-microbatches: "
                f"{args.batch} % {args.n_microbatches} != 0")
        if args.elastic:
            from repro.launch.faults import FaultPlan
            from repro.train.elastic import ElasticConfig, finetune_elastic
            fp = None
            if args.faults:
                with open(args.faults) as f:
                    fp = FaultPlan.from_json(f.read())
            el = ElasticConfig(refresh_every=args.refresh_every,
                               ckpt_every=args.ckpt_every,
                               ckpt_dir=args.ckpt_dir,
                               merge_every=args.merge_every)
            params, opt_state, log = finetune_elastic(
                params, cfg, d2, opt, batches, steps=args.steps,
                mesh=mesh, sync_mode=args.sync_mode, faults=fp,
                elastic=el, use_kernel=args.kernel,
                resume_from=args.resume_from)
            ev = log.extras["elastic"]
            print(f"elastic: final_mode={ev['final_mode']} "
                  f"devices={ev['n_devices']} "
                  f"guard_skips={ev['guard_skips']} "
                  f"sync_faults={ev['sync_faults']} "
                  f"merges={ev['merges']}")
            for e in ev["events"]:
                print(f"  event: {e}")
            print(f"last checkpoint: {ev['last_ckpt']}")
        else:
            params, opt_state, log = finetune_distributed(
                params, cfg, d2, opt, batches, steps=args.steps,
                mesh=mesh, parallel=pconf,
                refresh_every=args.refresh_every)
        rep, sync = log.extras["rebalance"], log.extras.get("sync")
        print(f"assignment: loads {rep['loads']} spread {rep['spread']} "
              f"imbalance {rep['imbalance']:.3f} "
              f"({len(log.extras.get('refreshes', []))} replans)")
        stages = log.extras.get("stages")
        if stages is not None:
            print(f"pipeline: boundaries {stages['boundaries']} "
                  f"loads {stages['loads']} "
                  f"makespan_ratio {stages['makespan_ratio']:.3f} "
                  f"(vs layer-count {stages['layer_count_boundaries']}) "
                  f"bubble {stages['bubble_fraction']:.3f}")
        if sync is None:
            print("grad sync: none (lo-fi local replicas, merged "
                  f"every {args.merge_every} steps)")
        elif args.sync_mode in ("zero", "zero3"):
            print(f"grad sync ({args.sync_mode}): {sync['fraction']:.0%} "
                  f"all-reduce-equivalent bytes ({sync['n_zero']} leaves "
                  f"partitioned over {ndev} shards, "
                  f"rs {sync['rs_bytes']:.2e}B / "
                  f"ag {sync['ag_bytes']:.2e}B)")
            z3 = log.extras.get("zero3_params")
            if args.sync_mode == "zero3" and z3 is not None:
                print(f"param residency (zero3): "
                      f"{z3['fraction']:.0%} of replicated peak "
                      f"({z3['n_gather_elided']} forward-dead gathers "
                      f"elided, peak unit {z3['peak_unit']})")
        else:
            print(f"grad sync: {sync['fraction']:.0%} of param bytes "
                  f"all-reduced ({sync['n_skipped']} leaves skipped, "
                  f"{sync['n_sliced']} group-sliced)")
    else:
        params, opt_state, log = finetune(params, cfg, d2, opt, batches,
                                          steps=args.steps,
                                          packed=args.packed,
                                          use_kernel=args.kernel)
    dt = time.time() - t0
    print(f"{args.steps} steps in {dt:.1f}s — loss "
          f"{log.losses[0]:.3f} -> {log.losses[-1]:.3f}")
    if args.ckpt:
        save_checkpoint(args.ckpt, {"params": params})
        print(f"saved {args.ckpt}")


if __name__ == "__main__":
    main()
