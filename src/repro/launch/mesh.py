"""Production mesh construction.

Target: TPU v5e, 256 chips per pod. Single pod = (data=16, model=16);
two pods = (pod=2, data=16, model=16) with the ``pod`` axis carrying
data parallelism across the DCN/ICI boundary (gradient all-reduce only).

Defined as a FUNCTION so importing this module never touches jax device
state (required: smoke tests must see 1 CPU device; only dryrun.py sets
XLA_FLAGS for 512 host devices before any jax import).
"""
from __future__ import annotations

import jax

try:  # jax.sharding.AxisType (and the axis_types kwarg) appeared after 0.4.37
    from jax.sharding import AxisType
except ImportError:
    AxisType = None


def compat_make_mesh(shape, axes):
    """jax.make_mesh across jax versions: passes axis_types=Auto when the
    installed jax supports it, plain make_mesh otherwise."""
    if AxisType is not None:
        return jax.make_mesh(shape, axes,
                             axis_types=(AxisType.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat_make_mesh(shape, axes)


def make_host_mesh(model: int = 1):
    """Tiny mesh over however many local devices exist (tests/examples)."""
    n = len(jax.devices())
    data = n // model
    return compat_make_mesh((data, model), ("data", "model"))


def make_data_mesh(n_devices=None):
    """1-D ("data",) mesh over the first n local devices.

    The distributed D2FT train step (train.loop.make_distributed_train_step)
    is pure data parallelism, so it runs on this or on make_host_mesh's
    ("data", "model") mesh alike; the explicit device count lets the
    dry-run carve an 8-device data mesh out of its 512 host devices."""
    import numpy as np
    devs = jax.devices()
    n = len(devs) if n_devices is None else int(n_devices)
    if n > len(devs):
        # never truncate silently: a bench/dry-run asking for 8 devices on
        # a 1-device backend would otherwise record a bogus measurement
        raise ValueError(
            f"requested a {n}-device data mesh but only {len(devs)} local "
            "devices exist (--xla_force_host_platform_device_count must be "
            "in XLA_FLAGS before jax initializes)")
    return jax.sharding.Mesh(np.asarray(devs[:n]), ("data",))


# TPU v5e hardware constants used by the roofline analysis.
PEAK_FLOPS_BF16 = 197e12        # per chip
HBM_BW = 819e9                  # bytes/s per chip
ICI_BW = 50e9                   # bytes/s per link
