"""Production mesh construction.

Target: TPU v5e, 256 chips per pod. Single pod = (data=16, model=16);
two pods = (pod=2, data=16, model=16) with the ``pod`` axis carrying
data parallelism across the DCN/ICI boundary (gradient all-reduce only).

All constructors are thin wrappers over the one mesh entry point,
``launch.parallel.MeshSpec.build`` — multi-axis (data, stage, tensor)
meshes come straight from ``MeshSpec(...).build()``; the functions here
keep the legacy axis layouts (1-D ``("data",)``, GSPMD
``("data", "model")``) alive.

Defined as FUNCTIONS so importing this module never touches jax device
state (required: smoke tests must see 1 CPU device; only dryrun.py sets
XLA_FLAGS for 512 host devices before any jax import).
"""
from __future__ import annotations

import jax

try:  # jax.sharding.AxisType (and the axis_types kwarg) appeared after 0.4.37
    from jax.sharding import AxisType
except ImportError:
    AxisType = None


def compat_make_mesh(shape, axes):
    """jax.make_mesh across jax versions: passes axis_types=Auto when the
    installed jax supports it, plain make_mesh otherwise."""
    if AxisType is not None:
        return jax.make_mesh(shape, axes,
                             axis_types=(AxisType.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    from repro.launch.parallel import MeshSpec
    if multi_pod:
        # the pod axis rides the spec's data slot; data/model fill stage/
        # tensor — build() is a pure reshape+naming, the semantics live in
        # the axis names
        return MeshSpec(data=2, stage=16, tensor=16).build(
            axis_names=("pod", "data", "model"), auto_axes=True)
    return MeshSpec(data=16, stage=16).build(
        axis_names=("data", "model"), auto_axes=True)


def make_host_mesh(model: int = 1):
    """Tiny ("data", "model") mesh over ALL local devices (tests/examples)."""
    from repro.launch.parallel import MeshSpec
    n = len(jax.devices())
    if n % model:
        # never drop remainder devices silently (same contract as
        # make_data_mesh's short-mesh refusal)
        raise ValueError(
            f"make_host_mesh(model={model}) cannot tile {n} local devices: "
            f"{n} % {model} != 0 would silently drop "
            f"{n % model} device(s)")
    return MeshSpec(data=n // model, stage=model).build(
        axis_names=("data", "model"), auto_axes=True)


def make_data_mesh(n_devices=None):
    """1-D ("data",) mesh over the first n local devices.

    The distributed D2FT train step (train.loop.make_distributed_train_step)
    is pure data parallelism, so it runs on this or on make_host_mesh's
    ("data", "model") mesh alike; the explicit device count lets the
    dry-run carve an 8-device data mesh out of its 512 host devices."""
    from repro.launch.parallel import MeshSpec
    n = len(jax.devices()) if n_devices is None else int(n_devices)
    return MeshSpec(data=n).build(axis_names=("data",))


# TPU v5e hardware constants used by the roofline analysis.
PEAK_FLOPS_BF16 = 197e12        # per chip
HBM_BW = 819e9                  # bytes/s per chip
ICI_BW = 50e9                   # bytes/s per link
