"""Production mesh construction.

Target: TPU v5e, 256 chips per pod. Single pod = (data=16, model=16);
two pods = (pod=2, data=16, model=16) with the ``pod`` axis carrying
data parallelism across the DCN/ICI boundary (gradient all-reduce only).

Defined as a FUNCTION so importing this module never touches jax device
state (required: smoke tests must see 1 CPU device; only dryrun.py sets
XLA_FLAGS for 512 host devices before any jax import).
"""
from __future__ import annotations

import jax

try:  # jax.sharding.AxisType (and the axis_types kwarg) appeared after 0.4.37
    from jax.sharding import AxisType
except ImportError:
    AxisType = None


def compat_make_mesh(shape, axes):
    """jax.make_mesh across jax versions: passes axis_types=Auto when the
    installed jax supports it, plain make_mesh otherwise."""
    if AxisType is not None:
        return jax.make_mesh(shape, axes,
                             axis_types=(AxisType.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return compat_make_mesh(shape, axes)


def make_host_mesh(model: int = 1):
    """Tiny mesh over however many local devices exist (tests/examples)."""
    n = len(jax.devices())
    data = n // model
    return compat_make_mesh((data, model), ("data", "model"))


# TPU v5e hardware constants used by the roofline analysis.
PEAK_FLOPS_BF16 = 197e12        # per chip
HBM_BW = 819e9                  # bytes/s per chip
ICI_BW = 50e9                   # bytes/s per link
