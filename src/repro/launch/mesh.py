"""Production mesh construction.

Target: TPU v5e, 256 chips per pod. Single pod = (data=16, model=16);
two pods = (pod=2, data=16, model=16) with the ``pod`` axis carrying
data parallelism across the DCN/ICI boundary (gradient all-reduce only).

Defined as a FUNCTION so importing this module never touches jax device
state (required: smoke tests must see 1 CPU device; only dryrun.py sets
XLA_FLAGS for 512 host devices before any jax import).
"""
from __future__ import annotations

import jax
from jax.sharding import AxisType


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(axes))


def make_host_mesh(model: int = 1):
    """Tiny mesh over however many local devices exist (tests/examples)."""
    n = len(jax.devices())
    data = n // model
    return jax.make_mesh((data, model), ("data", "model"),
                         axis_types=(AxisType.Auto,) * 2)


# TPU v5e hardware constants used by the roofline analysis.
PEAK_FLOPS_BF16 = 197e12        # per chip
HBM_BW = 819e9                  # bytes/s per chip
ICI_BW = 50e9                   # bytes/s per link
