"""ShapeDtypeStruct input stand-ins + step builders for every
(architecture × input shape) combination. No device allocation — the
dry-run lowers against these specs only.
"""
from __future__ import annotations

import functools
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import INPUT_SHAPES, InputShape, ModelConfig
from repro.models import frontends
from repro.models.transformer import (decode_step, forward, init_cache,
                                      init_model, lm_loss)
from repro.optim.optimizers import adamw, clip_by_global_norm


def batch_specs(cfg: ModelConfig, shape: InputShape) -> Dict:
    """Training/prefill batch ShapeDtypeStructs."""
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    specs: Dict = {}
    if cfg.frontend == "audio_stub":
        specs["features"] = frontends.feature_spec(cfg, B, S)
        specs["labels"] = jax.ShapeDtypeStruct((B, S), i32)
    elif cfg.frontend == "vision_stub":
        s_text = S - cfg.frontend_tokens
        specs["features"] = frontends.feature_spec(cfg, B, S)
        specs["tokens"] = jax.ShapeDtypeStruct((B, s_text), i32)
        specs["labels"] = jax.ShapeDtypeStruct((B, s_text), i32)
    else:
        specs["tokens"] = jax.ShapeDtypeStruct((B, S), i32)
        specs["labels"] = jax.ShapeDtypeStruct((B, S), i32)
    if shape.kind == "prefill":
        specs.pop("labels", None)
    return specs


def param_shapes(cfg: ModelConfig):
    return jax.eval_shape(functools.partial(init_model, cfg=cfg),
                          jax.random.PRNGKey(0))


def make_train_step_fn(cfg: ModelConfig, policy, lr: float = 1e-4):
    """Full fine-tuning step (fwd + bwd + AdamW update), remat'd scan."""
    opt = adamw(lr)

    def train_step(params, opt_state, batch):
        def loss_of(p):
            return lm_loss(p, cfg, batch.get("tokens"), batch["labels"],
                           features=batch.get("features"), policy=policy,
                           remat=True)
        (loss, metrics), grads = jax.value_and_grad(
            loss_of, has_aux=True)(params)
        grads, gnorm = clip_by_global_norm(grads, 1.0)
        params, opt_state = opt.update(grads, opt_state, params)
        return params, opt_state, {"loss": loss, "grad_norm": gnorm}

    return train_step, opt


def make_packed_train_step_fn(cfg: ModelConfig, policy, shape: InputShape,
                              d2ft):
    """Packed D2FT fine-tuning step (the paper's technique at production
    scale): every head-group processes only its knapsack-selected
    micro-batches. The schedule is planned host-side (here: uniform scores
    -> balanced table, the structure the knapsack guarantees) and baked in
    as static gather indices."""
    import numpy as np
    from repro.core.d2ft import (mb_packed_indices, packed_forward_mb,
                                 plan_schedule)
    from repro.models.transformer import fused_xent

    G = d2ft.head_groups or policy.model_size
    rng = np.random.default_rng(0)
    K, N = cfg.n_layers * G, d2ft.n_microbatches
    bw = np.repeat(rng.random((K, 1)) + 0.1, N, 1)
    fw = rng.random((K, N)) + 0.1
    sched = plan_schedule(d2ft, bw, fw, cfg.n_layers, G)
    idx, bwd, val = mb_packed_indices(sched, N)
    arrays = (jnp.asarray(idx), jnp.asarray(bwd), jnp.asarray(val))
    opt = adamw(1e-4)

    def train_step(params, opt_state, batch):
        def loss_of(p):
            logits, _ = packed_forward_mb(p, cfg, batch["tokens"], arrays,
                                          N, policy=policy, remat=True)
            return fused_xent(logits, batch["labels"])
        loss, grads = jax.value_and_grad(loss_of)(params)
        grads, gnorm = clip_by_global_norm(grads, 1.0)
        params, opt_state = opt.update(grads, opt_state, params)
        return params, opt_state, {"loss": loss, "grad_norm": gnorm}

    return train_step, opt


def make_prefill_fn(cfg: ModelConfig, policy):
    def prefill_step(params, batch):
        logits, _ = forward(params, cfg, tokens=batch.get("tokens"),
                            features=batch.get("features"), policy=policy,
                            remat=False)
        return logits[:, -1]          # next-token logits
    return prefill_step


def make_serve_fn(cfg: ModelConfig, policy):
    def serve_step(params, cache, token, t):
        logits, cache = decode_step(params, cache, cfg, token, t,
                                    policy=policy)
        return jnp.argmax(logits[:, -1], -1).astype(jnp.int32)[:, None], cache
    return serve_step


def decode_specs(cfg: ModelConfig, shape: InputShape):
    B, S = shape.global_batch, shape.seq_len
    cache = jax.eval_shape(
        functools.partial(init_cache, cfg=cfg, batch=B, max_len=S))
    token = jax.ShapeDtypeStruct((B, 1), jnp.int32)
    t = jax.ShapeDtypeStruct((), jnp.int32)
    return cache, token, t
