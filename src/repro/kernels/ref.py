"""Pure-jnp oracles for the Pallas kernels (the allclose targets).

``gated_attention_ref`` is also the *reference VJP*: its forward matches the
kernel path and differentiating it with ``jax.grad``/``jax.vjp`` produces
the target dq/dk/dv for grad-parity tests — the stop-gradient mix encodes
the p_f / p_o / p_s semantics exactly (see models.transformer.gate_mix).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -2.0 ** 30


def attention_ref(q, k, v, *, causal: bool = True, window: int = 0):
    """Ungated attention oracle; q, k, v: [B, H, S, hd]. Returns f32."""
    B, H, S, hd = q.shape
    scale = 1.0 / jnp.sqrt(jnp.asarray(hd, jnp.float32))
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    qpos = jnp.arange(S)[:, None]
    kpos = jnp.arange(S)[None, :]
    mask = jnp.ones((S, S), bool)
    if causal:
        mask &= kpos <= qpos
    if window and window > 0:
        mask &= kpos > qpos - window
    s = jnp.where(mask[None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32))


def d2ft_attention_ref(q, k, v, gates, *, causal: bool = True,
                       window: int = 0):
    """Gated attention oracle.

    q, k, v: [B, H, S, hd]; gates: [B, H] in {0, 1} — 0 means the
    (micro-batch sample, head) subnet is shortcut (p_s): output is zeros.
    """
    out = attention_ref(q, k, v, causal=causal, window=window)
    out = out * gates[:, :, None, None].astype(jnp.float32)
    return out.astype(q.dtype)


def gated_attention_ref(q, k, v, g_f, g_b, *, causal: bool = True,
                        window: int = 0):
    """Reference VJP oracle for ``ops.gated_attention``.

    Forward: g_f * attention (p_s heads zeroed). Backward: gradients flow
    only where g_b == 1 — the (1 - g_b) share routes through stop_gradient,
    so p_o heads keep their forward value but contribute zero dq/dk/dv.
    Gates are schedule constants in {0, 1} with g_b <= g_f elementwise.
    """
    out = attention_ref(q, k, v, causal=causal, window=window)
    gf = g_f[:, :, None, None].astype(jnp.float32)
    gb = g_b[:, :, None, None].astype(jnp.float32)
    out = gf * (gb * out + (1.0 - gb) * jax.lax.stop_gradient(out))
    return out.astype(q.dtype)


def lora_matmul_ref(x, w, a, b, scale: float):
    """y = x @ w + scale * (x @ a) @ b.   x: [M, K]; w: [K, N];
    a: [K, r]; b: [r, N]."""
    base = x @ w
    delta = (x @ a) @ b
    return base + scale * delta.astype(base.dtype)
