"""Pure-jnp oracles for the Pallas kernels (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -2.0 ** 30


def d2ft_attention_ref(q, k, v, gates, *, causal: bool = True,
                       window: int = 0):
    """Gated attention oracle.

    q, k, v: [B, H, S, hd]; gates: [B, H] in {0, 1} — 0 means the
    (micro-batch sample, head) subnet is shortcut (p_s): output is zeros.
    """
    B, H, S, hd = q.shape
    scale = 1.0 / jnp.sqrt(jnp.asarray(hd, jnp.float32))
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32), k.astype(jnp.float32)) * scale
    qpos = jnp.arange(S)[:, None]
    kpos = jnp.arange(S)[None, :]
    mask = jnp.ones((S, S), bool)
    if causal:
        mask &= kpos <= qpos
    if window and window > 0:
        mask &= kpos > qpos - window
    s = jnp.where(mask[None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32))
    out = out * gates[:, :, None, None].astype(jnp.float32)
    return out.astype(q.dtype)


def lora_matmul_ref(x, w, a, b, scale: float):
    """y = x @ w + scale * (x @ a) @ b.   x: [M, K]; w: [K, N];
    a: [K, r]; b: [r, N]."""
    base = x @ w
    delta = (x @ a) @ b
    return base + scale * delta.astype(base.dtype)
