"""Pure-jnp oracles for the Pallas kernels (the allclose targets).

``gated_attention_ref`` is also the *reference VJP*: its forward matches the
kernel path and differentiating it with ``jax.grad``/``jax.vjp`` produces
the target dq/dk/dv for grad-parity tests — the stop-gradient mix encodes
the p_f / p_o / p_s semantics exactly (see models.transformer.gate_mix).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -2.0 ** 30


def attention_ref(q, k, v, *, causal: bool = True, window: int = 0):
    """Ungated attention oracle; q, k, v: [B, H, S, hd]. Returns f32."""
    B, H, S, hd = q.shape
    scale = 1.0 / jnp.sqrt(jnp.asarray(hd, jnp.float32))
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    qpos = jnp.arange(S)[:, None]
    kpos = jnp.arange(S)[None, :]
    mask = jnp.ones((S, S), bool)
    if causal:
        mask &= kpos <= qpos
    if window and window > 0:
        mask &= kpos > qpos - window
    s = jnp.where(mask[None, None], s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32))


def d2ft_attention_ref(q, k, v, gates, *, causal: bool = True,
                       window: int = 0):
    """Gated attention oracle.

    q, k, v: [B, H, S, hd]; gates: [B, H] in {0, 1} — 0 means the
    (micro-batch sample, head) subnet is shortcut (p_s): output is zeros.
    """
    out = attention_ref(q, k, v, causal=causal, window=window)
    out = out * gates[:, :, None, None].astype(jnp.float32)
    return out.astype(q.dtype)


def gated_attention_ref(q, k, v, g_f, g_b, *, causal: bool = True,
                        window: int = 0):
    """Reference VJP oracle for ``ops.gated_attention``.

    Forward: g_f * attention (p_s heads zeroed). Backward: gradients flow
    only where g_b == 1 — the (1 - g_b) share routes through stop_gradient,
    so p_o heads keep their forward value but contribute zero dq/dk/dv.
    Gates are schedule constants in {0, 1} with g_b <= g_f elementwise.
    """
    out = attention_ref(q, k, v, causal=causal, window=window)
    gf = g_f[:, :, None, None].astype(jnp.float32)
    gb = g_b[:, :, None, None].astype(jnp.float32)
    out = gf * (gb * out + (1.0 - gb) * jax.lax.stop_gradient(out))
    return out.astype(q.dtype)


def lora_matmul_ref(x, w, a, b, scale: float):
    """y = x @ w + scale * (x @ a) @ b.   x: [M, K]; w: [K, N];
    a: [K, r]; b: [r, N]."""
    base = x @ w
    delta = (x @ a) @ b
    return base + scale * delta.astype(base.dtype)


# ------------------------------------------------------------------ SSD scan
def ssd_scan_ref(x, da, Bm, Cm, chunk: int):
    """Ungated SSD chunked-scan oracle — ``models.ssm.ssd_chunked`` minus
    the dt/A preprocessing (operands here are already the dt-weighted input
    and per-step log-decay, matching the kernel boundary). x: [B,S,H,P];
    da: [B,S,H]; Bm, Cm: [B,S,N]. Returns y: [B,S,H,P]."""
    Bsz, S, H, P = x.shape
    N = Bm.shape[-1]
    Q = min(chunk, S)
    assert S % Q == 0, (S, Q)
    nc = S // Q
    dac = da.reshape(Bsz, nc, Q, H)
    xc = x.reshape(Bsz, nc, Q, H, P)
    Bc = Bm.reshape(Bsz, nc, Q, N)
    Cc = Cm.reshape(Bsz, nc, Q, N)
    cum = jnp.cumsum(dac, axis=2)
    total = cum[:, :, -1]
    diff = cum[:, :, :, None, :] - cum[:, :, None, :, :]
    causal = jnp.tril(jnp.ones((Q, Q), bool))
    L = jnp.where(causal[None, None, :, :, None], jnp.exp(diff), 0.0)
    CB = jnp.einsum("bcqn,bckn->bcqk", Cc, Bc)
    y_intra = jnp.einsum("bcqk,bcqkh,bckhp->bcqhp", CB, L, xc)
    decay_to_end = jnp.exp(total[:, :, None] - cum)
    states = jnp.einsum("bckh,bckhp,bckn->bchpn", decay_to_end, xc,
                        Bc).astype(jnp.float32)

    def step(carry, inp):
        st, tot = inp
        return carry * jnp.exp(tot)[:, :, None, None] + st, carry
    init = jnp.zeros((Bsz, H, P, N), jnp.float32)
    _, prev_states = jax.lax.scan(
        step, init, (jnp.moveaxis(states, 1, 0),
                     jnp.moveaxis(total, 1, 0).astype(jnp.float32)))
    prev_states = jnp.moveaxis(prev_states, 0, 1)
    y_inter = jnp.einsum("bcqn,bcqh,bchpn->bcqhp",
                         Cc.astype(jnp.float32), jnp.exp(cum), prev_states)
    y = (y_intra.astype(jnp.float32) + y_inter).reshape(Bsz, S, H, P)
    return y.astype(x.dtype)


def gated_ssd_ref(x, da, Bm, Cm, g_f, g_b, *, chunk: int):
    """Reference VJP oracle for ``ops.gated_ssd_scan``: g_f gates the
    forward per (sample, head), the (1 - g_b) share routes through
    stop_gradient so p_o heads keep their value but no gradients."""
    y = ssd_scan_ref(x, da, Bm, Cm, chunk)
    gf = g_f[:, None, :, None].astype(y.dtype)
    gb = g_b[:, None, :, None].astype(y.dtype)
    return gf * (gb * y + (1.0 - gb) * jax.lax.stop_gradient(y))


# --------------------------------------------------------------- RG-LRU scan
def rglru_scan_ref(log_a, b, chunk: int):
    """Ungated RG-LRU chunked-scan oracle: h_t = exp(log_a_t) h_{t-1} + b_t
    via the same chunked log-space formulation as the kernel (exponents
    always <= 0). log_a, b: [B,S,W] f32. Returns h: [B,S,W] f32."""
    Bsz, S, W = log_a.shape
    Q = min(chunk, S)
    assert S % Q == 0, (S, Q)
    nc = S // Q
    lac = log_a.reshape(Bsz, nc, Q, W).astype(jnp.float32)
    bc = b.reshape(Bsz, nc, Q, W).astype(jnp.float32)
    lc = jnp.cumsum(lac, axis=2)
    diff = lc[:, :, :, None, :] - lc[:, :, None, :, :]
    causal = jnp.tril(jnp.ones((Q, Q), bool))
    Lm = jnp.where(causal[None, None, :, :, None], jnp.exp(diff), 0.0)
    h_intra = jnp.einsum("bcqkw,bckw->bcqw", Lm, bc)

    def step(carry, inp):
        hi, lcc = inp
        h = hi + jnp.exp(lcc) * carry[:, None, :]
        return h[:, -1], h
    init = jnp.zeros((Bsz, W), jnp.float32)
    _, hs = jax.lax.scan(step, init, (jnp.moveaxis(h_intra, 1, 0),
                                      jnp.moveaxis(lc, 1, 0)))
    return jnp.moveaxis(hs, 0, 1).reshape(Bsz, S, W)


def gated_rglru_ref(log_a, b, g_f, g_b, *, chunk: int):
    """Reference VJP oracle for ``ops.gated_rglru_scan``: gates are
    per (sample, channel-group), G = g_f.shape[1] slicing W into G
    contiguous groups."""
    h = rglru_scan_ref(log_a, b, chunk)
    Bsz, S, W = h.shape
    G = g_f.shape[1]
    hg = h.reshape(Bsz, S, G, W // G)
    gf = g_f[:, None, :, None].astype(h.dtype)
    gb = g_b[:, None, :, None].astype(h.dtype)
    hg = gf * (gb * hg + (1.0 - gb) * jax.lax.stop_gradient(hg))
    return hg.reshape(Bsz, S, W)


# ------------------------------------------------------------ MoE expert FFN
def gated_moe_ffn_ref(xb, w_up, w_gate, w_down, fwd_mask, bwd_mask, *,
                      act, block_c: int):
    """Reference VJP oracle for ``ops.gated_moe_ffn``: the dense per-expert
    gated-MLP einsum with the kernel's (expert, capacity-block) masks
    applied as a stop-gradient mix. xb: [E,C,D]; w_up/w_gate: [E,D,F];
    w_down: [E,F,D]; fwd_mask/bwd_mask: [E, n_cb] {0,1} over capacity
    blocks of ``block_c`` slots (bwd <= fwd). ``act`` is the callable."""
    E, C, D = xb.shape
    h = jnp.einsum("ecd,edf->ecf", xb, w_up)
    g = jnp.einsum("ecd,edf->ecf", xb, w_gate)
    y = jnp.einsum("ecf,efd->ecd", act(g) * h, w_down)
    mf = jnp.repeat(fwd_mask, block_c, axis=1)[:, :C, None].astype(y.dtype)
    mb = jnp.repeat(bwd_mask, block_c, axis=1)[:, :C, None].astype(y.dtype)
    return mf * (mb * y + (1.0 - mb) * jax.lax.stop_gradient(y))
