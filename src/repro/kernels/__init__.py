"""Pallas hot-path kernels for the compute the paper optimizes.

``d2ft_attention`` — D2FT-gated flash attention (gate-aware fused one-pass
backward, compaction dispatch); ``lora_matmul`` — fused LoRA matmul;
``ops`` — jit'd public wrappers with backend auto-detection (interpret
mode off-TPU); ``ref`` — pure-jnp oracles the tests compare against.
Design notes: docs/kernels.md.
"""
