"""Pallas TPU kernels: D2FT-gated flash attention, forward *and* backward.

The paper skips a subnet's work per micro-batch: p_s (shortcut) skips the
subnet entirely, p_o (forward-only) runs the forward but skips the backward.
On a GPU cluster the subnet's device simply idles; the TPU analogue is a
flash-attention kernel family with per-(sample, head) gate operands:

* forward kernel, gate ``g_f``: when ``g_f == 0`` the whole online-softmax
  KV loop for that (batch, head) slice is skipped with ``@pl.when`` and
  zeros are written once, so the MXU never sees the block (p_s).
* fused backward kernel, gate ``g_b``: when ``g_b == 0`` every backward
  matmul for the slice is skipped the same way and zero gradients are
  written once (p_o *and* p_s) — this is where the paper's headline ~40%
  training-compute saving lives, since the backward is ~60% of attention
  FLOPs.

Two dispatch-level optimisations make the *launched* work proportional to
the *live* work instead of merely skipping the MXU:

1. **Compaction dispatch** — the (B, H) axes are flattened into one slice
   axis and, when the caller supplies a static live-count upper bound
   (derived from the Schedule's p_f/p_o counts), the live slices are
   gathered front via a stable argsort permutation computed from the gates.
   The kernels then run on a grid whose leading dim is ``n_live`` instead of
   ``B*H`` and the results are scattered back with zeros elsewhere — so
   gated-off slices cost neither sequential grid steps nor HBM→VMEM DMA.
2. **Fused one-pass backward** — a single kernel computes ``s`` and ``dp``
   once per tile and emits dq, dk and dv together: 5 matmuls per live tile
   instead of the 7 the previous split dq / transposed-grid dkv pair paid,
   one launch instead of two, and one read of q/k/v/do/lse/delta instead of
   two. dq is accumulated across kv steps in a per-slice output block whose
   index map ignores the inner grid dims, so it stays resident in VMEM for
   the whole slice (no recomputation, no input/output aliasing — which the
   interpreter does not honour for read-back accumulation).

Supports causal and sliding-window masks (the assigned archs' local
-attention layers).

Tiling: q tiles [block_q, head_dim], kv tiles [block_k, head_dim] — both
MXU-aligned (multiples of 128 for fp32/bf16 lanes). Forward scratch: the
fp32 accumulator (block_q × head_dim) plus m/l online-softmax statistics in
VMEM; the KV axis is the innermost (sequential) grid dim so scratch carries
across kv steps. The forward additionally emits the logsumexp residual
[B, H, S] consumed by the backward kernel (the paper-standard o/lse-residual
flash backward — s and p are recomputed blockwise instead of materializing
[S, S]). Fully-masked causal/window blocks are skipped with ``@pl.when`` in
every kernel.

``gated_flash_attention`` is the differentiable custom-VJP entry point;
``d2ft_flash_attention`` remains the forward-only op. The jit'd public
wrapper with interpret auto-detection is ``repro.kernels.ops
.gated_attention``; the pure-jnp oracles live in ``repro.kernels.ref``.
"""
from __future__ import annotations

import functools
import math

import numpy as np

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import contract as _contract
from repro.kernels.compat import CompilerParams as _CompilerParams

NEG_INF = -2.0 ** 30
# logsumexp stored for rows that never saw a live key: large *positive* so
# exp(s - LSE_MASKED) underflows to exactly 0 in the backward for any score.
LSE_MASKED = 2.0 ** 30

# Test hook: when set to a callable, the backward kernel invokes it (via
# jax.debug.callback) once per *executed* compute block. Lets tests assert
# that g_b == 0 slices do no backward matmul work — static HLO FLOP counts
# cannot see the skip because interpret mode lowers the grid to a loop whose
# body XLA counts once regardless of trip count or taken branches. The hook
# is read at trace time: set it before the first trace of the function under
# test (avoid pre-cached jits).
on_backward_block = None

# Test hook: when set to a callable, every pallas_call built by _forward /
# _backward reports its dispatch as ``on_dispatch(kind, grid)`` with kind in
# {"fwd", "bwd"} at TRACE time. Lets tests assert the compacted grid's
# leading dim equals the live-slice bound instead of B*H. Same caveat as
# on_backward_block: set it before the first trace (jit caches skip tracing).
on_dispatch = None


def _maybe_count_block():
    if on_backward_block is not None:
        jax.debug.callback(on_backward_block)


def _report_dispatch(kind: str, grid):
    if on_dispatch is not None:
        on_dispatch(kind, tuple(grid))


def _block_live(qpos0, kpos0, block_q: int, block_k: int, causal: bool,
                window: int, seq_len: int):
    """Whether the (iq, ik) tile contains any unmasked in-bounds entry
    (tiles fully in the seq_len padding region are skipped too)."""
    live = jnp.logical_and(qpos0 < seq_len, kpos0 < seq_len)
    if causal:
        live &= kpos0 <= qpos0 + block_q - 1
    if window and window > 0:
        live &= kpos0 + block_k - 1 > qpos0 - window
    return live


def _tile_mask(qpos0, kpos0, block_q: int, block_k: int, seq_len: int,
               causal: bool, window: int):
    qpos = qpos0 + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
    kpos = kpos0 + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
    mask = kpos < seq_len
    if causal:
        mask &= kpos <= qpos
    if window and window > 0:
        mask &= kpos > qpos - window
    return mask


# ==================================================== compaction dispatch
# Shared across every gated kernel (ssd / rglru / moe speak the same
# contract); canonical definitions live in repro.kernels.contract.
_dispatch_count = _contract.dispatch_count
_live_permutation = _contract.live_permutation


# ================================================================== forward
def _fwd_kernel(gate_ref, q_ref, k_ref, v_ref, o_ref, lse_ref, acc_ref,
                m_ref, l_ref, *, scale: float, causal: bool, window: int,
                block_q: int, block_k: int, n_k: int, seq_len: int):
    iq = pl.program_id(1)
    ik = pl.program_id(2)
    gate = gate_ref[0, 0]

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # block-level skip: gate==0 (p_s subnet) or fully-masked block
    qpos0 = iq * block_q
    kpos0 = ik * block_k
    run = jnp.logical_and(
        gate != 0, _block_live(qpos0, kpos0, block_q, block_k, causal,
                               window, seq_len))

    @pl.when(run)
    def _compute():
        q = q_ref[0].astype(jnp.float32)               # [bq, hd]
        k = k_ref[0].astype(jnp.float32)               # [bk, hd]
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(q * scale, k,
                                (((1,), (1,)), ((), ())))   # [bq, bk]
        mask = _tile_mask(qpos0, kpos0, block_q, block_k, seq_len, causal,
                          window)
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1)
        acc_ref[...] = acc_ref[...] * corr[:, None] + \
            jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())))
        m_ref[...] = m_new

    @pl.when(ik == n_k - 1)
    def _finalize():
        l = l_ref[...]
        safe = jnp.where(l > 0, l, 1.0)
        out = acc_ref[...] / safe[:, None]
        out = jnp.where((l > 0)[:, None], out, 0.0)
        out = out * gate.astype(jnp.float32)
        o_ref[0] = out.astype(o_ref.dtype)
        lse_ref[0] = jnp.where(l > 0, m_ref[...] + jnp.log(safe),
                               LSE_MASKED)


def _forward(q, k, v, g_f, *, causal: bool, window: int, block_q: int,
             block_k: int, interpret: bool, seq_len: int = 0,
             live: int = None):
    """Returns (o [B,H,S,hd], lse [B,H,S] f32). seq_len is the true length
    when the arrays carry tile padding (0 means unpadded). ``live`` is the
    static live-slice upper bound enabling compaction dispatch."""
    B, H, S, hd = q.shape
    assert S % block_q == 0 and S % block_k == 0, (S, block_q, block_k)
    seq_len = seq_len or S
    n_q = S // block_q
    n_k = S // block_k
    scale = 1.0 / (hd ** 0.5)

    N = B * H
    q, k, v = (a.reshape(N, S, hd) for a in (q, k, v))
    g = g_f.reshape(N)
    n_disp = _dispatch_count(live, N)
    idx = None
    if n_disp < N:
        idx = _live_permutation(g, n_disp)
        q, k, v, g = (jnp.take(a, idx, axis=0) for a in (q, k, v, g))

    kernel = functools.partial(
        _fwd_kernel, scale=scale, causal=causal, window=window,
        block_q=block_q, block_k=block_k, n_k=n_k, seq_len=seq_len)

    grid = (n_disp, n_q, n_k)
    _report_dispatch("fwd", grid)
    o, lse = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda s, iq, ik: (s, 0)),            # g_f
            pl.BlockSpec((1, block_q, hd), lambda s, iq, ik: (s, iq, 0)),
            pl.BlockSpec((1, block_k, hd), lambda s, iq, ik: (s, ik, 0)),
            pl.BlockSpec((1, block_k, hd), lambda s, iq, ik: (s, ik, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_q, hd), lambda s, iq, ik: (s, iq, 0)),
            pl.BlockSpec((1, block_q), lambda s, iq, ik: (s, iq)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_disp, S, hd), q.dtype),
            jax.ShapeDtypeStruct((n_disp, S), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_q, hd), jnp.float32),   # acc
            pltpu.VMEM((block_q,), jnp.float32),      # m
            pltpu.VMEM((block_q,), jnp.float32),      # l
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(g.reshape(n_disp, 1), q, k, v)

    if idx is not None:
        # scatter live results back; dead (never-dispatched) slices are the
        # zero-fill, dispatched-but-gated-off padding slices wrote zeros /
        # LSE_MASKED themselves so the set() is a no-op value-wise.
        o = jnp.zeros((N, S, hd), o.dtype).at[idx].set(
            o, unique_indices=True)
        lse = jnp.full((N, S), LSE_MASKED, jnp.float32).at[idx].set(
            lse, unique_indices=True)
    return o.reshape(B, H, S, hd), lse.reshape(B, H, S)


def d2ft_flash_attention(q, k, v, gates, *, causal: bool = True,
                         window: int = 0, block_q: int = 128,
                         block_k: int = 128, interpret: bool = False,
                         live: int = None):
    """Forward-only gated flash attention (no VJP registered).

    q, k, v: [B, H, S, hd] (kv heads already expanded to H);
    gates: [B, H] float {0,1}. Returns [B, H, S, hd]. Sequence lengths that
    don't divide the tiles go through the same ``select_blocks`` shrink-or
    -pad wrapper as ``ops.gated_attention`` (padded rows are masked via the
    kernel's seq_len bound and sliced off). ``live`` optionally enables
    compaction dispatch with a static live-slice upper bound. For the
    differentiable path use ``gated_flash_attention`` / ``ops
    .gated_attention``.
    """
    q, k, v, bq, bk, S, Sp = pad_to_blocks(q, k, v, block_q, block_k)
    out = _forward(q, k, v, gates, causal=causal, window=window,
                   block_q=bq, block_k=bk, interpret=interpret,
                   seq_len=S, live=live)[0]
    return out[:, :, :S] if Sp != S else out


# ================================================================= backward
def _bwd_fused_kernel(gate_ref, q_ref, k_ref, v_ref, do_ref, lse_ref,
                      delta_ref, dq_ref, dk_ref, dv_ref, dk_acc, dv_acc, *,
                      scale: float, causal: bool, window: int, block_q: int,
                      block_k: int, n_q: int, seq_len: int):
    """Fused one-pass backward, grid (n_slices, n_k, n_q) — q innermost.

    Per live tile: 5 matmuls (``s``, ``p^T·do``, ``do·v^T``, ``ds·k``,
    ``ds^T·q``); ``s`` and ``dp`` are computed once and shared between the
    dq and dk paths (the split-kernel design recomputed them, 3 + 4 = 7).
    dk/dv accumulate in VMEM scratch while the kv tile stays resident and
    flush at the end of each q sweep. dq accumulates *in the output block
    itself*: its index map ignores (ik, iq), so the whole [S, hd] per-slice
    dq tile stays resident in VMEM across the slice's grid steps and is
    flushed to HBM exactly once — cross-step accumulation without
    recomputation or input/output aliasing. ``g_b == 0`` skips every matmul;
    zeros are written once per slice."""
    ik = pl.program_id(1)
    iq = pl.program_id(2)
    gate = gate_ref[0, 0]

    @pl.when(jnp.logical_and(ik == 0, iq == 0))
    def _init_dq():
        dq_ref[...] = jnp.zeros_like(dq_ref)

    @pl.when(iq == 0)
    def _init_kv():
        dk_acc[...] = jnp.zeros_like(dk_acc)
        dv_acc[...] = jnp.zeros_like(dv_acc)

    qpos0 = iq * block_q
    kpos0 = ik * block_k
    run = jnp.logical_and(
        gate != 0, _block_live(qpos0, kpos0, block_q, block_k, causal,
                               window, seq_len))

    @pl.when(run)
    def _compute():
        _maybe_count_block()
        q = q_ref[0].astype(jnp.float32)               # [bq, hd]
        k = k_ref[0].astype(jnp.float32)               # [bk, hd]
        v = v_ref[0].astype(jnp.float32)
        do = do_ref[0].astype(jnp.float32)             # [bq, hd]
        lse = lse_ref[0]                               # [bq]
        delta = delta_ref[0]                           # [bq]
        s = jax.lax.dot_general(q * scale, k,
                                (((1,), (1,)), ((), ())))   # [bq, bk]
        mask = _tile_mask(qpos0, kpos0, block_q, block_k, seq_len, causal,
                          window)
        s = jnp.where(mask, s, NEG_INF)
        p = jnp.exp(s - lse[:, None])                  # [bq, bk]
        dv_acc[...] += jax.lax.dot_general(p, do, (((0,), (0,)), ((), ())))
        dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())))
        ds = p * (dp - delta[:, None])                 # [bq, bk]
        dq_ref[0, pl.dslice(qpos0, block_q), :] += jax.lax.dot_general(
            ds, k, (((1,), (0,)), ((), ()))) * scale
        dk_acc[...] += jax.lax.dot_general(
            ds, q, (((0,), (0,)), ((), ()))) * scale

    @pl.when(iq == n_q - 1)
    def _flush():
        dk_ref[0] = dk_acc[...].astype(dk_ref.dtype)
        dv_ref[0] = dv_acc[...].astype(dv_ref.dtype)


def _backward(q, k, v, g_b, o, lse, do, *, causal: bool, window: int,
              block_q: int, block_k: int, interpret: bool, seq_len: int = 0,
              live: int = None):
    B, H, S, hd = q.shape
    seq_len = seq_len or S
    n_q = S // block_q
    n_k = S // block_k
    scale = 1.0 / (hd ** 0.5)

    N = B * H
    q, k, v, o, do = (a.reshape(N, S, hd) for a in (q, k, v, o, do))
    lse = lse.reshape(N, S)
    g = g_b.reshape(N)
    n_disp = _dispatch_count(live, N)
    idx = None
    if n_disp < N:
        idx = _live_permutation(g, n_disp)
        q, k, v, o, do = (jnp.take(a, idx, axis=0)
                          for a in (q, k, v, o, do))
        lse, g = jnp.take(lse, idx, axis=0), jnp.take(g, idx, axis=0)
    # delta_i = sum_d dO_id * O_id — cheap elementwise reduce, done outside
    # the kernel (standard flash-bwd preprocessing) on the *compacted*
    # operands so gated-off slices don't pay it either.
    delta = jnp.sum(do.astype(jnp.float32) * o.astype(jnp.float32), axis=-1)

    grid = (n_disp, n_k, n_q)
    _report_dispatch("bwd", grid)
    dq, dk, dv = pl.pallas_call(
        functools.partial(_bwd_fused_kernel, scale=scale, causal=causal,
                          window=window, block_q=block_q, block_k=block_k,
                          n_q=n_q, seq_len=seq_len),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda s, ik, iq: (s, 0)),            # g_b
            pl.BlockSpec((1, block_q, hd), lambda s, ik, iq: (s, iq, 0)),
            pl.BlockSpec((1, block_k, hd), lambda s, ik, iq: (s, ik, 0)),
            pl.BlockSpec((1, block_k, hd), lambda s, ik, iq: (s, ik, 0)),
            pl.BlockSpec((1, block_q, hd), lambda s, ik, iq: (s, iq, 0)),
            pl.BlockSpec((1, block_q), lambda s, ik, iq: (s, iq)),
            pl.BlockSpec((1, block_q), lambda s, ik, iq: (s, iq)),
        ],
        out_specs=[
            # dq: per-slice block, VMEM-resident across the whole slice —
            # S*hd*4 bytes of VMEM on real TPU (interpret mode is unbounded;
            # ~16MB/core caps S around 16-32k at hd=128: docs/kernels.md)
            pl.BlockSpec((1, S, hd), lambda s, ik, iq: (s, 0, 0)),
            pl.BlockSpec((1, block_k, hd), lambda s, ik, iq: (s, ik, 0)),
            pl.BlockSpec((1, block_k, hd), lambda s, ik, iq: (s, ik, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_disp, S, hd), jnp.float32),
            jax.ShapeDtypeStruct((n_disp, S, hd), k.dtype),
            jax.ShapeDtypeStruct((n_disp, S, hd), v.dtype),
        ],
        scratch_shapes=[pltpu.VMEM((block_k, hd), jnp.float32),
                        pltpu.VMEM((block_k, hd), jnp.float32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary", "arbitrary")),
        interpret=interpret,
    )(g.reshape(n_disp, 1), q, k, v, do, lse, delta)

    dq = dq.astype(q.dtype)
    if idx is not None:
        dq, dk, dv = (jnp.zeros((N, S, hd), a.dtype).at[idx].set(
            a, unique_indices=True) for a in (dq, dk, dv))
    return (dq.reshape(B, H, S, hd), dk.reshape(B, H, S, hd),
            dv.reshape(B, H, S, hd))


# =============================================================== custom VJP
@functools.partial(jax.custom_vjp, nondiff_argnums=(5, 6, 7, 8, 9, 10, 11,
                                                    12))
def gated_flash_attention(q, k, v, g_f, g_b, causal, window, block_q,
                          block_k, interpret, seq_len=0, live_fwd=None,
                          live_bwd=None):
    """Differentiable gated flash attention core.

    Forward output is ``g_f``-gated (p_s heads produce zeros, MXU skipped);
    the registered backward returns dq/dk/dv that are *computed* only where
    ``g_b != 0`` — p_o / p_s slices skip every backward matmul via
    ``@pl.when`` and write zeros once. Gates receive zero cotangents (they
    are schedule constants). seq_len is the true length when the operands
    carry tile padding (0 = unpadded). ``live_fwd`` / ``live_bwd`` are
    static upper bounds on the number of g_f != 0 / g_b != 0 slices: when
    given, the kernels dispatch a compacted grid of that many slices instead
    of B*H (gather live front / scatter back — see the module docstring);
    None dispatches everything. Prefer the jit'd ``ops.gated_attention``,
    which also picks tile sizes and padding.
    """
    o, _ = _forward(q, k, v, g_f, causal=causal, window=window,
                    block_q=block_q, block_k=block_k, interpret=interpret,
                    seq_len=seq_len, live=live_fwd)
    return o


def _vjp_fwd(q, k, v, g_f, g_b, causal, window, block_q, block_k, interpret,
             seq_len=0, live_fwd=None, live_bwd=None):
    o, lse = _forward(q, k, v, g_f, causal=causal, window=window,
                      block_q=block_q, block_k=block_k, interpret=interpret,
                      seq_len=seq_len, live=live_fwd)
    return o, (q, k, v, g_f, g_b, o, lse)


def _vjp_bwd(causal, window, block_q, block_k, interpret, seq_len, live_fwd,
             live_bwd, res, do):
    q, k, v, g_f, g_b, o, lse = res
    dq, dk, dv = _backward(q, k, v, g_b, o, lse, do, causal=causal,
                           window=window, block_q=block_q, block_k=block_k,
                           interpret=interpret, seq_len=seq_len,
                           live=live_bwd)
    return dq, dk, dv, jnp.zeros_like(g_f), jnp.zeros_like(g_b)


gated_flash_attention.defvjp(_vjp_fwd, _vjp_bwd)


# ======================================================= tile selection
def _largest_divisor(S: int, block: int) -> int:
    b = min(block, S)
    while S % b:
        b -= 1
    return b


def select_blocks(S: int, block_q: int, block_k: int):
    """(block_q, block_k, padded_S) used by ``ops.gated_attention``,
    ``d2ft_flash_attention`` AND the FLOP/DMA accounting below — one source
    of truth for tile geometry.

    Exact fit when S divides the requested tiles; otherwise shrink to a
    divisor if one exists within 2x of the request (stays near MXU width);
    otherwise keep the requested tiles and pad S up to a common multiple —
    never degenerate slivers (e.g. S=257 pads to 384 with 128-tiles instead
    of running 1-wide tiles the TPU lowering would reject)."""
    bq = min(block_q, S)
    bk = min(block_k, S)
    if S % bq == 0 and S % bk == 0:
        return bq, bk, S
    dq_ = _largest_divisor(S, bq)
    dk_ = _largest_divisor(S, bk)
    if dq_ >= bq // 2 and dk_ >= bk // 2:
        return dq_, dk_, S
    m = math.lcm(bq, bk)
    return bq, bk, -(-S // m) * m


def pad_to_blocks(q, k, v, block_q: int, block_k: int):
    """Shared select_blocks + zero-pad step for every kernel entry point
    (``ops.gated_attention`` and the forward-only ``d2ft_flash_attention``).

    Returns (q, k, v, bq, bk, S, Sp): operands padded along the sequence
    axis to Sp when S doesn't divide the chosen tiles (padded rows are
    masked inside the kernels via their seq_len bound; callers slice
    outputs back to S, and jnp.pad's VJP keeps the padding out of the
    gradients)."""
    S = q.shape[2]
    bq, bk, Sp = select_blocks(S, block_q, block_k)
    if Sp != S:
        pad = ((0, 0), (0, 0), (0, Sp - S), (0, 0))
        q, k, v = jnp.pad(q, pad), jnp.pad(k, pad), jnp.pad(v, pad)
    return q, k, v, bq, bk, S, Sp


# ======================================================== analytic accounting
def live_block_count(S: int, block_q: int, block_k: int, causal: bool,
                     window: int, seq_len: int = 0) -> int:
    """Number of (iq, ik) tiles the kernels execute per live (batch, head)
    slice — the same block-granular predicate as the ``@pl.when`` skip.
    S is the (possibly padded) grid extent; seq_len the true length."""
    seq_len = seq_len or S
    n_q, n_k = S // block_q, S // block_k
    return sum(
        bool(_block_live(iq * block_q, ik * block_k, block_q, block_k,
                         causal, window, seq_len))
        for iq in range(n_q) for ik in range(n_k))


FWD_MATMULS_PER_TILE = 2   # qk^T, pv
BWD_MATMULS_PER_TILE = 5   # s, p^T·do, do·v^T, ds·k, ds^T·q (fused one-pass)


def gated_attention_flops(g_f, g_b, S: int, hd: int, *, causal: bool = True,
                          window: int = 0, block_q: int = 128,
                          block_k: int = 128):
    """Executed MXU FLOPs (fwd, bwd) of the kernel path under concrete gates.

    Uses the same tile geometry as ``ops.gated_attention`` (select_blocks,
    including padding) and the same block-granular skip predicate: 2 matmuls
    per live tile forward (qk^T, pv); 5 backward — the fused one-pass kernel
    computes ``s`` and ``dp`` once per tile and emits dq/dk/dv together
    (the former split dq / dkv kernels paid 3 + 4 = 7, recomputing both).
    Each matmul is 2·bq·bk·hd FLOPs. Static HLO FLOP counts can't report
    this (interpret mode lowers the grid to a loop whose body XLA counts
    once), hence this mirror of the kernel's own skip logic.
    """
    bq, bk, Sp = select_blocks(S, block_q, block_k)
    tiles = live_block_count(Sp, bq, bk, causal, window, seq_len=S)
    per_matmul = 2 * bq * bk * hd
    fwd = float(np.sum(np.asarray(g_f) != 0)) \
        * tiles * FWD_MATMULS_PER_TILE * per_matmul
    bwd = float(np.sum(np.asarray(g_b) != 0)) \
        * tiles * BWD_MATMULS_PER_TILE * per_matmul
    return fwd, bwd


def gated_attention_dispatched_bytes(g_f, g_b, S: int, hd: int, *,
                                     causal: bool = True, window: int = 0,
                                     block_q: int = 128, block_k: int = 128,
                                     live_fwd: int = None,
                                     live_bwd: int = None,
                                     itemsize: int = 4):
    """(fwd_bytes, bwd_bytes) the BlockSpec pipelines stream HBM<->VMEM for
    one fwd / one bwd ``pallas_call`` under the given dispatch.

    Mirrors the kernels' grids and index maps: a block is (re)fetched only
    when its index-map output changes between consecutive grid steps, so per
    dispatched slice the forward streams q once per q-tile, k/v once per
    (iq, ik) step and writes o/lse once; the fused backward keeps k/v
    resident per kv sweep, streams q/do/lse/delta once per (ik, iq) step,
    writes dk/dv once per kv tile and the VMEM-resident dq block exactly
    once. The ``@pl.when`` gate/mask skip does NOT skip this traffic — only
    compaction dispatch does: without ``live_fwd``/``live_bwd`` every one of
    the B*H slices is streamed; with bounds, only the compacted grid's
    slices are. Gate scalars and the jnp-level gather/scatter/pad copies are
    not modelled (they are O(live) and fuse outside the kernels).
    """
    bq, bk, Sp = select_blocks(S, block_q, block_k)
    n_q, n_k = Sp // bq, Sp // bk
    N = int(np.asarray(g_f).size)
    assert int(np.asarray(g_b).size) == N
    disp_f = _dispatch_count(live_fwd, N)
    disp_b = _dispatch_count(live_bwd, N)
    fwd_slice = (n_q * bq * hd                 # q: fetched once per q tile
                 + 2 * n_q * n_k * bk * hd    # k, v: refetched per (iq, ik)
                 + n_q * bq * hd              # o written once per q tile
                 + n_q * bq)                  # lse
    bwd_slice = (2 * n_k * bk * hd            # k, v: resident per kv sweep
                 + 2 * n_k * n_q * bq * hd    # q, do: refetched per (ik, iq)
                 + 2 * n_k * n_q * bq         # lse, delta
                 + Sp * hd                    # dq block: written once/slice
                 + 2 * n_k * bk * hd)         # dk, dv: written once per tile
    return disp_f * fwd_slice * itemsize, disp_b * bwd_slice * itemsize
