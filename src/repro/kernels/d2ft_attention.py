"""Pallas TPU kernel: D2FT-gated flash attention.

The paper skips a subnet's forward entirely for shortcut (p_s) micro-batches
— on a GPU cluster the subnet's device simply idles. The TPU analogue is a
flash-attention kernel with a per-(sample, head) gate operand: when
``gate == 0`` the whole online-softmax KV loop for that (batch, head) grid
slice is skipped with ``@pl.when`` and zeros are written once, so the MXU
never sees the block. Supports causal and sliding-window masks (the
assigned archs' local-attention layers).

Tiling: q tiles [block_q, head_dim], kv tiles [block_k, head_dim] — both
MXU-aligned (multiples of 128 for fp32/bf16 lanes); the fp32 accumulator
(block_q × head_dim) plus m/l statistics live in VMEM scratch. The KV axis
is the innermost (sequential) grid dim, so the scratch carries across kv
steps; fully-masked causal blocks are skipped with @pl.when as well.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -2.0 ** 30


def _kernel(gate_ref, q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
            scale: float, causal: bool, window: int, block_q: int,
            block_k: int, n_k: int, seq_len: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)
    gate = gate_ref[0, 0]

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # block-level skip: gate==0 (p_s subnet) or fully-masked causal block
    qpos0 = iq * block_q
    kpos0 = ik * block_k
    block_live = jnp.bool_(True)
    if causal:
        block_live &= kpos0 <= qpos0 + block_q - 1
    if window and window > 0:
        block_live &= kpos0 + block_k - 1 > qpos0 - window
    run = jnp.logical_and(gate != 0, block_live)

    @pl.when(run)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)            # [bq, hd]
        k = k_ref[0, 0].astype(jnp.float32)            # [bk, hd]
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q * scale, k,
                                (((1,), (1,)), ((), ())))   # [bq, bk]
        qpos = qpos0 + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        kpos = kpos0 + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        mask = kpos < seq_len
        if causal:
            mask &= kpos <= qpos
        if window and window > 0:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1)
        acc_ref[...] = acc_ref[...] * corr[:, None] + \
            jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())))
        m_ref[...] = m_new

    @pl.when(ik == n_k - 1)
    def _finalize():
        l = l_ref[...]
        safe = jnp.where(l > 0, l, 1.0)
        out = acc_ref[...] / safe[:, None]
        out = jnp.where((l > 0)[:, None], out, 0.0)
        out = out * gate.astype(jnp.float32)
        o_ref[0, 0] = out.astype(o_ref.dtype)


def d2ft_flash_attention(q, k, v, gates, *, causal: bool = True,
                         window: int = 0, block_q: int = 128,
                         block_k: int = 128, interpret: bool = False):
    """q, k, v: [B, H, S, hd] (kv heads already expanded to H);
    gates: [B, H] float {0,1}. Returns [B, H, S, hd]."""
    B, H, S, hd = q.shape
    block_q = min(block_q, S)
    block_k = min(block_k, S)
    assert S % block_q == 0 and S % block_k == 0, (S, block_q, block_k)
    n_q = S // block_q
    n_k = S // block_k
    scale = 1.0 / (hd ** 0.5)

    kernel = functools.partial(
        _kernel, scale=scale, causal=causal, window=window, block_q=block_q,
        block_k=block_k, n_k=n_k, seq_len=S)

    return pl.pallas_call(
        kernel,
        grid=(B, H, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, 1), lambda b, h, iq, ik: (b, h)),          # gates
            pl.BlockSpec((1, 1, block_q, hd), lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, block_k, hd), lambda b, h, iq, ik: (b, h, ik, 0)),
            pl.BlockSpec((1, 1, block_k, hd), lambda b, h, iq, ik: (b, h, ik, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, hd),
                               lambda b, h, iq, ik: (b, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, S, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, hd), jnp.float32),   # acc
            pltpu.VMEM((block_q,), jnp.float32),      # m
            pltpu.VMEM((block_q,), jnp.float32),      # l
        ],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(gates, q, k, v)
