"""Pallas TPU kernels: D2FT-gated flash attention, forward *and* backward.

The paper skips a subnet's work per micro-batch: p_s (shortcut) skips the
subnet entirely, p_o (forward-only) runs the forward but skips the backward.
On a GPU cluster the subnet's device simply idles; the TPU analogue is a
flash-attention kernel family with per-(sample, head) gate operands:

* forward kernel, gate ``g_f``: when ``g_f == 0`` the whole online-softmax
  KV loop for that (batch, head) grid slice is skipped with ``@pl.when`` and
  zeros are written once, so the MXU never sees the block (p_s).
* backward kernels (dq; dk/dv on the transposed grid), gate ``g_b``: when
  ``g_b == 0`` every backward matmul for the slice is skipped the same way
  and zero gradients are written once (p_o *and* p_s) — this is where the
  paper's headline ~40% training-compute saving lives, since the backward
  is ~60% of attention FLOPs.

Supports causal and sliding-window masks (the assigned archs' local
-attention layers).

Tiling: q tiles [block_q, head_dim], kv tiles [block_k, head_dim] — both
MXU-aligned (multiples of 128 for fp32/bf16 lanes). Forward scratch: the
fp32 accumulator (block_q × head_dim) plus m/l online-softmax statistics in
VMEM; the KV axis is the innermost (sequential) grid dim so scratch carries
across kv steps. The forward additionally emits the logsumexp residual
[B, H, S] consumed by the backward kernels (the paper-standard
o/lse-residual flash backward — s and p are recomputed blockwise instead of
materializing [S, S]). Fully-masked causal/window blocks are skipped with
``@pl.when`` in every kernel.

``gated_flash_attention`` is the differentiable custom-VJP entry point;
``d2ft_flash_attention`` remains the forward-only op. The jit'd public
wrapper with interpret auto-detection is ``repro.kernels.ops
.gated_attention``; the pure-jnp oracles live in ``repro.kernels.ref``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams as _CompilerParams

NEG_INF = -2.0 ** 30
# logsumexp stored for rows that never saw a live key: large *positive* so
# exp(s - LSE_MASKED) underflows to exactly 0 in the backward for any score.
LSE_MASKED = 2.0 ** 30

# Test hook: when set to a callable, the backward kernels invoke it (via
# jax.debug.callback) once per *executed* compute block. Lets tests assert
# that g_b == 0 slices do no backward matmul work — static HLO FLOP counts
# cannot see the skip because interpret mode lowers the grid to a loop whose
# body XLA counts once regardless of trip count or taken branches. The hook
# is read at trace time: set it before the first trace of the function under
# test (avoid pre-cached jits).
on_backward_block = None


def _maybe_count_block():
    if on_backward_block is not None:
        jax.debug.callback(on_backward_block)


def _block_live(qpos0, kpos0, block_q: int, block_k: int, causal: bool,
                window: int, seq_len: int):
    """Whether the (iq, ik) tile contains any unmasked in-bounds entry
    (tiles fully in the seq_len padding region are skipped too)."""
    live = jnp.logical_and(qpos0 < seq_len, kpos0 < seq_len)
    if causal:
        live &= kpos0 <= qpos0 + block_q - 1
    if window and window > 0:
        live &= kpos0 + block_k - 1 > qpos0 - window
    return live


def _tile_mask(qpos0, kpos0, block_q: int, block_k: int, seq_len: int,
               causal: bool, window: int):
    qpos = qpos0 + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
    kpos = kpos0 + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
    mask = kpos < seq_len
    if causal:
        mask &= kpos <= qpos
    if window and window > 0:
        mask &= kpos > qpos - window
    return mask


# ================================================================== forward
def _fwd_kernel(gate_ref, q_ref, k_ref, v_ref, o_ref, lse_ref, acc_ref,
                m_ref, l_ref, *, scale: float, causal: bool, window: int,
                block_q: int, block_k: int, n_k: int, seq_len: int):
    iq = pl.program_id(2)
    ik = pl.program_id(3)
    gate = gate_ref[0, 0]

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # block-level skip: gate==0 (p_s subnet) or fully-masked block
    qpos0 = iq * block_q
    kpos0 = ik * block_k
    run = jnp.logical_and(
        gate != 0, _block_live(qpos0, kpos0, block_q, block_k, causal,
                               window, seq_len))

    @pl.when(run)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)            # [bq, hd]
        k = k_ref[0, 0].astype(jnp.float32)            # [bk, hd]
        v = v_ref[0, 0].astype(jnp.float32)
        s = jax.lax.dot_general(q * scale, k,
                                (((1,), (1,)), ((), ())))   # [bq, bk]
        mask = _tile_mask(qpos0, kpos0, block_q, block_k, seq_len, causal,
                          window)
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(p, axis=1)
        acc_ref[...] = acc_ref[...] * corr[:, None] + \
            jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())))
        m_ref[...] = m_new

    @pl.when(ik == n_k - 1)
    def _finalize():
        l = l_ref[...]
        safe = jnp.where(l > 0, l, 1.0)
        out = acc_ref[...] / safe[:, None]
        out = jnp.where((l > 0)[:, None], out, 0.0)
        out = out * gate.astype(jnp.float32)
        o_ref[0, 0] = out.astype(o_ref.dtype)
        lse_ref[0, 0] = jnp.where(l > 0, m_ref[...] + jnp.log(safe),
                                  LSE_MASKED)


def _forward(q, k, v, g_f, *, causal: bool, window: int, block_q: int,
             block_k: int, interpret: bool, seq_len: int = 0):
    """Returns (o [B,H,S,hd], lse [B,H,S] f32). seq_len is the true length
    when the arrays carry tile padding (0 means unpadded)."""
    B, H, S, hd = q.shape
    assert S % block_q == 0 and S % block_k == 0, (S, block_q, block_k)
    seq_len = seq_len or S
    n_q = S // block_q
    n_k = S // block_k
    scale = 1.0 / (hd ** 0.5)

    kernel = functools.partial(
        _fwd_kernel, scale=scale, causal=causal, window=window,
        block_q=block_q, block_k=block_k, n_k=n_k, seq_len=seq_len)

    return pl.pallas_call(
        kernel,
        grid=(B, H, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, 1), lambda b, h, iq, ik: (b, h)),          # g_f
            pl.BlockSpec((1, 1, block_q, hd), lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, block_k, hd), lambda b, h, iq, ik: (b, h, ik, 0)),
            pl.BlockSpec((1, 1, block_k, hd), lambda b, h, iq, ik: (b, h, ik, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, block_q, hd),
                         lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, block_q), lambda b, h, iq, ik: (b, h, iq)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, S, hd), q.dtype),
            jax.ShapeDtypeStruct((B, H, S), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((block_q, hd), jnp.float32),   # acc
            pltpu.VMEM((block_q,), jnp.float32),      # m
            pltpu.VMEM((block_q,), jnp.float32),      # l
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel",
                                 "arbitrary")),
        interpret=interpret,
    )(g_f, q, k, v)


def d2ft_flash_attention(q, k, v, gates, *, causal: bool = True,
                         window: int = 0, block_q: int = 128,
                         block_k: int = 128, interpret: bool = False):
    """Forward-only gated flash attention (no VJP registered).

    q, k, v: [B, H, S, hd] (kv heads already expanded to H);
    gates: [B, H] float {0,1}. Returns [B, H, S, hd]. For the
    differentiable path use ``gated_flash_attention`` / ``ops.gated_attention``.
    """
    B, H, S, hd = q.shape
    block_q = min(block_q, S)
    block_k = min(block_k, S)
    return _forward(q, k, v, gates, causal=causal, window=window,
                    block_q=block_q, block_k=block_k, interpret=interpret)[0]


# ================================================================= backward
def _bwd_dq_kernel(gate_ref, q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                   dq_ref, acc_ref, *, scale: float, causal: bool,
                   window: int, block_q: int, block_k: int, n_k: int,
                   seq_len: int):
    """dq, grid (B, H, n_q, n_k) — kv innermost so the dq tile accumulates
    in VMEM scratch. ``g_b == 0`` skips every matmul; zeros written once."""
    iq = pl.program_id(2)
    ik = pl.program_id(3)
    gate = gate_ref[0, 0]

    @pl.when(ik == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    qpos0 = iq * block_q
    kpos0 = ik * block_k
    run = jnp.logical_and(
        gate != 0, _block_live(qpos0, kpos0, block_q, block_k, causal,
                               window, seq_len))

    @pl.when(run)
    def _compute():
        _maybe_count_block()
        q = q_ref[0, 0].astype(jnp.float32)            # [bq, hd]
        k = k_ref[0, 0].astype(jnp.float32)            # [bk, hd]
        v = v_ref[0, 0].astype(jnp.float32)
        do = do_ref[0, 0].astype(jnp.float32)          # [bq, hd]
        lse = lse_ref[0, 0]                            # [bq]
        delta = delta_ref[0, 0]                        # [bq]
        s = jax.lax.dot_general(q * scale, k,
                                (((1,), (1,)), ((), ())))   # [bq, bk]
        mask = _tile_mask(qpos0, kpos0, block_q, block_k, seq_len, causal,
                          window)
        s = jnp.where(mask, s, NEG_INF)
        p = jnp.exp(s - lse[:, None])                  # [bq, bk]
        dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())))
        ds = p * (dp - delta[:, None])
        acc_ref[...] += jax.lax.dot_general(
            ds, k, (((1,), (0,)), ((), ()))) * scale

    @pl.when(ik == n_k - 1)
    def _finalize():
        dq_ref[0, 0] = acc_ref[...].astype(dq_ref.dtype)


def _bwd_dkv_kernel(gate_ref, q_ref, k_ref, v_ref, do_ref, lse_ref,
                    delta_ref, dk_ref, dv_ref, dk_acc, dv_acc, *,
                    scale: float, causal: bool, window: int, block_q: int,
                    block_k: int, n_q: int, seq_len: int):
    """dk/dv, transposed grid (B, H, n_k, n_q) — q innermost so the dk/dv
    tiles accumulate in VMEM scratch while the kv tile stays resident."""
    ik = pl.program_id(2)
    iq = pl.program_id(3)
    gate = gate_ref[0, 0]

    @pl.when(iq == 0)
    def _init():
        dk_acc[...] = jnp.zeros_like(dk_acc)
        dv_acc[...] = jnp.zeros_like(dv_acc)

    qpos0 = iq * block_q
    kpos0 = ik * block_k
    run = jnp.logical_and(
        gate != 0, _block_live(qpos0, kpos0, block_q, block_k, causal,
                               window, seq_len))

    @pl.when(run)
    def _compute():
        _maybe_count_block()
        q = q_ref[0, 0].astype(jnp.float32)            # [bq, hd]
        k = k_ref[0, 0].astype(jnp.float32)            # [bk, hd]
        v = v_ref[0, 0].astype(jnp.float32)
        do = do_ref[0, 0].astype(jnp.float32)          # [bq, hd]
        lse = lse_ref[0, 0]
        delta = delta_ref[0, 0]
        s = jax.lax.dot_general(q * scale, k,
                                (((1,), (1,)), ((), ())))   # [bq, bk]
        mask = _tile_mask(qpos0, kpos0, block_q, block_k, seq_len, causal,
                          window)
        s = jnp.where(mask, s, NEG_INF)
        p = jnp.exp(s - lse[:, None])                  # [bq, bk]
        dv_acc[...] += jax.lax.dot_general(p, do, (((0,), (0,)), ((), ())))
        dp = jax.lax.dot_general(do, v, (((1,), (1,)), ((), ())))
        ds = p * (dp - delta[:, None])                 # [bq, bk]
        dk_acc[...] += jax.lax.dot_general(
            ds, q, (((0,), (0,)), ((), ()))) * scale

    @pl.when(iq == n_q - 1)
    def _finalize():
        dk_ref[0, 0] = dk_acc[...].astype(dk_ref.dtype)
        dv_ref[0, 0] = dv_acc[...].astype(dv_ref.dtype)


def _backward(q, k, v, g_b, o, lse, do, *, causal: bool, window: int,
              block_q: int, block_k: int, interpret: bool, seq_len: int = 0):
    B, H, S, hd = q.shape
    seq_len = seq_len or S
    n_q = S // block_q
    n_k = S // block_k
    scale = 1.0 / (hd ** 0.5)
    # delta_i = sum_d dO_id * O_id — cheap elementwise reduce, done outside
    # the kernels (standard flash-bwd preprocessing).
    delta = jnp.sum(do.astype(jnp.float32) * o.astype(jnp.float32), axis=-1)

    gate_spec = pl.BlockSpec((1, 1), lambda b, h, i, j: (b, h))
    params = _CompilerParams(
        dimension_semantics=("parallel", "parallel", "parallel", "arbitrary"))

    dq = pl.pallas_call(
        functools.partial(_bwd_dq_kernel, scale=scale, causal=causal,
                          window=window, block_q=block_q, block_k=block_k,
                          n_k=n_k, seq_len=seq_len),
        grid=(B, H, n_q, n_k),
        in_specs=[
            gate_spec,                                                  # g_b
            pl.BlockSpec((1, 1, block_q, hd), lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, block_k, hd), lambda b, h, iq, ik: (b, h, ik, 0)),
            pl.BlockSpec((1, 1, block_k, hd), lambda b, h, iq, ik: (b, h, ik, 0)),
            pl.BlockSpec((1, 1, block_q, hd), lambda b, h, iq, ik: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, block_q), lambda b, h, iq, ik: (b, h, iq)),
            pl.BlockSpec((1, 1, block_q), lambda b, h, iq, ik: (b, h, iq)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, hd),
                               lambda b, h, iq, ik: (b, h, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, S, hd), q.dtype),
        scratch_shapes=[pltpu.VMEM((block_q, hd), jnp.float32)],
        compiler_params=params,
        interpret=interpret,
    )(g_b, q, k, v, do, lse, delta)

    dk, dv = pl.pallas_call(
        functools.partial(_bwd_dkv_kernel, scale=scale, causal=causal,
                          window=window, block_q=block_q, block_k=block_k,
                          n_q=n_q, seq_len=seq_len),
        grid=(B, H, n_k, n_q),
        in_specs=[
            gate_spec,                                                  # g_b
            pl.BlockSpec((1, 1, block_q, hd), lambda b, h, ik, iq: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, block_k, hd), lambda b, h, ik, iq: (b, h, ik, 0)),
            pl.BlockSpec((1, 1, block_k, hd), lambda b, h, ik, iq: (b, h, ik, 0)),
            pl.BlockSpec((1, 1, block_q, hd), lambda b, h, ik, iq: (b, h, iq, 0)),
            pl.BlockSpec((1, 1, block_q), lambda b, h, ik, iq: (b, h, iq)),
            pl.BlockSpec((1, 1, block_q), lambda b, h, ik, iq: (b, h, iq)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, block_k, hd),
                         lambda b, h, ik, iq: (b, h, ik, 0)),
            pl.BlockSpec((1, 1, block_k, hd),
                         lambda b, h, ik, iq: (b, h, ik, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, S, hd), k.dtype),
            jax.ShapeDtypeStruct((B, H, S, hd), v.dtype),
        ],
        scratch_shapes=[pltpu.VMEM((block_k, hd), jnp.float32),
                        pltpu.VMEM((block_k, hd), jnp.float32)],
        compiler_params=params,
        interpret=interpret,
    )(g_b, q, k, v, do, lse, delta)
    return dq, dk, dv


# =============================================================== custom VJP
@functools.partial(jax.custom_vjp, nondiff_argnums=(5, 6, 7, 8, 9, 10))
def gated_flash_attention(q, k, v, g_f, g_b, causal, window, block_q,
                          block_k, interpret, seq_len=0):
    """Differentiable gated flash attention core.

    Forward output is ``g_f``-gated (p_s heads produce zeros, MXU skipped);
    the registered backward returns dq/dk/dv that are *computed* only where
    ``g_b != 0`` — p_o / p_s slices skip every backward matmul via
    ``@pl.when`` and write zeros once. Gates receive zero cotangents (they
    are schedule constants). seq_len is the true length when the operands
    carry tile padding (0 = unpadded). Prefer the jit'd
    ``ops.gated_attention``, which also picks tile sizes and padding.
    """
    o, _ = _forward(q, k, v, g_f, causal=causal, window=window,
                    block_q=block_q, block_k=block_k, interpret=interpret,
                    seq_len=seq_len)
    return o


def _vjp_fwd(q, k, v, g_f, g_b, causal, window, block_q, block_k, interpret,
             seq_len=0):
    o, lse = _forward(q, k, v, g_f, causal=causal, window=window,
                      block_q=block_q, block_k=block_k, interpret=interpret,
                      seq_len=seq_len)
    return o, (q, k, v, g_f, g_b, o, lse)


def _vjp_bwd(causal, window, block_q, block_k, interpret, seq_len, res, do):
    q, k, v, g_f, g_b, o, lse = res
    dq, dk, dv = _backward(q, k, v, g_b, o, lse, do, causal=causal,
                           window=window, block_q=block_q, block_k=block_k,
                           interpret=interpret, seq_len=seq_len)
    return dq, dk, dv, jnp.zeros_like(g_f), jnp.zeros_like(g_b)


gated_flash_attention.defvjp(_vjp_fwd, _vjp_bwd)


# ======================================================= tile selection
def _largest_divisor(S: int, block: int) -> int:
    b = min(block, S)
    while S % b:
        b -= 1
    return b


def select_blocks(S: int, block_q: int, block_k: int):
    """(block_q, block_k, padded_S) used by ``ops.gated_attention`` AND the
    FLOP accounting below — one source of truth for tile geometry.

    Exact fit when S divides the requested tiles; otherwise shrink to a
    divisor if one exists within 2x of the request (stays near MXU width);
    otherwise keep the requested tiles and pad S up to a common multiple —
    never degenerate slivers (e.g. S=257 pads to 384 with 128-tiles instead
    of running 1-wide tiles the TPU lowering would reject)."""
    import math
    bq = min(block_q, S)
    bk = min(block_k, S)
    if S % bq == 0 and S % bk == 0:
        return bq, bk, S
    dq_ = _largest_divisor(S, bq)
    dk_ = _largest_divisor(S, bk)
    if dq_ >= bq // 2 and dk_ >= bk // 2:
        return dq_, dk_, S
    m = math.lcm(bq, bk)
    return bq, bk, -(-S // m) * m


# ======================================================== analytic accounting
def live_block_count(S: int, block_q: int, block_k: int, causal: bool,
                     window: int, seq_len: int = 0) -> int:
    """Number of (iq, ik) tiles the kernels execute per live (batch, head)
    slice — the same block-granular predicate as the ``@pl.when`` skip.
    S is the (possibly padded) grid extent; seq_len the true length."""
    seq_len = seq_len or S
    n_q, n_k = S // block_q, S // block_k
    return sum(
        bool(_block_live(iq * block_q, ik * block_k, block_q, block_k,
                         causal, window, seq_len))
        for iq in range(n_q) for ik in range(n_k))


def gated_attention_flops(g_f, g_b, S: int, hd: int, *, causal: bool = True,
                          window: int = 0, block_q: int = 128,
                          block_k: int = 128):
    """Executed MXU FLOPs (fwd, bwd) of the kernel path under concrete gates.

    Uses the same tile geometry as ``ops.gated_attention`` (select_blocks,
    including padding) and the same block-granular skip predicate: 2 matmuls
    per live tile forward (qk^T, pv); 7 backward — the split dq / dkv
    kernels each recompute s and dp (3 + 4) in exchange for no cross-tile
    output revisits. Each matmul is 2·bq·bk·hd FLOPs. Static HLO FLOP
    counts can't report this (interpret mode lowers the grid to a loop
    whose body is counted once), hence this mirror of the kernel's own
    skip logic.
    """
    import numpy as np
    bq, bk, Sp = select_blocks(S, block_q, block_k)
    tiles = live_block_count(Sp, bq, bk, causal, window, seq_len=S)
    per_matmul = 2 * bq * bk * hd
    fwd = float(np.sum(np.asarray(g_f) != 0)) * tiles * 2 * per_matmul
    bwd = float(np.sum(np.asarray(g_b) != 0)) * tiles * 7 * per_matmul
    return fwd, bwd
