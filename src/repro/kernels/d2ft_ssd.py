"""Pallas TPU kernels: D2FT-gated SSD chunked scan, forward *and* backward.

Implements the gated block kernel contract (``repro.kernels.contract``,
docs/kernels.md) for the Mamba-2 SSD block: the subnet axis is the
flattened (sample, head) pair, matching the per-head decay ``dA`` of
``models/ssm.ssd_chunked``. Per live slice the kernel runs the exact
chunked algorithm of the jnp reference — intra-chunk quadratic term,
inter-chunk recurrence carried in f32 VMEM scratch — so the kernel and
masked paths agree to float-associativity:

* forward, gate ``g_f``: ``g_f == 0`` slices skip the whole chunk loop
  body with ``@pl.when`` (no MXU work) and write zeros once per chunk;
  the recurrent state scratch stays at its zero init.
* fused backward, gate ``g_b``: dead slices skip every backward matmul
  and write zero dx / ddA / dB / dC. The backward walks chunks in
  *reverse* grid order (the output index maps flip the chunk index)
  carrying the state cotangent in VMEM scratch; everything else is
  recomputed per chunk from the saved inputs plus the per-chunk incoming
  states (``prevs``) the forward emits as a residual.

Compaction dispatch is shared with the attention kernel: live slices are
gathered front via ``contract.live_permutation`` under a static
``live_fwd`` / ``live_bwd`` bound and the grid's leading dim shrinks to
the bound; results scatter back with zeros elsewhere.

B/C are shared across heads in the model (single B/C group); the wrapper
broadcasts them per slice before compaction and the VJP sums the
per-head dB/dC back. ``S % chunk != 0`` is handled by the *caller*
(``models/ssm.apply_ssd``) zero-padding the scan inputs — a padded row
has ``dA = 0`` (identity decay) and ``xbar = 0`` (no state
contribution), so no in-kernel length masking is needed and the pad
rows' outputs/grads are sliced/dropped outside.

The jit'd public wrapper with interpret auto-detection is
``repro.kernels.ops.gated_ssd_scan``; the pure-jnp oracle is
``repro.kernels.ref.gated_ssd_ref``.
"""
from __future__ import annotations

import functools

import numpy as np

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import contract as _contract
from repro.kernels.compat import CompilerParams as _CompilerParams

# Test hooks — same contract as d2ft_attention: on_backward_block fires once
# per *executed* backward chunk (via jax.debug.callback), on_dispatch fires
# per pallas_call at trace time as (kind, grid). Set before the first trace.
on_backward_block = None
on_dispatch = None


def _maybe_count_block():
    if on_backward_block is not None:
        jax.debug.callback(on_backward_block)


def _report_dispatch(kind: str, grid):
    if on_dispatch is not None:
        on_dispatch(kind, tuple(grid))


def _causal_decay(da):
    """cum (inclusive cumsum), L[q, k] = exp(cum_q - cum_k) masked causal
    (diagonal included, = 1) — the reference's intra-chunk decay matrix."""
    Q = da.shape[0]
    cum = jnp.cumsum(da)
    diff = cum[:, None] - cum[None, :]
    tril = jnp.tril(jnp.ones((Q, Q), jnp.bool_))
    return cum, jnp.where(tril, jnp.exp(diff), 0.0)


# ================================================================== forward
def _fwd_kernel(gate_ref, da_ref, x_ref, b_ref, c_ref, y_ref, prev_ref,
                state_ref):
    j = pl.program_id(1)
    gate = gate_ref[0, 0]

    @pl.when(j == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    prev = state_ref[...]                                   # [P, N] f32

    @pl.when(gate != 0)
    def _compute():
        x = x_ref[0].astype(jnp.float32)                    # [Q, P]
        da = da_ref[0].astype(jnp.float32)                  # [Q]
        b = b_ref[0].astype(jnp.float32)                    # [Q, N]
        c = c_ref[0].astype(jnp.float32)                    # [Q, N]
        Q = da.shape[0]
        cum, L = _causal_decay(da)
        cb = jax.lax.dot_general(c, b, (((1,), (1,)), ((), ())))  # [Q, Q]
        y = jax.lax.dot_general(cb * L, x, (((1,), (0,)), ((), ())))
        e_cum = jnp.exp(cum)
        y = y + jax.lax.dot_general(
            c, prev, (((1,), (1,)), ((), ()))) * e_cum[:, None]
        y_ref[0] = y.astype(y_ref.dtype)
        prev_ref[0, 0] = prev
        xw = x * jnp.exp(cum[Q - 1] - cum)[:, None]         # decay-to-end
        state_ref[...] = jnp.exp(cum[Q - 1]) * prev + \
            jax.lax.dot_general(xw, b, (((0,), (0,)), ((), ())))

    @pl.when(gate == 0)
    def _dead():
        y_ref[0] = jnp.zeros_like(y_ref[0])
        prev_ref[0, 0] = jnp.zeros_like(prev_ref[0, 0])


def _slice_major(x, da, Bm, Cm):
    """[B,S,H,*] model layout -> slice-major [B*H, S, *] kernel layout,
    broadcasting the head-shared B/C per slice."""
    B, S, H, P = x.shape
    N = Bm.shape[-1]
    xs = x.transpose(0, 2, 1, 3).reshape(B * H, S, P)
    das = da.transpose(0, 2, 1).reshape(B * H, S)
    Bs = jnp.broadcast_to(Bm[:, None], (B, H, S, N)).reshape(B * H, S, N)
    Cs = jnp.broadcast_to(Cm[:, None], (B, H, S, N)).reshape(B * H, S, N)
    return xs, das, Bs, Cs


def _forward(x, da, Bm, Cm, g_f, *, chunk: int, interpret: bool, live=None):
    """x: [B,S,H,P] (dt-weighted input), da: [B,S,H] (dt*A), Bm/Cm: [B,S,N],
    g_f: [B,H]. Returns (y [B,S,H,P], prevs [B*H, nc, P, N] f32 — the state
    entering each chunk, zeros for never-dispatched slices)."""
    B, S, H, P = x.shape
    N = Bm.shape[-1]
    Q = min(chunk, S)
    assert S % Q == 0, (S, Q)
    nc = S // Q
    NS = B * H
    xs, das, Bs, Cs = _slice_major(x, da, Bm, Cm)
    g = g_f.reshape(NS)
    n_disp = _contract.dispatch_count(live, NS)
    idx = None
    if n_disp < NS:
        idx = _contract.live_permutation(g, n_disp)
        xs, das, Bs, Cs, g = (jnp.take(a, idx, axis=0)
                              for a in (xs, das, Bs, Cs, g))

    grid = (n_disp, nc)
    _report_dispatch("fwd", grid)
    y, prevs = pl.pallas_call(
        _fwd_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda s, j: (s, 0)),              # g_f
            pl.BlockSpec((1, Q), lambda s, j: (s, j)),              # da
            pl.BlockSpec((1, Q, P), lambda s, j: (s, j, 0)),        # x
            pl.BlockSpec((1, Q, N), lambda s, j: (s, j, 0)),        # B
            pl.BlockSpec((1, Q, N), lambda s, j: (s, j, 0)),        # C
        ],
        out_specs=[
            pl.BlockSpec((1, Q, P), lambda s, j: (s, j, 0)),        # y
            pl.BlockSpec((1, 1, P, N), lambda s, j: (s, j, 0, 0)),  # prevs
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_disp, S, P), x.dtype),
            jax.ShapeDtypeStruct((n_disp, nc, P, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],           # state
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(g.reshape(n_disp, 1), das, xs, Bs, Cs)

    if idx is not None:
        y = jnp.zeros((NS, S, P), y.dtype).at[idx].set(
            y, unique_indices=True)
        prevs = jnp.zeros((NS, nc, P, N), jnp.float32).at[idx].set(
            prevs, unique_indices=True)
    return y.reshape(B, H, S, P).transpose(0, 2, 1, 3), prevs


# ================================================================= backward
def _bwd_kernel(gate_ref, da_ref, x_ref, b_ref, c_ref, prev_ref, dy_ref,
                dx_ref, dda_ref, db_ref, dc_ref, dstate_ref):
    """Reverse chunk sweep (the index maps flip j); VMEM scratch carries the
    state cotangent. Per live chunk: recompute the decay/state quantities
    and emit dx / ddA / dB / dC plus the carry for the previous chunk."""
    j = pl.program_id(1)
    gate = gate_ref[0, 0]

    @pl.when(j == 0)
    def _init():
        dstate_ref[...] = jnp.zeros_like(dstate_ref)

    @pl.when(gate != 0)
    def _compute():
        _maybe_count_block()
        x = x_ref[0].astype(jnp.float32)                    # [Q, P]
        da = da_ref[0].astype(jnp.float32)                  # [Q]
        b = b_ref[0].astype(jnp.float32)                    # [Q, N]
        c = c_ref[0].astype(jnp.float32)                    # [Q, N]
        prev = prev_ref[0, 0]                               # [P, N] f32
        dy = dy_ref[0].astype(jnp.float32)                  # [Q, P]
        ds = dstate_ref[...]                                # [P, N] f32
        Q = da.shape[0]
        cum, L = _causal_decay(da)
        tot = cum[Q - 1]
        e_cum = jnp.exp(cum)
        d2e = jnp.exp(tot - cum)
        e_tot = jnp.exp(tot)
        cb = jax.lax.dot_general(c, b, (((1,), (1,)), ((), ())))  # [Q, Q]

        # intra-chunk: y_intra = (CB * L) @ x
        gqk = jax.lax.dot_general(dy, x, (((1,), (1,)), ((), ())))
        dcb = gqk * L
        m = gqk * cb * L                                    # dL * L
        dc_intra = jax.lax.dot_general(dcb, b, (((1,), (0,)), ((), ())))
        db_intra = jax.lax.dot_general(dcb, c, (((0,), (0,)), ((), ())))
        dx_intra = jax.lax.dot_general(cb * L, dy, (((0,), (0,)), ((), ())))

        # inter-chunk output: y_inter = (c @ prev^T) * e_cum
        t1 = dy * e_cum[:, None]
        dc_inter = jax.lax.dot_general(t1, prev, (((1,), (0,)), ((), ())))
        dprev_y = jax.lax.dot_general(t1, c, (((0,), (0,)), ((), ())))
        y_int = jax.lax.dot_general(
            c, prev, (((1,), (1,)), ((), ()))) * e_cum[:, None]
        dcum_yint = jnp.sum(dy * y_int, axis=1)

        # state update: state' = e_tot * prev + (x * d2e)^T @ b
        dprev_state = e_tot * ds
        dtot_state = e_tot * jnp.sum(ds * prev)
        dxw = jax.lax.dot_general(b, ds, (((1,), (1,)), ((), ())))  # [Q, P]
        xw = x * d2e[:, None]
        db_state = jax.lax.dot_general(xw, ds, (((1,), (0,)), ((), ())))
        dx_state = dxw * d2e[:, None]
        dd2e = jnp.sum(dxw * x, axis=1)

        w = dd2e * d2e
        dcum = jnp.sum(m, axis=1) - jnp.sum(m, axis=0) + dcum_yint - w
        dtot = dtot_state + jnp.sum(w)
        dda = jnp.cumsum(dcum[::-1])[::-1] + dtot           # cumsum adjoint

        dx_ref[0] = (dx_intra + dx_state).astype(dx_ref.dtype)
        dda_ref[0] = dda.astype(dda_ref.dtype)
        db_ref[0] = (db_intra + db_state).astype(db_ref.dtype)
        dc_ref[0] = (dc_intra + dc_inter).astype(dc_ref.dtype)
        dstate_ref[...] = dprev_state + dprev_y

    @pl.when(gate == 0)
    def _dead():
        dx_ref[0] = jnp.zeros_like(dx_ref[0])
        dda_ref[0] = jnp.zeros_like(dda_ref[0])
        db_ref[0] = jnp.zeros_like(db_ref[0])
        dc_ref[0] = jnp.zeros_like(dc_ref[0])


def _backward(x, da, Bm, Cm, g_b, prevs, dy, *, chunk: int, interpret: bool,
              live=None):
    B, S, H, P = x.shape
    N = Bm.shape[-1]
    Q = min(chunk, S)
    nc = S // Q
    NS = B * H
    xs, das, Bs, Cs = _slice_major(x, da, Bm, Cm)
    dys = dy.transpose(0, 2, 1, 3).reshape(NS, S, P)
    g = g_b.reshape(NS)
    n_disp = _contract.dispatch_count(live, NS)
    idx = None
    if n_disp < NS:
        idx = _contract.live_permutation(g, n_disp)
        xs, das, Bs, Cs, dys, prevs, g = (
            jnp.take(a, idx, axis=0)
            for a in (xs, das, Bs, Cs, dys, prevs, g))

    rev = nc - 1
    grid = (n_disp, nc)
    _report_dispatch("bwd", grid)
    dx, dda, db, dc = pl.pallas_call(
        _bwd_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda s, j: (s, 0)),                # g_b
            pl.BlockSpec((1, Q), lambda s, j: (s, rev - j)),          # da
            pl.BlockSpec((1, Q, P), lambda s, j: (s, rev - j, 0)),    # x
            pl.BlockSpec((1, Q, N), lambda s, j: (s, rev - j, 0)),    # B
            pl.BlockSpec((1, Q, N), lambda s, j: (s, rev - j, 0)),    # C
            pl.BlockSpec((1, 1, P, N),
                         lambda s, j: (s, rev - j, 0, 0)),            # prevs
            pl.BlockSpec((1, Q, P), lambda s, j: (s, rev - j, 0)),    # dy
        ],
        out_specs=[
            pl.BlockSpec((1, Q, P), lambda s, j: (s, rev - j, 0)),    # dx
            pl.BlockSpec((1, Q), lambda s, j: (s, rev - j)),          # dda
            pl.BlockSpec((1, Q, N), lambda s, j: (s, rev - j, 0)),    # dB
            pl.BlockSpec((1, Q, N), lambda s, j: (s, rev - j, 0)),    # dC
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_disp, S, P), jnp.float32),
            jax.ShapeDtypeStruct((n_disp, S), jnp.float32),
            jax.ShapeDtypeStruct((n_disp, S, N), jnp.float32),
            jax.ShapeDtypeStruct((n_disp, S, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],           # dstate
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(g.reshape(n_disp, 1), das, xs, Bs, Cs, prevs, dys)

    if idx is not None:
        dx, dda, db, dc = (
            jnp.zeros((NS,) + a.shape[1:], a.dtype).at[idx].set(
                a, unique_indices=True) for a in (dx, dda, db, dc))
    dx = dx.reshape(B, H, S, P).transpose(0, 2, 1, 3).astype(x.dtype)
    dda = dda.reshape(B, H, S).transpose(0, 2, 1).astype(da.dtype)
    # B/C are shared across heads: sum the per-slice cotangents back
    db = db.reshape(B, H, S, N).sum(axis=1).astype(Bm.dtype)
    dc = dc.reshape(B, H, S, N).sum(axis=1).astype(Cm.dtype)
    return dx, dda, db, dc


# =============================================================== custom VJP
@functools.partial(jax.custom_vjp, nondiff_argnums=(6, 7, 8, 9))
def gated_ssd_scan(x, da, Bm, Cm, g_f, g_b, chunk, interpret,
                   live_fwd=None, live_bwd=None):
    """Differentiable gated SSD chunked scan core.

    x: [B,S,H,P] dt-weighted input (``xh * dt``), da: [B,S,H] per-step
    log-decay (``dt * A``, negative), Bm/Cm: [B,S,N] shared across heads,
    g_f/g_b: [B,H] float {0,1} with g_b <= g_f. Returns y: [B,S,H,P],
    ``g_f``-gated; the registered backward computes dx/ddA/dB/dC only where
    ``g_b != 0`` (gates receive zero cotangents). ``live_fwd`` / ``live_bwd``
    are static live-slice bounds enabling compaction dispatch. S must be a
    multiple of ``chunk`` (the caller pads — see module docstring). Prefer
    the jit'd ``ops.gated_ssd_scan``.
    """
    y, _ = _forward(x, da, Bm, Cm, g_f, chunk=chunk, interpret=interpret,
                    live=live_fwd)
    return y


def _vjp_fwd(x, da, Bm, Cm, g_f, g_b, chunk, interpret, live_fwd=None,
             live_bwd=None):
    y, prevs = _forward(x, da, Bm, Cm, g_f, chunk=chunk, interpret=interpret,
                        live=live_fwd)
    return y, (x, da, Bm, Cm, g_f, g_b, prevs)


def _vjp_bwd(chunk, interpret, live_fwd, live_bwd, res, dy):
    x, da, Bm, Cm, g_f, g_b, prevs = res
    dx, dda, db, dc = _backward(x, da, Bm, Cm, g_b, prevs, dy, chunk=chunk,
                                interpret=interpret, live=live_bwd)
    return dx, dda, db, dc, jnp.zeros_like(g_f), jnp.zeros_like(g_b)


gated_ssd_scan.defvjp(_vjp_fwd, _vjp_bwd)


# ======================================================== analytic accounting
# matmuls per live chunk (FLOPs = 2 * m * n * k each):
#   fwd: CB [Q,Q,N], y_intra [Q,Q,P], y_inter [Q,P,N], state-add [Q,P,N]
#   bwd: gqk + dx_intra [Q,Q,P]*2, dc_intra + db_intra [Q,Q,N]*2,
#        dc_inter + dprev_y + y_int + dxw + db_state [Q,P,N]*5
def _chunk_flops(Q: int, P: int, N: int):
    fwd = 2 * (Q * Q * N + Q * Q * P + 2 * Q * P * N)
    bwd = 2 * (2 * Q * Q * P + 2 * Q * Q * N + 5 * Q * P * N)
    return fwd, bwd


def gated_ssd_flops(g_f, g_b, S: int, P: int, N: int, *, chunk: int):
    """Executed MXU FLOPs (fwd, bwd) of the kernel path under concrete
    gates: live slices x chunks x the per-chunk matmul list above. Mirrors
    the kernel's own ``@pl.when`` skip — static HLO counts cannot."""
    Q = min(chunk, S)
    nc = -(-S // Q)
    f, b = _chunk_flops(Q, P, N)
    return (float(np.sum(np.asarray(g_f) != 0)) * nc * f,
            float(np.sum(np.asarray(g_b) != 0)) * nc * b)


def gated_ssd_dispatched_bytes(g_f, g_b, S: int, P: int, N: int, *,
                               chunk: int, live_fwd: int = None,
                               live_bwd: int = None, itemsize: int = 4):
    """(fwd_bytes, bwd_bytes) the BlockSpec pipelines stream per pallas_call.

    Every input block's index map advances each chunk step, so per
    dispatched slice each operand streams exactly once: fwd reads
    x/da/B/C and writes y + the per-chunk prevs residual; bwd re-reads
    them plus prevs/dy and writes dx/dda/dB/dC. ``@pl.when`` does not
    skip this traffic — only compaction dispatch does."""
    Q = min(chunk, S)
    nc = -(-S // Q)
    NS = int(np.asarray(g_f).size)
    disp_f = _contract.dispatch_count(live_fwd, NS)
    disp_b = _contract.dispatch_count(live_bwd, NS)
    fwd_slice = (S * P + S + 2 * S * N        # x, da, B, C read
                 + S * P + nc * P * N)        # y, prevs written
    bwd_slice = (S * P + S + 2 * S * N + nc * P * N + S * P   # reads + dy
                 + S * P + S + 2 * S * N)     # dx, dda, dB, dC written
    return disp_f * fwd_slice * itemsize, disp_b * bwd_slice * itemsize
