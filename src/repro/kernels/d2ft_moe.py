"""Pallas TPU kernels: D2FT-gated MoE expert FFN, forward *and* backward.

Implements the gated block kernel contract (``repro.kernels.contract``,
docs/kernels.md) for the MoE expert path. The schedule gate composes with
router sparsity *upstream*, in ``models/moe.py``'s dispatch: gate-dead
(token, k) routing entries are dropped before the capacity sort, so each
expert's ``[C, D]`` capacity buffer is front-packed with live tokens only
(p_f slots packed before p_o slots within each expert segment). This
kernel then runs the doubly-sparse expert einsum over that buffer on a
grid of (expert, capacity-block) tiles:

* forward: a tile runs only when its ``fwd_mask`` bit is set (some live
  token occupies one of its slots) — empty and gate-dead tiles write
  zeros via ``@pl.when`` and the gated-MLP matmuls are skipped.
* fused backward: a tile runs only when its ``bwd_mask`` bit is set (some
  p_f token occupies it); h and the gate pre-activation are recomputed
  from x, and dx plus the per-expert dW accumulators are emitted in one
  pass. dW tiles use the attention kernel's dq pattern: their output
  index map ignores the capacity-block dim so they stay VMEM-resident per
  expert and flush once.

The masks are per (expert, capacity-block) in {0, 1} with bwd <= fwd and
receive zero cotangents. The analogue of compaction dispatch is *static
capacity truncation*: the wrapper (``ops.gated_moe_ffn``) shrinks the
capacity axis to the schedule-derived live-slot bound before launching,
so provably-empty trailing blocks cost neither grid steps nor DMA. The
two passes truncate independently — the forward to the g_f bound, the
backward to the (smaller or equal) g_b bound via the ``bwd_blocks``
nondiff argument, which works because the dispatch sorts backward-live
assignments into a capacity prefix per expert.

The jit'd public wrapper with interpret auto-detection is
``repro.kernels.ops.gated_moe_ffn``; the pure-jnp oracle is
``repro.kernels.ref.gated_moe_ffn_ref``.
"""
from __future__ import annotations

import functools
import math
from typing import Optional

import numpy as np

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams as _CompilerParams

# Test hooks — same contract as d2ft_attention / d2ft_ssd / d2ft_rglru.
on_backward_block = None
on_dispatch = None


def _maybe_count_block():
    if on_backward_block is not None:
        jax.debug.callback(on_backward_block)


def _report_dispatch(kind: str, grid):
    if on_dispatch is not None:
        on_dispatch(kind, tuple(grid))


def act_pair(name: str):
    """(f, df) for the expert activation — the backward kernel needs an
    explicit derivative (no autodiff inside a Pallas body). Matches
    ``models.layers._act``: silu, gelu (tanh approximation — jax.nn.gelu's
    default), relu."""
    if name == "silu":
        def df(g):
            s = jax.nn.sigmoid(g)
            return s * (1.0 + g * (1.0 - s))
        return jax.nn.silu, df
    if name == "gelu":
        c = math.sqrt(2.0 / math.pi)

        def df(g):
            t = jnp.tanh(c * (g + 0.044715 * g ** 3))
            return 0.5 * (1.0 + t) + \
                0.5 * g * (1.0 - t ** 2) * c * (1.0 + 3 * 0.044715 * g ** 2)
        return jax.nn.gelu, df
    if name == "relu":
        return jax.nn.relu, lambda g: (g > 0).astype(g.dtype)
    raise ValueError(f"unknown activation {name!r}")


# ================================================================== forward
def _fwd_kernel(fm_ref, x_ref, wu_ref, wg_ref, wd_ref, y_ref, *, act: str):
    f, _ = act_pair(act)
    live = fm_ref[0, 0]

    @pl.when(live != 0)
    def _compute():
        x = x_ref[0].astype(jnp.float32)                    # [bc, D]
        wu = wu_ref[0].astype(jnp.float32)                  # [D, F]
        wg = wg_ref[0].astype(jnp.float32)
        wd = wd_ref[0].astype(jnp.float32)                  # [F, D]
        h = jax.lax.dot_general(x, wu, (((1,), (0,)), ((), ())))
        g = jax.lax.dot_general(x, wg, (((1,), (0,)), ((), ())))
        y = jax.lax.dot_general(f(g) * h, wd, (((1,), (0,)), ((), ())))
        y_ref[0] = y.astype(y_ref.dtype)

    @pl.when(live == 0)
    def _dead():
        y_ref[0] = jnp.zeros_like(y_ref[0])


def _forward(xb, w_up, w_gate, w_down, fm, *, act: str, block_c: int,
             interpret: bool):
    E, C, D = xb.shape
    F = w_up.shape[-1]
    assert C % block_c == 0, (C, block_c)
    n_cb = C // block_c
    grid = (E, n_cb)
    _report_dispatch("fwd", grid)
    return pl.pallas_call(
        functools.partial(_fwd_kernel, act=act),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda e, ic: (e, ic)),            # fm
            pl.BlockSpec((1, block_c, D), lambda e, ic: (e, ic, 0)),
            pl.BlockSpec((1, D, F), lambda e, ic: (e, 0, 0)),       # w_up
            pl.BlockSpec((1, D, F), lambda e, ic: (e, 0, 0)),       # w_gate
            pl.BlockSpec((1, F, D), lambda e, ic: (e, 0, 0)),       # w_down
        ],
        out_specs=pl.BlockSpec((1, block_c, D), lambda e, ic: (e, ic, 0)),
        out_shape=jax.ShapeDtypeStruct((E, C, D), xb.dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(fm, xb, w_up, w_gate, w_down)


# ================================================================= backward
def _bwd_kernel(bm_ref, x_ref, wu_ref, wg_ref, wd_ref, dy_ref, dx_ref,
                dwu_ref, dwg_ref, dwd_ref, *, act: str):
    """Fused one-pass backward over (expert, capacity-block) tiles. dW
    outputs accumulate in per-expert blocks whose index map ignores the
    capacity-block dim (VMEM-resident per expert, init at ic == 0)."""
    f, df = act_pair(act)
    ic = pl.program_id(1)
    live = bm_ref[0, 0]

    @pl.when(ic == 0)
    def _init():
        dwu_ref[...] = jnp.zeros_like(dwu_ref)
        dwg_ref[...] = jnp.zeros_like(dwg_ref)
        dwd_ref[...] = jnp.zeros_like(dwd_ref)

    @pl.when(live != 0)
    def _compute():
        _maybe_count_block()
        x = x_ref[0].astype(jnp.float32)                    # [bc, D]
        wu = wu_ref[0].astype(jnp.float32)
        wg = wg_ref[0].astype(jnp.float32)
        wd = wd_ref[0].astype(jnp.float32)
        dy = dy_ref[0].astype(jnp.float32)                  # [bc, D]
        h = jax.lax.dot_general(x, wu, (((1,), (0,)), ((), ())))
        g = jax.lax.dot_general(x, wg, (((1,), (0,)), ((), ())))
        a = f(g)
        dmid = jax.lax.dot_general(dy, wd, (((1,), (1,)), ((), ())))
        dwd_ref[0] += jax.lax.dot_general(a * h, dy, (((0,), (0,)), ((), ())))
        dh = dmid * a
        dgpre = dmid * h * df(g)
        dx = jax.lax.dot_general(dh, wu, (((1,), (1,)), ((), ()))) + \
            jax.lax.dot_general(dgpre, wg, (((1,), (1,)), ((), ())))
        dx_ref[0] = dx.astype(dx_ref.dtype)
        dwu_ref[0] += jax.lax.dot_general(x, dh, (((0,), (0,)), ((), ())))
        dwg_ref[0] += jax.lax.dot_general(x, dgpre, (((0,), (0,)), ((), ())))

    @pl.when(live == 0)
    def _dead():
        dx_ref[0] = jnp.zeros_like(dx_ref[0])


def _backward(xb, w_up, w_gate, w_down, bm, dy, *, act: str, block_c: int,
              interpret: bool):
    E, C, D = xb.shape
    F = w_up.shape[-1]
    n_cb = C // block_c
    grid = (E, n_cb)
    _report_dispatch("bwd", grid)
    return pl.pallas_call(
        functools.partial(_bwd_kernel, act=act),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda e, ic: (e, ic)),            # bm
            pl.BlockSpec((1, block_c, D), lambda e, ic: (e, ic, 0)),
            pl.BlockSpec((1, D, F), lambda e, ic: (e, 0, 0)),
            pl.BlockSpec((1, D, F), lambda e, ic: (e, 0, 0)),
            pl.BlockSpec((1, F, D), lambda e, ic: (e, 0, 0)),
            pl.BlockSpec((1, block_c, D), lambda e, ic: (e, ic, 0)),  # dy
        ],
        out_specs=[
            pl.BlockSpec((1, block_c, D), lambda e, ic: (e, ic, 0)),  # dx
            pl.BlockSpec((1, D, F), lambda e, ic: (e, 0, 0)),         # dwu
            pl.BlockSpec((1, D, F), lambda e, ic: (e, 0, 0)),         # dwg
            pl.BlockSpec((1, F, D), lambda e, ic: (e, 0, 0)),         # dwd
        ],
        out_shape=[
            jax.ShapeDtypeStruct((E, C, D), jnp.float32),
            jax.ShapeDtypeStruct((E, D, F), jnp.float32),
            jax.ShapeDtypeStruct((E, D, F), jnp.float32),
            jax.ShapeDtypeStruct((E, F, D), jnp.float32),
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(bm, xb, w_up, w_gate, w_down, dy)


# =============================================================== custom VJP
@functools.partial(jax.custom_vjp, nondiff_argnums=(6, 7, 8, 9))
def gated_moe_ffn(xb, w_up, w_gate, w_down, fm, bm, act, block_c,
                  bwd_blocks, interpret):
    """Differentiable doubly-sparse MoE expert FFN core.

    xb: [E, C, D] capacity buffer (front-packed live tokens — see
    models/moe.py), w_up/w_gate: [E, D, F], w_down: [E, F, D], fm/bm:
    [E, C // block_c] float {0,1} per-(expert, capacity-block) masks with
    bm <= fm. Forward skips fm == 0 tiles; backward skips bm == 0 tiles
    and returns zero gradients there (masks get zero cotangents).
    bwd_blocks: static capacity-block count for the backward grid, keyed
    on the g_b bound *separately* from the forward's truncation — the
    dispatch packs backward-live slots into a capacity prefix per expert
    (models/moe.py sorts p_f assignments before p_o), so when g_b < g_f
    every bm bit beyond the first ``bwd_blocks`` blocks is zero and the
    backward launches a (E, bwd_blocks) grid over sliced operands instead
    of re-walking the forward's capacity; dx zero-pads back to C. Pass
    ``None`` (or >= C // block_c) for the full grid. C must be a multiple
    of block_c (the wrapper pads + truncates). Prefer
    ``ops.gated_moe_ffn``.
    """
    return _forward(xb, w_up, w_gate, w_down, fm, act=act, block_c=block_c,
                    interpret=interpret)


def _vjp_fwd(xb, w_up, w_gate, w_down, fm, bm, act, block_c, bwd_blocks,
             interpret):
    y = _forward(xb, w_up, w_gate, w_down, fm, act=act, block_c=block_c,
                 interpret=interpret)
    return y, (xb, w_up, w_gate, w_down, fm, bm)


def _vjp_bwd(act, block_c, bwd_blocks, interpret, res, dy):
    xb, w_up, w_gate, w_down, fm, bm = res
    E, C, _ = xb.shape
    n_cb = C // block_c
    nb = n_cb if bwd_blocks is None else min(int(bwd_blocks), n_cb)
    if nb < n_cb:
        # backward-live slots are front-packed: the truncated tail holds
        # only bm == 0 blocks, whose dx is zero and whose dW tiles are
        # @pl.when-skipped anyway — slicing them off the grid makes the
        # backward's steps and DMA scale with g_b, not g_f.
        cr = nb * block_c
        dx, dwu, dwg, dwd = _backward(
            xb[:, :cr], w_up, w_gate, w_down, bm[:, :nb], dy[:, :cr],
            act=act, block_c=block_c, interpret=interpret)
        dx = jnp.pad(dx, ((0, 0), (0, C - cr), (0, 0)))
    else:
        dx, dwu, dwg, dwd = _backward(xb, w_up, w_gate, w_down, bm, dy,
                                      act=act, block_c=block_c,
                                      interpret=interpret)
    return (dx.astype(xb.dtype), dwu.astype(w_up.dtype),
            dwg.astype(w_gate.dtype), dwd.astype(w_down.dtype),
            jnp.zeros_like(fm), jnp.zeros_like(bm))


gated_moe_ffn.defvjp(_vjp_fwd, _vjp_bwd)


# ======================================================== analytic accounting
FWD_MATMULS_PER_TILE = 3   # x·w_up, x·w_gate, (act·h)·w_down
BWD_MATMULS_PER_TILE = 8   # h, g recompute; dmid; dwd; dx (2); dwu; dwg


def gated_moe_flops(fm, bm, block_c: int, D: int, F: int):
    """Executed MXU FLOPs (fwd, bwd) under concrete block masks: live tiles
    x matmuls/tile x 2·bc·D·F each — the kernel's own skip, mirrored."""
    per = 2 * block_c * D * F
    return (float(np.sum(np.asarray(fm) != 0)) * FWD_MATMULS_PER_TILE * per,
            float(np.sum(np.asarray(bm) != 0)) * BWD_MATMULS_PER_TILE * per)


def gated_moe_dispatched_bytes(E: int, n_cb: int, block_c: int, D: int,
                               F: int, *, itemsize: int = 4,
                               n_cb_bwd: Optional[int] = None):
    """(fwd_bytes, bwd_bytes) streamed for grids of (E, n_cb): expert
    weights fetch once per expert (their index maps ignore the capacity
    dim), x/y/dy/dx once per tile, dW written once per expert. Capacity
    truncation (the wrapper's n_cb) is what shrinks this — ``@pl.when``
    alone does not. ``n_cb_bwd`` prices the backward's separate g_b-keyed
    truncation (defaults to the shared grid)."""
    nb = n_cb if n_cb_bwd is None else n_cb_bwd
    wb = 3 * D * F * itemsize
    tile = block_c * D * itemsize
    fwd = E * (wb + n_cb * 2 * tile)               # x read + y written
    bwd = E * (wb + nb * 3 * tile + wb)            # x, dy read; dx, dW out
    return fwd, bwd
