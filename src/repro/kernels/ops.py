"""jit'd public wrappers for the Pallas kernels.

On CPU (this container) the kernels execute with interpret=True; on TPU the
same pallas_call lowers to Mosaic. ``interpret=None`` auto-detects.
"""
from __future__ import annotations

import functools
from typing import Optional

import numpy as np

import jax
import jax.numpy as jnp

from repro.kernels.d2ft_attention import (d2ft_flash_attention,
                                          gated_flash_attention,
                                          pad_to_blocks)
from repro.kernels.lora_matmul import lora_matmul
from repro.kernels.paged_decode import paged_flash_decode
from repro.kernels import ref


def _auto_interpret(interpret: Optional[bool]) -> bool:
    if interpret is not None:
        return interpret
    return jax.default_backend() != "tpu"


def _concrete(x):
    """np array when x is a concrete value, None when it is a tracer."""
    try:
        return np.asarray(x)
    except (jax.errors.ConcretizationTypeError,
            jax.errors.TracerArrayConversionError):
        return None


def _validate_gates(g_f, g_b, B: int, H: int, live_fwd, live_bwd):
    """Shape check always; two value contracts are checked whenever the
    gates are concrete — i.e. at every direct call; inside an outer jit the
    gates are tracers and the checks are skipped (the schedule construction
    upholds them):

    * the documented ``g_b <= g_f`` invariant (a p_s head cannot run its
      backward);
    * ``live_fwd``/``live_bwd`` being true *upper* bounds on the live gate
      counts — an undersized bound would silently truncate the compaction
      gather and zero live slices' outputs/gradients (the classic mistake
      is passing per-(sample, group) schedule bounds without the
      heads-per-group scaling the model stack applies).
    """
    if g_f.shape != (B, H) or g_b.shape != (B, H):
        raise ValueError(
            f"gates must be [B={B}, H={H}], got {g_f.shape} / {g_b.shape}")
    cf, cb = _concrete(g_f), _concrete(g_b)
    if cf is None or cb is None:
        return
    if np.any(cb > cf):
        bad = np.argwhere(cb > cf)
        raise ValueError(
            "g_b <= g_f violated (a gated-off forward cannot have a live "
            f"backward): g_b > g_f at (sample, head) {bad[:8].tolist()}"
            f"{' ...' if len(bad) > 8 else ''}")
    for name, bound, live in (("live_fwd", live_fwd, int((cf != 0).sum())),
                              ("live_bwd", live_bwd, int((cb != 0).sum()))):
        if bound is not None and bound < live:
            raise ValueError(
                f"{name}={bound} is below the live gate count {live}: the "
                "compaction bound must be an upper bound or live slices "
                "would be silently dropped (did you forget the H//G "
                "heads-per-group scaling?)")


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_k", "interpret",
                                             "live_fwd", "live_bwd"))
def _gated_attention_impl(q, k, v, g_f, g_b, *, causal, window, block_q,
                          block_k, interpret, live_fwd, live_bwd):
    q, k, v, bq, bk, S, Sp = pad_to_blocks(q, k, v, block_q, block_k)
    out = gated_flash_attention(q, k, v, g_f, g_b, causal, window, bq, bk,
                                _auto_interpret(interpret), S, live_fwd,
                                live_bwd)
    return out[:, :, :S] if Sp != S else out


def gated_attention(q, k, v, g_f, g_b=None, *, causal: bool = True,
                    window: int = 0, block_q: int = 128, block_k: int = 128,
                    interpret: Optional[bool] = None,
                    live_fwd: Optional[int] = None,
                    live_bwd: Optional[int] = None):
    """D2FT-gated flash attention with a gate-aware backward (custom VJP).

    q, k, v: [B, H, S, hd]; g_f, g_b: [B, H] float {0,1} with g_b <= g_f
    elementwise (checked whenever the gates are concrete). g_f gates the
    forward (0 -> zeros and no forward MXU work: p_s); g_b gates the
    backward kernel (0 -> zero dq/dk/dv and no backward MXU work: p_o and
    p_s). Omitting g_b uses g_b = g_f, i.e. the fully differentiable p_f
    path (back-compat with the forward-only API).

    live_fwd / live_bwd: optional *static* upper bounds on the number of
    g_f != 0 / g_b != 0 (sample, head) slices — e.g. B*H scaled by the
    Schedule's p_f/p_o micro-batch counts (``core.schedule
    .live_slice_bounds``). When given, the kernels run on a compacted grid
    of that many slices (live slices gathered front via a stable argsort of
    the gates, results scattered back with zeros elsewhere) so gated-off
    slices pay neither grid steps nor DMA. Bounds must be >= the actual
    live counts for the gates passed; None dispatches all B*H slices.

    Sequence lengths that don't divide the tiles either shrink the tiles
    (near-divisor case) or zero-pad S (select_blocks); padded rows/tiles
    are masked via the kernels' seq_len bound and sliced off, and jnp.pad's
    VJP routes the padding out of the gradients.
    """
    if g_b is None:
        g_b = g_f
    B, H, S, _ = q.shape
    _validate_gates(g_f, g_b, B, H, live_fwd, live_bwd)
    return _gated_attention_impl(q, k, v, g_f, g_b, causal=causal,
                                 window=window, block_q=block_q,
                                 block_k=block_k, interpret=interpret,
                                 live_fwd=live_fwd, live_bwd=live_bwd)


@functools.partial(jax.jit, static_argnames=("window", "interpret"))
def _paged_decode_impl(q, k_pages, v_pages, page_table, lengths, g_f, *,
                       window, interpret):
    return paged_flash_decode(q, k_pages, v_pages, page_table, lengths, g_f,
                              window=window,
                              interpret=_auto_interpret(interpret))


def paged_decode_attention(q, k_pages, v_pages, page_table, lengths,
                           g_f=None, *, window: int = 0,
                           interpret: Optional[bool] = None):
    """Decode-mode entry to the gated attention kernel family: one token per
    sequence against a paged KV cache, pages streamed by table indirection
    from scalar prefetch (kernels/paged_decode.py).

    q: [B, H, hd] post-rope queries (position ``lengths[b]``); k_pages,
    v_pages: [n_pages, page_size, n_kv, hd] shared pools (GQA un-expanded —
    the kernel's index map resolves head groups); page_table: [B, n_pmax]
    int32, padded with the null page 0 (every entry must be a valid page id:
    index maps run before block-skip predicates); lengths: [B] int32 tokens
    already cached. g_f: optional [B, H] forward gates in {0,1} — serving is
    schedule-free so the default is all-ones; gated-off heads write zeros
    and skip the MXU like the training kernel's p_s path. Returns [B,H,hd].
    """
    B, H, hd = q.shape
    if q.shape[-1] != k_pages.shape[-1]:
        raise ValueError(f"q head_dim {hd} != pool head_dim "
                         f"{k_pages.shape[-1]}")
    if k_pages.shape != v_pages.shape:
        raise ValueError(f"k/v pool shapes differ: {k_pages.shape} vs "
                         f"{v_pages.shape}")
    if page_table.shape[0] != B or lengths.shape != (B,):
        raise ValueError(
            f"page_table/lengths batch mismatch: {page_table.shape}, "
            f"{lengths.shape}, B={B}")
    if g_f is None:
        g_f = jnp.ones((B, H), jnp.float32)
    elif g_f.shape != (B, H):
        raise ValueError(f"g_f must be [B={B}, H={H}], got {g_f.shape}")
    ct = _concrete(page_table)
    if ct is not None:
        n_pages = k_pages.shape[0]
        if ct.min() < 0 or ct.max() >= n_pages:
            raise ValueError(
                f"page_table entries must be valid page ids in [0, "
                f"{n_pages}): got range [{ct.min()}, {ct.max()}]")
    return _paged_decode_impl(q, k_pages, v_pages, page_table, lengths, g_f,
                              window=window, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("scale", "block_m", "block_n",
                                             "interpret"))
def lora_linear(x, w, a, b, scale: float = 1.0, *, block_m: int = 256,
                block_n: int = 256, interpret: Optional[bool] = None):
    """Fused y = x·W + scale·(x·A)·B for 2-D or 3-D x."""
    shape = x.shape
    x2 = x.reshape(-1, shape[-1])
    y = lora_matmul(x2, w, a, b, scale, block_m=block_m, block_n=block_n,
                    interpret=_auto_interpret(interpret))
    return y.reshape(*shape[:-1], w.shape[-1])
