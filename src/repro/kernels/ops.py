"""jit'd public wrappers for the Pallas kernels.

On CPU (this container) the kernels execute with interpret=True; on TPU the
same pallas_call lowers to Mosaic. ``interpret=None`` auto-detects.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.d2ft_attention import d2ft_flash_attention
from repro.kernels.lora_matmul import lora_matmul
from repro.kernels import ref


def _auto_interpret(interpret: Optional[bool]) -> bool:
    if interpret is not None:
        return interpret
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_k", "interpret"))
def gated_attention(q, k, v, gates, *, causal: bool = True, window: int = 0,
                    block_q: int = 128, block_k: int = 128,
                    interpret: Optional[bool] = None):
    """D2FT-gated flash attention. q,k,v: [B, H, S, hd]; gates: [B, H]."""
    return d2ft_flash_attention(
        q, k, v, gates, causal=causal, window=window, block_q=block_q,
        block_k=block_k, interpret=_auto_interpret(interpret))


@functools.partial(jax.jit, static_argnames=("scale", "block_m", "block_n",
                                             "interpret"))
def lora_linear(x, w, a, b, scale: float = 1.0, *, block_m: int = 256,
                block_n: int = 256, interpret: Optional[bool] = None):
    """Fused y = x·W + scale·(x·A)·B for 2-D or 3-D x."""
    shape = x.shape
    x2 = x.reshape(-1, shape[-1])
    y = lora_matmul(x2, w, a, b, scale, block_m=block_m, block_n=block_n,
                    interpret=_auto_interpret(interpret))
    return y.reshape(*shape[:-1], w.shape[-1])
