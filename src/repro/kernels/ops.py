"""jit'd public wrappers for the Pallas kernels.

On CPU (this container) the kernels execute with interpret=True; on TPU the
same pallas_call lowers to Mosaic. ``interpret=None`` auto-detects.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.d2ft_attention import (d2ft_flash_attention,
                                          gated_flash_attention,
                                          select_blocks)
from repro.kernels.lora_matmul import lora_matmul
from repro.kernels import ref


def _auto_interpret(interpret: Optional[bool]) -> bool:
    if interpret is not None:
        return interpret
    return jax.default_backend() != "tpu"


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_k", "interpret"))
def gated_attention(q, k, v, g_f, g_b=None, *, causal: bool = True,
                    window: int = 0, block_q: int = 128, block_k: int = 128,
                    interpret: Optional[bool] = None):
    """D2FT-gated flash attention with a gate-aware backward (custom VJP).

    q, k, v: [B, H, S, hd]; g_f, g_b: [B, H] float {0,1} with g_b <= g_f.
    g_f gates the forward (0 -> zeros and no forward MXU work: p_s); g_b
    gates the backward kernels (0 -> zero dq/dk/dv and no backward MXU
    work: p_o and p_s). Omitting g_b uses g_b = g_f, i.e. the fully
    differentiable p_f path (back-compat with the forward-only API).

    Sequence lengths that don't divide the tiles either shrink the tiles
    (near-divisor case) or zero-pad S (select_blocks); padded rows/tiles
    are masked via the kernels' seq_len bound and sliced off, and jnp.pad's
    VJP routes the padding out of the gradients.
    """
    if g_b is None:
        g_b = g_f
    B, H, S, _ = q.shape
    assert g_f.shape == (B, H) and g_b.shape == (B, H), \
        f"gates must be [B={B}, H={H}], got {g_f.shape} / {g_b.shape}"
    bq, bk, Sp = select_blocks(S, block_q, block_k)
    if Sp != S:
        pad = ((0, 0), (0, 0), (0, Sp - S), (0, 0))
        q, k, v = jnp.pad(q, pad), jnp.pad(k, pad), jnp.pad(v, pad)
    out = gated_flash_attention(q, k, v, g_f, g_b, causal, window, bq, bk,
                                _auto_interpret(interpret), S)
    return out[:, :, :S] if Sp != S else out


@functools.partial(jax.jit, static_argnames=("scale", "block_m", "block_n",
                                             "interpret"))
def lora_linear(x, w, a, b, scale: float = 1.0, *, block_m: int = 256,
                block_n: int = 256, interpret: Optional[bool] = None):
    """Fused y = x·W + scale·(x·A)·B for 2-D or 3-D x."""
    shape = x.shape
    x2 = x.reshape(-1, shape[-1])
    y = lora_matmul(x2, w, a, b, scale, block_m=block_m, block_n=block_n,
                    interpret=_auto_interpret(interpret))
    return y.reshape(*shape[:-1], w.shape[-1])
