"""jit'd public wrappers for the Pallas kernels.

On CPU (this container) the kernels execute with interpret=True; on TPU the
same pallas_call lowers to Mosaic. ``interpret=None`` auto-detects.
"""
from __future__ import annotations

import functools
from typing import Optional

import numpy as np

import jax
import jax.numpy as jnp

from repro.kernels.d2ft_attention import (d2ft_flash_attention,
                                          gated_flash_attention,
                                          pad_to_blocks)
from repro.kernels import d2ft_moe as _moe
from repro.kernels import d2ft_rglru as _rglru
from repro.kernels import d2ft_ssd as _ssd
from repro.kernels.lora_matmul import lora_matmul
from repro.kernels.paged_decode import paged_flash_decode
from repro.kernels import ref


def _auto_interpret(interpret: Optional[bool]) -> bool:
    if interpret is not None:
        return interpret
    return jax.default_backend() != "tpu"


def _concrete(x):
    """np array when x is a concrete value, None when it is a tracer."""
    try:
        return np.asarray(x)
    except (jax.errors.ConcretizationTypeError,
            jax.errors.TracerArrayConversionError):
        return None


def _validate_gates(g_f, g_b, B: int, H: int, live_fwd, live_bwd):
    """Shape check always; two value contracts are checked whenever the
    gates are concrete — i.e. at every direct call; inside an outer jit the
    gates are tracers and the checks are skipped (the schedule construction
    upholds them):

    * the documented ``g_b <= g_f`` invariant (a p_s head cannot run its
      backward);
    * ``live_fwd``/``live_bwd`` being true *upper* bounds on the live gate
      counts — an undersized bound would silently truncate the compaction
      gather and zero live slices' outputs/gradients (the classic mistake
      is passing per-(sample, group) schedule bounds without the
      heads-per-group scaling the model stack applies).
    """
    if g_f.shape != (B, H) or g_b.shape != (B, H):
        raise ValueError(
            f"gates must be [B={B}, H={H}], got {g_f.shape} / {g_b.shape}")
    cf, cb = _concrete(g_f), _concrete(g_b)
    if cf is None or cb is None:
        return
    if np.any(cb > cf):
        bad = np.argwhere(cb > cf)
        raise ValueError(
            "g_b <= g_f violated (a gated-off forward cannot have a live "
            f"backward): g_b > g_f at (sample, head) {bad[:8].tolist()}"
            f"{' ...' if len(bad) > 8 else ''}")
    for name, bound, live in (("live_fwd", live_fwd, int((cf != 0).sum())),
                              ("live_bwd", live_bwd, int((cb != 0).sum()))):
        if bound is not None and bound < live:
            raise ValueError(
                f"{name}={bound} is below the live gate count {live}: the "
                "compaction bound must be an upper bound or live slices "
                "would be silently dropped (did you forget the H//G "
                "heads-per-group scaling?)")


@functools.partial(jax.jit, static_argnames=("causal", "window", "block_q",
                                             "block_k", "interpret",
                                             "live_fwd", "live_bwd"))
def _gated_attention_impl(q, k, v, g_f, g_b, *, causal, window, block_q,
                          block_k, interpret, live_fwd, live_bwd):
    q, k, v, bq, bk, S, Sp = pad_to_blocks(q, k, v, block_q, block_k)
    out = gated_flash_attention(q, k, v, g_f, g_b, causal, window, bq, bk,
                                _auto_interpret(interpret), S, live_fwd,
                                live_bwd)
    return out[:, :, :S] if Sp != S else out


def gated_attention(q, k, v, g_f, g_b=None, *, causal: bool = True,
                    window: int = 0, block_q: int = 128, block_k: int = 128,
                    interpret: Optional[bool] = None,
                    live_fwd: Optional[int] = None,
                    live_bwd: Optional[int] = None):
    """D2FT-gated flash attention with a gate-aware backward (custom VJP).

    q, k, v: [B, H, S, hd]; g_f, g_b: [B, H] float {0,1} with g_b <= g_f
    elementwise (checked whenever the gates are concrete). g_f gates the
    forward (0 -> zeros and no forward MXU work: p_s); g_b gates the
    backward kernel (0 -> zero dq/dk/dv and no backward MXU work: p_o and
    p_s). Omitting g_b uses g_b = g_f, i.e. the fully differentiable p_f
    path (back-compat with the forward-only API).

    live_fwd / live_bwd: optional *static* upper bounds on the number of
    g_f != 0 / g_b != 0 (sample, head) slices — e.g. B*H scaled by the
    Schedule's p_f/p_o micro-batch counts (``core.schedule
    .live_slice_bounds``). When given, the kernels run on a compacted grid
    of that many slices (live slices gathered front via a stable argsort of
    the gates, results scattered back with zeros elsewhere) so gated-off
    slices pay neither grid steps nor DMA. Bounds must be >= the actual
    live counts for the gates passed; None dispatches all B*H slices.

    Sequence lengths that don't divide the tiles either shrink the tiles
    (near-divisor case) or zero-pad S (select_blocks); padded rows/tiles
    are masked via the kernels' seq_len bound and sliced off, and jnp.pad's
    VJP routes the padding out of the gradients.
    """
    if g_b is None:
        g_b = g_f
    B, H, S, _ = q.shape
    _validate_gates(g_f, g_b, B, H, live_fwd, live_bwd)
    return _gated_attention_impl(q, k, v, g_f, g_b, causal=causal,
                                 window=window, block_q=block_q,
                                 block_k=block_k, interpret=interpret,
                                 live_fwd=live_fwd, live_bwd=live_bwd)


# ------------------------------------------------------- gated SSD / RG-LRU
def _scan_pad(S: int, chunk: int):
    """(Q, Sp): chunk size actually used and the padded length. Scan-shaped
    inputs can't shrink tiles the way attention's select_blocks does (the
    chunk is the recurrence granularity), so odd lengths always zero-pad up
    to the next chunk multiple — safe because a padded row carries zero
    log-decay (identity state update) and zero input."""
    Q = min(chunk, S)
    return Q, -(-S // Q) * Q


@functools.partial(jax.jit, static_argnames=("chunk", "interpret",
                                             "live_fwd", "live_bwd"))
def _gated_ssd_impl(x, da, Bm, Cm, g_f, g_b, *, chunk, interpret, live_fwd,
                    live_bwd):
    S = x.shape[1]
    Q, Sp = _scan_pad(S, chunk)
    if Sp != S:
        pad = (0, Sp - S)
        x = jnp.pad(x, ((0, 0), pad, (0, 0), (0, 0)))
        da = jnp.pad(da, ((0, 0), pad, (0, 0)))
        Bm = jnp.pad(Bm, ((0, 0), pad, (0, 0)))
        Cm = jnp.pad(Cm, ((0, 0), pad, (0, 0)))
    y = _ssd.gated_ssd_scan(x, da, Bm, Cm, g_f, g_b, Q,
                            _auto_interpret(interpret), live_fwd, live_bwd)
    return y[:, :S] if Sp != S else y


def gated_ssd_scan(x, da, Bm, Cm, g_f, g_b=None, *, chunk: int,
                   live_fwd: Optional[int] = None,
                   live_bwd: Optional[int] = None,
                   interpret: Optional[bool] = None):
    """D2FT-gated SSD chunked scan with a gate-aware backward (custom VJP).

    x: [B,S,H,P] dt-weighted input, da: [B,S,H] per-step log-decay
    (``dt * A``), Bm/Cm: [B,S,N] (shared across heads); g_f, g_b: [B,H]
    float {0,1} with g_b <= g_f per (sample, head) — g_f == 0 heads produce
    zeros and skip the forward chunk loop (p_s), g_b == 0 heads skip every
    backward matmul and get zero dx/ddA/dB/dC (p_o and p_s). Omitting g_b
    uses g_b = g_f. live_fwd / live_bwd are static live-slice upper bounds
    enabling compaction dispatch (``core.schedule.live_slice_bounds``
    scaled by heads-per-group). S that doesn't divide the chunk is
    zero-padded (identity decay) and sliced back — the recurrent-arch
    analogue of attention's select_blocks pad path.
    """
    if g_b is None:
        g_b = g_f
    B, S, H, P = x.shape
    _validate_gates(g_f, g_b, B, H, live_fwd, live_bwd)
    return _gated_ssd_impl(x, da, Bm, Cm, g_f, g_b, chunk=chunk,
                           interpret=interpret, live_fwd=live_fwd,
                           live_bwd=live_bwd)


@functools.partial(jax.jit, static_argnames=("chunk", "interpret",
                                             "live_fwd", "live_bwd"))
def _gated_rglru_impl(la, b, g_f, g_b, *, chunk, interpret, live_fwd,
                      live_bwd):
    S = la.shape[1]
    Q, Sp = _scan_pad(S, chunk)
    if Sp != S:
        pad = ((0, 0), (0, Sp - S), (0, 0))
        la, b = jnp.pad(la, pad), jnp.pad(b, pad)
    h = _rglru.gated_rglru_scan(la, b, g_f, g_b, Q,
                                _auto_interpret(interpret), live_fwd,
                                live_bwd)
    return h[:, :S] if Sp != S else h


def gated_rglru_scan(la, b, g_f, g_b=None, *, chunk: int = 128,
                     live_fwd: Optional[int] = None,
                     live_bwd: Optional[int] = None,
                     interpret: Optional[bool] = None):
    """D2FT-gated RG-LRU scan h_t = exp(la_t) h_{t-1} + b_t (custom VJP).

    la, b: [B,S,W] (la <= 0); g_f, g_b: [B,G] float {0,1} with g_b <= g_f
    per (sample, channel-group), W % G == 0 — the W channels split into G
    contiguous bands gating independently. Returns h [B,S,W] f32 with
    g_f-dead bands exactly zero; g_b-dead bands contribute zero dla/db and
    skip every backward contraction. live_fwd / live_bwd enable compaction
    dispatch over the (B*G) slice axis. Odd S zero-pads to the chunk
    (identity decay) and slices back.
    """
    if g_b is None:
        g_b = g_f
    B, S, W = la.shape
    G = g_f.shape[1]
    if W % G != 0:
        raise ValueError(f"lru width {W} not divisible by G={G} gate groups")
    _validate_gates(g_f, g_b, B, G, live_fwd, live_bwd)
    return _gated_rglru_impl(la, b, g_f, g_b, chunk=chunk,
                             interpret=interpret, live_fwd=live_fwd,
                             live_bwd=live_bwd)


# ------------------------------------------------------------ gated MoE FFN
@functools.partial(jax.jit, static_argnames=("act", "block_c", "live_slots",
                                             "live_bwd_slots", "interpret"))
def _gated_moe_impl(xb, w_up, w_gate, w_down, fwd_slots, bwd_slots, *, act,
                    block_c, live_slots, live_bwd_slots, interpret):
    E, C, D = xb.shape
    bc = min(block_c, C)
    Cp = -(-C // bc) * bc
    n_cb = Cp // bc
    # static capacity truncation: trailing blocks beyond the schedule's
    # live-slot bound are provably empty — don't launch or stream them
    if live_slots is not None and live_slots < Cp:
        n_cb = min(n_cb, -(-max(1, int(live_slots)) // bc))
    # the backward truncates independently, on the g_b bound: the dispatch
    # packs backward-live slots into a capacity prefix per expert, so a
    # g_b < g_f mix shrinks the backward grid below the forward's
    n_cb_b = n_cb
    if live_bwd_slots is not None:
        n_cb_b = min(n_cb, -(-max(1, int(live_bwd_slots)) // bc))
    Cr = n_cb * bc
    pad = ((0, 0), (0, max(0, Cr - C)))
    xs = jnp.pad(xb, pad + ((0, 0),))[:, :Cr]
    fm = jnp.pad(fwd_slots, pad)[:, :Cr].reshape(E, n_cb, bc)
    bm = jnp.pad(bwd_slots, pad)[:, :Cr].reshape(E, n_cb, bc)
    fm = (fm.sum(-1) > 0).astype(jnp.float32)
    bm = (bm.sum(-1) > 0).astype(jnp.float32)
    y = _moe.gated_moe_ffn(xs, w_up, w_gate, w_down, fm, bm, act, bc,
                           n_cb_b, _auto_interpret(interpret))
    if Cr < C:
        y = jnp.pad(y, ((0, 0), (0, C - Cr), (0, 0)))
    return y[:, :C]


def gated_moe_ffn(xb, w_up, w_gate, w_down, fwd_slots, bwd_slots=None, *,
                  act: str = "silu", block_c: int = 128,
                  live_slots: Optional[int] = None,
                  live_bwd_slots: Optional[int] = None,
                  interpret: Optional[bool] = None):
    """Doubly-sparse MoE expert FFN over a capacity buffer (custom VJP).

    xb: [E, C, D] front-packed capacity buffer (see models/moe.py's
    gate-aware dispatch), w_up/w_gate: [E, D, F], w_down: [E, F, D];
    fwd_slots / bwd_slots: [E, C] float {0,1} slot-occupancy masks
    (bwd <= fwd elementwise — a slot's backward can't be live if its
    forward isn't). Slots are grouped into capacity blocks of ``block_c``;
    a block computes only when it holds at least one live slot
    (``@pl.when`` skip otherwise). ``live_slots`` is a static upper bound
    on live slots per expert (schedule live-sample bound x top_k): blocks
    beyond it are truncated from the grid entirely — the MoE analogue of
    compaction dispatch. ``live_bwd_slots`` bounds the *backward-live*
    slots separately (g_b bound x top_k): the dispatch packs p_f slots
    into a capacity prefix per expert, so the backward grid truncates to
    this smaller bound even when the forward must cover every p_o slot.
    Omitting it shares the forward's bound (every backward-live slot is
    forward-live, so ``live_slots`` always covers it). Omitting bwd_slots
    uses bwd = fwd.
    """
    if bwd_slots is None:
        bwd_slots = fwd_slots
    E, C, D = xb.shape
    if fwd_slots.shape != (E, C) or bwd_slots.shape != (E, C):
        raise ValueError(
            f"slot masks must be [E={E}, C={C}], got {fwd_slots.shape} / "
            f"{bwd_slots.shape}")
    cf, cb = _concrete(fwd_slots), _concrete(bwd_slots)
    if cf is not None and cb is not None:
        if np.any(cb > cf):
            raise ValueError("bwd_slots <= fwd_slots violated: a slot with "
                             "no live forward cannot have a live backward")
        if live_slots is not None:
            occupied = np.argwhere(cf != 0)
            top = int(occupied[:, 1].max()) + 1 if occupied.size else 0
            if live_slots < top:
                raise ValueError(
                    f"live_slots={live_slots} is below the highest occupied "
                    f"slot {top}: the capacity-truncation bound must cover "
                    "every live slot or their outputs would be zeroed")
        if live_bwd_slots is not None:
            occ_b = np.argwhere(cb != 0)
            top_b = int(occ_b[:, 1].max()) + 1 if occ_b.size else 0
            if live_bwd_slots < top_b:
                raise ValueError(
                    f"live_bwd_slots={live_bwd_slots} is below the highest "
                    f"occupied backward slot {top_b}: the backward "
                    "truncation bound must cover every backward-live slot "
                    "or their gradients would be zeroed")
    return _gated_moe_impl(xb, w_up, w_gate, w_down, fwd_slots, bwd_slots,
                           act=act, block_c=block_c, live_slots=live_slots,
                           live_bwd_slots=live_bwd_slots,
                           interpret=interpret)


@functools.partial(jax.jit, static_argnames=("window", "interpret"))
def _paged_decode_impl(q, k_pages, v_pages, page_table, lengths, g_f, *,
                       window, interpret):
    return paged_flash_decode(q, k_pages, v_pages, page_table, lengths, g_f,
                              window=window,
                              interpret=_auto_interpret(interpret))


def paged_decode_attention(q, k_pages, v_pages, page_table, lengths,
                           g_f=None, *, window: int = 0,
                           interpret: Optional[bool] = None):
    """Decode-mode entry to the gated attention kernel family: one token per
    sequence against a paged KV cache, pages streamed by table indirection
    from scalar prefetch (kernels/paged_decode.py).

    q: [B, H, hd] post-rope queries (position ``lengths[b]``); k_pages,
    v_pages: [n_pages, page_size, n_kv, hd] shared pools (GQA un-expanded —
    the kernel's index map resolves head groups); page_table: [B, n_pmax]
    int32, padded with the null page 0 (every entry must be a valid page id:
    index maps run before block-skip predicates); lengths: [B] int32 tokens
    already cached. g_f: optional [B, H] forward gates in {0,1} — serving is
    schedule-free so the default is all-ones; gated-off heads write zeros
    and skip the MXU like the training kernel's p_s path. Returns [B,H,hd].
    """
    B, H, hd = q.shape
    if q.shape[-1] != k_pages.shape[-1]:
        raise ValueError(f"q head_dim {hd} != pool head_dim "
                         f"{k_pages.shape[-1]}")
    if k_pages.shape != v_pages.shape:
        raise ValueError(f"k/v pool shapes differ: {k_pages.shape} vs "
                         f"{v_pages.shape}")
    if page_table.shape[0] != B or lengths.shape != (B,):
        raise ValueError(
            f"page_table/lengths batch mismatch: {page_table.shape}, "
            f"{lengths.shape}, B={B}")
    if g_f is None:
        g_f = jnp.ones((B, H), jnp.float32)
    elif g_f.shape != (B, H):
        raise ValueError(f"g_f must be [B={B}, H={H}], got {g_f.shape}")
    ct = _concrete(page_table)
    if ct is not None:
        n_pages = k_pages.shape[0]
        if ct.min() < 0 or ct.max() >= n_pages:
            raise ValueError(
                f"page_table entries must be valid page ids in [0, "
                f"{n_pages}): got range [{ct.min()}, {ct.max()}]")
    return _paged_decode_impl(q, k_pages, v_pages, page_table, lengths, g_f,
                              window=window, interpret=interpret)


@functools.partial(jax.jit, static_argnames=("scale", "block_m", "block_n",
                                             "interpret"))
def lora_linear(x, w, a, b, scale: float = 1.0, *, block_m: int = 256,
                block_n: int = 256, interpret: Optional[bool] = None):
    """Fused y = x·W + scale·(x·A)·B for 2-D or 3-D x."""
    shape = x.shape
    x2 = x.reshape(-1, shape[-1])
    y = lora_matmul(x2, w, a, b, scale, block_m=block_m, block_n=block_n,
                    interpret=_auto_interpret(interpret))
    return y.reshape(*shape[:-1], w.shape[-1])
