"""jax version compatibility for the Pallas TPU kernels."""
from jax.experimental.pallas import tpu as pltpu

# jax renamed TPUCompilerParams -> CompilerParams across releases.
CompilerParams = getattr(pltpu, "CompilerParams", None) or \
    getattr(pltpu, "TPUCompilerParams")
