"""Pallas TPU kernel: paged flash decode for the gated attention family.

Single-token decode against a *paged* KV cache (serving/pages.py): each
sequence's history lives in fixed-size pages of a shared pool
``[n_pages, page_size, n_kv, hd]``, addressed through a per-sequence page
table. The kernel streams pages HBM→VMEM **by table indirection**: the page
table and sequence lengths ride in scalar-prefetch SMEM operands
(``pltpu.PrefetchScalarGridSpec``), so the K/V BlockSpec index maps can
compute the source page id ``table[b, p]`` before each grid step's DMA —
no gathered contiguous copy of the history is ever materialized (the jnp
``jnp.take`` reference in ``serving/paged_decode.py`` is exactly that copy,
kept as the parity oracle).

Grid: ``(B, H, n_pmax)`` with the page axis innermost/sequential, carrying
the online-softmax scratch (f32 acc/m/l at block_q = 1 — one query row per
(slot, head)). Per-page block skip with ``@pl.when``:

* pages past the sequence length (``p * page_size > t``) — covers table
  padding, which points at the null page 0;
* pages wholly outside a sliding window (local-attention layers keep full
  history in pages; the window is enforced here by masking);
* gated-off heads (``g_f == 0``) — serving is schedule-free so the default
  gates are all-ones, but gate-elided adapters route through the same entry
  (mirrors the training kernel's p_s semantics: zeros written, MXU idle).

Padded table entries MUST hold a valid page id (the null page): index maps
run for every grid step regardless of ``@pl.when``, so the DMA source must
be in bounds even for skipped blocks. ``PageManager.table_array`` upholds
this.

The jit'd public wrapper with interpret auto-detection is
``repro.kernels.ops.paged_decode_attention``.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams as _CompilerParams

NEG_INF = -2.0 ** 30

# Test hook: when set to a callable, ``paged_flash_decode`` reports its
# dispatch as ``on_dispatch(grid)`` at TRACE time (set before the first
# trace; jit caches skip tracing — same caveat as d2ft_attention's hooks).
on_dispatch = None


def _paged_decode_kernel(tbl_ref, len_ref, gate_ref, q_ref, k_ref, v_ref,
                         o_ref, acc_ref, m_ref, l_ref, *, scale: float,
                         page_size: int, n_pmax: int, window: int):
    b = pl.program_id(0)
    h = pl.program_id(1)
    p = pl.program_id(2)
    t = len_ref[b]                      # query position == tokens cached
    gate = gate_ref[b, h]

    @pl.when(p == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # block-level skip: gated-off head, page past the valid length (incl.
    # null-page table padding), or page wholly left of the window
    run = jnp.logical_and(gate != 0, p * page_size <= t)
    if window and window > 0:
        run = jnp.logical_and(run, (p + 1) * page_size - 1 > t - window)

    @pl.when(run)
    def _compute():
        q = q_ref[0].astype(jnp.float32)                 # [1, hd]
        k = k_ref[0, :, 0, :].astype(jnp.float32)        # [page_size, hd]
        v = v_ref[0, :, 0, :].astype(jnp.float32)
        s = jax.lax.dot_general(q * scale, k,
                                (((1,), (1,)), ((), ())))  # [1, page_size]
        pos = p * page_size + jax.lax.broadcasted_iota(
            jnp.int32, (1, page_size), 1)
        mask = pos <= t
        if window and window > 0:
            mask = jnp.logical_and(mask, pos > t - window)
        s = jnp.where(mask, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
        pr = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + jnp.sum(pr, axis=1)
        acc_ref[...] = acc_ref[...] * corr[:, None] + \
            jax.lax.dot_general(pr, v, (((1,), (0,)), ((), ())))
        m_ref[...] = m_new

    @pl.when(p == n_pmax - 1)
    def _finalize():
        l = l_ref[...]
        safe = jnp.where(l > 0, l, 1.0)
        out = acc_ref[...] / safe[:, None]
        out = jnp.where((l > 0)[:, None], out, 0.0)
        out = out * gate.astype(jnp.float32)
        o_ref[0] = out.astype(o_ref.dtype)


def paged_flash_decode(q, k_pages, v_pages, page_table, lengths, gates, *,
                       window: int = 0, interpret: bool = False):
    """One decode step of paged attention.

    q: [B, H, hd] (post-rope query at position ``lengths[b]``);
    k_pages, v_pages: [n_pages, page_size, n_kv, hd] (this step's K/V
    already written); page_table: [B, n_pmax] int32, null-padded;
    lengths: [B] int32; gates: [B, H] float. Returns [B, H, hd].
    GQA is resolved in the index map (head h reads kv head h // (H//n_kv)),
    so the pools stay un-expanded in HBM.
    """
    B, H, hd = q.shape
    n_pages, page_size, n_kv, _ = k_pages.shape
    n_pmax = page_table.shape[1]
    assert H % n_kv == 0, (H, n_kv)
    rep = H // n_kv
    scale = 1.0 / (hd ** 0.5)

    kernel = functools.partial(_paged_decode_kernel, scale=scale,
                               page_size=page_size, n_pmax=n_pmax,
                               window=window)
    grid = (B, H, n_pmax)
    if on_dispatch is not None:
        on_dispatch(tuple(grid))

    def kv_map(b, h, p, tbl, ln, g):
        return (tbl[b, p], 0, h // rep, 0)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,          # page_table, lengths, gates
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, hd), lambda b, h, p, tbl, ln, g: (b, h, 0)),
            pl.BlockSpec((1, page_size, 1, hd), kv_map),
            pl.BlockSpec((1, page_size, 1, hd), kv_map),
        ],
        out_specs=pl.BlockSpec((1, 1, hd),
                               lambda b, h, p, tbl, ln, g: (b, h, 0)),
        scratch_shapes=[
            pltpu.VMEM((1, hd), jnp.float32),    # acc
            pltpu.VMEM((1,), jnp.float32),       # m
            pltpu.VMEM((1,), jnp.float32),       # l
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((B, H, hd), q.dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(page_table.astype(jnp.int32), lengths.astype(jnp.int32),
      gates.astype(jnp.float32), q, k_pages, v_pages)
