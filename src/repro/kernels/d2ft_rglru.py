"""Pallas TPU kernels: D2FT-gated RG-LRU recurrence, forward *and* backward.

Implements the gated block kernel contract (``repro.kernels.contract``,
docs/kernels.md) for the RecurrentGemma RG-LRU block
``h_t = a_t * h_{t-1} + b_t``. The subnet axis is the flattened
(sample, channel-group) pair: the recurrence is elementwise per channel,
so the schedule's G groups slice the ``lru_width`` into G contiguous
``Wg = W // G`` channel bands that gate independently — the same
slice-major compacted grid as the attention and SSD kernels.

The scan is chunked in log space: with ``lc = cumsum(log_a)`` inside a
chunk, ``h_q = sum_{k<=q} exp(lc_q - lc_k) b_k + exp(lc_q) * h_prev``
(every exponent <= 0 since log_a <= 0, so this is stable), and the last
row carries to the next chunk in VMEM scratch. The backward walks chunks
in reverse carrying the cotangent of the incoming state; per chunk it
needs only the inputs and the forward's *output* h (g_b = 1 implies
g_f = 1, so the gated output equals h on every backward-live slice):

    db_k   = sum_{q>=k} exp(lc_q - lc_k) dh_q
    dprev  = sum_q exp(lc_q) dh_q
    dlc_q  = dh_q * h_q - b_q * db_q          (diagonal terms cancel)
    dla    = reverse_cumsum(dlc)

``g_f == 0`` slices skip the forward body via ``@pl.when`` and write
zeros; ``g_b == 0`` slices skip every backward tensor contraction and
write zero dla/db. Compaction dispatch under static ``live_fwd`` /
``live_bwd`` bounds is shared via ``contract``. ``S % chunk != 0`` is
handled by the jit'd wrapper zero-padding (log_a = 0 is the identity
decay, b = 0 adds nothing; pad rows are sliced off and jnp.pad's VJP
drops their gradients).

The jit'd public wrapper with interpret auto-detection is
``repro.kernels.ops.gated_rglru_scan``; the pure-jnp oracle is
``repro.kernels.ref.gated_rglru_ref``.
"""
from __future__ import annotations

import functools

import numpy as np

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import contract as _contract
from repro.kernels.compat import CompilerParams as _CompilerParams

# Test hooks — same contract as d2ft_attention / d2ft_ssd.
on_backward_block = None
on_dispatch = None


def _maybe_count_block():
    if on_backward_block is not None:
        jax.debug.callback(on_backward_block)


def _report_dispatch(kind: str, grid):
    if on_dispatch is not None:
        on_dispatch(kind, tuple(grid))


def _decay_matrix(lc):
    """Lm[q, k, w] = exp(lc_q - lc_k) masked causal (diag = 1); lc [Q,Wg]."""
    Q = lc.shape[0]
    diff = lc[:, None, :] - lc[None, :, :]
    tril = jnp.tril(jnp.ones((Q, Q), jnp.bool_))[:, :, None]
    return jnp.where(tril, jnp.exp(diff), 0.0)


# ================================================================== forward
def _fwd_kernel(gate_ref, la_ref, b_ref, h_ref, carry_ref):
    j = pl.program_id(1)
    gate = gate_ref[0, 0]

    @pl.when(j == 0)
    def _init():
        carry_ref[...] = jnp.zeros_like(carry_ref)

    prev = carry_ref[...]                                   # [Wg] f32

    @pl.when(gate != 0)
    def _compute():
        la = la_ref[0].astype(jnp.float32)                  # [Q, Wg]
        b = b_ref[0].astype(jnp.float32)
        Q = la.shape[0]
        lc = jnp.cumsum(la, axis=0)
        h = jnp.sum(_decay_matrix(lc) * b[None, :, :], axis=1)
        h = h + jnp.exp(lc) * prev[None, :]
        h_ref[0] = h.astype(h_ref.dtype)
        carry_ref[...] = h[Q - 1]

    @pl.when(gate == 0)
    def _dead():
        h_ref[0] = jnp.zeros_like(h_ref[0])


def _slice_major(a, G: int):
    """[B,S,W] -> [B*G, S, Wg]: contiguous channel bands per group."""
    B, S, W = a.shape
    Wg = W // G
    return a.reshape(B, S, G, Wg).transpose(0, 2, 1, 3).reshape(B * G, S, Wg)


def _unslice(a, B: int, G: int):
    NS, S, Wg = a.shape
    return a.reshape(B, G, S, Wg).transpose(0, 2, 1, 3).reshape(B, S, G * Wg)


def _forward(la, b, g_f, *, chunk: int, interpret: bool, live=None):
    """la, b: [B,S,W]; g_f: [B,G] with W % G == 0. Returns h [B,S,W] f32
    (g_f-gated: dead channel-groups are exact zeros)."""
    B, S, W = la.shape
    G = g_f.shape[1]
    Wg = W // G
    Q = min(chunk, S)
    assert S % Q == 0, (S, Q)
    nc = S // Q
    NS = B * G
    las, bs = _slice_major(la, G), _slice_major(b, G)
    g = g_f.reshape(NS)
    n_disp = _contract.dispatch_count(live, NS)
    idx = None
    if n_disp < NS:
        idx = _contract.live_permutation(g, n_disp)
        las, bs, g = (jnp.take(a, idx, axis=0) for a in (las, bs, g))

    grid = (n_disp, nc)
    _report_dispatch("fwd", grid)
    h = pl.pallas_call(
        _fwd_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda s, j: (s, 0)),             # g_f
            pl.BlockSpec((1, Q, Wg), lambda s, j: (s, j, 0)),      # log_a
            pl.BlockSpec((1, Q, Wg), lambda s, j: (s, j, 0)),      # b
        ],
        out_specs=pl.BlockSpec((1, Q, Wg), lambda s, j: (s, j, 0)),
        out_shape=jax.ShapeDtypeStruct((n_disp, S, Wg), jnp.float32),
        scratch_shapes=[pltpu.VMEM((Wg,), jnp.float32)],           # carry
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(g.reshape(n_disp, 1), las, bs)

    if idx is not None:
        h = jnp.zeros((NS, S, Wg), h.dtype).at[idx].set(
            h, unique_indices=True)
    return _unslice(h, B, G)


# ================================================================= backward
def _bwd_kernel(gate_ref, la_ref, b_ref, h_ref, dy_ref, dla_ref, db_ref,
                dcarry_ref):
    j = pl.program_id(1)
    gate = gate_ref[0, 0]

    @pl.when(j == 0)
    def _init():
        dcarry_ref[...] = jnp.zeros_like(dcarry_ref)

    @pl.when(gate != 0)
    def _compute():
        _maybe_count_block()
        la = la_ref[0].astype(jnp.float32)                  # [Q, Wg]
        b = b_ref[0].astype(jnp.float32)
        h = h_ref[0].astype(jnp.float32)                    # fwd output
        dy = dy_ref[0].astype(jnp.float32)
        Q = la.shape[0]
        lc = jnp.cumsum(la, axis=0)
        last = (jax.lax.broadcasted_iota(jnp.int32, (Q, 1), 0) == Q - 1)
        dh = dy + jnp.where(last, dcarry_ref[...][None, :], 0.0)
        Lm = _decay_matrix(lc)
        db = jnp.sum(Lm * dh[:, None, :], axis=0)           # [Q, Wg]
        dprev = jnp.sum(jnp.exp(lc) * dh, axis=0)           # [Wg]
        dlc = dh * h - b * db
        dla = jnp.cumsum(dlc[::-1], axis=0)[::-1]           # cumsum adjoint
        dla_ref[0] = dla.astype(dla_ref.dtype)
        db_ref[0] = db.astype(db_ref.dtype)
        dcarry_ref[...] = dprev

    @pl.when(gate == 0)
    def _dead():
        dla_ref[0] = jnp.zeros_like(dla_ref[0])
        db_ref[0] = jnp.zeros_like(db_ref[0])


def _backward(la, b, g_b, h, dy, *, chunk: int, interpret: bool, live=None):
    B, S, W = la.shape
    G = g_b.shape[1]
    Wg = W // G
    Q = min(chunk, S)
    nc = S // Q
    NS = B * G
    las, bs, hs, dys = (_slice_major(a, G) for a in (la, b, h, dy))
    g = g_b.reshape(NS)
    n_disp = _contract.dispatch_count(live, NS)
    idx = None
    if n_disp < NS:
        idx = _contract.live_permutation(g, n_disp)
        las, bs, hs, dys, g = (jnp.take(a, idx, axis=0)
                               for a in (las, bs, hs, dys, g))

    rev = nc - 1
    grid = (n_disp, nc)
    _report_dispatch("bwd", grid)
    dla, db = pl.pallas_call(
        _bwd_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1), lambda s, j: (s, 0)),                # g_b
            pl.BlockSpec((1, Q, Wg), lambda s, j: (s, rev - j, 0)),   # log_a
            pl.BlockSpec((1, Q, Wg), lambda s, j: (s, rev - j, 0)),   # b
            pl.BlockSpec((1, Q, Wg), lambda s, j: (s, rev - j, 0)),   # h
            pl.BlockSpec((1, Q, Wg), lambda s, j: (s, rev - j, 0)),   # dy
        ],
        out_specs=[
            pl.BlockSpec((1, Q, Wg), lambda s, j: (s, rev - j, 0)),   # dla
            pl.BlockSpec((1, Q, Wg), lambda s, j: (s, rev - j, 0)),   # db
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_disp, S, Wg), jnp.float32),
            jax.ShapeDtypeStruct((n_disp, S, Wg), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((Wg,), jnp.float32)],            # dcarry
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(g.reshape(n_disp, 1), las, bs, hs, dys)

    if idx is not None:
        dla, db = (jnp.zeros((NS, S, Wg), a.dtype).at[idx].set(
            a, unique_indices=True) for a in (dla, db))
    return (_unslice(dla, B, G).astype(la.dtype),
            _unslice(db, B, G).astype(b.dtype))


# =============================================================== custom VJP
@functools.partial(jax.custom_vjp, nondiff_argnums=(4, 5, 6, 7))
def gated_rglru_scan(la, b, g_f, g_b, chunk, interpret, live_fwd=None,
                     live_bwd=None):
    """Differentiable gated RG-LRU scan core.

    la: [B,S,W] per-step log-decay (<= 0), b: [B,S,W] input, g_f/g_b:
    [B,G] float {0,1} with g_b <= g_f and W % G == 0. Returns h [B,S,W]
    f32, ``g_f``-gated per channel-group; the backward computes dla/db
    only where ``g_b != 0`` (gates receive zero cotangents). S must be a
    multiple of ``chunk`` (the jit'd wrapper pads). Prefer
    ``ops.gated_rglru_scan``.
    """
    return _forward(la, b, g_f, chunk=chunk, interpret=interpret,
                    live=live_fwd)


def _vjp_fwd(la, b, g_f, g_b, chunk, interpret, live_fwd=None,
             live_bwd=None):
    h = _forward(la, b, g_f, chunk=chunk, interpret=interpret,
                 live=live_fwd)
    return h, (la, b, g_f, g_b, h)


def _vjp_bwd(chunk, interpret, live_fwd, live_bwd, res, dy):
    la, b, g_f, g_b, h = res
    dla, db = _backward(la, b, g_b, h, dy, chunk=chunk, interpret=interpret,
                        live=live_bwd)
    return dla, db, jnp.zeros_like(g_f), jnp.zeros_like(g_b)


gated_rglru_scan.defvjp(_vjp_fwd, _vjp_bwd)


# ======================================================== analytic accounting
def gated_rglru_flops(g_f, g_b, S: int, Wg: int, *, chunk: int):
    """Executed FLOPs (fwd, bwd) of the kernel path under concrete gates:
    the dominant [Q,Q,Wg] intra-chunk contraction (2*Q*Q*Wg MACs) per live
    chunk — one in the forward (h_intra), one in the backward (db)."""
    Q = min(chunk, S)
    nc = -(-S // Q)
    per = 2 * Q * Q * Wg
    return (float(np.sum(np.asarray(g_f) != 0)) * nc * per,
            float(np.sum(np.asarray(g_b) != 0)) * nc * per)


def gated_rglru_dispatched_bytes(g_f, g_b, S: int, Wg: int, *, chunk: int,
                                 live_fwd: int = None, live_bwd: int = None,
                                 itemsize: int = 4):
    """(fwd_bytes, bwd_bytes) streamed per pallas_call: every block's index
    map advances each chunk step, so per dispatched slice each operand
    streams exactly once (fwd: la + b read, h written; bwd: la, b, h, dy
    read, dla + db written). Only compaction skips this traffic."""
    NS = int(np.asarray(g_f).size)
    disp_f = _contract.dispatch_count(live_fwd, NS)
    disp_b = _contract.dispatch_count(live_bwd, NS)
    return (disp_f * 3 * S * Wg * itemsize,
            disp_b * 6 * S * Wg * itemsize)
