"""The gated block kernel contract shared by every D2FT Pallas kernel.

Every gated kernel in this package — attention (`d2ft_attention`), the SSD
chunked scan (`d2ft_ssd`), the RG-LRU recurrence (`d2ft_rglru`) and MoE
expert dispatch (`d2ft_moe`) — speaks the same interface:

* **gates** — a forward gate ``g_f`` and backward gate ``g_b`` over the
  kernel's *subnet axis* (flattened (sample, head) / (sample, group) /
  token slices), float {0, 1}, with the invariant ``g_b <= g_f``
  elementwise: p_f subnets have (1, 1), p_o (1, 0), p_s (0, 0). The
  forward output is ``g_f``-gated (dead subnets produce exact zeros and
  their compute blocks are skipped with ``@pl.when``); the registered
  backward computes gradients only where ``g_b != 0`` and writes exact
  zeros elsewhere. Gates are schedule constants and receive zero
  cotangents.
* **compaction bounds** — static live-slice upper bounds (``live_fwd``,
  ``live_bwd``) derived from ``core/schedule.live_slice_bounds``: when
  given, the kernel gathers live slices to the front via a stable argsort
  permutation of the gates (``live_permutation``) and launches a grid
  whose leading dim is ``dispatch_count(live, N)`` instead of N, then
  scatters results back with zeros elsewhere. Dead slices beyond the
  bound cost neither grid steps nor HBM->VMEM DMA.
* **dispatch hook** — each kernel module exposes ``on_dispatch(kind,
  grid)`` fired at trace time for every pallas_call it builds, and
  ``on_backward_block()`` fired (via jax.debug.callback) once per
  *executed* backward compute block. Tests assert executed work matches
  the schedule bounds exactly.
* **FLOP / DMA byte model** — each kernel module exports
  ``gated_*_flops(g_f, g_b, ...)`` and ``gated_*_dispatched_bytes(...)``
  mirroring its own grid, skip predicate and BlockSpec streams, since
  static HLO FLOP counts cannot see ``@pl.when`` skips in interpret mode.

``models/transformer.py`` routes every block type through a kernel
implementing this contract when ``use_kernel=True``; any route that falls
back to the dense stop-gradient mix reports itself through ``on_fallback``
so the config-zoo test can fail loudly instead of silently testing the
wrong path. See docs/kernels.md.
"""
from __future__ import annotations

import jax.numpy as jnp

# Hook: when set to a callable, every place that takes a non-kernel route
# despite use_kernel=True calls ``on_fallback(kind, reason)`` with kind the
# block type ("attn", "ssd", "rglru", "moe", ...). tests/test_config_zoo.py
# uses it to assert every block type in every config hits a real kernel.
on_fallback = None


def report_fallback(kind: str, reason: str):
    if on_fallback is not None:
        on_fallback(kind, reason)


def dispatch_count(live, N: int) -> int:
    """Static number of slices to launch: the live-count upper bound clamped
    to [1, N]; None disables compaction (dispatch all N slices)."""
    if live is None or live >= N:
        return N
    return max(1, int(live))


def live_permutation(gate_flat, n_dispatch: int):
    """First ``n_dispatch`` entries of the stable permutation that sorts
    live (gate != 0) slices to the front, preserving original order within
    each class. jit-compatible: the *values* are traced, the *size* is the
    static schedule-derived bound — any dead slices padding the tail carry
    gate 0 and are skipped block-level inside the kernels."""
    dead = (gate_flat == 0).astype(jnp.int32)
    return jnp.argsort(dead, stable=True)[:n_dispatch]
