"""Pallas TPU kernel: fused LoRA matmul  y = x·W + s·(x·A)·B.

D2FT-LoRA keeps the frozen QKV weight and its low-rank adapter co-located on
the subnet's device (paper §II-D); this kernel fuses the adapter branch into
the frozen matmul so the [M, r] intermediate never round-trips HBM.

Tiling: grid over (M/bm, N/bn); each step loads a full-K stripe of x
[bm, K] and W [K, bn] into VMEM plus the whole adapter (A [K, r], B [r,bn]),
computes base and low-rank contribution on the MXU and writes one output
tile. K stripes are fine for fine-tuning-scale d_model (K·(bm+bn)·2 bytes
must fit VMEM — checked in the wrapper).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.compat import CompilerParams as _CompilerParams


def _kernel(x_ref, w_ref, a_ref, b_ref, o_ref, *, scale: float):
    x = x_ref[...]
    base = jax.lax.dot_general(x, w_ref[...], (((1,), (0,)), ((), ())),
                               preferred_element_type=jnp.float32)
    u = jax.lax.dot_general(x, a_ref[...], (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    delta = jax.lax.dot_general(u.astype(x.dtype), b_ref[...],
                                (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
    o_ref[...] = (base + scale * delta).astype(o_ref.dtype)


def lora_matmul(x, w, a, b, scale: float = 1.0, *, block_m: int = 256,
                block_n: int = 256, interpret: bool = False):
    """x: [M, K]; w: [K, N]; a: [K, r]; b: [r, N]. Returns [M, N]."""
    M, K = x.shape
    _, N = w.shape
    r = a.shape[1]
    block_m = min(block_m, M)
    block_n = min(block_n, N)
    assert M % block_m == 0 and N % block_n == 0, (M, N, block_m, block_n)
    # VMEM budget check (bf16/f32): x stripe + w stripe + A + B + out tile
    vmem = (block_m * K + K * block_n + K * r + r * block_n +
            block_m * block_n) * x.dtype.itemsize
    assert vmem < 100 * 2 ** 20, f"tile working set {vmem} too large"

    return pl.pallas_call(
        functools.partial(_kernel, scale=scale),
        grid=(M // block_m, N // block_n),
        in_specs=[
            pl.BlockSpec((block_m, K), lambda i, j: (i, 0)),
            pl.BlockSpec((K, block_n), lambda i, j: (0, j)),
            pl.BlockSpec((K, r), lambda i, j: (0, 0)),
            pl.BlockSpec((r, block_n), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((M, N), x.dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel")),
        interpret=interpret,
    )(x, w, a, b)
