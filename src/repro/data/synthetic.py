"""Synthetic datasets + micro-batching pipeline.

The container is offline, so CIFAR-10/100 and Stanford Cars are replaced by
a *learnable* synthetic image classification task: each class has a random
smooth template; samples are template + noise. A model fine-tuned from a
"pretrained" checkpoint (pretrained on a superset task) shows the same
qualitative orderings the paper reports (D2FT > Random > pruning at equal
budget) because the task actually requires the attention stack.

Text pipelines emit token streams with Markov structure so next-token loss
is reducible (not uniform noise).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Iterator, Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp


@dataclass
class ImageTask:
    n_classes: int
    image_size: int
    templates: np.ndarray          # [C, H, W, 3]
    noise: float

    def sample(self, rng: np.random.Generator, n: int):
        labels = rng.integers(0, self.n_classes, n)
        x = self.templates[labels] + rng.normal(0, self.noise,
                                                (n, self.image_size,
                                                 self.image_size, 3))
        return x.astype(np.float32), labels.astype(np.int32)


def make_image_task(seed: int, n_classes: int = 10, image_size: int = 32,
                    noise: float = 0.35, smooth: int = 4) -> ImageTask:
    rng = np.random.default_rng(seed)
    raw = rng.normal(0, 1, (n_classes, image_size // smooth,
                            image_size // smooth, 3))
    tpl = np.repeat(np.repeat(raw, smooth, 1), smooth, 2)
    return ImageTask(n_classes, image_size, tpl.astype(np.float32), noise)


def image_batches(task: ImageTask, seed: int, batch: int, steps: int
                  ) -> Iterator[Tuple[np.ndarray, np.ndarray]]:
    rng = np.random.default_rng(seed)
    for _ in range(steps):
        yield task.sample(rng, batch)


# ------------------------------------------------------------------- text
def markov_tokens(rng: np.random.Generator, pref: np.ndarray, vocab: int,
                  batch: int, seq: int,
                  order_bias: float = 6.0) -> np.ndarray:
    """Token batch from a FIXED sparse Markov chain ``pref`` (the chain must
    stay constant across batches or there is nothing to learn)."""
    toks = np.empty((batch, seq), np.int32)
    toks[:, 0] = rng.integers(0, vocab, batch)
    for t in range(1, seq):
        follow = rng.random(batch) < (order_bias / (order_bias + 1))
        toks[:, t] = np.where(follow, pref[toks[:, t - 1]],
                              rng.integers(0, vocab, batch))
    return toks


def lm_batches(seed: int, vocab: int, batch: int, seq: int, steps: int):
    rng = np.random.default_rng(seed)
    pref = rng.integers(0, vocab, vocab)        # the learnable structure
    for _ in range(steps):
        toks = markov_tokens(rng, pref, vocab, batch, seq + 1)
        yield {"tokens": toks[:, :-1], "labels": toks[:, 1:]}


# ------------------------------------------------------------- microbatching
def microbatch_assignment(batch: int, n_microbatches: int) -> np.ndarray:
    """[B] micro-batch id per sample (contiguous split, paper §III-A)."""
    assert batch % n_microbatches == 0, (batch, n_microbatches)
    return np.repeat(np.arange(n_microbatches), batch // n_microbatches)


def split_microbatches(arrays, n_microbatches: int):
    """Split leading batch dim of a pytree into a list of micro-batches."""
    def get(i):
        return jax.tree.map(
            lambda a: a[i * (a.shape[0] // n_microbatches):
                        (i + 1) * (a.shape[0] // n_microbatches)], arrays)
    return [get(i) for i in range(n_microbatches)]
