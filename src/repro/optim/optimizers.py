"""Minimal pure-JAX optimizer library (no optax dependency).

The paper fine-tunes with SGD + momentum (§IV-A); AdamW is provided for the
LLM fine-tuning paths. Optimizer states are pytrees shaped like params and
``update`` is leaf-wise, so the same Optimizer runs replicated, on ZeRO-1
moment shards, or fully shard-resident under ZeRO-3 (grads, moments and
params all at shard shape — sharding/sync.py / train/loop.py).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, NamedTuple, Tuple

import jax
import jax.numpy as jnp


class Optimizer(NamedTuple):
    init: Callable[[Any], Any]
    update: Callable[[Any, Any, Any], Tuple[Any, Any]]  # (grads, state, params)
    # True when a parameter shard with zero gradient AND zero moments gets
    # an exactly-identity update (no weight decay): the ZeRO-1 sync may
    # then elide the param all-gather for runs that have been backward-dead
    # since their moments were last zero (sharding/sync.py zero mode).
    # ZeRO-3 does NOT need this: its owned shards are always updated (decay
    # included) and its gather elision is a forward-liveness question only.
    elidable: bool = True
    # params-shaped moment copies in the state (sgd: mu; adamw: m and v) —
    # what the ZeRO memory accounting (zero_state_byte_report) multiplies
    # by, instead of every call site hard-coding the optimizer family.
    n_moments: int = 1


def sgd(lr: float, momentum: float = 0.9, weight_decay: float = 0.0,
        nesterov: bool = False) -> Optimizer:
    def init(params):
        return {"mu": jax.tree.map(jnp.zeros_like, params),
                "step": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        if weight_decay:
            grads = jax.tree.map(lambda g, p: g + weight_decay * p, grads, params)
        mu = jax.tree.map(lambda m, g: momentum * m + g, state["mu"], grads)
        if nesterov:
            upd = jax.tree.map(lambda m, g: momentum * m + g, mu, grads)
        else:
            upd = mu
        new_params = jax.tree.map(lambda p, u: p - lr * u, params, upd)
        return new_params, {"mu": mu, "step": state["step"] + 1}

    return Optimizer(init, update, elidable=weight_decay == 0.0,
                     n_moments=1)


def adamw(lr: float, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
          weight_decay: float = 0.01) -> Optimizer:
    def init(params):
        return {"m": jax.tree.map(jnp.zeros_like, params),
                "v": jax.tree.map(jnp.zeros_like, params),
                "step": jnp.zeros((), jnp.int32)}

    def update(grads, state, params):
        step = state["step"] + 1
        m = jax.tree.map(lambda m_, g: b1 * m_ + (1 - b1) * g, state["m"], grads)
        v = jax.tree.map(lambda v_, g: b2 * v_ + (1 - b2) * g * g, state["v"], grads)
        bc1 = 1 - b1 ** step.astype(jnp.float32)
        bc2 = 1 - b2 ** step.astype(jnp.float32)

        def upd(p, m_, v_):
            mh = m_ / bc1
            vh = v_ / bc2
            return p - lr * (mh / (jnp.sqrt(vh) + eps) + weight_decay * p)

        new_params = jax.tree.map(upd, params, m, v)
        return new_params, {"m": m, "v": v, "step": step}

    return Optimizer(init, update, elidable=weight_decay == 0.0,
                     n_moments=2)


def chunked(opt: Optimizer, chunk: int) -> Optimizer:
    """Stream ``opt``'s update chunk-by-chunk (ChunkFT style).

    The update of every optimizer here is leafwise *and* elementwise, so
    each leaf (and its params-shaped moment entries) can be flattened,
    zero-padded to a chunk multiple and updated one ``chunk``-sized slice
    at a time under ``jax.lax.map`` — the live working set of the update
    is O(chunk) instead of O(leaf), which is what makes the ZeRO-3
    shard-resident update byte-streamable. Every chunk sees the *same*
    input ``step`` (bias correction matches the whole-shard update) and
    the step counter advances once per call, so results are bit-identical
    to ``opt.update`` — a hypothesis property pins that for sgd and adamw.
    Zero padding is benign: an elementwise update of (g=0, p=0, m=0) is 0
    and the padded tail is discarded anyway.
    """
    assert chunk >= 1, chunk

    def update(grads, state, params):
        moment_keys = [k for k in state if k != "step"]
        step = state["step"]
        g_leaves, treedef = jax.tree.flatten(grads)
        p_leaves = treedef.flatten_up_to(params)
        m_leaves = {k: treedef.flatten_up_to(state[k]) for k in moment_keys}
        new_p, new_m = [], {k: [] for k in moment_keys}
        for i, (g, p) in enumerate(zip(g_leaves, p_leaves)):
            n, shape = g.size, g.shape
            pad = (-n) % chunk

            def flat(x):
                return jnp.pad(x.reshape(-1), (0, pad)).reshape(-1, chunk)

            def body(sl):
                g_c, p_c, *m_c = sl
                st = {"step": step, **dict(zip(moment_keys, m_c))}
                p_new, st_new = opt.update(g_c, st, p_c)
                return (p_new, *[st_new[k] for k in moment_keys])

            out = jax.lax.map(body, (flat(g), flat(p),
                                     *[flat(m_leaves[k][i])
                                       for k in moment_keys]))

            def unflat(x):
                return x.reshape(-1)[:n].reshape(shape)

            new_p.append(unflat(out[0]))
            for k, m in zip(moment_keys, out[1:]):
                new_m[k].append(unflat(m))
        new_state = {k: jax.tree.unflatten(treedef, new_m[k])
                     for k in moment_keys}
        new_state["step"] = step + 1
        return jax.tree.unflatten(treedef, new_p), new_state

    return Optimizer(opt.init, update, elidable=opt.elidable,
                     n_moments=opt.n_moments)


def clip_scale(norm, max_norm: float):
    """Global-norm clip factor — shared by clip_by_global_norm and the
    distributed ZeRO step (which computes the norm itself, via a scalar
    psum over grad shards)."""
    return jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))


def clip_by_global_norm(grads, max_norm: float):
    leaves = jax.tree.leaves(grads)
    norm = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2) for g in leaves))
    scale = clip_scale(norm, max_norm)
    return jax.tree.map(lambda g: g * scale, grads), norm
