"""Schedule-masked gradient synchronization for the distributed D2FT step.

In data-parallel D2FT every device computes gradients only for its own
micro-batches, but the masked/kernel gated paths guarantee something
stronger: a (layer, head-group) subnet with **no p_f micro-batch anywhere
in the schedule** has *identically zero* gradient on every device — p_o
contributions are ``stop_gradient``-ed and p_s contributions are zeroed
before they enter the residual stream. All-reducing those zeros is pure
waste, and on commodity interconnects gradient all-reduce is the binding
constraint of distributed fine-tuning. This module turns the host-side
schedule table into a per-leaf *sync plan* that the shard_map train step
applies instead of a blanket ``pmean``:

* ``all``     — live backward somewhere in the leaf: full pmean.
* ``none``    — no live backward in any covered subnet: the psum is elided
                (every device already holds the exact, zero, global grad).
* ``sliced``  — the leaf has head-group structure along one axis (wq/wo
                columns/rows, gated-FFN up/down blocks): only the live
                groups' contiguous slices are pmean'd; dead slices ride
                along untouched. This is the fine granularity that makes
                the skip worth bytes even when a few groups stay live.
* ``stacked`` — scan-stacked ``cycles`` leaves carry one layer per leading
                index; each gets its own per-cycle spec.

Safety rails (always ``all``): embeddings / unembeddings / final norm
(grads flow through every sample), MoE subtrees and their ``norm2`` (the
router aux losses are *not* gated — they produce gradients regardless of
the schedule), and any leaf whose group axis is not divisible by G.

``sync_byte_report`` prices the plan (live vs total all-reduce bytes) so
the dry-run and the ``distributed_step`` bench can report the comm saving
without parsing HLO, and the HLO-parsed numbers can be cross-checked
against it.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Tuple

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.schedule import P_F, Schedule


def backward_live_groups(sched: Schedule) -> np.ndarray:
    """[L, G] bool — subnet (l, g) has a live backward (any p_f micro-batch).

    Note this is *schedule*-global, not per-device: a subnet live on any
    device needs the all-reduce on every device (SPMD runs one program)."""
    return (sched.layer_group_view() == P_F).any(axis=-1)


@dataclass(frozen=True)
class SyncSpec:
    """Per-leaf gradient synchronization recipe (see module docstring)."""
    mode: str                                  # all | none | sliced | stacked
    axis: int = 0                              # sliced: group-block axis
    live: Tuple[bool, ...] = ()                # sliced: per-group liveness
    per_cycle: Tuple["SyncSpec", ...] = field(default=())   # stacked


_ALL = SyncSpec("all")
_NONE = SyncSpec("none")

# Leaf name -> axis holding the G contiguous head-group blocks. Matches the
# group decomposition of the masked path (models/transformer.py
# _group_project / _apply_ffn) and the packed path's _slice_cols/_slice_rows.
_Q_AXIS = {"wq": 1, "bq": 0, "wo": 0}
_KV_AXIS = {"wk": 1, "bk": 0, "wv": 1, "bv": 0}
_FFN_AXIS = {"w_up": 1, "w_gate": 1, "w_down": 0}


def _sliceable_axis(name: str, shape: Tuple[int, ...], cfg: ModelConfig,
                    G: int):
    """Axis of the G group blocks in this leaf, or None (coarse leaf)."""
    axis = None
    if name in _Q_AXIS:
        axis = _Q_AXIS[name]
    elif name in _KV_AXIS:
        # KV columns align with query groups only when every group owns a
        # whole number of kv heads; shared kv heads (G % n_kv != 0) receive
        # gradients from several groups -> coarse.
        if cfg.n_kv_heads % G == 0:
            axis = _KV_AXIS[name]
    elif name in _FFN_AXIS and len(shape) == 2:
        axis = _FFN_AXIS[name]
    if axis is None or shape[axis] % G != 0:
        return None
    return axis


def _leaf_spec(name: str, shape: Tuple[int, ...], live_g: np.ndarray,
               cfg: ModelConfig, protected: bool) -> SyncSpec:
    """Spec for one unstacked block leaf given its layer's [G] liveness."""
    if protected:
        return _ALL
    if live_g.all():
        return _ALL
    if not live_g.any():
        return _NONE
    axis = _sliceable_axis(name, shape, cfg, len(live_g))
    if axis is None:
        return _ALL          # partially live, not group-sliceable
    return SyncSpec("sliced", axis=axis, live=tuple(bool(x) for x in live_g))


def _block_plan(block, live_g: np.ndarray, cfg: ModelConfig,
                stack: int = 0):
    """Plan for one block's param subtree. ``stack`` > 0 marks scan-stacked
    leaves whose leading dim holds one layer per index; ``live_g`` is then
    [stack, G] instead of [G]."""
    has_moe = isinstance(block, dict) and "moe" in block

    def rec(tree, name, protected):
        if isinstance(tree, dict):
            return {k: rec(v, k, protected or k == "moe") for k, v in
                    tree.items()}
        if isinstance(tree, (list, tuple)):
            return [rec(v, name, protected) for v in tree]
        # MoE router aux losses are computed from norm2(x) regardless of
        # gating, so the whole FFN side of an MoE block keeps full sync.
        prot = protected or (has_moe and name == "norm2")
        if stack == 0:
            return _leaf_spec(name, tree.shape, live_g, cfg, prot)
        per_cycle = tuple(_leaf_spec(name, tree.shape[1:], live_g[c], cfg,
                                     prot) for c in range(stack))
        if all(s == per_cycle[0] for s in per_cycle):
            s = per_cycle[0]
            if s.mode in ("all", "none"):
                return s
            # identical slice pattern in every cycle: slice the stacked
            # leaf directly (group axis shifts past the stack dim)
            return SyncSpec("sliced", axis=s.axis + 1, live=s.live)
        return SyncSpec("stacked", per_cycle=per_cycle)

    return rec(block, None, False)


def _fill(tree, spec: SyncSpec):
    if isinstance(tree, dict):
        return {k: _fill(v, spec) for k, v in tree.items()}
    if isinstance(tree, (list, tuple)):
        return [_fill(v, spec) for v in tree]
    return spec


def grad_sync_plan(params, cfg: ModelConfig, sched: Schedule):
    """Mirror of the params tree with a SyncSpec at every leaf.

    Static and host-side (numpy over the schedule table, shapes from the
    params/eval_shape tree) — baked into the jitted distributed step, so a
    new schedule means a new plan and a re-jit, exactly like the compaction
    bounds."""
    from repro.models.transformer import layer_groups
    live = backward_live_groups(sched)                       # [L, G]
    n_cycles, pat, rem = layer_groups(cfg)
    P = len(pat)
    assert live.shape[0] == cfg.n_layers, (live.shape, cfg.n_layers)
    plan = {}
    for key, sub in params.items():
        if key == "cycles":
            # sub[i]: block at pattern position i, leaves [n_cycles, ...];
            # cycle c holds layer c * P + i
            plan[key] = [
                _block_plan(sub[i],
                            live[[c * P + i for c in range(n_cycles)]],
                            cfg, stack=n_cycles)
                for i in range(P)]
        elif key == "rest":
            plan[key] = [_block_plan(sub[i], live[n_cycles * P + i], cfg)
                         for i in range(len(sub))]
        else:
            # embed / unembed / final_norm / frontend_proj: gradients flow
            # through every sample's loss path — never skip.
            plan[key] = _fill(sub, _ALL)
    return plan


# ------------------------------------------------------------- application
def _runs(live: Tuple[bool, ...]):
    """Merge consecutive equal-liveness groups into (live, start, stop)."""
    out = []
    start = 0
    for g in range(1, len(live) + 1):
        if g == len(live) or live[g] != live[start]:
            out.append((live[start], start, g))
            start = g
    return out


def _sync_leaf(g, spec: SyncSpec, axis_name: str):
    if spec.mode == "none":
        return g
    if spec.mode == "all":
        return jax.lax.pmean(g, axis_name)
    if spec.mode == "stacked":
        return jnp.stack([_sync_leaf(g[c], s, axis_name)
                          for c, s in enumerate(spec.per_cycle)])
    blocks = len(spec.live)
    size = g.shape[spec.axis] // blocks
    parts = []
    for is_live, start, stop in _runs(spec.live):
        seg = jax.lax.slice_in_dim(g, start * size, stop * size,
                                   axis=spec.axis)
        parts.append(jax.lax.pmean(seg, axis_name) if is_live else seg)
    return jnp.concatenate(parts, axis=spec.axis) if len(parts) > 1 \
        else parts[0]


def apply_grad_sync(grads, plan, axis_name: str):
    """Masked pmean: all-reduce exactly the live slices of the grads tree.

    Must run inside shard_map over ``axis_name``. Skipped leaves/slices are
    identically zero on every device (see module docstring), so eliding
    their psum leaves them — correctly — at the global value."""
    if isinstance(plan, SyncSpec):
        return _sync_leaf(grads, plan, axis_name)
    if isinstance(plan, dict):
        return {k: apply_grad_sync(grads[k], plan[k], axis_name)
                for k in grads}
    return [apply_grad_sync(g, p, axis_name) for g, p in zip(grads, plan)]


# --------------------------------------------------------------- accounting
def _live_fraction(spec: SyncSpec) -> float:
    if spec.mode == "all":
        return 1.0
    if spec.mode == "none":
        return 0.0
    if spec.mode == "stacked":
        return float(np.mean([_live_fraction(s) for s in spec.per_cycle]))
    return float(sum(spec.live)) / len(spec.live)


def sync_byte_report(plan, params) -> dict:
    """Price the plan: bytes entering the gradient all-reduce vs a full
    pmean of every leaf. Works on concrete arrays or ShapeDtypeStructs."""
    totals = {"total_bytes": 0.0, "synced_bytes": 0.0, "n_leaves": 0,
              "n_skipped": 0, "n_sliced": 0}

    def rec(p, spec):
        if isinstance(spec, SyncSpec):
            size = float(np.prod(p.shape)) * np.dtype(p.dtype).itemsize
            totals["total_bytes"] += size
            totals["synced_bytes"] += size * _live_fraction(spec)
            totals["n_leaves"] += 1
            if spec.mode == "none":
                totals["n_skipped"] += 1
            elif spec.mode in ("sliced", "stacked"):
                totals["n_sliced"] += 1
            return
        if isinstance(spec, dict):
            for k in spec:
                rec(p[k], spec[k])
        else:
            for pi, si in zip(p, spec):
                rec(pi, si)

    rec(params, plan)
    totals["fraction"] = (totals["synced_bytes"] / totals["total_bytes"]
                          if totals["total_bytes"] else 1.0)
    return totals
