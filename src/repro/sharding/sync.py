"""Schedule-masked gradient synchronization for the distributed D2FT step.

In data-parallel D2FT every device computes gradients only for its own
micro-batches, but the masked/kernel gated paths guarantee something
stronger: a (layer, head-group) subnet with **no p_f micro-batch anywhere
in the schedule** has *identically zero* gradient on every device — p_o
contributions are ``stop_gradient``-ed and p_s contributions are zeroed
before they enter the residual stream. All-reducing those zeros is pure
waste, and on commodity interconnects gradient all-reduce is the binding
constraint of distributed fine-tuning. This module turns the host-side
schedule table into a per-leaf *sync plan* that the shard_map train step
applies instead of a blanket ``pmean``:

* ``all``     — live backward somewhere in the leaf: full pmean.
* ``none``    — no live backward in any covered subnet: the psum is elided
                (every device already holds the exact, zero, global grad).
* ``sliced``  — the leaf has head-group structure along one axis (wq/wo
                columns/rows, gated-FFN up/down blocks): only the live
                groups' contiguous slices are pmean'd; dead slices ride
                along untouched. This is the fine granularity that makes
                the skip worth bytes even when a few groups stay live.
* ``stacked`` — scan-stacked ``cycles`` leaves carry one layer per leading
                index; each gets its own per-cycle spec.

Safety rails (always ``all``): embeddings / unembeddings / final norm
(grads flow through every sample), MoE subtrees and their ``norm2`` (the
router aux losses are *not* gated — they produce gradients regardless of
the schedule), and any leaf whose group axis is not divisible by G.

``sync_byte_report`` prices the plan (live vs total all-reduce bytes) so
the dry-run and the ``distributed_step`` bench can report the comm saving
without parsing HLO, and the HLO-parsed numbers can be cross-checked
against it.

ZeRO-1 form (``mode="zero"``)
-----------------------------
``grad_sync_plan(..., mode="zero", n_shards=k)`` replaces the masked pmean
with a partitioned sync: every leaf that can be evenly split over the data
mesh gets a ``zero`` spec that

* **reduce-scatters** only the runs of backward-live groups (dead runs are
  locally sliced — their gradient is identically zero on every device, so
  the device's own sub-chunk already holds the global value);
* hands each device one owned shard per leaf (for each (live, gather) run,
  device d owns the d-th sub-chunk of the run), on which the optimizer
  moments live and the update executes — per-device moment memory drops to
  ~1/k of the replicated baseline;
* **all-gathers** only the runs whose parameters can have changed: the
  backward-live runs plus any run that was ever live since the moments
  were last zero (``ever_live``). A dead run with zero moments has an
  identity update under an *elidable* optimizer (zero weight decay — see
  ``Optimizer.elidable``), so its params are still replicated-correct
  without the gather. Non-elidable optimizers force a full gather mask.

Wire-byte accounting is honest about the physics: reduce-scatter +
all-gather of a live run costs exactly what a ring all-reduce of the same
run costs (2·(k-1)/k bytes per element), so the zero mode *matches* the
masked psum's bytes at equal masks — its wins are the sharded optimizer
state, shard-sized update FLOPs, and that partially-live leaves never pay
a full-tensor collective even where whole-subnet elision can't fire.
Leaves with no evenly divisible axis fall back to their masked spec (and
keep replicated moments); ``sync_byte_report(..., n_shards=k)`` prices
both collectives separately plus the ring-wire total, and
``zero_state_byte_report`` prices the per-device moment memory.

ZeRO-3 form (``mode="zero3"``)
------------------------------
``grad_sync_plan(..., mode="zero3", n_shards=k)`` keeps the same run
partition but makes the *shards* the persistent parameter state: no device
holds a full replica between steps. Inside the step, full-leaf views exist
only transiently (``zero3_materialize``) and the gather mask becomes a
**forward** question — a (layer, head-group) run that is p_s on every
micro-batch of the schedule contributes *exactly zero* to the residual
stream (``gate_mix`` multiplies the group contribution by g_f == 0), so
its parameters are never all-gathered at all: a zeros view is
bit-identical, for the forward and (trivially) the backward. Runs with any
p_f or p_o cell are gathered; protected leaves gather densely. Gradients
of backward-live runs reduce-scatter straight onto the owning shard
(the ZeRO-2 half), each device updates only its owned slice, and there is
no post-update gather — next step's materialization starts from the
updated shards. Unlike the ZeRO-1 gather elision this needs neither
``Optimizer.elidable`` nor ``ever_live`` bookkeeping: the shard is the
source of truth and is always updated, weight decay included.

``zero3_param_byte_report`` prices the residency-window memory model:
persistent bytes (owned shards + replicated fallback leaves) plus the
largest transiently materialized unit (one transformer block, or one
loss-path subtree) under the gather mask.

Streamed execution
------------------
``zero3_stream_materialize`` is the execution the residency model prices:
each residency unit (one cycle of one pattern position, one ``rest``
block, one loss-path subtree) is materialized by its own per-unit
all-gathers, and every leaf's reduce-scatter is fused into the
materialization's ``custom_vjp`` — the scatter is emitted exactly where
the backward releases that unit's gradient instead of in a serialized
post-backward pass. Values and wire bytes are identical to
``zero3_materialize`` + ``apply_zero_scatter`` (same collectives on the
same operands, split per unit); what changes is the *schedule*: XLA is
free to overlap unit i+1's gathers with unit i's compute and to free each
unit's views as its backward retires. A ``ResidencyRecorder`` passed to
the streamed materializer counts the gathered bytes of every unit at
trace time, and ``check_zero3_residency`` fails if that measurement
disagrees with ``zero3_param_byte_report``'s model beyond tolerance —
``per_device_peak_bytes`` is a measurement, not a promise.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.schedule import P_F, P_S, Schedule


def backward_live_groups(sched: Schedule) -> np.ndarray:
    """[L, G] bool — subnet (l, g) has a live backward (any p_f micro-batch).

    Note this is *schedule*-global, not per-device: a subnet live on any
    device needs the all-reduce on every device (SPMD runs one program)."""
    return (sched.layer_group_view() == P_F).any(axis=-1)


def forward_live_groups(sched: Schedule) -> np.ndarray:
    """[L, G] bool — subnet (l, g) has a live forward (any non-p_s cell).

    The complement is the ZeRO-3 gather-elision set: a subnet that is p_s
    on every micro-batch contributes exactly zero to the residual stream
    whatever its parameter values (``gate_mix`` multiplies by g_f == 0),
    so no device needs its params materialized that step. Superset of
    ``backward_live_groups`` (p_f cells are forward-live too), which keeps
    the zero-mode invariant gather ⊇ scatter intact."""
    return (sched.layer_group_view() != P_S).any(axis=-1)


@dataclass(frozen=True)
class SyncSpec:
    """Per-leaf gradient synchronization recipe (see module docstring)."""
    mode: str              # all | none | sliced | stacked | zero | zero_stacked
    axis: int = 0                              # sliced/zero: partition axis
    live: Tuple[bool, ...] = ()                # per-group backward liveness
    per_cycle: Tuple["SyncSpec", ...] = field(default=())   # (zero_)stacked
    gather: Tuple[bool, ...] = ()              # zero: param all-gather mask
    shards: int = 0                            # zero: data-mesh size k


_ALL = SyncSpec("all")
_NONE = SyncSpec("none")

# Leaf name -> axis holding the G contiguous head-group blocks. Matches the
# group decomposition of the masked path (models/transformer.py
# _group_project / _apply_ffn) and the packed path's _slice_cols/_slice_rows.
_Q_AXIS = {"wq": 1, "bq": 0, "wo": 0}
_KV_AXIS = {"wk": 1, "bk": 0, "wv": 1, "bv": 0}
_FFN_AXIS = {"w_up": 1, "w_gate": 1, "w_down": 0}


def _sliceable_axis(name: str, shape: Tuple[int, ...], cfg: ModelConfig,
                    G: int):
    """Axis of the G group blocks in this leaf, or None (coarse leaf)."""
    axis = None
    if name in _Q_AXIS:
        axis = _Q_AXIS[name]
    elif name in _KV_AXIS:
        # KV columns align with query groups only when every group owns a
        # whole number of kv heads; shared kv heads (G % n_kv != 0) receive
        # gradients from several groups -> coarse.
        if cfg.n_kv_heads % G == 0:
            axis = _KV_AXIS[name]
    elif name in _FFN_AXIS and len(shape) == 2:
        axis = _FFN_AXIS[name]
    if axis is None or shape[axis] % G != 0:
        return None
    return axis


def _leaf_spec(name: str, shape: Tuple[int, ...], live_g: np.ndarray,
               cfg: ModelConfig, protected: bool) -> SyncSpec:
    """Spec for one unstacked block leaf given its layer's [G] liveness."""
    if protected:
        return _ALL
    if live_g.all():
        return _ALL
    if not live_g.any():
        return _NONE
    axis = _sliceable_axis(name, shape, cfg, len(live_g))
    if axis is None:
        return _ALL          # partially live, not group-sliceable
    return SyncSpec("sliced", axis=axis, live=tuple(bool(x) for x in live_g))


def _zero_axis(name: str, shape: Tuple[int, ...], cfg: ModelConfig, G: int,
               k: int):
    """(partition axis, groups along it) for a zero leaf, or None.

    Group-sliceable leaves keep mask granularity G when every group block
    splits evenly over the k shards; otherwise the leaf is partitioned
    coarse (one run spanning the largest evenly divisible axis)."""
    axis = _sliceable_axis(name, shape, cfg, G)
    if axis is not None and (shape[axis] // G) % k == 0:
        return axis, G
    divisible = [a for a in range(len(shape)) if shape[a] % k == 0]
    if not divisible:
        return None
    return max(divisible, key=lambda a: shape[a]), 1


def _zero_leaf_spec(name: str, shape: Tuple[int, ...], live_g: np.ndarray,
                    ever_g: np.ndarray, fwd_g: np.ndarray, cfg: ModelConfig,
                    protected: bool, k: int, elide_gather: bool,
                    zero3: bool) -> SyncSpec:
    """Zero-mode spec for one unstacked leaf: partition + (live, gather)
    masks; falls back to the masked spec when no axis splits evenly.

    zero3=True switches the gather mask to forward liveness: the full view
    is rebuilt from the owned shards every step, so the only question is
    whether any consumer of a run's params survives the forward gates —
    staleness (``ever_g``) and optimizer elidability cannot arise."""
    part = _zero_axis(name, shape, cfg, len(live_g), k)
    if part is None:
        return _leaf_spec(name, shape, live_g, cfg, protected)
    axis, groups = part
    if protected:
        live_g = np.ones_like(live_g)
    if zero3:
        gather_g = fwd_g | live_g
    else:
        gather_g = live_g | ever_g if elide_gather \
            else np.ones_like(live_g, bool)
    if groups == 1:
        # coarse partition: the mask collapses to the whole block — for
        # zero3 that is exactly the safety a non-group-sliceable leaf
        # (norms, shared-KV weights, SSD/RG-LRU params) needs: it is only
        # elidable when every group of its block is forward-dead.
        live_g = np.atleast_1d(live_g.any())
        gather_g = np.atleast_1d(gather_g.any())
    return SyncSpec("zero", axis=axis, shards=k,
                    live=tuple(bool(x) for x in live_g),
                    gather=tuple(bool(x) for x in gather_g))


def _block_plan(block, live_g: np.ndarray, cfg: ModelConfig,
                stack: int = 0, *, mode: str = "masked", n_shards: int = 0,
                ever_g: Optional[np.ndarray] = None,
                fwd_g: Optional[np.ndarray] = None,
                elide_gather: bool = True):
    """Plan for one block's param subtree. ``stack`` > 0 marks scan-stacked
    leaves whose leading dim holds one layer per index; ``live_g`` is then
    [stack, G] instead of [G]."""
    has_moe = isinstance(block, dict) and "moe" in block
    if ever_g is None:
        ever_g = np.zeros_like(live_g)
    if fwd_g is None:
        fwd_g = np.zeros_like(live_g)

    def leaf(name, shape, lg, eg, fg, prot):
        if mode in ("zero", "zero3"):
            return _zero_leaf_spec(name, shape, lg, eg, fg, cfg, prot,
                                   n_shards, elide_gather, mode == "zero3")
        return _leaf_spec(name, shape, lg, cfg, prot)

    def rec(tree, name, protected):
        if isinstance(tree, dict):
            return {k: rec(v, k, protected or k == "moe") for k, v in
                    tree.items()}
        if isinstance(tree, (list, tuple)):
            return [rec(v, name, protected) for v in tree]
        # MoE router aux losses are computed from norm2(x) regardless of
        # gating, so the whole FFN side of an MoE block keeps full sync.
        prot = protected or (has_moe and name == "norm2")
        if stack == 0:
            return leaf(name, tree.shape, live_g, ever_g, fwd_g, prot)
        per_cycle = tuple(leaf(name, tree.shape[1:], live_g[c], ever_g[c],
                               fwd_g[c], prot) for c in range(stack))
        if all(s == per_cycle[0] for s in per_cycle):
            s = per_cycle[0]
            if s.mode in ("all", "none"):
                return s
            # identical pattern in every cycle: operate on the stacked
            # leaf directly (partition axis shifts past the stack dim)
            return SyncSpec(s.mode, axis=s.axis + 1, live=s.live,
                            gather=s.gather, shards=s.shards)
        if all(s.mode == "zero" for s in per_cycle):
            # zero-vs-fallback is decided by (name, shape, cfg, k) alone,
            # so cycles never mix zero and masked specs: stack the
            # per-cycle masks under one shape-derived partition axis
            return SyncSpec("zero_stacked", axis=per_cycle[0].axis + 1,
                            shards=n_shards, per_cycle=per_cycle)
        return SyncSpec("stacked", per_cycle=per_cycle)

    return rec(block, None, False)


def _fill(tree, spec: SyncSpec):
    if isinstance(tree, dict):
        return {k: _fill(v, spec) for k, v in tree.items()}
    if isinstance(tree, (list, tuple)):
        return [_fill(v, spec) for v in tree]
    return spec


def _fill_zero(tree, cfg, k):
    """Zero specs for loss-path leaves: fully live, fully gathered."""
    if isinstance(tree, dict):
        return {key: _fill_zero(v, cfg, k) for key, v in tree.items()}
    if isinstance(tree, (list, tuple)):
        return [_fill_zero(v, cfg, k) for v in tree]
    one = np.ones(1, bool)
    return _zero_leaf_spec("", tree.shape, one, one, one, cfg, True, k,
                           True, False)


def grad_sync_plan(params, cfg: ModelConfig, sched: Schedule, *,
                   mode: str = "masked", n_shards: int = 0,
                   ever_live: Optional[np.ndarray] = None,
                   elide_gather: bool = True):
    """Mirror of the params tree with a SyncSpec at every leaf.

    mode="masked" (default): the PR-3 masked-psum plan. mode="zero": the
    ZeRO-1 plan (requires ``n_shards`` = data-mesh size); ``ever_live``
    is an optional [L, G] bool of groups that were backward-live under any
    earlier plan since the moments were last zero — their moments may be
    non-zero, so their params must still be gathered; ``elide_gather=False``
    (non-elidable optimizer, e.g. weight decay) forces a full gather mask.
    mode="zero3": fully sharded params — same partition, but the gather
    mask is *forward* liveness (see module docstring); ``ever_live`` and
    ``elide_gather`` are ignored (the owned shards are always updated, so
    no staleness exists for the mask to track).

    Static and host-side (numpy over the schedule table, shapes from the
    params/eval_shape tree) — baked into the jitted distributed step, so a
    new schedule means a new plan and a re-jit, exactly like the compaction
    bounds."""
    from repro.models.transformer import layer_groups
    assert mode in ("masked", "zero", "zero3"), mode
    assert mode == "masked" or n_shards >= 1, f"{mode} mode needs n_shards"
    live = backward_live_groups(sched)                       # [L, G]
    ever = np.zeros_like(live) if ever_live is None \
        else np.asarray(ever_live, bool)
    assert ever.shape == live.shape, (ever.shape, live.shape)
    fwd = forward_live_groups(sched) if mode == "zero3" \
        else np.zeros_like(live)
    n_cycles, pat, rem = layer_groups(cfg)
    P = len(pat)
    assert live.shape[0] == cfg.n_layers, (live.shape, cfg.n_layers)
    kw = dict(mode=mode, n_shards=n_shards, elide_gather=elide_gather)
    plan = {}
    for key, sub in params.items():
        if key == "cycles":
            # sub[i]: block at pattern position i, leaves [n_cycles, ...];
            # cycle c holds layer c * P + i
            plan[key] = [
                _block_plan(sub[i],
                            live[[c * P + i for c in range(n_cycles)]],
                            cfg, stack=n_cycles,
                            ever_g=ever[[c * P + i for c in range(n_cycles)]],
                            fwd_g=fwd[[c * P + i for c in range(n_cycles)]],
                            **kw)
                for i in range(P)]
        elif key == "rest":
            plan[key] = [_block_plan(sub[i], live[n_cycles * P + i], cfg,
                                     ever_g=ever[n_cycles * P + i],
                                     fwd_g=fwd[n_cycles * P + i], **kw)
                         for i in range(len(sub))]
        else:
            # embed / unembed / final_norm / frontend_proj: gradients flow
            # through every sample's loss path — never skip (and in the
            # zero modes: always scattered, always gathered).
            plan[key] = _fill_zero(sub, cfg, n_shards) \
                if mode in ("zero", "zero3") else _fill(sub, _ALL)
    return plan


# ------------------------------------------------------------- application
def _runs(live: Tuple[bool, ...]):
    """Merge consecutive equal-liveness groups into (live, start, stop)."""
    out = []
    start = 0
    for g in range(1, len(live) + 1):
        if g == len(live) or live[g] != live[start]:
            out.append((live[start], start, g))
            start = g
    return out


def _sync_leaf(g, spec: SyncSpec, axis_name: str):
    if spec.mode == "none":
        return g
    if spec.mode == "all":
        return jax.lax.pmean(g, axis_name)
    if spec.mode == "stacked":
        return jnp.stack([_sync_leaf(g[c], s, axis_name)
                          for c, s in enumerate(spec.per_cycle)])
    blocks = len(spec.live)
    size = g.shape[spec.axis] // blocks
    parts = []
    for is_live, start, stop in _runs(spec.live):
        seg = jax.lax.slice_in_dim(g, start * size, stop * size,
                                   axis=spec.axis)
        parts.append(jax.lax.pmean(seg, axis_name) if is_live else seg)
    return jnp.concatenate(parts, axis=spec.axis) if len(parts) > 1 \
        else parts[0]


def apply_grad_sync(grads, plan, axis_name: str):
    """Masked pmean: all-reduce exactly the live slices of the grads tree.

    Must run inside shard_map over ``axis_name``. Skipped leaves/slices are
    identically zero on every device (see module docstring), so eliding
    their psum leaves them — correctly — at the global value."""
    if isinstance(plan, SyncSpec):
        return _sync_leaf(grads, plan, axis_name)
    if isinstance(plan, dict):
        return {k: apply_grad_sync(grads[k], plan[k], axis_name)
                for k in grads}
    return [apply_grad_sync(g, p, axis_name) for g, p in zip(grads, plan)]


# ------------------------------------------------------ lo-fi local sync
def stack_replicas(tree, n: int):
    """Replicated tree -> per-replica stacked tree ([n, ...] leaves): the
    state layout of ``sync_mode="local"``, where each replica fine-tunes
    its own copy with zero gradient sync between merges."""
    return jax.tree.map(lambda x: jnp.broadcast_to(x[None],
                                                   (n,) + x.shape), tree)


def _merge_leaf(x, spec: SyncSpec):
    """[R, ...] stacked replica leaf -> merged single leaf.

    The lo-fi merge rule: slices with a live backward anywhere in the
    mask diverge across replicas and are averaged; dead slices received
    identically-zero grads on every replica, so their copies are still
    bit-identical and replica 0 IS the merged value — taking it (instead
    of a mean) keeps dead slices bit-stable and models the wire saving
    (dead slices never need to move)."""
    if spec.mode == "none":
        return x[0]
    if spec.mode == "all":
        return x.mean(axis=0)
    if spec.mode == "stacked":
        return jnp.stack([_merge_leaf(x[:, c], s)
                          for c, s in enumerate(spec.per_cycle)])
    assert spec.mode == "sliced", spec.mode
    blocks = len(spec.live)
    axis = spec.axis + 1                       # leaf axes shift past [R]
    size = x.shape[axis] // blocks
    parts = []
    for is_live, start, stop in _runs(spec.live):
        seg = jax.lax.slice_in_dim(x, start * size, stop * size, axis=axis)
        parts.append(seg.mean(axis=0) if is_live else seg[0])
    return jnp.concatenate(parts, axis=spec.axis) if len(parts) > 1 \
        else parts[0]


def lofi_merge(stacked, plan):
    """Merge per-replica stacked state under a masked-mode sync plan.

    ``plan`` should be built from the union of every schedule that was
    active since the replicas were last in sync (the elastic loop tracks
    that as ``live_since_merge``): a subnet live under ANY of them may
    have diverged and must be averaged; a subnet dead under all of them
    is still replica-identical and is passed through from replica 0.
    ``sync_byte_report(plan, params)`` prices the merge's wire bytes."""
    if isinstance(plan, SyncSpec):
        return _merge_leaf(stacked, plan)
    if isinstance(plan, dict):
        return {k: lofi_merge(stacked[k], plan[k]) for k in stacked}
    return [lofi_merge(x, p) for x, p in zip(stacked, plan)]


# --------------------------------------------------------- zero application
def _is_zero(spec) -> bool:
    return isinstance(spec, SyncSpec) and spec.mode in ("zero",
                                                        "zero_stacked")


def _zero_runs(spec: SyncSpec):
    """Merge consecutive groups with equal (live, gather) into
    (live, gather, start_group, stop_group) runs. Run boundaries define the
    shard layout: for each run, device d owns its d-th sub-chunk, and the
    device shard is the concatenation of those sub-chunks in run order."""
    out = []
    start = 0
    n = len(spec.live)
    for g in range(1, n + 1):
        if g == n or (spec.live[g], spec.gather[g]) != \
                (spec.live[start], spec.gather[start]):
            out.append((spec.live[start], spec.gather[start], start, g))
            start = g
    return out


def _map_zero(fn, tree_args, plan):
    """Apply fn(leaf_args..., spec) over the (tree_args..., plan) lockstep
    tree. zero_stacked leaves are lifted automatically: fn runs per cycle
    on the unstacked slices with the per-cycle spec and the results are
    re-stacked (spec-only calls — no tree_args — get the stacked spec
    itself, whose ``axis`` already includes the cycle dim)."""
    if isinstance(plan, SyncSpec):
        if plan.mode == "zero_stacked" and tree_args:
            return jnp.stack([fn(*[t[c] for t in tree_args], s)
                              for c, s in enumerate(plan.per_cycle)])
        return fn(*tree_args, plan)
    if isinstance(plan, dict):
        return {k: _map_zero(fn, [t[k] for t in tree_args], plan[k])
                for k in plan}
    return [_map_zero(fn, [t[i] for t in tree_args], p)
            for i, p in enumerate(plan)]


def _plan_leaves(tree, plan):
    """Yield (leaf, spec) pairs of a (params-like tree, plan) in lockstep
    — the one traversal all plan accounting shares."""
    if isinstance(plan, SyncSpec):
        yield tree, plan
    elif isinstance(plan, dict):
        for k in plan:
            yield from _plan_leaves(tree[k], plan[k])
    else:
        for t, s in zip(tree, plan):
            yield from _plan_leaves(t, s)


def _zero_scatter_leaf(g, spec: SyncSpec, axis_name: str):
    """Full local grad -> this device's reduced shard (pmean semantics).

    Live runs reduce-scatter (the only cross-device bytes); dead runs are
    identically zero everywhere, so the device's own sub-chunk is already
    the global value and is sliced locally."""
    if not _is_zero(spec):
        return _sync_leaf(g, spec, axis_name)
    k = spec.shards
    gs = g.shape[spec.axis] // len(spec.live)
    idx = jax.lax.axis_index(axis_name)
    parts = []
    for live, _, s, e in _zero_runs(spec):
        seg = jax.lax.slice_in_dim(g, s * gs, e * gs, axis=spec.axis)
        if live:
            parts.append(jax.lax.psum_scatter(
                seg, axis_name, scatter_dimension=spec.axis, tiled=True) / k)
        else:
            plen = (e - s) * gs // k
            parts.append(jax.lax.dynamic_slice_in_dim(
                seg, idx * plen, plen, axis=spec.axis))
    return jnp.concatenate(parts, axis=spec.axis) if len(parts) > 1 \
        else parts[0]


def _zero_shard_leaf(x, spec: SyncSpec, axis_name: str):
    """Replicated leaf -> this device's owned shard (no communication)."""
    if not _is_zero(spec):
        return x
    gs = x.shape[spec.axis] // len(spec.live)
    idx = jax.lax.axis_index(axis_name)
    parts = []
    for _, _, s, e in _zero_runs(spec):
        plen = (e - s) * gs // spec.shards
        seg = jax.lax.slice_in_dim(x, s * gs, e * gs, axis=spec.axis)
        parts.append(jax.lax.dynamic_slice_in_dim(
            seg, idx * plen, plen, axis=spec.axis))
    return jnp.concatenate(parts, axis=spec.axis) if len(parts) > 1 \
        else parts[0]


def _zero_gather_leaf(u, old, spec: SyncSpec, axis_name: str):
    """Updated shard + previous replicated leaf -> new replicated leaf.

    Runs outside the gather mask kept their old params on every device
    (zero grad, zero moments, elidable update — see module docstring)."""
    if not _is_zero(spec):
        return u
    k = spec.shards
    gs = old.shape[spec.axis] // len(spec.live)
    off = 0
    parts = []
    for _, gather, s, e in _zero_runs(spec):
        plen = (e - s) * gs // k
        if gather:
            piece = jax.lax.slice_in_dim(u, off, off + plen, axis=spec.axis)
            parts.append(jax.lax.all_gather(piece, axis_name,
                                            axis=spec.axis, tiled=True))
        else:
            parts.append(jax.lax.slice_in_dim(old, s * gs, e * gs,
                                              axis=spec.axis))
        off += plen
    return jnp.concatenate(parts, axis=spec.axis) if len(parts) > 1 \
        else parts[0]


def apply_zero_scatter(grads, plan, axis_name: str):
    """Local grads tree -> mixed tree: reduced shards at zero leaves,
    masked-pmean full leaves elsewhere. Must run inside shard_map."""
    return _map_zero(lambda g, s: _zero_scatter_leaf(g, s, axis_name),
                     [grads], plan)


def zero_shard_params(params, plan, axis_name: str):
    """Replicated params tree -> this device's owned shards (zero leaves)
    with non-partitioned leaves passed through."""
    return _map_zero(lambda x, s: _zero_shard_leaf(x, s, axis_name),
                     [params], plan)


def apply_zero_gather(updated, old_params, plan, axis_name: str):
    """Updated shard tree + previous replicated params -> new replicated
    params; only runs in the gather mask move bytes."""
    return _map_zero(lambda u, o, s: _zero_gather_leaf(u, o, s, axis_name),
                     [updated, old_params], plan)


def _zero3_materialize_leaf(shard, spec: SyncSpec, axis_name: str):
    """Owned shard -> transient full-leaf view for the step body.

    Runs in the gather mask are all-gathered (device d's piece is the d-th
    sub-chunk of the run, so a tiled gather restores the canonical run
    content); elided runs materialize as zeros — exact, because every
    consumer of their values is multiplied by g_f == 0 (see
    ``forward_live_groups``), and the persistent state is the shard, which
    the elision never touches."""
    if not _is_zero(spec):
        return shard
    k = spec.shards
    full = shard.shape[spec.axis] * k
    gs = full // len(spec.live)
    off = 0
    parts = []
    for _, gather, s, e in _zero_runs(spec):
        plen = (e - s) * gs // k
        if gather:
            piece = jax.lax.slice_in_dim(shard, off, off + plen,
                                         axis=spec.axis)
            parts.append(jax.lax.all_gather(piece, axis_name,
                                            axis=spec.axis, tiled=True))
        else:
            shape = list(shard.shape)
            shape[spec.axis] = (e - s) * gs
            parts.append(jnp.zeros(shape, shard.dtype))
        off += plen
    return jnp.concatenate(parts, axis=spec.axis) if len(parts) > 1 \
        else parts[0]


def zero3_materialize(params, plan, axis_name: str):
    """Sharded params tree -> full-param views for the forward/backward.

    Only runs in the gather mask move bytes; fallback (masked) leaves pass
    through replicated. Must run inside shard_map. The inverse direction
    needs no collective: grads reduce-scatter onto the shards
    (``apply_zero_scatter``) and the update runs shard-resident."""
    return _map_zero(lambda x, s: _zero3_materialize_leaf(x, s, axis_name),
                     [params], plan)


def zero_norm_sq(grads, plan):
    """(shard_sq, full_sq): squared-norm contributions of a mixed grads
    tree. ``shard_sq`` sums zero-leaf shards (disjoint across devices — a
    scalar psum completes them); ``full_sq`` sums the replicated masked
    leaves, identical on every device."""
    shard_sq = jnp.zeros((), jnp.float32)
    full_sq = jnp.zeros((), jnp.float32)
    for g, spec in _plan_leaves(grads, plan):
        sq = jnp.sum(g.astype(jnp.float32) ** 2)
        if _is_zero(spec):
            shard_sq = shard_sq + sq
        else:
            full_sq = full_sq + sq
    return shard_sq, full_sq


def zero_param_specs(plan, axis_name: str):
    """PartitionSpec tree for the sharded moment leaves: the partition axis
    is sharded over ``axis_name`` for zero leaves, replicated otherwise.
    The global array layout is the concatenation of device shards (each the
    run-ordered sub-chunks of ``_zero_runs``) along the partition axis."""
    from jax.sharding import PartitionSpec as P

    def leaf(spec):
        if _is_zero(spec):
            return P(*([None] * spec.axis + [axis_name]))
        return P()

    return _map_zero(leaf, [], plan)


# ------------------------------------------------ streamed materialization
class ResidencyRecorder:
    """Trace-time counter of the bytes each residency unit all-gathers.

    The streamed materializer reports every gather site it emits as
    ``record(unit, site, nbytes)``; sites are keyed (not summed) so
    re-tracing the same step is idempotent. ``unit_bytes()`` is the
    *measured* side of the residency model — ``check_zero3_residency``
    compares it against ``zero3_param_byte_report``."""

    def __init__(self):
        self.sites: dict = {}            # unit -> {site_key: bytes}

    def record(self, unit: str, site: str, nbytes: float):
        self.sites.setdefault(unit, {})[site] = float(nbytes)

    def unit_bytes(self) -> dict:
        return {u: float(sum(s.values())) for u, s in self.sites.items()}


def _cycle_spec(spec: SyncSpec, c: int) -> SyncSpec:
    """Spec for cycle c of a scan-stacked leaf (the uniform stacked forms
    carry the cycle dim in ``axis``; unstacking shifts it back)."""
    if spec.mode in ("zero_stacked", "stacked"):
        return spec.per_cycle[c]
    if spec.mode == "zero":
        return SyncSpec("zero", axis=spec.axis - 1, live=spec.live,
                        gather=spec.gather, shards=spec.shards)
    if spec.mode == "sliced":
        return SyncSpec("sliced", axis=spec.axis - 1, live=spec.live)
    return spec                          # all / none: per-cycle unchanged


def _stream_leaf(shard, spec: SyncSpec, axis_name: str, recorder=None,
                 unit: str = "", path: str = ""):
    """One leaf of the streamed schedule: forward all-gather and backward
    reduce-scatter fused into a ``custom_vjp``, so the scatter sits exactly
    where the backward releases this leaf's gradient. Fallback (masked)
    leaves stream too: identity forward, masked pmean in the vjp. Values
    match ``zero3_materialize`` + ``apply_zero_scatter`` bit-for-bit —
    the ``dist_zero3_streamed`` parity arm pins that."""
    if recorder is not None and _is_zero(spec):
        full_ax = shard.shape[spec.axis] * spec.shards
        gs = full_ax // len(spec.live)
        row = float(np.prod(shard.shape)) / shard.shape[spec.axis] \
            * np.dtype(shard.dtype).itemsize
        for _, gather, s, e in _zero_runs(spec):
            if gather:
                recorder.record(unit, f"{path}[{s}:{e}]",
                                (e - s) * gs * row)

    @jax.custom_vjp
    def mat(s):
        return _zero3_materialize_leaf(s, spec, axis_name)

    def mat_fwd(s):
        return _zero3_materialize_leaf(s, spec, axis_name), None

    def mat_bwd(_, ct):
        return (_zero_scatter_leaf(ct, spec, axis_name),)

    mat.defvjp(mat_fwd, mat_bwd)
    return mat(shard)


def _stream_block(tree, plan_t, axis_name: str, recorder, unit: str,
                  path: str = ""):
    if isinstance(plan_t, SyncSpec):
        return _stream_leaf(tree, plan_t, axis_name, recorder, unit, path)
    if isinstance(plan_t, dict):
        return {k: _stream_block(tree[k], plan_t[k], axis_name, recorder,
                                 unit, f"{path}/{k}") for k in plan_t}
    return [_stream_block(tree[i], p, axis_name, recorder, unit,
                          f"{path}/{i}") for i, p in enumerate(plan_t)]


def _stream_block_stacked(tree, plan_t, axis_name: str, recorder,
                          unit_prefix: str, path: str = ""):
    """Scan-stacked block: each cycle is its own residency unit, so every
    leaf is unstacked and materialized per cycle (one gather per cycle per
    run instead of one spanning the stack — same bytes, unit-granular
    scheduling) and re-stacked for the scan."""
    if isinstance(plan_t, SyncSpec):
        n_cycles = tree.shape[0]
        return jnp.stack([
            _stream_leaf(tree[c], _cycle_spec(plan_t, c), axis_name,
                         recorder, f"{unit_prefix}[{c}]", path)
            for c in range(n_cycles)])
    if isinstance(plan_t, dict):
        return {k: _stream_block_stacked(tree[k], plan_t[k], axis_name,
                                         recorder, unit_prefix,
                                         f"{path}/{k}") for k in plan_t}
    return [_stream_block_stacked(tree[i], p, axis_name, recorder,
                                  unit_prefix, f"{path}/{i}")
            for i, p in enumerate(plan_t)]


def zero3_stream_materialize(params, plan, axis_name: str, *,
                             recorder: Optional[ResidencyRecorder] = None):
    """Sharded params tree -> full views, one residency unit at a time.

    Drop-in replacement for ``zero3_materialize`` whose gradient is
    already the scattered mixed tree of ``apply_zero_scatter`` — take
    ``jax.grad`` of a loss over this and the reduce-scatters land at each
    unit's backward release point, with per-unit all-gathers the XLA
    scheduler can prefetch (double-buffer) against the previous unit's
    compute. Must run inside shard_map. ``recorder`` (optional) counts
    every gather site's bytes per unit at trace time."""
    out = {}
    for key in params:
        if key == "cycles":
            out[key] = [
                _stream_block_stacked(params[key][i], plan[key][i],
                                      axis_name, recorder, f"cycles[{i}]")
                for i in range(len(plan[key]))]
        elif key == "rest":
            out[key] = [
                _stream_block(params[key][i], plan[key][i], axis_name,
                              recorder, f"rest[{i}]")
                for i in range(len(plan[key]))]
        else:
            out[key] = _stream_block(params[key], plan[key], axis_name,
                                     recorder, key)
    return out


# --------------------------------------------------------------- accounting
def _mask_fraction(mask: Tuple[bool, ...]) -> float:
    return float(sum(mask)) / len(mask) if mask else 0.0


def _live_fraction(spec: SyncSpec) -> float:
    if spec.mode == "all":
        return 1.0
    if spec.mode == "none":
        return 0.0
    if spec.mode in ("stacked", "zero_stacked"):
        return float(np.mean([_live_fraction(s) for s in spec.per_cycle]))
    return _mask_fraction(spec.live)


def _gather_fraction(spec: SyncSpec) -> float:
    if spec.mode == "zero":
        return _mask_fraction(spec.gather)
    if spec.mode == "zero_stacked":
        return float(np.mean([_gather_fraction(s) for s in spec.per_cycle]))
    return 0.0


def sync_byte_report(plan, params, n_shards: Optional[int] = None) -> dict:
    """Price the plan. Works on concrete arrays or ShapeDtypeStructs.

    Masked leaves contribute live bytes to ``ar_bytes`` (all-reduce); zero
    leaves contribute scatter-live bytes to ``rs_bytes`` (reduce-scatter)
    and gather-mask bytes to ``ag_bytes`` (all-gather). ``synced_bytes``
    keeps the PR-3 meaning of all-reduce-*equivalent* bytes — a zero
    leaf's RS + AG pair counts as the mean of the two masks, so
    ``fraction`` stays comparable across modes. With ``n_shards`` the
    report adds ``wire``: per-device ring traffic by collective
    (2·(k-1)/k per all-reduce byte, (k-1)/k per RS/AG byte), the number
    the HLO-parsed ``launch.hlo.collective_bytes`` should reproduce."""
    totals = {"total_bytes": 0.0, "synced_bytes": 0.0, "ar_bytes": 0.0,
              "rs_bytes": 0.0, "ag_bytes": 0.0, "n_leaves": 0,
              "n_skipped": 0, "n_sliced": 0, "n_zero": 0}
    for p, spec in _plan_leaves(params, plan):
        size = float(np.prod(p.shape)) * np.dtype(p.dtype).itemsize
        totals["total_bytes"] += size
        totals["n_leaves"] += 1
        if _is_zero(spec):
            rs, ag = _live_fraction(spec), _gather_fraction(spec)
            totals["rs_bytes"] += size * rs
            totals["ag_bytes"] += size * ag
            totals["synced_bytes"] += size * (rs + ag) / 2.0
            totals["n_zero"] += 1
            continue
        totals["ar_bytes"] += size * _live_fraction(spec)
        totals["synced_bytes"] += size * _live_fraction(spec)
        if spec.mode == "none":
            totals["n_skipped"] += 1
        elif spec.mode in ("sliced", "stacked"):
            totals["n_sliced"] += 1
    totals["fraction"] = (totals["synced_bytes"] / totals["total_bytes"]
                          if totals["total_bytes"] else 1.0)
    if n_shards is not None and n_shards > 1:
        k = n_shards
        wire = {
            "all_reduce": 2.0 * (k - 1) / k * totals["ar_bytes"],
            "reduce_scatter": (k - 1) / k * totals["rs_bytes"],
            "all_gather": (k - 1) / k * totals["ag_bytes"],
        }
        wire["total"] = sum(wire.values())
        totals["wire"] = wire
    return totals


def zero_state_byte_report(plan, params, n_shards: int,
                           n_moments: int = 1) -> dict:
    """Per-device optimizer-moment memory under the plan's partition.

    Zero leaves keep 1/k of each moment copy per device; fallback (masked)
    leaves stay replicated. ``fraction`` is per-device bytes over the
    replicated baseline — the ZeRO-1 memory claim."""
    totals = {"replicated_bytes": 0.0, "per_device_bytes": 0.0,
              "n_partitioned": 0, "n_replicated": 0}
    for p, spec in _plan_leaves(params, plan):
        size = float(np.prod(p.shape)) * np.dtype(p.dtype).itemsize
        totals["replicated_bytes"] += size
        if _is_zero(spec):
            totals["per_device_bytes"] += size / n_shards
            totals["n_partitioned"] += 1
        else:
            totals["per_device_bytes"] += size
            totals["n_replicated"] += 1
    for key in ("replicated_bytes", "per_device_bytes"):
        totals[key] *= n_moments
    totals["n_shards"] = n_shards
    totals["fraction"] = (totals["per_device_bytes"]
                          / totals["replicated_bytes"]
                          if totals["replicated_bytes"] else 1.0)
    return totals


def _cycle_gather_fraction(spec: SyncSpec, c: int) -> Optional[float]:
    """Gather fraction of cycle c of a (possibly stacked) leaf spec, or
    None for fallback (masked/replicated) leaves."""
    if spec.mode == "zero_stacked":
        return _gather_fraction(spec.per_cycle[c])
    if _is_zero(spec):
        return _gather_fraction(spec)        # uniform across cycles
    return None


def _unit_gathered_bytes(tree, plan_t, cycle=None, n_cycles: int = 1):
    """Gathered bytes of one residency unit (cycle slice or whole block)
    under the plan's gather mask — the model side of what the streamed
    materializer's ``ResidencyRecorder`` measures at trace time."""
    u = 0.0
    for p, spec in _plan_leaves(tree, plan_t):
        if not _is_zero(spec):
            continue
        f = _cycle_gather_fraction(spec, 0 if cycle is None else cycle)
        u += float(np.prod(p.shape)) * np.dtype(p.dtype).itemsize \
            / n_cycles * f
    return u


def zero3_param_byte_report(plan, params, n_shards: int) -> dict:
    """Residency-window memory model of the ZeRO-3 partition.

    Persistent per-device bytes: the owned shards (1/k of every
    partitioned leaf) plus replicated fallback leaves. Transient bytes:
    the full-leaf views the step materializes, priced per *residency
    unit* — one transformer block (one cycle of one pattern position, or
    one ``rest`` block) or one loss-path subtree — under the plan's gather
    mask, with elided runs costing nothing (their zeros view folds into
    the gated-off consumer). ``per_device_peak_bytes`` prices the
    streaming schedule ``zero3_stream_materialize`` executes: one unit
    materialized at a time, freed after its backward retires. The model is
    cross-checked against that execution's trace-time gather counter by
    ``check_zero3_residency`` (and the distributed-step bench fails if
    they disagree beyond 5%); the double-buffered variant that also holds
    the prefetched next unit is priced by the overlap model in
    launch/diststep.py.

    ``fraction`` = peak / replicated is the ZeRO-3 memory claim;
    ``n_gather_elided`` counts runs whose all-gather the schedule killed
    (> 0 is the "the gates tell us which gathers are dead" acceptance)."""
    def size_of(p):
        return float(np.prod(p.shape)) * np.dtype(p.dtype).itemsize

    totals = {"replicated_bytes": 0.0, "shard_bytes": 0.0,
              "fallback_bytes": 0.0, "gathered_bytes": 0.0,
              "elided_bytes": 0.0, "n_runs": 0, "n_gather_elided": 0,
              "n_partitioned": 0, "n_fallback": 0}
    for p, spec in _plan_leaves(params, plan):
        size = size_of(p)
        totals["replicated_bytes"] += size
        if not _is_zero(spec):
            totals["fallback_bytes"] += size
            totals["n_fallback"] += 1
            continue
        totals["shard_bytes"] += size / n_shards
        totals["n_partitioned"] += 1
        subs = [(size / len(spec.per_cycle), s) for s in spec.per_cycle] \
            if spec.mode == "zero_stacked" else [(size, spec)]
        for sub_size, sub in subs:
            for _, gather, s, e in _zero_runs(sub):
                frac = (e - s) / len(sub.live)
                totals["n_runs"] += 1
                if gather:
                    totals["gathered_bytes"] += sub_size * frac
                else:
                    totals["n_gather_elided"] += 1
                    totals["elided_bytes"] += sub_size * frac

    units = dict(zero3_unit_schedule(plan, params))
    totals["peak_unit_bytes"] = max(units.values()) if units else 0.0
    totals["peak_unit"] = max(units, key=units.get) if units else ""
    totals["per_device_peak_bytes"] = (totals["shard_bytes"]
                                       + totals["fallback_bytes"]
                                       + totals["peak_unit_bytes"])
    totals["fraction"] = (totals["per_device_peak_bytes"]
                          / totals["replicated_bytes"]
                          if totals["replicated_bytes"] else 1.0)
    totals["n_shards"] = n_shards
    return totals


# Loss-path subtrees consumed before the layer stack in the forward —
# everything else non-block (final norm, unembed, ...) runs after it.
_HEAD_KEYS = ("embed", "frontend_proj")


def zero3_unit_schedule(plan, params):
    """Ordered [(unit_name, gathered_bytes)] in forward execution order.

    Head loss-path subtrees (embeddings) first, then the layer stack in
    layer order — layer l = c * P + i is unit ``cycles[i][c]``, followed
    by the ``rest`` blocks — then the remaining loss-path subtrees. This
    is the unit sequence the streamed materializer gathers and the order
    the overlap-window model (launch/diststep.py) prefetches in; names
    match ``zero3_param_byte_report``'s units and the keys the
    ``ResidencyRecorder`` measures."""
    head, tail = [], []
    for key in plan:
        if key in ("cycles", "rest"):
            continue
        entry = (key, _unit_gathered_bytes(params[key], plan[key]))
        (head if key in _HEAD_KEYS else tail).append(entry)
    blocks = []
    if "cycles" in plan and plan["cycles"]:
        leaves = jax.tree.leaves(params["cycles"][0])
        n_cycles = leaves[0].shape[0] if leaves else 1
        for c in range(n_cycles):
            for i in range(len(plan["cycles"])):
                blocks.append((f"cycles[{i}][{c}]", _unit_gathered_bytes(
                    params["cycles"][i], plan["cycles"][i], cycle=c,
                    n_cycles=n_cycles)))
    for i in range(len(plan.get("rest", []))):
        blocks.append((f"rest[{i}]", _unit_gathered_bytes(
            params["rest"][i], plan["rest"][i])))
    return head + blocks + tail


def check_zero3_residency(recorder: ResidencyRecorder, plan, params,
                          n_shards: int, *, tol: float = 0.05) -> dict:
    """Fail if the executed streaming schedule and the residency model
    disagree beyond ``tol`` (relative).

    ``recorder`` holds the gather bytes the streamed materializer actually
    emitted (counted at trace time, per residency unit);
    ``zero3_param_byte_report`` / ``zero3_unit_schedule`` hold what the
    model promises. Every unit is compared, units the model does not know
    about are an error, and the derived ``per_device_peak_bytes`` must
    agree — the measurement contract the distributed-step bench and the
    8-device parity suite enforce. Returns the measured-side summary for
    the bench's ``overlap`` block."""
    report = zero3_param_byte_report(plan, params, n_shards)
    measured = recorder.unit_bytes()
    model = dict(zero3_unit_schedule(plan, params))

    def close(a, b):
        return abs(a - b) <= tol * max(abs(a), abs(b), 1.0)

    unknown = set(measured) - set(model)
    assert not unknown, f"streamed schedule gathered unknown units {unknown}"
    for name, want in model.items():
        got = measured.get(name, 0.0)
        assert close(got, want), \
            f"unit {name}: measured {got:.0f}B vs model {want:.0f}B"
    peak_meas = max(measured.values()) if measured else 0.0
    assert close(peak_meas, report["peak_unit_bytes"]), \
        (peak_meas, report["peak_unit_bytes"])
    device_peak = (report["shard_bytes"] + report["fallback_bytes"]
                   + peak_meas)
    assert close(device_peak, report["per_device_peak_bytes"]), \
        (device_peak, report["per_device_peak_bytes"])
    return {
        "measured_peak_unit_bytes": peak_meas,
        "measured_peak_unit": (max(measured, key=measured.get)
                               if measured else ""),
        "measured_per_device_peak_bytes": device_peak,
        "model_per_device_peak_bytes": report["per_device_peak_bytes"],
        "peak_agreement": (device_peak / report["per_device_peak_bytes"]
                           if report["per_device_peak_bytes"] else 1.0),
        "n_units_measured": len(measured),
        "n_units_model": len(model),
    }


# ----------------------------------------------- layout / state resharding
def _zero_layout_perm(spec: SyncSpec, axis_len: int) -> np.ndarray:
    """perm[i] = canonical axis index held at position i of the global
    shard-concatenated layout (device-major, runs in order, d-th sub-chunk
    of each run per device). A bijection over range(axis_len)."""
    k = spec.shards
    gs = axis_len // len(spec.live)
    perm = np.empty(axis_len, np.int64)
    pos = 0
    for d in range(k):
        for _, _, s, e in _zero_runs(spec):
            plen = (e - s) * gs // k
            start = s * gs + d * plen
            perm[pos:pos + plen] = np.arange(start, start + plen)
            pos += plen
    assert pos == axis_len
    return perm


def _leaf_to_canonical(x: np.ndarray, spec: SyncSpec) -> np.ndarray:
    """Global shard-layout array -> canonical element order (numpy)."""
    if spec.mode == "zero_stacked":
        return np.stack([_leaf_to_canonical(x[c], s)
                         for c, s in enumerate(spec.per_cycle)])
    if not _is_zero(spec):
        return x
    perm = _zero_layout_perm(spec, x.shape[spec.axis])
    out = np.empty_like(x)
    idx = [slice(None)] * x.ndim
    idx[spec.axis] = perm
    out[tuple(idx)] = x
    return out


def _leaf_from_canonical(x: np.ndarray, spec: SyncSpec) -> np.ndarray:
    """Canonical array -> the global shard-concatenated layout (numpy)."""
    if spec.mode == "zero_stacked":
        return np.stack([_leaf_from_canonical(x[c], s)
                         for c, s in enumerate(spec.per_cycle)])
    if not _is_zero(spec):
        return x
    perm = _zero_layout_perm(spec, x.shape[spec.axis])
    return np.take(x, perm, axis=spec.axis)


# ------------------------------------------------ tensor-axis grad combine
# Leaves whose COMPUTE shards over the tensor axis (attention head blocks,
# FFN column blocks — models.transformer tp= path). Their local grads are
# disjoint slices of the true grad (zero outside this device's block), so
# a psum over the tensor axis reassembles the full tensor. Every other
# leaf's compute is replicated across the axis (identical grads after the
# _tp_copy backward all-reduces the activation cotangent), so it must NOT
# be psum'd. MoE reuses the w_up/w_gate/w_down names but runs replicated,
# hence the parent-key restriction.
_TP_SHARDED = {
    "attn": {"wq", "wk", "wv", "wo", "bq", "bk", "bv"},
    "mlp": {"w_up", "w_gate", "w_down"},
}


def apply_tensor_grad_sync(grads, axis_name: str):
    """psum the tensor-parallel-sharded grad leaves over ``axis_name``;
    replicated leaves pass through. Mirrors ``apply_grad_sync``'s role on
    the data axis — run this FIRST, so the data-axis sync (masked / ZeRO
    scatter) sees full per-data-shard grads."""
    def walk(tree, parent=None):
        if isinstance(tree, dict):
            return {k: (jax.lax.psum(v, axis_name)
                        if not isinstance(v, (dict, list, tuple))
                        and k in _TP_SHARDED.get(parent, ())
                        else walk(v, k))
                    for k, v in tree.items()}
        if isinstance(tree, (list, tuple)):
            return [walk(v, parent) for v in tree]
        return tree

    return walk(grads)


def zero_reshard(tree, old_plan, new_plan):
    """Re-layout a moments tree from one plan's shard layout to another's
    (host-side numpy; used when a schedule refresh changes the plan).
    Either plan may be None, meaning canonical/replicated layout — so this
    also converts masked <-> zero optimizer state."""
    def rec(x, old_s, new_s):
        if isinstance(x, (dict,)):
            return {k: rec(x[k],
                           old_s[k] if old_s is not None else None,
                           new_s[k] if new_s is not None else None)
                    for k in x}
        if isinstance(x, (list, tuple)):
            return [rec(xi,
                        old_s[i] if old_s is not None else None,
                        new_s[i] if new_s is not None else None)
                    for i, xi in enumerate(x)]
        arr = np.asarray(x)
        if old_s is not None:
            arr = _leaf_to_canonical(arr, old_s)
        if new_s is not None:
            arr = _leaf_from_canonical(arr, new_s)
        return jnp.asarray(arr)

    return rec(tree, old_plan, new_plan)
