"""Sharding policy: per-tensor PartitionSpecs for params, inputs, caches,
and activation constraints, with divisibility checks + documented fallbacks.

Axes
----
``model``  tensor/expert parallel:
    * FFN hidden dim, MoE expert dim (EP when n_experts % model == 0, else
      TP-within-expert on expert d_ff), vocab dim of embed/unembed,
    * attention Q/KV head dims when divisible by the axis — otherwise the
      policy falls back to *context parallelism*: attention weights stay
      replicated on `model` and the sequence dim of activations is sharded
      (recorded in ``self.fallbacks``),
    * RG-LRU width, SSD head_dim (both elementwise over channels).
``data`` (+ ``pod``)  batch parallel for activations; ZeRO/FSDP shard for
    params & optimizer state (largest replicated dim, when divisible).

The residual stream is sequence-sharded over ``model`` between blocks
(Megatron sequence parallelism) so per-layer remat residuals fit HBM.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, List, Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig


def _divisible(n: int, k: int) -> bool:
    return k > 0 and n % k == 0


@dataclass
class ShardingPolicy:
    mesh: Mesh
    cfg: ModelConfig
    seq_parallel: bool = True
    fsdp: bool = True
    # --- beyond-paper perf levers (EXPERIMENTS.md §Perf) ---
    # pad attention heads with zero heads up to the next mesh-divisible
    # count so head-TP applies where context-parallelism would otherwise be
    # forced (exact: zero wo rows null the padded heads' contribution).
    pad_heads: bool = False
    max_pad_overhead: float = 1.5
    # chunk the query dim of global causal attention (lax.map over chunks)
    # to cap the [B,H,Sq,Sk] scores buffer.
    attn_q_chunk: int = 0
    fallbacks: List[str] = field(default_factory=list)

    # -------------------------------------------------------------- helpers
    @property
    def batch_axes(self):
        return tuple(a for a in ("pod", "data") if a in self.mesh.axis_names)

    @property
    def model_size(self) -> int:
        return self.mesh.shape["model"]

    @property
    def data_size(self) -> int:
        return int(np.prod([self.mesh.shape[a] for a in self.batch_axes]))

    @property
    def fsdp_axis(self):
        return "data" if ("data" in self.mesh.axis_names and self.fsdp) else None

    def ns(self, *spec) -> NamedSharding:
        return NamedSharding(self.mesh, P(*spec))

    def _c(self, x, *spec):
        return jax.lax.with_sharding_constraint(x, self.ns(*spec))

    @property
    def attn_head_sharded(self) -> bool:
        return _divisible(self.cfg.n_heads, self.model_size)

    @property
    def kv_head_sharded(self) -> bool:
        return _divisible(self.cfg.n_kv_heads, self.model_size)

    @property
    def expert_parallel(self) -> bool:
        moe = self.cfg.moe
        return moe is not None and _divisible(moe.n_experts, self.model_size)

    def head_padding(self):
        """(Hp, Hkvp) padded head counts enabling head-TP, or None.

        Preserves the GQA ratio G = H/Hkv so real query head h keeps its kv
        head h // G; padded heads produce zero contribution because their
        wo rows are zero. Only applied when the FLOP overhead Hp/H stays
        under max_pad_overhead (e.g. qwen 40->48: 1.2x attention; gemma3
        4->16 q-heads with kv 1->4: 4x but attention is a small fraction;
        recurrentgemma 10->80 would be 8x: rejected -> CP fallback)."""
        if not self.pad_heads or self.cfg.n_heads == 0 or \
                self.attn_head_sharded:
            return None
        H, Hkv, ms = self.cfg.n_heads, self.cfg.n_kv_heads, self.model_size
        G = H // Hkv
        Hkvp = Hkv
        while (G * Hkvp) % ms != 0:
            Hkvp += 1
            if G * Hkvp > H * self.max_pad_overhead:
                return None
        return G * Hkvp, Hkvp

    def moe_sharded(self, cfg) -> bool:
        """Use the shard_map dispatch (EP or TP-within-expert)."""
        moe = cfg.moe
        if moe is None:
            return False
        return _divisible(moe.n_experts, self.model_size) or \
            _divisible(moe.d_ff, self.model_size)

    # ------------------------------------------------------------- params
    def _param_spec(self, name: str, shape: Tuple[int, ...], stacked: bool
                    ) -> P:
        """Spec for one weight. ``stacked`` = leading scan-cycle dim."""
        cfg = self.cfg
        ms = self.model_size
        lead = (None,) if stacked else ()
        body = shape[1:] if stacked else shape
        fa = self.fsdp_axis

        def fs(dim_idx_in_body, current):
            """apply FSDP axis to dim if free & divisible."""
            if fa is None:
                return current
            out = list(current)
            if out[dim_idx_in_body] is None and \
                    _divisible(body[dim_idx_in_body], self.mesh.shape["data"]):
                out[dim_idx_in_body] = fa
            return tuple(out)

        hd = cfg.resolved_head_dim if cfg.n_heads else 0

        if name in ("wq", "wo"):
            tp_ok = self.attn_head_sharded
            if name == "wq":
                spec = (None, "model") if tp_ok else (None, None)
                spec = fs(0, spec)
            else:
                spec = ("model", None) if tp_ok else (None, None)
                spec = fs(1, spec)
        elif name in ("wk", "wv"):
            tp_ok = self.kv_head_sharded
            spec = (None, "model") if tp_ok else (None, None)
            spec = fs(0, spec)
        elif name in ("bq",):
            spec = ("model",) if self.attn_head_sharded else (None,)
        elif name in ("bk", "bv"):
            spec = ("model",) if self.kv_head_sharded else (None,)
        elif name in ("w_up", "w_gate") and len(body) == 2:
            spec = fs(0, (None, "model"))
        elif name == "w_down" and len(body) == 2:
            spec = fs(1, ("model", None))
        elif name in ("w_up", "w_gate", "w_down") and len(body) == 3:
            # MoE expert weights [E, d, f] / [E, f, d]
            if self.expert_parallel:
                spec = fs(1, ("model", None, None))
            else:
                tp_dim = 2 if name in ("w_up", "w_gate") else 1
                spec = [None, None, None]
                spec[tp_dim] = "model"
                spec = fs(1 if tp_dim == 2 else 2, tuple(spec))
        elif name == "router":
            spec = fs(0, (None, None))
        elif name in ("shared_up", "shared_gate"):
            spec = fs(0, (None, "model"))
        elif name == "shared_down":
            spec = fs(1, ("model", None))
        elif name == "table":
            # embedding [V, D]: vocab over model AND data (2-axis shard);
            # keeping D replicated avoids batch-gathering reshards in the
            # embedding-scatter backward (see EXPERIMENTS.md §Perf).
            if _divisible(body[0], ms * self.mesh.shape.get("data", 1)):
                spec = (("model", "data"), None)
            elif _divisible(body[0], ms):
                spec = (("model",), None)
            else:                        # e.g. hubert's 504-way output
                spec = (None, None)
        elif name == "unembed":          # [D, V]
            if _divisible(body[1], ms * self.mesh.shape.get("data", 1)):
                spec = (None, ("model", "data"))
            elif _divisible(body[1], ms):
                spec = (None, "model")
            else:
                spec = fs(0, (None, None))
        elif name == "frontend_proj":
            spec = fs(0, (None, None))
        # ---- SSD
        elif name == "w_in":
            spec = fs(0, (None, None))   # mixed z|xBC|dt cols: replicate
        elif name == "w_out" and cfg.ssm is not None and \
                _divisible(body[0], ms):
            spec = fs(1, ("model", None))
        # ---- RG-LRU (width divisible -> channel TP)
        elif name in ("w_gate_branch", "w_rec_branch") and \
                _divisible(body[-1], ms):
            spec = fs(0, (None, "model"))
        elif name in ("w_a", "w_x") and _divisible(body[-1], ms):
            spec = fs(0, (None, "model"))
        elif name == "w_out" and cfg.rglru is not None and \
                _divisible(body[0], ms):
            spec = fs(1, ("model", None))
        elif name in ("b_a", "b_x", "Lambda", "conv_b", "norm_scale") and \
                len(body) == 1 and _divisible(body[-1], ms):
            spec = ("model",)
        elif name == "conv_w" and _divisible(body[-1], ms):
            spec = (None, "model")
        else:
            spec = tuple(None for _ in body)
        return P(*(lead + tuple(spec)))

    def param_specs(self, params) -> Dict:
        """Tree of NamedShardings matching the params tree."""
        def rec(tree, stacked: bool, path=()):
            if isinstance(tree, dict):
                return {k: rec(v, stacked or k == "cycles", path + (k,))
                        for k, v in tree.items()}
            if isinstance(tree, (list, tuple)):
                return [rec(v, stacked, path + (str(i),))
                        for i, v in enumerate(tree)]
            name = path[-1]
            return self.ns(*self._param_spec(name, tree.shape, stacked))
        return rec(params, False)

    # ------------------------------------------------------- activations
    def residual(self, x):
        """[B, S, D] — batch over data(+pod), sequence over model (seq par)."""
        if x.ndim != 3:
            return x
        b_ok = _divisible(x.shape[0], self.data_size)
        s_ok = self.seq_parallel and _divisible(x.shape[1], self.model_size) \
            and x.shape[1] > 1
        return self._c(x, self.batch_axes if b_ok else None,
                       "model" if s_ok else None, None)

    def heads(self, q):
        """[B, S, H, hd] — heads over model when divisible, else sequence
        (context parallelism fallback)."""
        if q.ndim != 4:
            return q
        b_ok = _divisible(q.shape[0], self.data_size)
        bspec = self.batch_axes if b_ok else None
        if _divisible(q.shape[2], self.model_size):
            return self._c(q, bspec, None, "model", None)
        if _divisible(q.shape[1], self.model_size) and q.shape[1] > 1:
            return self._c(q, bspec, "model", None, None)
        return self._c(q, bspec, None, None, None)

    def packed_residual(self, x):
        """[M, B', S, D] (micro-batch axis leading): batch over data,
        sequence over model; M stays unsharded (selection axis)."""
        if x.ndim != 4:
            return x
        b_ok = _divisible(x.shape[1], self.data_size)
        s_ok = self.seq_parallel and _divisible(x.shape[2], self.model_size)
        return self._c(x, None, self.batch_axes if b_ok else None,
                       "model" if s_ok else None, None)

    def packed_groups(self, hg):
        """[G, C*B', S, D] gathered per-group inputs: head-groups over
        model (the D2FT subnet axis), batch within groups over data."""
        if hg.ndim != 4:
            return hg
        g_ok = _divisible(hg.shape[0], self.model_size)
        b_ok = _divisible(hg.shape[1], self.data_size)
        return self._c(hg, "model" if g_ok else None,
                       self.batch_axes if b_ok else None, None, None)

    def kv(self, k):
        """[B, S, Hkv, hd]. In context-parallel fallback mode the K/V for
        attention must be sequence-replicated — constraining them here (post
        projection + RoPE) makes GSPMD gather the small [B,S,Hkv,hd] heads
        instead of the full residual stream (see EXPERIMENTS.md §Perf)."""
        if k.ndim != 4:
            return k
        b_ok = _divisible(k.shape[0], self.data_size)
        bspec = self.batch_axes if b_ok else None
        if _divisible(k.shape[2], self.model_size):
            return self._c(k, bspec, None, "model", None)
        return self._c(k, bspec, None, None, None)

    def ffn(self, h):
        """[B, S, F] — hidden dim over model."""
        if h.ndim != 3 or not _divisible(h.shape[-1], self.model_size):
            return h
        b_ok = _divisible(h.shape[0], self.data_size)
        return self._c(h, self.batch_axes if b_ok else None, None, "model")

    def moe(self, buf):
        """[E, C, D] dispatch buffer — experts over model when EP."""
        if self.expert_parallel and _divisible(buf.shape[0], self.model_size):
            return self._c(buf, "model", None, None)
        return buf

    def logits(self, logits):
        """Sequence-sharded logits (Megatron seq-parallel CE): softmax and
        take_along_axis stay local, no vocab collectives; the unembedding
        columns are gathered once instead."""
        if logits.ndim != 3:
            return logits
        b_ok = _divisible(logits.shape[0], self.data_size)
        bspec = self.batch_axes if b_ok else None
        s_ok = self.seq_parallel and _divisible(logits.shape[1],
                                                self.model_size) \
            and logits.shape[1] > 1
        if s_ok:
            return self._c(logits, bspec, "model", None)
        if _divisible(logits.shape[-1], self.model_size):
            return self._c(logits, bspec, None, "model")
        return self._c(logits, bspec, None, None)

    # ------------------------------------------------------------ inputs
    def batch_spec(self, shape: Tuple[int, ...]) -> NamedSharding:
        """Tokens/labels/features: shard leading batch dim when divisible."""
        b_ok = _divisible(shape[0], self.data_size)
        return self.ns(self.batch_axes if b_ok else None,
                       *(None,) * (len(shape) - 1))

    def cache_specs(self, cache) -> Dict:
        """KV/ring/SSM caches. [B, L, Hkv, hd]: batch over data, kv-heads
        over model when divisible, else cache length over model."""
        def leaf(path, a):
            name = path[-1]
            lead = (None,) if path_has_cycles(path) else ()
            body = a.shape[1:] if path_has_cycles(path) else a.shape
            bspec = self.batch_axes if _divisible(body[0], self.data_size) \
                else None
            if name in ("k", "v"):
                if _divisible(body[2], self.model_size):
                    spec = (bspec, None, "model", None)
                elif _divisible(body[1], self.model_size):
                    spec = (bspec, "model", None, None)
                else:
                    spec = (bspec, None, None, None)
            elif name == "state":      # [B, H, P, N]
                if _divisible(body[2], self.model_size):
                    spec = (bspec, None, "model", None)
                else:
                    spec = (bspec, None, None, None)
            elif name == "conv":       # [B, W-1, Ch]
                if _divisible(body[2], self.model_size):
                    spec = (bspec, None, "model")
                else:
                    spec = (bspec, None, None)
            elif name == "h":          # [B, W]
                if _divisible(body[1], self.model_size):
                    spec = (bspec, "model")
                else:
                    spec = (bspec, None)
            else:
                spec = tuple(None for _ in body)
            return self.ns(*(lead + tuple(spec)))

        def path_has_cycles(path):
            return "cycles" in path

        def rec(tree, path=()):
            if isinstance(tree, dict):
                return {k: rec(v, path + (k,)) for k, v in tree.items()}
            if isinstance(tree, (list, tuple)):
                return [rec(v, path + (str(i),)) for i, v in enumerate(tree)]
            return leaf(path, tree)
        return rec(cache)

    def report(self) -> str:
        cfg = self.cfg
        lines = [f"policy for {cfg.name} on mesh {dict(self.mesh.shape)}:"]
        lines.append(f"  attention: {'head-TP' if self.attn_head_sharded else 'context-parallel fallback (heads % model != 0)'}")
        if cfg.moe is not None:
            lines.append(f"  moe: {'expert-parallel' if self.expert_parallel else 'TP-within-expert'}")
        lines.append(f"  seq_parallel={self.seq_parallel} fsdp={self.fsdp_axis}")
        return "\n".join(lines)
