"""mamba2-130m — attention-free SSM with SSD (state-space duality).

[arXiv:2405.21060] 24 layers, d_model 768, no attention / no FFN (the SSD
mixer is the whole block), vocab 50280, state 128, head_dim 64, expand 2.
"""
from repro.configs.base import SSD, ModelConfig, SSMConfig

CONFIG = ModelConfig(
    name="mamba2-130m", arch_type="ssm",
    n_layers=24, d_model=768, n_heads=0, n_kv_heads=0, d_ff=0,
    vocab_size=50_280, block_pattern=(SSD,), rope=False,
    ssm=SSMConfig(state_dim=128, head_dim=64, expand=2, conv_width=4,
                  chunk=256),
    tie_embeddings=True,
    source="arXiv:2405.21060",
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(
        n_layers=2, d_model=128, vocab_size=512,
        ssm=SSMConfig(state_dim=16, head_dim=16, expand=2, conv_width=4,
                      chunk=8))
