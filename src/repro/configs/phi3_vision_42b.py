"""phi-3-vision-4.2b — VLM: phi3-mini language backbone + CLIP vision stub.

[hf:microsoft/Phi-3-vision-128k-instruct] LM backbone: 32 layers,
d_model 3072, 32 heads (MHA), d_ff 8192, vocab 32064. The CLIP ViT-L/14
tower + projector is a STUB frontend emitting 576 patch embeddings of
dim 1024 that are prepended to the text tokens.
"""
from repro.configs.base import ATTN_GLOBAL, ModelConfig

CONFIG = ModelConfig(
    name="phi-3-vision-4.2b", arch_type="vlm",
    n_layers=32, d_model=3072, n_heads=32, n_kv_heads=32, head_dim=96,
    d_ff=8192, vocab_size=32_064, block_pattern=(ATTN_GLOBAL,),
    mlp_act="silu", mlp_gated=True,
    frontend="vision_stub", frontend_tokens=576, frontend_dim=1024,
    source="hf:microsoft/Phi-3-vision-128k-instruct",
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(n_layers=2, d_model=128, n_heads=4, n_kv_heads=4,
                          head_dim=32, d_ff=256, vocab_size=512,
                          frontend_tokens=8, frontend_dim=32)
