"""gemma3-1b — dense decoder with 5:1 local:global attention, 128k context.

[hf:google/gemma-3-1b-pt] 26 layers, d_model 1152, 4 Q heads / 1 KV head
(head_dim 256), d_ff 6912, vocab 262144, sliding window 512 on local
layers; pattern = 5 local + 1 global (layers 5, 11, 17, 23 global, final
2 layers local remainder).
"""
from repro.configs.base import ATTN_GLOBAL, ATTN_LOCAL, ModelConfig

CONFIG = ModelConfig(
    name="gemma3-1b", arch_type="dense",
    n_layers=26, d_model=1152, n_heads=4, n_kv_heads=1, head_dim=256,
    d_ff=6912, vocab_size=262_144,
    block_pattern=(ATTN_LOCAL,) * 5 + (ATTN_GLOBAL,), window=512,
    mlp_act="gelu", mlp_gated=True, rope_theta=1_000_000.0,
    tie_embeddings=True, logit_softcap=30.0,
    source="hf:google/gemma-3-1b-pt",
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(n_layers=7, d_model=128, n_heads=4, n_kv_heads=1,
                          head_dim=32, d_ff=256, vocab_size=512, window=8)
