"""Config registry: ``--arch <id>`` resolution for launchers and tests."""
from __future__ import annotations

import importlib
from typing import Dict

from repro.configs.base import (D2FTConfig, InputShape, INPUT_SHAPES,
                                ModelConfig)

# arch id -> module name
ARCH_MODULES: Dict[str, str] = {
    "recurrentgemma-2b": "recurrentgemma_2b",
    "mamba2-130m": "mamba2_130m",
    "qwen1.5-32b": "qwen15_32b",
    "hubert-xlarge": "hubert_xlarge",
    "mixtral-8x22b": "mixtral_8x22b",
    "stablelm-3b": "stablelm_3b",
    "moonshot-v1-16b-a3b": "moonshot_v1_16b_a3b",
    "phi-3-vision-4.2b": "phi3_vision_42b",
    "gemma3-1b": "gemma3_1b",
    "olmoe-1b-7b": "olmoe_1b_7b",
}

ARCH_IDS = tuple(ARCH_MODULES)


def _module(arch: str):
    if arch not in ARCH_MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(ARCH_MODULES)}")
    return importlib.import_module(f"repro.configs.{ARCH_MODULES[arch]}")


def get_config(arch: str) -> ModelConfig:
    return _module(arch).CONFIG


def get_smoke_config(arch: str) -> ModelConfig:
    return _module(arch).smoke_config()


# (arch, shape) pairs skipped by design — see DESIGN.md "Shape skips".
SKIPS = {
    ("hubert-xlarge", "decode_32k"): "encoder-only: no decode step",
    ("hubert-xlarge", "long_500k"): "encoder-only: no decode step",
    ("qwen1.5-32b", "long_500k"): "pure full attention: no sub-quadratic path",
    ("stablelm-3b", "long_500k"): "pure full attention: no sub-quadratic path",
    ("moonshot-v1-16b-a3b", "long_500k"): "pure full attention: no sub-quadratic path",
    ("phi-3-vision-4.2b", "long_500k"): "pure full attention: no sub-quadratic path",
    ("olmoe-1b-7b", "long_500k"): "pure full attention: no sub-quadratic path",
}


def live_pairs():
    for arch in ARCH_IDS:
        for shape in INPUT_SHAPES:
            if (arch, shape) not in SKIPS:
                yield arch, shape
