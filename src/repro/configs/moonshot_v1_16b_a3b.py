"""moonshot-v1-16b-a3b — fine-grained MoE (Moonlight / DeepSeek-V3 style).

[hf:moonshotai/Moonlight-16B-A3B] 48 layers, d_model 2048, 16 heads,
64 routed experts top-6 with expert d_ff 1408 + 2 shared experts,
vocab 163840. ~3B active parameters.
"""
from repro.configs.base import ATTN_GLOBAL, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="moonshot-v1-16b-a3b", arch_type="moe",
    n_layers=48, d_model=2048, n_heads=16, n_kv_heads=16, head_dim=128,
    d_ff=0, vocab_size=163_840, block_pattern=(ATTN_GLOBAL,),
    moe=MoEConfig(n_experts=64, top_k=6, d_ff=1408, n_shared_experts=2),
    mlp_act="silu",
    source="hf:moonshotai/Moonlight-16B-A3B",
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(n_layers=2, d_model=128, n_heads=4, n_kv_heads=4,
                          head_dim=32, vocab_size=512,
                          moe=MoEConfig(n_experts=4, top_k=2, d_ff=64,
                                        n_shared_experts=1))
