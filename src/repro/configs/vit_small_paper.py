"""ViT-small — the paper's own experimental model (§III-A).

12 transformer blocks, 6 heads, d_model 384, d_ff 1536, patch 16,
input 224x224; used for the paper-validation benchmarks (Fig. 1/2,
Tables I-X) on synthetic CIFAR-like data.
"""
from repro.models.vit import ViTConfig

CONFIG = ViTConfig(n_layers=12, d_model=384, n_heads=6, d_ff=1536,
                   patch=16, image_size=224, n_classes=10)


def smoke_config() -> ViTConfig:
    return ViTConfig(n_layers=2, d_model=96, n_heads=6, d_ff=192,
                     patch=8, image_size=32, n_classes=10)
