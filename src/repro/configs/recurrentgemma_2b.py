"""recurrentgemma-2b — hybrid RG-LRU + local attention, 1:2 ratio.

[arXiv:2402.19427] Griffin/RecurrentGemma: repeating (recurrent, recurrent,
local-attn) pattern; 26 layers, d_model 2560, 10 Q heads with 1 KV head
(GQA), d_ff 7680, vocab 256000, local attention window 2048.
"""
from repro.configs.base import (ATTN_LOCAL, RGLRU, ModelConfig, RGLRUConfig)

CONFIG = ModelConfig(
    name="recurrentgemma-2b", arch_type="hybrid",
    n_layers=26, d_model=2560, n_heads=10, n_kv_heads=1, head_dim=256,
    d_ff=7680, vocab_size=256_000,
    block_pattern=(RGLRU, RGLRU, ATTN_LOCAL), window=2048,
    mlp_act="gelu", mlp_gated=True, norm="rms",
    rglru=RGLRUConfig(lru_width=2560, conv_width=4),
    source="arXiv:2402.19427",
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(
        n_layers=3, d_model=128, n_heads=4, n_kv_heads=1, head_dim=32,
        d_ff=256, vocab_size=512, window=16,
        rglru=RGLRUConfig(lru_width=128, conv_width=4))
