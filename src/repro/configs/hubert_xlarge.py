"""hubert-xlarge — encoder-only audio transformer (w2v2-style backbone).

[arXiv:2106.07447] 48 layers, d_model 1280, 16 heads, d_ff 5120,
output vocabulary 504 (k-means targets). The mel + conv feature extractor
is a STUB frontend providing per-frame embeddings (frontend_dim 512);
encoder-only => bidirectional attention, no decode shapes.
"""
from repro.configs.base import ATTN_GLOBAL, ModelConfig

CONFIG = ModelConfig(
    name="hubert-xlarge", arch_type="audio",
    n_layers=48, d_model=1280, n_heads=16, n_kv_heads=16, head_dim=80,
    d_ff=5120, vocab_size=504, causal=False, rope=False,
    block_pattern=(ATTN_GLOBAL,), mlp_act="gelu", mlp_gated=False,
    norm="layer", frontend="audio_stub", frontend_dim=512,
    source="arXiv:2106.07447",
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(n_layers=2, d_model=128, n_heads=4, n_kv_heads=4,
                          head_dim=32, d_ff=256, vocab_size=64,
                          frontend_dim=32)
