"""Model / run configuration dataclasses.

Every assigned architecture gets one ``<id>.py`` in this package exporting
``CONFIG`` (the full-size config, exercised only via the dry-run) and
``smoke_config()`` (a reduced variant of the same family for CPU tests).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Sequence, Tuple

# Block kinds composable into a per-layer pattern.
ATTN_GLOBAL = "attn_global"      # full (causal or bidirectional) attention
ATTN_LOCAL = "attn_local"        # sliding-window attention
SSD = "ssd"                      # Mamba-2 state-space duality block
RGLRU = "rglru"                  # Griffin RG-LRU recurrent block

BLOCK_KINDS = (ATTN_GLOBAL, ATTN_LOCAL, SSD, RGLRU)


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_ff: int                     # per-expert hidden dim
    n_shared_experts: int = 0     # DeepSeek/Moonlight-style shared experts
    capacity_factor: float = 1.25
    router_z_loss: float = 1e-3
    aux_loss: float = 1e-2


@dataclass(frozen=True)
class SSMConfig:
    state_dim: int = 128          # N (SSD state size)
    head_dim: int = 64            # P
    expand: int = 2               # d_inner = expand * d_model
    conv_width: int = 4
    chunk: int = 256              # SSD chunk length


@dataclass(frozen=True)
class RGLRUConfig:
    lru_width: Optional[int] = None   # default: d_model
    conv_width: int = 4
    n_heads: Optional[int] = None     # block-diagonal gating heads


@dataclass(frozen=True)
class ModelConfig:
    name: str
    arch_type: str                    # dense|moe|ssm|hybrid|audio|vlm
    n_layers: int
    d_model: int
    n_heads: int                      # query heads (0 for attention-free)
    n_kv_heads: int
    d_ff: int                         # dense MLP hidden (0 if pure MoE/SSM)
    vocab_size: int
    head_dim: Optional[int] = None    # default d_model // n_heads
    # per-layer block pattern, cycled over n_layers. A trailing partial
    # cycle is allowed (e.g. gemma3: 5 local + 1 global over 26 layers).
    block_pattern: Tuple[str, ...] = (ATTN_GLOBAL,)
    window: int = 0                   # sliding window for ATTN_LOCAL
    causal: bool = True               # False for encoder-only (hubert)
    qkv_bias: bool = False
    mlp_act: str = "silu"             # silu|gelu
    mlp_gated: bool = True
    norm: str = "rms"                 # rms|layer
    rope_theta: float = 10_000.0
    rope: bool = True
    tie_embeddings: bool = False
    logit_softcap: float = 0.0
    moe: Optional[MoEConfig] = None
    ssm: Optional[SSMConfig] = None
    rglru: Optional[RGLRUConfig] = None
    frontend: str = "none"            # none|audio_stub|vision_stub
    # VLM/audio stub frontends: number of prepended embedding tokens the
    # stub produces per sample (the transformer consumes [emb; text]).
    frontend_tokens: int = 0
    frontend_dim: int = 0             # raw feature dim fed to the projector
    # Source citation from the assignment table.
    source: str = ""
    param_dtype: str = "float32"
    compute_dtype: str = "float32"

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim is not None:
            return self.head_dim
        assert self.n_heads > 0
        return self.d_model // self.n_heads

    @property
    def layer_kinds(self) -> Tuple[str, ...]:
        """Block kind of each of the n_layers layers (pattern cycled)."""
        pat = self.block_pattern
        return tuple(pat[i % len(pat)] for i in range(self.n_layers))

    @property
    def is_decoder(self) -> bool:
        return self.causal

    @property
    def supports_long_context(self) -> bool:
        """True if no layer requires O(S^2) global attention state growth.

        Used only for documentation; shape skips are listed in launch/shapes.
        """
        return all(k != ATTN_GLOBAL for k in self.layer_kinds)

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str                         # train|prefill|decode


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


@dataclass(frozen=True)
class D2FTConfig:
    """Scheduler configuration for Distributed Dynamic Fine-Tuning."""
    n_microbatches: int = 5           # micro-batches per batch (paper: 5)
    # Budget expressed as number of micro-batches per subnet per batch.
    n_pf: int = 3                     # micro-batches doing full fwd+bwd
    n_po: int = 1                     # micro-batches doing forward-only
    # Relative costs (paper Table IV: fwd ~= 40% of fwd+bwd).
    cost_fwd: float = 0.4
    cost_bwd: float = 0.6
    backward_score: str = "weight_magnitude"   # paper's final choice
    forward_score: str = "fisher"
    head_groups: int = 0              # subnets per layer (0 = n_heads)
    mode: str = "packed"              # packed|masked
