"""qwen1.5-32b — dense decoder with QKV bias.

[hf:Qwen/Qwen1.5-0.5B family] 64 layers, d_model 5120, 40 heads (GQA kv=40
i.e. MHA), d_ff 27392, vocab 152064, QKV bias, SwiGLU, RMSNorm, RoPE.
"""
from repro.configs.base import ATTN_GLOBAL, ModelConfig

CONFIG = ModelConfig(
    name="qwen1.5-32b", arch_type="dense",
    n_layers=64, d_model=5120, n_heads=40, n_kv_heads=40, head_dim=128,
    d_ff=27392, vocab_size=152_064, qkv_bias=True,
    block_pattern=(ATTN_GLOBAL,), mlp_act="silu", mlp_gated=True,
    rope_theta=1_000_000.0,
    source="hf:Qwen/Qwen1.5-0.5B",
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(n_layers=2, d_model=128, n_heads=4, n_kv_heads=4,
                          head_dim=32, d_ff=256, vocab_size=512)
