"""stablelm-3b — dense decoder (stablelm-2 family scaled).

[hf:stabilityai/stablelm-2-1_6b] 32 layers, d_model 2560, 32 heads (MHA),
d_ff 6912, vocab 50304, SwiGLU-style gated MLP, RoPE (full, simplified
from the model's 25% partial rotary — noted in DESIGN.md).
"""
from repro.configs.base import ATTN_GLOBAL, ModelConfig

CONFIG = ModelConfig(
    name="stablelm-3b", arch_type="dense",
    n_layers=32, d_model=2560, n_heads=32, n_kv_heads=32, head_dim=80,
    d_ff=6912, vocab_size=50_304, block_pattern=(ATTN_GLOBAL,),
    mlp_act="silu", mlp_gated=True, norm="layer",
    source="hf:stabilityai/stablelm-2-1_6b",
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(n_layers=2, d_model=128, n_heads=4, n_kv_heads=4,
                          head_dim=32, d_ff=256, vocab_size=512)
