"""olmoe-1b-7b — fully open MoE, 64 experts top-8.

[arXiv:2409.02060] 16 layers, d_model 2048, 16 heads (MHA), 64 experts
top-8 with expert d_ff 1024, vocab 50304. ~1B active / 7B total.
"""
from repro.configs.base import ATTN_GLOBAL, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="olmoe-1b-7b", arch_type="moe",
    n_layers=16, d_model=2048, n_heads=16, n_kv_heads=16, head_dim=128,
    d_ff=0, vocab_size=50_304, block_pattern=(ATTN_GLOBAL,),
    moe=MoEConfig(n_experts=64, top_k=8, d_ff=1024),
    mlp_act="silu",
    source="arXiv:2409.02060",
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(n_layers=2, d_model=128, n_heads=4, n_kv_heads=4,
                          head_dim=32, vocab_size=512,
                          moe=MoEConfig(n_experts=4, top_k=2, d_ff=64))
