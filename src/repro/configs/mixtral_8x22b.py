"""mixtral-8x22b — sparse MoE decoder, 8 experts top-2, sliding-window attn.

[arXiv:2401.04088] 56 layers, d_model 6144, 48 heads (GQA kv=8), expert
d_ff 16384, vocab 32768, SWA window 4096 (per assignment card). All FFNs
are routed (d_ff=0 dense).
"""
from repro.configs.base import ATTN_LOCAL, ModelConfig, MoEConfig

CONFIG = ModelConfig(
    name="mixtral-8x22b", arch_type="moe",
    n_layers=56, d_model=6144, n_heads=48, n_kv_heads=8, head_dim=128,
    d_ff=0, vocab_size=32_768, block_pattern=(ATTN_LOCAL,), window=4096,
    moe=MoEConfig(n_experts=8, top_k=2, d_ff=16384),
    mlp_act="silu", rope_theta=1_000_000.0,
    source="arXiv:2401.04088",
)


def smoke_config() -> ModelConfig:
    return CONFIG.replace(n_layers=2, d_model=128, n_heads=4, n_kv_heads=2,
                          head_dim=32, vocab_size=512, window=16,
                          moe=MoEConfig(n_experts=4, top_k=2, d_ff=128))
