"""GPipe-style microbatch pipeline over shard_map stages.

Runs INSIDE the shard_map body of the distributed train step, on a mesh
with a ``stage`` axis (``launch.parallel.MeshSpec``). Each stage device
owns a contiguous layer range chosen by the schedule-aware assigner
(``core.assignment.assign_stages`` — stages balanced by *live* cost, not
layer count) and the local batch is split into M microbatches that flow
stage-to-stage through ``lax.ppermute``:

* round t, stage s works microbatch ``m = clip(t - s, 0, M-1)`` and is
  *active* when ``0 <= t - s < M`` — the classic GPipe diagonal with
  ``M + S - 1`` rounds and bubble fraction ``(S - 1) / (M + S - 1)`` in
  round units (``analytic_bubble_fraction`` weights it by stage loads).
* stage 0 embeds its microbatch; every other stage consumes the
  activation ppermuted from stage s-1 at the end of the previous round.
* ``lax.switch`` on ``axis_index("stage")`` selects the device's static
  layer range, so the single SPMD program stays one trace.
* only the last stage runs the LM head; inactive-round and
  non-last-stage contributions are ``where``/``cond``-masked to exact
  zeros, so garbage activations in the bubble never touch the loss or
  any gradient.

The returned per-device loss/grads are PARTIAL (each stage's own layers,
last stage's head): the step body psums loss, metrics and the grad tree
over the stage axis — each parameter is touched by exactly the stage(s)
that own it (the tied embedding by stage 0's lookup and the last stage's
unembed), so the psum reassembles full-batch grads without double
counting. After that the existing data-axis sync (masked / ZeRO-1 /
ZeRO-3) applies unchanged, and the D2FT gates ride along per microbatch
— a stage whose slice of the schedule is dead contributes zeros exactly
as in the single-stage gated path.
"""
from __future__ import annotations

from typing import Optional, Sequence, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.layers import apply_embedding, apply_norm, softcap
from repro.models.transformer import apply_block, fused_xent, layer_groups


# ------------------------------------------------------------- trace hooks
class PipelineRecorder:
    """Trace-time pipeline counters (mirrors ``sync.ResidencyRecorder``):
    filled while the step traces, checked against the analytic round/send
    model by ``report()`` — the "via trace hooks" half of the bubble
    accounting."""

    def __init__(self):
        self.boundaries: Optional[Tuple[int, ...]] = None
        self.n_microbatches: Optional[int] = None
        self.rounds: list = []
        self.n_sends: int = 0

    def setup(self, boundaries, n_microbatches: int):
        self.boundaries = tuple(int(b) for b in boundaries)
        self.n_microbatches = int(n_microbatches)
        self.rounds = []
        self.n_sends = 0

    def round(self, t: int):
        self.rounds.append(int(t))

    def send(self):
        self.n_sends += 1

    @property
    def n_rounds(self) -> int:
        return len(self.rounds)

    def report(self) -> dict:
        S = len(self.boundaries) - 1
        M = self.n_microbatches
        expected_rounds = M + S - 1
        expected_sends = max(expected_rounds - 1, 0)
        return {
            "n_stages": S,
            "n_microbatches": M,
            "n_rounds": self.n_rounds,
            "n_sends": self.n_sends,
            "expected_rounds": expected_rounds,
            "expected_sends": expected_sends,
            "trace_ok": (self.n_rounds == expected_rounds
                         and self.n_sends == expected_sends),
        }


def analytic_bubble_fraction(loads: Sequence[float],
                             n_microbatches: int) -> float:
    """Idle fraction of the GPipe schedule under per-stage loads c_s:
    total time ~ (M + S - 1) * max(c), useful work per device ~ M *
    mean(c) — so bubble = 1 - M * mean(c) / ((M + S - 1) * max(c)).
    Uniform loads reduce to the classic (S - 1) / (M + S - 1)."""
    loads = np.asarray(loads, np.float64)
    S, M = len(loads), int(n_microbatches)
    cmax = float(loads.max())
    if cmax <= 0:
        return 0.0
    return float(1.0 - (M * loads.mean()) / ((M + S - 1) * cmax))


# ------------------------------------------------------------ layer access
def _layer_params(params, cfg: ModelConfig, layer: int):
    """(block params, kind) for one layer — static cycle index, so the
    per-stage branches bake their layer ranges into the trace."""
    n_cycles, pat, rem = layer_groups(cfg)
    P = len(pat)
    if layer < n_cycles * P:
        c, i = divmod(layer, P)
        return jax.tree.map(lambda a: a[c], params["cycles"][i]), pat[i]
    j = layer - n_cycles * P
    return params["rest"][j], rem[j]


# ------------------------------------------------------------ pipeline loss
def pipeline_loss(params, cfg: ModelConfig, tokens, labels, gates, *,
                  boundaries: Sequence[int], n_microbatches: int,
                  stage_axis: str = "stage", tp=None, recorder=None):
    """Per-device pipelined gated LM loss (call inside shard_map).

    tokens/labels: this data shard's [B_loc, T]; gates: (g_f, g_b) each
    [L, B_loc, G] or None; boundaries: the stage assigner's (S+1,) layer
    boundaries (replicated constant). Returns (loss, {"ce", "aux"}) —
    PARTIAL per device; psum over ``stage_axis`` completes them (see
    module docstring). ``tp`` threads the tensor-parallel spec into each
    block; ``recorder`` is a ``PipelineRecorder`` trace hook."""
    boundaries = tuple(int(b) for b in boundaries)
    S_stages = len(boundaries) - 1
    M = int(n_microbatches)
    assert boundaries[0] == 0 and boundaries[-1] == cfg.n_layers, boundaries
    assert all(b2 > b1 for b1, b2 in zip(boundaries, boundaries[1:])), \
        f"empty pipeline stage in {boundaries}"
    B, T = tokens.shape
    assert B % M == 0, f"microbatches {M} must divide local batch {B}"
    mb = B // M
    cdt = jnp.dtype(cfg.compute_dtype)
    sid = jax.lax.axis_index(stage_axis)

    tok_m = tokens.reshape(M, mb, T)
    lab_m = labels.reshape(M, mb, T)
    if gates is not None:
        g_f, g_b = gates
        L, _, G = g_f.shape
        gf_m = g_f.reshape(L, M, mb, G)
        gb_m = g_b.reshape(L, M, mb, G)

    def stage_fn(lo, hi):
        def run(x, gf_t, gb_t):
            aux = jnp.zeros((), jnp.float32)
            for layer in range(lo, hi):
                blk, kind = _layer_params(params, cfg, layer)
                lg = (gf_t[layer], gb_t[layer]) if gates is not None \
                    else None
                x, a = apply_block(blk, x, kind, cfg, lg, None, False, None,
                                   tp)
                if a is not None:
                    aux = aux + a["load_balance"] + a["router_z"]
            return x, aux
        return run

    branches = [stage_fn(boundaries[s], boundaries[s + 1])
                for s in range(S_stages)]

    def head_ce(y, lab_t):
        xf = apply_norm(params["final_norm"], y, cfg.norm)
        if cfg.tie_embeddings:
            logits = xf @ params["embed"]["table"].T.astype(cdt)
        else:
            logits = xf @ params["unembed"].astype(cdt)
        logits = softcap(logits, cfg.logit_softcap)
        return fused_xent(logits, lab_t)

    n_rounds = M + S_stages - 1
    perm = [(s, s + 1) for s in range(S_stages - 1)]
    if recorder is not None:
        recorder.setup(boundaries, M)
    x_recv = jnp.zeros((mb, T, cfg.d_model), cdt)
    ce_acc = jnp.zeros((), jnp.float32)
    aux_acc = jnp.zeros((), jnp.float32)
    for t in range(n_rounds):
        if recorder is not None:
            recorder.round(t)
        m = jnp.clip(t - sid, 0, M - 1)
        active = (t - sid >= 0) & (t - sid < M)
        tok_t = jax.lax.dynamic_index_in_dim(tok_m, m, 0, keepdims=False)
        lab_t = jax.lax.dynamic_index_in_dim(lab_m, m, 0, keepdims=False)
        x0 = apply_embedding(params["embed"], tok_t).astype(cdt)
        x_in = jnp.where(sid == 0, x0, x_recv)
        if gates is not None:
            gf_t = jax.lax.dynamic_index_in_dim(gf_m, m, 1, keepdims=False)
            gb_t = jax.lax.dynamic_index_in_dim(gb_m, m, 1, keepdims=False)
        else:
            gf_t = gb_t = jnp.zeros((), cdt)     # unused placeholder
        y, aux = jax.lax.switch(sid, branches, x_in, gf_t, gb_t)
        # inactive rounds compute on bubble garbage (finite: zeros or a
        # re-run microbatch) — mask their aux and skip the head entirely
        aux_acc = aux_acc + jnp.where(active, aux, 0.0)
        ce_acc = ce_acc + jax.lax.cond(
            active & (sid == S_stages - 1),
            head_ce, lambda y_, l_: jnp.zeros((), jnp.float32), y, lab_t)
        if t < n_rounds - 1 and S_stages > 1:
            if recorder is not None:
                recorder.send()
            x_recv = jax.lax.ppermute(y, stage_axis, perm)
    ce = ce_acc / M
    aux = aux_acc / M
    return ce + aux, {"ce": ce, "aux": aux}
