"""Elastic fault-tolerant D2FT fine-tuning (docs/robustness.md).

``finetune_elastic`` wraps the distributed D2FT machinery of
``train/loop.py`` with the four responses a commodity fleet needs, driven
by a deterministic ``launch.faults.FaultPlan``:

* **straggler-aware replanning** — the loop keeps an EMA of measured
  per-device step time per unit of assigned schedule cost; when the
  spread exceeds ``straggler_tol`` the per-refresh knapsack runs with
  ``core.assignment.speed_capacities`` budgets, shifting p_f-heavy
  micro-batches off slow devices. Every refresh logs a rebalance report
  extended with the predicted makespan with and without mitigation.
* **device-dropout recovery** — a dropout shrinks the fleet to the
  largest survivor count that still divides the micro-batch count
  (equal shard_map shards), restores the last step-level checkpoint
  (always saved in canonical element order), re-runs the assigner over
  the survivors and re-lays ZeRO-1/ZeRO-3 shards out for the new mesh
  via ``sharding.sync.zero_reshard``. Replay from the checkpoint is
  bit-exact: a recovered run equals a fresh ``resume_from`` run on the
  shrunk mesh.
* **non-finite-grad guard** — every step runs with the pre-sync
  per-subnet anomaly guard armed (``make_distributed_train_step``
  ``guard=True``): a NaN/inf burst (or a grad-norm spike past
  ``guard_factor`` x the norm EMA) on one replica zeroes that replica's
  contribution before the pmean and skips the global update.
* **degraded-sync fallback** — each injected dropped sync round discards
  that step's update; once ``sync_fault_threshold`` rounds have been
  lost the loop gives up on collectives entirely and switches to the
  lo-fi ``sync_mode="local"``: per-replica stacked state, zero gradient
  sync, and a masked weight merge (``sharding.sync.lofi_merge``) every
  ``merge_every`` steps under the union of backward-live masks since the
  replicas were last in sync.

Everything the loop decides is recorded in ``log.extras["elastic"]``
(events, recoveries, guard skips, merges, final mode) and per-refresh in
``log.extras["refreshes"]`` — the fault-matrix tests and
``BENCH_elastic.json`` read those records.
"""
from __future__ import annotations

import os
import tempfile
import time
from dataclasses import dataclass
from typing import Iterable, Optional

import numpy as np

import jax

from repro.configs.base import D2FTConfig, ModelConfig
from repro.core.assignment import (device_sample_order,
                                   distributed_live_bounds,
                                   microbatch_costs, plan_device_assignment,
                                   speed_capacities, weighted_makespan)
from repro.core.schedule import P_F, P_S, Schedule, gates_from_schedule
from repro.data.synthetic import microbatch_assignment
from repro.launch.faults import NO_FAULTS, FaultPlan
from repro.models.transformer import lm_loss
from repro.optim.optimizers import Optimizer
from repro.sharding.sync import (backward_live_groups, grad_sync_plan,
                                 lofi_merge, stack_replicas,
                                 sync_byte_report, zero_reshard)
from repro.train.checkpoints import load_train_state, save_train_state
from repro.train.loop import (TrainLog, _reshard_opt_state,
                              make_distributed_train_step, plan_from_scores)


@dataclass
class ElasticConfig:
    """Policy knobs of the elastic loop (see module docstring)."""
    refresh_every: Optional[int] = None   # re-score the schedule every k
    ckpt_every: int = 1                   # step-level checkpoint cadence
    ckpt_dir: Optional[str] = None        # default: a fresh temp dir
    ema_alpha: float = 0.5                # step-time / grad-norm EMA weight
    capacity_slack: float = 1.1           # speed_capacities feasibility slack
    straggler_tol: float = 0.15           # engage capacities past this spread
    guard_factor: Optional[float] = 10.0  # norm-anomaly thresh = f * EMA
    sync_fault_threshold: int = 2         # dropped syncs before lo-fi
    merge_every: int = 4                  # lo-fi merge cadence (steps)


def feasible_survivor_count(n_devices: int, n_microbatches: int) -> int:
    """Largest fleet size < n_devices that still divides the micro-batch
    count — the shard_map step needs equal shards, so after a 1-device
    dropout the mesh shrinks to the nearest feasible size (8 -> 4 for 8
    micro-batches) rather than an un-shardable 7."""
    for n in range(n_devices - 1, 0, -1):
        if n_microbatches % n == 0:
            return n
    return 1


def _mask_schedule(mask: np.ndarray) -> Schedule:
    """[L, G] bool liveness -> a one-micro-batch Schedule whose
    backward-live set is exactly the mask (feeds ``grad_sync_plan`` to
    build the lo-fi merge plan)."""
    table = np.where(np.asarray(mask, bool).reshape(-1, 1), P_F,
                     P_S).astype(np.int8)
    return Schedule(table, mask.shape[0], mask.shape[1])


def _shapes_of(tree):
    return jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)


def finetune_elastic(params, cfg: ModelConfig, d2: D2FTConfig,
                     opt: Optimizer, batches: Iterable, *, steps: int,
                     mesh, sync_mode: str = "masked",
                     faults: Optional[FaultPlan] = None,
                     elastic: Optional[ElasticConfig] = None,
                     use_kernel: bool = False, clip: float = 1.0,
                     rng=None, resume_from: Optional[str] = None,
                     log: Optional[TrainLog] = None) -> tuple:
    """Elastic distributed D2FT fine-tuning (see module docstring).

    batches must be a deterministic, restartable-by-index stream (they
    are buffered internally so a recovery can replay from the checkpoint
    step). ``resume_from`` restores a ``save_train_state`` checkpoint —
    on the original mesh size or a shrunk one (the assigner and the ZeRO
    layouts are rebuilt for whatever ``mesh`` is passed). Returns
    (params, opt_state, log) in canonical order/layout; in local mode the
    replicas are merged one last time before returning."""
    from repro.data.synthetic import split_microbatches
    from repro.launch.mesh import make_data_mesh

    fp = faults or NO_FAULTS
    el = elastic or ElasticConfig()
    log = log or TrainLog()
    assert sync_mode in ("masked", "zero", "zero3", "local"), sync_mode
    ckpt_dir = el.ckpt_dir or tempfile.mkdtemp(prefix="elastic_ckpt_")

    ndev = mesh.shape["data"]
    mode = sync_mode
    opt_state = opt.init(params)
    if mode == "local":
        # lo-fi from step 0: state lives per-replica stacked from the start
        params = stack_replicas(params, ndev)
        opt_state = stack_replicas(opt_state, ndev)
    speeds = np.ones(ndev)                # EMA of per-unit step time
    ema_gnorm: Optional[float] = None
    sync_faults = 0
    guard_skips = 0
    merges = 0
    next_refresh = el.refresh_every or 0
    sched = assignment = sync_plan = step_fn = None
    ever_live = None                      # zero-mode gather staleness mask
    live_since_merge = None               # local-mode divergence mask
    steps_since_merge = 0
    dropped = False
    events: list = []
    elastic_log = {"events": events, "ckpts": []}
    log.extras["elastic"] = elastic_log

    batch_buf: list = []
    batch_iter = iter(batches)

    def get_batch(idx: int):
        while len(batch_buf) <= idx:
            batch_buf.append(next(batch_iter))
        return batch_buf[idx]

    def canonical_state():
        """(params, opt_state) in canonical order whatever the mode."""
        if mode == "zero3":
            return (zero_reshard(params, sync_plan, None),
                    _reshard_opt_state(opt_state, sync_plan, None))
        if mode == "zero":
            return params, _reshard_opt_state(opt_state, sync_plan, None)
        return params, opt_state          # masked replicated / local stack

    def save_ckpt(step: int) -> str:
        p, s = canonical_state()
        extra = {
            "speeds": speeds,
            "ema_gnorm": np.nan if ema_gnorm is None else ema_gnorm,
            "sync_faults": sync_faults, "guard_skips": guard_skips,
            "merges": merges, "next_refresh": next_refresh,
            "local": 1 if mode == "local" else 0, "n_devices": ndev,
        }
        if ever_live is not None:
            extra["ever_live"] = ever_live
        if mode == "local" and live_since_merge is not None:
            extra["live_since_merge"] = live_since_merge
        path = os.path.join(ckpt_dir, f"ckpt_{step}.npz")
        save_train_state(path, step=step, params=p, opt_state=s,
                         sched=sched, assignment=assignment, rng=rng,
                         extra=extra)
        elastic_log["ckpts"].append({"step": step, "path": path})
        return path

    def restore(path: str):
        """Load a checkpoint into the loop state for the CURRENT ndev
        (assignment/plan/layout are rebuilt, the schedule is kept)."""
        nonlocal params, opt_state, sched, sync_plan, step_fn, speeds, \
            ema_gnorm, sync_faults, guard_skips, merges, next_refresh, \
            mode, ever_live, live_since_merge, steps_since_merge, assignment
        ck = load_train_state(path, params_template=None)
        params, opt_state = ck["params"], ck["opt_state"]
        sched = ck.get("schedule")
        extra = ck.get("extra", {})
        was_local = bool(int(extra.get("local", 0)))
        ck_ndev = int(extra.get("n_devices", ndev))
        spd = np.asarray(extra.get("speeds", np.ones(ndev)), np.float64)
        speeds = spd if len(spd) == ndev else np.ones(ndev)
        eg = float(extra.get("ema_gnorm", np.nan))
        ema_gnorm = None if np.isnan(eg) else eg
        sync_faults = int(extra.get("sync_faults", 0))
        guard_skips = int(extra.get("guard_skips", 0))
        merges = int(extra.get("merges", 0))
        next_refresh = int(extra.get("next_refresh", next_refresh))
        ev = extra.get("ever_live")
        ever_live = np.asarray(ev, bool) if ev is not None else None
        mode = sync_mode if sync_mode != "local" else "masked"
        if was_local and ck_ndev == ndev:
            mode = "local"
            lsm = extra.get("live_since_merge")
            live_since_merge = np.asarray(lsm, bool) if lsm is not None \
                else None
        elif was_local:
            # stacked state cannot survive a fleet resize: the checkpoint
            # stores the replica stack, so merge it and fall back to the
            # pre-degradation sync mode
            plan = grad_sync_plan(
                _shapes_of(jax.tree.map(lambda x: x[0], params)), cfg,
                _mask_schedule(np.asarray(extra["live_since_merge"], bool))
                if extra.get("live_since_merge") is not None
                else _mask_schedule(np.ones((cfg.n_layers,
                                             sched.n_groups), bool)))
            params = lofi_merge(params, plan)
            opt_state = jax.tree.map(lambda x: x[0], opt_state)
            mode = sync_mode if sync_mode != "local" else "masked"
        steps_since_merge = 0
        sync_plan = None
        assignment = None
        step_fn = None
        return int(ck["step"])

    def rescore(batch):
        """Fresh scoring pass -> new schedule (the expensive half of a
        refresh; the assigner/plan rebuild happens in ``rebuild``)."""
        score_params = params
        if mode == "zero3" and sync_plan is not None:
            score_params = zero_reshard(params, sync_plan, None)
        elif mode == "local":
            score_params = jax.tree.map(lambda x: x[0], params)
        mbs = split_microbatches(batch, d2.n_microbatches)
        return plan_from_scores(
            cfg, d2, score_params, mbs,
            lambda p, mb: lm_loss(p, cfg, mb.get("tokens"), mb["labels"],
                                  features=mb.get("features"))[0])

    def rebuild(step: int, run_mesh):
        """Schedule + current speeds -> assignment (capacity-mitigated
        when a straggler shows), sync plan, refresh record. Reshards
        zero/zero3 state between plan layouts."""
        nonlocal assignment, sync_plan, params, opt_state, ever_live, \
            live_since_merge
        costs = microbatch_costs(sched)
        caps = None
        if speeds.max() / speeds.min() > 1.0 + el.straggler_tol:
            caps = speed_capacities(costs, speeds, el.capacity_slack)
        old_plan = sync_plan
        assignment, report = plan_device_assignment(sched, ndev, caps)
        mitigation = {
            "unit_times": [round(float(u), 4) for u in speeds],
            "capacities": [round(float(c), 4) for c in caps]
            if caps is not None else None,
            "makespan": round(weighted_makespan(assignment, speeds), 6),
        }
        if caps is not None:
            base, _ = plan_device_assignment(sched, ndev, None)
            unmit = weighted_makespan(base, speeds)
            mitigation["unmitigated_makespan"] = round(unmit, 6)
            mitigation["mitigation_ratio"] = round(
                mitigation["makespan"] / unmit, 6) if unmit > 0 else 1.0
        if mode == "zero":
            prior = ever_live
            if ever_live is None:
                ever_live = np.zeros((cfg.n_layers, sched.n_groups), bool)
            sync_plan = grad_sync_plan(
                _template(), cfg, sched, mode="zero", n_shards=ndev,
                ever_live=prior, elide_gather=opt.elidable)
            ever_live = ever_live | backward_live_groups(sched)
            opt_state = _reshard_opt_state(opt_state, old_plan, sync_plan)
        elif mode == "zero3":
            sync_plan = grad_sync_plan(_template(), cfg, sched,
                                       mode="zero3", n_shards=ndev)
            params = zero_reshard(params, old_plan, sync_plan)
            opt_state = _reshard_opt_state(opt_state, old_plan, sync_plan)
        elif mode == "masked":
            sync_plan = grad_sync_plan(_template(), cfg, sched)
        else:                                       # local: merge mask only
            sync_plan = None
            live = backward_live_groups(sched)
            live_since_merge = live if live_since_merge is None \
                else live_since_merge | live
        record = {"step": step, "rebalance": report, "elastic": mitigation,
                  "n_devices": ndev, "sync_mode": mode}
        if sync_plan is not None:
            record["sync"] = sync_byte_report(sync_plan, _template(),
                                              n_shards=ndev)
            log.extras["sync"] = record["sync"]
        log.extras["rebalance"] = report
        log.extras.setdefault("refreshes", []).append(record)
        return record

    def _template():
        """Unstacked canonical-shaped params view for plan building
        (shapes are layout-invariant, so the current tree works)."""
        if mode == "local":
            return _shapes_of(jax.tree.map(lambda x: x[0], params))
        return _shapes_of(params)

    def switch_to_local(step: int):
        nonlocal mode, params, opt_state, sync_plan, step_fn, \
            live_since_merge, steps_since_merge
        p, s = canonical_state()
        mode = "local"
        sync_plan = None
        params = stack_replicas(p, ndev)
        opt_state = stack_replicas(s, ndev)
        live_since_merge = backward_live_groups(sched) \
            if sched is not None else None
        steps_since_merge = 0
        step_fn = None
        events.append({"type": "lofi_fallback", "step": step,
                       "sync_faults": sync_faults,
                       "merge_every": el.merge_every})

    def do_merge(step: int):
        nonlocal params, live_since_merge, steps_since_merge, merges
        mask = live_since_merge if live_since_merge is not None \
            else np.ones((cfg.n_layers, sched.n_groups), bool)
        plan = grad_sync_plan(_template(), cfg, _mask_schedule(mask))
        rep = sync_byte_report(plan, _template())
        merged = lofi_merge(params, plan)
        params = stack_replicas(merged, ndev)
        merges += 1
        steps_since_merge = 0
        live_since_merge = backward_live_groups(sched)
        events.append({"type": "merge", "step": step,
                       "live_fraction": round(rep["fraction"], 6),
                       "merged_bytes": rep["synced_bytes"]})

    run_mesh = mesh
    i = 0
    if resume_from is not None:
        i = restore(resume_from)
        events.append({"type": "resume", "step": i, "path": resume_from})
    last_ckpt = save_ckpt(i)

    while i < steps:
        # -- 1. simulated device dropout: shrink + restore + replay ----
        dev = fp.dropout_at(i) if not dropped else None
        if dev is not None:
            dropped = True
            new_ndev = feasible_survivor_count(ndev, d2.n_microbatches)
            ck_path = last_ckpt
            old_i, ndev = i, new_ndev
            run_mesh = make_data_mesh(ndev)
            i = restore(ck_path)
            events.append({
                "type": "dropout_recovery", "step": old_i, "device": dev,
                "ckpt_step": i, "recovery_steps": old_i - i,
                "n_devices": ndev, "ckpt": ck_path})
            continue

        # -- 2. plan: fresh scores on refresh, rebuild after recovery --
        if sched is None or (el.refresh_every and i >= next_refresh
                             and i > 0):
            sched = rescore(get_batch(i))
            if el.refresh_every:
                next_refresh = (i // el.refresh_every + 1) * el.refresh_every
            rebuild(i, run_mesh)
            step_fn = None
        elif step_fn is None and (assignment is None or sync_plan is None
                                  or mode == "local"):
            rebuild(i, run_mesh)

        # -- 3. dropped gradient-sync round: lose the step, count it ---
        if mode != "local" and fp.sync_dropped(i):
            sync_faults += 1
            events.append({"type": "sync_drop", "step": i,
                           "count": sync_faults})
            if el.sync_fault_threshold and \
                    sync_faults >= el.sync_fault_threshold:
                switch_to_local(i)
            if el.ckpt_every and (i + 1) % el.ckpt_every == 0:
                last_ckpt = save_ckpt(i + 1)
            i += 1
            continue

        # -- 4. run the guarded step --------------------------------
        batch = get_batch(i)
        B = batch["labels"].shape[0]
        mb_of = microbatch_assignment(B, d2.n_microbatches)
        perm = device_sample_order(assignment, mb_of)
        pbatch = jax.tree.map(lambda a: a[perm], batch)
        gates = gates_from_schedule(sched, mb_of[perm])
        if step_fn is None:
            bounds = distributed_live_bounds(sched, mb_of, assignment) \
                if use_kernel else None
            from repro.launch.parallel import MeshSpec, ParallelConfig
            step_fn = make_distributed_train_step(
                cfg, opt, run_mesh, sync_plan, clip=clip,
                live_bounds=bounds,
                parallel=ParallelConfig(mesh=MeshSpec(data=ndev),
                                        sync_mode=mode, guard=True,
                                        use_kernel=use_kernel),
                params=params if mode != "local" else None,
                n_replicas=ndev)
        fault_vec = fp.grad_fault_vector(i, ndev)
        thresh = np.float32(np.inf)
        if el.guard_factor is not None and ema_gnorm is not None:
            thresh = np.float32(el.guard_factor * ema_gnorm)
        t0 = time.perf_counter()
        params, opt_state, metrics = step_fn(params, opt_state, pbatch,
                                             gates, fault_vec, thresh)
        jax.block_until_ready(metrics["loss"])
        wall = time.perf_counter() - t0
        log.step_times.append(wall)
        log.losses.append(float(metrics["loss"]))
        log.metrics.append({k: float(v) for k, v in metrics.items()})

        skipped = float(metrics.get("skipped", 0.0)) > 0
        if skipped:
            guard_skips += 1
            events.append({
                "type": "guard_skip", "step": i,
                "bad_devices": float(metrics.get("bad_devices", 0.0)),
                "bad_blocks": float(metrics.get("bad_blocks", 0.0))})
        elif np.isfinite(float(metrics["grad_norm"])):
            g = float(metrics["grad_norm"])
            ema_gnorm = g if ema_gnorm is None else \
                (1 - el.ema_alpha) * ema_gnorm + el.ema_alpha * g

        # -- 5. synthesized per-device timing -> speed EMA -----------
        # (a per-device wall clock does not exist in one SPMD program;
        # the fault plan's unit times ARE the measurement — see
        # launch/faults.py. measured_time_d = load_d * unit_time_d, so
        # time/load recovers unit_time exactly.)
        u_obs = fp.unit_times(i, ndev)
        speeds = (1 - el.ema_alpha) * speeds + el.ema_alpha * u_obs

        # -- 6. lo-fi merge cadence ----------------------------------
        if mode == "local":
            steps_since_merge += 1
            if steps_since_merge >= el.merge_every \
                    and not fp.sync_dropped(i):
                do_merge(i)

        if el.ckpt_every and (i + 1) % el.ckpt_every == 0:
            last_ckpt = save_ckpt(i + 1)
        i += 1

    # ---- hand back canonical state ---------------------------------
    if mode == "local":
        if steps_since_merge > 0:
            do_merge(steps - 1)
        params = jax.tree.map(lambda x: x[0], params)
        # lo-fi keeps optimizer moments per-replica; replica 0's state is
        # returned as the representative (documented in docs/robustness.md)
        opt_state = jax.tree.map(lambda x: x[0], opt_state)
    elif mode in ("zero", "zero3") and sync_plan is not None:
        opt_state = _reshard_opt_state(opt_state, sync_plan, None)
        if mode == "zero3":
            params = zero_reshard(params, sync_plan, None)
    elastic_log.update({
        "final_mode": mode, "n_devices": ndev,
        "guard_skips": guard_skips, "sync_faults": sync_faults,
        "merges": merges, "last_ckpt": last_ckpt,
        "unit_times": [round(float(u), 4) for u in speeds],
    })
    return params, opt_state, log
