"""Training loops: standard fine-tuning and the D2FT-orchestrated variants.

`finetune` drives either path on LLM backbones; `finetune_vit` mirrors the
paper's ViT experiments and is what the paper-table benchmarks call.
"""
from __future__ import annotations

import time
import warnings
from dataclasses import dataclass, field
from typing import Callable, Iterable, Optional

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs.base import D2FTConfig, ModelConfig
from repro.core import d2ft as d2ft_mod
from repro.core.schedule import (Schedule, gates_from_schedule,
                                 live_slice_bounds, packed_indices)
from repro.core.scores import compute_scores, transformer_blocks, vit_blocks
from repro.data.synthetic import microbatch_assignment
from repro.models.transformer import lm_loss
from repro.models.vit import ViTConfig, vit_loss
from repro.optim.optimizers import (Optimizer, clip_by_global_norm,
                                    clip_scale)


@dataclass
class TrainLog:
    losses: list = field(default_factory=list)
    metrics: list = field(default_factory=list)
    step_times: list = field(default_factory=list)
    # distributed path: rebalance report + sync-plan byte report
    extras: dict = field(default_factory=dict)

    def last(self, k: str):
        return self.metrics[-1][k] if self.metrics else None


# ------------------------------------------------------------------ LLM path
def make_train_step(cfg: ModelConfig, opt: Optimizer, *, use_gates: bool,
                    packed: bool = False, policy=None, remat: bool = False,
                    clip: float = 1.0, use_kernel: bool = False,
                    live_bounds=None):
    """Returns jit-able step(params, opt_state, batch[, sched_args]).

    use_kernel: run attention through the Pallas gated flash kernel whose
    custom-VJP backward skips p_o / p_s (sample, head-group) slices.
    Ignored on the packed path (packed gathers subnet micro-batches
    instead of gating).
    live_bounds: static (live_fwd, live_bwd) (sample, group) slice bounds
    from ``core.schedule.live_slice_bounds`` — enables the kernel path's
    compaction dispatch. Baked into the jitted step: re-make (and re-jit)
    the step when the schedule's live counts change.
    """

    def loss_of(params, batch, sched_args):
        if packed:
            logits, aux = d2ft_mod.packed_forward(
                params, cfg, batch["tokens"], sched_args, policy=policy,
                remat=remat)
            labels = batch["labels"]
            logp = jax.nn.log_softmax(logits.astype(jnp.float32), -1)
            ll = jnp.take_along_axis(logp, labels[..., None], -1)[..., 0]
            return -jnp.mean(ll), {"ce": -jnp.mean(ll)}
        gates = sched_args if use_gates else None
        return lm_loss(params, cfg, batch.get("tokens"), batch["labels"],
                       features=batch.get("features"), gates=gates,
                       policy=policy, remat=remat, use_kernel=use_kernel,
                       live_bounds=live_bounds if use_gates else None)

    def step(params, opt_state, batch, sched_args=None):
        (loss, metrics), grads = jax.value_and_grad(
            loss_of, has_aux=True)(params, batch, sched_args)
        grads, gnorm = clip_by_global_norm(grads, clip)
        params, opt_state = opt.update(grads, opt_state, params)
        metrics = dict(metrics, loss=loss, grad_norm=gnorm)
        return params, opt_state, metrics

    return step


def plan_from_scores(cfg: ModelConfig, d2: D2FTConfig, params,
                     score_batches, loss_fn) -> Schedule:
    """Scoring pass (paper: before fine-tuning) + bi-level knapsack."""
    G = d2.head_groups or max(cfg.n_heads, 1)
    bw, fw = compute_scores(loss_fn, params,
                            lambda t: transformer_blocks(t, cfg),
                            score_batches, G,
                            backward_metric=d2.backward_score,
                            forward_metric=d2.forward_score)
    return d2ft_mod.plan_schedule(d2, bw, fw, cfg.n_layers, G)


def finetune(params, cfg: ModelConfig, d2: Optional[D2FTConfig],
             opt: Optimizer, batches: Iterable, *, steps: int,
             packed: bool = False, use_kernel: bool = False,
             log: Optional[TrainLog] = None) -> tuple:
    """Fine-tune; if d2 is given, schedule ops per batch via D2FT."""
    log = log or TrainLog()
    opt_state = opt.init(params)
    # jitted steps cached per compaction bound pair: bounds are re-derived
    # every batch (batch size or schedule changes change the live counts),
    # identical counts reuse the cached trace
    step_fns = {}

    def get_step(bounds):
        if bounds not in step_fns:
            step_fns[bounds] = jax.jit(make_train_step(
                cfg, opt, use_gates=d2 is not None, packed=packed,
                use_kernel=use_kernel, live_bounds=bounds))
        return step_fns[bounds]

    sched = None
    for i, batch in enumerate(batches):
        if i >= steps:
            break
        if d2 is not None and sched is None:
            from repro.data.synthetic import split_microbatches
            mbs = split_microbatches(batch, d2.n_microbatches)
            sched = plan_from_scores(
                cfg, d2, params, mbs,
                lambda p, mb: lm_loss(p, cfg, mb.get("tokens"), mb["labels"],
                                      features=mb.get("features"))[0])
        sched_args = None
        bounds = None
        if d2 is not None:
            B = batch["labels"].shape[0]
            mb_of = microbatch_assignment(B, d2.n_microbatches)
            if packed:
                idx, bwd, val, _ = packed_indices(sched, mb_of)
                sched_args = (jnp.asarray(idx), jnp.asarray(bwd),
                              jnp.asarray(val))
            else:
                sched_args = gates_from_schedule(sched, mb_of)
                if use_kernel:
                    bounds = live_slice_bounds(sched, mb_of)
        step_fn = get_step(bounds)
        t0 = time.perf_counter()
        params, opt_state, metrics = step_fn(params, opt_state, batch,
                                             sched_args)
        jax.block_until_ready(metrics["loss"])
        log.step_times.append(time.perf_counter() - t0)
        log.losses.append(float(metrics["loss"]))
        log.metrics.append({k: float(v) for k, v in metrics.items()})
    return params, opt_state, log


# ----------------------------------------------------------- distributed path
def _zero_state_specs(opt_state_shapes, plan, axis_name: str):
    """PartitionSpec tree for the optimizer state: params-shaped subtrees
    (same treedef as the plan — moments, EMA copies, anything updated
    leafwise from grad/param shards) get the plan's partition specs,
    everything else (step counters, fallback scalars) stays replicated.
    Callers must hand such subtrees over in the plan's shard layout
    (``sharding.sync.zero_reshard``; zero-init moments are
    layout-invariant)."""
    from jax.sharding import PartitionSpec as P

    from repro.sharding.sync import SyncSpec, zero_param_specs

    pspecs = zero_param_specs(plan, axis_name)
    plan_def = jax.tree.structure(plan,
                                  is_leaf=lambda x: isinstance(x, SyncSpec))
    return {
        k: pspecs if jax.tree.structure(v) == plan_def
        else jax.tree.map(lambda _: P(), v)
        for k, v in opt_state_shapes.items()
    }


def _grad_anomaly(grads, thresh):
    """Per-subnet-block gradient anomaly detection (the pre-sync guard).

    Computes one squared grad norm per parameter block — each cycle of
    every scan-stacked ``cycles`` entry, each ``rest`` block, and each
    loss-path subtree — and flags blocks that are non-finite or whose
    norm exceeds ``thresh`` (pass +inf to disable the norm test).
    Returns (bad_any, n_bad_blocks): a scalar bool and a float count."""
    def block_sq(tree, stacked):
        leaves = jax.tree.leaves(tree)
        if stacked:
            tot = sum(jnp.sum(l.astype(jnp.float32) ** 2,
                              axis=tuple(range(1, l.ndim)))
                      for l in leaves)
        else:
            tot = sum(jnp.sum(l.astype(jnp.float32) ** 2) for l in leaves)
        return jnp.atleast_1d(tot)

    sqs = []
    for key, sub in grads.items():
        if key == "cycles":
            sqs.extend(block_sq(blk, True) for blk in sub)
        elif key == "rest":
            sqs.extend(block_sq(blk, False) for blk in sub)
        else:
            sqs.append(block_sq(sub, False))
    sq = jnp.concatenate(sqs)
    bad = ~jnp.isfinite(sq) | (jnp.sqrt(sq) > thresh)
    return bad.any(), bad.sum().astype(jnp.float32)


def _tree_where(cond, a, b):
    """Leafwise select: ``a`` where the scalar ``cond`` holds, else ``b``."""
    return jax.tree.map(lambda x, y: jnp.where(cond, x, y), a, b)


_UNSET = object()     # sentinel: a deprecated loose kwarg was not passed


def _resolve_parallel(parallel, mesh, given: dict, *, where: str):
    """Deprecation shim: fold the historical loose kwargs into a
    ``launch.parallel.ParallelConfig``.

    ``given`` holds only the deprecated kwargs the caller actually passed
    (callers filter out ``_UNSET``). Exactly one of ``parallel`` / loose
    kwargs may be used. Legacy validation errors keep their types and
    messages — ``ParallelConfig.validate()`` raises the same
    AssertionError for streamed/opt_chunk off zero3 and the same
    ``"streamed ZeRO-3 cannot guard"`` ValueError.

    Returns (config, data_axis_name): the axis name stays a separate
    return so the legacy ``axis_name=`` kwarg keeps working on meshes
    whose data axis is not literally called "data"."""
    from repro.launch.parallel import MeshSpec, ParallelConfig

    if parallel is not None:
        if given:
            raise TypeError(
                f"{where}: pass either parallel=ParallelConfig(...) or the "
                f"deprecated kwargs {sorted(given)}, not both")
        return parallel, parallel.data_axis
    if given:
        warnings.warn(
            f"{where}({', '.join(sorted(given))}=...) is deprecated; pass "
            "parallel=repro.launch.parallel.ParallelConfig(...) instead",
            DeprecationWarning, stacklevel=3)
    axis = given.pop("axis_name", "data")
    shape = dict(mesh.shape) if mesh is not None else {}
    spec = MeshSpec(data=int(shape.get(axis, 1)),
                    stage=int(shape.get("stage", 1)),
                    tensor=int(shape.get("tensor", 1)))
    return ParallelConfig(mesh=spec, **given), axis


def make_distributed_train_step(cfg: ModelConfig, opt: Optimizer, mesh,
                                sync_plan, *, parallel=None,
                                clip: float = 1.0, live_bounds=None,
                                params=None, n_replicas=None,
                                residency_recorder=None,
                                stage_assignment=None,
                                pipeline_recorder=None,
                                use_kernel=_UNSET, axis_name=_UNSET,
                                sync_mode=_UNSET, guard=_UNSET,
                                streamed=_UNSET, opt_chunk=_UNSET):
    """shard_map multi-axis gated train step (paper's *distributed* D2FT).

    ``parallel`` (a ``launch.parallel.ParallelConfig``) is the one knob
    bundle: mesh spec (data/stage/tensor sizes), sync_mode, streamed,
    opt_chunk, guard, use_kernel and pipeline microbatches. The loose
    ``sync_mode=``/``guard=``/... kwargs below the sentinel line are the
    deprecated pre-ParallelConfig spelling — still honored, with a
    DeprecationWarning — and may not be mixed with ``parallel=``.

    Axes beyond data compose around the same bodies:

    * ``stage > 1`` — GPipe microbatch pipeline (``train.pipeline``): each
      stage device runs its ``stage_assignment`` layer range (a
      ``core.assignment.StageAssignment``, required) on
      ``parallel.microbatches`` microbatches with ppermute handoffs; the
      per-stage partial loss/metrics/grads are psum-completed over the
      stage axis before any data-axis sync, so masked/ZeRO-1/ZeRO-3 see
      exactly the replicated full-batch grads they always did.
      ``pipeline_recorder`` (a ``train.pipeline.PipelineRecorder``) counts
      rounds/sends at trace time for the bubble cross-check.
    * ``tensor > 1`` — Megatron sharding of attention heads / FFN columns
      inside each block (``models.transformer`` ``tp=``); the TP-sharded
      leaf grads are disjoint slices, psum-reassembled over the tensor
      axis (``sharding.sync.apply_tensor_grad_sync``) so everything
      downstream again sees replicated full grads.

    Each device runs the masked/kernel gated path on its shard of the batch
    — its multiple-knapsack-assigned micro-batches after
    ``core.assignment.device_sample_order`` reordering — then gradients are
    combined per ``sync_mode``:

    * ``"masked"`` — ``sharding.sync.apply_grad_sync``: only parameters
      with a live backward somewhere in the schedule enter the pmean;
      p_o/p_s-only subnets contribute identically-zero grads on every
      device and their psum is elided. Params and optimizer state stay
      replicated.
    * ``"zero"`` — ZeRO-1: live runs are reduce-scattered, each device
      updates only its owned param shard with its shard of the optimizer
      moments (per-device moment memory ~1/n_devices), then updated params
      are all-gathered under the plan's gather mask. Requires a zero-mode
      ``sync_plan`` (``grad_sync_plan(mode="zero", n_shards=...)``) and
      ``params`` (a template for the optimizer-state structure); the
      returned step expects/returns the optimizer state in the plan's
      shard layout (``sharding.sync.zero_reshard`` converts).
    * ``"zero3"`` — fully sharded params: the step expects AND returns the
      params in the plan's shard layout (same layout the moments use), so
      no device holds a full replica between steps. The step body
      materializes full views via a *schedule-masked* all-gather
      (``sharding.sync.zero3_materialize``) — runs that are p_s on every
      micro-batch are never gathered, a zeros view being exact — takes
      grads against the views, reduce-scatters live runs straight onto
      the owning shards (ZeRO-2), and updates shard-resident. Requires a
      ``grad_sync_plan(mode="zero3", ...)`` plan and ``params``.
      ``streamed=True`` swaps in the per-residency-unit streamed schedule
      (``sharding.sync.zero3_stream_materialize``): one set of all-gathers
      per unit that the XLA scheduler can prefetch against the previous
      unit's compute, and each unit's reduce-scatter fused into its
      backward release point via ``custom_vjp`` instead of a serialized
      post-backward pass. Same collectives on the same operands — the
      ``dist_zero3_streamed`` parity arm pins value equality and the
      8-device suite pins wire-byte equality. ``opt_chunk=n`` streams the
      shard-resident update ``n`` elements at a time
      (``optim.optimizers.chunked``, bit-identical);
      ``residency_recorder`` (a ``sharding.sync.ResidencyRecorder``)
      counts the streamed schedule's per-unit gather bytes at trace time
      for the ``check_zero3_residency`` cross-check. Both options require
      ``sync_mode="zero3"``, and ``streamed`` is incompatible with
      ``guard`` (the guard must zero anomalous local grads *before* any
      collective, which the vjp-embedded scatters make impossible).

    * ``"local"`` — the lo-fi communication-free mode: params and
      optimizer state arrive *per-replica stacked* ([n_replicas, ...]
      leaves, see ``sharding.sync.stack_replicas``) and every replica
      updates its own copy from its own batch shard with ZERO gradient
      sync (vmap over the replica axis — no collectives, no mesh
      needed). The caller merges replicas every K steps with
      ``sharding.sync.lofi_merge``. ``sync_plan``/``mesh`` are ignored;
      ``n_replicas`` sets the stack size (defaults to the mesh's data
      axis).

    guard=True arms the pre-sync non-finite-grad guard: the step takes
    two extra arguments ``(fault, thresh)`` — ``fault`` a [n_devices]
    float32 multiplier applied to each device's local grads (the fault
    injection seam, all-ones when healthy) and ``thresh`` a scalar
    per-block grad-norm anomaly threshold (+inf disables). After the
    backward, each device runs per-subnet-block anomaly detection
    (``_grad_anomaly``) on its LOCAL grads; anomalous devices zero their
    contribution *before* any collective (one bad replica cannot poison
    the pmean), a one-scalar psum counts bad devices, and if any device
    flagged, the whole update is skipped — params and optimizer state
    pass through unchanged. Metrics gain ``skipped`` (0/1),
    ``bad_devices`` and ``bad_blocks``. In local mode the guard is
    per-replica: only the anomalous replica skips its own update.

    sync_plan: per-leaf SyncSpec tree from ``sharding.sync.grad_sync_plan``.
    live_bounds: static per-device (live_fwd, live_bwd) compaction bounds
    (``core.assignment.distributed_live_bounds``) — each device dispatches
    only its local shard's live slices through the gated kernels.
    Returns jitted step(params, opt_state, batch, gates[, fault, thresh])
    with params replicated, batch sharded on the leading axis and gates
    [L, B, G] sharded on the sample axis.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    from repro.optim.optimizers import chunked
    from repro.sharding.sync import (apply_grad_sync, apply_tensor_grad_sync,
                                     apply_zero_gather, apply_zero_scatter,
                                     zero3_materialize,
                                     zero3_stream_materialize, zero_norm_sq,
                                     zero_param_specs, zero_shard_params)
    from repro.train.pipeline import pipeline_loss

    given = {k: v for k, v in dict(
        use_kernel=use_kernel, axis_name=axis_name, sync_mode=sync_mode,
        guard=guard, streamed=streamed, opt_chunk=opt_chunk).items()
        if v is not _UNSET}
    parallel, axis_name = _resolve_parallel(
        parallel, mesh, given, where="make_distributed_train_step")
    sync_mode, guard = parallel.sync_mode, parallel.guard
    streamed, opt_chunk = parallel.streamed, parallel.opt_chunk
    use_kernel = parallel.use_kernel
    S, T = parallel.mesh.stage, parallel.mesh.tensor
    tp = (parallel.tensor_axis, T) if T > 1 else None
    parallel.validate_model(cfg)
    if (S > 1 or T > 1) and mesh is not None:
        parallel.validate_mesh(mesh)
    if S > 1:
        assert stage_assignment is not None, \
            "stage > 1 needs a core.assignment.StageAssignment " \
            "(plan_stage_assignment on the current schedule)"
        assert stage_assignment.n_stages == S, \
            (stage_assignment.n_stages, S)
    upd_opt = chunked(opt, opt_chunk) if opt_chunk else opt

    def grads_of(params_full, batch, gates):
        """value_and_grad of the local loss. Under stage/tensor axes the
        per-device partials are psum-completed HERE, so every body below
        sees full-batch replicated (loss, metrics, grads) exactly as on a
        pure data mesh — masked/ZeRO sync tails compose unchanged."""
        if S > 1:
            assert batch.get("features") is None, \
                "the pipeline path is tokens-only"

            def fn(p):
                return pipeline_loss(
                    p, cfg, batch["tokens"], batch["labels"], gates,
                    boundaries=stage_assignment.boundaries,
                    n_microbatches=parallel.microbatches,
                    stage_axis=parallel.stage_axis, tp=tp,
                    recorder=pipeline_recorder)
        else:
            def fn(p):
                return lm_loss(p, cfg, batch.get("tokens"), batch["labels"],
                               features=batch.get("features"), gates=gates,
                               use_kernel=use_kernel,
                               live_bounds=live_bounds, tp=tp)
        (loss, metrics), grads = jax.value_and_grad(
            fn, has_aux=True)(params_full)
        if S > 1:
            # stage partials (each stage's own layers, last stage's head)
            # sum to the full-batch values; grad supports are disjoint per
            # layer, so the psum is a reassembly, not an average
            stage_ax = parallel.stage_axis
            loss = jax.lax.psum(loss, stage_ax)
            metrics = {k: jax.lax.psum(v, stage_ax)
                       for k, v in metrics.items()}
            grads = jax.tree.map(lambda g: jax.lax.psum(g, stage_ax), grads)
        if tp is not None:
            grads = apply_tensor_grad_sync(grads, tp[0])
        return (loss, metrics), grads

    def guard_local(grads, fault, thresh):
        """Fault-inject, then neutralize anomalous local grads BEFORE any
        collective; returns (clean grads, this-device bad flag, count)."""
        f = fault[0]          # this device's scalar multiplier
        grads = jax.tree.map(lambda g: g * f.astype(g.dtype), grads)
        bad, n_bad_blocks = _grad_anomaly(grads, thresh)
        grads = jax.tree.map(lambda g: jnp.where(bad, jnp.zeros_like(g), g),
                             grads)
        return grads, bad, n_bad_blocks

    def finish_guarded(old_params, old_state, new_params, new_state,
                       metrics, bad, n_bad_blocks):
        """Skip-step: if ANY device flagged, every device keeps its old
        params/state (the psum makes the decision replicated)."""
        n_bad = jax.lax.psum(bad.astype(jnp.float32), axis_name)
        skip = n_bad > 0
        return (_tree_where(skip, old_params, new_params),
                _tree_where(skip, old_state, new_state),
                dict(metrics, skipped=skip.astype(jnp.float32),
                     bad_devices=n_bad,
                     bad_blocks=jax.lax.psum(n_bad_blocks, axis_name)))

    def local_step(params, opt_state, batch, gates, fault=None, thresh=None):
        (loss, metrics), grads = grads_of(params, batch, gates)
        if guard:
            grads, bad, n_bad_blocks = guard_local(grads, fault, thresh)
        grads = apply_grad_sync(grads, sync_plan, axis_name)
        loss = jax.lax.pmean(loss, axis_name)
        metrics = {k: jax.lax.pmean(v, axis_name) for k, v in metrics.items()}
        # post-sync grads are the global mean on every device, so the norm,
        # clip and optimizer update stay replicated without more collectives
        grads, gnorm = clip_by_global_norm(grads, clip)
        new_params, new_state = opt.update(grads, opt_state, params)
        metrics = dict(metrics, loss=loss, grad_norm=gnorm)
        if guard:
            return finish_guarded(params, opt_state, new_params, new_state,
                                  metrics, bad, n_bad_blocks)
        return new_params, new_state, metrics

    def local_step_zero(params, opt_state, batch, gates, fault=None,
                        thresh=None):
        (loss, metrics), grads = grads_of(params, batch, gates)
        if guard:
            grads, bad, n_bad_blocks = guard_local(grads, fault, thresh)
        # mixed tree: reduced shards at zero leaves (live runs
        # reduce-scattered, dead runs locally sliced), masked pmean
        # elsewhere
        gsync = apply_zero_scatter(grads, sync_plan, axis_name)
        loss = jax.lax.pmean(loss, axis_name)
        metrics = {k: jax.lax.pmean(v, axis_name) for k, v in metrics.items()}
        # global grad norm: zero-leaf shards tile their tensors disjointly
        # across devices, so one scalar psum completes their square sum;
        # fallback leaves are replicated and added locally
        shard_sq, full_sq = zero_norm_sq(gsync, sync_plan)
        gnorm = jnp.sqrt(jax.lax.psum(shard_sq, axis_name) + full_sq)
        scale = clip_scale(gnorm, clip)
        gsync = jax.tree.map(lambda g: g * scale, gsync)
        # each device updates only its owned shard (moments arrive sharded
        # through in_specs); the schedule-masked all-gather re-replicates
        # exactly the runs whose params can have changed
        pshard = zero_shard_params(params, sync_plan, axis_name)
        new_shard, new_state = opt.update(gsync, opt_state, pshard)
        new_params = apply_zero_gather(new_shard, params, sync_plan,
                                       axis_name)
        metrics = dict(metrics, loss=loss, grad_norm=gnorm)
        if guard:
            return finish_guarded(params, opt_state, new_params, new_state,
                                  metrics, bad, n_bad_blocks)
        return new_params, new_state, metrics

    def local_step_zero3(params, opt_state, batch, gates, fault=None,
                         thresh=None):
        # params arrive as owned shards (the plan's layout); full views
        # exist only between here and the update — the ZeRO-3 residency
        # window. Runs the schedule proves forward-dead are never gathered
        # (zeros view, exact: their every consumer is gated off).
        full = zero3_materialize(params, sync_plan, axis_name)
        (loss, metrics), grads = grads_of(full, batch, gates)
        if guard:
            grads, bad, n_bad_blocks = guard_local(grads, fault, thresh)
        gsync = apply_zero_scatter(grads, sync_plan, axis_name)
        loss = jax.lax.pmean(loss, axis_name)
        metrics = {k: jax.lax.pmean(v, axis_name) for k, v in metrics.items()}
        shard_sq, full_sq = zero_norm_sq(gsync, sync_plan)
        gnorm = jnp.sqrt(jax.lax.psum(shard_sq, axis_name) + full_sq)
        scale = clip_scale(gnorm, clip)
        gsync = jax.tree.map(lambda g: g * scale, gsync)
        # grads and params are both shard-resident at zero leaves: the
        # update never touches a full tensor and there is no post-update
        # gather — next step's materialization starts from the new shards.
        new_params, new_state = upd_opt.update(gsync, opt_state, params)
        metrics = dict(metrics, loss=loss, grad_norm=gnorm)
        if guard:
            return finish_guarded(params, opt_state, new_params, new_state,
                                  metrics, bad, n_bad_blocks)
        return new_params, new_state, metrics

    def local_step_zero3_streamed(params, opt_state, batch, gates):
        # the streamed schedule: differentiate straight through the
        # per-unit materializer, whose custom_vjp scatters each unit's
        # grads onto the owning shards at that unit's backward release
        # point — grads arrive here already in shard layout, with the
        # gathers issued per unit so prefetch can hide them behind the
        # previous unit's compute.
        def fn(shards):
            full = zero3_stream_materialize(shards, sync_plan, axis_name,
                                            recorder=residency_recorder)
            return lm_loss(full, cfg, batch.get("tokens"), batch["labels"],
                           features=batch.get("features"), gates=gates,
                           use_kernel=use_kernel, live_bounds=live_bounds)

        (loss, metrics), gsync = jax.value_and_grad(
            fn, has_aux=True)(params)
        loss = jax.lax.pmean(loss, axis_name)
        metrics = {k: jax.lax.pmean(v, axis_name) for k, v in metrics.items()}
        shard_sq, full_sq = zero_norm_sq(gsync, sync_plan)
        gnorm = jnp.sqrt(jax.lax.psum(shard_sq, axis_name) + full_sq)
        scale = clip_scale(gnorm, clip)
        gsync = jax.tree.map(lambda g: g * scale, gsync)
        new_params, new_state = upd_opt.update(gsync, opt_state, params)
        metrics = dict(metrics, loss=loss, grad_norm=gnorm)
        return new_params, new_state, metrics

    if sync_mode == "local":
        # lo-fi: per-replica stacked state, zero collectives — a vmap over
        # the replica axis stands in for the mesh (each replica is a
        # device that lost its links but kept training).
        R = int(n_replicas) if n_replicas else mesh.shape[axis_name]

        def one_replica(params, opt_state, batch, gates, fault=None,
                        thresh=None):
            (loss, metrics), grads = grads_of(params, batch, gates)
            if guard:
                grads = jax.tree.map(
                    lambda g: g * fault.astype(g.dtype), grads)
                bad, n_bad_blocks = _grad_anomaly(grads, thresh)
                grads = jax.tree.map(
                    lambda g: jnp.where(bad, jnp.zeros_like(g), g), grads)
            grads, gnorm = clip_by_global_norm(grads, clip)
            new_params, new_state = opt.update(grads, opt_state, params)
            metrics = dict(metrics, loss=loss, grad_norm=gnorm)
            if guard:
                # per-replica skip: only the anomalous replica holds back
                new_params = _tree_where(bad, params, new_params)
                new_state = _tree_where(bad, opt_state, new_state)
                metrics = dict(metrics, skipped=bad.astype(jnp.float32),
                               bad_blocks=n_bad_blocks)
            return new_params, new_state, metrics

        in_axes = (0, 0, 0, (1, 1)) + ((0, None) if guard else ())
        vstep = jax.vmap(one_replica, in_axes=in_axes)

        def step(params_stack, opt_stack, batch, gates, *rest):
            batch = jax.tree.map(
                lambda a: a.reshape((R, a.shape[0] // R) + a.shape[1:]),
                batch)
            gates = tuple(
                g.reshape(g.shape[0], R, g.shape[1] // R, g.shape[2])
                for g in gates)
            new_p, new_s, metrics = vstep(params_stack, opt_stack, batch,
                                          gates, *rest)
            metrics = {
                k: v.sum() if k in ("skipped", "bad_blocks") else v.mean()
                for k, v in metrics.items()}
            if guard:
                metrics["bad_devices"] = metrics["skipped"]
            return new_p, new_s, metrics

        return jax.jit(step)

    # check_rep=False: skipped (dead-subnet) grad leaves are device-invariant
    # — identically zero everywhere — but shard_map's replication tracker
    # cannot prove that through an elided psum.
    param_specs = P()
    if sync_mode == "masked":
        state_specs = P()
        body = local_step
    elif sync_mode in ("zero", "zero3"):
        assert params is not None, f"{sync_mode} mode needs a params template"
        state_shapes = jax.eval_shape(opt.init, params)
        state_specs = _zero_state_specs(state_shapes, sync_plan, axis_name)
        if sync_mode == "zero":
            body = local_step_zero
        else:
            param_specs = zero_param_specs(sync_plan, axis_name)
            body = local_step_zero3_streamed if streamed \
                else local_step_zero3
    else:
        raise ValueError(f"unknown sync_mode {sync_mode!r}")
    in_specs = (param_specs, state_specs, P(axis_name),
                (P(None, axis_name), P(None, axis_name)))
    if guard:
        in_specs = in_specs + (P(axis_name), P())
    step = shard_map(
        body, mesh=mesh,
        in_specs=in_specs,
        out_specs=(param_specs, state_specs, P()),
        check_rep=False)
    return jax.jit(step)


def _reshard_opt_state(opt_state, old_plan, new_plan):
    """Re-layout params-shaped state subtrees between zero-plan shard
    layouts; either plan may be None for canonical order (host-side;
    identity for masked plans and non-params-shaped state)."""
    from repro.sharding.sync import SyncSpec, zero_reshard
    ref = new_plan if new_plan is not None else old_plan
    if ref is None:
        return opt_state
    plan_def = jax.tree.structure(
        ref, is_leaf=lambda x: isinstance(x, SyncSpec))
    return {
        k: zero_reshard(v, old_plan, new_plan)
        if jax.tree.structure(v) == plan_def else v
        for k, v in opt_state.items()
    }


def finetune_distributed(params, cfg: ModelConfig, d2: D2FTConfig,
                         opt: Optimizer, batches: Iterable, *, steps: int,
                         mesh, parallel=None, clip: float = 1.0,
                         refresh_every: Optional[int] = None,
                         log: Optional[TrainLog] = None,
                         use_kernel=_UNSET, sync_mode=_UNSET,
                         streamed=_UNSET, opt_chunk=_UNSET) -> tuple:
    """Distributed D2FT fine-tuning: plan, balance micro-batches over the
    mesh's data axis with the multiple-knapsack assigner, then drive the
    shard_map gated step. ``refresh_every=k`` re-plans the schedule every k
    steps from fresh scores — and re-runs the knapsack assigner, rebuilds
    the sync plan and (zero mode) reshards the optimizer moments, since an
    assignment balanced for a stale schedule un-balances the new one. The
    latest rebalance/sync reports land in ``log.extras`` and every refresh
    is appended to ``log.extras["refreshes"]``.

    ``parallel`` (``launch.parallel.ParallelConfig``) selects sync mode and
    extra mesh axes; the loose ``sync_mode=``/``streamed=``/... kwargs are
    the deprecated spelling (DeprecationWarning, may not be mixed with
    ``parallel=``). With ``parallel.mesh.stage > 1`` every refresh also
    re-runs the schedule-aware stage assigner
    (``core.assignment.plan_stage_assignment``) — pipeline stages are
    packed by the NEW schedule's live FLOP cost and the jitted step is
    rebuilt around the new boundaries — and the per-refresh record gains a
    ``"stages"`` report (boundaries, loads, makespan vs layer-count
    packing, analytic bubble fraction).

    sync_mode="zero" runs the ZeRO-1 sync (sliced reduce-scatter +
    schedule-masked all-gather, optimizer moments sharded ~1/n_devices);
    the gather elision engages only for ``opt.elidable`` optimizers and
    groups that have never been backward-live since their moments were
    zero (tracked here as ``ever_live``). sync_mode="zero3" additionally
    shards the params themselves: between steps every device holds only
    its owned shards, full views are materialized inside the step under
    the schedule's *forward* mask, and the per-refresh record gains the
    ``zero3_params`` residency report. ``streamed=True`` /
    ``opt_chunk=n`` select the per-unit streamed schedule and the
    chunk-streamed update (zero3 only; see
    ``make_distributed_train_step``) — numerically identical, so replan,
    reshard and checkpointing via ``zero_reshard`` are unchanged. The returned params and opt_state
    are in canonical element order regardless of sync_mode (the in-loop
    shard layout is converted back on return), so they checkpoint/resume
    on any path."""
    from repro.core.assignment import (device_sample_order,
                                       distributed_live_bounds,
                                       plan_device_assignment,
                                       plan_stage_assignment)
    from repro.core.schedule import op_counts
    from repro.sharding.sync import (backward_live_groups, grad_sync_plan,
                                     sync_byte_report, zero3_param_byte_report,
                                     zero_reshard)
    from repro.train.pipeline import analytic_bubble_fraction

    given = {k: v for k, v in dict(
        use_kernel=use_kernel, sync_mode=sync_mode, streamed=streamed,
        opt_chunk=opt_chunk).items() if v is not _UNSET}
    parallel, _ = _resolve_parallel(parallel, mesh, given,
                                    where="finetune_distributed")
    sync_mode, use_kernel = parallel.sync_mode, parallel.use_kernel
    S = parallel.mesh.stage
    parallel.validate_model(cfg)

    log = log or TrainLog()
    opt_state = opt.init(params)
    ndev = mesh.shape["data"]
    assert sync_mode in ("masked", "zero", "zero3"), sync_mode
    sched = assignment = stage_assign = sync_plan = step_fn = None
    ever_live = None

    def replan(batch):
        from repro.data.synthetic import split_microbatches
        nonlocal ever_live
        mbs = split_microbatches(batch, d2.n_microbatches)
        sched = plan_from_scores(
            cfg, d2, params, mbs,
            lambda p, mb: lm_loss(p, cfg, mb.get("tokens"), mb["labels"],
                                  features=mb.get("features"))[0])
        assignment, report = plan_device_assignment(sched, ndev)
        if sync_mode == "zero":
            prior = ever_live
            if ever_live is None:
                ever_live = np.zeros((cfg.n_layers, sched.n_groups), bool)
            sync_plan = grad_sync_plan(
                params, cfg, sched, mode="zero", n_shards=ndev,
                ever_live=prior, elide_gather=opt.elidable)
            ever_live = ever_live | backward_live_groups(sched)
        elif sync_mode == "zero3":
            sync_plan = grad_sync_plan(params, cfg, sched, mode="zero3",
                                       n_shards=ndev)
        else:
            sync_plan = grad_sync_plan(params, cfg, sched)
        record = {
            "rebalance": report,
            "sync": sync_byte_report(sync_plan, params, n_shards=ndev),
            "op_counts": op_counts(sched),
            "device_of": [int(x) for x in assignment.device_of],
        }
        if sync_mode == "zero3":
            record["zero3_params"] = zero3_param_byte_report(
                sync_plan, params, ndev)
        stage_assign = None
        if S > 1:
            # re-pack pipeline stages for the NEW schedule's live costs —
            # a balanced packing for a stale schedule un-balances this one
            stage_assign, stage_rep = plan_stage_assignment(sched, S)
            stage_rep["bubble_fraction"] = analytic_bubble_fraction(
                stage_assign.loads, parallel.microbatches)
            record["stages"] = stage_rep
        return sched, assignment, stage_assign, sync_plan, record

    for i, batch in enumerate(batches):
        if i >= steps:
            break
        if sched is None or (refresh_every and i % refresh_every == 0
                             and i > 0):
            old_plan = sync_plan
            if sync_mode == "zero3" and old_plan is not None:
                # back to canonical before scoring: the scoring pass reads
                # param values whose group structure the shard layout
                # permutes
                params = zero_reshard(params, old_plan, None)
            sched, assignment, stage_assign, sync_plan, record = \
                replan(batch)
            if sync_mode == "zero":
                # canonical -> shard layout at the first plan (zeros are
                # layout-invariant, but a params-shaped state initialized
                # from values, e.g. an EMA copy, is not), then between
                # layouts on refresh
                opt_state = _reshard_opt_state(opt_state, old_plan,
                                               sync_plan)
            elif sync_mode == "zero3":
                params = zero_reshard(params, None, sync_plan)
                opt_state = _reshard_opt_state(opt_state, old_plan,
                                               sync_plan)
                log.extras["zero3_params"] = record["zero3_params"]
            record["step"] = i
            log.extras["rebalance"] = record["rebalance"]
            log.extras["sync"] = record["sync"]
            if "stages" in record:
                log.extras["stages"] = record["stages"]
            log.extras.setdefault("refreshes", []).append(record)
            step_fn = None
        B = batch["labels"].shape[0]
        mb_of = microbatch_assignment(B, d2.n_microbatches)
        perm = device_sample_order(assignment, mb_of)
        batch = jax.tree.map(lambda a: a[perm], batch)
        gates = gates_from_schedule(sched, mb_of[perm])
        if step_fn is None:
            bounds = distributed_live_bounds(sched, mb_of, assignment) \
                if use_kernel else None
            step_fn = make_distributed_train_step(
                cfg, opt, mesh, sync_plan, clip=clip,
                live_bounds=bounds, params=params, parallel=parallel,
                stage_assignment=stage_assign)
        t0 = time.perf_counter()
        params, opt_state, metrics = step_fn(params, opt_state, batch, gates)
        jax.block_until_ready(metrics["loss"])
        log.step_times.append(time.perf_counter() - t0)
        log.losses.append(float(metrics["loss"]))
        log.metrics.append({k: float(v) for k, v in metrics.items()})
    if sync_mode in ("zero", "zero3") and sync_plan is not None:
        # hand back canonical element order: the shard layout is an
        # internal representation a checkpoint or another path must not see
        opt_state = _reshard_opt_state(opt_state, sync_plan, None)
        if sync_mode == "zero3":
            params = zero_reshard(params, sync_plan, None)
    return params, opt_state, log


# ------------------------------------------------------------------ ViT path
def make_vit_step(cfg: ViTConfig, opt: Optimizer, use_gates: bool,
                  clip: float = 1.0, use_kernel: bool = False,
                  live_bounds=None):
    """live_bounds: static (live_fwd, live_bwd) compaction bounds baked
    into the jitted step (see make_train_step)."""
    def step(params, opt_state, images, labels, gates=None):
        def loss_of(p):
            return vit_loss(p, images, labels, cfg,
                            gates=gates if use_gates else None,
                            use_kernel=use_kernel,
                            live_bounds=live_bounds if use_gates else None)
        (loss, metrics), grads = jax.value_and_grad(loss_of, has_aux=True)(params)
        grads, gnorm = clip_by_global_norm(grads, clip)
        params, opt_state = opt.update(grads, opt_state, params)
        return params, opt_state, dict(metrics, loss=loss, grad_norm=gnorm)
    return step


def finetune_vit(params, cfg: ViTConfig, opt: Optimizer, batches,
                 steps: int, schedule_fn: Optional[Callable] = None,
                 n_microbatches: int = 5, use_kernel: bool = False,
                 log: Optional[TrainLog] = None):
    """schedule_fn(step_idx, params, images, labels) -> Schedule or None.

    The schedule is rematerialized whenever schedule_fn returns a new one
    (supports dynamic-pruning baselines that refresh every k iterations).
    use_kernel routes attention through the Pallas gated flash kernel so
    the Schedule's (g_f, g_b) gates drive the gate-aware backward kernels.
    """
    log = log or TrainLog()
    opt_state = opt.init(params)
    use_gates = schedule_fn is not None
    # jitted steps cached per compaction bound pair — schedule refreshes
    # that change the live counts re-jit, identical counts reuse the trace
    step_fns = {}

    def get_step(bounds):
        if bounds not in step_fns:
            step_fns[bounds] = jax.jit(make_vit_step(
                cfg, opt, use_gates, use_kernel=use_kernel,
                live_bounds=bounds))
        return step_fns[bounds]

    step_fn = get_step(None)
    sched = None
    for i, (images, labels) in enumerate(batches):
        if i >= steps:
            break
        gates = None
        if schedule_fn is not None:
            new = schedule_fn(i, params, images, labels)
            sched = new if new is not None else sched
            mb_of = microbatch_assignment(images.shape[0], n_microbatches)
            gates = gates_from_schedule(sched, mb_of)
            if use_kernel:
                step_fn = get_step(live_slice_bounds(sched, mb_of))
        t0 = time.perf_counter()
        params, opt_state, metrics = step_fn(
            params, opt_state, jnp.asarray(images), jnp.asarray(labels),
            gates)
        jax.block_until_ready(metrics["loss"])
        log.step_times.append(time.perf_counter() - t0)
        log.losses.append(float(metrics["loss"]))
        log.metrics.append({k: float(v) for k, v in metrics.items()})
    return params, opt_state, log


def eval_vit(params, cfg: ViTConfig, batches, max_batches: int = 10) -> float:
    from repro.models.vit import vit_forward
    fwd = jax.jit(lambda p, x: vit_forward(p, x, cfg))
    correct = total = 0
    for i, (images, labels) in enumerate(batches):
        if i >= max_batches:
            break
        pred = np.asarray(jnp.argmax(fwd(params, jnp.asarray(images)), -1))
        correct += int((pred == labels).sum())
        total += len(labels)
    return correct / max(total, 1)
