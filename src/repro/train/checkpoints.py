"""Checkpointing: save/restore param + optimizer pytrees (npz, no orbax).

Trees are flattened to path-keyed arrays; structure is rebuilt on load from
the same tree-def derived paths, so any pytree of jnp/np arrays round-trips.

Distributed notes: the shard_map train step keeps params and optimizer
state replicated (docs/distributed.md), so a checkpoint taken from any
process is the global state — ``np.asarray`` on a replicated array is a
local, collective-free read. Restoring into a sharded run is the caller's
job: ``jax.device_put`` the loaded tree against ``sharding.policy``
PartitionSpecs (the dry-run's ``_opt_state_shardings`` shows the layout).
"""
from __future__ import annotations

import os
from typing import Any, Dict

import numpy as np

import jax
import jax.numpy as jnp


def _flatten(tree, prefix="") -> Dict[str, np.ndarray]:
    out = {}
    if isinstance(tree, dict):
        for k in sorted(tree):
            out.update(_flatten(tree[k], f"{prefix}{k}/"))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}#{i}/"))
    else:
        out[prefix[:-1]] = np.asarray(tree)
    return out


def _unflatten(flat: Dict[str, np.ndarray]):
    root: Any = {}
    for path, arr in flat.items():
        keys = path.split("/")
        node = root
        for k in keys[:-1]:
            node = node.setdefault(k, {})
        node[keys[-1]] = arr

    def rebuild(node):
        if not isinstance(node, dict):
            return jnp.asarray(node)
        if node and all(k.startswith("#") for k in node):
            items = sorted(node.items(), key=lambda kv: int(kv[0][1:]))
            return [rebuild(v) for _, v in items]
        return {k: rebuild(v) for k, v in node.items()}

    return rebuild(root)


def save_checkpoint(path: str, state) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    np.savez(path, **_flatten(state))


def load_checkpoint(path: str):
    with np.load(path if path.endswith(".npz") else path + ".npz") as z:
        flat = {k: z[k] for k in z.files}
    return _unflatten(flat)
