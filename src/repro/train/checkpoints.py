"""Checkpointing: save/restore param + optimizer pytrees (npz, no orbax).

Trees are flattened to path-keyed arrays; structure is rebuilt on load from
the same tree-def derived paths, so any pytree of jnp/np arrays round-trips.
``load_checkpoint(path, template=...)`` validates the loaded tree against a
caller-provided template (same paths, shapes, dtypes) and fails with a
per-path mismatch report instead of silently rebuilding whatever treedef
the file happens to contain.

Distributed notes: the shard_map train step keeps params and optimizer
state replicated (docs/distributed.md), so a checkpoint taken from any
process is the global state — ``np.asarray`` on a replicated array is a
local, collective-free read. ZeRO-1/ZeRO-3 runs hold moments (and, for
zero3, params) in a plan-dependent shard layout; the elastic loop's
step-level checkpoints (``save_train_state``) always store *canonical*
element order (``sharding.sync.zero_reshard`` before save), so a
checkpoint restores onto any mesh size / sync mode — which is exactly what
device-dropout recovery needs (docs/robustness.md).

``save_train_state`` / ``load_train_state`` extend the bare tree
round-trip with everything a bit-exact resume needs: step counter, the
active ``Schedule`` and ``DeviceAssignment``, the RNG key, and arbitrary
scalar loop state (speed EMAs, fault counters).
"""
from __future__ import annotations

import os
from typing import Any, Dict, Optional

import numpy as np

import jax
import jax.numpy as jnp


# empty containers have no leaves, so they would vanish from a path-keyed
# flat dict; a zero-size marker entry keeps them round-trippable (a config
# with no remainder blocks has params["rest"] == [])
_EMPTY_LIST = "__empty_list__"
_EMPTY_DICT = "__empty_dict__"


def _flatten(tree, prefix="") -> Dict[str, np.ndarray]:
    out = {}
    if isinstance(tree, dict):
        if not tree:
            out[f"{prefix}{_EMPTY_DICT}"] = np.zeros(0)
        for k in sorted(tree):
            out.update(_flatten(tree[k], f"{prefix}{k}/"))
    elif isinstance(tree, (list, tuple)):
        if not tree:
            out[f"{prefix}{_EMPTY_LIST}"] = np.zeros(0)
        for i, v in enumerate(tree):
            out.update(_flatten(v, f"{prefix}#{i}/"))
    else:
        out[prefix[:-1]] = np.asarray(tree)
    return out


def _unflatten(flat: Dict[str, np.ndarray]):
    root: Any = {}
    for path, arr in flat.items():
        keys = path.split("/")
        node = root
        for k in keys[:-1]:
            node = node.setdefault(k, {})
        node[keys[-1]] = arr

    def rebuild(node):
        if not isinstance(node, dict):
            return jnp.asarray(node)
        if _EMPTY_LIST in node:
            return []
        if _EMPTY_DICT in node:
            return {}
        if node and all(k.startswith("#") for k in node):
            items = sorted(node.items(), key=lambda kv: int(kv[0][1:]))
            return [rebuild(v) for _, v in items]
        return {k: rebuild(v) for k, v in node.items()}

    return rebuild(root)


def _spec_flatten(tree, prefix="") -> Dict[str, tuple]:
    """Like ``_flatten`` but records (shape, dtype) instead of values, so
    templates can be concrete arrays OR ``jax.ShapeDtypeStruct`` trees."""
    out = {}
    if isinstance(tree, dict):
        if not tree:
            out[f"{prefix}{_EMPTY_DICT}"] = ((0,), np.dtype(np.float64))
        for k in sorted(tree):
            out.update(_spec_flatten(tree[k], f"{prefix}{k}/"))
    elif isinstance(tree, (list, tuple)):
        if not tree:
            out[f"{prefix}{_EMPTY_LIST}"] = ((0,), np.dtype(np.float64))
        for i, v in enumerate(tree):
            out.update(_spec_flatten(v, f"{prefix}#{i}/"))
    else:
        out[prefix[:-1]] = (tuple(tree.shape), np.dtype(tree.dtype))
    return out


def validate_tree(flat: Dict[str, np.ndarray], template,
                  what: str = "checkpoint") -> None:
    """Raise ValueError with an actionable per-path report when the
    flattened tree does not match the template's treedef/shapes/dtypes."""
    want = _spec_flatten(template)
    have = {k: (tuple(v.shape), np.dtype(v.dtype)) for k, v in flat.items()}
    problems = []
    for path in sorted(set(want) - set(have)):
        problems.append(f"  missing  {path} "
                        f"(template wants {want[path][0]} {want[path][1]})")
    for path in sorted(set(have) - set(want)):
        problems.append(f"  unexpected  {path} "
                        f"(file has {have[path][0]} {have[path][1]})")
    for path in sorted(set(want) & set(have)):
        if want[path][0] != have[path][0]:
            problems.append(
                f"  shape mismatch  {path}: file {have[path][0]} "
                f"vs template {want[path][0]}")
        elif want[path][1] != have[path][1]:
            problems.append(
                f"  dtype mismatch  {path}: file {have[path][1]} "
                f"vs template {want[path][1]}")
    if problems:
        shown = problems[:12]
        if len(problems) > len(shown):
            shown.append(f"  ... and {len(problems) - len(shown)} more")
        raise ValueError(
            f"{what} does not match the provided template "
            f"({len(problems)} problem(s)):\n" + "\n".join(shown) +
            "\nLikely causes: a config change since the checkpoint was "
            "saved, or loading a different run's file.")


def save_checkpoint(path: str, state) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    np.savez(path, **_flatten(state))


def load_checkpoint(path: str, template=None):
    """Load a checkpoint tree; with ``template`` (a pytree of arrays or
    ShapeDtypeStructs shaped like the expected result) the file's
    paths/shapes/dtypes are validated first and a mismatch raises
    ValueError with the offending paths — instead of silently rebuilding
    whatever treedef the file contains."""
    with np.load(path if path.endswith(".npz") else path + ".npz") as z:
        flat = {k: z[k] for k in z.files}
    if template is not None:
        validate_tree(flat, template)
    return _unflatten(flat)


# ------------------------------------------------- elastic train state
def pack_schedule(sched) -> Dict[str, np.ndarray]:
    """Schedule -> array dict (op table + dims) for npz storage."""
    return {"table": np.asarray(sched.table, np.int8),
            "n_layers": np.int64(sched.n_layers),
            "n_groups": np.int64(sched.n_groups)}


def unpack_schedule(d):
    from repro.core.schedule import Schedule
    return Schedule(np.asarray(d["table"], np.int8),
                    int(d["n_layers"]), int(d["n_groups"]))


def pack_assignment(assignment) -> Dict[str, np.ndarray]:
    """DeviceAssignment -> array dict; capacities round-trip when set."""
    out = {"device_of": np.asarray(assignment.device_of, np.int64),
           "costs": np.asarray(assignment.costs, np.float64),
           "n_devices": np.int64(assignment.n_devices)}
    if assignment.capacities is not None:
        out["capacities"] = np.asarray(assignment.capacities, np.float64)
    return out


def unpack_assignment(d):
    from repro.core.assignment import DeviceAssignment
    caps = d.get("capacities")
    return DeviceAssignment(
        np.asarray(d["device_of"], np.int64),
        np.asarray(d["costs"], np.float64), int(d["n_devices"]),
        np.asarray(caps, np.float64) if caps is not None else None)


def save_train_state(path: str, *, step: int, params, opt_state,
                     sched=None, assignment=None, rng=None,
                     extra: Optional[dict] = None) -> None:
    """Step-level checkpoint for the elastic loop: params + optimizer
    state (caller must pass them in CANONICAL element order — reshard
    zero/zero3 layouts first), step counter, the active schedule and
    device assignment, the RNG key, and any extra scalar/array loop state
    (a dict of numpy-able values). Everything a resume needs to be
    bit-exact, on the original mesh or a shrunk one."""
    state = {"step": np.int64(step), "params": params,
             "opt_state": opt_state}
    if sched is not None:
        state["schedule"] = pack_schedule(sched)
    if assignment is not None:
        state["assignment"] = pack_assignment(assignment)
    if rng is not None:
        state["rng"] = np.asarray(rng)
    if extra:
        state["extra"] = {k: np.asarray(v) for k, v in extra.items()}
    save_checkpoint(path, state)


def load_train_state(path: str, params_template=None) -> dict:
    """Inverse of ``save_train_state``. Returns a dict with ``step``
    (int), ``params``, ``opt_state``, and — when saved — ``schedule``
    (a ``Schedule``), ``assignment`` (a ``DeviceAssignment``), ``rng``
    and ``extra``. ``params_template`` validates the params subtree
    (see ``load_checkpoint``)."""
    state = load_checkpoint(path)
    if params_template is not None:
        validate_tree(_flatten(state["params"]), params_template,
                      what="checkpointed params")
    out = {"step": int(state["step"]), "params": state["params"],
           "opt_state": state["opt_state"]}
    if "schedule" in state:
        out["schedule"] = unpack_schedule(state["schedule"])
    if "assignment" in state:
        out["assignment"] = unpack_assignment(state["assignment"])
    if "rng" in state:
        out["rng"] = np.asarray(state["rng"])
    if "extra" in state:
        out["extra"] = {k: np.asarray(v) for k, v in state["extra"].items()}
    return out
