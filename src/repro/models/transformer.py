"""Composable transformer backbone: ModelConfig -> init / forward / decode.

Design notes
------------
* Layers are grouped into *pattern cycles* (cfg.block_pattern) and executed
  with jax.lax.scan over stacked per-cycle params, keeping HLO size O(1) in
  depth (64-layer configs compile as a 1-cycle body). Remainder layers (when
  n_layers % len(pattern) != 0) run unstacked after the scan.
* D2FT gating: ``gates = (g_f, g_b)`` with shape [n_layers, B, G] each.
  Per block, the residual contribution is decomposed into G head/width
  groups c_g and mixed as
      c_eff = g_f * (g_b * c_g + (1 - g_b) * stop_gradient(c_g)),
  which implements p_f (1,1), p_o (1,0), p_s (0,·) exactly: p_o keeps the
  forward value but kills every gradient (params *and* activations) through
  the subnet for that sample; p_s removes the contribution so only the
  residual route remains. This is the masked reference path; the packed
  deployment path lives in core/d2ft.py.
* MoE blocks treat the routed FFN as a single D2FT group (G position 0).
"""
from __future__ import annotations

import functools
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import (ATTN_GLOBAL, ATTN_LOCAL, RGLRU, SSD,
                                ModelConfig)
from repro.kernels import contract as kernel_contract
from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import rglru as rglru_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import (apply_norm, dense_init, init_embedding,
                                 init_mlp, init_norm, softcap, _act)


# ============================================================ gating helpers
def gate_mix(c_g, g_f, g_b):
    """c_g: [B,S,G,D]; g_f,g_b: [B,G] in {0,1}. See module docstring."""
    gf = g_f[:, None, :, None].astype(c_g.dtype)
    gb = g_b[:, None, :, None].astype(c_g.dtype)
    return gf * (gb * c_g + (1.0 - gb) * jax.lax.stop_gradient(c_g))


def _group_project(heads_out, wo, G):
    """heads_out: [B,S,H,hd]; wo: [H*hd, D]. Returns per-group projected
    contributions [B,S,G,D] (sum over G == plain projection)."""
    B, S, H, hd = heads_out.shape
    D = wo.shape[-1]
    w3 = wo.reshape(H, hd, D)
    per_head = jnp.einsum("bshd,hdD->bshD", heads_out, w3)
    return per_head.reshape(B, S, G, H // G, D).sum(axis=3)


# =================================================== tensor-parallel helpers
# tp = (axis_name, T): Megatron-style sharding over a shard_map mesh axis.
# Weights stay replicated (ZeRO over the data axis composes unchanged);
# each device COMPUTES only its contiguous block of heads / FFN columns
# and the partial residual contributions are psum'd over the axis.
@functools.partial(jax.custom_vjp, nondiff_argnums=(1,))
def _tp_copy(x, axis_name):
    """Megatron's f operator: identity forward, psum backward. Placed at
    every tensor-parallel region's input so the activation cotangent —
    which each device only computes for its own head/column slice — is
    all-reduced, keeping grads of everything upstream replicated-exact."""
    return x


def _tp_copy_fwd(x, axis_name):
    return x, None


def _tp_copy_bwd(axis_name, _, g):
    return (jax.lax.psum(g, axis_name),)


_tp_copy.defvjp(_tp_copy_fwd, _tp_copy_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(1,))
def _tp_sum(x, axis_name):
    """Megatron's g operator: psum forward, identity backward. Used at
    every tensor-parallel region's output — the downstream cotangent is
    replicated over the axis, so the backward must NOT re-reduce it
    (pinned here explicitly rather than relying on psum's transpose)."""
    return jax.lax.psum(x, axis_name)


def _tp_sum_fwd(x, axis_name):
    return jax.lax.psum(x, axis_name), None


def _tp_sum_bwd(axis_name, _, g):
    return (g,)


_tp_sum.defvjp(_tp_sum_fwd, _tp_sum_bwd)


def _tp_gate_slice(layer_gates, idx, G_local):
    """This device's contiguous block of head-group gates ([B, G] pair)."""
    g_f, g_b = layer_gates
    return (jax.lax.dynamic_slice_in_dim(g_f, idx * G_local, G_local, 1),
            jax.lax.dynamic_slice_in_dim(g_b, idx * G_local, G_local, 1))


# ============================================================== block params
def _init_block(key, kind: str, cfg: ModelConfig, dtype):
    ks = jax.random.split(key, 4)
    p = {"norm1": init_norm(cfg.norm, cfg.d_model, dtype)}
    if kind in (ATTN_GLOBAL, ATTN_LOCAL):
        p["attn"] = attn.init_attention(
            ks[0], cfg.d_model, cfg.n_heads, cfg.n_kv_heads,
            cfg.resolved_head_dim, cfg.qkv_bias, dtype)
    elif kind == SSD:
        p["ssd"] = ssm_mod.init_ssd(ks[0], cfg.d_model, cfg.ssm, dtype)
    elif kind == RGLRU:
        p["rglru"] = rglru_mod.init_rglru(ks[0], cfg.d_model, cfg.rglru, dtype)
    else:
        raise ValueError(kind)
    has_ffn = (cfg.moe is not None) or cfg.d_ff > 0
    if kind == SSD:
        has_ffn = cfg.d_ff > 0       # mamba2: no FFN
    if has_ffn:
        p["norm2"] = init_norm(cfg.norm, cfg.d_model, dtype)
        if cfg.moe is not None and kind != SSD:
            p["moe"] = moe_mod.init_moe(ks[1], cfg.d_model, cfg.moe, dtype)
        else:
            p["mlp"] = init_mlp(ks[1], cfg.d_model, cfg.d_ff, cfg.mlp_gated, dtype)
    return p


def _split_gates(gates, idx):
    if gates is None:
        return None
    g_f, g_b = gates
    return g_f[idx], g_b[idx]


# ============================================================= block forward
def _apply_attn_inner(p, h, kind, cfg: ModelConfig, layer_gates, policy,
                      use_kernel: bool = False, live_bounds=None, tp=None):
    """Attention contribution (pre-residual), with per-head-group gating.

    live_bounds: static (live_fwd, live_bwd) bounds at (sample, group)
    granularity (``core.schedule.live_slice_bounds``); scaled to per-head
    slice counts here before reaching the kernel's compaction dispatch.
    tp: optional (axis_name, T) — shard the H heads over a shard_map
    tensor axis. Contiguous head blocks keep the GQA query->kv mapping
    and the (head-group) gate tiling exact when T divides H, Hkv and G."""
    window = cfg.window if kind == ATTN_LOCAL else 0
    hd = cfg.resolved_head_dim
    B, S, _ = h.shape
    n_heads, n_kv = cfg.n_heads, cfg.n_kv_heads
    if tp is not None:
        tp_axis, T = tp
        assert policy is None and not use_kernel, \
            "tensor parallelism has no policy/kernel route"
        assert n_heads % T == 0 and n_kv % T == 0, (n_heads, n_kv, T)
        idx = jax.lax.axis_index(tp_axis)
        h = _tp_copy(h, tp_axis)
        hq, hkv = n_heads // T, n_kv // T

        def sl(a, width, axis):
            return jax.lax.dynamic_slice_in_dim(a, idx * width, width, axis)

        p = dict(p, wq=sl(p["wq"], hq * hd, 1), wk=sl(p["wk"], hkv * hd, 1),
                 wv=sl(p["wv"], hkv * hd, 1), wo=sl(p["wo"], hq * hd, 0))
        if "bq" in p:
            p["bq"] = sl(p["bq"], hq * hd, 0)
            p["bk"] = sl(p["bk"], hkv * hd, 0)
            p["bv"] = sl(p["bv"], hkv * hd, 0)
        n_heads, n_kv = hq, hkv
        if layer_gates is not None:
            G = layer_gates[0].shape[-1]
            assert G % T == 0, (G, T)
            layer_gates = _tp_gate_slice(layer_gates, idx, G // T)
    if policy is not None and layer_gates is None:
        padding = policy.head_padding()
        if padding is not None:
            n_heads, n_kv = padding
            p = dict(p, **attn.pad_attention_params(
                p, cfg.n_heads, cfg.n_kv_heads, hd, n_heads, n_kv))
    q, k, v = attn._project_qkv(p, h, n_heads, n_kv, hd)
    if cfg.rope:
        pos = jnp.arange(S)[None, :]
        q = attn.apply_rope(q, pos, cfg.rope_theta)
        k = attn.apply_rope(k, pos, cfg.rope_theta)
    if use_kernel and policy is None:
        # Pallas kernel path: the gated flash kernel computes attention with
        # g_f forward gates and a custom VJP whose backward kernels skip all
        # g_b == 0 (sample, head) slices (see kernels/d2ft_attention.py).
        # The window branch is always causal-windowed, matching _window_mask.
        kernel_bounds = None
        if layer_gates is None:
            gf_h = gb_h = jnp.ones((B, n_heads), h.dtype)
        else:
            g_f, g_b = layer_gates
            rep = n_heads // g_f.shape[-1]
            gf_h = jnp.repeat(g_f, rep, axis=1).astype(h.dtype)
            gb_h = jnp.repeat(g_b, rep, axis=1).astype(h.dtype)
            if live_bounds is not None:
                # schedule bounds are per (sample, group); each group is
                # rep consecutive per-head slices after the expansion above
                kernel_bounds = (live_bounds[0] * rep, live_bounds[1] * rep)
        out = attn.gated_kernel_attention(q, k, v, gf_h, gb_h,
                                          causal=cfg.causal or window > 0,
                                          window=window,
                                          live_bounds=kernel_bounds)
    else:
        if use_kernel and policy is not None:
            kernel_contract.report_fallback(
                "attn", "sharded policy path has no kernel route")
        if policy is not None:
            q, k, v = policy.heads(q), policy.kv(k), policy.kv(v)
        chunk = policy.attn_q_chunk if policy is not None else 0
        if window and window > 0 and S > 2 * window and S % window == 0:
            out = attn._block_local_attention(q, k, v, window)
        elif chunk and chunk > 0 and S % chunk == 0 and S > chunk:
            out = attn._chunked_sdpa(q, k, v, chunk, causal=cfg.causal,
                                     window=window)
        elif window and window > 0:
            out = attn._sdpa(q, k, v, attn._window_mask(S, S, window))
        elif cfg.causal:
            out = attn._sdpa(q, k, v, attn._causal_mask(S, S))
        else:
            out = attn._sdpa(q, k, v, jnp.ones((1, 1, S, S), bool))
    if layer_gates is None:
        c = out.reshape(B, S, n_heads * hd) @ p["wo"]
        return _tp_sum(c, tp[0]) if tp is not None else c
    # group-wise projection + gate_mix: on the kernel path this also cuts
    # wo gradients for p_o groups, matching the masked reference exactly.
    g_f, g_b = layer_gates
    G = g_f.shape[-1]
    c_g = _group_project(out, p["wo"], G)               # [B,S,G,D]
    c = gate_mix(c_g, g_f, g_b).sum(axis=2)
    return _tp_sum(c, tp[0]) if tp is not None else c


def _apply_ffn(p, h, cfg: ModelConfig, layer_gates, policy,
               use_kernel: bool = False, live_bounds=None, tp=None):
    # MoE compute stays replicated under tensor parallelism (the
    # expert-parallel route is the GSPMD policy path) — replicated inputs
    # give replicated outputs/grads, so no psum is needed.
    if "moe" in p:
        if policy is not None and policy.moe_sharded(cfg):
            if use_kernel:
                kernel_contract.report_fallback(
                    "moe", "sharded expert-parallel path has no kernel route")
            y, aux = moe_mod.apply_moe_ep(
                p["moe"], h, cfg.moe, cfg.mlp_act, policy.mesh,
                policy.batch_axes if
                h.shape[0] % policy.data_size == 0 else None,
                seq_sharded=h.shape[1] % policy.model_size == 0
                and h.shape[1] > 1,
                expert_parallel=policy.expert_parallel)
        else:
            if use_kernel and policy is not None:
                kernel_contract.report_fallback(
                    "moe", "sharded policy path has no kernel route")
            moe_gates = None
            live_toks = bwd_toks = None
            if layer_gates is not None:
                g_f, g_b = layer_gates
                # MoE is one D2FT group (G position 0): per-sample gates
                moe_gates = (g_f[:, 0], g_b[:, 0])
                if live_bounds is not None:
                    live_toks = min(h.shape[0], live_bounds[0]) * h.shape[1]
                    # separate g_b bound: backward-live slots pack into a
                    # capacity prefix, so the kernel backward truncates to
                    # this even when the forward covers every p_o slot
                    bwd_toks = min(h.shape[0], live_bounds[1]) * h.shape[1]
            y, aux = moe_mod.apply_moe(
                p["moe"], h, cfg.moe, act=cfg.mlp_act,
                shard_fn=policy.moe if policy is not None else None,
                gates=moe_gates,
                use_kernel=use_kernel and policy is None,
                live_tokens=live_toks, live_bwd_tokens=bwd_toks)
        if layer_gates is not None:
            g_f, g_b = layer_gates
            y = gate_mix(y[:, :, None, :], g_f[:, :1], g_b[:, :1])[:, :, 0]
        return y, aux
    mlp = p["mlp"]
    if tp is not None:
        # FFN columns over the tensor axis: T | G keeps each device's
        # F/T-column block an integral number of whole gate groups, so the
        # grouped w_down reshape below stays exact on the local slice.
        tp_axis, T = tp
        assert policy is None, "tensor parallelism has no policy route"
        idx = jax.lax.axis_index(tp_axis)
        h = _tp_copy(h, tp_axis)
        F_full = mlp["w_up"].shape[-1]
        assert F_full % T == 0, (F_full, T)
        Fl = F_full // T

        def sl(a, axis):
            return jax.lax.dynamic_slice_in_dim(a, idx * Fl, Fl, axis)

        mlp = dict(mlp, w_up=sl(mlp["w_up"], 1), w_down=sl(mlp["w_down"], 0))
        if "w_gate" in mlp:
            mlp["w_gate"] = sl(mlp["w_gate"], 1)
        if layer_gates is not None:
            G = layer_gates[0].shape[-1]
            assert G % T == 0 and F_full % G == 0, (G, T, F_full)
            layer_gates = _tp_gate_slice(layer_gates, idx, G // T)
    up = h @ mlp["w_up"]
    if cfg.mlp_gated:
        hid = _act(cfg.mlp_act)(h @ mlp["w_gate"]) * up
    else:
        hid = _act(cfg.mlp_act)(up)
    if policy is not None:
        hid = policy.ffn(hid)
    if layer_gates is None:
        y = hid @ mlp["w_down"]
        return (_tp_sum(y, tp[0]) if tp is not None else y), None
    g_f, g_b = layer_gates
    G = g_f.shape[-1]
    B, S, F = hid.shape
    D = mlp["w_down"].shape[-1]
    wd = mlp["w_down"].reshape(G, F // G, D)
    c_g = jnp.einsum("bsgf,gfD->bsgD", hid.reshape(B, S, G, F // G), wd)
    y = gate_mix(c_g, g_f, g_b).sum(axis=2)
    return (_tp_sum(y, tp[0]) if tp is not None else y), None


def _apply_ssd_inner(p, h, cfg: ModelConfig, layer_gates,
                     use_kernel: bool = False, live_bounds=None):
    if layer_gates is None:
        return ssm_mod.apply_ssd(p, h, cfg.d_model, cfg.ssm)
    g_f, g_b = layer_gates
    G = g_f.shape[-1]
    d_inner, H, P, N = ssm_mod._dims(cfg.d_model, cfg.ssm)
    if H % G != 0:
        # heads don't tile into gate groups: fall back to the coarse
        # block-granularity run-twice mix (test-scale only)
        if use_kernel:
            kernel_contract.report_fallback(
                "ssd", f"H={H} not divisible by G={G} gate groups")
        full = ssm_mod.apply_ssd(p, h, cfg.d_model, cfg.ssm)
        sg = jax.lax.stop_gradient(full)
        gf = g_f[:, :1].mean(-1)[:, None, None]         # block granularity
        gb = g_b[:, :1].mean(-1)[:, None, None]
        return gf * (gb * full + (1 - gb) * sg)
    # gate per SSD head: each of the G schedule groups spans H // G
    # consecutive heads; the scan is gated per (sample, head) inside
    # apply_ssd (kernel or masked mix) before the D-residual shortcut.
    rep = H // G
    gf_h = jnp.repeat(g_f, rep, axis=1).astype(jnp.float32)
    gb_h = jnp.repeat(g_b, rep, axis=1).astype(jnp.float32)
    kernel_bounds = None
    if live_bounds is not None:
        kernel_bounds = (live_bounds[0] * rep, live_bounds[1] * rep)
    return ssm_mod.apply_ssd(p, h, cfg.d_model, cfg.ssm,
                             gates=(gf_h, gb_h), use_kernel=use_kernel,
                             live_bounds=kernel_bounds)


def _apply_rglru_inner(p, h, cfg: ModelConfig, layer_gates,
                      use_kernel: bool = False, live_bounds=None):
    if layer_gates is None:
        return rglru_mod.apply_rglru(p, h, cfg.rglru)
    g_f, g_b = layer_gates
    G = g_f.shape[-1]
    W = cfg.rglru.lru_width or cfg.d_model
    if W % G != 0:
        # width doesn't tile into gate groups: coarse run-twice mix
        if use_kernel:
            kernel_contract.report_fallback(
                "rglru", f"lru width={W} not divisible by G={G} gate groups")
        full = rglru_mod.apply_rglru(p, h, cfg.rglru)
        sg = jax.lax.stop_gradient(full)
        gf = g_f[:, :1].mean(-1)[:, None, None]
        gb = g_b[:, :1].mean(-1)[:, None, None]
        return gf * (gb * full + (1 - gb) * sg)
    # gates stay at (sample, group) granularity: the G groups slice the LRU
    # width into contiguous channel bands (the kernel's slice axis is B*G,
    # so schedule live bounds pass through unscaled)
    gf = g_f.astype(jnp.float32)
    gb = g_b.astype(jnp.float32)
    return rglru_mod.apply_rglru(p, h, cfg.rglru, gates=(gf, gb),
                                 use_kernel=use_kernel,
                                 live_bounds=live_bounds)


def apply_block(p, x, kind: str, cfg: ModelConfig, layer_gates=None,
                policy=None, use_kernel: bool = False, live_bounds=None,
                tp=None):
    """Pre-norm residual block. Returns (x, aux_losses or None).

    tp: optional (axis_name, T) shard_map tensor-parallel spec — attention
    heads and FFN columns shard over the axis, SSD/RG-LRU/MoE blocks run
    replicated (their grads stay replicated, so no psum is needed)."""
    h = apply_norm(p["norm1"], x, cfg.norm)
    if kind in (ATTN_GLOBAL, ATTN_LOCAL):
        c = _apply_attn_inner(p["attn"], h, kind, cfg, layer_gates, policy,
                              use_kernel, live_bounds, tp)
    elif kind == SSD:
        c = _apply_ssd_inner(p["ssd"], h, cfg, layer_gates, use_kernel,
                             live_bounds)
    elif kind == RGLRU:
        c = _apply_rglru_inner(p["rglru"], h, cfg, layer_gates, use_kernel,
                               live_bounds)
    if policy is not None:
        # constrain the CONTRIBUTION before the residual add so GSPMD emits
        # a reduce-scatter of the partial-sum projection instead of
        # all-reduce + slice (Megatron sequence-parallel; §Perf iter q2)
        c = policy.residual(c)
    x = x + c
    if policy is not None:
        x = policy.residual(x)
    aux = None
    if "norm2" in p:
        h2 = apply_norm(p["norm2"], x, cfg.norm)
        y, aux = _apply_ffn(p, h2, cfg, layer_gates, policy, use_kernel,
                            live_bounds, tp)
        if policy is not None:
            y = policy.residual(y)
        x = x + y
        if policy is not None:
            x = policy.residual(x)
    return x, aux


# ========================================================== layer grouping
def layer_groups(cfg: ModelConfig) -> Tuple[int, Tuple[str, ...], Tuple[str, ...]]:
    """Returns (n_cycles, pattern, remainder_kinds)."""
    pat = cfg.block_pattern
    n_cycles = cfg.n_layers // len(pat)
    rem = cfg.layer_kinds[n_cycles * len(pat):]
    return n_cycles, pat, rem


# ================================================================ model init
def init_model(key, cfg: ModelConfig):
    dtype = jnp.dtype(cfg.param_dtype)
    n_cycles, pat, rem = layer_groups(cfg)
    keys = jax.random.split(key, 4 + len(rem))
    params = {"embed": init_embedding(keys[0], cfg.vocab_size, cfg.d_model, dtype),
              "final_norm": init_norm(cfg.norm, cfg.d_model, dtype)}
    if not cfg.tie_embeddings:
        params["unembed"] = dense_init(keys[1], cfg.d_model, cfg.vocab_size, dtype)
    if cfg.frontend != "none":
        params["frontend_proj"] = dense_init(
            keys[2], cfg.frontend_dim, cfg.d_model, dtype)

    def init_cycle(ck):
        cks = jax.random.split(ck, len(pat))
        return [_init_block(cks[i], pat[i], cfg, dtype) for i in range(len(pat))]

    if n_cycles > 0:
        cycle_keys = jax.random.split(keys[3], n_cycles)
        stacked = jax.vmap(init_cycle)(cycle_keys)   # leading dim n_cycles
        params["cycles"] = stacked
    params["rest"] = [
        _init_block(keys[4 + i], rem[i], cfg, dtype) for i in range(len(rem))]
    return params


# ============================================================ model forward
def forward(params, cfg: ModelConfig, tokens=None, features=None,
            gates=None, policy=None, remat: bool = False,
            use_kernel: bool = False, live_bounds=None, tp=None):
    """Returns (logits, aux) — logits [B, S, vocab].

    tokens: [B, S_text] int32 (None for pure-audio encoders)
    features: [B, T_f, frontend_dim] stub frontend embeddings (audio/vlm)
    gates: optional (g_f, g_b) of shape [n_layers, B, G]
    use_kernel: route attention blocks through the Pallas gated flash
        kernel (gate-aware custom VJP) instead of the masked dense path.
    live_bounds: optional static (live_fwd, live_bwd) per-layer max live
        (sample, group) slice counts from ``core.schedule
        .live_slice_bounds`` — enables the kernel path's compaction
        dispatch (one shared bound so scan compiles a single body).
    tp: optional (axis_name, T) shard_map tensor-parallel spec (must be
        traced inside shard_map over a mesh with that axis; see
        ``apply_block``). Embedding/norm/logits compute stays replicated.
    """
    cdt = jnp.dtype(cfg.compute_dtype)
    parts = []
    if features is not None:
        parts.append((features.astype(cdt) @ params["frontend_proj"].astype(cdt)))
    if tokens is not None:
        from repro.models.layers import apply_embedding
        parts.append(apply_embedding(params["embed"], tokens).astype(cdt))
    x = jnp.concatenate(parts, axis=1) if len(parts) > 1 else parts[0]
    if policy is not None:
        x = policy.residual(x)

    n_cycles, pat, rem = layer_groups(cfg)
    P = len(pat)
    aux_sum = jnp.zeros((), jnp.float32)

    if gates is not None:
        g_f, g_b = gates
        g_f_c = g_f[:n_cycles * P].reshape(n_cycles, P, *g_f.shape[1:])
        g_b_c = g_b[:n_cycles * P].reshape(n_cycles, P, *g_b.shape[1:])
        g_rest = (g_f[n_cycles * P:], g_b[n_cycles * P:])
    else:
        g_f_c = g_b_c = g_rest = None

    if n_cycles > 0:
        def cycle_body(carry, xs):
            x, aux = carry
            if gates is not None:
                blocks, gfc, gbc = xs
            else:
                (blocks,) = xs
            for i in range(P):
                lg = (gfc[i], gbc[i]) if gates is not None else None
                x, a = apply_block(blocks[i], x, pat[i], cfg, lg, policy,
                                   use_kernel, live_bounds, tp)
                if a is not None:
                    aux = aux + a["load_balance"] + a["router_z"]
            return (x, aux), None

        body = cycle_body
        if remat:
            body = jax.checkpoint(cycle_body, prevent_cse=False)
        xs = (params["cycles"],) if gates is None else (
            params["cycles"], g_f_c, g_b_c)
        if n_cycles <= 2:
            # Unrolled: XLA's cost_analysis counts a while body ONCE
            # regardless of trip count, so the dry-run's depth-1/depth-2
            # FLOP extrapolation needs shallow models fully inlined.
            for c in range(n_cycles):
                xs_c = jax.tree.map(lambda a: a[c], xs)
                (x, aux_sum), _ = body((x, aux_sum), xs_c)
        else:
            (x, aux_sum), _ = jax.lax.scan(body, (x, aux_sum), xs)

    for i, kind in enumerate(rem):
        lg = None
        if gates is not None:
            lg = (g_rest[0][i], g_rest[1][i])
        x, a = apply_block(params["rest"][i], x, kind, cfg, lg, policy,
                           use_kernel, live_bounds, tp)
        if a is not None:
            aux_sum = aux_sum + a["load_balance"] + a["router_z"]

    x = apply_norm(params["final_norm"], x, cfg.norm)
    if cfg.tie_embeddings:
        logits = x @ params["embed"]["table"].T.astype(cdt)
    else:
        logits = x @ params["unembed"].astype(cdt)
    if policy is not None:
        logits = policy.logits(logits)
    logits = softcap(logits, cfg.logit_softcap)
    return logits, {"aux_loss": aux_sum}


# ================================================================== decoding
def init_cache(cfg: ModelConfig, batch: int, max_len: int):
    """Per-layer caches, stacked per cycle position (mirrors params)."""
    dtype = jnp.dtype(cfg.compute_dtype)
    n_cycles, pat, rem = layer_groups(cfg)

    def one(kind):
        if kind == ATTN_GLOBAL:
            return attn.init_kv_cache(batch, max_len, cfg.n_kv_heads,
                                      cfg.resolved_head_dim, 0, dtype)
        if kind == ATTN_LOCAL:
            return attn.init_kv_cache(batch, max_len, cfg.n_kv_heads,
                                      cfg.resolved_head_dim, cfg.window, dtype)
        if kind == SSD:
            return ssm_mod.init_ssd_cache(batch, cfg.d_model, cfg.ssm, dtype)
        if kind == RGLRU:
            return rglru_mod.init_rglru_cache(batch, cfg.d_model, cfg.rglru, dtype)
        raise ValueError(kind)

    cache = {}
    if n_cycles > 0:
        cache["cycles"] = [
            jax.tree.map(lambda a: jnp.broadcast_to(a, (n_cycles,) + a.shape),
                         one(k)) for k in pat]
    cache["rest"] = [one(k) for k in rem]
    return cache


def _decode_block(p, c, x, kind, cfg: ModelConfig, t):
    h = apply_norm(p["norm1"], x, cfg.norm)
    if kind in (ATTN_GLOBAL, ATTN_LOCAL):
        hd = cfg.resolved_head_dim
        window = cfg.window if kind == ATTN_LOCAL else 0
        y, c = attn.decode_attention(
            p["attn"], c, h, t=t, n_heads=cfg.n_heads,
            n_kv_heads=cfg.n_kv_heads, head_dim=hd, window=window,
            rope=cfg.rope, rope_theta=cfg.rope_theta)
    elif kind == SSD:
        y, c = ssm_mod.decode_ssd(p["ssd"], c, h, cfg.d_model, cfg.ssm)
    elif kind == RGLRU:
        y, c = rglru_mod.decode_rglru(p["rglru"], c, h, cfg.rglru)
    x = x + y
    if "norm2" in p:
        h2 = apply_norm(p["norm2"], x, cfg.norm)
        if "moe" in p:
            y2, _ = moe_mod.apply_moe(p["moe"], h2, cfg.moe, act=cfg.mlp_act)
        else:
            from repro.models.layers import apply_mlp
            y2 = apply_mlp(p["mlp"], h2, cfg.mlp_act, cfg.mlp_gated)
        x = x + y2
    return x, c


def decode_step(params, cache, cfg: ModelConfig, token, t, policy=None):
    """One decode step. token: [B,1] int32; t: scalar — tokens already cached.
    Returns (logits [B,1,vocab], new_cache)."""
    from repro.models.layers import apply_embedding
    cdt = jnp.dtype(cfg.compute_dtype)
    x = apply_embedding(params["embed"], token).astype(cdt)
    n_cycles, pat, rem = layer_groups(cfg)
    P = len(pat)

    new_cache = {"rest": []}
    if n_cycles > 0:
        def cycle_body(x, xs):
            blocks = xs[0]
            caches = xs[1]
            new_cs = []
            for i in range(P):
                x, nc = _decode_block(blocks[i], caches[i], x, pat[i], cfg, t)
                new_cs.append(nc)
            return x, new_cs

        if n_cycles <= 2:
            # unrolled for dry-run cost extrapolation (see forward())
            emitted = []
            for c in range(n_cycles):
                xs_c = jax.tree.map(lambda a: a[c],
                                    (params["cycles"], cache["cycles"]))
                x, nc = cycle_body(x, xs_c)
                emitted.append(nc)
            new_cache["cycles"] = jax.tree.map(
                lambda *leaves: jnp.stack(leaves), *emitted)
        else:
            x, new_cycle_cache = jax.lax.scan(
                cycle_body, x, (params["cycles"], cache["cycles"]))
            new_cache["cycles"] = new_cycle_cache
    for i, kind in enumerate(rem):
        x, nc = _decode_block(params["rest"][i], cache["rest"][i], x, kind,
                              cfg, t)
        new_cache["rest"].append(nc)

    x = apply_norm(params["final_norm"], x, cfg.norm)
    if cfg.tie_embeddings:
        logits = x @ params["embed"]["table"].T.astype(cdt)
    else:
        logits = x @ params["unembed"].astype(cdt)
    if policy is not None:
        logits = policy.logits(logits)
    return softcap(logits, cfg.logit_softcap), new_cache


# ====================================================== prefill (cache dump)
def _prefill_block(p, x, kind: str, cfg: ModelConfig, max_len: int,
                   raw_kv: bool):
    """One block of the batched prefill: the dense forward computation of
    ``apply_block`` (ungated, unsharded) plus the decode-cache entry the
    block leaves behind — post-rope K/V for attention, final conv/recurrent
    state for SSD / RG-LRU. Returns (x, cache_entry)."""
    h = apply_norm(p["norm1"], x, cfg.norm)
    if kind in (ATTN_GLOBAL, ATTN_LOCAL):
        window = cfg.window if kind == ATTN_LOCAL else 0
        c, k, v = attn.apply_attention(
            p["attn"], h, n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
            head_dim=cfg.resolved_head_dim, causal=cfg.causal, window=window,
            rope=cfg.rope, rope_theta=cfg.rope_theta, return_kv=True)
        entry = {"k": k, "v": v} if raw_kv else \
            attn.kv_prefill_cache(k, v, window, max_len)
    elif kind == SSD:
        c, entry = ssm_mod.apply_ssd(p["ssd"], h, cfg.d_model, cfg.ssm,
                                     return_state=True)
    elif kind == RGLRU:
        c, entry = rglru_mod.apply_rglru(p["rglru"], h, cfg.rglru,
                                         return_state=True)
    else:
        raise ValueError(kind)
    x = x + c
    if "norm2" in p:
        h2 = apply_norm(p["norm2"], x, cfg.norm)
        y, _ = _apply_ffn(p, h2, cfg, None, None)
        x = x + y
    return x, entry


def prefill_forward(params, cfg: ModelConfig, tokens, max_len: int = 0,
                    *, raw_kv: bool = False):
    """Batched serving prefill: ONE teacher-forced ``forward()`` pass over
    the whole prompt that also dumps the decode caches — O(1) launches
    instead of the O(S) sequential decode-path loop.

    tokens: [B, S] int32. Returns (logits [B, S, vocab], cache) where cache
    matches ``init_cache(cfg, B, max_len)`` structurally and decode can
    continue from position S. With ``raw_kv=True`` attention entries are
    instead the full post-rope history ``{"k","v"}: [B, S, n_kv, hd]`` —
    what the paged serving engine slices into fixed-size pages
    (``serving/engine.py``).

    Layers are unrolled (no scan): serving compiles once per engine and
    needs per-layer cache capture, not O(1)-in-depth HLO like training.
    """
    cdt = jnp.dtype(cfg.compute_dtype)
    from repro.models.layers import apply_embedding
    B, S = tokens.shape
    max_len = max_len or S
    x = apply_embedding(params["embed"], tokens).astype(cdt)

    n_cycles, pat, rem = layer_groups(cfg)
    P = len(pat)
    cache = {"rest": []}
    cycle_entries = []
    for c in range(n_cycles):
        blocks = jax.tree.map(lambda a: a[c], params["cycles"])
        ents = []
        for i in range(P):
            x, e = _prefill_block(blocks[i], x, pat[i], cfg, max_len, raw_kv)
            ents.append(e)
        cycle_entries.append(ents)
    if n_cycles > 0:
        cache["cycles"] = jax.tree.map(lambda *leaves: jnp.stack(leaves),
                                       *cycle_entries)
    for i, kind in enumerate(rem):
        x, e = _prefill_block(params["rest"][i], x, kind, cfg, max_len,
                              raw_kv)
        cache["rest"].append(e)

    x = apply_norm(params["final_norm"], x, cfg.norm)
    if cfg.tie_embeddings:
        logits = x @ params["embed"]["table"].T.astype(cdt)
    else:
        logits = x @ params["unembed"].astype(cdt)
    return softcap(logits, cfg.logit_softcap), cache


# ============================================================== loss helpers
@jax.custom_vjp
def fused_xent(logits, labels):
    """Mean token cross-entropy with a hand-written backward.

    Forward never materializes a float32 [B,S,V] buffer (label logit is
    gathered first; logsumexp reduces with fused elementwise ops) and the
    backward emits the cotangent (softmax - onehot) directly in the logits
    dtype — the naive log_softmax formulation kept several f32 logits-sized
    temps alive, dominating HBM in the dry-run (EXPERIMENTS.md §Perf).
    """
    loss, _ = _xent_fwd_impl(logits, labels)
    return loss


def _xent_fwd_impl(logits, labels):
    label_logit = jnp.take_along_axis(logits, labels[..., None],
                                      axis=-1)[..., 0].astype(jnp.float32)
    m = jnp.max(logits, axis=-1)
    # exp stays in the logits dtype; the reduce accumulates in f32 (no f32
    # [B,S,V] materialization even on backends with weak fusion)
    lse = m.astype(jnp.float32) + jnp.log(jnp.sum(
        jnp.exp(logits - m[..., None]), axis=-1, dtype=jnp.float32))
    loss = jnp.mean(lse - label_logit)
    return loss, (logits, labels, m, lse)


def _xent_bwd_impl(res, g):
    logits, labels, m, lse = res
    n = logits.size // logits.shape[-1]
    # softmax in the logits dtype; exp fuses with the subtraction
    z = (lse - m.astype(jnp.float32)).astype(logits.dtype)
    gn = (g / n).astype(logits.dtype)
    dlogits = jnp.exp(logits - m[..., None] - z[..., None]) * gn
    # subtract g/n at the label position by scatter — avoids materializing a
    # [B, S, V] onehot buffer
    b_idx = jnp.arange(dlogits.shape[0])[:, None]
    s_idx = jnp.arange(dlogits.shape[1])[None, :]
    dlogits = dlogits.at[b_idx, s_idx, labels].add(-gn)
    return dlogits, None


fused_xent.defvjp(lambda logits, labels: _xent_fwd_impl(logits, labels),
                  _xent_bwd_impl)


def lm_loss(params, cfg: ModelConfig, tokens, labels, features=None,
            gates=None, policy=None, remat: bool = False,
            use_kernel: bool = False, live_bounds=None, tp=None):
    """Next-token (or frame-classification) cross-entropy."""
    logits, aux = forward(params, cfg, tokens=tokens, features=features,
                          gates=gates, policy=policy, remat=remat,
                          use_kernel=use_kernel, live_bounds=live_bounds,
                          tp=tp)
    if features is not None and tokens is not None:
        # VLM: loss only over the text region (labels align to text tokens)
        logits = logits[:, -labels.shape[1]:]
    loss = fused_xent(logits, labels)
    return loss + aux["aux_loss"], {"ce": loss, "aux": aux["aux_loss"]}
