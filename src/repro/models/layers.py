"""Basic pure-JAX layers: norms, embeddings, RoPE, MLPs.

Convention: every layer is a pair of functions
  ``init_<layer>(key, ...) -> params``  (params = pytree of jnp arrays)
  ``<layer>(params, x, ...) -> y``      (pure, jit-able)
No framework dependency; shapes follow [batch, seq, d_model].
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def _dtype(name: str):
    return jnp.dtype(name)


# ---------------------------------------------------------------- init utils
def dense_init(key, in_dim: int, out_dim: int, dtype, scale: float | None = None):
    scale = scale if scale is not None else 1.0 / np.sqrt(in_dim)
    return (jax.random.normal(key, (in_dim, out_dim)) * scale).astype(dtype)


def embed_init(key, vocab: int, dim: int, dtype):
    return (jax.random.normal(key, (vocab, dim)) * 0.02).astype(dtype)


# --------------------------------------------------------------------- norms
def init_norm(kind: str, dim: int, dtype):
    if kind == "rms":
        return {"scale": jnp.ones((dim,), dtype)}
    elif kind == "layer":
        return {"scale": jnp.ones((dim,), dtype), "bias": jnp.zeros((dim,), dtype)}
    raise ValueError(kind)


def apply_norm(params, x, kind: str = "rms", eps: float = 1e-6):
    xf = x.astype(jnp.float32)
    if kind == "rms":
        var = jnp.mean(xf * xf, axis=-1, keepdims=True)
        y = xf * jax.lax.rsqrt(var + eps)
        return (y * params["scale"].astype(jnp.float32)).astype(x.dtype)
    elif kind == "layer":
        mu = jnp.mean(xf, axis=-1, keepdims=True)
        var = jnp.mean((xf - mu) ** 2, axis=-1, keepdims=True)
        y = (xf - mu) * jax.lax.rsqrt(var + eps)
        y = y * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)
        return y.astype(x.dtype)
    raise ValueError(kind)


# ---------------------------------------------------------------------- RoPE
def rope_freqs(head_dim: int, theta: float):
    return 1.0 / (theta ** (np.arange(0, head_dim, 2) / head_dim))


def apply_rope(x, positions, theta: float):
    """x: [..., seq, heads, head_dim]; positions: [..., seq]."""
    head_dim = x.shape[-1]
    freqs = jnp.asarray(rope_freqs(head_dim, theta), jnp.float32)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # [..., S, hd/2]
    angles = angles[..., None, :]                                 # broadcast heads
    sin, cos = jnp.sin(angles), jnp.cos(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ----------------------------------------------------------------------- MLP
def init_mlp(key, d_model: int, d_ff: int, gated: bool, dtype):
    k1, k2, k3 = jax.random.split(key, 3)
    p = {"w_up": dense_init(k1, d_model, d_ff, dtype),
         "w_down": dense_init(k2, d_ff, d_model, dtype)}
    if gated:
        p["w_gate"] = dense_init(k3, d_model, d_ff, dtype)
    return p


def _act(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu, "relu": jax.nn.relu}[name]


def apply_mlp(params, x, act: str = "silu", gated: bool = True):
    h = x @ params["w_up"]
    if gated:
        h = _act(act)(x @ params["w_gate"]) * h
    else:
        h = _act(act)(h)
    return h @ params["w_down"]


# ----------------------------------------------------------------- embedding
def init_embedding(key, vocab: int, d_model: int, dtype):
    return {"table": embed_init(key, vocab, d_model, dtype)}


def apply_embedding(params, tokens):
    return jnp.take(params["table"], tokens, axis=0)


def apply_unembedding(params, x):
    return x @ params["table"].T


def softcap(logits, cap: float):
    if cap and cap > 0:
        return jnp.tanh(logits / cap) * cap
    return logits
