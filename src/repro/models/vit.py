"""ViT for the paper's own experiments (ViT-small, 12 blocks, 6 heads).

Reuses the shared transformer blocks (bidirectional attention, learned
positional embeddings, classification head over the CLS token) so D2FT head
-group gating works identically to the LLM backbones.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.configs.base import ATTN_GLOBAL, ModelConfig
from repro.models.layers import apply_norm, dense_init, init_norm
from repro.models.transformer import _init_block, apply_block


@dataclass(frozen=True)
class ViTConfig:
    n_layers: int = 12
    d_model: int = 384
    n_heads: int = 6
    d_ff: int = 1536
    patch: int = 16
    image_size: int = 224
    n_classes: int = 10

    @property
    def n_patches(self) -> int:
        return (self.image_size // self.patch) ** 2

    def backbone(self) -> ModelConfig:
        return ModelConfig(
            name="vit", arch_type="vit", n_layers=self.n_layers,
            d_model=self.d_model, n_heads=self.n_heads,
            n_kv_heads=self.n_heads, d_ff=self.d_ff, vocab_size=self.n_classes,
            causal=False, rope=False, mlp_act="gelu", mlp_gated=False,
            norm="layer", block_pattern=(ATTN_GLOBAL,))


def vit_small(n_classes: int = 10) -> ViTConfig:
    return ViTConfig(n_classes=n_classes)


def init_vit(key, cfg: ViTConfig, dtype=jnp.float32):
    bb = cfg.backbone()
    ks = jax.random.split(key, cfg.n_layers + 4)
    patch_dim = cfg.patch * cfg.patch * 3
    params = {
        "patch_proj": dense_init(ks[0], patch_dim, cfg.d_model, dtype),
        "patch_bias": jnp.zeros((cfg.d_model,), dtype),
        "cls": (jax.random.normal(ks[1], (1, 1, cfg.d_model)) * 0.02).astype(dtype),
        "pos": (jax.random.normal(ks[2], (1, cfg.n_patches + 1, cfg.d_model))
                * 0.02).astype(dtype),
        "blocks": [_init_block(ks[3 + i], ATTN_GLOBAL, bb, dtype)
                   for i in range(cfg.n_layers)],
        "final_norm": init_norm("layer", cfg.d_model, dtype),
        "head": dense_init(ks[3 + cfg.n_layers], cfg.d_model, cfg.n_classes, dtype),
    }
    return params


def patchify(images, patch: int):
    """images: [B, H, W, 3] -> [B, n_patches, patch*patch*3]."""
    B, H, W, C = images.shape
    ph, pw = H // patch, W // patch
    x = images.reshape(B, ph, patch, pw, patch, C)
    x = x.transpose(0, 1, 3, 2, 4, 5)
    return x.reshape(B, ph * pw, patch * patch * C)


def vit_forward(params, images, cfg: ViTConfig, gates=None,
                use_kernel: bool = False, live_bounds=None):
    """images: [B,H,W,3]; gates: optional (g_f, g_b) [n_layers, B, G];
    use_kernel routes attention through the Pallas gated flash kernel
    (gate-aware backward) instead of the masked dense path; live_bounds is
    the optional static (live_fwd, live_bwd) (sample, group) slice bound
    pair (``core.schedule.live_slice_bounds``) enabling the kernel's
    compaction dispatch.

    Returns logits [B, n_classes].
    """
    bb = cfg.backbone()
    x = patchify(images, cfg.patch) @ params["patch_proj"] + params["patch_bias"]
    cls = jnp.broadcast_to(params["cls"], (x.shape[0], 1, cfg.d_model))
    x = jnp.concatenate([cls, x], axis=1) + params["pos"]
    for i, blk in enumerate(params["blocks"]):
        lg = None
        if gates is not None:
            lg = (gates[0][i], gates[1][i])
        x, _ = apply_block(blk, x, ATTN_GLOBAL, bb, lg,
                           use_kernel=use_kernel, live_bounds=live_bounds)
    x = apply_norm(params["final_norm"], x, "layer")
    return x[:, 0] @ params["head"]


def vit_loss(params, images, labels, cfg: ViTConfig, gates=None,
             use_kernel: bool = False, live_bounds=None):
    logits = vit_forward(params, images, cfg, gates, use_kernel=use_kernel,
                         live_bounds=live_bounds)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32))
    ll = jnp.take_along_axis(logp, labels[:, None], axis=-1)[:, 0]
    loss = -jnp.mean(ll)
    acc = jnp.mean((jnp.argmax(logits, -1) == labels).astype(jnp.float32))
    return loss, {"acc": acc}
