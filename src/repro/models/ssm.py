"""Mamba-2 SSD (state-space duality) block, chunked-scan implementation.

Follows arXiv:2405.21060: per-head scalar decay a_t = exp(dt_t * A_h),
state update h_t = a_t h_{t-1} + dt_t * x_t B_t^T, output y_t = C_t h_t + D x_t.
Training/prefill uses the chunked algorithm (intra-chunk quadratic term +
inter-chunk recurrence via lax.scan); decode is the O(1) single-step
recurrence against a cached state.

Shapes: d_inner = expand * d_model, H = d_inner // head_dim (P), state N.
Single B/C group (ngroups=1) as in mamba2-130m.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import SSMConfig
from repro.kernels import ops as kernel_ops
from repro.models.layers import dense_init


def _dims(d_model: int, cfg: SSMConfig):
    d_inner = cfg.expand * d_model
    H = d_inner // cfg.head_dim
    return d_inner, H, cfg.head_dim, cfg.state_dim


def init_ssd(key, d_model: int, cfg: SSMConfig, dtype):
    d_inner, H, P, N = _dims(d_model, cfg)
    conv_ch = d_inner + 2 * N
    ks = jax.random.split(key, 5)
    return {
        # in_proj -> [z (d_inner) | xBC (d_inner + 2N) | dt (H)]
        "w_in": dense_init(ks[0], d_model, 2 * d_inner + 2 * N + H, dtype),
        "conv_w": (jax.random.normal(ks[1], (cfg.conv_width, conv_ch)) * 0.1).astype(dtype),
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "A_log": jnp.zeros((H,), dtype),          # A = -exp(A_log)
        "dt_bias": jnp.zeros((H,), dtype),
        "D": jnp.ones((H,), dtype),
        "norm_scale": jnp.ones((d_inner,), dtype),
        "w_out": dense_init(ks[2], d_inner, d_model, dtype),
    }


def _split_in(params, x, d_model, cfg):
    d_inner, H, P, N = _dims(d_model, cfg)
    zxbcdt = x @ params["w_in"]
    z = zxbcdt[..., :d_inner]
    xBC = zxbcdt[..., d_inner:2 * d_inner + 2 * N]
    dt = zxbcdt[..., 2 * d_inner + 2 * N:]
    return z, xBC, dt


def _causal_conv(xBC, conv_w, conv_b, prev: Optional[jnp.ndarray] = None):
    """Depthwise causal conv over seq. xBC: [B,S,Ch]; prev: [B,W-1,Ch]."""
    W = conv_w.shape[0]
    if prev is None:
        pad = jnp.zeros((xBC.shape[0], W - 1, xBC.shape[-1]), xBC.dtype)
    else:
        pad = prev
    xp = jnp.concatenate([pad, xBC], axis=1)       # [B, S+W-1, Ch]
    out = sum(xp[:, i:i + xBC.shape[1]] * conv_w[i] for i in range(W))
    return jax.nn.silu(out + conv_b)


def _gated_rmsnorm(y, z, scale, eps=1e-6):
    y = y * jax.nn.silu(z)
    var = jnp.mean(y.astype(jnp.float32) ** 2, axis=-1, keepdims=True)
    return (y.astype(jnp.float32) * jax.lax.rsqrt(var + eps)).astype(y.dtype) * scale


def ssd_chunked(xh, dt, A, Bm, Cm, chunk: int, return_final_state=False):
    """Core SSD. xh: [B,S,H,P]; dt: [B,S,H]; A: [H] (negative);
    Bm, Cm: [B,S,N]. Returns y: [B,S,H,P]; with ``return_final_state`` also
    the recurrent state after the last token [B,H,P,N] f32 (what a decode
    cache must carry to continue the sequence — the serving prefill dump).
    """
    Bsz, S, H, P = xh.shape
    N = Bm.shape[-1]
    Q = min(chunk, S)
    Sp = -(-S // Q) * Q
    if Sp != S:
        # odd lengths zero-pad to the chunk: a padded row has dt == 0, so
        # its log-decay is 0 (identity state update) and its input is zero
        pad = ((0, 0), (0, Sp - S))
        xh = jnp.pad(xh, pad + ((0, 0), (0, 0)))
        dt = jnp.pad(dt, pad + ((0, 0),))
        Bm = jnp.pad(Bm, pad + ((0, 0),))
        Cm = jnp.pad(Cm, pad + ((0, 0),))
    nc = Sp // Q
    # decay per step (log-space), weighted input
    dA = dt * A[None, None, :]                          # [B,S,H] (negative)
    xbar = xh * dt[..., None]                           # dt-weighted input
    # reshape into chunks
    dAc = dA.reshape(Bsz, nc, Q, H)
    xc = xbar.reshape(Bsz, nc, Q, H, P)
    Bc = Bm.reshape(Bsz, nc, Q, N)
    Cc = Cm.reshape(Bsz, nc, Q, N)
    cum = jnp.cumsum(dAc, axis=2)                       # [B,nc,Q,H]
    total = cum[:, :, -1]                               # [B,nc,H]

    # ---- intra-chunk (quadratic within chunk): L[q,k] = exp(cum_q - cum_k) causal
    diff = cum[:, :, :, None, :] - cum[:, :, None, :, :]        # [B,nc,Q(q),Q(k),H]
    causal = jnp.tril(jnp.ones((Q, Q), bool))
    L = jnp.where(causal[None, None, :, :, None], jnp.exp(diff), 0.0)
    CB = jnp.einsum("bcqn,bckn->bcqk", Cc, Bc)                  # [B,nc,Q,Q]
    y_intra = jnp.einsum("bcqk,bcqkh,bckhp->bcqhp", CB, L, xc)

    # ---- chunk states: state_c = sum_k exp(total - cum_k) * xbar_k B_k^T
    # (state accumulation in f32 — also keeps the scan carry dtype stable
    # under bf16 compute)
    decay_to_end = jnp.exp(total[:, :, None] - cum)             # [B,nc,Q,H]
    states = jnp.einsum("bckh,bckhp,bckn->bchpn", decay_to_end, xc,
                        Bc).astype(jnp.float32)

    # ---- inter-chunk recurrence over chunk index
    def step(carry, inp):
        st, tot = inp                                           # [B,H,P,N], [B,H]
        new = carry * jnp.exp(tot)[:, :, None, None] + st
        return new, carry                                        # emit state *before* this chunk
    init = jnp.zeros((Bsz, H, P, N), jnp.float32)
    final_state, prev_states = jax.lax.scan(
        step, init,
        (jnp.moveaxis(states, 1, 0),
         jnp.moveaxis(total, 1, 0).astype(jnp.float32)))
    prev_states = jnp.moveaxis(prev_states, 0, 1)               # [B,nc,H,P,N]

    # ---- inter-chunk output: y_q += C_q . (exp(cum_q) * prev_state)
    y_inter = jnp.einsum("bcqn,bcqh,bchpn->bcqhp",
                         Cc.astype(jnp.float32), jnp.exp(cum), prev_states)
    y = (y_intra.astype(jnp.float32) + y_inter).reshape(Bsz, Sp, H, P)
    y = y.astype(xh.dtype)
    if Sp != S:
        y = y[:, :S]
    if return_final_state:
        return y, final_state
    return y


def apply_ssd(params, x, d_model: int, cfg: SSMConfig,
              head_scale: Optional[jnp.ndarray] = None,
              return_state: bool = False,
              gates: Optional[Tuple[jnp.ndarray, jnp.ndarray]] = None,
              use_kernel: bool = False,
              live_bounds: Optional[Tuple[int, int]] = None):
    """Training/prefill forward. x: [B,S,d_model] -> [B,S,d_model].

    gates: optional per-head D2FT gates (g_f, g_b), each [B, H] in {0, 1}
    with g_b <= g_f — the scan output is gated per (sample, head) *before*
    the D-residual (the skip connection IS the p_s shortcut path) with the
    (1 - g_b) share routed through stop_gradient. use_kernel routes the
    gated scan through the Pallas kernel (``ops.gated_ssd_scan``) with
    ``live_bounds`` = static (live_fwd, live_bwd) head-slice upper bounds
    for compaction dispatch; otherwise a masked stop-gradient mix over the
    dense chunked scan computes the same function (the reference VJP).

    return_state: additionally return the decode cache after the last token
    (same structure as ``init_ssd_cache``: the conv tail of raw xBC inputs
    plus the f32 recurrent state) — the serving prefill dump, so a decode
    loop can continue the sequence without replaying it token by token.
    """
    d_inner, H, P, N = _dims(d_model, cfg)
    z, xBC_raw, dt = _split_in(params, x, d_model, cfg)
    xBC = _causal_conv(xBC_raw, params["conv_w"], params["conv_b"])
    xin = xBC[..., :d_inner].reshape(*x.shape[:2], H, P)
    Bm = xBC[..., d_inner:d_inner + N]
    Cm = xBC[..., d_inner + N:]
    dt = jax.nn.softplus(dt + params["dt_bias"])
    A = -jnp.exp(params["A_log"].astype(jnp.float32))
    if gates is not None and use_kernel and not return_state:
        g_f, g_b = gates
        # same operand preprocessing as ssd_chunked; the kernel pads odd S
        dA = dt * A[None, None, :]
        xbar = xin * dt[..., None]
        lf, lb = live_bounds if live_bounds is not None else (None, None)
        y = kernel_ops.gated_ssd_scan(xbar, dA, Bm, Cm, g_f, g_b,
                                      chunk=cfg.chunk, live_fwd=lf,
                                      live_bwd=lb)
        y = y.astype(xin.dtype)
        state = None
    else:
        y = ssd_chunked(xin, dt, A, Bm, Cm, cfg.chunk,
                        return_final_state=return_state)
        state = None
        if return_state:
            y, state = y
        if gates is not None:
            g_f, g_b = gates
            gf = g_f[:, None, :, None].astype(y.dtype)
            gb = g_b[:, None, :, None].astype(y.dtype)
            y = gf * (gb * y + (1.0 - gb) * jax.lax.stop_gradient(y))
    y = y + params["D"][None, None, :, None] * xin
    if head_scale is not None:
        y = y * head_scale[:, None, :, None].astype(y.dtype)
    y = y.reshape(*x.shape[:2], d_inner)
    y = _gated_rmsnorm(y, z, params["norm_scale"])
    out = y @ params["w_out"]
    if return_state:
        W = params["conv_w"].shape[0]
        pad = jnp.zeros((x.shape[0], W - 1, xBC_raw.shape[-1]), xBC_raw.dtype)
        conv_tail = jnp.concatenate([pad, xBC_raw], axis=1)[:, -(W - 1):]
        return out, {"conv": conv_tail, "state": state}
    return out


# -------------------------------------------------------------------- decode
def init_ssd_cache(batch: int, d_model: int, cfg: SSMConfig, dtype):
    d_inner, H, P, N = _dims(d_model, cfg)
    conv_ch = d_inner + 2 * N
    return {
        "conv": jnp.zeros((batch, cfg.conv_width - 1, conv_ch), dtype),
        # recurrent state accumulates in f32 regardless of compute dtype
        "state": jnp.zeros((batch, H, P, N), jnp.float32),
    }


def decode_ssd(params, cache, x, d_model: int, cfg: SSMConfig):
    """One-token decode. x: [B,1,d_model]."""
    d_inner, H, P, N = _dims(d_model, cfg)
    z, xBC, dt = _split_in(params, x, d_model, cfg)
    conv_in = jnp.concatenate([cache["conv"], xBC], axis=1)     # [B,W,Ch]
    W = params["conv_w"].shape[0]
    out = sum(conv_in[:, i] * params["conv_w"][i] for i in range(W))
    xBC1 = jax.nn.silu(out + params["conv_b"])[:, None]         # [B,1,Ch]
    new_conv = conv_in[:, 1:]
    xin = xBC1[..., :d_inner].reshape(x.shape[0], H, P)
    Bm = xBC1[:, 0, d_inner:d_inner + N]
    Cm = xBC1[:, 0, d_inner + N:]
    dt1 = jax.nn.softplus(dt[:, 0] + params["dt_bias"])         # [B,H]
    A = -jnp.exp(params["A_log"].astype(jnp.float32))
    a = jnp.exp(dt1.astype(jnp.float32) * A[None, :])           # [B,H]
    dBx = jnp.einsum("bhp,bn,bh->bhpn", xin, Bm,
                     dt1).astype(jnp.float32)
    state = cache["state"] * a[:, :, None, None] + dBx
    y = jnp.einsum("bn,bhpn->bhp", Cm.astype(jnp.float32),
                   state).astype(x.dtype)
    y = y + params["D"][None, :, None] * xin
    y = y.reshape(x.shape[0], 1, d_inner)
    y = _gated_rmsnorm(y, z, params["norm_scale"])
    return y @ params["w_out"], {"conv": new_conv, "state": state}
