"""Modality frontends for [audio] and [vlm] architectures.

Per the assignment carve-out these are STUBS: the conv feature extractor
(HuBERT) and the CLIP/SigLIP vision tower (Phi-3-vision) are not
implemented. ``input_specs``-compatible helpers below produce precomputed
frame/patch embeddings of the right shape; a learned linear projector inside
the backbone (params["frontend_proj"]) maps them to d_model.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig


def feature_spec(cfg: ModelConfig, batch: int, seq_len: int):
    """ShapeDtypeStruct stand-in for the frontend's output embeddings."""
    dt = jnp.dtype(cfg.compute_dtype)
    if cfg.frontend == "audio_stub":
        # encoder consumes one embedding per frame: the whole sequence
        return jax.ShapeDtypeStruct((batch, seq_len, cfg.frontend_dim), dt)
    if cfg.frontend == "vision_stub":
        return jax.ShapeDtypeStruct((batch, cfg.frontend_tokens, cfg.frontend_dim), dt)
    return None


def synth_features(key, cfg: ModelConfig, batch: int, seq_len: int):
    spec = feature_spec(cfg, batch, seq_len)
    if spec is None:
        return None
    return jax.random.normal(key, spec.shape, spec.dtype)


def text_len(cfg: ModelConfig, seq_len: int) -> int:
    """Text tokens so that frontend tokens + text == seq_len."""
    if cfg.frontend == "vision_stub":
        return seq_len - cfg.frontend_tokens
    if cfg.frontend == "audio_stub":
        return 0
    return seq_len
