"""GQA attention: global/causal, bidirectional, sliding-window (block-local
subquadratic), plus single-token decode against full-length or ring KV caches.

D2FT head-group gating happens in transformer.py at the block level; this
module optionally accepts per-(sample, head) multipliers for the packed path.
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.models.layers import apply_rope, dense_init

NEG_INF = -2.0 ** 30


# ------------------------------------------------------------------- params
def init_attention(key, d_model: int, n_heads: int, n_kv_heads: int,
                   head_dim: int, qkv_bias: bool, dtype):
    ks = jax.random.split(key, 4)
    p = {
        "wq": dense_init(ks[0], d_model, n_heads * head_dim, dtype),
        "wk": dense_init(ks[1], d_model, n_kv_heads * head_dim, dtype),
        "wv": dense_init(ks[2], d_model, n_kv_heads * head_dim, dtype),
        "wo": dense_init(ks[3], n_heads * head_dim, d_model, dtype),
    }
    if qkv_bias:
        p["bq"] = jnp.zeros((n_heads * head_dim,), dtype)
        p["bk"] = jnp.zeros((n_kv_heads * head_dim,), dtype)
        p["bv"] = jnp.zeros((n_kv_heads * head_dim,), dtype)
    return p


def _project_qkv(params, x, n_heads, n_kv_heads, head_dim):
    B, S, _ = x.shape
    q = x @ params["wq"]
    k = x @ params["wk"]
    v = x @ params["wv"]
    if "bq" in params:
        q = q + params["bq"]
        k = k + params["bk"]
        v = v + params["bv"]
    q = q.reshape(B, S, n_heads, head_dim)
    k = k.reshape(B, S, n_kv_heads, head_dim)
    v = v.reshape(B, S, n_kv_heads, head_dim)
    return q, k, v


def _repeat_kv(k, Hq: int):
    """[B,S,Hkv,hd] -> [B,S,Hq,hd]. Keeping the einsum 4-D (instead of a
    5-D [B,S,Hkv,G,hd] grouping) lets GSPMD shard the head dim cleanly —
    the grouped form forced involuntary full rematerialization when
    Hkv % model_axis != 0 (see EXPERIMENTS.md §Perf)."""
    Hkv = k.shape[2]
    if Hkv == Hq:
        return k
    return jnp.repeat(k, Hq // Hkv, axis=2)


def gated_kernel_attention(q, k, v, g_f, g_b, *, causal: bool,
                           window: int = 0,
                           interpret: Optional[bool] = None,
                           live_bounds: Optional[Tuple[int, int]] = None):
    """Pallas-kernel attention with D2FT (g_f, g_b) head gates.

    q: [B,S,Hq,hd]; k, v: [B,S,Hkv,hd] (GQA expanded here); g_f, g_b:
    [B,Hq] in {0,1}. Returns [B,S,Hq,hd]. Forward output is g_f-gated (p_s
    heads are zeros and skip the MXU); the custom-VJP backward skips every
    (sample, head) slice with g_b == 0 inside the kernel (p_o and p_s), so
    forward-only micro-batches never pay attention-backward FLOPs.

    live_bounds: optional static (live_fwd, live_bwd) upper bounds on the
    g_f != 0 / g_b != 0 (sample, head) slice counts — enables compaction
    dispatch (the kernel grid's leading dim becomes the bound instead of
    B*Hq and gated-off slices stop paying DMA). Schedule-derived, see
    ``core.schedule.live_slice_bounds``.
    """
    from repro.kernels.ops import gated_attention
    Hq = q.shape[2]
    k = _repeat_kv(k, Hq)
    v = _repeat_kv(v, Hq)
    live_f, live_b = live_bounds if live_bounds is not None else (None, None)
    out = gated_attention(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                          v.transpose(0, 2, 1, 3), g_f, g_b, causal=causal,
                          window=window, interpret=interpret,
                          live_fwd=live_f, live_bwd=live_b)
    return out.transpose(0, 2, 1, 3)


def _sdpa(q, k, v, mask):
    """q: [B,Sq,Hq,hd]; k,v: [B,Sk,Hkv,hd]; mask: broadcastable
    [B,1,Sq,Sk] boolean (True = attend). GQA via KV head repetition."""
    B, Sq, Hq, hd = q.shape
    k = _repeat_kv(k, Hq)
    v = _repeat_kv(v, Hq)
    scale = 1.0 / jnp.sqrt(jnp.asarray(hd, jnp.float32)).astype(q.dtype)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q * scale, k)
    logits = jnp.where(mask, logits.astype(jnp.float32), NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    out = jnp.einsum("bhqk,bkhd->bqhd", probs, v)
    return out


def _causal_mask(Sq, Sk, offset=0):
    # True where key position <= query position (+offset aligns positions)
    qpos = jnp.arange(Sq)[:, None] + offset
    kpos = jnp.arange(Sk)[None, :]
    return (kpos <= qpos)[None, None]


def _window_mask(Sq, Sk, window, offset=0):
    qpos = jnp.arange(Sq)[:, None] + offset
    kpos = jnp.arange(Sk)[None, :]
    return ((kpos <= qpos) & (kpos > qpos - window))[None, None]


def pad_attention_params(p, n_heads, n_kv, head_dim, Hp, Hkvp):
    """Zero-pad head dims: wq/bq to Hp heads, wk/wv/bk/bv to Hkvp, wo rows
    to Hp. Exact — padded heads' wo rows are zero so their contribution
    vanishes; real head h keeps kv head h // (Hp/Hkvp) (ratio preserved).
    Enables head-TP sharding when the true head count is not divisible by
    the model axis (EXPERIMENTS.md §Perf)."""
    dq = (Hp - n_heads) * head_dim
    dkv = (Hkvp - n_kv) * head_dim
    out = dict(p)
    out["wq"] = jnp.pad(p["wq"], ((0, 0), (0, dq)))
    out["wk"] = jnp.pad(p["wk"], ((0, 0), (0, dkv)))
    out["wv"] = jnp.pad(p["wv"], ((0, 0), (0, dkv)))
    out["wo"] = jnp.pad(p["wo"], ((0, dq), (0, 0)))
    for b, d in (("bq", dq), ("bk", dkv), ("bv", dkv)):
        if b in p:
            out[b] = jnp.pad(p[b], (0, d))
    return out


def _chunked_sdpa(q, k, v, chunk_q: int, causal: bool, window: int = 0):
    """Exact causal/window attention with the query dim processed in
    chunks via lax.map — caps the [B,H,cq,Sk] scores buffer instead of
    materializing [B,H,Sq,Sk] (a memory lever, FLOPs unchanged)."""
    B, S, Hq, hd = q.shape
    k = _repeat_kv(k, Hq)
    v = _repeat_kv(v, Hq)
    n = S // chunk_q
    scale = 1.0 / jnp.sqrt(jnp.asarray(hd, jnp.float32)).astype(q.dtype)
    kpos = jnp.arange(S)[None, :]

    def one(ci):
        qc = jax.lax.dynamic_slice_in_dim(q, ci * chunk_q, chunk_q, axis=1)
        qpos = ci * chunk_q + jnp.arange(chunk_q)[:, None]
        mask = jnp.ones((chunk_q, S), bool)
        if causal:
            mask &= kpos <= qpos
        if window and window > 0:
            mask &= kpos > qpos - window
        logits = jnp.einsum("bqhd,bkhd->bhqk", qc * scale, k)
        logits = jnp.where(mask[None, None], logits.astype(jnp.float32),
                           NEG_INF)
        probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
        return jnp.einsum("bhqk,bkhd->bqhd", probs, v)

    chunks = jax.lax.map(one, jnp.arange(n))          # [n,B,cq,H,hd]
    return jnp.moveaxis(chunks, 0, 1).reshape(B, S, Hq, hd)


# ----------------------------------------------------------- train / prefill
def apply_attention(params, x, *, n_heads: int, n_kv_heads: int, head_dim: int,
                    causal: bool, window: int = 0, rope: bool = True,
                    rope_theta: float = 10_000.0,
                    positions: Optional[jnp.ndarray] = None,
                    head_scale: Optional[jnp.ndarray] = None,
                    return_kv: bool = False):
    """Returns attention block output [B,S,d_model].

    window > 0 selects sliding-window attention; when S > 2*window a
    block-local (chunked) subquadratic implementation is used.
    head_scale: optional [B, n_heads] multiplier applied to per-head outputs
    before the output projection (D2FT packed-path gating hook).
    return_kv: additionally return the post-rope (k, v) [B,S,n_kv,hd] —
    the serving prefill captures them into the KV cache (forward() + cache
    dump instead of a sequential decode loop, see serving/decode.py).
    """
    B, S, _ = x.shape
    if positions is None:
        positions = jnp.arange(S)[None, :]
    q, k, v = _project_qkv(params, x, n_heads, n_kv_heads, head_dim)
    if rope:
        q = apply_rope(q, positions, rope_theta)
        k = apply_rope(k, positions, rope_theta)

    if window and window > 0 and S > 2 * window and S % window == 0:
        out = _block_local_attention(q, k, v, window)
    else:
        if window and window > 0:
            mask = _window_mask(S, S, window)
        elif causal:
            mask = _causal_mask(S, S)
        else:
            mask = jnp.ones((1, 1, S, S), bool)
        out = _sdpa(q, k, v, mask)

    if head_scale is not None:
        out = out * head_scale[:, None, :, None].astype(out.dtype)
    out = out.reshape(B, S, n_heads * head_dim) @ params["wo"]
    if return_kv:
        return out, k, v
    return out


def kv_prefill_cache(k, v, window: int, max_len: int) -> dict:
    """Full-history prefill K/V [B,S,n_kv,hd] -> the ``init_kv_cache``
    decode layout, so ``decode_attention`` can continue from position S.

    Global layers get the zero-padded [B, max_len, ...] cache. Local layers
    get the [B, W, ...] ring buffer: slot ``p % W`` holds position ``p`` for
    the last ``min(S, W)`` positions — exactly the state a sequential
    decode-path prefill would have left, including the invariant that the
    next decode step (t = S) overwrites the slot whose position just fell
    out of the window."""
    B, S = k.shape[:2]
    if window and window > 0:
        L = window
        m = min(S, L)
        pos = jnp.arange(S - m, S)
        kc = jnp.zeros((B, L) + k.shape[2:], k.dtype).at[:, pos % L].set(
            k[:, pos])
        vc = jnp.zeros((B, L) + v.shape[2:], v.dtype).at[:, pos % L].set(
            v[:, pos])
        return {"k": kc, "v": vc}
    assert S <= max_len, f"prompt length {S} exceeds cache max_len {max_len}"
    pad = ((0, 0), (0, max_len - S)) + ((0, 0),) * (k.ndim - 2)
    return {"k": jnp.pad(k, pad), "v": jnp.pad(v, pad)}


def _block_local_attention(q, k, v, window: int):
    """Subquadratic sliding-window attention: chunk queries by `window`;
    each chunk attends to itself + the previous chunk under an exact
    (kpos <= qpos) & (kpos > qpos - window) mask. O(S * 2W) work."""
    B, S, Hq, hd = q.shape
    Hkv = k.shape[2]
    C = S // window
    qc = q.reshape(B, C, window, Hq, hd)
    kc = k.reshape(B, C, window, Hkv, hd)
    vc = v.reshape(B, C, window, Hkv, hd)
    # previous chunk (zeros for the first chunk)
    kprev = jnp.pad(kc[:, :-1], ((0, 0), (1, 0), (0, 0), (0, 0), (0, 0)))
    vprev = jnp.pad(vc[:, :-1], ((0, 0), (1, 0), (0, 0), (0, 0), (0, 0)))
    kcat = jnp.concatenate([kprev, kc], axis=2)   # [B,C,2W,Hkv,hd]
    vcat = jnp.concatenate([vprev, vc], axis=2)
    kcat = _repeat_kv(kcat.reshape(B, C * 2 * window, Hkv, hd), Hq)\
        .reshape(B, C, 2 * window, Hq, hd)
    vcat = _repeat_kv(vcat.reshape(B, C * 2 * window, Hkv, hd), Hq)\
        .reshape(B, C, 2 * window, Hq, hd)
    qpos = jnp.arange(window)[:, None] + window   # within [W, 2W)
    kpos = jnp.arange(2 * window)[None, :]
    mask = (kpos <= qpos) & (kpos > qpos - window)      # [W, 2W]
    # first chunk: mask out the zero-padded "previous" half
    first = (kpos >= window) & mask
    mask_all = jnp.broadcast_to(mask, (C, window, 2 * window))
    mask_all = mask_all.at[0].set(first)
    scale = 1.0 / jnp.sqrt(jnp.asarray(hd, jnp.float32)).astype(q.dtype)
    logits = jnp.einsum("bcqhd,bckhd->bchqk", qc * scale, kcat)
    logits = jnp.where(mask_all[None, :, None], logits.astype(jnp.float32),
                       NEG_INF)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    out = jnp.einsum("bchqk,bckhd->bcqhd", probs, vcat)
    return out.reshape(B, S, Hq, hd)


# -------------------------------------------------------------------- decode
def init_kv_cache(batch: int, max_len: int, n_kv_heads: int, head_dim: int,
                  window: int, dtype) -> dict:
    L = window if window and window > 0 else max_len
    return {
        "k": jnp.zeros((batch, L, n_kv_heads, head_dim), dtype),
        "v": jnp.zeros((batch, L, n_kv_heads, head_dim), dtype),
    }


def decode_attention(params, cache: dict, x, *, t, n_heads: int,
                     n_kv_heads: int, head_dim: int, window: int = 0,
                     rope: bool = True, rope_theta: float = 10_000.0
                     ) -> Tuple[jnp.ndarray, dict]:
    """One-token decode. x: [B, 1, d_model]; t: scalar int32 — number of
    tokens already in the cache (the new token has position t). Global
    caches are [B, max_len, ...]; local caches are ring buffers [B, W, ...].
    """
    B = x.shape[0]
    q, k, v = _project_qkv(params, x, n_heads, n_kv_heads, head_dim)
    pos = jnp.full((B, 1), t, jnp.int32)
    if rope:
        q = apply_rope(q, pos, rope_theta)
        k = apply_rope(k, pos, rope_theta)
    L = cache["k"].shape[1]
    if window and window > 0:
        slot = t % L                       # ring buffer
    else:
        slot = jnp.minimum(t, L - 1)       # full-length cache
    kc = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, slot, axis=1)
    vc = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, slot, axis=1)
    # valid positions: ring buffer slot s holds absolute position
    #   global: s ; local: the latest absolute position congruent to s mod L
    idx = jnp.arange(L)
    if window and window > 0:
        # absolute position stored in slot s after writing token t:
        #   p(s) = t - ((t - s) mod L)
        abs_pos = t - jnp.mod(t - idx, L)
        valid = (abs_pos >= 0) & (abs_pos <= t) & (abs_pos > t - window)
    else:
        valid = idx <= t
    mask = valid[None, None, None, :]                  # [1,1,1,L]
    out = _sdpa(q, kc, vc, mask)                       # [B,1,Hq,hd]
    out = out.reshape(B, 1, n_heads * head_dim) @ params["wo"]
    return out, {"k": kc, "v": vc}
