"""Mixture-of-Experts layer with sort-based static-capacity dispatch.

Dispatch is the MaxText-style sort/scatter form (no [T,E,C] one-hot blow-up):
tokens are sorted by expert id, positioned within their expert segment,
dropped beyond capacity, gathered into a dense [E, C, d] buffer, processed
with a batched expert einsum, and combined with a scatter-add.

Sharding: the expert dim is annotated by an optional ``shard_fn`` supplied by
the caller (expert-parallel when n_experts % model_axis == 0, otherwise
TP-within-expert on the hidden dim). This module stays mesh-agnostic.
"""
from __future__ import annotations

from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.kernels import ops as kernel_ops
from repro.models.layers import dense_init, _act
from repro.configs.base import MoEConfig


def init_moe(key, d_model: int, cfg: MoEConfig, dtype):
    ks = jax.random.split(key, 6)
    E, F = cfg.n_experts, cfg.d_ff
    p = {
        "router": dense_init(ks[0], d_model, E, dtype, scale=0.02),
        "w_up": (jax.random.normal(ks[1], (E, d_model, F)) / jnp.sqrt(d_model)).astype(dtype),
        "w_gate": (jax.random.normal(ks[2], (E, d_model, F)) / jnp.sqrt(d_model)).astype(dtype),
        "w_down": (jax.random.normal(ks[3], (E, F, d_model)) / jnp.sqrt(F)).astype(dtype),
    }
    if cfg.n_shared_experts > 0:
        SF = cfg.n_shared_experts * F
        p["shared_up"] = dense_init(ks[4], d_model, SF, dtype)
        p["shared_gate"] = dense_init(ks[5], d_model, SF, dtype)
        p["shared_down"] = dense_init(ks[0], SF, d_model, dtype)
    return p


def apply_moe(params, x, cfg: MoEConfig, act: str = "silu",
              shard_fn: Optional[Callable] = None,
              gates: Optional[Tuple[jnp.ndarray, jnp.ndarray]] = None,
              use_kernel: bool = False,
              live_tokens: Optional[int] = None,
              live_bwd_tokens: Optional[int] = None,
              block_c: int = 128):
    """x: [B, S, d]. Returns (y, aux) where aux has load-balance/z losses.

    gates: optional per-sample D2FT gates (g_f, g_b), each [B] in {0, 1}
    with g_b <= g_f — the schedule gate intersects the router's top-k:
    assignments from g_f == 0 samples are dropped *at dispatch* (they never
    occupy capacity slots), and within each expert segment g_b == 1
    assignments sort first so backward-live slots pack into a prefix of
    capacity blocks. The caller's gate_mix still owns the stop-gradient
    semantics on the combined output. use_kernel routes the expert FFN
    through the doubly-sparse Pallas kernel (``ops.gated_moe_ffn``) with
    slot-occupancy masks; ``live_tokens`` is the schedule's static upper
    bound on forward-live tokens (live samples x S), bounding live capacity
    slots at ``live_tokens * top_k`` for compaction-style block truncation.
    ``live_bwd_tokens`` is the matching *backward*-live bound (g_b samples
    x S): because bwd-live assignments pack first per expert segment, it
    truncates the kernel's backward grid separately — a g_b < g_f mix
    stops dispatching capacity blocks that only hold p_o slots.
    """
    B, S, D = x.shape
    T = B * S
    E, K, C_f = cfg.n_experts, cfg.top_k, cfg.capacity_factor
    xt = x.reshape(T, D)

    logits = (xt @ params["router"]).astype(jnp.float32)        # [T, E]
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_e = jax.lax.top_k(probs, K)                      # [T, K]
    top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)

    # ---- flatten (token, k) assignments and sort by expert id
    e_flat = top_e.reshape(T * K)
    tok_flat = jnp.repeat(jnp.arange(T), K)
    w_flat = top_w.reshape(T * K)
    if gates is None:
        live_a = bwd_a = None
        order = jnp.argsort(e_flat, stable=True)
        counts = jnp.bincount(e_flat, length=E)                 # [E]
    else:
        g_f, g_b = gates
        gf_t = jnp.repeat(g_f.reshape(B), S)                    # [T]
        gb_t = jnp.repeat(g_b.reshape(B), S)
        live_a = gf_t[tok_flat] > 0                             # [T*K]
        bwd_a = gb_t[tok_flat] > 0
        # sort key: (expert, bwd-dead-last) for live assignments; dead ones
        # past every expert so they never claim a capacity slot
        key = jnp.where(live_a,
                        2 * e_flat + (1 - bwd_a.astype(e_flat.dtype)),
                        2 * E)
        order = jnp.argsort(key, stable=True)
        counts = jnp.bincount(jnp.where(live_a, e_flat, E),
                              length=E + 1)[:E]
    e_s, tok_s, w_s = e_flat[order], tok_flat[order], w_flat[order]

    offsets = jnp.cumsum(counts) - counts                       # exclusive
    pos = jnp.arange(T * K) - offsets[e_s]                      # rank in segment
    capacity = int(max(1, round(T * K / E * C_f)))
    keep = pos < capacity
    if live_a is not None:
        keep = keep & live_a[order]
    pos_c = jnp.where(keep, pos, capacity)                      # OOB -> drop

    buf = jnp.zeros((E, capacity + 1, D), x.dtype)
    buf = buf.at[e_s, pos_c].set(xt[tok_s])
    buf = buf[:, :capacity]                                     # [E, C, D]
    if shard_fn is not None:
        buf = shard_fn(buf)

    if use_kernel and gates is not None and shard_fn is None:
        keep_f = keep.astype(jnp.float32)
        keep_b = (keep & bwd_a[order]).astype(jnp.float32)
        fwd_slots = jnp.zeros((E, capacity + 1), jnp.float32)
        fwd_slots = fwd_slots.at[e_s, pos_c].add(keep_f)[:, :capacity]
        bwd_slots = jnp.zeros((E, capacity + 1), jnp.float32)
        bwd_slots = bwd_slots.at[e_s, pos_c].add(keep_b)[:, :capacity]
        live_slots = (min(capacity, int(live_tokens) * K)
                      if live_tokens is not None else None)
        live_bwd_slots = (min(capacity, int(live_bwd_tokens) * K)
                          if live_bwd_tokens is not None else None)
        out_e = kernel_ops.gated_moe_ffn(
            buf, params["w_up"], params["w_gate"], params["w_down"],
            fwd_slots, bwd_slots, act=act, block_c=block_c,
            live_slots=live_slots, live_bwd_slots=live_bwd_slots)
        out_e = out_e.astype(x.dtype)
    else:
        h = jnp.einsum("ecd,edf->ecf", buf, params["w_up"])
        g = jnp.einsum("ecd,edf->ecf", buf, params["w_gate"])
        h = _act(act)(g) * h
        out_e = jnp.einsum("ecf,efd->ecd", h, params["w_down"])  # [E, C, D]
        if shard_fn is not None:
            out_e = shard_fn(out_e)

    y = jnp.zeros((T, D), x.dtype)
    contrib = out_e[e_s, jnp.minimum(pos_c, capacity - 1)]
    contrib = contrib * (w_s * keep).astype(x.dtype)[:, None]
    y = y.at[tok_s].add(contrib)

    if cfg.n_shared_experts > 0:
        hs = xt @ params["shared_up"]
        gs = _act(act)(xt @ params["shared_gate"])
        y = y + (hs * gs) @ params["shared_down"]

    # ---- aux losses (GShard/Switch style)
    frac_tokens = jnp.mean(jax.nn.one_hot(top_e[:, 0], E), axis=0)
    frac_probs = jnp.mean(probs, axis=0)
    aux_lb = E * jnp.sum(frac_tokens * frac_probs) * cfg.aux_loss
    z = jax.scipy.special.logsumexp(logits, axis=-1)
    aux_z = jnp.mean(z ** 2) * cfg.router_z_loss
    dropped = 1.0 - jnp.mean(keep.astype(jnp.float32))
    aux = {"load_balance": aux_lb, "router_z": aux_z, "drop_frac": dropped}
    return y.reshape(B, S, D), aux


# ===================================================== expert parallel (EP)
def apply_moe_ep(params, x, cfg: MoEConfig, act: str, mesh, batch_axes,
                 model_axis: str = "model", seq_sharded: bool = True,
                 expert_parallel: bool = True):
    """Sharded MoE via shard_map with explicit collectives.

    The global sort-based dispatch above is correct single-device JAX, but
    its data-dependent gather/scatter defeats GSPMD (the compiler replicates
    the token buffers — see EXPERIMENTS.md §Perf). Production dispatch is
    explicit. Two modes:

    * expert_parallel (E % model_size == 0): each device routes its LOCAL
      tokens, builds a per-expert send buffer, all-to-alls over ``model``
      (experts live there), computes its local experts, all-to-alls back.
    * TP-within-expert (e.g. mixtral's 8 experts on a 16-wide axis): every
      device holds an F-slice of ALL experts; dispatch is local, the expert
      down-projection is partial and psum'd over ``model``.

    x: [B, S, D] (batch over batch_axes, seq over model). Fixed local
    capacity C_l = ceil(T_l * K / E) * capacity_factor.
    """
    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map

    E, K = cfg.n_experts, cfg.top_k
    M = mesh.shape[model_axis]
    E_l = E // M if expert_parallel else E

    def local_fn(xl, router, w_up, w_gate, w_down):
        # xl: [B_l, S_l, D]; w_*: [E_l, D, F] (EP) or [E, D, F/M] (TP)
        B_l, S_l, D = xl.shape
        T_l = B_l * S_l
        xt = xl.reshape(T_l, D)
        logits = (xt @ router).astype(jnp.float32)
        probs = jax.nn.softmax(logits, axis=-1)
        top_w, top_e = jax.lax.top_k(probs, K)
        top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)

        e_flat = top_e.reshape(T_l * K)
        tok_flat = jnp.repeat(jnp.arange(T_l), K)
        w_flat = top_w.reshape(T_l * K)
        order = jnp.argsort(e_flat, stable=True)
        e_s, tok_s, w_s = e_flat[order], tok_flat[order], w_flat[order]
        counts = jnp.bincount(e_flat, length=E)
        offsets = jnp.cumsum(counts) - counts
        pos = jnp.arange(T_l * K) - offsets[e_s]
        C_l = int(max(1, -(-T_l * K // E) * cfg.capacity_factor))
        keep = pos < C_l
        pos_c = jnp.where(keep, pos, C_l)

        send = jnp.zeros((E, C_l + 1, D), xl.dtype)
        send = send.at[e_s, pos_c].set(xt[tok_s])[:, :C_l]    # [E, C_l, D]
        if expert_parallel:
            # ---- all-to-all: expert dim -> devices; add source-device dim
            recv = jax.lax.all_to_all(
                send.reshape(M, E_l, C_l, D), model_axis, split_axis=0,
                concat_axis=0, tiled=False)                   # [M,E_l,C_l,D]
            buf = recv.transpose(1, 0, 2, 3).reshape(E_l, M * C_l, D)
        else:
            buf = send                                        # [E, C_l, D]

        h = jnp.einsum("ecd,edf->ecf", buf, w_up)
        g = jnp.einsum("ecd,edf->ecf", buf, w_gate)
        out_e = jnp.einsum("ecf,efd->ecd", _act(act)(g) * h, w_down)

        if expert_parallel:
            back = out_e.reshape(E_l, M, C_l, D).transpose(1, 0, 2, 3)
            got = jax.lax.all_to_all(back, model_axis, split_axis=0,
                                     concat_axis=0, tiled=False)
            got = got.reshape(E, C_l, D)
        else:
            got = jax.lax.psum(out_e, model_axis)             # partial sums

        y = jnp.zeros((T_l, D), xl.dtype)
        contrib = got[e_s, jnp.minimum(pos_c, C_l - 1)]
        contrib = contrib * (w_s * keep).astype(xl.dtype)[:, None]
        y = y.at[tok_s].add(contrib)

        frac_tokens = jnp.mean(jax.nn.one_hot(top_e[:, 0], E), axis=0)
        frac_probs = jnp.mean(probs, axis=0)
        all_axes = tuple(mesh.axis_names)
        aux_lb = E * jnp.sum(
            jax.lax.pmean(frac_tokens * frac_probs, all_axes)) * cfg.aux_loss
        z = jax.scipy.special.logsumexp(logits, axis=-1)
        aux_z = jax.lax.pmean(jnp.mean(z ** 2), all_axes) * cfg.router_z_loss
        drop = jax.lax.pmean(1.0 - jnp.mean(keep.astype(jnp.float32)),
                             all_axes)
        return y.reshape(B_l, S_l, D), {"load_balance": aux_lb,
                                        "router_z": aux_z, "drop_frac": drop}

    # TP-within-expert psums partial-F outputs per token, so every model
    # rank must see the SAME tokens: sequence stays gathered in that mode.
    xspec = P(batch_axes,
              model_axis if (seq_sharded and expert_parallel) else None,
              None)
    if expert_parallel:
        up_spec = gate_spec = down_spec = P(model_axis, None, None)
    else:
        up_spec = gate_spec = P(None, None, model_axis)
        down_spec = P(None, model_axis, None)
    fn = shard_map(
        local_fn, mesh=mesh,
        in_specs=(xspec, P(None, None), up_spec, gate_spec, down_spec),
        out_specs=(xspec, {"load_balance": P(), "router_z": P(),
                           "drop_frac": P()}),
        check_rep=False)
    y, aux = fn(x, params["router"], params["w_up"], params["w_gate"],
                params["w_down"])

    if cfg.n_shared_experts > 0:
        B, S, D = x.shape
        xt = x.reshape(B * S, D)
        hs = xt @ params["shared_up"]
        gs = _act(act)(xt @ params["shared_gate"])
        y = y + ((hs * gs) @ params["shared_down"]).reshape(B, S, D)
    return y, aux
