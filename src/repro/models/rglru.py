"""RG-LRU recurrent block (Griffin / RecurrentGemma, arXiv:2402.19427).

Recurrence (per channel):
    r_t = sigmoid(W_a x_t)                 (recurrence gate)
    i_t = sigmoid(W_x x_t)                 (input gate)
    log a_t = -c * softplus(Lambda) * r_t  (c = 8)
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

Training/prefill uses jax.lax.associative_scan on the affine recurrence;
decode is the single-step update. The full recurrent block is:
    x -> [gate branch: linear+gelu] * [linear -> conv1d -> RG-LRU] -> out proj
"""
from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import RGLRUConfig
from repro.kernels import ops as kernel_ops
from repro.kernels import ref as kernel_ref
from repro.models.layers import dense_init

_C = 8.0
# chunk used by both the gated Pallas kernel and its masked reference mix --
# they must match so the two paths see identical chunked-scan numerics
_SCAN_CHUNK = 128


def init_rglru(key, d_model: int, cfg: RGLRUConfig, dtype):
    width = cfg.lru_width or d_model
    ks = jax.random.split(key, 6)
    return {
        "w_gate_branch": dense_init(ks[0], d_model, width, dtype),
        "w_rec_branch": dense_init(ks[1], d_model, width, dtype),
        "conv_w": (jax.random.normal(ks[2], (cfg.conv_width, width)) * 0.1).astype(dtype),
        "conv_b": jnp.zeros((width,), dtype),
        "w_a": dense_init(ks[3], width, width, dtype, scale=0.02),
        "b_a": jnp.zeros((width,), dtype),
        "w_x": dense_init(ks[4], width, width, dtype, scale=0.02),
        "b_x": jnp.zeros((width,), dtype),
        # Lambda init so that a^c in [0.9, 0.999] at r=1 (Griffin appendix)
        "Lambda": jnp.asarray(
            jnp.log(jnp.expm1(-jnp.log(jnp.linspace(0.9, 0.999, width)) / _C)),
            dtype),
        "w_out": dense_init(ks[5], width, d_model, dtype),
    }


def _rglru_log_gates(params, x):
    """x: [..., width] (post-conv). Returns (log_a, gated_input) in f32 —
    the log-space operands the chunked/kernel scan consumes directly."""
    r = jax.nn.sigmoid(x @ params["w_a"] + params["b_a"])
    i = jax.nn.sigmoid(x @ params["w_x"] + params["b_x"])
    log_a = -_C * jax.nn.softplus(params["Lambda"].astype(jnp.float32)) * \
        r.astype(jnp.float32)
    a = jnp.exp(log_a)
    beta = jnp.sqrt(jnp.maximum(1.0 - a * a, 1e-12))
    b = beta * (i * x).astype(jnp.float32)
    return log_a, b


def _rglru_gates(params, x):
    """x: [..., width] (post-conv). Returns (a, gated_input)."""
    log_a, b = _rglru_log_gates(params, x)
    return jnp.exp(log_a), b


def _assoc_scan(a, b, h0=None):
    """Affine scan h_t = a_t h_{t-1} + b_t over axis=1. a,b: [B,S,W]."""
    if h0 is not None:
        b = b.at[:, 0].add(a[:, 0] * h0)
    def combine(x, y):
        a1, b1 = x
        a2, b2 = y
        return a1 * a2, a2 * b1 + b2
    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    return h


def _causal_conv(x, conv_w, conv_b, prev=None):
    W = conv_w.shape[0]
    if prev is None:
        pad = jnp.zeros((x.shape[0], W - 1, x.shape[-1]), x.dtype)
    else:
        pad = prev
    xp = jnp.concatenate([pad, x], axis=1)
    return sum(xp[:, i:i + x.shape[1]] * conv_w[i] for i in range(W)) + conv_b


def _gated_scan_ref(log_a, b, g_f, g_b):
    """Masked-path gated scan: the chunked log-space oracle (which the
    kernel mirrors op for op) with the stop-gradient mix, zero-padded to
    the chunk like ``ops.gated_rglru_scan`` pads for the kernel."""
    S = log_a.shape[1]
    Q = min(_SCAN_CHUNK, S)
    Sp = -(-S // Q) * Q
    if Sp != S:
        pad = ((0, 0), (0, Sp - S), (0, 0))
        log_a, b = jnp.pad(log_a, pad), jnp.pad(b, pad)
    h = kernel_ref.gated_rglru_ref(log_a, b, g_f, g_b, chunk=_SCAN_CHUNK)
    return h[:, :S] if Sp != S else h


def apply_rglru(params, x, cfg: RGLRUConfig,
                head_scale: Optional[jnp.ndarray] = None,
                return_state: bool = False,
                gates: Optional[Tuple[jnp.ndarray, jnp.ndarray]] = None,
                use_kernel: bool = False,
                live_bounds: Optional[Tuple[int, int]] = None):
    """Training/prefill forward. x: [B,S,d_model] -> [B,S,d_model].

    gates: optional per-group D2FT gates (g_f, g_b), each [B, G] in {0, 1}
    with g_b <= g_f — the G gate groups slice the LRU width into contiguous
    channel bands, gated on the scan output before the gate-branch multiply
    (the (1 - g_b) share routes through stop_gradient). use_kernel runs the
    gated scan in the Pallas kernel (``ops.gated_rglru_scan``) with
    ``live_bounds`` = static (live_fwd, live_bwd) band-slice upper bounds
    for compaction dispatch; otherwise the chunked reference scan with a
    masked stop-gradient mix computes the same function.

    return_state: additionally return the decode cache after the last token
    (``init_rglru_cache`` structure: the conv tail of raw pre-conv inputs
    plus the f32 hidden state) — the serving prefill dump."""
    gate = jax.nn.gelu(x @ params["w_gate_branch"])
    u_raw = x @ params["w_rec_branch"]
    u = _causal_conv(u_raw, params["conv_w"], params["conv_b"])
    if gates is not None:
        g_f, g_b = gates
        log_a, b = _rglru_log_gates(params, u)
        if use_kernel and not return_state:
            lf, lb = live_bounds if live_bounds is not None else (None, None)
            h32 = kernel_ops.gated_rglru_scan(log_a, b, g_f, g_b,
                                              chunk=_SCAN_CHUNK,
                                              live_fwd=lf, live_bwd=lb)
        else:
            h32 = _gated_scan_ref(log_a, b, g_f, g_b)
    else:
        a, b = _rglru_gates(params, u)
        h32 = _assoc_scan(a, b)                         # [B,S,W] f32
    h = h32.astype(x.dtype)
    if head_scale is not None:
        H = head_scale.shape[-1]
        W = h.shape[-1]
        hs = jnp.repeat(head_scale, W // H, axis=-1)    # block-diagonal groups
        h = h * hs[:, None, :].astype(h.dtype)
    y = h * gate
    out = y @ params["w_out"]
    if return_state:
        W = params["conv_w"].shape[0]
        pad = jnp.zeros((x.shape[0], W - 1, u_raw.shape[-1]), u_raw.dtype)
        conv_tail = jnp.concatenate([pad, u_raw], axis=1)[:, -(W - 1):]
        return out, {"conv": conv_tail, "h": h32[:, -1]}
    return out


def init_rglru_cache(batch: int, d_model: int, cfg: RGLRUConfig, dtype):
    width = cfg.lru_width or d_model
    return {
        "conv": jnp.zeros((batch, cfg.conv_width - 1, width), dtype),
        "h": jnp.zeros((batch, width), jnp.float32),
    }


def decode_rglru(params, cache, x, cfg: RGLRUConfig):
    """One-token decode. x: [B,1,d_model]."""
    gate = jax.nn.gelu(x @ params["w_gate_branch"])
    u = x @ params["w_rec_branch"]
    conv_in = jnp.concatenate([cache["conv"], u], axis=1)
    W = params["conv_w"].shape[0]
    u1 = sum(conv_in[:, i] * params["conv_w"][i] for i in range(W)) + params["conv_b"]
    a, b = _rglru_gates(params, u1)                     # [B,W]
    h = a * cache["h"] + b
    y = (h.astype(x.dtype)[:, None] * gate)
    return y @ params["w_out"], {"conv": conv_in[:, 1:], "h": h}
