"""LoRA extension of D2FT (paper §II-D).

LoRA adapters attach to the Q/K/V projections of every attention block; the
foundation weights stay frozen, gradients flow only into the low-rank A/B
matrices. Each adapter is co-located with its head's subnet, so the D2FT
gates/packed selection act on the LoRA contribution exactly as on full
fine-tuning (the paper's "subnet = frozen head + its LoRA matrices").

Cost model (paper §III-A): rank controls the LoRA compute; the operation
schedule controls how many micro-batches exercise fwd/bwd per subnet.
"""
from __future__ import annotations

from typing import Sequence, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

LORA_TARGETS = ("wq", "wk", "wv")


def _target_paths(tree, targets, prefix=()):
    """Yield (path, leaf) for every 2-D target weight in a params tree."""
    if isinstance(tree, dict):
        for k, v in tree.items():
            yield from _target_paths(v, targets, prefix + (k,))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            yield from _target_paths(v, targets, prefix + (i,))
    else:
        if prefix and prefix[-1] in targets and tree.ndim >= 2:
            yield prefix, tree


def init_lora(key, params, rank: int, targets: Sequence[str] = LORA_TARGETS,
              dtype=jnp.float32):
    """Returns a flat dict {path_str: {"a": [.., in, r], "b": [.., r, out]}}.

    Stacked (scan-cycled) weights get stacked adapters — leading dims are
    preserved so the LoRA tree is scan-compatible.
    """
    lora = {}
    paths = list(_target_paths(params, tuple(targets)))
    keys = jax.random.split(key, max(len(paths), 1))
    for (path, w), k in zip(paths, keys):
        lead, (din, dout) = w.shape[:-2], w.shape[-2:]
        a = (jax.random.normal(k, lead + (din, rank)) / jnp.sqrt(din)).astype(dtype)
        b = jnp.zeros(lead + (rank, dout), dtype)
        lora["/".join(map(str, path))] = {"a": a, "b": b}
    return lora


def merge_lora(params, lora, scale: float = 1.0):
    """Return params with W <- stop_grad(W) + scale * A @ B for targets and
    stop_grad elsewhere; gradients flow only through the adapters."""
    frozen = jax.lax.stop_gradient(params)

    def set_path(tree, path, value):
        head = path[0]
        if len(path) == 1:
            if isinstance(tree, dict):
                return {**tree, head: value}
            out = list(tree)
            out[int(head)] = value
            return type(tree)(out)
        if isinstance(tree, dict):
            return {**tree, head: set_path(tree[head], path[1:], value)}
        out = list(tree)
        out[int(head)] = set_path(tree[int(head)], path[1:], value)
        return type(tree)(out)

    merged = frozen
    for path_str, ab in lora.items():
        path = path_str.split("/")
        w = merged
        for pkey in path:
            w = w[pkey] if isinstance(w, dict) else w[int(pkey)]
        delta = jnp.einsum("...ir,...ro->...io", ab["a"], ab["b"]) * scale
        merged = set_path(merged, path, w + delta.astype(w.dtype))
    return merged


def lora_param_count(lora) -> int:
    return sum(int(x.size) for x in jax.tree.leaves(lora))


def lora_flops_fraction(cfg: ModelConfig, rank: int) -> float:
    """Relative LoRA-branch compute vs the frozen QKV matmuls — used to map
    the paper's rank-matched baselines (R=1/60/200/240)."""
    hd = cfg.resolved_head_dim
    d = cfg.d_model
    qkv_cols = (cfg.n_heads + 2 * cfg.n_kv_heads) * hd
    full = d * qkv_cols
    lora = rank * (d + qkv_cols)
    return lora / full
