"""Cost accounting for D2FT schedules (paper §III-A metrics, §IV-A analysis).

Per micro-batch relative costs (measured by the paper, Table IV):
  compute: p_f = c_f + c_b = 1.0, p_o = c_f = 0.4, p_s = 0
  comm:    p_f = 1.0 (activations fwd + grads bwd), p_o = 0.5, p_s = 0
All costs are reported as a fraction of standard full fine-tuning
(every subnet doing p_f on every micro-batch).
"""
from __future__ import annotations

import numpy as np

from repro.core.schedule import P_F, P_O, P_S, Schedule


def compute_cost(table: np.ndarray, c_f: float = 0.4, c_b: float = 0.6
                 ) -> float:
    per_op = np.where(table == P_F, c_f + c_b,
                      np.where(table == P_O, c_f, 0.0))
    return float(per_op.sum() / (table.size * (c_f + c_b)))


def comm_cost(table: np.ndarray) -> float:
    per_op = np.where(table == P_F, 1.0, np.where(table == P_O, 0.5, 0.0))
    return float(per_op.mean())


def per_device_load(table: np.ndarray, c_f: float = 0.4, c_b: float = 0.6
                    ) -> np.ndarray:
    """[K] — compute load per subnet/device for one batch."""
    per_op = np.where(table == P_F, c_f + c_b,
                      np.where(table == P_O, c_f, 0.0))
    return per_op.sum(axis=1)


def workload_variance(table: np.ndarray, c_f: float = 0.4, c_b: float = 0.6
                      ) -> float:
    """Variance of per-device workloads (paper Table I). D2FT's knapsack
    gives every device the same number of p_f / p_o micro-batches when
    capacities are homogeneous, so this is exactly 0."""
    return float(np.var(per_device_load(table, c_f, c_b)))
