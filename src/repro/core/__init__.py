"""D2FT planning layer — everything host-side and static.

Scores (``scores``) feed the bi-level knapsack (``knapsack``) which emits
an Algorithm-1 ``Schedule`` (``schedule``); ``assignment`` solves Eq. 4's
multiple-knapsack to place micro-batches on devices; ``cost_model`` prices
tables, ``baselines`` implements the paper's comparison schedulers,
``lora`` the D2FT-LoRA variant, and ``d2ft`` is the planning facade plus
the packed execution path. Plans are numpy + Python ints; the execution
layers (models/, kernels/, train/) consume them as static jit constants.
See docs/architecture.md for the planning-vs-execution split.
"""
