"""Multiple-knapsack device assignment — the *distributed* half of Eq. 4.

The bi-level knapsack (``core/knapsack.py``) decides WHAT runs: which
micro-batches each subnet treats as p_f / p_o / p_s. This module decides
WHERE: map the N micro-batches onto K devices under per-device capacities
C_k so every device carries a near-equal share of the schedule's live
(g_f, g_b) work. Balance is what turns the schedule's savings into
wall-clock — the slowest device gates the step, and the gradient
all-reduce cannot start until no straggler holds it open.

Solver (deterministic, host-side like the schedule itself):

* **LPT seed** — each micro-batch, heaviest first, goes to the least-loaded
  feasible device; the classic (4/3 - 1/(3K))-approximation for makespan.
* **DP refinement** — when per-device counts are free, a subset-sum
  transfer solved with ``dp_knapsack`` moves items from the max- to the
  min-loaded device (target: half the spread). In ``equal_counts`` mode
  (the shard_map data-parallel step needs equal shard sizes) a best-swap
  pass exchanges one item between the extremes instead. Both repeat until
  the spread stops improving.

Ties always break on the lowest micro-batch / device index, so identical
inputs produce identical assignments (re-planning on a restart is a
no-op). ``rebalance_report`` summarizes per-device cost spread; the
launcher prints it before training starts.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple

import numpy as np

from repro.core.knapsack import dp_knapsack
from repro.core.schedule import P_F, P_O, Schedule, live_slice_bounds


def microbatch_costs(sched: Schedule, c_f: float = 0.4, c_b: float = 0.6
                     ) -> np.ndarray:
    """[N] — schedule cost of each micro-batch summed over all subnets
    (p_f = c_f + c_b, p_o = c_f, p_s = 0): the item weights of Eq. 4."""
    t = sched.table
    per_op = np.where(t == P_F, c_f + c_b, np.where(t == P_O, c_f, 0.0))
    return per_op.sum(axis=0)


@dataclass(frozen=True)
class DeviceAssignment:
    """Result of the multiple-knapsack solve: micro-batch -> device."""
    device_of: np.ndarray                 # [N] int — device per micro-batch
    costs: np.ndarray                     # [N] item costs the solver used
    n_devices: int
    capacities: Optional[np.ndarray] = None   # [K] or None (unconstrained)

    @property
    def loads(self) -> np.ndarray:
        return np.bincount(self.device_of, weights=self.costs,
                           minlength=self.n_devices)

    @property
    def counts(self) -> np.ndarray:
        return np.bincount(self.device_of, minlength=self.n_devices)

    def items_of(self, k: int) -> np.ndarray:
        return np.nonzero(self.device_of == k)[0]


def _spread(loads: np.ndarray) -> float:
    return float(loads.max() - loads.min())


def _dp_transfer(device_of, costs, loads, a: int, b: int, caps,
                 resolution: int) -> bool:
    """Move a dp_knapsack-selected subset from device a to device b.

    Target transfer is half the spread (exact hit zeroes the pair's
    imbalance); the knapsack maximizes moved weight under that capacity, so
    the chosen subset is the closest-from-below subset sum. Returns True if
    a strictly-improving move was applied."""
    items = np.nonzero(device_of == a)[0]
    if len(items) == 0:
        return False
    target = (loads[a] - loads[b]) / 2.0
    if caps is not None:
        target = min(target, caps[b] - loads[b])
    if target <= 0:
        return False
    sel = dp_knapsack(costs[items], costs[items], target, resolution)
    moved = float(costs[items[sel]].sum())
    if moved <= 0:
        return False
    before = _spread(loads)
    loads[a] -= moved
    loads[b] += moved
    if _spread(loads) >= before - 1e-12:
        loads[a] += moved
        loads[b] -= moved
        return False
    device_of[items[sel]] = b
    return True


def _best_swap(device_of, costs, loads, a: int, b: int, caps) -> bool:
    """Exchange one item between the extreme devices (count-preserving).

    Picks the pair whose cost difference d is closest to half the spread
    with 0 < d < spread, i.e. the largest guaranteed spread reduction for a
    single swap. Returns True if an improving swap was applied."""
    ia = np.nonzero(device_of == a)[0]
    ib = np.nonzero(device_of == b)[0]
    if len(ia) == 0 or len(ib) == 0:
        return False
    spread = loads[a] - loads[b]
    half = spread / 2.0
    best = None
    for i in ia:
        for j in ib:
            d = costs[i] - costs[j]
            if d <= 1e-12 or d >= spread:
                continue
            if caps is not None and loads[b] + d > caps[b] + 1e-9:
                continue
            key = (abs(d - half), int(i), int(j))
            if best is None or key < best[0]:
                best = (key, int(i), int(j), d)
    if best is None:
        return False
    _, i, j, d = best
    device_of[i], device_of[j] = b, a
    loads[a] -= d
    loads[b] += d
    return True


def assign_microbatches(costs, n_devices: int, capacities=None, *,
                        equal_counts: bool = False, refine_rounds: int = 32,
                        resolution: int = 100) -> DeviceAssignment:
    """Assign N cost-weighted micro-batches to K devices.

    capacities: scalar or [K] per-device cost budget C_k. Infeasible items
    (no device has room) still get placed on the least-loaded device so
    every micro-batch executes; the violation shows up in
    ``rebalance_report`` rather than raising mid-training.
    equal_counts: force exactly N/K items per device (required by the
    shard_map data-parallel step, whose shards must be equal-sized).
    """
    costs = np.asarray(costs, np.float64)
    N, K = len(costs), int(n_devices)
    assert K >= 1
    caps = None
    if capacities is not None:
        caps = np.broadcast_to(np.asarray(capacities, np.float64), (K,))
    if equal_counts:
        assert N % K == 0, f"equal_counts needs N % K == 0, got {N} % {K}"
    max_count = N // K if equal_counts else N

    device_of = np.full(N, -1, np.int64)
    loads = np.zeros(K)
    counts = np.zeros(K, np.int64)
    # LPT seed: heaviest first; stable sort keeps index order on ties.
    for i in np.argsort(-costs, kind="stable"):
        open_ = counts < max_count
        if caps is not None:
            fits = open_ & (loads + costs[i] <= caps + 1e-9)
            if fits.any():
                open_ = fits
        cand = np.nonzero(open_)[0]
        k = int(cand[np.argmin(loads[cand])])
        device_of[i] = k
        loads[k] += costs[i]
        counts[k] += 1

    for _ in range(refine_rounds):
        a, b = int(np.argmax(loads)), int(np.argmin(loads))
        if a == b or loads[a] - loads[b] <= 1e-12:
            break
        moved = False
        if not equal_counts:
            moved = _dp_transfer(device_of, costs, loads, a, b, caps,
                                 resolution)
        if not moved:
            moved = _best_swap(device_of, costs, loads, a, b, caps)
        if not moved:
            break

    return DeviceAssignment(device_of, costs, K, caps)


def rebalance_report(assignment: DeviceAssignment) -> dict:
    """Per-device cost spread of an assignment (printed by the launcher,
    embedded in the distributed-step bench/dry-run artifacts)."""
    loads = assignment.loads
    counts = assignment.counts
    mean = float(loads.mean())
    over = []
    if assignment.capacities is not None:
        over = [int(k) for k in range(assignment.n_devices)
                if loads[k] > assignment.capacities[k] + 1e-9]
    return {
        "n_devices": assignment.n_devices,
        "n_microbatches": int(len(assignment.device_of)),
        "loads": [round(float(x), 6) for x in loads],
        "counts": [int(c) for c in counts],
        "mean_load": round(mean, 6),
        "max_load": round(float(loads.max()), 6),
        "min_load": round(float(loads.min()), 6),
        "spread": round(_spread(loads), 6),
        "imbalance": round(float(loads.max() / mean), 6) if mean > 0 else 1.0,
        "load_variance": round(float(np.var(loads)), 6),
        "capacity_ok": not over,
        "overloaded_devices": over,
    }


def plan_device_assignment(sched: Schedule, n_devices: int, capacities=None,
                           *, equal_counts: bool = True, c_f: float = 0.4,
                           c_b: float = 0.6
                           ) -> Tuple[DeviceAssignment, dict]:
    """Schedule -> balanced device assignment + rebalance report."""
    assignment = assign_microbatches(
        microbatch_costs(sched, c_f, c_b), n_devices, capacities,
        equal_counts=equal_counts)
    return assignment, rebalance_report(assignment)


def speed_capacities(costs, unit_times, slack: float = 1.1) -> np.ndarray:
    """[K] per-device cost budgets C_k from measured per-unit step times.

    The elastic loop's straggler mitigation: device k's share of the total
    schedule cost is proportional to its *speed* 1/u_k (u_k = the EMA of
    measured step time per unit of assigned cost), so a 2x-slow straggler
    gets half the budget of a healthy device and the knapsack shifts
    p_f-heavy micro-batches off it. ``slack`` > 1 keeps the capacities
    jointly feasible (sum C_k = slack * total cost) — the assigner treats
    a violated capacity as a report entry, not an error, so slack only
    shapes how hard the LPT seed and the refinement push."""
    costs = np.asarray(costs, np.float64)
    u = np.asarray(unit_times, np.float64)
    assert (u > 0).all(), f"unit times must be positive, got {u}"
    speed = 1.0 / u
    return slack * float(costs.sum()) * speed / speed.sum()


def weighted_makespan(assignment: DeviceAssignment,
                      unit_times) -> float:
    """Predicted step time under heterogeneous speeds: the slowest
    device's (assigned cost x per-unit time)."""
    u = np.asarray(unit_times, np.float64)
    return float((assignment.loads * u).max())


# ----------------------------------------------- execution-layer bridging
def device_sample_order(assignment: DeviceAssignment, mb_of: np.ndarray
                        ) -> np.ndarray:
    """[B] sample permutation making the batch device-contiguous.

    After ``batch[perm]``, device k's shard (rows k*B/K : (k+1)*B/K under a
    PartitionSpec("data") sharding) holds exactly the samples of its
    assigned micro-batches. Requires an equal_counts assignment and
    equal-size micro-batches, so every shard comes out the same size."""
    parts = [np.nonzero(np.isin(mb_of, assignment.items_of(k)))[0]
             for k in range(assignment.n_devices)]
    sizes = {len(p) for p in parts}
    assert len(sizes) == 1, \
        f"uneven device shards {[len(p) for p in parts]}: the shard_map " \
        "step needs an equal_counts assignment and equal micro-batches"
    return np.concatenate(parts)


def distributed_live_bounds(sched: Schedule, mb_of: np.ndarray,
                            assignment: DeviceAssignment
                            ) -> Tuple[int, int]:
    """Per-device static (live_fwd, live_bwd) compaction bounds.

    Each device only dispatches its local shard's live slices, so the
    single SPMD program's static bound is the max over devices — much
    tighter than the global-batch bound when the assigner balanced the
    p_f / p_o counts (the whole point of Eq. 4)."""
    live_f = live_b = 0
    for k in range(assignment.n_devices):
        local = mb_of[np.isin(mb_of, assignment.items_of(k))]
        if len(local) == 0:
            continue
        lf, lb = live_slice_bounds(sched, local)
        live_f, live_b = max(live_f, lf), max(live_b, lb)
    return live_f, live_b


# ------------------------------------------------ pipeline stage assignment
def layer_live_costs(sched: Schedule, c_f: float = 0.4, c_b: float = 0.6
                     ) -> np.ndarray:
    """[L] — live schedule cost of each LAYER summed over its head groups
    and every micro-batch (p_f = c_f + c_b, p_o = c_f, p_s = 0): the item
    weights of the pipeline-stage generalization of Eq. 4. A layer whose
    groups are mostly p_s is nearly free, so stages must be balanced by
    this, not by layer count."""
    t = sched.layer_group_view()                       # [L, G, N]
    per_op = np.where(t == P_F, c_f + c_b, np.where(t == P_O, c_f, 0.0))
    return per_op.sum(axis=(1, 2))


@dataclass(frozen=True)
class StageAssignment:
    """Contiguous layer ranges -> pipeline stages.

    boundaries: (S+1,) ints with boundaries[0] == 0 and
    boundaries[S] == L; stage s owns layers
    [boundaries[s], boundaries[s+1]).
    """
    boundaries: Tuple[int, ...]
    costs: np.ndarray                         # [L] layer costs used
    capacities: Optional[np.ndarray] = None   # [S] or None

    @property
    def n_stages(self) -> int:
        return len(self.boundaries) - 1

    @property
    def n_layers(self) -> int:
        return int(self.boundaries[-1])

    @property
    def stage_of(self) -> np.ndarray:
        out = np.zeros(self.n_layers, np.int64)
        for s in range(self.n_stages):
            out[self.boundaries[s]:self.boundaries[s + 1]] = s
        return out

    @property
    def loads(self) -> np.ndarray:
        return np.asarray([float(self.costs[self.boundaries[s]:
                                            self.boundaries[s + 1]].sum())
                           for s in range(self.n_stages)])

    def layer_range(self, s: int) -> Tuple[int, int]:
        return int(self.boundaries[s]), int(self.boundaries[s + 1])


def _uniform_boundaries(L: int, S: int) -> Tuple[int, ...]:
    """Layer-count split (np.array_split sizes): the schedule-blind
    baseline the live-cost assigner is measured against."""
    base, extra = divmod(L, S)
    sizes = [base + (1 if s < extra else 0) for s in range(S)]
    bounds = [0]
    for sz in sizes:
        bounds.append(bounds[-1] + sz)
    return tuple(bounds)


def assign_stages(costs, n_stages: int, capacities=None) -> StageAssignment:
    """Pack L layers into S CONTIGUOUS stages minimizing the bottleneck.

    Exact min-max contiguous-partition DP (O(S * L^2), host-side like the
    micro-batch knapsack): f[s][j] = min over i of
    max(f[s-1][i], w(i, j)) with w(i, j) the cost of layers [i, j) — or
    the *normalized* load w(i, j) / C_s when per-stage ``capacities`` are
    given (heterogeneous stage devices, same convention as
    ``speed_capacities``). Every stage gets at least one layer; ties break
    on the lowest boundary so identical inputs replan identically."""
    costs = np.asarray(costs, np.float64)
    L, S = len(costs), int(n_stages)
    assert S >= 1
    if L < S:
        raise ValueError(f"cannot split {L} layers into {S} non-empty "
                         "contiguous stages")
    caps = None
    if capacities is not None:
        caps = np.broadcast_to(np.asarray(capacities, np.float64), (S,))
        assert (caps > 0).all(), f"stage capacities must be > 0, got {caps}"
    prefix = np.concatenate([[0.0], np.cumsum(costs)])

    def w(s, i, j):
        load = prefix[j] - prefix[i]
        return load / caps[s] if caps is not None else load

    INF = float("inf")
    # f[s][j]: best bottleneck putting the first j layers into s+1 stages
    f = np.full((S, L + 1), INF)
    arg = np.zeros((S, L + 1), np.int64)
    for j in range(1, L + 1):
        f[0][j] = w(0, 0, j)
    for s in range(1, S):
        for j in range(s + 1, L + 1):
            best, best_i = INF, s
            for i in range(s, j):
                v = max(f[s - 1][i], w(s, i, j))
                if v < best - 1e-15:
                    best, best_i = v, i
            f[s][j], arg[s][j] = best, best_i
    bounds = [L]
    for s in range(S - 1, 0, -1):
        bounds.append(int(arg[s][bounds[-1]]))
    bounds.append(0)
    return StageAssignment(tuple(reversed(bounds)), costs, caps)


def stage_report(assignment: StageAssignment) -> dict:
    """Live-cost stage balance vs the layer-count baseline.

    ``makespan_ratio`` < 1 is the live-cost assigner beating the
    schedule-blind equal-layer split (the bench gates < 0.95 on the paper
    mix); == 1 means the uniform split was already optimal."""
    loads = assignment.loads
    uniform = StageAssignment(
        _uniform_boundaries(assignment.n_layers, assignment.n_stages),
        assignment.costs, assignment.capacities)
    uloads = uniform.loads
    umax = float(uloads.max())
    return {
        "n_stages": assignment.n_stages,
        "n_layers": assignment.n_layers,
        "boundaries": [int(b) for b in assignment.boundaries],
        "loads": [round(float(x), 6) for x in loads],
        "makespan": round(float(loads.max()), 6),
        "layer_count_boundaries": [int(b) for b in uniform.boundaries],
        "layer_count_makespan": round(umax, 6),
        "makespan_ratio": round(float(loads.max()) / umax, 6)
        if umax > 0 else 1.0,
    }


def plan_stage_assignment(sched: Schedule, n_stages: int, capacities=None,
                          *, c_f: float = 0.4, c_b: float = 0.6
                          ) -> Tuple[StageAssignment, dict]:
    """Schedule -> live-cost-balanced stage assignment + report. Re-run at
    every schedule refresh, exactly like ``plan_device_assignment``."""
    assignment = assign_stages(layer_live_costs(sched, c_f, c_b), n_stages,
                               capacities)
    return assignment, stage_report(assignment)
