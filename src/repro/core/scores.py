"""Contribution scores for D2FT subnets (paper §II-A3, Table III).

Metrics: Fisher Information  Σ‖∇w‖² (per micro-batch), Weight Magnitude
Σ‖w‖ (sample-independent), Gradient Magnitude Σ‖∇w‖, Taylor importance
Σ‖w ⊙ ∇w‖. The paper's final choice: backward = Weight Magnitude,
forward = Fisher Information.

A *subnet* is (layer l, head-group g): the g-th slice of every width-
partitionable weight in block l. Slicing rules are name-based; weights with
no natural width partition (router, norms, conv, scalar SSM params) are
counted fully in every group (they are replicated across subnets in the
paper's deployment too — e.g. frozen norms are "replicated for every
subnet", §III-A).
"""
from __future__ import annotations

from typing import Callable, Dict, List, Sequence

import numpy as np

import jax
import jax.numpy as jnp

# name -> ("col" slice last dim | "row" slice first dim | "rep" replicate)
_SLICE_RULES: Dict[str, str] = {
    "wq": "col", "wk": "col", "wv": "col", "wo": "row",
    "bq": "col", "bk": "col", "bv": "col",
    "w_up": "col", "w_gate": "col", "w_down": "row",
    # SSD
    "w_in": "col", "w_out": "row", "A_log": "col", "dt_bias": "col",
    "D": "col", "norm_scale": "col", "conv_w": "col", "conv_b": "col",
    # RG-LRU
    "w_gate_branch": "col", "w_rec_branch": "col", "w_a": "col",
    "w_x": "col", "b_a": "col", "b_x": "col", "Lambda": "col",
}


def _slice_reduce(name: str, arr, G: int, leaf_fn) -> jnp.ndarray:
    """Reduce one weight into per-group scalars [G]."""
    rule = _SLICE_RULES.get(name, "rep")
    a = arr
    if rule == "col" and a.shape[-1] % G == 0:
        parts = a.reshape(*a.shape[:-1], G, a.shape[-1] // G)
        axes = tuple(i for i in range(parts.ndim) if i != parts.ndim - 2)
        return leaf_fn(parts, axes)
    if rule == "row" and a.shape[0] % G == 0:
        parts = a.reshape(G, a.shape[0] // G, *a.shape[1:])
        axes = tuple(range(1, parts.ndim))
        return leaf_fn(parts, axes)
    full = leaf_fn(a[None], tuple(range(1, a.ndim + 1)))
    return jnp.broadcast_to(full, (G,))


def _walk(block: dict, prefix=""):
    for k, v in block.items():
        if isinstance(v, dict):
            yield from _walk(v, k)
        else:
            yield k, v


def subnet_reduce(block: dict, G: int, leaf_fn) -> jnp.ndarray:
    """Reduce a block's params (or grads) into per-group scores [G]."""
    total = jnp.zeros((G,), jnp.float32)
    for name, arr in _walk(block):
        total = total + _slice_reduce(name, arr.astype(jnp.float32), G, leaf_fn)
    return total


def _sum_abs(parts, axes):
    return jnp.sum(jnp.abs(parts), axis=axes)


def _sum_sq(parts, axes):
    return jnp.sum(parts * parts, axis=axes)


# ------------------------------------------------------------------ metrics
def weight_magnitude(blocks: Sequence[dict], G: int) -> np.ndarray:
    """[L, G] — Σ‖w‖ per subnet."""
    return np.stack([np.asarray(subnet_reduce(b, G, _sum_abs)) for b in blocks])


def grad_metric(grad_blocks: Sequence[dict], blocks: Sequence[dict], G: int,
                metric: str) -> np.ndarray:
    """[L, G] for one micro-batch's gradients."""
    out = []
    for gb, wb in zip(grad_blocks, blocks):
        if metric == "fisher":
            out.append(subnet_reduce(gb, G, _sum_sq))
        elif metric == "gradient_magnitude":
            out.append(subnet_reduce(gb, G, _sum_abs))
        elif metric == "taylor":
            prod = jax.tree.map(lambda g, w: g * w, gb, wb)
            out.append(subnet_reduce(prod, G, _sum_abs))
        else:
            raise ValueError(metric)
    return np.stack([np.asarray(o) for o in out])


def compute_scores(loss_fn: Callable, params, blocks_getter: Callable,
                   microbatches: Sequence, G: int,
                   backward_metric: str = "weight_magnitude",
                   forward_metric: str = "fisher"):
    """Score every (subnet, micro-batch) pair before fine-tuning.

    loss_fn(params, microbatch) -> scalar; blocks_getter(tree) -> list of
    per-layer block dicts (works on params and on grads, which share
    structure). Returns (backward [K, N], forward [K, N]) with K = L*G.

    Per the paper: all samples are fed forward+backward once *without
    updating weights* to collect gradient statistics.
    """
    blocks = blocks_getter(params)
    L = len(blocks)
    N = len(microbatches)
    need_grads = ("fisher" in (backward_metric, forward_metric)
                  or "gradient_magnitude" in (backward_metric, forward_metric)
                  or "taylor" in (backward_metric, forward_metric))
    grad_fn = jax.jit(jax.grad(loss_fn)) if need_grads else None

    def metric_per_mb(metric):
        if metric == "weight_magnitude":
            wm = weight_magnitude(blocks, G)                    # [L, G]
            return np.repeat(wm.reshape(L * G, 1), N, axis=1)
        vals = np.zeros((L * G, N))
        for i, mb in enumerate(microbatches):
            grads = grad_fn(params, mb)
            gm = grad_metric(blocks_getter(grads), blocks, G, metric)
            vals[:, i] = gm.reshape(L * G)
        return vals

    return metric_per_mb(backward_metric), metric_per_mb(forward_metric)


# ------------------------------------------------------- block extractors
def transformer_blocks(params, cfg) -> List[dict]:
    """Unstack scan-cycled transformer params into a per-layer block list."""
    from repro.models.transformer import layer_groups
    n_cycles, pat, rem = layer_groups(cfg)
    blocks: List[dict] = []
    if n_cycles > 0:
        for c in range(n_cycles):
            for pi in range(len(pat)):
                blocks.append(jax.tree.map(lambda a: a[c],
                                           params["cycles"][pi]))
    blocks.extend(params["rest"])
    return blocks


def vit_blocks(params, cfg=None) -> List[dict]:
    return list(params["blocks"])
