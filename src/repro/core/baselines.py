"""Baseline schedulers the paper compares against (§III-A).

* Random           — per subnet, random micro-batches for p_f/p_o/p_s at the
                     same budget as D2FT.
* DPruning-M       — dynamic pruning by weight magnitude: top-r fraction of
                     subnets do p_f on every micro-batch, the rest p_s;
                     reselected every ``refresh`` iterations. No p_o option.
* DPruning-M/G     — same with magnitude+gradient importance.
* MoE-GShard       — gate-score routing with expert capacity: each subnet
                     ("expert") takes micro-batches by gate preference until
                     capacity, overflow is dropped (p_s). Mirrors the paper's
                     observation that capacity limits skip samples that
                     needed processing.
All return Schedule tables with the same encoding as D2FT so the cost model
and training paths are shared.
"""
from __future__ import annotations

import numpy as np

from repro.core.schedule import P_F, P_O, P_S, Schedule


def random_schedule(rng: np.random.Generator, n_layers: int, n_groups: int,
                    n_mb: int, n_pf: int, n_po: int,
                    balanced: bool = False) -> Schedule:
    """Random scheduling at the same *expected* budget as D2FT.

    balanced=False (paper's "Random", Table I variance 0.23): ops drawn
    i.i.d. per (subnet, micro-batch) with probabilities matching the budget,
    so per-device workloads fluctuate. balanced=True fixes exact counts per
    subnet (an ablation knob, not the paper's baseline)."""
    K = n_layers * n_groups
    table = np.full((K, n_mb), P_S, np.int8)
    if balanced:
        for k in range(K):
            perm = rng.permutation(n_mb)
            table[k, perm[:n_pf]] = P_F
            table[k, perm[n_pf:n_pf + n_po]] = P_O
    else:
        probs = [n_pf / n_mb, n_po / n_mb, 1.0 - (n_pf + n_po) / n_mb]
        draws = rng.choice([P_F, P_O, P_S], size=(K, n_mb), p=probs)
        table[:] = draws
    return Schedule(table, n_layers, n_groups)


def dpruning_schedule(importance: np.ndarray, n_layers: int, n_groups: int,
                      n_mb: int, keep_fraction: float) -> Schedule:
    """importance: [K] per-subnet score (M: Σ|w|; M/G: Σ|w| * Σ|∇w|).
    Kept subnets run p_f on all micro-batches; pruned subnets p_s."""
    K = n_layers * n_groups
    n_keep = max(1, int(round(keep_fraction * K)))
    keep = np.argsort(-importance)[:n_keep]
    table = np.full((K, n_mb), P_S, np.int8)
    table[keep] = P_F
    return Schedule(table, n_layers, n_groups)


def gshard_schedule(rng: np.random.Generator, gate_logits: np.ndarray,
                    n_layers: int, n_groups: int, capacity: int) -> Schedule:
    """gate_logits: [K, N] preference of subnet k for micro-batch i.
    Every micro-batch is routed to its top-preference subnets per layer;
    a subnet beyond ``capacity`` drops the overflow (p_s)."""
    K, N = gate_logits.shape
    table = np.full((K, N), P_S, np.int8)
    logits = gate_logits.reshape(n_layers, n_groups, N)
    for l in range(n_layers):
        filled = np.zeros(n_groups, int)
        # route each micro-batch to its best expert in this layer
        order = rng.permutation(N)
        for i in order:
            pref = np.argsort(-logits[l, :, i])
            for g in pref:
                if filled[g] < capacity:
                    table[l * n_groups + g, i] = P_F
                    filled[g] += 1
                    break
    return Schedule(table, n_layers, n_groups)
