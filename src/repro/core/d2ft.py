"""D2FT orchestration: planning + the two execution paths.

``plan_schedule``    scores -> bi-level knapsack -> Schedule (host side).
``gates_from_schedule`` (re-exported) -> masked reference path, used by
                     models.transformer.forward / models.vit.
``packed_*``         deployment path: gather each subnet's selected
                     micro-batches (static capacity — the knapsack equalizes
                     counts, paper Table I), compute packed, scatter-add
                     back. This is where the 40% compute / 50% comm saving
                     is visible in compiled FLOPs.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs.base import D2FTConfig, ModelConfig, ATTN_GLOBAL, ATTN_LOCAL
from repro.core import knapsack
from repro.core.schedule import (P_F, P_O, P_S, Schedule, build_schedule,
                                 gates_from_schedule, merge_tables,
                                 packed_indices)
from repro.models import attention as attn
from repro.models.layers import apply_norm, _act


# ------------------------------------------------------------------ planning
def capacities(d2ft: D2FTConfig) -> Tuple[float, float]:
    """Per-device knapsack capacities from the micro-batch budget."""
    cap_pf = d2ft.n_pf * (d2ft.cost_fwd + d2ft.cost_bwd)
    cap_po = d2ft.n_po * d2ft.cost_fwd
    return cap_pf, cap_po


def plan_schedule(d2ft: D2FTConfig, backward_scores: np.ndarray,
                  forward_scores: np.ndarray, n_layers: int, n_groups: int,
                  cap_pf=None, cap_po=None, exclusive_po: bool = True
                  ) -> Schedule:
    """Bi-level knapsack over every subnet (Alg. 1).

    exclusive_po: zero out p_f-selected micro-batches' forward scores before
    the inner solve so the final table hits the (n_pf, n_po) budget exactly
    (the paper's experimental setups are described in those terms); with
    False the raw Alg. 1 overlap semantics apply (p_f wins conflicts).
    """
    c_f, c_b = d2ft.cost_fwd, d2ft.cost_bwd
    dflt_pf, dflt_po = capacities(d2ft)
    cap_pf = dflt_pf if cap_pf is None else cap_pf
    cap_po = dflt_po if cap_po is None else cap_po
    K, N = backward_scores.shape
    cap_pf_arr = np.broadcast_to(np.asarray(cap_pf, np.float64), (K,))
    cap_po_arr = np.broadcast_to(np.asarray(cap_po, np.float64), (K,))
    sel_pf = np.zeros((K, N), bool)
    sel_po = np.zeros((K, N), bool)
    for k in range(K):
        sel_pf[k] = knapsack.dp_knapsack(
            backward_scores[k], np.full(N, c_f + c_b), cap_pf_arr[k])
        fwd = forward_scores[k].copy()
        if exclusive_po:
            fwd[sel_pf[k]] = 0.0
        sel_po[k] = knapsack.dp_knapsack(fwd, np.full(N, c_f), cap_po_arr[k])
    return Schedule(merge_tables(sel_pf, sel_po), n_layers, n_groups)


# ---------------------------------------------------------------- packed path
def _slice_cols(w, G):
    """[..., X] -> [G, ..., X/G] (contiguous group slices on last dim)."""
    parts = w.reshape(*w.shape[:-1], G, w.shape[-1] // G)
    return jnp.moveaxis(parts, -2, 0)


def _slice_rows(w, G):
    return w.reshape(G, w.shape[0] // G, *w.shape[1:])


def _kv_slices(p, G, n_kv, head_dim):
    """Per-group KV projection weights. Returns (wk_g, wv_g, kv_per_group)."""
    if n_kv % G == 0:
        return _slice_cols(p["wk"], G), _slice_cols(p["wv"], G), n_kv // G
    if G % n_kv == 0:
        # each group uses exactly one kv head
        kv_of_g = (jnp.arange(G) * n_kv) // G
        wk3 = p["wk"].reshape(p["wk"].shape[0], n_kv, head_dim)
        wv3 = p["wv"].reshape(p["wv"].shape[0], n_kv, head_dim)
        wk_g = wk3[:, kv_of_g].transpose(1, 0, 2)      # [G, D, hd]
        wv_g = wv3[:, kv_of_g].transpose(1, 0, 2)
        return wk_g, wv_g, 1
    # fallback: replicate full kv per group
    return (jnp.broadcast_to(p["wk"], (G,) + p["wk"].shape),
            jnp.broadcast_to(p["wv"], (G,) + p["wv"].shape), n_kv)


def packed_attention_block(p, x, cfg: ModelConfig, idx, bwd, val,
                           kind: str = ATTN_GLOBAL):
    """Packed D2FT attention sub-block.

    x: [B,S,D] residual stream; idx/bwd/val: [G,C] gather indices, backward
    mask (1 = p_f), validity mask (0 = padding). Each head-group g computes
    attention only for its C selected micro-batch samples.
    Returns the residual contribution [B,S,D].
    """
    B, S, D = x.shape
    G, C = idx.shape
    H, Hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    assert H % G == 0, (H, G)
    h = apply_norm(p["norm1"], x, cfg.norm)
    hg = h[idx.reshape(-1)].reshape(G, C, S, D)

    wq_g = _slice_cols(p["attn"]["wq"], G)             # [G, D, (H/G)*hd]
    wk_g, wv_g, kv_pg = _kv_slices(p["attn"], G, Hkv, hd)
    wo_g = _slice_rows(p["attn"]["wo"], G)             # [G, (H/G)*hd, D]
    window = cfg.window if kind == ATTN_LOCAL else 0

    def per_group(hx, wq, wk, wv, wo):
        q = (hx @ wq).reshape(C, S, H // G, hd)
        k = (hx @ wk).reshape(C, S, kv_pg, hd)
        v = (hx @ wv).reshape(C, S, kv_pg, hd)
        if cfg.rope:
            pos = jnp.arange(S)[None, :]
            q = attn.apply_rope(q, pos, cfg.rope_theta)
            k = attn.apply_rope(k, pos, cfg.rope_theta)
        if window and window > 0 and S > 2 * window and S % window == 0:
            o = attn._block_local_attention(q, k, v, window)
        elif window and window > 0:
            o = attn._sdpa(q, k, v, attn._window_mask(S, S, window))
        elif cfg.causal:
            o = attn._sdpa(q, k, v, attn._causal_mask(S, S))
        else:
            o = attn._sdpa(q, k, v, jnp.ones((1, 1, S, S), bool))
        return o.reshape(C, S, (H // G) * hd) @ wo

    out_g = jax.vmap(per_group)(hg, wq_g, wk_g, wv_g, wo_g)   # [G,C,S,D]
    m_b = bwd[:, :, None, None].astype(out_g.dtype)
    m_v = val[:, :, None, None].astype(out_g.dtype)
    out_g = m_v * (m_b * out_g + (1 - m_b) * jax.lax.stop_gradient(out_g))
    y = jnp.zeros((B, S, D), x.dtype)
    y = y.at[idx.reshape(-1)].add(out_g.reshape(G * C, S, D))
    return y


def packed_mlp_block(p, x, cfg: ModelConfig, idx, bwd, val):
    """Packed D2FT FFN sub-block (dense MLP). Same contract as above."""
    B, S, D = x.shape
    G, C = idx.shape
    F = cfg.d_ff
    assert F % G == 0
    h = apply_norm(p["norm2"], x, cfg.norm)
    hg = h[idx.reshape(-1)].reshape(G, C, S, D)
    wu_g = _slice_cols(p["mlp"]["w_up"], G)
    wd_g = _slice_rows(p["mlp"]["w_down"], G)
    wg_g = _slice_cols(p["mlp"]["w_gate"], G) if "w_gate" in p["mlp"] else None

    def per_group(hx, wu, wd, wg):
        up = hx @ wu
        hid = _act(cfg.mlp_act)(hx @ wg) * up if wg is not None else \
            _act(cfg.mlp_act)(up)
        return hid @ wd

    if wg_g is None:
        out_g = jax.vmap(lambda a, b, c: per_group(a, b, c, None))(hg, wu_g, wd_g)
    else:
        out_g = jax.vmap(per_group)(hg, wu_g, wd_g, wg_g)
    m_b = bwd[:, :, None, None].astype(out_g.dtype)
    m_v = val[:, :, None, None].astype(out_g.dtype)
    out_g = m_v * (m_b * out_g + (1 - m_b) * jax.lax.stop_gradient(out_g))
    y = jnp.zeros((B, S, D), x.dtype)
    y = y.at[idx.reshape(-1)].add(out_g.reshape(G * C, S, D))
    return y


def mb_packed_indices(sched, n_mb: int):
    """Micro-batch-level gather plan: for each (layer, group) the selected
    micro-batch ids (p_f first, then p_o), plus bwd/valid masks, all padded
    to the max count C_mb. The knapsack's balanced budget makes C_mb equal
    across subnets in the homogeneous case (paper Table I)."""
    t = sched.layer_group_view()                          # [L, G, N]
    L, G, N = t.shape
    assert N == n_mb
    counts = (t != P_S).sum(-1)
    C = int(counts.max())
    idx = np.zeros((L, G, C), np.int32)
    bwd = np.zeros((L, G, C), np.float32)
    val = np.zeros((L, G, C), np.float32)
    for l in range(L):
        for g in range(G):
            f = np.nonzero(t[l, g] == P_F)[0]
            o = np.nonzero(t[l, g] == P_O)[0]
            take = np.concatenate([f, o])[:C]
            idx[l, g, :len(take)] = take
            bwd[l, g, :len(f)] = 1.0
            val[l, g, :len(take)] = 1.0
    return idx, bwd, val


def _mb_gather(x, idx):
    """x: [M, B', S, D]; idx: [G, C] micro-batch ids -> [G, C*B', S, D].
    The gather runs over the small UNSHARDED micro-batch axis, so no
    cross-device data movement happens for sample selection (the
    batch shard layout is untouched) — this is what makes the packed path
    GSPMD-friendly at pod scale (EXPERIMENTS.md §Perf)."""
    G, C = idx.shape
    M, Bp, S, D = x.shape
    return x[idx.reshape(-1)].reshape(G, C * Bp, S, D)


def _mb_scatter(y, out_g, idx, shape):
    """Scatter-add group contributions back onto the micro-batch axis."""
    M, Bp, S, D = shape
    G, C = idx.shape
    return y.at[idx.reshape(-1)].add(
        out_g.reshape(G * C, Bp, S, D))


def _split_fo(idx, bwd, val, n_pf: int):
    """Split the gather plan into the p_f part and the p_o part. The
    backward for the p_o part is wrapped in stop_gradient at the CALL level
    so autodiff emits NO backward graph for it at all — masking the
    cotangent instead leaves the backward matmuls dense (measured: only
    −4% step FLOPs; with the split the p_o backward is DCE'd,
    EXPERIMENTS.md §Perf iter s2)."""
    return (idx[:, :n_pf], val[:, :n_pf]), (idx[:, n_pf:], val[:, n_pf:])


def packed_attention_block_mb(p, x, cfg: ModelConfig, idx, bwd, val,
                              kind=ATTN_GLOBAL, policy=None, n_pf=None):
    """Micro-batch-axis packed attention. x: [M, B', S, D]."""
    M, Bp, S, D = x.shape
    G, C = idx.shape
    H, Hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.resolved_head_dim
    h = apply_norm(p["norm1"], x, cfg.norm)
    wq_g = _slice_cols(p["attn"]["wq"], G)
    wk_g, wv_g, kv_pg = _kv_slices(p["attn"], G, Hkv, hd)
    wo_g = _slice_rows(p["attn"]["wo"], G)
    window = cfg.window if kind == ATTN_LOCAL else 0

    def per_group(hx, wq, wk, wv, wo):
        CB = hx.shape[0]
        q = (hx @ wq).reshape(CB, S, H // G, hd)
        k = (hx @ wk).reshape(CB, S, kv_pg, hd)
        v = (hx @ wv).reshape(CB, S, kv_pg, hd)
        if cfg.rope:
            pos = jnp.arange(S)[None, :]
            q = attn.apply_rope(q, pos, cfg.rope_theta)
            k = attn.apply_rope(k, pos, cfg.rope_theta)
        if window and window > 0 and S > 2 * window and S % window == 0:
            o = attn._block_local_attention(q, k, v, window)
        elif window and window > 0:
            o = attn._sdpa(q, k, v, attn._window_mask(S, S, window))
        elif cfg.causal:
            o = attn._sdpa(q, k, v, attn._causal_mask(S, S))
        else:
            o = attn._sdpa(q, k, v, jnp.ones((1, 1, S, S), bool))
        return o.reshape(CB, S, (H // G) * hd) @ wo

    def run(sub_idx, sub_val):
        hg = _mb_gather(h, sub_idx)
        if policy is not None:
            hg = policy.packed_groups(hg)
        out = jax.vmap(per_group)(hg, wq_g, wk_g, wv_g, wo_g)
        Csub = sub_idx.shape[1]
        out = out.reshape(G, Csub, Bp, S, D)
        return out * sub_val[:, :, None, None, None].astype(out.dtype)

    return _fo_combine(run, idx, bwd, val, n_pf, (M, Bp, S, D), x.dtype)


def _fo_combine(run, idx, bwd, val, n_pf, shape, dtype):
    """Run the p_f part with gradients and the p_o part fully
    stop-gradient'd (backward DCE'd), scatter both onto the mb axis."""
    if n_pf is None:
        n_pf = int(bwd.sum(-1).max()) if hasattr(bwd, "sum") else idx.shape[1]
    (idx_f, val_f), (idx_o, val_o) = _split_fo(idx, bwd, val, n_pf)
    y = jnp.zeros(shape, dtype)
    if idx_f.shape[1] > 0:
        y = _mb_scatter(y, run(idx_f, val_f), idx_f, shape)
    if idx_o.shape[1] > 0:
        out_o = jax.lax.stop_gradient(run(idx_o, val_o))
        y = _mb_scatter(y, out_o, idx_o, shape)
    return y


def packed_mlp_block_mb(p, x, cfg: ModelConfig, idx, bwd, val, policy=None,
                        n_pf=None):
    M, Bp, S, D = x.shape
    G, C = idx.shape
    h = apply_norm(p["norm2"], x, cfg.norm)
    wu_g = _slice_cols(p["mlp"]["w_up"], G)
    wd_g = _slice_rows(p["mlp"]["w_down"], G)
    wg_g = _slice_cols(p["mlp"]["w_gate"], G) if "w_gate" in p["mlp"] \
        else None

    def per_group(hx, wu, wd, wg):
        up = hx @ wu
        hid = _act(cfg.mlp_act)(hx @ wg) * up if wg is not None else \
            _act(cfg.mlp_act)(up)
        return hid @ wd

    def run(sub_idx, sub_val):
        hg = _mb_gather(h, sub_idx)
        if policy is not None:
            hg = policy.packed_groups(hg)
        if wg_g is None:
            out = jax.vmap(lambda a, b, c: per_group(a, b, c, None))(
                hg, wu_g, wd_g)
        else:
            out = jax.vmap(per_group)(hg, wu_g, wd_g, wg_g)
        Csub = sub_idx.shape[1]
        out = out.reshape(G, Csub, Bp, S, D)
        return out * sub_val[:, :, None, None, None].astype(out.dtype)

    return _fo_combine(run, idx, bwd, val, n_pf, (M, Bp, S, D), x.dtype)


def packed_forward_mb(params, cfg: ModelConfig, tokens, sched_arrays,
                      n_mb: int, policy=None, remat: bool = False,
                      n_pf: Optional[int] = None):
    """Micro-batch-axis packed path (deployment form).

    tokens: [B, S] with contiguous micro-batch blocks (sample i belongs to
    micro-batch i // (B/n_mb)); sched_arrays = mb_packed_indices(...) of
    shapes [L, G, C_mb]. Sample selection happens on the unsharded
    micro-batch axis; the batch stays data-sharded throughout.
    """
    from repro.models.layers import apply_embedding, softcap
    from repro.models.transformer import layer_groups
    idx, bwd, val = sched_arrays
    if n_pf is None:
        n_pf = int(np.asarray(bwd).sum(-1).max())   # static (balanced table)
    cdt = jnp.dtype(cfg.compute_dtype)
    B, S = tokens.shape
    Bp = B // n_mb
    x = apply_embedding(params["embed"], tokens).astype(cdt)
    x = x.reshape(n_mb, Bp, S, -1)
    if policy is not None:
        x = policy.packed_residual(x)
    n_cycles, pat, rem = layer_groups(cfg)
    P = len(pat)
    assert all(k in (ATTN_GLOBAL, ATTN_LOCAL) for k in pat + tuple(rem))

    def one_block(blk, x, kind, i3):
        li, bi, vi = i3
        x = x + packed_attention_block_mb(blk, x, cfg, li, bi, vi, kind,
                                          policy, n_pf=n_pf)
        if policy is not None:
            x = policy.packed_residual(x)
        if "norm2" in blk and "mlp" in blk:
            x = x + packed_mlp_block_mb(blk, x, cfg, li, bi, vi, policy,
                                        n_pf=n_pf)
            if policy is not None:
                x = policy.packed_residual(x)
        return x

    if n_cycles > 0:
        idx_c = idx[:n_cycles * P].reshape(n_cycles, P, *idx.shape[1:])
        bwd_c = bwd[:n_cycles * P].reshape(n_cycles, P, *bwd.shape[1:])
        val_c = val[:n_cycles * P].reshape(n_cycles, P, *val.shape[1:])

        def body(x, xs):
            blocks, ic, bc, vc = xs
            for i in range(P):
                x = one_block(blocks[i], x, pat[i], (ic[i], bc[i], vc[i]))
            return x, None
        if remat:
            body = jax.checkpoint(body, prevent_cse=False)
        xs = (params["cycles"], idx_c, bwd_c, val_c)
        if n_cycles <= 2:      # unrolled for dry-run cost extrapolation
            for c in range(n_cycles):
                x, _ = body(x, jax.tree.map(lambda a: a[c], xs))
        else:
            x, _ = jax.lax.scan(body, x, xs)

    for i, kind in enumerate(rem):
        j = n_cycles * P + i
        x = one_block(params["rest"][i], x, kind, (idx[j], bwd[j], val[j]))

    x = apply_norm(params["final_norm"], x, cfg.norm)
    x = x.reshape(B, S, -1)
    if cfg.tie_embeddings:
        logits = x @ params["embed"]["table"].T.astype(cdt)
    else:
        logits = x @ params["unembed"].astype(cdt)
    if policy is not None:
        logits = policy.logits(logits)
    return softcap(logits, cfg.logit_softcap), \
        {"aux_loss": jnp.zeros((), jnp.float32)}


def packed_forward(params, cfg: ModelConfig, tokens, sched_arrays,
                   policy=None, remat: bool = False):
    """Packed-path forward for attention-pattern configs.

    sched_arrays = (idx, bwd, val) with shapes [L, G, C] (from
    schedule.packed_indices). Only ATTN_* block patterns are supported —
    other families use the masked path (see DESIGN.md §Arch-applicability).
    Returns (logits, aux).
    """
    from repro.models.layers import apply_embedding, softcap
    from repro.models.transformer import layer_groups
    idx, bwd, val = sched_arrays
    cdt = jnp.dtype(cfg.compute_dtype)
    x = apply_embedding(params["embed"], tokens).astype(cdt)
    if policy is not None:
        x = policy.residual(x)
    n_cycles, pat, rem = layer_groups(cfg)
    P = len(pat)
    assert all(k in (ATTN_GLOBAL, ATTN_LOCAL) for k in pat + tuple(rem)), \
        "packed path supports attention blocks only"

    def one_block(blk, x, kind, i3):
        li, bi, vi = i3
        x = x + packed_attention_block(blk, x, cfg, li, bi, vi, kind)
        if policy is not None:
            x = policy.residual(x)
        if "norm2" in blk and "mlp" in blk:
            x = x + packed_mlp_block(blk, x, cfg, li, bi, vi)
            if policy is not None:
                x = policy.residual(x)
        return x

    if n_cycles > 0:
        idx_c = idx[:n_cycles * P].reshape(n_cycles, P, *idx.shape[1:])
        bwd_c = bwd[:n_cycles * P].reshape(n_cycles, P, *bwd.shape[1:])
        val_c = val[:n_cycles * P].reshape(n_cycles, P, *val.shape[1:])

        def body(x, xs):
            blocks, ic, bc, vc = xs
            for i in range(P):
                x = one_block(blocks[i], x, pat[i], (ic[i], bc[i], vc[i]))
            return x, None
        if remat:
            body = jax.checkpoint(body, prevent_cse=False)
        x, _ = jax.lax.scan(body, x, (params["cycles"], idx_c, bwd_c, val_c))

    for i, kind in enumerate(rem):
        j = n_cycles * P + i
        x = one_block(params["rest"][i], x, kind, (idx[j], bwd[j], val[j]))

    x = apply_norm(params["final_norm"], x, cfg.norm)
    if cfg.tie_embeddings:
        logits = x @ params["embed"]["table"].T.astype(cdt)
    else:
        logits = x @ params["unembed"].astype(cdt)
    if policy is not None:
        logits = policy.logits(logits)
    return softcap(logits, cfg.logit_softcap), {"aux_loss": jnp.zeros((), jnp.float32)}
