"""Schedule table construction and gate materialization (paper Algorithm 1).

A ``ScheduleTable`` is an int8 array [K, N] over subnets k and micro-batches
i with entries  1 = p_f (full),  2 = p_o (forward-only),  3 = p_s (shortcut)
— the exact encoding of Algorithm 1.

Subnets are indexed k = l * G + g for layer l and head-group g; this module
converts tables to the (g_f, g_b) gate arrays consumed by
models.transformer.forward and to packed-path gather indices.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Optional, Tuple

import numpy as np

import jax.numpy as jnp

P_F, P_O, P_S = 1, 2, 3


@dataclass
class Schedule:
    table: np.ndarray          # [K, N] int8 in {1,2,3}
    n_layers: int
    n_groups: int              # G (subnets per layer)

    @property
    def n_microbatches(self) -> int:
        return self.table.shape[1]

    def layer_group_view(self) -> np.ndarray:
        return self.table.reshape(self.n_layers, self.n_groups, -1)


def merge_tables(sel_pf: np.ndarray, sel_po: np.ndarray) -> np.ndarray:
    """Algorithm 1 lines 14-31. sel_pf, sel_po: [K, N] bool."""
    table = np.full(sel_pf.shape, P_S, np.int8)
    table[sel_po] = P_O
    table[sel_pf] = P_F            # p_f wins conflicts (line 23-25)
    return table


def build_schedule(backward_scores: np.ndarray, forward_scores: np.ndarray,
                   n_layers: int, n_groups: int, *, c_f: float, c_b: float,
                   cap_pf, cap_po, resolution: int = 100) -> Schedule:
    """Run the bi-level knapsack for every subnet (= device) independently.

    backward_scores / forward_scores: [K, N]; cap_pf / cap_po: scalar or [K]
    per-device capacities (heterogeneity support, paper §IV-D).
    """
    from repro.core.knapsack import bilevel_select
    K, N = backward_scores.shape
    cap_pf = np.broadcast_to(np.asarray(cap_pf, np.float64), (K,))
    cap_po = np.broadcast_to(np.asarray(cap_po, np.float64), (K,))
    sel_pf = np.zeros((K, N), bool)
    sel_po = np.zeros((K, N), bool)
    for k in range(K):
        sel_pf[k], sel_po[k] = bilevel_select(
            backward_scores[k], forward_scores[k], c_f, c_b,
            cap_pf[k], cap_po[k], resolution)
    return Schedule(merge_tables(sel_pf, sel_po), n_layers, n_groups)


# ------------------------------------------------------------------- gates
def gates_from_schedule(sched: Schedule, mb_of_sample: np.ndarray
                        ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Materialize (g_f, g_b) of shape [n_layers, B, G] for the masked path.

    mb_of_sample: [B] micro-batch index of each sample in the batch.
    g_f = 1 where op in {p_f, p_o} (forward runs); g_b = 1 where op == p_f.
    """
    t = sched.layer_group_view()                         # [L, G, N]
    per_sample = t[:, :, mb_of_sample]                   # [L, G, B]
    g_f = jnp.asarray((per_sample != P_S).transpose(0, 2, 1), jnp.float32)
    g_b = jnp.asarray((per_sample == P_F).transpose(0, 2, 1), jnp.float32)
    return g_f, g_b


def live_slice_bounds(sched: Schedule, mb_of_sample: np.ndarray
                      ) -> Tuple[int, int]:
    """Static (live_fwd, live_bwd) upper bounds for compaction dispatch.

    Counts, per layer, the (sample, group) slices with g_f != 0 (op in
    {p_f, p_o}) and with g_b != 0 (op == p_f) and takes the max over layers
    — one static bound shared by every layer so scan/jit compile a single
    kernel dispatch. The kernel consumes per-(sample, head) gates, so
    multiply by heads-per-group (H // G) before passing to
    ``ops.gated_attention`` (models do this). These are Python ints derived
    from the host-side schedule table, never traced values.
    """
    per_sample = sched.layer_group_view()[:, :, mb_of_sample]   # [L, G, B]
    live_f = int((per_sample != P_S).sum(axis=(1, 2)).max())
    live_b = int((per_sample == P_F).sum(axis=(1, 2)).max())
    return live_f, live_b


def packed_indices(sched: Schedule, mb_of_sample: np.ndarray,
                   pad_to: Optional[int] = None
                   ) -> Tuple[np.ndarray, np.ndarray, int, int]:
    """Gather indices for the packed path.

    Returns (idx [L, G, C], bwd_mask [L, G, C], C_f, C) where idx[l,g] lists
    the samples each subnet processes forward (p_f first, then p_o) and
    bwd_mask is 1 for the p_f entries. Requires the schedule to be balanced
    (equal counts per subnet) — which the knapsack guarantees when scores
    are positive; otherwise pad_to sets the capacity and entries are
    repeated (repeats are masked out via a zero in bwd/fwd scale... padding
    repeats the first selected sample and contributes via scatter with a
    zero weight handled by the caller).
    """
    t = sched.layer_group_view()                         # [L, G, N]
    L, G, N = t.shape
    per_sample = t[:, :, mb_of_sample]                   # [L, G, B]
    B = per_sample.shape[-1]
    counts_f = (per_sample == P_F).sum(-1)
    counts_o = (per_sample == P_O).sum(-1)
    C_f = int(counts_f.max())
    C_o = int(counts_o.max())
    C = pad_to or (C_f + C_o)
    idx = np.zeros((L, G, C), np.int32)
    bwd = np.zeros((L, G, C), np.float32)
    val = np.zeros((L, G, C), np.float32)
    for l in range(L):
        for g in range(G):
            f = np.nonzero(per_sample[l, g] == P_F)[0]
            o = np.nonzero(per_sample[l, g] == P_O)[0]
            take = np.concatenate([f, o])[:C]
            idx[l, g, :len(take)] = take
            bwd[l, g, :len(f)] = 1.0
            val[l, g, :len(take)] = 1.0
    return idx, bwd, val, C_f


def op_counts(sched: Schedule) -> dict:
    t = sched.table
    return {"p_f": int((t == P_F).sum()), "p_o": int((t == P_O).sum()),
            "p_s": int((t == P_S).sum())}
