"""Knapsack machinery for D2FT scheduling (paper Algorithms 1 & 2).

The orchestration problem (Eq. 4) is a multiple-knapsack; the paper's
heuristic decouples it (i) across devices and (ii) per device into a
bi-level pair of 0/1 knapsacks — outer selects p_f micro-batches by the
*backward* score under capacity C_k^{p_f} with item weight (c_f + c_b);
inner selects p_o micro-batches by the *forward* score under C_k^{p_o}
with weight c_f.

Solvers:
  * ``dp_knapsack``        — classic table DP with backtracking (numpy; the
                             production scheduler — host-side, like data
                             ordering in MaxText).
  * ``dp_knapsack_value_jax`` — jax.lax.scan DP returning the optimal value
                             (used in property tests / on-device scheduling
                             experiments).
  * ``brute_force``        — exhaustive oracle for small N (tests).

Costs are floats; they are scaled to integers with ``resolution`` before the
DP (exact when costs are rationals with small denominators, as in the
paper's c_f = 0.4, c_b = 0.6 setup).
"""
from __future__ import annotations

import itertools
from typing import Tuple

import numpy as np

import jax
import jax.numpy as jnp


def _to_int(weights: np.ndarray, capacity: float, resolution: int):
    w = np.round(np.asarray(weights, np.float64) * resolution).astype(np.int64)
    c = int(round(float(capacity) * resolution))
    return w, c


def dp_knapsack(values: np.ndarray, weights: np.ndarray, capacity: float,
                resolution: int = 100) -> np.ndarray:
    """0/1 knapsack. Returns boolean selection mask of shape [N].

    Matches paper Algorithm 2 (Phase 1 table fill, Phase 2 backtrack).
    """
    values = np.asarray(values, np.float64)
    w, C = _to_int(weights, capacity, resolution)
    N = len(values)
    if C <= 0 or N == 0:
        return np.zeros(N, bool)
    # T[i][c] = best value using items < i with capacity c
    T = np.zeros((N + 1, C + 1), np.float64)
    for i in range(1, N + 1):
        wi, vi = w[i - 1], values[i - 1]
        T[i] = T[i - 1]
        if wi <= C and vi >= 0:
            take = T[i - 1, :C + 1 - wi] + vi
            upd = np.concatenate([T[i - 1, :wi], np.maximum(T[i - 1, wi:], take)])
            T[i] = upd
    sel = np.zeros(N, bool)
    c = C
    for i in range(N, 0, -1):
        if T[i, c] != T[i - 1, c]:
            sel[i - 1] = True
            c -= w[i - 1]
    return sel


def dp_knapsack_value_jax(values, weights_int, capacity_int: int):
    """Optimal knapsack value via jax.lax.scan (device-side variant).

    values: [N] float; weights_int: [N] int32; capacity_int: static int.
    """
    C = int(capacity_int)
    values = jnp.asarray(values, jnp.float32)
    weights_int = jnp.asarray(weights_int, jnp.int32)

    def step(f, item):
        v, w = item
        idx = jnp.arange(C + 1)
        shifted_idx = jnp.clip(idx - w, 0, C)
        take = jnp.where(idx >= w, f[shifted_idx] + v, -jnp.inf)
        return jnp.maximum(f, take), None

    f0 = jnp.zeros(C + 1)
    f, _ = jax.lax.scan(step, f0, (values, weights_int))
    return f[C]


def brute_force(values: np.ndarray, weights: np.ndarray, capacity: float
                ) -> Tuple[float, np.ndarray]:
    """Exhaustive 0/1 knapsack oracle (N <= ~18)."""
    N = len(values)
    best_v, best_sel = 0.0, np.zeros(N, bool)
    for bits in itertools.product([0, 1], repeat=N):
        sel = np.asarray(bits, bool)
        if weights[sel].sum() <= capacity + 1e-9:
            v = values[sel].sum()
            if v > best_v:
                best_v, best_sel = v, sel
    return best_v, best_sel


# --------------------------------------------------------------- bi-level
def bilevel_select(backward_scores: np.ndarray, forward_scores: np.ndarray,
                   c_f: float, c_b: float, cap_pf: float, cap_po: float,
                   resolution: int = 100) -> Tuple[np.ndarray, np.ndarray]:
    """Per-device bi-level solve (Eq. 7 outer / Eq. 8 inner).

    backward_scores, forward_scores: [N] per micro-batch.
    Returns (sel_pf, sel_po) boolean masks — before Alg. 1 merge.
    """
    sel_pf = dp_knapsack(backward_scores,
                         np.full(len(backward_scores), c_f + c_b),
                         cap_pf, resolution)
    sel_po = dp_knapsack(forward_scores,
                         np.full(len(forward_scores), c_f),
                         cap_po, resolution)
    return sel_pf, sel_po


def scalarized_select(backward_scores: np.ndarray, forward_scores: np.ndarray,
                      lam: float, c_f: float, c_b: float, cap_total: float,
                      resolution: int = 100) -> Tuple[np.ndarray, np.ndarray]:
    """'Scaler' baseline from paper §IV-F: a single knapsack over 2N items
    (each micro-batch contributes a p_f item valued by the backward score and
    a p_o item valued by lam * forward score); at most one of the pair is
    kept (p_f wins the conflict, mirroring Alg. 1's merge)."""
    N = len(backward_scores)
    values = np.concatenate([backward_scores, lam * forward_scores])
    weights = np.concatenate([np.full(N, c_f + c_b), np.full(N, c_f)])
    sel = dp_knapsack(values, weights, cap_total, resolution)
    sel_pf, sel_po = sel[:N].copy(), sel[N:].copy()
    sel_po &= ~sel_pf
    return sel_pf, sel_po
