"""Unit tests for the unified multi-axis parallelism API: MeshSpec /
ParallelConfig validation, the deprecation shim on the train-step
factories, the schedule-aware pipeline stage assigner and the bubble
model. The 8-device multi-axis parity (collectives actually moving
bytes) is the ``multidevice``-marked subprocess test at the bottom
(tests/_dist_parity_multiaxis.py)."""
import itertools
import os
import subprocess
import sys

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from conftest import optional_hypothesis
from repro.configs.base import ModelConfig
from repro.core.assignment import (StageAssignment, assign_stages,
                                   layer_live_costs, plan_stage_assignment,
                                   stage_report)
from repro.core.schedule import P_F, P_O, P_S, Schedule, gates_from_schedule
from repro.data.synthetic import lm_batches, microbatch_assignment
from repro.launch.parallel import MeshSpec, ParallelConfig
from repro.models.transformer import init_model
from repro.optim.optimizers import sgd
from repro.sharding.sync import grad_sync_plan
from repro.train.loop import make_distributed_train_step, make_train_step
from repro.train.pipeline import PipelineRecorder, analytic_bubble_fraction

given, settings, st = optional_hypothesis()

CFG = ModelConfig(name="par", arch_type="dense", n_layers=2, d_model=32,
                  n_heads=4, n_kv_heads=4, d_ff=64, vocab_size=128)
L, G, N, B, S = 2, 4, 4, 8, 8


def _schedule():
    rng = np.random.default_rng(7)
    table = rng.choice([P_F, P_O, P_S], size=(L * G, N),
                       p=[.4, .3, .3]).astype(np.int8)
    table[0, 0] = P_F
    return Schedule(table, L, G)


# ----------------------------------------------------------------- MeshSpec
def test_meshspec_parse():
    assert MeshSpec.parse("data=4,stage=2,tensor=1") == \
        MeshSpec(data=4, stage=2, tensor=1)
    assert MeshSpec.parse("data=8") == MeshSpec(data=8)
    assert MeshSpec.parse(" stage=2 , data=4 ") == MeshSpec(data=4, stage=2)
    assert MeshSpec.parse("").shape == (1, 1, 1)
    with pytest.raises(ValueError, match="axis=size"):
        MeshSpec.parse("data:4")
    with pytest.raises(ValueError, match="unknown mesh axis"):
        MeshSpec.parse("model=4")
    with pytest.raises(ValueError, match="twice"):
        MeshSpec.parse("data=4,data=2")
    with pytest.raises(ValueError, match="positive"):
        MeshSpec(data=0)


def test_meshspec_build():
    # default layout keeps all three axes, singletons included
    mesh = MeshSpec(data=1).build()
    assert tuple(mesh.axis_names) == ("data", "stage", "tensor")
    assert dict(mesh.shape) == {"data": 1, "stage": 1, "tensor": 1}
    # oversubscription is an error, not a silent truncation
    with pytest.raises(ValueError, match="device_count"):
        MeshSpec(data=2).build()
    # axis_names may only drop singleton axes
    with pytest.raises(ValueError, match="drops"):
        MeshSpec(data=1, stage=2).build(axis_names=("data",))
    legacy = MeshSpec(data=1).build(axis_names=("data",))
    assert tuple(legacy.axis_names) == ("data",)


def test_make_host_mesh_remainder_raises():
    from repro.launch.mesh import make_host_mesh
    # 1 local device (conftest pins it): model=3 would silently drop 1
    with pytest.raises(ValueError, match="silently drop"):
        make_host_mesh(model=3)
    mesh = make_host_mesh(model=1)
    assert dict(mesh.shape) == {"data": 1, "model": 1}


# ------------------------------------------------------------ ParallelConfig
def test_parallel_config_validation():
    with pytest.raises(ValueError, match="unknown sync_mode"):
        ParallelConfig(sync_mode="nope")
    with pytest.raises(AssertionError):
        ParallelConfig(sync_mode="masked", streamed=True)
    with pytest.raises(ValueError, match="guard"):
        ParallelConfig(sync_mode="zero3", streamed=True, guard=True)
    with pytest.raises(ValueError, match="communication-free"):
        ParallelConfig(mesh=MeshSpec(stage=2), sync_mode="local",
                       microbatches=2)
    with pytest.raises(ValueError, match="pure data mesh"):
        ParallelConfig(mesh=MeshSpec(tensor=2), guard=True)
    with pytest.raises(ValueError, match="use_kernel"):
        ParallelConfig(mesh=MeshSpec(stage=2), microbatches=2,
                       use_kernel=True)
    with pytest.raises(ValueError, match="microbatches"):
        ParallelConfig(mesh=MeshSpec(stage=2))       # pipeline needs M >= 1
    with pytest.raises(ValueError, match="stage"):
        ParallelConfig(microbatches=4)               # M without a pipeline
    ok = ParallelConfig(mesh=MeshSpec(data=2, stage=2, tensor=2),
                        microbatches=4)
    assert ok.stage_axis == "stage" and ok.tensor_axis == "tensor"
    assert ParallelConfig().stage_axis is None
    assert ParallelConfig().tensor_axis is None


def test_parallel_config_validate_model():
    cfg = ParallelConfig(mesh=MeshSpec(tensor=3))
    with pytest.raises(ValueError, match="n_heads"):
        cfg.validate_model(CFG)                      # 3 does not divide 4
    with pytest.raises(ValueError, match="layers"):
        ParallelConfig(mesh=MeshSpec(stage=4),
                       microbatches=2).validate_model(CFG)   # L=2 < S=4
    ParallelConfig(mesh=MeshSpec(tensor=2)).validate_model(CFG)


def test_parallel_config_validate_mesh():
    cfg = ParallelConfig(mesh=MeshSpec(data=1, tensor=2))
    legacy = MeshSpec(data=1).build(axis_names=("data",))
    with pytest.raises(ValueError, match="tensor"):
        cfg.validate_mesh(legacy)                    # mesh lacks the axis
    ParallelConfig().validate_mesh(legacy)


# --------------------------------------------------------- deprecation shim
def test_deprecated_kwargs_still_work():
    """Old loose kwargs run the same step, under a DeprecationWarning."""
    sched = _schedule()
    params = init_model(jax.random.PRNGKey(0), CFG)
    batch = next(lm_batches(0, CFG.vocab_size, B, S, 1))
    gates = gates_from_schedule(sched, microbatch_assignment(B, N))
    plan = grad_sync_plan(params, CFG, sched)
    opt = sgd(1e-2)
    from repro.launch.mesh import make_data_mesh
    mesh = make_data_mesh(1)
    with pytest.warns(DeprecationWarning, match="ParallelConfig"):
        step_old = make_distributed_train_step(CFG, opt, mesh, plan,
                                               sync_mode="masked")
    step_new = make_distributed_train_step(
        CFG, opt, mesh, plan, parallel=ParallelConfig(mesh=MeshSpec(data=1)))
    ref = jax.jit(make_train_step(CFG, opt, use_gates=True))
    p_o, s_o, _ = step_old(params, opt.init(params), batch, gates)
    p_n, s_n, _ = step_new(params, opt.init(params), batch, gates)
    p_r, s_r, _ = ref(params, opt.init(params), batch, gates)
    for p in (p_o, p_n):
        diff = max(jax.tree.leaves(jax.tree.map(
            lambda x, y: float(jnp.abs(x - y).max()), p, p_r)))
        assert diff <= 1e-6, diff


def test_mixing_parallel_and_deprecated_kwargs_raises():
    sched = _schedule()
    params = init_model(jax.random.PRNGKey(0), CFG)
    plan = grad_sync_plan(params, CFG, sched)
    from repro.launch.mesh import make_data_mesh
    mesh = make_data_mesh(1)
    with pytest.raises(TypeError, match="not both"):
        make_distributed_train_step(CFG, sgd(1e-2), mesh, plan,
                                    parallel=ParallelConfig(),
                                    sync_mode="masked")


# -------------------------------------------------------- stage assignment
def _oracle_makespan(costs, S, caps=None):
    """Brute-force best bottleneck over all contiguous partitions."""
    L = len(costs)
    best = float("inf")
    for cuts in itertools.combinations(range(1, L), S - 1):
        bounds = (0,) + cuts + (L,)
        loads = [sum(costs[lo:hi]) for lo, hi in zip(bounds, bounds[1:])]
        if caps is not None:
            loads = [l / c for l, c in zip(loads, caps)]
        best = min(best, max(loads))
    return best


def test_assign_stages_exact():
    rng = np.random.default_rng(0)
    for L_, S_ in [(4, 2), (6, 3), (8, 4), (5, 5), (7, 2)]:
        costs = rng.uniform(0, 10, L_)
        costs[rng.integers(L_)] = 0.0            # a near-free (p_s) layer
        a = assign_stages(costs, S_)
        assert a.boundaries[0] == 0 and a.boundaries[-1] == L_
        assert all(b2 > b1 for b1, b2 in zip(a.boundaries, a.boundaries[1:]))
        assert abs(float(a.loads.max())
                   - _oracle_makespan(costs, S_)) < 1e-9


def test_assign_stages_capacities():
    costs = np.array([4.0, 4.0, 4.0, 4.0])
    caps = np.array([3.0, 1.0])                  # stage 0 is 3x faster
    a = assign_stages(costs, 2, caps)
    # normalized optimum gives the fast stage 3 layers: max(12/3, 4/1) = 4
    assert a.boundaries == (0, 3, 4), a.boundaries
    with pytest.raises(ValueError):
        assign_stages(costs, 5)                  # more stages than layers


def test_plan_stage_assignment_report():
    sched = _schedule()
    a, rep = plan_stage_assignment(sched, 2)
    assert rep["boundaries"][0] == 0 and rep["boundaries"][-1] == L
    assert rep["makespan_ratio"] <= 1.0 + 1e-9
    costs = layer_live_costs(sched)
    assert np.allclose(a.costs, costs)
    # a top-heavy schedule where live cost beats layer count: layer 0
    # all-p_f (1.0), layers 1..3 all-p_o (0.4) -> best split [0,1),[1,4)
    # with makespan 1.2 vs the uniform split's 1.4
    t = np.full((4 * G, N), P_O, np.int8)
    t[0:G] = P_F
    a2, rep2 = plan_stage_assignment(Schedule(t, 4, G), 2)
    assert rep2["makespan_ratio"] < 1.0
    assert rep2["boundaries"] == [0, 1, 4]


# ------------------------------------------------------------- bubble model
def test_analytic_bubble_fraction():
    # uniform loads reduce to the classic (S-1)/(M+S-1)
    assert abs(analytic_bubble_fraction([2.0, 2.0], 4)
               - 1.0 / 5.0) < 1e-12
    assert abs(analytic_bubble_fraction([1.0, 1.0, 1.0], 6)
               - 2.0 / 8.0) < 1e-12
    assert analytic_bubble_fraction([0.0, 0.0], 4) == 0.0
    # imbalance only ever adds bubble
    assert analytic_bubble_fraction([1.0, 3.0], 4) > \
        analytic_bubble_fraction([2.0, 2.0], 4)


def test_pipeline_recorder_report():
    r = PipelineRecorder()
    r.setup((0, 2, 4), 4)
    for t in range(5):
        r.round(t)
    for _ in range(4):
        r.send()
    rep = r.report()
    assert rep["trace_ok"] and rep["expected_rounds"] == 5


# ------------------------------------------------- hypothesis property test
@given(st.data())
@settings(max_examples=30, deadline=None)
def test_stage_assignment_property(data):
    """Any (costs, n_stages, capacities): the DP returns a contiguous
    non-empty cover of all layers that matches the brute-force bottleneck
    optimum (normalized by capacities when given)."""
    L_ = data.draw(st.integers(min_value=1, max_value=9), label="L")
    S_ = data.draw(st.integers(min_value=1, max_value=L_), label="S")
    costs = data.draw(st.lists(
        st.floats(min_value=0.0, max_value=100.0, allow_nan=False),
        min_size=L_, max_size=L_), label="costs")
    caps = None
    if data.draw(st.booleans(), label="use_caps"):
        caps = data.draw(st.lists(
            st.floats(min_value=0.1, max_value=10.0, allow_nan=False),
            min_size=S_, max_size=S_), label="caps")
    a = assign_stages(costs, S_, caps)
    assert a.boundaries[0] == 0 and a.boundaries[-1] == L_
    assert all(b2 > b1 for b1, b2 in zip(a.boundaries, a.boundaries[1:]))
    assert len(a.boundaries) == S_ + 1
    assert (np.sort(np.unique(a.stage_of)) == np.arange(S_)).all()
    loads = a.loads if caps is None else a.loads / np.asarray(caps)
    assert float(max(loads)) <= _oracle_makespan(costs, S_, caps) + 1e-9
    rep = stage_report(a)
    assert rep["boundaries"] == list(a.boundaries)


# ------------------------------------------------ 8-device multi-axis arms
@pytest.mark.multidevice
def test_multiaxis_parity_8dev_subprocess():
    """Acceptance: (data=4, tensor=2) and (data=2, stage=2) — plus the
    all-three-axes and TP+ZeRO-3 and TP+LoRA compositions — match the
    single-device gated reference to <= 1e-6 over 3 steps on 8 emulated
    devices. Fresh interpreter (host-device count must precede jax init);
    ``-m multidevice``: compiles several shard_map variants, so it runs in
    the multidevice CI job's wall-clock budget."""
    script = os.path.join(os.path.dirname(__file__),
                          "_dist_parity_multiaxis.py")
    env = dict(os.environ,
               PYTHONPATH=os.pathsep.join(
                   [os.path.join(os.path.dirname(__file__), "..", "src")]
                   + ([os.environ["PYTHONPATH"]]
                      if "PYTHONPATH" in os.environ else [])))
    proc = subprocess.run([sys.executable, script], env=env,
                          capture_output=True, text=True, timeout=1500)
    assert proc.returncode == 0, \
        f"stdout:\n{proc.stdout}\nstderr:\n{proc.stderr}"
    assert "PARITY_OK" in proc.stdout, proc.stdout
