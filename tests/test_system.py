"""End-to-end behaviour: the paper's central claims at test scale.

1. D2FT at a 60-70% compute budget fine-tunes better than Random scheduling
   at the same budget (Fig. 1/2 ordering).
2. D2FT workload variance is 0; Random/GShard > 0 (Table I).
3. The packed deployment path trains equivalently to the masked path.
4. Sharded-model parity: the policy-constrained model on a host mesh equals
   the unsharded model (run in a subprocess with fake devices).
"""
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import D2FTConfig
from repro.core.baselines import random_schedule
from repro.core.cost_model import compute_cost, workload_variance
from repro.core.d2ft import plan_schedule
from repro.core.schedule import gates_from_schedule
from repro.core.scores import compute_scores, vit_blocks
from repro.data.synthetic import image_batches, make_image_task
from repro.models.vit import ViTConfig, init_vit, vit_loss
from repro.optim.optimizers import sgd
from repro.train.loop import eval_vit, finetune_vit

CFG = ViTConfig(n_layers=2, d_model=96, n_heads=6, d_ff=192, patch=8,
                image_size=32, n_classes=4)
N_MB = 5


def _pretrained(task, steps=25):
    params = init_vit(jax.random.PRNGKey(0), CFG)
    params, _, _ = finetune_vit(params, CFG, sgd(0.05),
                                image_batches(task, 11, 40, steps),
                                steps=steps)
    return params


def _d2ft_schedule_fn(d2):
    def fn(step, params, images, labels):
        if step % 16 != 0:
            return None
        mbs = list(zip(np.split(images, N_MB), np.split(labels, N_MB)))

        def loss_fn(p, mb):
            return vit_loss(p, jnp.asarray(mb[0]), jnp.asarray(mb[1]),
                            CFG)[0]

        bw, fw = compute_scores(loss_fn, params, vit_blocks, mbs,
                                CFG.n_heads)
        return plan_schedule(d2, bw, fw, CFG.n_layers, CFG.n_heads)
    return fn


def test_d2ft_beats_random_at_same_budget():
    task = make_image_task(3, n_classes=4, image_size=32, noise=0.35)
    base = _pretrained(task)
    d2 = D2FTConfig(n_microbatches=N_MB, n_pf=2, n_po=1)
    steps = 30

    p1, _, _ = finetune_vit(jax.tree.map(jnp.copy, base), CFG, sgd(0.05),
                            image_batches(task, 5, 40, steps), steps=steps,
                            schedule_fn=_d2ft_schedule_fn(d2),
                            n_microbatches=N_MB)
    acc_d2ft = eval_vit(p1, CFG, image_batches(task, 7, 40, 5))

    rng = np.random.default_rng(0)
    def random_fn(step, params, images, labels):
        return random_schedule(rng, CFG.n_layers, CFG.n_heads, N_MB, 2, 1)
    p2, _, _ = finetune_vit(jax.tree.map(jnp.copy, base), CFG, sgd(0.05),
                            image_batches(task, 5, 40, steps), steps=steps,
                            schedule_fn=random_fn, n_microbatches=N_MB)
    acc_rand = eval_vit(p2, CFG, image_batches(task, 7, 40, 5))
    assert acc_d2ft >= acc_rand - 0.02, (acc_d2ft, acc_rand)


def test_schedule_budget_and_balance():
    rng = np.random.default_rng(0)
    d2 = D2FTConfig(n_microbatches=5, n_pf=3, n_po=1)
    bw = np.repeat(rng.random((12, 1)) + .1, 5, 1)
    fw = rng.random((12, 5)) + .1
    sched = plan_schedule(d2, bw, fw, 2, 6)
    assert workload_variance(sched.table) == 0.0
    assert abs(compute_cost(sched.table) - 0.68) < 1e-9
    rs = random_schedule(rng, 2, 6, 5, 3, 1)
    assert workload_variance(rs.table) > 0.0


SHARDED_PARITY = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.configs.base import ModelConfig, MoEConfig
from repro.launch.mesh import compat_make_mesh
from repro.models.transformer import init_model, lm_loss
from repro.sharding.policy import ShardingPolicy

cfg = ModelConfig(name="t", arch_type="moe", n_layers=2, d_model=32,
                  n_heads=4, n_kv_heads=2, d_ff=0, vocab_size=64,
                  moe=MoEConfig(n_experts=4, top_k=2, d_ff=32,
                                capacity_factor=4.0))
mesh = compat_make_mesh((4, 2), ("data", "model"))
params = init_model(jax.random.PRNGKey(0), cfg)
toks = jax.random.randint(jax.random.PRNGKey(1), (8, 16), 0, 64)
l0, _ = lm_loss(params, cfg, toks, toks)
policy = ShardingPolicy(mesh, cfg)
with mesh:
    pspecs = policy.param_specs(params)
    fn = jax.jit(lambda p, t: lm_loss(p, cfg, t, t, policy=policy)[0],
                 in_shardings=(pspecs, policy.batch_spec(toks.shape)))
    l1 = fn(params, toks)
err = abs(float(l0) - float(l1))
assert err < 2e-3, err
print("sharded parity OK", err)
"""


def test_sharded_model_parity_subprocess():
    """EP MoE + policy-constrained forward == unsharded (8 fake devices)."""
    import os
    env = dict(os.environ,
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    # force CPU: xla_force_host_platform_device_count needs the host
    # platform, and autodetect burns minutes in TPU init when libtpu is
    # installed without hardware
    env["JAX_PLATFORMS"] = "cpu"
    out = subprocess.run([sys.executable, "-c", SHARDED_PARITY], env=env,
                         capture_output=True, text=True, timeout=300)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "sharded parity OK" in out.stdout


LEVER_PARITY = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp
from repro.configs.base import ModelConfig
from repro.launch.mesh import compat_make_mesh
from repro.models.transformer import init_model, forward
from repro.sharding.policy import ShardingPolicy

cfg = ModelConfig(name="t", arch_type="dense", n_layers=2, d_model=32,
                  n_heads=5, n_kv_heads=5, d_ff=64, vocab_size=64)
mesh = compat_make_mesh((2, 4), ("data", "model"))
params = init_model(jax.random.PRNGKey(0), cfg)
toks = jax.random.randint(jax.random.PRNGKey(1), (4, 16), 0, 64)
l0, _ = forward(params, cfg, tokens=toks)
with mesh:
    pol = ShardingPolicy(mesh, cfg, pad_heads=True, max_pad_overhead=2.0)
    assert pol.head_padding() == (8, 8), pol.head_padding()
    l1 = jax.jit(lambda p, t: forward(p, cfg, tokens=t, policy=pol)[0])(
        params, toks)
    pol2 = ShardingPolicy(mesh, cfg, attn_q_chunk=4)
    l2 = jax.jit(lambda p, t: forward(p, cfg, tokens=t, policy=pol2)[0])(
        params, toks)
e1 = float(jnp.max(jnp.abs(l0 - l1)))
e2 = float(jnp.max(jnp.abs(l0 - l2)))
assert e1 < 1e-4 and e2 < 1e-4, (e1, e2)
print("lever parity OK", e1, e2)
"""


def test_perf_lever_parity_subprocess():
    """Padded-head TP and q-chunked attention are EXACT rewrites."""
    import os
    env = dict(os.environ,
               PYTHONPATH=os.path.join(os.path.dirname(__file__), "..",
                                       "src"))
    env["JAX_PLATFORMS"] = "cpu"       # see test_sharded_model_parity
    out = subprocess.run([sys.executable, "-c", LEVER_PARITY], env=env,
                         capture_output=True, text=True, timeout=300)
    assert out.returncode == 0, out.stderr[-2000:]
    assert "lever parity OK" in out.stdout
