"""Per-assigned-architecture smoke tests: a REDUCED variant of the same
family runs one forward and one train step on CPU; shapes + finiteness
asserted. Decode smoke for decoder archs."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_smoke_config
from repro.models.frontends import synth_features, text_len
from repro.models.transformer import (decode_step, forward, init_cache,
                                      init_model, lm_loss)
from repro.optim.optimizers import sgd


def _batch(cfg, B=2, S=16):
    key = jax.random.PRNGKey(7)
    batch = {}
    if cfg.frontend == "audio_stub":
        batch["features"] = synth_features(key, cfg, B, S)
        batch["labels"] = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    elif cfg.frontend == "vision_stub":
        s_text = S - cfg.frontend_tokens
        batch["features"] = synth_features(key, cfg, B, S)
        batch["tokens"] = jax.random.randint(key, (B, s_text), 0,
                                             cfg.vocab_size)
        batch["labels"] = jax.random.randint(key, (B, s_text), 0,
                                             cfg.vocab_size)
    else:
        batch["tokens"] = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
        batch["labels"] = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    return batch


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_train_step(arch):
    cfg = get_smoke_config(arch)
    assert cfg.n_layers <= 8 and cfg.d_model <= 512
    if cfg.moe is not None:
        assert cfg.moe.n_experts <= 4
    params = init_model(jax.random.PRNGKey(0), cfg)
    B, S = 2, 16
    batch = _batch(cfg, B, S)
    logits, _ = forward(params, cfg, tokens=batch.get("tokens"),
                        features=batch.get("features"))
    assert logits.shape[0] == B and logits.shape[-1] == cfg.vocab_size
    assert bool(jnp.all(jnp.isfinite(logits))), arch

    opt = sgd(1e-2, momentum=0.9)
    state = opt.init(params)

    def loss_fn(p):
        return lm_loss(p, cfg, batch.get("tokens"), batch["labels"],
                       features=batch.get("features"))[0]

    l0, grads = jax.value_and_grad(loss_fn)(params)
    params2, _ = opt.update(grads, state, params)
    l1 = loss_fn(params2)
    assert np.isfinite(float(l0)) and np.isfinite(float(l1))
    assert float(l1) < float(l0)      # one SGD step reduces loss


@pytest.mark.parametrize("arch", [a for a in ARCH_IDS
                                  if a != "hubert-xlarge"])
def test_smoke_decode_step(arch):
    cfg = get_smoke_config(arch)
    if cfg.frontend == "vision_stub":
        pytest.skip("decode smoke uses pure-text prompt path")
    params = init_model(jax.random.PRNGKey(0), cfg)
    B, T = 2, 8
    cache = init_cache(cfg, B, T)
    tok = jax.random.randint(jax.random.PRNGKey(1), (B, 1), 0,
                             cfg.vocab_size)
    logits, cache2 = decode_step(params, cache, cfg, tok, jnp.int32(0))
    assert logits.shape == (B, 1, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits)))
    # cache structure preserved
    assert jax.tree.structure(cache) == jax.tree.structure(cache2)
