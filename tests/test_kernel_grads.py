"""Gate-aware backward pass of the Pallas attention kernel.

Grad parity (dq/dk/dv) vs the reference VJP under random p_f/p_o/p_s gate
mixes, exact-zero gradients and skipped MXU work for g_b == 0 slices, and
end-to-end kernel-path fine-tuning driven by a real Schedule.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import d2ft_attention as d2a
from repro.kernels.ops import gated_attention
from repro.kernels.ref import d2ft_attention_ref, gated_attention_ref

TOL = 1e-4   # fp32, interpret mode


def _random_mix(rng, B, H):
    """ops 0=p_f, 1=p_o, 2=p_s -> (g_f, g_b) with g_b <= g_f."""
    ops_ = rng.integers(0, 3, (B, H))
    g_f = jnp.asarray((ops_ != 2).astype(np.float32))
    g_b = jnp.asarray((ops_ == 0).astype(np.float32))
    return ops_, g_f, g_b


@pytest.mark.parametrize("causal,window", [(True, 0), (True, 64), (False, 0)])
@pytest.mark.parametrize("S,hd", [(128, 64), (256, 32)])
def test_grad_parity_vs_reference_vjp(causal, window, S, hd):
    B, H = 2, 4
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    q = jax.random.normal(ks[0], (B, H, S, hd))
    k = jax.random.normal(ks[1], (B, H, S, hd))
    v = jax.random.normal(ks[2], (B, H, S, hd))
    do = jax.random.normal(ks[3], (B, H, S, hd))
    rng = np.random.default_rng(hash((causal, window, S)) % 2 ** 31)
    _, g_f, g_b = _random_mix(rng, B, H)

    out_k, vjp_k = jax.vjp(
        lambda q, k, v: gated_attention(q, k, v, g_f, g_b, causal=causal,
                                        window=window, interpret=True),
        q, k, v)
    out_r, vjp_r = jax.vjp(
        lambda q, k, v: gated_attention_ref(q, k, v, g_f, g_b, causal=causal,
                                            window=window),
        q, k, v)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                               atol=TOL, rtol=TOL)
    for name, a, b in zip(("dq", "dk", "dv"), vjp_k(do), vjp_r(do)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=TOL,
                                   rtol=TOL, err_msg=name)


def test_forward_matches_forward_only_oracle():
    """g_f drives the forward exactly like the forward-only kernel/oracle."""
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (2, 3, 128, 64))
    k = jax.random.normal(ks[1], (2, 3, 128, 64))
    v = jax.random.normal(ks[2], (2, 3, 128, 64))
    g_f = jnp.asarray([[1., 0, 1], [0, 1, 1]])
    g_b = jnp.zeros_like(g_f)
    out = gated_attention(q, k, v, g_f, g_b, interpret=True)
    ref = d2ft_attention_ref(q, k, v, g_f)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=TOL,
                               rtol=TOL)


def test_gb_zero_heads_have_exact_zero_grads():
    B, H, S, hd = 2, 4, 128, 32
    ks = jax.random.split(jax.random.PRNGKey(2), 2)
    q = jax.random.normal(ks[0], (B, H, S, hd))
    rng = np.random.default_rng(7)
    ops_, g_f, g_b = _random_mix(rng, B, H)

    def loss(q, k, v):
        return gated_attention(q, k, v, g_f, g_b, interpret=True).sum()

    dq, dk, dv = jax.grad(loss, argnums=(0, 1, 2))(q, q, q)
    gb = np.asarray(g_b)
    for g in (dq, dk, dv):
        g = np.asarray(g)
        assert np.all(g[gb == 0] == 0.0)
    # p_f heads do produce gradient signal
    assert float(np.abs(np.asarray(dq)[gb == 1]).max()) > 0.0


def test_gb_zero_slices_do_no_backward_matmul_work():
    """Counts *executed* backward compute blocks via the kernel test hook.

    Static compiled-FLOPs can't observe the skip (interpret mode lowers the
    grid to a loop whose body XLA counts once regardless of taken branches),
    so we count the blocks that actually run: all-p_f executes the full
    block set of the fused backward kernel (one hook call per tile — the
    old split dq/dkv pair fired twice), all-p_o/p_s executes none, and a
    mix executes exactly the p_f share.
    """
    B, H, S, hd = 1, 4, 256, 32
    bq = bk = 128
    q = jax.random.normal(jax.random.PRNGKey(3), (B, H, S, hd))
    count = {"n": 0}
    d2a.on_backward_block = lambda: count.__setitem__("n", count["n"] + 1)
    try:
        def run(g_b):
            def loss(q, k, v):
                return d2a.gated_flash_attention(
                    q, k, v, jnp.ones((B, H)), jnp.asarray(g_b),
                    True, 0, bq, bk, True).sum()
            count["n"] = 0
            jax.grad(loss, argnums=(0, 1, 2))(q, q, q)
            jax.effects_barrier()       # debug callbacks are async
            return count["n"]

        # causal live tiles per (b, h): 3 of 4; ONE fused backward kernel
        per_head = d2a.live_block_count(S, bq, bk, True, 0)
        assert run(np.ones((B, H), np.float32)) == B * H * per_head
        assert run(np.zeros((B, H), np.float32)) == 0
        half = np.asarray([[1., 1., 0., 0.]], np.float32)
        assert run(half) == 2 * per_head
        # the analytic accounting reports 5 matmuls per executed tile
        _, bwd_flops = d2a.gated_attention_flops(
            np.ones((B, H)), half, S, hd, causal=True, block_q=bq,
            block_k=bk)
        assert bwd_flops == 2 * per_head * 5 * (2 * bq * bk * hd)
    finally:
        d2a.on_backward_block = None


def test_awkward_seq_len_pads_and_matches():
    """S=137 (prime) takes the pad-to-tile-multiple path: forward and
    dq/dk/dv still match the reference, with zero grads in nothing real."""
    B, H, S, hd = 1, 2, 137, 32
    ks = jax.random.split(jax.random.PRNGKey(5), 4)
    q = jax.random.normal(ks[0], (B, H, S, hd))
    k = jax.random.normal(ks[1], (B, H, S, hd))
    v = jax.random.normal(ks[2], (B, H, S, hd))
    do = jax.random.normal(ks[3], (B, H, S, hd))
    g_f = jnp.asarray([[1., 1.]])
    g_b = jnp.asarray([[1., 0.]])

    out_k, vjp_k = jax.vjp(
        lambda q, k, v: gated_attention(q, k, v, g_f, g_b, interpret=True),
        q, k, v)
    out_r, vjp_r = jax.vjp(
        lambda q, k, v: gated_attention_ref(q, k, v, g_f, g_b), q, k, v)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                               atol=TOL, rtol=TOL)
    for name, a, b in zip(("dq", "dk", "dv"), vjp_k(do), vjp_r(do)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=TOL,
                                   rtol=TOL, err_msg=name)


def test_select_blocks_geometry():
    from repro.kernels.d2ft_attention import select_blocks
    assert select_blocks(256, 128, 128) == (128, 128, 256)   # exact
    assert select_blocks(5, 128, 128) == (5, 5, 5)           # tiny seq
    assert select_blocks(192, 128, 128) == (96, 96, 192)     # near divisor
    assert select_blocks(257, 128, 128) == (128, 128, 384)   # pad, no slivers


def test_gates_get_zero_cotangents():
    q = jax.random.normal(jax.random.PRNGKey(4), (1, 2, 128, 32))
    g = jnp.ones((1, 2))

    def loss(g_f, g_b):
        return gated_attention(q, q, q, g_f, g_b, interpret=True).sum()

    dgf, dgb = jax.grad(loss, argnums=(0, 1))(g, g)
    assert float(jnp.abs(dgf).max()) == 0.0
    assert float(jnp.abs(dgb).max()) == 0.0


# --------------------------------------------------------------- end to end
def _tiny_vit():
    from repro.models.vit import ViTConfig
    return ViTConfig(n_layers=2, d_model=48, n_heads=6, d_ff=96, patch=8,
                     image_size=16, n_classes=4)


def _real_schedule(cfg, B, M=5, G=None):
    from repro.configs.base import D2FTConfig
    from repro.core.d2ft import plan_schedule
    from repro.core.schedule import gates_from_schedule
    from repro.data.synthetic import microbatch_assignment
    G = G or cfg.n_heads
    rng = np.random.default_rng(0)
    d2 = D2FTConfig(n_microbatches=M, n_pf=2, n_po=1)
    K = cfg.n_layers * G
    sched = plan_schedule(d2, rng.random((K, M)) + .1, rng.random((K, M)) + .1,
                          cfg.n_layers, G)
    return sched, gates_from_schedule(sched, microbatch_assignment(B, M))


def test_vit_step_kernel_matches_masked_path():
    """One optimizer step, kernel vs masked path, same real Schedule."""
    from repro.models.vit import init_vit
    from repro.optim.optimizers import sgd
    from repro.train.loop import make_vit_step

    cfg = _tiny_vit()
    params = init_vit(jax.random.PRNGKey(0), cfg)
    B = 10
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.standard_normal((B, 16, 16, 3)).astype(np.float32))
    y = jnp.asarray(rng.integers(0, 4, B))
    _, gates = _real_schedule(cfg, B)
    opt = sgd(0.05)

    out = {}
    for uk in (False, True):
        step = jax.jit(make_vit_step(cfg, opt, True, use_kernel=uk))
        p2, _, metrics = step(params, opt.init(params), x, y, gates)
        out[uk] = (p2, metrics)
    assert abs(float(out[False][1]["loss"]) -
               float(out[True][1]["loss"])) < 1e-5
    diffs = jax.tree.map(lambda a, b: float(jnp.abs(a - b).max()),
                         out[False][0], out[True][0])
    assert max(jax.tree.leaves(diffs)) < TOL


def test_finetune_vit_kernel_end_to_end():
    """The fine-tune loop runs with use_kernel=True driven by a Schedule."""
    from repro.models.vit import init_vit
    from repro.optim.optimizers import sgd
    from repro.train.loop import finetune_vit

    cfg = _tiny_vit()
    params = init_vit(jax.random.PRNGKey(0), cfg)
    B, M = 10, 5
    rng = np.random.default_rng(2)
    batches = [(rng.standard_normal((B, 16, 16, 3)).astype(np.float32),
                rng.integers(0, 4, B)) for _ in range(2)]
    sched, _ = _real_schedule(cfg, B, M)

    params, _, log = finetune_vit(
        params, cfg, sgd(0.05), iter(batches), steps=2,
        schedule_fn=lambda i, p, im, lb: sched if i == 0 else None,
        n_microbatches=M, use_kernel=True)
    assert len(log.losses) == 2
    assert all(np.isfinite(l) for l in log.losses)


def test_llm_loss_kernel_matches_masked_path():
    """GQA + global/local pattern through lm_loss, kernel vs masked."""
    from repro.configs.base import ATTN_GLOBAL, ATTN_LOCAL, ModelConfig
    from repro.models.transformer import init_model, lm_loss

    cfg = ModelConfig(name="t", arch_type="dense", n_layers=2, d_model=64,
                      n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=97,
                      block_pattern=(ATTN_GLOBAL, ATTN_LOCAL), window=16)
    params = init_model(jax.random.PRNGKey(0), cfg)
    B, S, G = 4, 64, 4
    rng = np.random.default_rng(3)
    toks = jnp.asarray(rng.integers(0, 97, (B, S)))
    labels = jnp.asarray(rng.integers(0, 97, (B, S)))
    ops_ = rng.integers(0, 3, (cfg.n_layers, B, G))
    gates = (jnp.asarray((ops_ != 2).astype(np.float32)),
             jnp.asarray((ops_ == 0).astype(np.float32)))

    out = {}
    for uk in (False, True):
        loss, grads = jax.value_and_grad(
            lambda p: lm_loss(p, cfg, toks, labels, gates=gates,
                              use_kernel=uk)[0])(params)
        out[uk] = (float(loss), grads)
    assert abs(out[False][0] - out[True][0]) < 1e-5
    diffs = jax.tree.map(lambda a, b: float(jnp.abs(a - b).max()),
                         out[False][1], out[True][1])
    assert max(jax.tree.leaves(diffs)) < 5e-4
