"""LoRA: zero-init identity, adapter-only gradients, D2FT-LoRA gating,
fused kernel == merge-then-matmul."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.core.lora import init_lora, lora_param_count, merge_lora
from repro.kernels.ops import lora_linear
from repro.models.transformer import forward, init_model, lm_loss

CFG = ModelConfig(name="t", arch_type="dense", n_layers=4, d_model=64,
                  n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=97)


def test_lora_zero_init_is_identity():
    params = init_model(jax.random.PRNGKey(0), CFG)
    lora = init_lora(jax.random.PRNGKey(1), params, rank=4)
    toks = jax.random.randint(jax.random.PRNGKey(2), (2, 8), 0, 97)
    l0, _ = forward(params, CFG, tokens=toks)
    l1, _ = forward(merge_lora(params, lora, 2.0), CFG, tokens=toks)
    np.testing.assert_allclose(np.asarray(l0), np.asarray(l1), atol=1e-6)


def test_lora_grads_only_in_adapters():
    params = init_model(jax.random.PRNGKey(0), CFG)
    lora = init_lora(jax.random.PRNGKey(1), params, rank=4)
    toks = jax.random.randint(jax.random.PRNGKey(2), (4, 8), 0, 97)

    def loss(lr):
        return lm_loss(merge_lora(params, lr, 1.0), CFG, toks, toks)[0]

    grads = jax.grad(loss)(lora)
    gn = sum(float(jnp.abs(g).sum()) for g in jax.tree.leaves(grads))
    assert gn > 0
    # stacked adapters follow the scan-cycle leading dims
    for entry in lora.values():
        assert entry["a"].shape[:-2] == entry["b"].shape[:-2]


def test_d2ft_lora_gating_blocks_adapter_grads():
    params = init_model(jax.random.PRNGKey(0), CFG)
    lora = init_lora(jax.random.PRNGKey(1), params, rank=4)
    # make adapters non-trivial so gating has something to cut
    lora = jax.tree.map(lambda a: a + 0.01, lora)
    toks = jax.random.randint(jax.random.PRNGKey(2), (10, 8), 0, 97)
    L, B, G = 4, 10, 4
    g_f = jnp.ones((L, B, G))
    g_b = jnp.zeros((L, B, G))        # everything forward-only

    def loss(lr):
        merged = merge_lora(params, lr, 1.0)
        return lm_loss(merged, CFG, toks, toks, gates=(g_f, g_b))[0]

    grads = jax.grad(loss)(lora)
    gn = max(float(jnp.abs(g).max()) for g in jax.tree.leaves(grads))
    assert gn < 1e-12                  # p_o => no adapter updates


def test_fused_kernel_matches_merge():
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    x = jax.random.normal(ks[0], (128, 64))
    w = jax.random.normal(ks[1], (64, 128))
    a = jax.random.normal(ks[2], (64, 8))
    b = jax.random.normal(ks[3], (8, 128))
    fused = lora_linear(x, w, a, b, 0.7, block_m=128, block_n=128)
    merged = x @ (w + 0.7 * a @ b)
    np.testing.assert_allclose(np.asarray(fused), np.asarray(merged),
                               atol=1e-4, rtol=1e-4)


def test_lora_param_count():
    params = init_model(jax.random.PRNGKey(0), CFG)
    lora = init_lora(jax.random.PRNGKey(1), params, rank=4)
    # cycles stacked: wq [4, 64, 64] -> a [4, 64, 4], b [4, 4, 64];
    # wk/wv [4, 64, 32] -> a [4, 64, 4], b [4, 4, 32]
    assert lora_param_count(lora) == 4 * (64 * 4 + 4 * 64) + \
        2 * 4 * (64 * 4 + 4 * 32)
