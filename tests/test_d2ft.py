"""D2FT operation semantics: masked path gating, packed == masked, p_o
kills subnet gradients, p_s kills subnet contribution; scores."""
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import D2FTConfig, ModelConfig
from repro.core.d2ft import packed_forward, plan_schedule
from repro.core.schedule import (P_F, P_O, P_S, Schedule,
                                 gates_from_schedule, packed_indices)
from repro.core.scores import (compute_scores, transformer_blocks,
                               weight_magnitude)
from repro.models.transformer import forward, init_model, lm_loss

CFG = ModelConfig(name="t", arch_type="dense", n_layers=4, d_model=64,
                  n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=97)


def _setup(seed=0, B=10, S=16):
    params = init_model(jax.random.PRNGKey(seed), CFG)
    toks = jax.random.randint(jax.random.PRNGKey(seed + 1), (B, S), 0, 97)
    return params, toks


def _schedule(seed=0, L=4, G=4, N=5, n_pf=3, n_po=1):
    rng = np.random.default_rng(seed)
    d2 = D2FTConfig(n_microbatches=N, n_pf=n_pf, n_po=n_po)
    bw = np.repeat(rng.random((L * G, 1)) + .1, N, 1)
    fw = rng.random((L * G, N)) + .1
    return plan_schedule(d2, bw, fw, L, G)


def test_all_pf_gates_equal_ungated():
    params, toks = _setup()
    ones = jnp.ones((4, 10, 4))
    l0, _ = forward(params, CFG, tokens=toks)
    l1, _ = forward(params, CFG, tokens=toks, gates=(ones, ones))
    np.testing.assert_allclose(np.asarray(l0), np.asarray(l1), atol=1e-5)


def test_masked_equals_packed_forward_and_grad():
    params, toks = _setup()
    sched = _schedule()
    mb_of = np.repeat(np.arange(5), 2)
    gates = gates_from_schedule(sched, mb_of)
    idx, bwd, val, _ = packed_indices(sched, mb_of)
    sched_arrays = tuple(map(jnp.asarray, (idx, bwd, val)))

    lm, _ = forward(params, CFG, tokens=toks, gates=gates)
    lp, _ = packed_forward(params, CFG, toks, sched_arrays)
    np.testing.assert_allclose(np.asarray(lm), np.asarray(lp), atol=2e-5,
                               rtol=1e-4)

    def loss_masked(p):
        return jnp.mean(forward(p, CFG, tokens=toks, gates=gates)[0] ** 2)

    def loss_packed(p):
        return jnp.mean(packed_forward(p, CFG, toks, sched_arrays)[0] ** 2)

    gm = jax.grad(loss_masked)(params)
    gp = jax.grad(loss_packed)(params)
    for a, b in zip(jax.tree.leaves(gm), jax.tree.leaves(gp)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5,
                                   rtol=1e-3)


def test_po_blocks_param_grads_and_ps_blocks_contribution():
    params, toks = _setup()
    L, B, G = 4, 10, 4
    # all p_o: forward equals full, but no gradient reaches block params
    g_f = jnp.ones((L, B, G))
    g_b = jnp.zeros((L, B, G))
    full, _ = forward(params, CFG, tokens=toks)
    po, _ = forward(params, CFG, tokens=toks, gates=(g_f, g_b))
    np.testing.assert_allclose(np.asarray(full), np.asarray(po), atol=1e-5)

    def loss(p, gates):
        return jnp.mean(forward(p, CFG, tokens=toks, gates=gates)[0] ** 2)

    grads = jax.grad(loss)(params, (g_f, g_b))
    block_grads = jax.tree.leaves(grads["cycles"])
    assert all(float(jnp.abs(g).max()) < 1e-12 for g in block_grads)

    # all p_s: block contribution removed -> logits == embedding-only model
    zero, _ = forward(params, CFG, tokens=toks,
                      gates=(jnp.zeros((L, B, G)), g_b))
    from repro.models.layers import apply_embedding, apply_norm
    x = apply_embedding(params["embed"], toks)
    x = apply_norm(params["final_norm"], x, CFG.norm)
    skip_logits = x @ params["unembed"]
    np.testing.assert_allclose(np.asarray(zero), np.asarray(skip_logits),
                               atol=1e-5)


def test_scores_shapes_and_variation():
    params, toks = _setup()
    mbs = [dict(tokens=toks[i * 2:(i + 1) * 2]) for i in range(5)]

    def loss_fn(p, mb):
        return lm_loss(p, CFG, mb["tokens"], mb["tokens"])[0]

    bw, fw = compute_scores(loss_fn, params,
                            lambda t: transformer_blocks(t, CFG), mbs, G=4)
    assert bw.shape == (16, 5) and fw.shape == (16, 5)
    assert np.allclose(bw, bw[:, :1])          # magnitude: mb-independent
    assert not np.allclose(fw, fw[:, :1])      # fisher: mb-dependent
    assert (bw > 0).all() and (fw >= 0).all()


def test_heterogeneous_capacities():
    """Paper §IV-D: per-device capacities; fast devices get more p_f."""
    rng = np.random.default_rng(0)
    L, G, N = 2, 4, 5
    d2 = D2FTConfig(n_microbatches=N, n_pf=2, n_po=2)
    bw = np.repeat(rng.random((L * G, 1)) + .1, N, 1)
    fw = rng.random((L * G, N)) + .1
    cap_pf = np.full(L * G, 2.0)
    cap_pf[:4] = 3.0                            # 4 "fast" devices
    sched = plan_schedule(d2, bw, fw, L, G, cap_pf=cap_pf, cap_po=0.8)
    per_dev_pf = (sched.table == P_F).sum(1)
    assert (per_dev_pf[:4] == 3).all() and (per_dev_pf[4:] == 2).all()


def test_mb_packed_equals_masked():
    """Micro-batch-axis packed path (deployment form, p_f/p_o split with
    backward DCE) == masked reference, values and gradients."""
    from repro.core.d2ft import mb_packed_indices, packed_forward_mb
    params, toks = _setup(B=12)
    M = 4
    sched = _schedule(N=M, n_pf=2, n_po=1)
    mb_of = np.repeat(np.arange(M), 12 // M)
    gates = gates_from_schedule(sched, mb_of)
    idx, bwd, val = mb_packed_indices(sched, M)
    arrays = tuple(map(jnp.asarray, (idx, bwd, val)))

    lm, _ = forward(params, CFG, tokens=toks, gates=gates)
    lp, _ = packed_forward_mb(params, CFG, toks, arrays, M)
    np.testing.assert_allclose(np.asarray(lm), np.asarray(lp), atol=2e-5,
                               rtol=1e-4)

    gm = jax.grad(lambda p: jnp.mean(
        forward(p, CFG, tokens=toks, gates=gates)[0] ** 2))(params)
    gp = jax.grad(lambda p: jnp.mean(
        packed_forward_mb(p, CFG, toks, arrays, M)[0] ** 2))(params)
    for a, b in zip(jax.tree.leaves(gm), jax.tree.leaves(gp)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=2e-5,
                                   rtol=1e-3)
