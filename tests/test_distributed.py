"""Distributed D2FT execution: the schedule-masked gradient sync plan
(sharding/sync.py), the shard_map step's byte accounting, and an
8-host-device parity run in a subprocess (this process is pinned to one
CPU device by conftest, and jax locks the device count at first init)."""
import os
import subprocess
import sys

import numpy as np

import jax

from repro.configs.base import ModelConfig
from repro.core.schedule import P_F, P_O, P_S, Schedule
from repro.launch.diststep import all_pf_schedule, paper_mix_schedule
from repro.models.transformer import init_model
from repro.sharding.sync import (SyncSpec, apply_grad_sync,
                                 backward_live_groups, grad_sync_plan,
                                 sync_byte_report)

CFG = ModelConfig(name="sync", arch_type="dense", n_layers=4, d_model=64,
                  n_heads=4, n_kv_heads=4, d_ff=128, vocab_size=256)
L, G, N = 4, 4, 8


def _params():
    return init_model(jax.random.PRNGKey(0), CFG)


def _mixed_schedule():
    rng = np.random.default_rng(0)
    table = rng.choice([P_F, P_O, P_S], size=(L * G, N),
                       p=[.4, .3, .3]).astype(np.int8)
    table[0:G] = P_O                       # layer 0: forward-only everywhere
    table[2 * G:3 * G] = P_F               # layer 2: fully live
    return Schedule(table, L, G)


def test_backward_live_groups():
    sched = _mixed_schedule()
    live = backward_live_groups(sched)
    assert live.shape == (L, G)
    assert not live[0].any() and live[2].all()


def test_plan_modes_and_protected_leaves():
    params = _params()
    plan = grad_sync_plan(params, CFG, _mixed_schedule())
    # loss-path leaves never skip
    assert plan["embed"]["table"].mode == "all"
    assert all(s.mode == "all" for s in jax.tree.leaves(
        plan["final_norm"], is_leaf=lambda x: isinstance(x, SyncSpec)))
    # the 4 layers are scan-stacked at pattern position 0 with differing
    # liveness, so attention weights get per-cycle specs
    wq = plan["cycles"][0]["attn"]["wq"]
    assert wq.mode == "stacked" and len(wq.per_cycle) == 4
    assert wq.per_cycle[0].mode == "none"          # layer 0: p_o only
    assert wq.per_cycle[2].mode == "all"           # layer 2: fully live
    assert wq.per_cycle[1].mode in ("sliced", "all", "none")


def test_plan_all_pf_is_full_sync():
    params = _params()
    plan = grad_sync_plan(params, CFG, all_pf_schedule(L, G, N))
    assert all(s.mode == "all" for s in jax.tree.leaves(
        plan, is_leaf=lambda x: isinstance(x, SyncSpec)))
    assert sync_byte_report(plan, params)["fraction"] == 1.0


def test_sync_bytes_paper_mix_under_target():
    params = _params()
    sched = paper_mix_schedule(L, G, N, (0.4, 0.3, 0.3), seed=0)
    rep = sync_byte_report(grad_sync_plan(params, CFG, sched), params)
    assert rep["fraction"] <= 0.60, rep
    assert rep["n_skipped"] + rep["n_sliced"] > 0


def test_sync_bytes_all_ps_only_loss_path():
    params = _params()
    sched = Schedule(np.full((L * G, N), P_S, np.int8), L, G)
    rep = sync_byte_report(grad_sync_plan(params, CFG, sched), params)
    # only embed/unembed/final_norm stay synced
    assert 0.0 < rep["fraction"] < 0.35
    assert rep["n_skipped"] > 0


def test_apply_grad_sync_structure_single_device():
    """On a 1-device mesh pmean is the identity, so applying the plan must
    return every leaf (incl. sliced/stacked reassembly) bit-identical."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import Mesh, PartitionSpec as P

    params = _params()
    plan = grad_sync_plan(params, CFG, _mixed_schedule())
    fake_grads = jax.tree.map(
        lambda a: jax.random.normal(jax.random.PRNGKey(1), a.shape), params)
    mesh = Mesh(np.asarray(jax.devices()[:1]), ("data",))
    out = jax.jit(shard_map(
        lambda g: apply_grad_sync(g, plan, "data"), mesh=mesh,
        in_specs=(P(),), out_specs=P(), check_rep=False))(fake_grads)
    for a, b in zip(jax.tree.leaves(fake_grads), jax.tree.leaves(out)):
        assert a.shape == b.shape
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_distributed_parity_8dev_subprocess():
    """Acceptance: 8-host-device shard_map step == single-device gated step
    (masked and compacted-kernel paths) and paper-mix all-reduce bytes at
    <= 60% of the all-p_f baseline. Runs in a fresh interpreter because the
    host-device count must be set before jax initializes."""
    script = os.path.join(os.path.dirname(__file__), "_dist_parity.py")
    env = dict(os.environ,
               PYTHONPATH=os.pathsep.join(
                   [os.path.join(os.path.dirname(__file__), "..", "src")]
                   + ([os.environ["PYTHONPATH"]]
                      if "PYTHONPATH" in os.environ else [])))
    proc = subprocess.run([sys.executable, script], env=env,
                          capture_output=True, text=True, timeout=1500)
    assert proc.returncode == 0, f"stdout:\n{proc.stdout}\nstderr:\n{proc.stderr}"
    assert "PARITY_OK" in proc.stdout, proc.stdout
