"""Distributed D2FT execution: the schedule-masked gradient sync plan
(sharding/sync.py), the shard_map step's byte accounting, and an
8-host-device parity run in a subprocess (this process is pinned to one
CPU device by conftest, and jax locks the device count at first init)."""
import os
import subprocess
import sys

import numpy as np
import pytest

import jax

from repro.configs.base import ModelConfig
from repro.core.schedule import P_F, P_O, P_S, Schedule
from repro.launch.diststep import (all_pf_schedule, paper_mix_schedule,
                                   uniform_half_schedule)
from repro.models.transformer import init_model
from repro.sharding.sync import (SyncSpec, apply_grad_sync,
                                 backward_live_groups, forward_live_groups,
                                 grad_sync_plan, sync_byte_report,
                                 zero3_param_byte_report, zero_reshard,
                                 zero_state_byte_report)

CFG = ModelConfig(name="sync", arch_type="dense", n_layers=4, d_model=64,
                  n_heads=4, n_kv_heads=4, d_ff=128, vocab_size=256)
L, G, N = 4, 4, 8


def _params():
    return init_model(jax.random.PRNGKey(0), CFG)


def _mixed_schedule():
    rng = np.random.default_rng(0)
    table = rng.choice([P_F, P_O, P_S], size=(L * G, N),
                       p=[.4, .3, .3]).astype(np.int8)
    table[0:G] = P_O                       # layer 0: forward-only everywhere
    table[2 * G:3 * G] = P_F               # layer 2: fully live
    return Schedule(table, L, G)


def test_backward_live_groups():
    sched = _mixed_schedule()
    live = backward_live_groups(sched)
    assert live.shape == (L, G)
    assert not live[0].any() and live[2].all()


def test_plan_modes_and_protected_leaves():
    params = _params()
    plan = grad_sync_plan(params, CFG, _mixed_schedule())
    # loss-path leaves never skip
    assert plan["embed"]["table"].mode == "all"
    assert all(s.mode == "all" for s in jax.tree.leaves(
        plan["final_norm"], is_leaf=lambda x: isinstance(x, SyncSpec)))
    # the 4 layers are scan-stacked at pattern position 0 with differing
    # liveness, so attention weights get per-cycle specs
    wq = plan["cycles"][0]["attn"]["wq"]
    assert wq.mode == "stacked" and len(wq.per_cycle) == 4
    assert wq.per_cycle[0].mode == "none"          # layer 0: p_o only
    assert wq.per_cycle[2].mode == "all"           # layer 2: fully live
    assert wq.per_cycle[1].mode in ("sliced", "all", "none")


def test_plan_all_pf_is_full_sync():
    params = _params()
    plan = grad_sync_plan(params, CFG, all_pf_schedule(L, G, N))
    assert all(s.mode == "all" for s in jax.tree.leaves(
        plan, is_leaf=lambda x: isinstance(x, SyncSpec)))
    assert sync_byte_report(plan, params)["fraction"] == 1.0


def test_sync_bytes_paper_mix_under_target():
    params = _params()
    sched = paper_mix_schedule(L, G, N, (0.4, 0.3, 0.3), seed=0)
    rep = sync_byte_report(grad_sync_plan(params, CFG, sched), params)
    assert rep["fraction"] <= 0.60, rep
    assert rep["n_skipped"] + rep["n_sliced"] > 0


def test_sync_bytes_all_ps_only_loss_path():
    params = _params()
    sched = Schedule(np.full((L * G, N), P_S, np.int8), L, G)
    rep = sync_byte_report(grad_sync_plan(params, CFG, sched), params)
    # only embed/unembed/final_norm stay synced
    assert 0.0 < rep["fraction"] < 0.35
    assert rep["n_skipped"] > 0


def test_apply_grad_sync_structure_single_device():
    """On a 1-device mesh pmean is the identity, so applying the plan must
    return every leaf (incl. sliced/stacked reassembly) bit-identical."""
    from jax.experimental.shard_map import shard_map
    from jax.sharding import Mesh, PartitionSpec as P

    params = _params()
    plan = grad_sync_plan(params, CFG, _mixed_schedule())
    fake_grads = jax.tree.map(
        lambda a: jax.random.normal(jax.random.PRNGKey(1), a.shape), params)
    mesh = Mesh(np.asarray(jax.devices()[:1]), ("data",))
    out = jax.jit(shard_map(
        lambda g: apply_grad_sync(g, plan, "data"), mesh=mesh,
        in_specs=(P(),), out_specs=P(), check_rep=False))(fake_grads)
    for a, b in zip(jax.tree.leaves(fake_grads), jax.tree.leaves(out)):
        assert a.shape == b.shape
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# -------------------------------------------------------- HLO byte parser
def test_collective_bytes_group_size_forms():
    """collective_bytes reads the group size from explicit-list, iota and
    async-pair (`-start` carries the attribute, `-done` the array shape)
    prints, and falls back to default_group_size on the empty print."""
    from repro.launch.hlo import collective_bytes

    explicit = ("%ar = f32[100]{0} all-reduce(f32[100] %x), channel_id=1, "
                "replica_groups={{0,1,2,3,4,5,6,7}}, to_apply=%sum")
    iota = ("%rs = f32[100]{0} reduce-scatter(f32[800] %x), channel_id=2, "
            "replica_groups=[1,8]<=[8], dimensions={0}, to_apply=%sum")
    async_pair = (
        "%ag-start = (f32[100], f32[800]) all-gather-start(f32[100] %x), "
        "channel_id=3, replica_groups={{0,1,2,3,4,5,6,7}}, dimensions={0}\n"
        "%ag-done = f32[800]{0} all-gather-done("
        "(f32[100], f32[800]) %ag-start), channel_id=3")
    empty = ("%ar2 = f32[100]{0} all-reduce(f32[100] %x), channel_id=4, "
             "replica_groups={}, to_apply=%sum")
    got = collective_bytes("\n".join([explicit, iota, async_pair]))
    assert got["all-reduce"] == pytest.approx(2 * 7 / 8 * 400)
    assert got["reduce-scatter"] == pytest.approx(7 * 400)
    assert got["all-gather"] == pytest.approx(7 / 8 * 3200)
    assert collective_bytes(empty, default_group_size=8)["all-reduce"] \
        == pytest.approx(2 * 7 / 8 * 400)


# ------------------------------------------------------------- ZeRO plans
def _spec_leaves(plan):
    return jax.tree.leaves(plan, is_leaf=lambda x: isinstance(x, SyncSpec))


def test_zero_plan_modes_and_masks():
    params = _params()
    plan = grad_sync_plan(params, CFG, _mixed_schedule(), mode="zero",
                          n_shards=8)
    specs = _spec_leaves(plan)
    assert all(isinstance(s, SyncSpec) for s in specs)
    # every leaf of this config splits evenly over 8 shards -> all zero
    assert all(s.mode in ("zero", "zero_stacked") for s in specs)
    # loss-path leaves: fully scattered and gathered
    emb = plan["embed"]["table"]
    assert emb.mode == "zero" and all(emb.live) and all(emb.gather)
    # stacked attention weights: per-cycle masks follow the layers
    wq = plan["cycles"][0]["attn"]["wq"]
    assert wq.mode == "zero_stacked" and len(wq.per_cycle) == 4
    assert not any(wq.per_cycle[0].live)      # layer 0: p_o only, no scatter
    assert all(wq.per_cycle[2].live)          # layer 2: fully live
    # gather mask covers the scatter mask everywhere
    for s in specs:
        for sub in (s.per_cycle or (s,)):
            if sub.mode == "zero":
                assert all(g or not l
                           for l, g in zip(sub.live, sub.gather))


def test_zero_plan_ever_live_and_decay_force_gather():
    params = _params()
    sched = _mixed_schedule()
    # a group that was live under an earlier plan keeps its gather bit even
    # when now dead (its moments may be non-zero)
    ever = np.ones((L, G), bool)
    plan = grad_sync_plan(params, CFG, sched, mode="zero", n_shards=8,
                          ever_live=ever)
    for s in _spec_leaves(plan):
        for sub in (s.per_cycle or (s,)):
            if sub.mode == "zero":
                assert all(sub.gather), sub
    # a non-elidable optimizer (weight decay) forces the same dense gather
    plan = grad_sync_plan(params, CFG, sched, mode="zero", n_shards=8,
                          elide_gather=False)
    for s in _spec_leaves(plan):
        for sub in (s.per_cycle or (s,)):
            if sub.mode == "zero":
                assert all(sub.gather), sub


def test_zero_plan_indivisible_falls_back_to_masked():
    """n_shards that divides no axis degrades every leaf to its masked
    spec (replicated moments, pmean sync) — never a crash."""
    params = _params()
    sched = _mixed_schedule()
    plan7 = grad_sync_plan(params, CFG, sched, mode="zero", n_shards=7)
    masked = grad_sync_plan(params, CFG, sched)
    assert plan7 == masked


def test_zero_wire_model_matches_masked_psum():
    """Ring physics: reduce-scatter + all-gather of the live runs costs
    exactly what the masked all-reduce of the same runs costs."""
    params = _params()
    for sched in (_mixed_schedule(),
                  paper_mix_schedule(L, G, N, (0.4, 0.3, 0.3), 0),
                  uniform_half_schedule(L, G, N)):
        masked = sync_byte_report(grad_sync_plan(params, CFG, sched),
                                  params, n_shards=8)
        zero = sync_byte_report(
            grad_sync_plan(params, CFG, sched, mode="zero", n_shards=8),
            params, n_shards=8)
        assert zero["wire"]["total"] == \
            pytest.approx(masked["wire"]["total"], rel=1e-9)
        assert zero["fraction"] == pytest.approx(masked["fraction"],
                                                 rel=1e-9)


def test_uniform_half_schedule_no_whole_subnet_elision():
    """The uniformly spread 50%-live schedule: every layer partially live,
    so the masked plan's whole-subnet elision (`none`) never fires yet the
    sliced/zero run masks still price below the full sync."""
    params = _params()
    sched = uniform_half_schedule(L, G, N)
    live = backward_live_groups(sched)
    assert live.any(axis=1).all() and not live.all(axis=1).any()
    rep = sync_byte_report(grad_sync_plan(params, CFG, sched), params)
    assert rep["n_skipped"] == 0
    assert rep["fraction"] < 1.0


def test_zero_state_memory_fraction():
    """Acceptance: per-device optimizer-moment bytes under the ZeRO
    partition are <= 1/n_devices + slack of the replicated baseline."""
    params = _params()
    for sched in (paper_mix_schedule(L, G, N, (0.4, 0.3, 0.3), 0),
                  all_pf_schedule(L, G, N)):
        plan = grad_sync_plan(params, CFG, sched, mode="zero", n_shards=8)
        rep = zero_state_byte_report(plan, params, 8, n_moments=2)
        assert rep["fraction"] <= 1.0 / 8 + 0.05, rep
        assert rep["n_partitioned"] > 0
        # doubling the moment copies (adam m+v vs sgd mu) scales both sides
        assert rep["replicated_bytes"] == pytest.approx(
            2 * zero_state_byte_report(plan, params, 8)["replicated_bytes"])


# ------------------------------------------------------------ ZeRO-3 plans
def _ps_row_schedule():
    """Mixed schedule with a known p_s-everywhere subnet: layer 3 group 1
    is frozen on every micro-batch (forward-dead), layer 0 is p_o-only
    (forward-live, backward-dead), layer 2 fully live."""
    sched = _mixed_schedule()
    table = sched.table.copy()
    table[3 * G + 1] = P_S
    return Schedule(table, L, G)


def test_zero3_gather_mask_is_forward_liveness():
    params = _params()
    sched = _ps_row_schedule()
    fwd, live = forward_live_groups(sched), backward_live_groups(sched)
    assert fwd[0].all() and not live[0].any()     # p_o: fwd yes, bwd no
    assert not fwd[3, 1]                          # p_s everywhere: dead
    plan = grad_sync_plan(params, CFG, sched, mode="zero3", n_shards=8)
    wq = plan["cycles"][0]["attn"]["wq"]
    # layer 0 (p_o everywhere): nothing to scatter, everything to gather —
    # the zero-1 plan would elide this gather, zero3 cannot (forward needs
    # the values), which is exactly the semantic difference between them
    per0 = wq.per_cycle[0] if wq.mode == "zero_stacked" else wq
    assert not any(per0.live) and all(per0.gather)
    # layer 3 group 1 (p_s everywhere): the gather is elided
    per3 = wq.per_cycle[3] if wq.mode == "zero_stacked" else wq
    assert not per3.gather[1] and not per3.live[1]
    # gather covers scatter on every leaf
    for s in jax.tree.leaves(plan, is_leaf=lambda x: isinstance(x, SyncSpec)):
        for sub in (s.per_cycle or (s,)):
            if sub.mode == "zero":
                assert all(g or not l for l, g in zip(sub.live, sub.gather))
    # zero3 ignores ever_live and elide_gather: staleness cannot arise when
    # the owned shards are the persistent state
    same = grad_sync_plan(params, CFG, sched, mode="zero3", n_shards=8,
                          ever_live=np.ones((L, G), bool),
                          elide_gather=False)
    assert same == plan


def test_zero3_param_residency_report():
    """Acceptance numbers of the residency-window model: elision fires on
    the concentrated paper-mix and peak residency is <= 0.5x replicated;
    the all-p_f schedule elides nothing but still beats replication (the
    streaming window holds one block at a time)."""
    params = _params()
    sched = paper_mix_schedule(L, G, N, (0.4, 0.3, 0.3), 0)
    plan = grad_sync_plan(params, CFG, sched, mode="zero3", n_shards=8)
    rep = zero3_param_byte_report(plan, params, 8)
    assert rep["n_gather_elided"] > 0, rep
    assert rep["fraction"] <= 0.5, rep
    assert rep["per_device_peak_bytes"] == pytest.approx(
        rep["shard_bytes"] + rep["fallback_bytes"] + rep["peak_unit_bytes"])
    assert rep["gathered_bytes"] + rep["elided_bytes"] <= \
        rep["replicated_bytes"] + 1e-6
    plan_f = grad_sync_plan(params, CFG, all_pf_schedule(L, G, N),
                            mode="zero3", n_shards=8)
    rep_f = zero3_param_byte_report(plan_f, params, 8)
    assert rep_f["n_gather_elided"] == 0
    assert rep_f["elided_bytes"] == 0.0
    assert rep_f["fraction"] < 1.0
    # elision only shrinks the window
    assert rep["fraction"] <= rep_f["fraction"] + 1e-9


def test_zero3_wire_adds_forward_gather_honestly():
    """zero3's synced-byte fraction must EXCEED the zero-1 fraction on the
    paper-mix (it gathers forward-live runs every step, zero-1 only
    backward-live ones) — the byte model must not hide the cost that buys
    the sharded residency."""
    params = _params()
    sched = paper_mix_schedule(L, G, N, (0.4, 0.3, 0.3), 0)
    z1 = sync_byte_report(grad_sync_plan(params, CFG, sched, mode="zero",
                                         n_shards=8), params, n_shards=8)
    z3 = sync_byte_report(grad_sync_plan(params, CFG, sched, mode="zero3",
                                         n_shards=8), params, n_shards=8)
    assert z3["ag_bytes"] > z1["ag_bytes"]
    assert z3["fraction"] > z1["fraction"]
    assert z3["rs_bytes"] == pytest.approx(z1["rs_bytes"])


def test_zero_reshard_roundtrip_and_cross_plan():
    """Shard-layout -> canonical -> shard-layout is exact, and resharding
    between two different plans preserves every element (pure
    permutations)."""
    params = _params()
    plan_a = grad_sync_plan(params, CFG, _mixed_schedule(), mode="zero",
                            n_shards=8)
    plan_b = grad_sync_plan(params, CFG,
                            paper_mix_schedule(L, G, N, (0.4, 0.3, 0.3), 0),
                            mode="zero", n_shards=8)
    tree = jax.tree.map(
        lambda a: jax.random.normal(jax.random.PRNGKey(1), a.shape), params)
    canon = zero_reshard(tree, plan_a, None)
    back = zero_reshard(canon, None, plan_a)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    crossed = zero_reshard(zero_reshard(tree, plan_a, plan_b), plan_b,
                           plan_a)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(crossed)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # permutation property: sorted content identical in any layout
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(canon)):
        np.testing.assert_array_equal(np.sort(np.asarray(a), axis=None),
                                      np.sort(np.asarray(b), axis=None))


def test_paper_mix_costs_stay_seed_dependent():
    """Guard for the invariant the assigner regression test below rests
    on: the concentrated paper-mix must keep a seed-dependent
    per-micro-batch cost vector even when the p_o budget divides into
    whole rows (no natural partial row). K=20, n_mb=16 hits exactly that:
    round(0.3*20*16) = 96 = 6 full rows."""
    from repro.core.assignment import microbatch_costs
    for L_, G_, n_mb in [(5, 4, 16), (4, 4, 16), (4, 4, 8)]:
        costs = [microbatch_costs(paper_mix_schedule(
            L_, G_, n_mb, (0.4, 0.3, 0.3), seed=seed)) for seed in (0, 3)]
        assert not np.array_equal(costs[0], costs[1]), (L_, G_, n_mb)
        # mix preserved by the partial-row spill
        t = paper_mix_schedule(L_, G_, n_mb, (0.4, 0.3, 0.3), seed=0).table
        assert (t == P_O).sum() == round(0.3 * L_ * G_ * n_mb)


# ------------------------------------------ refresh re-planning regression
def test_assignment_changes_with_schedule():
    """Regression (ROADMAP "keeps one assignment"): the knapsack assigner
    must be re-run per schedule refresh — different schedules produce
    different micro-batch placements."""
    from repro.core.assignment import plan_device_assignment
    a1, _ = plan_device_assignment(
        paper_mix_schedule(L, G, 16, (0.4, 0.3, 0.3), seed=0), 4)
    a2, _ = plan_device_assignment(
        paper_mix_schedule(L, G, 16, (0.4, 0.3, 0.3), seed=3), 4)
    assert not np.array_equal(a1.device_of, a2.device_of), \
        "re-assignment is a no-op for a changed schedule"
    # determinism: replanning the same schedule is a no-op
    a3, _ = plan_device_assignment(
        paper_mix_schedule(L, G, 16, (0.4, 0.3, 0.3), seed=0), 4)
    assert np.array_equal(a1.device_of, a3.device_of)


def test_finetune_distributed_replans_per_refresh():
    """finetune_distributed(refresh_every=k) re-plans schedule AND device
    assignment every k steps (one refresh record per replan, each carrying
    a fresh assignment), in all three sync modes. The zero3 arm also pins
    the params layout contract: shard layout inside the loop (reshard per
    refresh), canonical order on return."""
    from repro.configs.base import D2FTConfig
    from repro.data.synthetic import lm_batches
    from repro.launch.mesh import make_data_mesh
    from repro.optim.optimizers import sgd
    from repro.train.loop import finetune_distributed

    cfg = ModelConfig(name="refresh", arch_type="dense", n_layers=2,
                      d_model=32, n_heads=4, n_kv_heads=4, d_ff=64,
                      vocab_size=128)
    d2 = D2FTConfig(n_microbatches=4, n_pf=2, n_po=1,
                    head_groups=cfg.n_heads)
    mesh = make_data_mesh(1)
    finals = {}
    for sync_mode in ("masked", "zero", "zero3"):
        params = init_model(jax.random.PRNGKey(0), cfg)
        batches = lm_batches(0, cfg.vocab_size, 8, 8, 5)
        p, _, log = finetune_distributed(
            params, cfg, d2, sgd(1e-2), batches, steps=5, mesh=mesh,
            sync_mode=sync_mode, refresh_every=2)
        refreshes = log.extras["refreshes"]
        assert [r["step"] for r in refreshes] == [0, 2, 4]
        for r in refreshes:
            assert len(r["device_of"]) == d2.n_microbatches
            assert "rebalance" in r and "sync" in r
            if sync_mode == "zero3":
                assert "zero3_params" in r
        assert len(log.losses) == 5
        assert all(np.isfinite(v) for v in log.losses)
        finals[sync_mode] = p
    # canonical-order contract: on a 1-device mesh every collective is the
    # identity, so all three modes walk the same trajectory — if zero3
    # returned shard-layout params this comparison would scramble
    for mode in ("zero", "zero3"):
        diff = max(jax.tree.leaves(jax.tree.map(
            lambda a, b: float(np.abs(np.asarray(a) - np.asarray(b)).max()),
            finals["masked"], finals[mode])))
        assert diff <= 1e-6, (mode, diff)


# ------------------------------------------------------- streamed ZeRO-3
def test_zero3_unit_schedule_matches_report():
    """The execution-ordered unit schedule is the report's unit set: names
    unique, head subtrees first, totals and peak agree with
    ``zero3_param_byte_report`` (the schedule is the model the streamed
    materializer is checked against, so the two must never drift)."""
    from repro.sharding.sync import zero3_unit_schedule
    params = _params()
    sched = paper_mix_schedule(L, G, N, (0.4, 0.3, 0.3), 0)
    plan = grad_sync_plan(params, CFG, sched, mode="zero3", n_shards=8)
    units = zero3_unit_schedule(plan, params)
    rep = zero3_param_byte_report(plan, params, 8)
    names = [n for n, _ in units]
    assert len(names) == len(set(names))
    assert names[0] == "embed", names
    assert sum(b for _, b in units) == pytest.approx(rep["gathered_bytes"])
    assert max(b for _, b in units) == pytest.approx(rep["peak_unit_bytes"])
    assert dict(units)[rep["peak_unit"]] == pytest.approx(
        rep["peak_unit_bytes"])
    # blocks appear in forward (cycle-major) order
    blocks = [n for n in names if n.startswith("cycles[")]
    assert blocks == sorted(blocks, key=lambda s: (
        int(s.split("][")[1][:-1]), int(s.split("[")[1].split("]")[0])))


def test_streamed_residency_counter_matches_model():
    """Lowering the streamed step on a 1-device mesh fills the trace-time
    gather counter; ``check_zero3_residency`` must accept it with peak
    agreement ~1.0 — the measured-vs-model contract of the bench."""
    from repro.core.schedule import gates_from_schedule
    from repro.data.synthetic import lm_batches, microbatch_assignment
    from repro.launch.mesh import make_data_mesh
    from repro.optim.optimizers import sgd
    from repro.sharding.sync import (ResidencyRecorder,
                                     check_zero3_residency)
    from repro.train.loop import make_distributed_train_step

    params = _params()
    sched = paper_mix_schedule(L, G, N, (0.4, 0.3, 0.3), 0)
    plan = grad_sync_plan(params, CFG, sched, mode="zero3", n_shards=1)
    opt = sgd(1e-2)
    rec = ResidencyRecorder()
    step = make_distributed_train_step(
        CFG, opt, make_data_mesh(1), plan, sync_mode="zero3",
        params=params, streamed=True, opt_chunk=64,
        residency_recorder=rec)
    batch = next(lm_batches(0, CFG.vocab_size, 8, 8, 1))
    gates = gates_from_schedule(sched, microbatch_assignment(8, N))
    shards = zero_reshard(params, None, plan)
    step.lower(shards, opt.init(params), batch, gates)
    out = check_zero3_residency(rec, plan, params, 1)
    assert out["peak_agreement"] == pytest.approx(1.0, abs=0.05)
    assert out["n_units_measured"] > 0
    assert out["n_units_measured"] <= out["n_units_model"]


def test_streamed_mode_validation():
    """streamed / opt_chunk are ZeRO-3-only, and streamed cannot compose
    with the pre-sync NaN guard (the reduce-scatters are fused into the
    backward, so there is no point where local grads exist to zero)."""
    from repro.launch.mesh import make_data_mesh
    from repro.optim.optimizers import sgd
    from repro.train.loop import make_distributed_train_step

    params = _params()
    sched = paper_mix_schedule(L, G, N, (0.4, 0.3, 0.3), 0)
    mesh = make_data_mesh(1)
    plan = grad_sync_plan(params, CFG, sched, mode="zero3", n_shards=1)
    with pytest.raises(ValueError, match="guard"):
        make_distributed_train_step(CFG, sgd(1e-2), mesh, plan,
                                    sync_mode="zero3", params=params,
                                    streamed=True, guard=True)
    plan_m = grad_sync_plan(params, CFG, sched, mode="masked")
    with pytest.raises(AssertionError):
        make_distributed_train_step(CFG, sgd(1e-2), mesh, plan_m,
                                    sync_mode="masked", params=params,
                                    streamed=True)


def test_zero3_overlap_report_model():
    """Overlap-window model invariants: exposed < serialized on the
    paper-mix (some gathers hide behind the previous unit's compute), more
    compute hides more, zero compute exposes everything, and the
    double-buffered window dominates the single-unit one."""
    from repro.launch.diststep import zero3_overlap_report
    params = _params()
    sched = paper_mix_schedule(L, G, N, (0.4, 0.3, 0.3), 0)
    plan = grad_sync_plan(params, CFG, sched, mode="zero3", n_shards=8)
    rep = zero3_param_byte_report(plan, params, 8)
    ov = zero3_overlap_report(plan, params, 8)
    assert 0.0 < ov["exposed_fraction"] < 1.0, ov
    assert ov["exposed_gather_bytes"] <= ov["serialized_gather_bytes"]
    assert ov["double_buffer_peak_bytes"] >= \
        rep["per_device_peak_bytes"] - 1e-6
    assert ov["double_buffer_fraction"] >= rep["fraction"] - 1e-9
    ov4 = zero3_overlap_report(plan, params, 8, compute_ratio=4.0)
    assert ov4["exposed_fraction"] <= ov["exposed_fraction"] + 1e-12
    ov0 = zero3_overlap_report(plan, params, 8, compute_ratio=0.0)
    assert ov0["exposed_fraction"] == pytest.approx(1.0)


@pytest.mark.multidevice
def test_distributed_parity_8dev_subprocess():
    """Acceptance: 8-host-device shard_map step == single-device gated step
    (masked, ZeRO-1 and ZeRO-3 sync, and the compacted-kernel path) and
    paper-mix all-reduce bytes at <= 60% of the all-p_f baseline. Runs in a
    fresh interpreter because the host-device count must be set before jax
    initializes. ``-m multidevice``: this is the slowest test in the repo
    (it compiles the whole schedule x sync-mode matrix on 8 emulated
    devices) and CI runs it in its own job with its own wall-clock
    budget."""
    script = os.path.join(os.path.dirname(__file__), "_dist_parity.py")
    env = dict(os.environ,
               PYTHONPATH=os.pathsep.join(
                   [os.path.join(os.path.dirname(__file__), "..", "src")]
                   + ([os.environ["PYTHONPATH"]]
                      if "PYTHONPATH" in os.environ else [])))
    proc = subprocess.run([sys.executable, script], env=env,
                          capture_output=True, text=True, timeout=1500)
    assert proc.returncode == 0, f"stdout:\n{proc.stdout}\nstderr:\n{proc.stderr}"
    assert "PARITY_OK" in proc.stdout, proc.stdout
