"""Model-stack correctness: SSD chunked scan vs naive recurrence,
block-local SWA vs masked dense, decode-vs-forward parity for every block
family, MoE dispatch vs dense reference."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import optional_hypothesis

given, settings, st = optional_hypothesis()

from repro.configs.base import (ATTN_GLOBAL, ATTN_LOCAL, RGLRU, SSD,
                                ModelConfig, MoEConfig, RGLRUConfig,
                                SSMConfig)
from repro.models.attention import (_block_local_attention, _sdpa,
                                    _window_mask)
from repro.models.moe import apply_moe, init_moe
from repro.models.ssm import ssd_chunked
from repro.models.transformer import (decode_step, forward, init_cache,
                                      init_model, lm_loss)


def test_ssd_chunked_matches_naive_recurrence():
    key = jax.random.PRNGKey(1)
    B, S, H, P, N = 2, 32, 3, 4, 8
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (B, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)))
    Bm = jax.random.normal(ks[3], (B, S, N))
    Cm = jax.random.normal(ks[4], (B, S, N))

    h = jnp.zeros((B, H, P, N))
    outs = []
    for t in range(S):
        a = jnp.exp(dt[:, t] * A[None, :])
        h = h * a[:, :, None, None] + jnp.einsum(
            "bhp,bn,bh->bhpn", x[:, t], Bm[:, t], dt[:, t])
        outs.append(jnp.einsum("bn,bhpn->bhp", Cm[:, t], h))
    naive = jnp.stack(outs, 1)
    for chunk in (4, 8, 16, 32):
        out = ssd_chunked(x, dt, A, Bm, Cm, chunk=chunk)
        np.testing.assert_allclose(np.asarray(out), np.asarray(naive),
                                   atol=2e-4, rtol=2e-3)


@settings(max_examples=10, deadline=None)
@given(st.sampled_from([8, 16]), st.sampled_from([2, 4]),
       st.sampled_from([1, 2]))
def test_block_local_swa_matches_dense(window, Hq, Hkv_div):
    Hkv = Hq // Hkv_div
    key = jax.random.PRNGKey(0)
    B, S, hd = 2, 4 * window, 16
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (B, S, Hq, hd))
    k = jax.random.normal(ks[1], (B, S, Hkv, hd))
    v = jax.random.normal(ks[2], (B, S, Hkv, hd))
    dense = _sdpa(q, k, v, _window_mask(S, S, window))
    local = _block_local_attention(q, k, v, window)
    np.testing.assert_allclose(np.asarray(dense), np.asarray(local),
                               atol=2e-5, rtol=2e-4)


CASES = {
    "dense_gqa": ModelConfig(name="d", arch_type="dense", n_layers=3,
                             d_model=32, n_heads=4, n_kv_heads=2, d_ff=64,
                             vocab_size=53),
    "swa": ModelConfig(name="l", arch_type="dense", n_layers=3, d_model=32,
                       n_heads=4, n_kv_heads=2, d_ff=64, vocab_size=53,
                       block_pattern=(ATTN_LOCAL,), window=4),
    "ssm": ModelConfig(name="s", arch_type="ssm", n_layers=2, d_model=32,
                       n_heads=0, n_kv_heads=0, d_ff=0, vocab_size=53,
                       rope=False, block_pattern=(SSD,),
                       ssm=SSMConfig(state_dim=8, head_dim=8, chunk=4)),
    "hybrid": ModelConfig(name="h", arch_type="hybrid", n_layers=3,
                          d_model=32, n_heads=4, n_kv_heads=1, d_ff=64,
                          vocab_size=53,
                          block_pattern=(RGLRU, RGLRU, ATTN_LOCAL), window=4,
                          rglru=RGLRUConfig()),
    "moe": ModelConfig(name="m", arch_type="moe", n_layers=2, d_model=32,
                       n_heads=4, n_kv_heads=2, d_ff=0, vocab_size=53,
                       moe=MoEConfig(n_experts=4, top_k=2, d_ff=32,
                                     capacity_factor=2.0)),
}


@pytest.mark.parametrize("name", list(CASES))
def test_decode_matches_forward(name):
    cfg = CASES[name]
    T = 12
    p = init_model(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(2), (2, T), 0,
                              cfg.vocab_size)
    full, _ = forward(p, cfg, tokens=toks)
    cache = init_cache(cfg, 2, T)
    step = jax.jit(lambda c, tok, t: decode_step(p, c, cfg, tok, t))
    outs = []
    for t in range(T):
        lg, cache = step(cache, toks[:, t:t + 1], jnp.int32(t))
        outs.append(lg)
    dec = jnp.concatenate(outs, 1)
    np.testing.assert_allclose(np.asarray(full), np.asarray(dec), atol=1e-4,
                               rtol=1e-3)


def test_moe_no_drop_equals_full_dispatch():
    """With generous capacity every token reaches its experts: the dispatch
    must equal a dense per-token expert sum."""
    cfg = MoEConfig(n_experts=4, top_k=2, d_ff=16, capacity_factor=8.0)
    p = init_moe(jax.random.PRNGKey(0), 8, cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 6, 8))
    y, aux = apply_moe(p, x, cfg)
    assert aux["drop_frac"] == 0.0

    xt = x.reshape(-1, 8)
    logits = xt @ p["router"]
    probs = jax.nn.softmax(logits, -1)
    top_w, top_e = jax.lax.top_k(probs, 2)
    top_w = top_w / top_w.sum(-1, keepdims=True)
    ref = jnp.zeros_like(xt)
    for e in range(4):
        h = xt @ p["w_up"][e]
        g = jax.nn.silu(xt @ p["w_gate"][e])
        oe = (g * h) @ p["w_down"][e]
        wsum = jnp.where(top_e == e, top_w, 0.0).sum(-1)
        ref = ref + oe * wsum[:, None]
    np.testing.assert_allclose(np.asarray(y.reshape(-1, 8)), np.asarray(ref),
                               atol=1e-5, rtol=1e-4)


def test_vlm_and_audio_frontends():
    from repro.models.frontends import synth_features, text_len
    cfg = ModelConfig(name="v", arch_type="vlm", n_layers=2, d_model=32,
                      n_heads=4, n_kv_heads=4, d_ff=64, vocab_size=53,
                      frontend="vision_stub", frontend_tokens=4,
                      frontend_dim=16)
    p = init_model(jax.random.PRNGKey(0), cfg)
    feats = synth_features(jax.random.PRNGKey(1), cfg, 2, 12)
    toks = jax.random.randint(jax.random.PRNGKey(2), (2, 8), 0, 53)
    logits, _ = forward(p, cfg, tokens=toks, features=feats)
    assert logits.shape == (2, 12, 53)       # 4 patch + 8 text
    loss, _ = lm_loss(p, cfg, toks, toks, features=feats)
    assert jnp.isfinite(loss)

    cfg_a = ModelConfig(name="a", arch_type="audio", n_layers=2, d_model=32,
                        n_heads=4, n_kv_heads=4, d_ff=64, vocab_size=19,
                        causal=False, rope=False, frontend="audio_stub",
                        frontend_dim=16, norm="layer", mlp_gated=False,
                        mlp_act="gelu")
    p = init_model(jax.random.PRNGKey(0), cfg_a)
    feats = synth_features(jax.random.PRNGKey(1), cfg_a, 2, 10)
    labels = jax.random.randint(jax.random.PRNGKey(2), (2, 10), 0, 19)
    loss, _ = lm_loss(p, cfg_a, None, labels, features=feats)
    assert jnp.isfinite(loss)
