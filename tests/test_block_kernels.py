"""Gated block kernel contract: SSD / RG-LRU / MoE kernels.

Mirrors tests/test_kernel_grads.py for the three non-attention kernels
(kernels/contract.py is the shared interface): grad parity vs the
reference VJPs under random p_f/p_o/p_s mixes — including odd
non-chunk-multiple sequence lengths through the pad path — exact-zero
gradients for g_b == 0 slices, the g_b <= g_f invariant, zero gate
cotangents, and hypothesis properties tying *executed* work (via the
on_dispatch / on_backward_block hooks) to ``core.schedule
.live_slice_bounds`` exactly.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from conftest import optional_hypothesis
from repro.core.schedule import (Schedule, gates_from_schedule,
                                 live_slice_bounds)
from repro.data.synthetic import microbatch_assignment
from repro.kernels import contract
from repro.kernels import d2ft_moe as d2m
from repro.kernels import d2ft_rglru as d2r
from repro.kernels import d2ft_ssd as d2s
from repro.kernels import ops
from repro.kernels.ref import (gated_moe_ffn_ref, gated_rglru_ref,
                               gated_ssd_ref)
from repro.models.layers import _act

given, settings, st = optional_hypothesis()

TOL = 1e-4   # fp32, interpret mode


def _mix(rng, B, H):
    """ops 0=p_f, 1=p_o, 2=p_s -> (g_f, g_b) with g_b <= g_f."""
    ops_ = rng.integers(0, 3, (B, H))
    g_f = jnp.asarray((ops_ != 2).astype(np.float32))
    g_b = jnp.asarray((ops_ == 0).astype(np.float32))
    return ops_, g_f, g_b


# ======================================================================= SSD
def _ssd_operands(key, B, S, H, P, N):
    ks = jax.random.split(key, 5)
    x = jax.random.normal(ks[0], (B, S, H, P))
    da = -jax.nn.softplus(jax.random.normal(ks[1], (B, S, H)))   # log-decay
    Bm = jax.random.normal(ks[2], (B, S, N)) * 0.5
    Cm = jax.random.normal(ks[3], (B, S, N)) * 0.5
    do = jax.random.normal(ks[4], (B, S, H, P))
    return x, da, Bm, Cm, do


def _ssd_ref(x, da, Bm, Cm, g_f, g_b, chunk):
    """Reference with the wrapper's zero-pad path (the oracle itself
    requires chunk-multiple S)."""
    S = x.shape[1]
    Q = min(chunk, S)
    Sp = -(-S // Q) * Q
    if Sp != S:
        p = ((0, 0), (0, Sp - S))
        x = jnp.pad(x, p + ((0, 0), (0, 0)))
        da = jnp.pad(da, p + ((0, 0),))
        Bm = jnp.pad(Bm, p + ((0, 0),))
        Cm = jnp.pad(Cm, p + ((0, 0),))
    return gated_ssd_ref(x, da, Bm, Cm, g_f, g_b, chunk=chunk)[:, :S]


@pytest.mark.parametrize("S,chunk", [(64, 16), (257, 64)])
def test_ssd_grad_parity_vs_reference_vjp(S, chunk):
    """S=257 exercises the recurrent-arch pad path end to end (backward
    included) — the odd-length analogue of attention's select_blocks."""
    B, H, P, N = 2, 4, 8, 8
    x, da, Bm, Cm, do = _ssd_operands(jax.random.PRNGKey(0), B, S, H, P, N)
    _, g_f, g_b = _mix(np.random.default_rng(S), B, H)

    out_k, vjp_k = jax.vjp(
        lambda x, da, Bm, Cm: ops.gated_ssd_scan(
            x, da, Bm, Cm, g_f, g_b, chunk=chunk, interpret=True),
        x, da, Bm, Cm)
    out_r, vjp_r = jax.vjp(
        lambda x, da, Bm, Cm: _ssd_ref(x, da, Bm, Cm, g_f, g_b, chunk),
        x, da, Bm, Cm)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                               atol=TOL, rtol=TOL)
    for name, a, b in zip(("dx", "dda", "dB", "dC"), vjp_k(do), vjp_r(do)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=TOL,
                                   rtol=TOL, err_msg=name)


def test_ssd_gb_zero_heads_have_exact_zero_grads():
    B, H, S, P, N = 2, 4, 32, 8, 8
    x, da, Bm, Cm, _ = _ssd_operands(jax.random.PRNGKey(1), B, S, H, P, N)
    _, g_f, g_b = _mix(np.random.default_rng(3), B, H)

    def loss(x, da):
        return ops.gated_ssd_scan(x, da, Bm, Cm, g_f, g_b, chunk=8,
                                  interpret=True).sum()

    dx, dda = jax.grad(loss, argnums=(0, 1))(x, da)
    gb = np.asarray(g_b)
    assert np.all(np.asarray(dx).transpose(0, 2, 1, 3)[gb == 0] == 0.0)
    assert np.all(np.asarray(dda).transpose(0, 2, 1)[gb == 0] == 0.0)
    assert float(np.abs(np.asarray(dx).transpose(0, 2, 1, 3)[gb == 1]).max()) > 0
    # g_f == 0 heads produce exact-zero forward output
    y = np.asarray(ops.gated_ssd_scan(x, da, Bm, Cm, g_f, g_b, chunk=8,
                                      interpret=True))
    assert np.all(y.transpose(0, 2, 1, 3)[np.asarray(g_f) == 0] == 0.0)


def test_ssd_gates_get_zero_cotangents():
    B, H, S, P, N = 1, 2, 16, 4, 4
    x, da, Bm, Cm, _ = _ssd_operands(jax.random.PRNGKey(2), B, S, H, P, N)
    g = jnp.ones((B, H))

    def loss(g_f, g_b):
        return ops.gated_ssd_scan(x, da, Bm, Cm, g_f, g_b, chunk=8,
                                  interpret=True).sum()

    dgf, dgb = jax.grad(loss, argnums=(0, 1))(g, g)
    assert float(jnp.abs(dgf).max()) == 0.0
    assert float(jnp.abs(dgb).max()) == 0.0


# ==================================================================== RG-LRU
def _rglru_operands(key, B, S, W):
    ks = jax.random.split(key, 3)
    la = -jax.nn.softplus(jax.random.normal(ks[0], (B, S, W)))
    b = jax.random.normal(ks[1], (B, S, W))
    do = jax.random.normal(ks[2], (B, S, W))
    return la, b, do


def _rglru_ref(la, b, g_f, g_b, chunk):
    S = la.shape[1]
    Q = min(chunk, S)
    Sp = -(-S // Q) * Q
    if Sp != S:
        p = ((0, 0), (0, Sp - S), (0, 0))
        la, b = jnp.pad(la, p), jnp.pad(b, p)
    return gated_rglru_ref(la, b, g_f, g_b, chunk=chunk)[:, :S]


@pytest.mark.parametrize("S,chunk", [(64, 16), (257, 64)])
def test_rglru_grad_parity_vs_reference_vjp(S, chunk):
    B, W, G = 2, 32, 4
    la, b, do = _rglru_operands(jax.random.PRNGKey(0), B, S, W)
    _, g_f, g_b = _mix(np.random.default_rng(S + 1), B, G)

    out_k, vjp_k = jax.vjp(
        lambda la, b: ops.gated_rglru_scan(la, b, g_f, g_b, chunk=chunk,
                                           interpret=True), la, b)
    out_r, vjp_r = jax.vjp(
        lambda la, b: _rglru_ref(la, b, g_f, g_b, chunk), la, b)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                               atol=TOL, rtol=TOL)
    for name, a, bb in zip(("dla", "db"), vjp_k(do), vjp_r(do)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(bb), atol=TOL,
                                   rtol=TOL, err_msg=name)


def test_rglru_gb_zero_bands_have_exact_zero_grads():
    B, S, W, G = 2, 32, 32, 4
    Wg = W // G
    la, b, _ = _rglru_operands(jax.random.PRNGKey(1), B, S, W)
    _, g_f, g_b = _mix(np.random.default_rng(5), B, G)

    def loss(la, b):
        return ops.gated_rglru_scan(la, b, g_f, g_b, chunk=8,
                                    interpret=True).sum()

    dla, db = jax.grad(loss, argnums=(0, 1))(la, b)
    for g in (dla, db):
        g = np.asarray(g).reshape(B, S, G, Wg).transpose(0, 2, 1, 3)
        assert np.all(g[np.asarray(g_b) == 0] == 0.0)
    h = np.asarray(ops.gated_rglru_scan(la, b, g_f, g_b, chunk=8,
                                        interpret=True))
    h = h.reshape(B, S, G, Wg).transpose(0, 2, 1, 3)
    assert np.all(h[np.asarray(g_f) == 0] == 0.0)


# ======================================================================= MoE
def _moe_operands(key, E, C, D, F):
    ks = jax.random.split(key, 5)
    xb = jax.random.normal(ks[0], (E, C, D))
    wu = jax.random.normal(ks[1], (E, D, F)) / np.sqrt(D)
    wg = jax.random.normal(ks[2], (E, D, F)) / np.sqrt(D)
    wd = jax.random.normal(ks[3], (E, F, D)) / np.sqrt(F)
    do = jax.random.normal(ks[4], (E, C, D))
    return xb, wu, wg, wd, do


def _slot_masks(rng, E, C):
    """Random slot masks with bwd <= fwd (float {0,1})."""
    ops_ = rng.integers(0, 3, (E, C))
    return (jnp.asarray((ops_ != 2).astype(np.float32)),
            jnp.asarray((ops_ == 0).astype(np.float32)))


def _moe_block_masks(fwd_slots, bwd_slots, C, block_c):
    """The wrapper's slot->block reduction, for the reference."""
    bc = min(block_c, C)
    Cp = -(-C // bc) * bc
    pad = ((0, 0), (0, Cp - C))
    fm = np.pad(np.asarray(fwd_slots), pad).reshape(-1, Cp // bc, bc)
    bm = np.pad(np.asarray(bwd_slots), pad).reshape(-1, Cp // bc, bc)
    return (jnp.asarray((fm.sum(-1) > 0).astype(np.float32)),
            jnp.asarray((bm.sum(-1) > 0).astype(np.float32)), bc)


@pytest.mark.parametrize("C,block_c", [(64, 16), (57, 16)])
@pytest.mark.parametrize("act", ["silu", "gelu"])
def test_moe_grad_parity_vs_reference_vjp(C, block_c, act):
    """C=57 exercises the capacity pad path (57 -> 4 blocks of 16)."""
    E, D, F = 4, 16, 32
    xb, wu, wg, wd, do = _moe_operands(jax.random.PRNGKey(0), E, C, D, F)
    fs, bs = _slot_masks(np.random.default_rng(C), E, C)
    fm, bm, bc = _moe_block_masks(fs, bs, C, block_c)

    out_k, vjp_k = jax.vjp(
        lambda xb, wu, wg, wd: ops.gated_moe_ffn(
            xb, wu, wg, wd, fs, bs, act=act, block_c=block_c,
            interpret=True), xb, wu, wg, wd)
    out_r, vjp_r = jax.vjp(
        lambda xb, wu, wg, wd: gated_moe_ffn_ref(
            xb, wu, wg, wd, fm, bm, act=_act(act), block_c=bc),
        xb, wu, wg, wd)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                               atol=TOL, rtol=TOL)
    for name, a, b in zip(("dx", "dwu", "dwg", "dwd"), vjp_k(do),
                          vjp_r(do)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=TOL,
                                   rtol=TOL, err_msg=name)


def test_moe_dead_block_slots_have_exact_zero_grads():
    E, C, D, F, bc = 2, 32, 8, 16, 8
    xb, wu, wg, wd, _ = _moe_operands(jax.random.PRNGKey(1), E, C, D, F)
    fs = jnp.ones((E, C))
    # expert 0: first block backward-live only; expert 1: all dead
    bs = np.zeros((E, C), np.float32)
    bs[0, :bc] = 1.0
    bs = jnp.asarray(bs)

    def loss(xb):
        return ops.gated_moe_ffn(xb, wu, wg, wd, fs, bs, block_c=bc,
                                 interpret=True).sum()

    dx = np.asarray(jax.grad(loss)(xb))
    assert np.all(dx[1] == 0.0)
    assert np.all(dx[0, bc:] == 0.0)
    assert float(np.abs(dx[0, :bc]).max()) > 0.0


# ============================================================== invariants
def test_ssd_gb_gt_gf_rejected():
    x, da, Bm, Cm, _ = _ssd_operands(jax.random.PRNGKey(0), 1, 16, 2, 4, 4)
    with pytest.raises(ValueError, match="g_b <= g_f"):
        ops.gated_ssd_scan(x, da, Bm, Cm, jnp.asarray([[1., 0.]]),
                           jnp.asarray([[1., 1.]]), chunk=8, interpret=True)


def test_ssd_undersized_live_bound_rejected():
    x, da, Bm, Cm, _ = _ssd_operands(jax.random.PRNGKey(0), 1, 16, 4, 4, 4)
    g = jnp.ones((1, 4))
    with pytest.raises(ValueError, match="live_bwd=2 is below"):
        ops.gated_ssd_scan(x, da, Bm, Cm, g, g, chunk=8, interpret=True,
                           live_fwd=4, live_bwd=2)


def test_rglru_gb_gt_gf_rejected():
    la, b, _ = _rglru_operands(jax.random.PRNGKey(0), 1, 16, 8)
    with pytest.raises(ValueError, match="g_b <= g_f"):
        ops.gated_rglru_scan(la, b, jnp.asarray([[1., 0.]]),
                             jnp.asarray([[1., 1.]]), chunk=8,
                             interpret=True)


def test_rglru_width_not_divisible_rejected():
    la, b, _ = _rglru_operands(jax.random.PRNGKey(0), 1, 16, 9)
    g = jnp.ones((1, 2))
    with pytest.raises(ValueError, match="not divisible"):
        ops.gated_rglru_scan(la, b, g, g, chunk=8, interpret=True)


def test_moe_bwd_gt_fwd_slots_rejected():
    xb, wu, wg, wd, _ = _moe_operands(jax.random.PRNGKey(0), 2, 16, 4, 8)
    fs = jnp.zeros((2, 16))
    bs = jnp.ones((2, 16))
    with pytest.raises(ValueError, match="bwd_slots <= fwd_slots"):
        ops.gated_moe_ffn(xb, wu, wg, wd, fs, bs, interpret=True)


def test_moe_undersized_live_slots_rejected():
    xb, wu, wg, wd, _ = _moe_operands(jax.random.PRNGKey(0), 2, 16, 4, 8)
    fs = jnp.ones((2, 16))
    with pytest.raises(ValueError, match="live_slots=4 is below"):
        ops.gated_moe_ffn(xb, wu, wg, wd, fs, fs, live_slots=4,
                          interpret=True)


# ============================== executed work == schedule bounds (hypothesis)
def _sched_gates(ops_flat, B, G, M):
    """A 1-layer Schedule from raw op codes -> gates + live_slice_bounds.

    ops_flat codes 0/1/2 map to the table encoding P_F/P_O/P_S (1/2/3)."""
    table = (np.asarray(ops_flat, np.int8) + 1).reshape(G, M)
    sched = Schedule(table, 1, G)
    mb_of = microbatch_assignment(B, M)
    g_f, g_b = gates_from_schedule(sched, mb_of)
    return g_f[0], g_b[0], live_slice_bounds(sched, mb_of)


@given(st.lists(st.integers(0, 2), min_size=8, max_size=8))
@settings(max_examples=10, deadline=None)
def test_ssd_executed_blocks_match_live_slice_bounds(ops_flat):
    """Grid leading dims equal the contract's dispatch_count of the
    schedule bounds; executed backward blocks equal live_bwd x chunks."""
    B, G, M, S, P, N, chunk = 4, 2, 4, 32, 4, 4, 8
    g_f, g_b, bounds = _sched_gates(ops_flat, B, G, M)
    n_live_b = int(np.sum(np.asarray(g_b) != 0))
    assert n_live_b <= bounds[1]
    x, da, Bm, Cm, do = _ssd_operands(jax.random.PRNGKey(7), B, S, G, P, N)

    grids, count = {}, {"n": 0}
    d2s.on_dispatch = lambda kind, grid: grids.__setitem__(kind, grid)
    d2s.on_backward_block = lambda: count.__setitem__("n", count["n"] + 1)
    jax.clear_caches()                       # hooks are read at trace time
    try:
        out, vjp = jax.vjp(
            lambda x: ops.gated_ssd_scan(
                x, da, Bm, Cm, g_f, g_b, chunk=chunk, interpret=True,
                live_fwd=bounds[0], live_bwd=bounds[1]), x)
        vjp(do)
        jax.effects_barrier()
    finally:
        d2s.on_dispatch = None
        d2s.on_backward_block = None

    nc = S // chunk
    assert grids["fwd"][0] == contract.dispatch_count(bounds[0], B * G)
    assert grids["bwd"][0] == contract.dispatch_count(bounds[1], B * G)
    assert count["n"] == n_live_b * nc


@given(st.lists(st.integers(0, 2), min_size=8, max_size=8))
@settings(max_examples=10, deadline=None)
def test_rglru_executed_blocks_match_live_slice_bounds(ops_flat):
    B, G, M, S, W, chunk = 4, 2, 4, 32, 16, 8
    g_f, g_b, bounds = _sched_gates(ops_flat, B, G, M)
    n_live_b = int(np.sum(np.asarray(g_b) != 0))
    la, b, do = _rglru_operands(jax.random.PRNGKey(8), B, S, W)

    grids, count = {}, {"n": 0}
    d2r.on_dispatch = lambda kind, grid: grids.__setitem__(kind, grid)
    d2r.on_backward_block = lambda: count.__setitem__("n", count["n"] + 1)
    jax.clear_caches()
    try:
        out, vjp = jax.vjp(
            lambda la, b: ops.gated_rglru_scan(
                la, b, g_f, g_b, chunk=chunk, interpret=True,
                live_fwd=bounds[0], live_bwd=bounds[1]), la, b)
        vjp(do)
        jax.effects_barrier()
    finally:
        d2r.on_dispatch = None
        d2r.on_backward_block = None

    nc = S // chunk
    assert grids["fwd"][0] == contract.dispatch_count(bounds[0], B * G)
    assert grids["bwd"][0] == contract.dispatch_count(bounds[1], B * G)
    assert count["n"] == n_live_b * nc


@given(st.lists(st.integers(0, 2), min_size=8, max_size=8))
@settings(max_examples=10, deadline=None)
def test_moe_executed_blocks_match_block_masks(ops_flat):
    """Executed backward tiles equal the number of backward-live capacity
    blocks; live_slots truncation shrinks the dispatched grid."""
    E, C, D, F, bc = 2, 32, 4, 8, 8
    ops_ = np.asarray(ops_flat).reshape(2, 4)    # op per (expert, block)
    fs = np.repeat((ops_ != 2).astype(np.float32), bc, axis=1)
    bs = np.repeat((ops_ == 0).astype(np.float32), bc, axis=1)
    n_bwd_blocks = int((ops_ == 0).sum())
    xb, wu, wg, wd, do = _moe_operands(jax.random.PRNGKey(9), E, C, D, F)

    grids, count = {}, {"n": 0}
    d2m.on_dispatch = lambda kind, grid: grids.__setitem__(kind, grid)
    d2m.on_backward_block = lambda: count.__setitem__("n", count["n"] + 1)
    jax.clear_caches()
    try:
        out, vjp = jax.vjp(
            lambda xb: ops.gated_moe_ffn(
                xb, wu, wg, wd, jnp.asarray(fs), jnp.asarray(bs),
                block_c=bc, interpret=True), xb)
        vjp(do)
        jax.effects_barrier()
    finally:
        d2m.on_dispatch = None
        d2m.on_backward_block = None

    assert grids["fwd"] == (E, C // bc)
    assert grids["bwd"] == (E, C // bc)
    assert count["n"] == n_bwd_blocks


def test_moe_live_slots_truncates_grid():
    E, C, D, F, bc = 2, 64, 4, 8, 16
    xb, wu, wg, wd, _ = _moe_operands(jax.random.PRNGKey(10), E, C, D, F)
    fs = np.zeros((E, C), np.float32)
    fs[:, :24] = 1.0                              # occupancy in first 24 slots
    grids = {}
    d2m.on_dispatch = lambda kind, grid: grids.__setitem__(kind, grid)
    jax.clear_caches()
    try:
        ops.gated_moe_ffn(xb, wu, wg, wd, jnp.asarray(fs), block_c=bc,
                          live_slots=24, interpret=True)
    finally:
        d2m.on_dispatch = None
    # 24 live slots -> ceil(24/16) = 2 of 4 capacity blocks dispatched
    assert grids["fwd"] == (E, 2)


def test_moe_live_bwd_slots_truncates_backward_grid():
    """The backward capacity bound is keyed on g_b, independent of g_f:
    with 40 forward-live but only 18 backward-live slots the forward
    dispatches ceil(40/16) = 3 capacity blocks and the backward only
    ceil(18/16) = 2 — and outputs and grads equal the untruncated path
    exactly (slots past the bound are backward-dead: their dy contribution
    is zero, so dropping their blocks changes nothing)."""
    E, C, D, F, bc = 2, 64, 4, 8, 16
    xb, wu, wg, wd, do = _moe_operands(jax.random.PRNGKey(11), E, C, D, F)
    fs = np.zeros((E, C), np.float32)
    fs[:, :40] = 1.0
    bs = np.zeros((E, C), np.float32)
    bs[:, :18] = 1.0                              # bwd-live prefix < fwd-live

    def run(bwd_slots):
        grids = {}
        d2m.on_dispatch = lambda kind, grid: grids.__setitem__(kind, grid)
        jax.clear_caches()
        try:
            out, vjp = jax.vjp(
                lambda *w: ops.gated_moe_ffn(
                    *w, jnp.asarray(fs), jnp.asarray(bs), block_c=bc,
                    live_slots=40, live_bwd_slots=bwd_slots,
                    interpret=True), xb, wu, wg, wd)
            grads = vjp(do)
            jax.effects_barrier()
        finally:
            d2m.on_dispatch = None
        return out, grads, grids

    out_t, g_t, grids_t = run(18)
    out_f, g_f, grids_f = run(None)
    assert grids_t["fwd"] == (E, 3)
    assert grids_t["bwd"] == (E, 2)               # shrunk past the fwd bound
    assert grids_f["fwd"] == (E, 3)
    assert grids_f["bwd"] == (E, 3)               # untruncated: fwd bound
    np.testing.assert_array_equal(np.asarray(out_t), np.asarray(out_f))
    for a, b, n in zip(g_t, g_f, ("dx", "dwu", "dwg", "dwd")):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=0, atol=1e-6, err_msg=n)
    # the bound must cover every backward-live slot — an undershoot would
    # silently zero live grads, so it is rejected loudly
    with pytest.raises(ValueError, match="backward"):
        ops.gated_moe_ffn(xb, wu, wg, wd, jnp.asarray(fs), jnp.asarray(bs),
                          block_c=bc, live_slots=40, live_bwd_slots=17,
                          interpret=True)
