"""Subprocess body for the 8-host-device distributed parity test.

Run by tests/test_distributed.py with a fresh interpreter so the forced
host-device count does not disturb the rest of the suite (conftest pins it
to ONE CPU device; jax locks the count at first init). Prints a single
machine-readable PARITY_OK line on success; any assertion or crash fails
the calling test via the exit code.
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.assignment import (device_sample_order,
                                   distributed_live_bounds,
                                   plan_device_assignment)
from repro.core.schedule import (P_F, P_O, P_S, Schedule,
                                 gates_from_schedule, live_slice_bounds)
from repro.data.synthetic import lm_batches, microbatch_assignment
from repro.launch.diststep import measure_distributed_step
from repro.launch.hlo import collective_bytes
from repro.launch.mesh import make_data_mesh
from repro.models.transformer import init_model
from repro.optim.optimizers import sgd
from repro.train.loop import make_distributed_train_step, make_train_step


def max_leaf_diff(a, b):
    return max(jax.tree.leaves(jax.tree.map(
        lambda x, y: float(jnp.abs(x - y).max()), a, b)))


assert len(jax.devices()) == 8, jax.devices()

cfg = ModelConfig(name="parity", arch_type="dense", n_layers=4, d_model=64,
                  n_heads=4, n_kv_heads=4, d_ff=128, vocab_size=256)
G, L, N, B, S, K = 4, 4, 16, 32, 16, 8
rng = np.random.default_rng(0)
# iid mix stresses the sliced/stacked plan paths; then force one fully-dead
# layer (all p_o/p_s -> psum elided) and one fully-live layer (plain pmean)
table = rng.choice([P_F, P_O, P_S], size=(L * G, N),
                   p=[.4, .3, .3]).astype(np.int8)
table[0:G] = np.where(table[0:G] == P_F, P_O, table[0:G])
table[2 * G:3 * G] = P_F
sched = Schedule(table, L, G)

from repro.sharding.sync import (grad_sync_plan, sync_byte_report,
                                 zero3_param_byte_report, zero_reshard,
                                 zero_state_byte_report)

params = init_model(jax.random.PRNGKey(0), cfg)
opt = sgd(1e-2)       # linear in grads: parity is pure FP reordering noise
mesh = make_data_mesh(K)
batch = next(lm_batches(0, cfg.vocab_size, B, S, 1))
mb_of = microbatch_assignment(B, N)

assignment, report = plan_device_assignment(sched, K)
assert report["capacity_ok"] and len(set(report["counts"])) == 1, report
perm = device_sample_order(assignment, mb_of)
pbatch = jax.tree.map(lambda a: a[perm], batch)
gates = gates_from_schedule(sched, mb_of[perm])
plan = grad_sync_plan(params, cfg, sched)
assert sync_byte_report(plan, params)["fraction"] < 1.0

# ---- masked-path parity: 3 optimizer steps, distributed vs single device
step = make_distributed_train_step(cfg, opt, mesh, plan)
ref_step = jax.jit(make_train_step(cfg, opt, use_gates=True))
p_d, s_d = params, opt.init(params)
p_r, s_r = params, opt.init(params)
for _ in range(3):
    p_d, s_d, m_d = step(p_d, s_d, pbatch, gates)
    p_r, s_r, m_r = ref_step(p_r, s_r, pbatch, gates)
maxdiff = max_leaf_diff(p_d, p_r)
assert maxdiff <= 1e-6, f"masked-path params diverged: {maxdiff}"
assert abs(float(m_d["loss"]) - float(m_r["loss"])) <= 1e-5

# ---- kernel-path parity: compacted Pallas dispatch inside shard_map, with
# per-device live bounds vs the single-device step's global bounds
bounds = distributed_live_bounds(sched, mb_of, assignment)
gbounds = live_slice_bounds(sched, mb_of)
assert bounds[0] <= gbounds[0] and bounds[1] <= gbounds[1], (bounds, gbounds)
kstep = make_distributed_train_step(cfg, opt, mesh, plan, use_kernel=True,
                                    live_bounds=bounds)
kref = jax.jit(make_train_step(cfg, opt, use_gates=True, use_kernel=True,
                               live_bounds=gbounds))
pk, sk, mk = kstep(params, opt.init(params), pbatch, gates)
pr, sr, mr = kref(params, opt.init(params), pbatch, gates)
kdiff = max_leaf_diff(pk, pr)
assert kdiff <= 1e-6, f"kernel-path params diverged: {kdiff}"

# ---- ZeRO-sync parity: sliced reduce-scatter + sharded moments + masked
# all-gather over 3 optimizer steps must match the same single-device
# reference as the masked path (p_r / s_r above)
zplan = grad_sync_plan(params, cfg, sched, mode="zero", n_shards=K,
                       elide_gather=opt.elidable)
zstep = make_distributed_train_step(cfg, opt, mesh, zplan, sync_mode="zero",
                                    params=params)
p_z, s_z = params, opt.init(params)
for _ in range(3):
    p_z, s_z, m_z = zstep(p_z, s_z, pbatch, gates)
zdiff = max_leaf_diff(p_z, p_r)
assert zdiff <= 1e-6, f"zero-sync params diverged: {zdiff}"
assert abs(float(m_z["loss"]) - float(m_r["loss"])) <= 1e-5
# the sharded momenta, restored to canonical layout, are the reference's
zmu = zero_reshard(s_z["mu"], zplan, None)
mudiff = max_leaf_diff(zmu, s_r["mu"])
assert mudiff <= 1e-6, f"sharded momenta diverged: {mudiff}"
# per-device moment memory is ~1/K of the replicated baseline
zmem = zero_state_byte_report(zplan, params, K)
assert zmem["fraction"] <= 1.0 / K + 0.05, zmem

# ---- ZeRO-3 parity: fully sharded params, schedule-masked forward gather.
# Run on the concentrated paper-mix (it HAS p_s-everywhere subnets), so the
# gather elision actually fires and the parity proves the zeros views are
# exact — then on the iid schedule used by the other paths above.
from repro.launch.diststep import paper_mix_schedule

mix_sched = paper_mix_schedule(L, G, N, (0.4, 0.3, 0.3), seed=0)
mix_assignment, _ = plan_device_assignment(mix_sched, K)
mix_perm = device_sample_order(mix_assignment, mb_of)
mix_batch = jax.tree.map(lambda a: a[mix_perm], batch)
mix_gates = gates_from_schedule(mix_sched, mb_of[mix_perm])
z3_elided = 0
for name, s, b, g in [("paper_mix", mix_sched, mix_batch, mix_gates),
                      ("iid", sched, pbatch, gates)]:
    z3plan = grad_sync_plan(params, cfg, s, mode="zero3", n_shards=K)
    z3rep = zero3_param_byte_report(z3plan, params, K)
    z3step = make_distributed_train_step(cfg, opt, mesh, z3plan,
                                         sync_mode="zero3", params=params)
    rstep = jax.jit(make_train_step(cfg, opt, use_gates=True))
    p_3, s_3 = zero_reshard(params, None, z3plan), opt.init(params)
    p_m, s_m = params, opt.init(params)
    for _ in range(3):
        p_3, s_3, m_3 = z3step(p_3, s_3, b, g)
        p_m, s_m, m_m = rstep(p_m, s_m, b, g)
    z3diff = max_leaf_diff(zero_reshard(p_3, z3plan, None), p_m)
    assert z3diff <= 1e-6, f"zero3 params diverged ({name}): {z3diff}"
    assert abs(float(m_3["loss"]) - float(m_m["loss"])) <= 1e-5, name
    mu3diff = max_leaf_diff(zero_reshard(s_3["mu"], z3plan, None), s_m["mu"])
    assert mu3diff <= 1e-6, f"zero3 momenta diverged ({name}): {mu3diff}"
    if name == "paper_mix":
        # acceptance: elision fires and the residency model is <= 0.5x
        assert z3rep["n_gather_elided"] > 0, z3rep
        assert z3rep["fraction"] <= 0.5, z3rep
        z3_elided = z3rep["n_gather_elided"]
        z3_residency = z3rep["fraction"]

# ---- streamed ZeRO-3: per-unit gathers with the reduce-scatter fused into
# each unit's backward release, plus the chunked shard-resident optimizer
# sweep. Must walk the SAME trajectory as unstreamed zero3 and the masked
# reference, carry identical collective bytes (overlap scheduling moves
# collectives against compute, never adds or removes wire), and its
# trace-time gather counter must agree with the residency model within 5%.
from repro.launch.hlo import compare_collective_bytes
from repro.sharding.sync import ResidencyRecorder, check_zero3_residency

z3plan = grad_sync_plan(params, cfg, mix_sched, mode="zero3", n_shards=K)
rec3 = ResidencyRecorder()
sstep = make_distributed_train_step(cfg, opt, mesh, z3plan,
                                    sync_mode="zero3", params=params,
                                    streamed=True, opt_chunk=1024,
                                    residency_recorder=rec3)
ustep = make_distributed_train_step(cfg, opt, mesh, z3plan,
                                    sync_mode="zero3", params=params)
mref = jax.jit(make_train_step(cfg, opt, use_gates=True))
p_s3, s_s3 = zero_reshard(params, None, z3plan), opt.init(params)
p_u3, s_u3 = zero_reshard(params, None, z3plan), opt.init(params)
p_m3, s_m3 = params, opt.init(params)
for _ in range(3):
    p_s3, s_s3, m_s3 = sstep(p_s3, s_s3, mix_batch, mix_gates)
    p_u3, s_u3, m_u3 = ustep(p_u3, s_u3, mix_batch, mix_gates)
    p_m3, s_m3, m_m3 = mref(p_m3, s_m3, mix_batch, mix_gates)
sdiff = max_leaf_diff(p_s3, p_u3)            # both in shard layout
assert sdiff <= 1e-6, f"streamed vs unstreamed zero3 diverged: {sdiff}"
smdiff = max_leaf_diff(zero_reshard(p_s3, z3plan, None), p_m3)
assert smdiff <= 1e-6, f"streamed zero3 vs masked reference: {smdiff}"
assert abs(float(m_s3["loss"]) - float(m_m3["loss"])) <= 1e-5
res3 = check_zero3_residency(rec3, z3plan, params, K)
assert 0.95 <= res3["peak_agreement"] <= 1.05, res3
args3 = (zero_reshard(params, None, z3plan), opt.init(params), mix_batch,
         mix_gates)
wire_cmp = compare_collective_bytes(
    sstep.lower(*args3).compile().as_text(),
    ustep.lower(*args3).compile().as_text(), default_group_size=K)
assert 0.98 <= wire_cmp["ratio"] <= 1.02, wire_cmp

# ---- comm accounting: schedule x sync-mode matrix vs all-p_f baseline
rec = measure_distributed_step(K, time_steps=0)
frac = rec["all_reduce_fraction"]
base = rec["variants"]["all_pf_baseline"]["all_reduce_bytes"]
assert base > 0, rec
assert frac <= 0.60, f"all-reduce fraction {frac} above the paper target"

# byte-model consistency: the sync plan's ring-wire prediction must match
# the HLO-parsed collective bytes within 25% for every variant (all /
# sliced / zero plans; the none-dominated plan is checked below)
for name, var in rec["variants"].items():
    model = var["sync_plan"]["wire"]["total"]
    hlo = var["wire_bytes"]
    assert model > 0, (name, var["sync_plan"])
    ratio = hlo / model
    assert 0.75 <= ratio <= 1.25, \
        f"{name}: HLO {hlo:.3e} vs model {model:.3e} (ratio {ratio:.3f})"

# none-dominated plan: all-p_s schedule keeps only the loss-path leaves
ps_sched = Schedule(np.full((L * G, N), P_S, np.int8), L, G)
ps_plan = grad_sync_plan(params, cfg, ps_sched)
ps_step = make_distributed_train_step(cfg, opt, mesh, ps_plan)
ps_gates = gates_from_schedule(ps_sched, mb_of[perm])
ps_hlo = sum(collective_bytes(
    ps_step.lower(params, opt.init(params), pbatch, ps_gates)
    .compile().as_text(), default_group_size=K).values())
ps_model = sync_byte_report(ps_plan, params, n_shards=K)["wire"]["total"]
ps_ratio = ps_hlo / ps_model
assert 0.75 <= ps_ratio <= 1.25, (ps_hlo, ps_model)

# ZeRO acceptance: paper-mix RS+AG wire bytes match the masked psum's (the
# only extra collective is the scalar grad-norm psum), and the uniformly
# spread 50%-live schedule still saves strictly even though whole-subnet
# elision never fires there
z = rec["zero_sync"]
assert z["paper_mix_wire_fraction"] <= \
    z["paper_mix_masked_wire_fraction"] * 1.001, z
assert z["paper_mix_wire_fraction"] <= 0.60, z
assert z["uniform_masked_n_skipped"] == 0, z
assert z["uniform_wire_fraction"] <= 0.85, z
assert z["opt_memory_fraction"] <= 1.0 / K + 0.05, z

# ZeRO-3 acceptance: the lowered step really carries param all-gathers,
# pays less wire than the all-p_f baseline even counting them, elides
# forward-dead gathers, and the residency model is <= 0.5x replicated
z3 = rec["zero3"]
assert z3["n_all_gather_ops"] > 0, z3
assert z3["n_gather_elided"] > 0, z3
assert z3["residency_fraction"] <= 0.5, z3
assert z3["paper_mix_wire_fraction"] <= 0.75, z3
assert z3["opt_memory_fraction"] <= 1.0 / K + 0.05, z3

# streamed acceptance: the bench's overlap block — some collectives hidden
# behind compute, measured residency agrees with the model, wire bytes
# invariant to the overlap scheduling, and the plan-derived sync_byte_report
# is bit-identical between the streamed and unstreamed variants (it prices
# the plan, not the schedule that executes it)
ov = rec["overlap"]
assert ov["exposed_collective_fraction"] < 1.0, ov
assert 0.95 <= ov["peak_agreement"] <= 1.05, ov
assert 0.98 <= ov["wire_ratio_vs_unstreamed"] <= 1.02, ov
assert rec["variants"]["paper_mix_zero3_streamed"]["sync_plan"] == \
    rec["variants"]["paper_mix_zero3"]["sync_plan"], \
    "sync_byte_report must be invariant to overlap scheduling"

print(f"PARITY_OK maxdiff={maxdiff:.3e} kernel_maxdiff={kdiff:.3e} "
      f"zero_maxdiff={zdiff:.3e} "
      f"all_reduce_fraction={frac:.4f} "
      f"sync_model_fraction={rec['sync_model_fraction']:.4f} "
      f"zero_paper_mix_wire={z['paper_mix_wire_fraction']:.4f} "
      f"zero_uniform_wire={z['uniform_wire_fraction']:.4f} "
      f"zero_opt_memory={z['opt_memory_fraction']:.4f} "
      f"zero3_wire={z3['paper_mix_wire_fraction']:.4f} "
      f"zero3_residency={z3_residency:.4f} "
      f"zero3_elided={z3_elided} "
      f"streamed_maxdiff={sdiff:.3e} "
      f"streamed_exposed={ov['exposed_collective_fraction']:.4f} "
      f"streamed_peak_agreement={ov['peak_agreement']:.4f} "
      f"streamed_wire_ratio={wire_cmp['ratio']:.4f} "
      f"byte_model_ratio_none={ps_ratio:.3f} "
      f"per_device_bounds={bounds[0]},{bounds[1]} "
      f"global_bounds={gbounds[0]},{gbounds[1]}")
