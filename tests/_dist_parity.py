"""Subprocess body for the 8-host-device distributed parity test.

Run by tests/test_distributed.py with a fresh interpreter so the forced
host-device count does not disturb the rest of the suite (conftest pins it
to ONE CPU device; jax locks the count at first init). Prints a single
machine-readable PARITY_OK line on success; any assertion or crash fails
the calling test via the exit code.
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.assignment import (device_sample_order,
                                   distributed_live_bounds,
                                   plan_device_assignment)
from repro.core.schedule import (P_F, P_O, P_S, Schedule,
                                 gates_from_schedule, live_slice_bounds)
from repro.data.synthetic import lm_batches, microbatch_assignment
from repro.launch.diststep import measure_distributed_step
from repro.launch.mesh import make_data_mesh
from repro.models.transformer import init_model
from repro.optim.optimizers import sgd
from repro.train.loop import make_distributed_train_step, make_train_step


def max_leaf_diff(a, b):
    return max(jax.tree.leaves(jax.tree.map(
        lambda x, y: float(jnp.abs(x - y).max()), a, b)))


assert len(jax.devices()) == 8, jax.devices()

cfg = ModelConfig(name="parity", arch_type="dense", n_layers=4, d_model=64,
                  n_heads=4, n_kv_heads=4, d_ff=128, vocab_size=256)
G, L, N, B, S, K = 4, 4, 16, 32, 16, 8
rng = np.random.default_rng(0)
# iid mix stresses the sliced/stacked plan paths; then force one fully-dead
# layer (all p_o/p_s -> psum elided) and one fully-live layer (plain pmean)
table = rng.choice([P_F, P_O, P_S], size=(L * G, N),
                   p=[.4, .3, .3]).astype(np.int8)
table[0:G] = np.where(table[0:G] == P_F, P_O, table[0:G])
table[2 * G:3 * G] = P_F
sched = Schedule(table, L, G)

from repro.sharding.sync import grad_sync_plan, sync_byte_report

params = init_model(jax.random.PRNGKey(0), cfg)
opt = sgd(1e-2)       # linear in grads: parity is pure FP reordering noise
mesh = make_data_mesh(K)
batch = next(lm_batches(0, cfg.vocab_size, B, S, 1))
mb_of = microbatch_assignment(B, N)

assignment, report = plan_device_assignment(sched, K)
assert report["capacity_ok"] and len(set(report["counts"])) == 1, report
perm = device_sample_order(assignment, mb_of)
pbatch = jax.tree.map(lambda a: a[perm], batch)
gates = gates_from_schedule(sched, mb_of[perm])
plan = grad_sync_plan(params, cfg, sched)
assert sync_byte_report(plan, params)["fraction"] < 1.0

# ---- masked-path parity: 3 optimizer steps, distributed vs single device
step = make_distributed_train_step(cfg, opt, mesh, plan)
ref_step = jax.jit(make_train_step(cfg, opt, use_gates=True))
p_d, s_d = params, opt.init(params)
p_r, s_r = params, opt.init(params)
for _ in range(3):
    p_d, s_d, m_d = step(p_d, s_d, pbatch, gates)
    p_r, s_r, m_r = ref_step(p_r, s_r, pbatch, gates)
maxdiff = max_leaf_diff(p_d, p_r)
assert maxdiff <= 1e-6, f"masked-path params diverged: {maxdiff}"
assert abs(float(m_d["loss"]) - float(m_r["loss"])) <= 1e-5

# ---- kernel-path parity: compacted Pallas dispatch inside shard_map, with
# per-device live bounds vs the single-device step's global bounds
bounds = distributed_live_bounds(sched, mb_of, assignment)
gbounds = live_slice_bounds(sched, mb_of)
assert bounds[0] <= gbounds[0] and bounds[1] <= gbounds[1], (bounds, gbounds)
kstep = make_distributed_train_step(cfg, opt, mesh, plan, use_kernel=True,
                                    live_bounds=bounds)
kref = jax.jit(make_train_step(cfg, opt, use_gates=True, use_kernel=True,
                               live_bounds=gbounds))
pk, sk, mk = kstep(params, opt.init(params), pbatch, gates)
pr, sr, mr = kref(params, opt.init(params), pbatch, gates)
kdiff = max_leaf_diff(pk, pr)
assert kdiff <= 1e-6, f"kernel-path params diverged: {kdiff}"

# ---- comm accounting: paper-mix all-reduce bytes vs all-p_f baseline
rec = measure_distributed_step(K, time_steps=0)
frac = rec["all_reduce_fraction"]
base = rec["variants"]["all_pf_baseline"]["all_reduce_bytes"]
assert base > 0, rec
assert frac <= 0.60, f"all-reduce fraction {frac} above the paper target"

print(f"PARITY_OK maxdiff={maxdiff:.3e} kernel_maxdiff={kdiff:.3e} "
      f"all_reduce_fraction={frac:.4f} "
      f"sync_model_fraction={rec['sync_model_fraction']:.4f} "
      f"per_device_bounds={bounds[0]},{bounds[1]} "
      f"global_bounds={gbounds[0]},{gbounds[1]}")
