"""Config-zoo kernel coverage: every architecture in the zoo must complete
one ``use_kernel=True`` gated train step with NO silent fallback to a
masked / dense route.

The gated block kernel contract (kernels/contract.py) requires every
non-kernel route taken while ``use_kernel=True`` to announce itself via
``contract.report_fallback(kind, reason)``. This test arms the
``on_fallback`` hook across the whole zoo — attention, SSD, RG-LRU and
MoE blocks alike — and fails loudly listing the un-kerneled block types,
so a new architecture (or a head-grouping change that breaks H % G == 0)
cannot quietly regress to the masked path while appearing kernel-covered.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_smoke_config
from repro.core.schedule import P_F, P_O, P_S, Schedule, gates_from_schedule
from repro.data.synthetic import microbatch_assignment
from repro.kernels import contract
from repro.models.frontends import synth_features
from repro.models.transformer import init_model
from repro.optim.optimizers import sgd
from repro.train.loop import make_train_step

G, N = 4, 2     # gate groups / micro-batches; every zoo smoke config has
                # heads, SSD heads, LRU width and expert count divisible by 4


def _batch(cfg, B, S):
    key = jax.random.PRNGKey(7)
    batch = {}
    if cfg.frontend == "audio_stub":
        batch["features"] = synth_features(key, cfg, B, S)
        batch["labels"] = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    elif cfg.frontend == "vision_stub":
        s_text = S - cfg.frontend_tokens
        batch["features"] = synth_features(key, cfg, B, S)
        batch["tokens"] = jax.random.randint(key, (B, s_text), 0,
                                             cfg.vocab_size)
        batch["labels"] = jax.random.randint(key, (B, s_text), 0,
                                             cfg.vocab_size)
    else:
        batch["tokens"] = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
        batch["labels"] = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    return batch


def _mixed_schedule(L):
    """Every op type present so each kernel sees live, fwd-only and dead
    slices in a single step."""
    rng = np.random.default_rng(11)
    table = rng.choice([P_F, P_O, P_S], size=(L * G, N),
                       p=[.4, .3, .3]).astype(np.int8)
    table[0, 0] = P_F                  # at least one live backward slice
    return Schedule(table, L, G)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_zoo_kernel_train_step_no_silent_fallback(arch):
    cfg = get_smoke_config(arch)
    params = init_model(jax.random.PRNGKey(0), cfg)
    B, S = 2, 16
    batch = _batch(cfg, B, S)
    sched = _mixed_schedule(cfg.n_layers)
    gates = gates_from_schedule(sched, microbatch_assignment(B, N))

    fallbacks = []
    contract.on_fallback = lambda kind, reason: fallbacks.append(
        (kind, reason))
    try:
        opt = sgd(1e-2)
        step = jax.jit(make_train_step(cfg, opt, use_gates=True,
                                       use_kernel=True))
        p2, _, metrics = step(params, opt.init(params), batch, gates)
        jax.block_until_ready(p2)
    finally:
        contract.on_fallback = None

    kinds = sorted({k for k, _ in fallbacks})
    assert not fallbacks, (
        f"{arch}: use_kernel=True silently fell back for block types "
        f"{kinds}: {fallbacks[:4]}")
    assert np.isfinite(float(metrics["loss"])), arch
    leaf = jax.tree.leaves(p2)[0]
    assert bool(jnp.all(jnp.isfinite(leaf))), arch
