"""Pallas kernels vs pure-jnp oracles (interpret=True), shape/dtype sweeps."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.d2ft_attention import d2ft_flash_attention
from repro.kernels.lora_matmul import lora_matmul
from repro.kernels.ops import gated_attention, lora_linear
from repro.kernels.ref import d2ft_attention_ref, lora_matmul_ref


@pytest.mark.parametrize("S,hd,block", [(128, 64, 128), (256, 128, 128),
                                        (256, 64, 64), (512, 32, 128)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal,window", [(True, 0), (True, 64), (False, 0)])
def test_flash_attention_sweep(S, hd, block, dtype, causal, window):
    key = jax.random.PRNGKey(0)
    B, H = 2, 3
    ks = jax.random.split(key, 4)
    q = jax.random.normal(ks[0], (B, H, S, hd), dtype)
    k = jax.random.normal(ks[1], (B, H, S, hd), dtype)
    v = jax.random.normal(ks[2], (B, H, S, hd), dtype)
    gates = jnp.asarray([[1., 0, 1], [0, 1, 1]])
    out = d2ft_flash_attention(q, k, v, gates, causal=causal, window=window,
                               block_q=block, block_k=block, interpret=True)
    ref = d2ft_attention_ref(q, k, v, gates, causal=causal, window=window)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-5
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=tol,
                               rtol=tol)


def test_flash_attention_gate_zero_rows_are_zero():
    key = jax.random.PRNGKey(1)
    q = jax.random.normal(key, (2, 2, 128, 64))
    gates = jnp.asarray([[0., 1], [1., 0]])
    out = d2ft_flash_attention(q, q, q, gates, interpret=True)
    assert float(jnp.abs(out[0, 0]).max()) == 0.0
    assert float(jnp.abs(out[1, 1]).max()) == 0.0
    assert float(jnp.abs(out[0, 1]).max()) > 0.0


@pytest.mark.parametrize("M,K,N,r", [(256, 128, 256, 4), (512, 384, 512, 16),
                                     (256, 256, 512, 64)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_lora_matmul_sweep(M, K, N, r, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    x = jax.random.normal(ks[0], (M, K), dtype)
    w = jax.random.normal(ks[1], (K, N), dtype)
    a = jax.random.normal(ks[2], (K, r), dtype)
    b = jax.random.normal(ks[3], (r, N), dtype)
    y = lora_matmul(x, w, a, b, 0.5, interpret=True)
    ref = lora_matmul_ref(x, w, a, b, 0.5)
    tol = 2e-1 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(ref, np.float32),
                               atol=tol * np.abs(np.asarray(ref)).max(),
                               rtol=tol)


def test_ops_wrappers_jit():
    ks = jax.random.split(jax.random.PRNGKey(0), 4)
    x3 = jax.random.normal(ks[0], (2, 128, 128))
    w = jax.random.normal(ks[1], (128, 256))
    a = jax.random.normal(ks[2], (128, 8))
    b = jax.random.normal(ks[3], (8, 256))
    y = lora_linear(x3, w, a, b, 1.0)
    assert y.shape == (2, 128, 256)
    q = jax.random.normal(ks[0], (1, 2, 128, 64))
    g = jnp.ones((1, 2))
    o = gated_attention(q, q, q, g)
    assert o.shape == q.shape
