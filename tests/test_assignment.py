"""Multiple-knapsack device assigner (core/assignment.py): balance vs the
exhaustive oracle, capacity respect, determinism, and the schedule-bridging
helpers the distributed train step relies on."""
import itertools

import numpy as np

from repro.core.assignment import (DeviceAssignment, assign_microbatches,
                                   device_sample_order,
                                   distributed_live_bounds, microbatch_costs,
                                   plan_device_assignment, rebalance_report)
from repro.core.schedule import P_F, P_O, P_S, Schedule, live_slice_bounds
from repro.data.synthetic import microbatch_assignment


def oracle_makespan(costs, K, equal_counts=False):
    """Exhaustive minimum max-load over all K^N assignments."""
    costs = np.asarray(costs, np.float64)
    best = np.inf
    for combo in itertools.product(range(K), repeat=len(costs)):
        counts = np.bincount(combo, minlength=K)
        if equal_counts and len(set(counts)) != 1:
            continue
        loads = np.bincount(combo, weights=costs, minlength=K)
        best = min(best, float(loads.max()))
    return best


def test_balance_vs_bruteforce_oracle():
    rng = np.random.default_rng(0)
    for K, N in [(2, 7), (3, 8), (4, 8)]:
        for _ in range(5):
            costs = rng.uniform(0.1, 3.0, N).round(2)
            a = assign_microbatches(costs, K)
            opt = oracle_makespan(costs, K)
            # LPT guarantee is (4/3 - 1/3K) * OPT; refinement only improves
            bound = (4 / 3 - 1 / (3 * K)) * opt + 1e-9
            assert float(a.loads.max()) <= bound, (costs, a.loads, opt)
            assert float(a.loads.max()) >= opt - 1e-9


def test_equal_counts_balance():
    rng = np.random.default_rng(1)
    for K, N in [(2, 8), (4, 8)]:
        for _ in range(5):
            costs = rng.uniform(0.1, 3.0, N).round(2)
            a = assign_microbatches(costs, K, equal_counts=True)
            assert set(a.counts) == {N // K}
            opt = oracle_makespan(costs, K, equal_counts=True)
            assert float(a.loads.max()) <= 1.5 * opt + 1e-9


def test_refinement_improves_on_lpt():
    # classic LPT trap: [3,3,2,2,2] on 2 devices -> LPT gives (7, 5),
    # optimal is (6, 6); the swap refinement must find it
    costs = np.array([3.0, 3.0, 2.0, 2.0, 2.0])
    seed = assign_microbatches(costs, 2, refine_rounds=0)
    refined = assign_microbatches(costs, 2)
    assert float(seed.loads.max() - seed.loads.min()) == 2.0
    assert float(refined.loads.max() - refined.loads.min()) == 0.0


def test_dp_transfer_moves_subset():
    # the dp_knapsack transfer: max-loaded device sheds a subset-sum
    # closest-from-below to half the spread
    from repro.core.assignment import _dp_transfer
    device_of = np.array([0, 0, 0, 1])
    costs = np.array([2.0, 1.0, 1.0, 1.0])
    loads = np.array([4.0, 1.0])
    assert _dp_transfer(device_of, costs, loads, 0, 1, None, 100)
    np.testing.assert_allclose(sorted(loads), [2.0, 3.0])
    np.testing.assert_allclose(
        np.bincount(device_of, weights=costs, minlength=2), loads)


def test_refinement_never_hurts():
    rng = np.random.default_rng(3)
    for K in (2, 3):
        for _ in range(10):
            costs = rng.uniform(0.0, 3.0, 9)
            seed = assign_microbatches(costs, K, refine_rounds=0)
            ref = assign_microbatches(costs, K)
            spread = lambda a: float(a.loads.max() - a.loads.min())
            assert spread(ref) <= spread(seed) + 1e-9


def test_capacity_respected_and_reported():
    costs = np.array([1.0, 1.0, 1.0, 1.0, 2.0, 2.0])
    caps = np.array([4.0, 4.0])
    a = assign_microbatches(costs, 2, caps)
    rep = rebalance_report(a)
    assert rep["capacity_ok"]
    assert (a.loads <= caps + 1e-9).all()


def test_capacity_overflow_flagged_not_dropped():
    # infeasible capacities: every item must still execute somewhere and
    # the report must flag the overload instead of raising
    costs = np.full(4, 2.0)
    a = assign_microbatches(costs, 2, capacities=1.0)
    assert (a.device_of >= 0).all()
    rep = rebalance_report(a)
    assert not rep["capacity_ok"] and rep["overloaded_devices"]


def test_determinism():
    rng = np.random.default_rng(2)
    costs = rng.uniform(0.0, 2.0, 12)
    a1 = assign_microbatches(costs, 3)
    a2 = assign_microbatches(costs, 3)
    np.testing.assert_array_equal(a1.device_of, a2.device_of)
    # ties (equal costs) break on index, not dict/hash order
    a3 = assign_microbatches(np.ones(6), 3, equal_counts=True)
    a4 = assign_microbatches(np.ones(6), 3, equal_counts=True)
    np.testing.assert_array_equal(a3.device_of, a4.device_of)


def _toy_schedule():
    # 2 layers x 2 groups, 4 micro-batches with uneven per-mb cost
    table = np.array([
        [P_F, P_F, P_O, P_S],
        [P_F, P_O, P_O, P_S],
        [P_F, P_F, P_S, P_S],
        [P_F, P_O, P_S, P_O],
    ], np.int8)
    return Schedule(table, 2, 2)


def test_microbatch_costs():
    c = microbatch_costs(_toy_schedule(), c_f=0.4, c_b=0.6)
    np.testing.assert_allclose(c, [4.0, 2.8, 0.8, 0.4])


def test_plan_device_assignment_report():
    sched = _toy_schedule()
    a, rep = plan_device_assignment(sched, 2)
    assert rep["n_devices"] == 2 and rep["n_microbatches"] == 4
    assert set(a.counts) == {2}                      # equal_counts default
    # best equal-count split of [4.0, 2.8, 0.8, 0.4]: {4.0, 0.4} | {2.8, 0.8}
    assert abs(rep["spread"] - 0.8) < 1e-9


def test_device_sample_order_contiguous():
    sched = _toy_schedule()
    a, _ = plan_device_assignment(sched, 2)
    mb_of = microbatch_assignment(8, 4)              # 2 samples per mb
    perm = device_sample_order(a, mb_of)
    assert sorted(perm.tolist()) == list(range(8))
    shard = np.split(mb_of[perm], 2)
    for k in range(2):
        assert set(shard[k]) == set(a.items_of(k).tolist())


def test_distributed_live_bounds_tighter_than_global():
    sched = _toy_schedule()
    a, _ = plan_device_assignment(sched, 2)
    mb_of = microbatch_assignment(8, 4)
    lf, lb = distributed_live_bounds(sched, mb_of, a)
    glf, glb = live_slice_bounds(sched, mb_of)
    assert 0 < lf <= glf and 0 < lb <= glb
    # per-device bounds must cover each device's own live slices
    for k in range(2):
        local = mb_of[np.isin(mb_of, a.items_of(k))]
        klf, klb = live_slice_bounds(sched, local)
        assert klf <= lf and klb <= lb
