"""Cross-path parity matrix: every D2FT execution path must produce the
same optimizer trajectory as the masked reference path.

One parametrized test replaces the per-PR parity spot checks: the masked
(gate_mix) path is the semantic definition, and the Pallas kernel path,
the compacted kernel dispatch, the shard_map distributed step (masked and
ZeRO sync, on a 1-device mesh where every collective is the identity) and
the LoRA variants must all match it to <= 1e-6 over 3 SGD steps. The
8-device distributed parity (where collectives actually move bytes) lives
in tests/_dist_parity.py — this matrix pins the *path* semantics, that
test pins the *collective* semantics.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.lora import init_lora, merge_lora
from repro.core.schedule import (P_F, P_O, P_S, Schedule,
                                 gates_from_schedule, live_slice_bounds)
from repro.data.synthetic import lm_batches, microbatch_assignment
from repro.models.transformer import lm_loss, init_model
from repro.optim.optimizers import sgd
from repro.sharding.sync import SyncSpec, grad_sync_plan
from repro.train.loop import make_distributed_train_step, make_train_step

CFG = ModelConfig(name="matrix", arch_type="dense", n_layers=2, d_model=32,
                  n_heads=4, n_kv_heads=4, d_ff=64, vocab_size=128)
L, G, N, B, S = 2, 4, 4, 8, 8
STEPS, TOL = 3, 1e-6


def _schedule():
    """Mixed table: a dead subnet, a fully live subnet, partial layers —
    exercises none / sliced / stacked / zero specs and the gate logic."""
    rng = np.random.default_rng(7)
    table = rng.choice([P_F, P_O, P_S], size=(L * G, N),
                       p=[.4, .3, .3]).astype(np.int8)
    table[0] = P_O                          # layer 0 group 0: never backward
    table[G + 2] = P_F                      # layer 1 group 2: fully live
    return Schedule(table, L, G)


@pytest.fixture(scope="module")
def setup():
    sched = _schedule()
    params = init_model(jax.random.PRNGKey(0), CFG)
    batch = next(lm_batches(0, CFG.vocab_size, B, S, 1))
    mb_of = microbatch_assignment(B, N)
    gates = gates_from_schedule(sched, mb_of)
    bounds = live_slice_bounds(sched, mb_of)
    return sched, params, batch, gates, bounds


def _max_diff(a, b):
    return max(jax.tree.leaves(jax.tree.map(
        lambda x, y: float(jnp.abs(x - y).max()), a, b)))


def _run(step_fn, params, opt, batch, gates):
    p, s = params, opt.init(params)
    for _ in range(STEPS):
        p, s, _ = step_fn(p, s, batch, gates)
    return p


@pytest.fixture(scope="module")
def reference(setup):
    """Masked gated path — the semantic definition all paths must match."""
    _, params, batch, gates, _ = setup
    opt = sgd(1e-2)
    step = jax.jit(make_train_step(CFG, opt, use_gates=True))
    return _run(step, params, opt, batch, gates)


@pytest.mark.parametrize("path", ["kernel", "compacted", "dist_masked",
                                  "dist_zero", "dist_zero3",
                                  "dist_zero3_streamed"])
def test_parity_matrix(path, setup, reference):
    sched, params, batch, gates, bounds = setup
    opt = sgd(1e-2)
    if path == "kernel":
        step = jax.jit(make_train_step(CFG, opt, use_gates=True,
                                       use_kernel=True))
    elif path == "compacted":
        step = jax.jit(make_train_step(CFG, opt, use_gates=True,
                                       use_kernel=True, live_bounds=bounds))
    else:
        from repro.launch.mesh import make_data_mesh
        mesh = make_data_mesh(1)
        mode = {"dist_masked": "masked", "dist_zero": "zero",
                "dist_zero3": "zero3",
                "dist_zero3_streamed": "zero3"}[path]
        # the streamed arm also runs the chunked shard-resident optimizer
        # sweep (non-divisor chunk so the zero-padding path is exercised)
        streamed = path == "dist_zero3_streamed"
        plan = grad_sync_plan(params, CFG, sched, mode=mode, n_shards=1,
                              elide_gather=opt.elidable)
        step = make_distributed_train_step(CFG, opt, mesh, plan,
                                           sync_mode=mode, params=params,
                                           streamed=streamed,
                                           opt_chunk=(48 if streamed
                                                      else None))
        if mode == "zero3":
            # zero3 holds the params in the plan's shard layout between
            # steps; run layout-in, layout-out and compare canonically
            from repro.sharding.sync import zero_reshard
            got = _run(step, zero_reshard(params, None, plan), opt, batch,
                       gates)
            got = zero_reshard(got, plan, None)
            diff = _max_diff(got, reference)
            assert diff <= TOL, f"{path} diverged from reference: {diff}"
            return
    got = _run(step, params, opt, batch, gates)
    diff = _max_diff(got, reference)
    assert diff <= TOL, f"{path} diverged from masked reference: {diff}"


# ----------------------------------------------------------------- LoRA arm
def _make_lora_step(base, opt, use_kernel, cfg=CFG):
    def step(lora_p, st, batch, gates):
        def loss(lp):
            merged = merge_lora(base, lp, 1.0)
            return lm_loss(merged, cfg, batch["tokens"], batch["labels"],
                           gates=gates, use_kernel=use_kernel)[0]
        g = jax.grad(loss)(lora_p)
        return opt.update(g, st, lora_p)
    return jax.jit(step)


@pytest.fixture(scope="module")
def lora_reference(setup):
    _, params, batch, gates, _ = setup
    opt = sgd(1e-2)
    lora = init_lora(jax.random.PRNGKey(3), params, rank=2)
    step = _make_lora_step(params, opt, use_kernel=False)
    p, s = lora, opt.init(lora)
    for _ in range(STEPS):
        p, s = step(p, s, batch, gates)
    return lora, p


@pytest.mark.parametrize("path", ["lora_kernel", "lora_dist",
                                  "lora_dist_streamed"])
def test_parity_matrix_lora(path, setup, lora_reference):
    """LoRA arm: adapters-only gradients through the gated paths. The
    distributed variant runs the same adapter loss inside shard_map with a
    full-sync plan over the adapter tree (adapters have no head-group
    axis, so they never skip). The streamed variant additionally holds the
    frozen base in the ZeRO-3 shard layout and stream-materializes it
    under the schedule's gather mask before the merge — streaming must
    compose with adapters-only training."""
    sched, params, batch, gates, _ = setup
    lora0, ref = lora_reference
    opt = sgd(1e-2)
    if path == "lora_kernel":
        step = _make_lora_step(params, opt, use_kernel=True)
        p, s = lora0, opt.init(lora0)
        for _ in range(STEPS):
            p, s = step(p, s, batch, gates)
    else:
        from jax.experimental.shard_map import shard_map
        from jax.sharding import PartitionSpec as P

        from repro.launch.mesh import make_data_mesh
        from repro.sharding.sync import (apply_grad_sync, zero_reshard,
                                         zero3_stream_materialize)

        plan = jax.tree.map(lambda _: SyncSpec("all"), lora0)
        mesh = make_data_mesh(1)
        streamed = path == "lora_dist_streamed"
        if streamed:
            plan3 = grad_sync_plan(params, CFG, sched, mode="zero3",
                                   n_shards=1, elide_gather=opt.elidable)
            base_shards = zero_reshard(params, None, plan3)

        def local(lora_p, st, batch, gates):
            base = zero3_stream_materialize(base_shards, plan3, "data") \
                if streamed else params

            def loss(lp):
                merged = merge_lora(base, lp, 1.0)
                return lm_loss(merged, CFG, batch["tokens"],
                               batch["labels"], gates=gates)[0]
            g = jax.grad(loss)(lora_p)
            g = apply_grad_sync(g, plan, "data")
            return opt.update(g, st, lora_p)

        step = jax.jit(shard_map(
            local, mesh=mesh,
            in_specs=(P(), P(), P("data"), (P(None, "data"), P(None, "data"))),
            out_specs=(P(), P()), check_rep=False))
        p, s = lora0, opt.init(lora0)
        for _ in range(STEPS):
            p, s = step(p, s, batch, gates)
    diff = _max_diff(p, ref)
    assert diff <= TOL, f"{path} diverged from LoRA masked reference: {diff}"


# ------------------------------------------------ block-kernel arch matrix
# The dense matrix above pins the attention kernel; this arm pins the SSD
# (mamba2), RG-LRU (recurrentgemma) and MoE (olmoe) gated block kernels on
# real zoo configs: kernel and compacted dispatch must match the masked
# reference trajectory to <= 1e-6 over 3 SGD steps, with and without LoRA.
BLOCK_ARCHS = ["mamba2-130m", "recurrentgemma-2b", "olmoe-1b-7b"]


def _arch_schedule(L):
    rng = np.random.default_rng(13)
    table = rng.choice([P_F, P_O, P_S], size=(L * G, N),
                       p=[.4, .3, .3]).astype(np.int8)
    table[0, 0] = P_F                       # at least one live backward
    return Schedule(table, L, G)


@pytest.fixture(scope="module", params=BLOCK_ARCHS)
def arch_setup(request):
    from repro.configs import get_smoke_config
    cfg = get_smoke_config(request.param)
    sched = _arch_schedule(cfg.n_layers)
    params = init_model(jax.random.PRNGKey(0), cfg)
    batch = next(lm_batches(0, cfg.vocab_size, B, 16, 1))
    mb_of = microbatch_assignment(B, N)
    gates = gates_from_schedule(sched, mb_of)
    bounds = live_slice_bounds(sched, mb_of)
    opt = sgd(1e-2)
    ref_step = jax.jit(make_train_step(cfg, opt, use_gates=True))
    ref = _run(ref_step, params, opt, batch, gates)
    return cfg, params, batch, gates, bounds, ref


@pytest.mark.parametrize("path", ["kernel", "compacted"])
def test_block_arch_parity(path, arch_setup):
    cfg, params, batch, gates, bounds, ref = arch_setup
    opt = sgd(1e-2)
    step = jax.jit(make_train_step(
        cfg, opt, use_gates=True, use_kernel=True,
        live_bounds=bounds if path == "compacted" else None))
    got = _run(step, params, opt, batch, gates)
    diff = _max_diff(got, ref)
    assert diff <= TOL, (f"{cfg.name} {path} diverged from masked "
                         f"reference: {diff}")


def test_block_arch_parity_lora(arch_setup):
    cfg, params, batch, gates, _, _ = arch_setup
    opt = sgd(1e-2)
    # default targets are attention-only; add the SSD/RG-LRU/MoE in/out
    # projections so adapter grads flow through every gated block kernel
    lora0 = init_lora(jax.random.PRNGKey(3), params, rank=2,
                      targets=("wq", "wk", "wv", "w_in", "w_out", "w_up"))
    ref_step = _make_lora_step(params, opt, use_kernel=False, cfg=cfg)
    ker_step = _make_lora_step(params, opt, use_kernel=True, cfg=cfg)
    p_ref, s_ref = lora0, opt.init(lora0)
    p_ker, s_ker = lora0, opt.init(lora0)
    for _ in range(STEPS):
        p_ref, s_ref = ref_step(p_ref, s_ref, batch, gates)
        p_ker, s_ker = ker_step(p_ker, s_ker, batch, gates)
    diff = _max_diff(p_ker, p_ref)
    assert diff <= TOL, (f"{cfg.name} lora_kernel diverged from LoRA "
                         f"masked reference: {diff}")
