"""tools/ regression gates: check_bench key resolution + error reporting.

The bench gate's failure mode that matters is the MISSING key: a renamed
metric (or a typo'd baseline path) must produce a message that names the
failing dotted-path component, the bench file, and what keys WERE
available at that level — not a bare KeyError — or every rename turns
into a dig through two JSON files.
"""
import importlib.util
import json
import os
import subprocess
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _load_check_bench():
    spec = importlib.util.spec_from_file_location(
        "check_bench", os.path.join(ROOT, "tools", "check_bench.py"))
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


cb = _load_check_bench()

DOC = {"zero3": {"residency_fraction": 0.33, "peak_unit": "wq"},
       "mixes": [{"executed_flop_fraction": 1.0},
                 {"executed_flop_fraction": 0.54}],
       "top": 3}


def test_resolve_walks_dicts_and_lists():
    assert cb.resolve(DOC, "zero3.residency_fraction") == 0.33
    assert cb.resolve(DOC, "mixes.1.executed_flop_fraction") == 0.54
    assert cb.resolve(DOC, "top") == 3


def test_resolve_missing_key_names_component_and_available():
    with pytest.raises(cb.ResolveError) as e:
        cb.resolve(DOC, "zero3.residency_fractoin")
    msg = e.value.args[0]
    assert "'residency_fractoin'" in msg          # the failing component
    assert "after zero3" in msg                   # where the walk stopped
    assert "peak_unit" in msg and "residency_fraction" in msg  # candidates


def test_resolve_bad_list_index_reports_length():
    with pytest.raises(cb.ResolveError) as e:
        cb.resolve(DOC, "mixes.7.executed_flop_fraction")
    assert "list of length 2" in e.value.args[0]
    with pytest.raises(cb.ResolveError) as e:
        cb.resolve(DOC, "mixes.notanint")
    assert "'notanint'" in e.value.args[0]


def test_resolve_descend_into_leaf_fails():
    with pytest.raises(cb.ResolveError) as e:
        cb.resolve(DOC, "top.deeper")
    msg = e.value.args[0]
    assert "'deeper'" in msg and "after top" in msg
    assert "leaf of type int" in msg


def test_check_key_missing_message_carries_bench_file():
    ok, msg = cb.check_key(DOC, "zero3.nope", {"max": 1.0},
                           "BENCH_x.json")
    assert not ok
    assert "MISSING" in msg and "BENCH_x.json" in msg
    assert "'nope'" in msg and "available keys" in msg


def test_check_key_bounds_and_drift():
    ok, _ = cb.check_key(DOC, "zero3.residency_fraction", {"max": 0.5})
    assert ok
    ok, msg = cb.check_key(DOC, "zero3.residency_fraction", {"max": 0.1})
    assert not ok and "> max" in msg
    ok, msg = cb.check_key(DOC, "zero3.residency_fraction",
                           {"value": 0.5, "tol": 0.05})
    assert not ok and "drifted" in msg
    ok, msg = cb.check_key(DOC, "zero3.peak_unit", {"min": 0})
    assert not ok and "not a number" in msg


def test_check_bench_cli_missing_key_exit_and_message(tmp_path):
    """End-to-end: a baseline pointing at a renamed metric fails with the
    component + available-keys diagnosis on stdout and exit code 1."""
    fresh = tmp_path / "BENCH_demo.json"
    fresh.write_text(json.dumps({"metrics": {"new_name": 1.0}}))
    base = tmp_path / "baselines.json"
    base.write_text(json.dumps(
        {"BENCH_demo.json": {"metrics.old_name": {"min": 0.5}}}))
    proc = subprocess.run(
        [sys.executable, os.path.join(ROOT, "tools", "check_bench.py"),
         "--baselines", str(base), "--dir", str(tmp_path)],
        capture_output=True, text=True)
    assert proc.returncode == 1
    assert "MISSING" in proc.stdout
    assert "'old_name'" in proc.stdout
    assert "new_name" in proc.stdout            # the available key


def test_check_bench_cli_passes_on_good_baseline(tmp_path):
    fresh = tmp_path / "BENCH_demo.json"
    fresh.write_text(json.dumps({"metrics": {"frac": 0.4}}))
    base = tmp_path / "baselines.json"
    base.write_text(json.dumps(
        {"BENCH_demo.json":
         {"metrics.frac": {"value": 0.4, "tol": 0.01, "max": 0.6}}}))
    proc = subprocess.run(
        [sys.executable, os.path.join(ROOT, "tools", "check_bench.py"),
         "--baselines", str(base), "--dir", str(tmp_path)],
        capture_output=True, text=True)
    assert proc.returncode == 0, proc.stdout + proc.stderr
    assert "PASS" in proc.stdout


def test_check_docs_requires_the_doc_set():
    """docs/robustness.md (and the rest of the REQUIRED set) must exist —
    the checker fails loudly when one is deleted or renamed."""
    spec = importlib.util.spec_from_file_location(
        "check_docs", os.path.join(ROOT, "tools", "check_docs.py"))
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    assert "docs/robustness.md" in mod.REQUIRED
    for r in mod.REQUIRED:
        assert os.path.exists(os.path.join(ROOT, r)), r
