"""Elastic fault-tolerance tests (train/elastic.py, launch/faults.py).

Fast single-device units run in tier 1; the 8-emulated-device fault
matrix (``-m faults``) runs in its own CI lane via a subprocess, like the
distributed parity test — the forced host-device count must be set before
jax initializes.
"""
import os
import subprocess
import sys
import tempfile

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs.base import D2FTConfig, ModelConfig
from repro.core.assignment import (microbatch_costs, plan_device_assignment,
                                   speed_capacities, weighted_makespan)
from repro.core.schedule import P_F, P_O, P_S, Schedule
from repro.data.synthetic import lm_batches
from repro.launch.faults import NO_FAULTS, FaultPlan, random_fault_plan
from repro.launch.mesh import make_data_mesh
from repro.models.transformer import init_model
from repro.optim.optimizers import adamw, sgd
from repro.sharding.sync import (grad_sync_plan, lofi_merge, stack_replicas)
from repro.train.elastic import (ElasticConfig, feasible_survivor_count,
                                 finetune_elastic)
from repro.train.loop import finetune_distributed

CFG = ModelConfig(name="elastic", arch_type="dense", n_layers=2, d_model=32,
                  n_heads=4, n_kv_heads=4, d_ff=64, vocab_size=128)
D2 = D2FTConfig(n_microbatches=4, n_pf=2, n_po=1, head_groups=4)
TOL = 1e-6


def _batches(n, seed=0):
    return list(lm_batches(seed, CFG.vocab_size, batch=8, seq=16, steps=n))


def _maxdiff(a, b):
    return max(jax.tree.leaves(jax.tree.map(
        lambda x, y: float(jnp.abs(x - y).max()), a, b)))


def _params():
    return init_model(jax.random.PRNGKey(0), CFG)


# ------------------------------------------------------------ fault plans
def test_fault_plan_queries():
    fp = FaultPlan(slowdowns=((3, 2.0),), slowdown_start=2,
                   dropout=(5, 1), grad_faults=((4, 0, float("nan")),),
                   dropped_syncs=(6, 7))
    assert fp.unit_times(0, 4).tolist() == [1, 1, 1, 1]   # not started yet
    assert fp.unit_times(2, 4).tolist() == [1, 1, 1, 2.0]
    assert fp.unit_times(2, 2).tolist() == [1, 1]         # device out of range
    v = fp.grad_fault_vector(4, 4)
    assert np.isnan(v[0]) and v[1:].tolist() == [1, 1, 1]
    assert fp.grad_fault_vector(3, 4).tolist() == [1, 1, 1, 1]
    assert fp.dropout_at(5) == 1 and fp.dropout_at(4) is None
    assert fp.sync_dropped(6) and not fp.sync_dropped(5)
    assert fp.any_faults() and not NO_FAULTS.any_faults()


def test_fault_plan_json_roundtrip():
    fp = FaultPlan(seed=3, slowdowns=((0, 1.5), (2, 2.25)),
                   slowdown_start=1, dropout=(4, 2),
                   grad_faults=((3, 1, float("inf")),), dropped_syncs=(2,))
    rt = FaultPlan.from_json(fp.to_json())
    assert rt.to_json() == fp.to_json()
    assert rt.slowdowns == fp.slowdowns and rt.dropout == fp.dropout


def test_random_fault_plan_deterministic():
    a = random_fault_plan(7, steps=20, n_devices=8, p_dropout=1.0)
    b = random_fault_plan(7, steps=20, n_devices=8, p_dropout=1.0)
    # NaN != NaN inside the tuples, so compare the canonical JSON forms
    assert a.to_json() == b.to_json() and a.dropout is not None
    other = random_fault_plan(8, steps=20, n_devices=8, p_dropout=1.0)
    assert a.to_json() != other.to_json()
    lo, hi = 20 // 4 + 1, 3 * 20 // 4
    assert lo <= a.dropout[0] < hi


# ------------------------------------------------- straggler capacities
def test_speed_capacities_shift_load():
    rng = np.random.default_rng(0)
    table = rng.choice([P_F, P_O, P_S], size=(8, 16),
                       p=[.4, .3, .3]).astype(np.int8)
    sched = Schedule(table, 2, 4)
    costs = microbatch_costs(sched)
    u = np.ones(8)
    u[3] = 2.0
    caps = speed_capacities(costs, u, slack=1.1)
    # straggler budget is half a healthy device's; total stays feasible
    assert caps[3] == pytest.approx(caps[0] / 2.0)
    assert caps.sum() == pytest.approx(1.1 * costs.sum())
    base, _ = plan_device_assignment(sched, 8, None)
    mit, _ = plan_device_assignment(sched, 8, caps)
    assert weighted_makespan(mit, u) < weighted_makespan(base, u)
    # equal_counts preserved under capacities (shard_map needs it)
    assert len(set(mit.counts.tolist())) == 1


def test_feasible_survivor_count():
    assert feasible_survivor_count(8, 16) == 4
    assert feasible_survivor_count(8, 8) == 4
    assert feasible_survivor_count(4, 12) == 3
    assert feasible_survivor_count(2, 7) == 1
    assert feasible_survivor_count(1, 8) == 1


# ------------------------------------------------------------ lo-fi merge
def test_lofi_merge_semantics():
    """Live slices average across replicas; dead slices pass through
    replica 0 bit-identically."""
    params = _params()
    table = np.full((2 * 4, 4), P_S, np.int8)
    table[4 + 2] = P_F                       # only layer 1 group 2 live
    sched = Schedule(table, 2, 4)
    plan = grad_sync_plan(params, CFG, sched)
    stacked = stack_replicas(params, 3)
    # make the replicas diverge everywhere
    stacked = jax.tree.map(
        lambda x: x + jnp.arange(3, dtype=x.dtype).reshape(
            (3,) + (1,) * (x.ndim - 1)), stacked)
    merged = lofi_merge(stacked, plan)
    # protected loss-path leaves ("all" specs) average: +0,+1,+2 -> +1
    assert _maxdiff(merged["embed"],
                    jax.tree.map(lambda x: x + 1.0, params["embed"])) < 1e-5
    # a dead subnet's wq slice is replica 0's copy (offset +0), untouched
    wq = np.asarray(merged["cycles"][0]["attn"]["wq"])
    ref = np.asarray(params["cycles"][0]["attn"]["wq"])
    G = 4
    gsize = wq.shape[-1] // G
    # layer 0 (cycle 0) is fully dead -> all its groups equal replica 0
    assert np.array_equal(wq[0], ref[0])
    # layer 1 (cycle 1): live group 2 averaged (+1), dead groups replica 0
    assert np.array_equal(wq[1][:, :2 * gsize], ref[1][:, :2 * gsize])
    live = wq[1][:, 2 * gsize:3 * gsize]
    assert np.allclose(live, ref[1][:, 2 * gsize:3 * gsize] + 1.0, atol=1e-5)


# ------------------------------------------------ guarded step (1 device)
def test_guard_skips_nan_burst():
    mesh = make_data_mesh(1)
    fp = FaultPlan(grad_faults=((1, 0, float("nan")),))
    el = ElasticConfig(ckpt_every=0, ckpt_dir=tempfile.mkdtemp())
    params = _params()
    p_f, _, log = finetune_elastic(params, CFG, D2, sgd(0.1), _batches(4),
                                   steps=4, mesh=mesh, faults=fp, elastic=el)
    events = log.extras["elastic"]["events"]
    assert [e["step"] for e in events if e["type"] == "guard_skip"] == [1]
    assert log.extras["elastic"]["guard_skips"] == 1
    assert all(np.isfinite(v) for v in log.losses)
    assert all(bool(np.isfinite(np.asarray(x)).all())
               for x in jax.tree.leaves(p_f))
    # the skipped step must be a true no-op: a clean run with that batch
    # removed walks the same trajectory
    clean = _batches(4)
    del clean[1]
    el = ElasticConfig(ckpt_every=0, ckpt_dir=tempfile.mkdtemp())
    p_c, _, _ = finetune_elastic(_params(), CFG, D2, sgd(0.1), clean,
                                 steps=3, mesh=mesh, elastic=el)
    assert _maxdiff(p_f, p_c) <= TOL


def test_elastic_no_faults_matches_distributed():
    """Guard armed with an all-ones fault vector is numerically inert:
    the elastic loop without faults IS finetune_distributed."""
    mesh = make_data_mesh(1)
    for mode in ("masked", "zero", "zero3"):
        ref, _, _ = finetune_distributed(
            _params(), CFG, D2, sgd(0.1), _batches(5), steps=4, mesh=mesh,
            sync_mode=mode, refresh_every=3)
        el = ElasticConfig(refresh_every=3, ckpt_every=0,
                           ckpt_dir=tempfile.mkdtemp())
        got, _, _ = finetune_elastic(
            _params(), CFG, D2, sgd(0.1), _batches(5), steps=4, mesh=mesh,
            sync_mode=mode, elastic=el)
        assert _maxdiff(ref, got) <= TOL, mode


def test_lofi_fallback_after_dropped_syncs():
    mesh = make_data_mesh(1)
    fp = FaultPlan(dropped_syncs=(1, 2))
    el = ElasticConfig(ckpt_every=0, merge_every=2, sync_fault_threshold=2,
                       ckpt_dir=tempfile.mkdtemp())
    p, _, log = finetune_elastic(_params(), CFG, D2, sgd(0.1), _batches(7),
                                 steps=7, mesh=mesh, faults=fp, elastic=el)
    ev = log.extras["elastic"]
    kinds = [e["type"] for e in ev["events"]]
    assert kinds.count("sync_drop") == 2
    assert "lofi_fallback" in kinds and ev["final_mode"] == "local"
    assert ev["merges"] >= 1
    # dropped steps log no loss: 7 steps - 2 dropped
    assert len(log.losses) == 5
    assert all(bool(np.isfinite(np.asarray(x)).all())
               for x in jax.tree.leaves(p))


# -------------------------------- checkpoint round-trip / resume parity
@pytest.mark.parametrize("mode", ["masked", "zero", "zero3", "local"])
def test_resume_parity(mode):
    """Satellite acceptance: save mid-run, resume from the step-level
    checkpoint, and match the uninterrupted run <= 1e-6 (params AND
    optimizer state) on every sync mode, refresh crossing included."""
    mesh = make_data_mesh(1)
    opt = adamw(1e-3)
    ck_dir = tempfile.mkdtemp()
    el = ElasticConfig(refresh_every=3, ckpt_every=1, merge_every=2,
                       ckpt_dir=ck_dir)
    p_full, s_full, _ = finetune_elastic(
        _params(), CFG, D2, opt, _batches(6), steps=5, mesh=mesh,
        sync_mode=mode, elastic=el)
    el2 = ElasticConfig(refresh_every=3, ckpt_every=1, merge_every=2,
                        ckpt_dir=tempfile.mkdtemp())
    p_res, s_res, _ = finetune_elastic(
        _params(), CFG, D2, opt, _batches(6), steps=5, mesh=mesh,
        sync_mode=mode, elastic=el2,
        resume_from=os.path.join(ck_dir, "ckpt_2.npz"))
    assert _maxdiff(p_full, p_res) <= TOL, mode
    assert _maxdiff(s_full, s_res) <= TOL, mode


def test_checkpoint_keeps_empty_containers():
    """A config with no remainder blocks has params["rest"] == []; the
    npz round-trip must preserve it (dropout recovery reloads params into
    code that indexes every top-level key)."""
    from repro.train.checkpoints import load_checkpoint, save_checkpoint
    tree = {"rest": [], "empty": {}, "x": np.ones(2)}
    path = os.path.join(tempfile.mkdtemp(), "ck.npz")
    save_checkpoint(path, tree)
    back = load_checkpoint(path)
    assert back["rest"] == [] and back["empty"] == {}
    assert np.array_equal(np.asarray(back["x"]), tree["x"])


def test_load_checkpoint_validates_template():
    from repro.train.checkpoints import load_checkpoint, save_checkpoint
    path = os.path.join(tempfile.mkdtemp(), "ck.npz")
    save_checkpoint(path, {"a": np.ones((2, 3)), "b": {"c": np.zeros(4)}})
    ok = load_checkpoint(path, template={"a": np.zeros((2, 3)),
                                         "b": {"c": np.zeros(4)}})
    assert np.asarray(ok["a"]).shape == (2, 3)
    with pytest.raises(ValueError, match=r"shape mismatch.*a"):
        load_checkpoint(path, template={"a": np.zeros((9, 9)),
                                        "b": {"c": np.zeros(4)}})
    with pytest.raises(ValueError, match=r"missing.*b/d"):
        load_checkpoint(path, template={"a": np.zeros((2, 3)),
                                        "b": {"c": np.zeros(4),
                                              "d": np.zeros(1)}})
    with pytest.raises(ValueError, match="unexpected"):
        load_checkpoint(path, template={"a": np.zeros((2, 3))})


def test_train_state_roundtrip():
    from repro.core.assignment import DeviceAssignment
    from repro.train.checkpoints import load_train_state, save_train_state
    params = _params()
    opt = adamw(1e-3)
    state = opt.init(params)
    table = np.full((2 * 4, 4), P_F, np.int8)
    sched = Schedule(table, 2, 4)
    assignment = DeviceAssignment(np.array([0, 1, 0, 1]),
                                  np.array([1.0, 2.0, 3.0, 4.0]), 2,
                                  np.array([5.0, 6.0]))
    rng = np.asarray(jax.random.PRNGKey(7))
    path = os.path.join(tempfile.mkdtemp(), "state.npz")
    save_train_state(path, step=11, params=params, opt_state=state,
                     sched=sched, assignment=assignment, rng=rng,
                     extra={"speeds": np.ones(4), "sync_faults": 2})
    back = load_train_state(path, params_template=params)
    assert back["step"] == 11
    assert _maxdiff(back["params"], params) == 0
    assert _maxdiff(back["opt_state"], state) == 0
    assert np.array_equal(back["schedule"].table, sched.table)
    a = back["assignment"]
    assert np.array_equal(a.device_of, assignment.device_of)
    assert np.array_equal(a.capacities, assignment.capacities)
    assert np.array_equal(back["rng"], rng)
    assert int(back["extra"]["sync_faults"]) == 2


# --------------------------------------------------- 8-device fault matrix
@pytest.mark.faults
def test_fault_matrix_8dev_subprocess():
    """Acceptance matrix on 8 emulated devices: straggler mitigation,
    dropout recovery parity, NaN-burst guard, lo-fi fallback. Runs in a
    fresh interpreter (host-device count must be set before jax init);
    ``-m faults``: its own CI lane with its own wall-clock budget."""
    script = os.path.join(os.path.dirname(__file__), "_fault_matrix.py")
    env = dict(os.environ,
               PYTHONPATH=os.pathsep.join(
                   [os.path.join(os.path.dirname(__file__), "..", "src")]
                   + ([os.environ["PYTHONPATH"]]
                      if "PYTHONPATH" in os.environ else [])))
    proc = subprocess.run([sys.executable, script], env=env,
                          capture_output=True, text=True, timeout=1200)
    assert proc.returncode == 0, \
        f"stdout:\n{proc.stdout}\nstderr:\n{proc.stderr}"
    assert "FAULTS_OK" in proc.stdout, proc.stdout
