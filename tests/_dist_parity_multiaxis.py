"""Subprocess body for the 8-device MULTI-AXIS parity test.

Same contract as tests/_dist_parity.py (fresh interpreter, forced host
device count, one PARITY_OK line on success) but over (data, stage,
tensor) meshes: every multi-axis arm must reproduce the single-device
masked gated reference trajectory to <= 1e-6 over 3 SGD steps.

Arms:
* (data=4, tensor=2)           — Megatron TP heads/columns, masked sync
* (data=4, tensor=2) + ZeRO-3  — TP composed with fully-sharded params
* (data=2, stage=2)            — GPipe pipeline, live-cost stage packing
* (data=2, stage=2, tensor=2)  — all three axes at once (8 devices)
* (data=4, tensor=2) + LoRA    — adapters-only grads through the TP path
"""
import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.assignment import plan_stage_assignment
from repro.core.lora import init_lora, merge_lora
from repro.core.schedule import P_F, P_O, P_S, Schedule, gates_from_schedule
from repro.data.synthetic import lm_batches, microbatch_assignment
from repro.launch.parallel import MeshSpec, ParallelConfig
from repro.models.transformer import init_model, lm_loss
from repro.optim.optimizers import sgd
from repro.sharding.sync import (SyncSpec, apply_grad_sync, grad_sync_plan,
                                 zero_reshard)
from repro.train.loop import make_distributed_train_step, make_train_step
from repro.train.pipeline import PipelineRecorder, analytic_bubble_fraction


def max_leaf_diff(a, b):
    return max(jax.tree.leaves(jax.tree.map(
        lambda x, y: float(jnp.abs(x - y).max()), a, b)))


assert len(jax.devices()) == 8, jax.devices()

cfg = ModelConfig(name="multiaxis", arch_type="dense", n_layers=4,
                  d_model=64, n_heads=4, n_kv_heads=4, d_ff=128,
                  vocab_size=256)
G, L, N, B, S = 4, 4, 16, 32, 16
STEPS, TOL = 3, 1e-6
rng = np.random.default_rng(0)
table = rng.choice([P_F, P_O, P_S], size=(L * G, N),
                   p=[.4, .3, .3]).astype(np.int8)
table[0:G] = np.where(table[0:G] == P_F, P_O, table[0:G])   # dead layer
table[2 * G:3 * G] = P_F                                    # live layer
sched = Schedule(table, L, G)

params = init_model(jax.random.PRNGKey(0), cfg)
opt = sgd(1e-2)
batch = next(lm_batches(0, cfg.vocab_size, B, S, 1))
mb_of = microbatch_assignment(B, N)
gates = gates_from_schedule(sched, mb_of)
plan = grad_sync_plan(params, cfg, sched)


def run(step_fn, p0):
    p, s = p0, opt.init(p0)
    for _ in range(STEPS):
        p, s, m = step_fn(p, s, batch, gates)
    return p, m


# ---- single-device masked reference --------------------------------------
ref_step = jax.jit(make_train_step(cfg, opt, use_gates=True))
p_ref, m_ref = run(ref_step, params)

# ---- (data=4, tensor=2): Megatron TP inside shard_map --------------------
spec_tp = MeshSpec(data=4, tensor=2)
mesh_tp = spec_tp.build()
step_tp = make_distributed_train_step(
    cfg, opt, mesh_tp, plan, parallel=ParallelConfig(mesh=spec_tp))
p_tp, m_tp = run(step_tp, params)
tp_diff = max_leaf_diff(p_tp, p_ref)
assert tp_diff <= TOL, f"(data=4,tensor=2) diverged: {tp_diff}"
assert abs(float(m_tp["loss"]) - float(m_ref["loss"])) <= 1e-5

# ---- (data=4, tensor=2) + ZeRO-3: TP composed with sharded params --------
plan3 = grad_sync_plan(params, cfg, sched, mode="zero3", n_shards=4)
step_z3 = make_distributed_train_step(
    cfg, opt, mesh_tp, plan3,
    parallel=ParallelConfig(mesh=spec_tp, sync_mode="zero3"), params=params)
p_z3, m_z3 = run(step_z3, zero_reshard(params, None, plan3))
z3_diff = max_leaf_diff(zero_reshard(p_z3, plan3, None), p_ref)
assert z3_diff <= TOL, f"(data=4,tensor=2)+zero3 diverged: {z3_diff}"

# ---- (data=2, stage=2): GPipe pipeline, schedule-balanced stages ---------
spec_pp = MeshSpec(data=2, stage=2)
mesh_pp = spec_pp.build()
stage_assign, stage_rep = plan_stage_assignment(sched, 2)
recorder = PipelineRecorder()
step_pp = make_distributed_train_step(
    cfg, opt, mesh_pp, plan,
    parallel=ParallelConfig(mesh=spec_pp, microbatches=4),
    stage_assignment=stage_assign, pipeline_recorder=recorder)
p_pp, m_pp = run(step_pp, params)
pp_diff = max_leaf_diff(p_pp, p_ref)
assert pp_diff <= TOL, f"(data=2,stage=2) diverged: {pp_diff}"
assert abs(float(m_pp["loss"]) - float(m_ref["loss"])) <= 1e-5
trace = recorder.report()
assert trace["trace_ok"], trace
bubble = analytic_bubble_fraction(stage_assign.loads, 4)
assert 0.0 <= bubble < 1.0, bubble

# ---- (data=2, stage=2, tensor=2): all three axes at once -----------------
spec_all = MeshSpec(data=2, stage=2, tensor=2)
mesh_all = spec_all.build()
step_all = make_distributed_train_step(
    cfg, opt, mesh_all, plan,
    parallel=ParallelConfig(mesh=spec_all, microbatches=4),
    stage_assignment=stage_assign)
p_all, m_all = run(step_all, params)
all_diff = max_leaf_diff(p_all, p_ref)
assert all_diff <= TOL, f"(data=2,stage=2,tensor=2) diverged: {all_diff}"

# ---- (data=4, tensor=2) + LoRA: adapters-only grads through TP -----------
from jax.experimental.shard_map import shard_map
from jax.sharding import PartitionSpec as P

lora0 = init_lora(jax.random.PRNGKey(3), params, rank=2)
lplan = jax.tree.map(lambda _: SyncSpec("all"), lora0)


def lora_ref_step(lora_p, st, batch, gates):
    def loss(lp):
        return lm_loss(merge_lora(params, lp, 1.0), cfg, batch["tokens"],
                       batch["labels"], gates=gates)[0]
    g = jax.grad(loss)(lora_p)
    return opt.update(g, st, lora_p)


def lora_tp_local(lora_p, st, batch, gates):
    def loss(lp):
        return lm_loss(merge_lora(params, lp, 1.0), cfg, batch["tokens"],
                       batch["labels"], gates=gates, tp=("tensor", 2))[0]
    g = jax.grad(loss)(lora_p)
    # adapter grads arrive through the device's merged-weight slice — the
    # tensor psum reassembles them before the usual data-axis sync
    g = jax.tree.map(lambda x: jax.lax.psum(x, "tensor"), g)
    g = apply_grad_sync(g, lplan, "data")
    return opt.update(g, st, lora_p)


lora_tp_step = jax.jit(shard_map(
    lora_tp_local, mesh=mesh_tp,
    in_specs=(P(), P(), P("data"), (P(None, "data"), P(None, "data"))),
    out_specs=(P(), P()), check_rep=False))
jref = jax.jit(lora_ref_step)
p_lr, s_lr = lora0, opt.init(lora0)
p_lt, s_lt = lora0, opt.init(lora0)
for _ in range(STEPS):
    p_lr, s_lr = jref(p_lr, s_lr, batch, gates)
    p_lt, s_lt = lora_tp_step(p_lt, s_lt, batch, gates)
lora_diff = max_leaf_diff(p_lt, p_lr)
assert lora_diff <= TOL, f"(data=4,tensor=2)+LoRA diverged: {lora_diff}"

print(f"PARITY_OK tp={tp_diff:.2e} tp_zero3={z3_diff:.2e} "
      f"pipe={pp_diff:.2e} all3={all_diff:.2e} lora_tp={lora_diff:.2e} "
      f"boundaries={stage_rep['boundaries']} "
      f"makespan_ratio={stage_rep['makespan_ratio']:.3f} "
      f"bubble={bubble:.3f} rounds={trace['n_rounds']} "
      f"sends={trace['n_sends']}")
