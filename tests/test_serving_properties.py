"""Property-based invariants for the paged-KV page manager and the request
packer (hypothesis; skipped cleanly when it is not installed — CI installs
it via requirements.txt, see conftest.optional_hypothesis).

* random admit/append/free traces keep the ``PageManager`` invariants at
  every step: no page owned twice, the null page never handed out, pages
  conserved (owned + free == capacity), reservations within the free pool,
  table sizes consistent with lengths;
* alloc/free is a bijection: evicting a sequence returns exactly the pages
  it was ever given, and draining everything restores the full free pool
  in the same set (LIFO discipline aside);
* ``can_admit`` is exact under reservations: an admit gated on it never
  raises, and growth after admission (within the reserved worst case)
  never fails;
* ``plan_waves`` partitions the request indices exactly once, never
  overflows the page budget or the slot count per wave, and is
  deterministic;
* the full ``PagedServingEngine`` under a randomized admission/evict
  fault trace (requests cancelled mid-flight at arbitrary points) keeps
  the page pool consistent at every step and reclaims an evicted
  sequence's pages exactly — the serving-side analogue of the elastic
  trainer's fault injection (docs/robustness.md).
"""
import numpy as np
import pytest

from conftest import optional_hypothesis

given, settings, st = optional_hypothesis()

import jax

from repro.configs.base import ModelConfig
from repro.models.transformer import init_model
from repro.serving.engine import PagedServingEngine, Request
from repro.serving.packer import plan_waves, worst_case_pages
from repro.serving.pages import PageManager, pages_needed


@st.composite
def traces(draw):
    """(n_pages, page_size, ops) — ops interleave admit/append/free with
    caller-side can_admit gating, the engine's usage pattern."""
    n_pages = draw(st.integers(2, 24))
    page_size = draw(st.integers(1, 8))
    ops = draw(st.lists(
        st.one_of(
            st.tuples(st.just("admit"), st.integers(0, 30),
                      st.integers(0, 12)),     # prompt tokens, extra budget
            st.tuples(st.just("append"), st.integers(0, 5)),  # victim rank
            st.tuples(st.just("free"), st.integers(0, 5)),
        ), min_size=1, max_size=40))
    return n_pages, page_size, ops


@settings(max_examples=60, deadline=None)
@given(traces())
def test_page_manager_trace_invariants(trace):
    n_pages, page_size, ops = trace
    pm = PageManager(n_pages=n_pages, page_size=page_size)
    granted = {}                       # seq -> set of pages ever granted
    next_id = 0
    for op in ops:
        if op[0] == "admit":
            _, n_tokens, extra = op
            worst = n_tokens + extra
            if pm.can_admit(worst):
                pages = pm.admit(next_id, n_tokens, worst)
                assert len(pages) == pages_needed(n_tokens, page_size)
                granted[next_id] = set(pages)
                next_id += 1
        elif op[0] == "append":
            live = sorted(pm.tables)
            if live:
                sid = live[op[1] % len(live)]
                # growth within the admitted worst case cannot fail
                if pm.reserved[sid] > 0 or pages_needed(
                        pm.lengths[sid] + 1, page_size) <= \
                        len(pm.tables[sid]):
                    page = pm.append_token(sid)
                    if page is not None:
                        assert page not in granted[sid]
                        granted[sid].add(page)
        elif op[0] == "free":
            live = sorted(pm.tables)
            if live:
                sid = live[op[1] % len(live)]
                freed = pm.free_seq(sid)
                # alloc/free bijection: exactly the pages ever granted
                assert set(freed) == granted.pop(sid)
        pm.check()
    # drain: every sequence returns its pages; the pool is whole again
    for sid in sorted(pm.tables):
        assert set(pm.free_seq(sid)) == granted.pop(sid)
        pm.check()
    assert pm.n_free == pm.capacity
    assert pm.n_reserved == 0


@settings(max_examples=60, deadline=None)
@given(st.integers(2, 40), st.integers(1, 8),
       st.lists(st.integers(0, 40), min_size=0, max_size=12))
def test_can_admit_is_exact(n_pages, page_size, sizes):
    """Admission gated on can_admit never raises, and its verdict matches
    first-principles accounting of free minus reserved pages."""
    pm = PageManager(n_pages=n_pages, page_size=page_size)
    for i, n in enumerate(sizes):
        expect = pm.n_free - pm.n_reserved >= pages_needed(n, page_size)
        assert pm.can_admit(n) == expect
        if expect:
            pm.admit(i, n, n)
            pm.check()


@settings(max_examples=40, deadline=None)
@given(st.lists(st.tuples(st.integers(1, 64), st.integers(1, 32)),
                min_size=1, max_size=16),
       st.integers(2, 8), st.integers(1, 8))
def test_plan_waves_partitions_within_budget(reqs, page_size, max_slots):
    budget = max(worst_case_pages(s, m, page_size) for s, m in reqs)
    budget = max(budget, 4)
    waves = plan_waves(reqs, page_size=page_size, page_budget=budget,
                       max_slots=max_slots)
    flat = [i for w in waves for i in w]
    assert sorted(flat) == list(range(len(reqs)))     # exact partition
    for w in waves:
        assert len(w) <= max_slots
        assert sum(worst_case_pages(*reqs[i], page_size)
                   for i in w) <= budget
    again = plan_waves(reqs, page_size=page_size, page_budget=budget,
                       max_slots=max_slots)
    assert waves == again                              # deterministic


def test_plan_waves_rejects_unservable_request():
    with pytest.raises(ValueError, match="exceed the page budget"):
        plan_waves([(100, 10)], page_size=1, page_budget=8, max_slots=4)


def test_table_array_null_padding():
    pm = PageManager(n_pages=16, page_size=4)
    pm.admit(7, 10, 20)
    row = pm.table_array(7, width=8)
    n = pages_needed(10, 4)
    assert row.dtype == np.int32
    assert list(row[:n]) == pm.tables[7]
    assert not row[n:].any(), "padding must be the null page 0"
    assert 0 not in row[:n]


# ===================================== engine under an admission/evict trace
_ENG_CFG = ModelConfig(name="pe", arch_type="dense", n_layers=2,
                       d_model=32, n_heads=2, n_kv_heads=2, d_ff=64,
                       vocab_size=64)
_ENG_PARAMS = init_model(jax.random.PRNGKey(0), _ENG_CFG)


@st.composite
def engine_fault_traces(draw):
    """Interleaved submit / step / evict ops — an admission stream with
    mid-flight cancellations at hypothesis-chosen points."""
    return draw(st.lists(
        st.one_of(
            st.tuples(st.just("submit"), st.integers(1, 10),
                      st.integers(1, 4)),          # prompt len, max_new
            st.tuples(st.just("step")),
            st.tuples(st.just("evict"), st.integers(0, 7)),  # victim rank
        ), min_size=3, max_size=22))


@settings(max_examples=12, deadline=None)
@given(engine_fault_traces())
def test_engine_eviction_trace_reclaims_pages(ops):
    """The engine's page pool survives arbitrary mid-flight evictions:
    after EVERY op the ``PageManager`` invariants hold (``check()``) and
    the pool is conserved (owned + free == capacity); an eviction returns
    exactly the pages the sequence owned, immediately reusable; draining
    the engine restores the whole pool."""
    eng = PagedServingEngine(_ENG_PARAMS, _ENG_CFG, page_size=4,
                             n_pages=12, max_slots=3, max_seq_len=16)
    cap = eng.pm.capacity
    next_uid = 0
    for op in ops:
        if op[0] == "submit":
            _, plen, max_new = op
            prompt = (np.arange(plen) % _ENG_CFG.vocab_size).astype(
                np.int32)
            eng.submit(Request(uid=next_uid, prompt=prompt,
                               max_new_tokens=max_new))
            next_uid += 1
        elif op[0] == "step":
            eng.step()
        else:
            pending = sorted(
                {s.req.uid for s in eng.live.values()}
                | {r.uid for r in eng.waiting})
            if pending:
                uid = pending[op[1] % len(pending)]
                owned = set(eng.pm.tables.get(uid, []))
                free_before = eng.pm.n_free
                freed = eng.evict(uid)
                # exact reclamation: everything it owned, nothing else
                assert set(freed) == owned
                assert eng.pm.n_free == free_before + len(freed)
                assert uid in eng.finished
        eng.pm.check()
        owned_total = sum(len(t) for t in eng.pm.tables.values())
        assert owned_total + eng.pm.n_free == cap
    # drain what is still in flight; the pool must come back whole
    for uid in sorted({s.req.uid for s in eng.live.values()}
                      | {r.uid for r in eng.waiting}):
        eng.evict(uid)
        eng.pm.check()
    assert eng.pm.n_free == cap
    assert eng.pm.n_reserved == 0
