import os
import sys

# Tests must see exactly ONE CPU device (the dry-run sets its own flags in a
# separate process). Keep XLA quiet and single-device.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))
