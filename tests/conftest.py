import os
import sys

# Tests must see exactly ONE CPU device (the dry-run sets its own flags in a
# separate process). Keep XLA quiet and single-device.
os.environ.setdefault("JAX_PLATFORMS", "cpu")

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def optional_hypothesis():
    """(given, settings, st) — real hypothesis when installed, otherwise
    stand-ins whose ``@given`` marks the test skipped. Property tests then
    skip cleanly instead of erroring the whole suite at collection
    (hypothesis is an optional extra, see requirements.txt)."""
    try:
        from hypothesis import given, settings, strategies as st
        return given, settings, st
    except ImportError:
        import pytest

        class _Strategies:
            def __getattr__(self, name):
                return lambda *a, **k: (lambda *a2, **k2: None)

        def given(*a, **k):
            return pytest.mark.skip(reason="hypothesis not installed")

        def settings(*a, **k):
            return lambda f: f

        return given, settings, _Strategies()
