"""Serving correctness: batched prefill vs the sequential decode-path
oracle, paged decode vs teacher-forced ``forward()`` (≤1e-5), the Pallas
paged-decode kernel vs the jnp gather reference, paged vs contiguous KV
equivalence, greedy-generate regression vs the pre-PR sequential path, and
the continuous-batching engine's invariants (batch-independence under
mid-flight admits, eviction frees exactly its pages, pool drains clean)."""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import (ATTN_LOCAL, RGLRU, SSD, ModelConfig,
                                RGLRUConfig, SSMConfig)
from repro.kernels.ops import paged_decode_attention
from repro.models.attention import _sdpa
from repro.models.transformer import forward, init_model, prefill_forward
from repro.serving.decode import generate, prefill, prefill_sequential
from repro.serving.engine import PagedServingEngine, Request
from repro.serving.pages import PageManager
from repro.serving.paged_decode import (dump_prefill_to_pools,
                                        init_paged_pools,
                                        paged_attention_ref,
                                        paged_decode_step)

CASES = {
    "dense_gqa": ModelConfig(name="d", arch_type="dense", n_layers=3,
                             d_model=32, n_heads=4, n_kv_heads=2, d_ff=64,
                             vocab_size=53),
    "swa": ModelConfig(name="l", arch_type="dense", n_layers=3, d_model=32,
                       n_heads=4, n_kv_heads=2, d_ff=64, vocab_size=53,
                       block_pattern=(ATTN_LOCAL,), window=4),
    "ssm": ModelConfig(name="s", arch_type="ssm", n_layers=2, d_model=32,
                       n_heads=0, n_kv_heads=0, d_ff=0, vocab_size=53,
                       rope=False, block_pattern=(SSD,),
                       ssm=SSMConfig(state_dim=8, head_dim=8, chunk=4)),
    "hybrid": ModelConfig(name="h", arch_type="hybrid", n_layers=3,
                          d_model=32, n_heads=4, n_kv_heads=1, d_ff=64,
                          vocab_size=53,
                          block_pattern=(RGLRU, RGLRU, ATTN_LOCAL), window=4,
                          rglru=RGLRUConfig()),
}
# engine tests use the attention-bearing configs (MoE couples slots through
# router capacity, so exact batch-independence is pinned on dense FFN only)
ENGINE_CASES = ["dense_gqa", "swa", "hybrid"]
PS = 4                                    # page size for all paged tests


@functools.lru_cache(maxsize=None)
def _params(name):
    return init_model(jax.random.PRNGKey(0), CASES[name])


def _prompts(cfg, B, S, seed=1):
    return jax.random.randint(jax.random.PRNGKey(seed), (B, S), 0,
                              cfg.vocab_size)


# ===================================================== prefill vs the oracle
@pytest.mark.parametrize("name", list(CASES))
def test_prefill_matches_sequential_oracle(name):
    """Batched forward()+dump prefill == the pre-PR O(S) decode-path loop:
    same last-position logits AND the same cache, leaf for leaf."""
    cfg = CASES[name]
    params = _params(name)
    toks = _prompts(cfg, 2, 8)
    lg_new, cache_new = prefill(params, cfg, toks, max_len=16)
    lg_old, cache_old = prefill_sequential(params, cfg, toks, max_len=16)
    np.testing.assert_allclose(np.asarray(lg_new), np.asarray(lg_old),
                               atol=1e-5, rtol=1e-5)
    for a, b in zip(jax.tree.leaves(cache_new), jax.tree.leaves(cache_old)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5,
                                   rtol=1e-5)


@pytest.mark.parametrize("name", list(CASES))
def test_generate_matches_pre_pr_sequential(name):
    """Greedy generate through the batched prefill is token-identical to
    the pre-PR sequential-prefill path."""
    cfg = CASES[name]
    params = _params(name)
    toks = _prompts(cfg, 2, 8)
    new = generate(params, cfg, toks, 5, max_len=16)
    old = generate(params, cfg, toks, 5, max_len=16,
                   sequential_prefill=True)
    np.testing.assert_array_equal(np.asarray(new), np.asarray(old))


# ============================================ paged decode vs forward logits
def _run_paged_teacher_forced(name, use_kernel):
    """Prefill two staggered sequences into pages, admit the second one
    mid-flight, teacher-force the rest through ``paged_decode_step`` and
    return the max |logits - forward()| over all decoded positions."""
    cfg = CASES[name]
    params = _params(name)
    max_slots, n_pmax, S_total = 3, 8, 16
    pm = PageManager(n_pages=64, page_size=PS)
    pools = init_paged_pools(cfg, 64, PS, max_slots)
    table = np.zeros((max_slots, n_pmax), np.int32)
    toks = _prompts(cfg, 2, S_total)
    prompt_lens, slots = [8, 12], [0, 2]
    admitted = set()
    ref_logits, _ = forward(params, cfg, toks)
    errs = []
    for t in range(min(prompt_lens), S_total):
        tok = np.zeros((max_slots, 1), np.int32)
        lens = np.zeros((max_slots,), np.int32)
        table_step = np.zeros_like(table)
        active = []
        for b, (slot, S) in enumerate(zip(slots, prompt_lens)):
            if S <= t:
                if b not in admitted:     # continuous-batching admit
                    pages = pm.admit(b, S, S_total)
                    _, cache = prefill_forward(params, cfg, toks[b:b + 1, :S],
                                               raw_kv=True)
                    pools = dump_prefill_to_pools(pools, cache, cfg, slot,
                                                  pages, PS, S)
                    table[slot, :len(pages)] = pages
                    admitted.add(b)
                newp = pm.append_token(b)
                if newp is not None:
                    table[slot, t // PS] = newp
                tok[slot, 0] = int(toks[b, t])
                lens[slot] = t
                table_step[slot] = table[slot]
                active.append((b, slot))
        logits, pools = paged_decode_step(
            params, pools, cfg, jnp.asarray(tok), jnp.asarray(table_step),
            jnp.asarray(lens), page_size=PS, use_kernel=use_kernel)
        for b, slot in active:
            errs.append(float(jnp.max(jnp.abs(
                logits[slot, 0] - ref_logits[b, t]))))
    pm.check()
    return max(errs)


@pytest.mark.parametrize("name", list(CASES))
def test_paged_decode_matches_forward(name):
    """Teacher-forced paged decode (gather reference) reproduces the
    training ``forward()`` logits to ≤1e-5 at every decoded position."""
    assert _run_paged_teacher_forced(name, use_kernel=False) <= 1e-5


@pytest.mark.parametrize("name", ["dense_gqa", "swa", "hybrid"])
def test_paged_decode_kernel_matches_forward(name):
    """Same bound through the Pallas paged flash-decode kernel."""
    assert _run_paged_teacher_forced(name, use_kernel=True) <= 1e-5


# ===================================== paged attention: kernel + equivalence
def _random_paged(key, B, H, n_kv, hd, n_pages, n_pmax):
    """Random contiguous histories scattered into random page layouts.
    Returns (q, k_pages, v_pages, table, k_full, v_full)."""
    ks = jax.random.split(key, 5)
    L = n_pmax * PS
    k_full = jax.random.normal(ks[0], (B, L, n_kv, hd))
    v_full = jax.random.normal(ks[1], (B, L, n_kv, hd))
    q = jax.random.normal(ks[2], (B, 1, H, hd))
    perm = np.random.RandomState(0).permutation(
        np.arange(1, n_pages))[:B * n_pmax]
    table = perm.reshape(B, n_pmax).astype(np.int32)
    k_pages = jnp.zeros((n_pages, PS, n_kv, hd))
    v_pages = jnp.zeros((n_pages, PS, n_kv, hd))
    for b in range(B):
        kc = k_full[b].reshape(n_pmax, PS, n_kv, hd)
        vc = v_full[b].reshape(n_pmax, PS, n_kv, hd)
        k_pages = k_pages.at[table[b]].set(kc)
        v_pages = v_pages.at[table[b]].set(vc)
    return q, k_pages, v_pages, jnp.asarray(table), k_full, v_full


@pytest.mark.parametrize("window", [0, 6])
def test_paged_matches_contiguous(window):
    """Gathering through an arbitrary page permutation == masked SDPA on
    the contiguous layout: paging is a pure layout change."""
    B, H, n_kv, hd, n_pmax = 2, 4, 2, 16, 4
    lengths = jnp.asarray([7, 13], jnp.int32)
    q, kp, vp, table, k_full, v_full = _random_paged(
        jax.random.PRNGKey(3), B, H, n_kv, hd, 64, n_pmax)
    paged = paged_attention_ref(q, kp, vp, table, lengths, window=window)
    L = n_pmax * PS
    pos = jnp.arange(L)[None, :]
    valid = pos <= lengths[:, None]
    if window:
        valid &= pos > lengths[:, None] - window
    dense = _sdpa(q, k_full, v_full, valid[:, None, None, :])
    np.testing.assert_allclose(np.asarray(paged), np.asarray(dense),
                               atol=1e-5, rtol=1e-5)


@pytest.mark.parametrize("window", [0, 6])
def test_paged_kernel_matches_gather_reference(window):
    """Pallas kernel (page-table-indirect DMA) vs the jnp gather oracle,
    GQA pools un-expanded, null-padded tables."""
    B, H, n_kv, hd, n_pmax = 3, 4, 2, 16, 4
    lengths = jnp.asarray([3, 9, 15], jnp.int32)   # some pages null-padded
    q, kp, vp, table, _, _ = _random_paged(
        jax.random.PRNGKey(4), B, H, n_kv, hd, 64, n_pmax)
    table = np.asarray(table).copy()
    for b, t in enumerate([3, 9, 15]):             # null-pad past the length
        table[b, t // PS + 1:] = 0
    table = jnp.asarray(table)
    ref = paged_attention_ref(q, kp, vp, table, lengths, window=window)
    out = paged_decode_attention(q[:, 0], kp, vp, table, lengths,
                                 window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref[:, 0]),
                               atol=1e-5, rtol=1e-5)


def test_paged_kernel_gates_zero_heads():
    """g_f == 0 heads write zeros (the training kernel's p_s semantics)."""
    B, H, n_kv, hd, n_pmax = 2, 4, 2, 16, 4
    lengths = jnp.asarray([7, 13], jnp.int32)
    q, kp, vp, table, _, _ = _random_paged(
        jax.random.PRNGKey(5), B, H, n_kv, hd, 64, n_pmax)
    g = jnp.ones((B, H)).at[0, 1].set(0.0).at[1, 3].set(0.0)
    out = paged_decode_attention(q[:, 0], kp, vp, table, lengths, g_f=g)
    assert float(jnp.abs(out[0, 1]).max()) == 0.0
    assert float(jnp.abs(out[1, 3]).max()) == 0.0
    ref = paged_attention_ref(q, kp, vp, table, lengths)[:, 0]
    live = np.asarray(g, bool)
    np.testing.assert_allclose(np.asarray(out)[live],
                               np.asarray(ref)[live], atol=1e-5, rtol=1e-5)


def test_paged_kernel_rejects_bad_tables():
    B, H, n_kv, hd = 1, 2, 2, 8
    q = jnp.zeros((B, H, hd))
    pools = jnp.zeros((8, PS, n_kv, hd))
    lengths = jnp.zeros((B,), jnp.int32)
    bad = jnp.full((B, 2), 99, jnp.int32)          # out of range
    with pytest.raises(ValueError, match="valid page ids"):
        paged_decode_attention(q, pools, pools, bad, lengths)


# ============================================================ engine behavior
def _engine(name, **kw):
    cfg = CASES[name]
    kw.setdefault("page_size", PS)
    kw.setdefault("n_pages", 32)
    kw.setdefault("max_slots", 3)
    kw.setdefault("max_seq_len", 32)
    return PagedServingEngine(_params(name), cfg, **kw)


def _requests(cfg, lens, max_new=6, seed=1):
    rng = np.random.RandomState(seed)
    return [Request(uid=i, prompt=rng.randint(
        0, cfg.vocab_size, size=s).astype(np.int32), max_new_tokens=max_new)
        for i, s in enumerate(lens)]


@pytest.mark.parametrize("name", ENGINE_CASES)
def test_engine_matches_generate(name):
    """Continuous batching (6 requests through 3 slots, mid-flight admits
    and evictions of finished sequences) is token-identical to generating
    each request alone through the pre-PR path."""
    cfg = CASES[name]
    eng = _engine(name)
    reqs = _requests(cfg, [5, 9, 13, 7, 11, 4])
    out = eng.run(reqs)
    eng.pm.check()
    assert eng.pm.n_free == eng.pm.capacity, "pages leaked after drain"
    for r in reqs:
        ref = np.asarray(generate(_params(name), cfg,
                                  jnp.asarray(r.prompt)[None], 6,
                                  max_len=r.prompt_len + 6)[0])
        np.testing.assert_array_equal(out[r.uid], ref)


def test_engine_batch_independence_under_midflight_admits():
    """A request's tokens do not depend on its batch neighbours: running it
    alone == running it jammed between 5 staggered neighbours."""
    name = "dense_gqa"
    cfg = CASES[name]
    reqs = _requests(cfg, [5, 9, 13, 7, 11, 4])
    together = _engine(name).run(reqs)
    for r in reqs:
        alone = _engine(name).run(
            [Request(uid=0, prompt=r.prompt,
                     max_new_tokens=r.max_new_tokens)])
        np.testing.assert_array_equal(together[r.uid], alone[0])


def test_engine_eviction_frees_exactly_its_pages():
    """Mid-flight eviction returns exactly the victim's pages, and the
    survivors' outputs are unchanged by the eviction (their slots never
    saw it)."""
    name = "dense_gqa"
    cfg = CASES[name]
    reqs = _requests(cfg, [5, 9, 7], max_new=8)

    baseline = _engine(name).run(reqs)

    eng = _engine(name)
    for r in reqs:
        eng.submit(r)
    eng.step()
    eng.step()
    victim_pages = set(eng.pm.tables[1])
    free_before = set(eng.pm.free)
    freed = eng.evict(1)
    eng.pm.check()
    assert set(freed) == victim_pages
    assert set(eng.pm.free) == free_before | victim_pages
    assert 1 not in eng.pm.tables
    while eng.live or eng.waiting:
        eng.step()
    assert eng.pm.n_free == eng.pm.capacity
    for uid in (0, 2):
        np.testing.assert_array_equal(eng.finished[uid], baseline[uid])
    # the victim's record is its partial output, a prefix of the baseline
    np.testing.assert_array_equal(
        eng.finished[1], baseline[1][:len(eng.finished[1])])


def test_engine_eos_stops_early():
    name = "dense_gqa"
    cfg = CASES[name]
    eng = _engine(name)
    req = _requests(cfg, [6], max_new=10)[0]
    out = eng.run([req])
    eos = int(out[0][7])
    # first generated position carrying the eos token decides the cutoff
    stop = next(p for p in range(6, len(out[0])) if int(out[0][p]) == eos)
    full = _engine(name, eos_id=eos).run([req])
    assert len(full[0]) == stop + 1
    np.testing.assert_array_equal(full[0], out[0][:stop + 1])


def test_engine_rejects_oversized_request():
    eng = _engine("dense_gqa", n_pages=8, max_seq_len=32)
    prompt = np.zeros(20, np.int32)
    with pytest.raises(MemoryError, match="never be admitted"):
        eng.submit(Request(uid=0, prompt=prompt, max_new_tokens=10))
    with pytest.raises(ValueError, match="exceeds max_seq_len"):
        eng.submit(Request(uid=1, prompt=prompt, max_new_tokens=20))
