"""Schedule construction, gates, packed indices, cost model."""
import numpy as np

from repro.configs.base import D2FTConfig
from repro.core.cost_model import (comm_cost, compute_cost, per_device_load,
                                   workload_variance)
from repro.core.d2ft import capacities, plan_schedule
from repro.core.baselines import (dpruning_schedule, gshard_schedule,
                                  random_schedule)
from repro.core.schedule import (P_F, P_O, P_S, gates_from_schedule,
                                 op_counts, packed_indices)


def _sched(L=4, G=4, N=5, n_pf=3, n_po=1, seed=0):
    rng = np.random.default_rng(seed)
    d2 = D2FTConfig(n_microbatches=N, n_pf=n_pf, n_po=n_po)
    bw = np.repeat(rng.random((L * G, 1)) + .1, N, axis=1)   # magnitude-like
    fw = rng.random((L * G, N)) + .1
    return plan_schedule(d2, bw, fw, L, G), d2


def test_knapsack_schedule_is_balanced():
    sched, d2 = _sched()
    assert workload_variance(sched.table) == 0.0
    counts = op_counts(sched)
    K = sched.table.shape[0]
    assert counts["p_f"] == 3 * K and counts["p_o"] == 1 * K


def test_costs_match_paper_budgets():
    sched, _ = _sched()
    # 3 p_f + 1 p_o of 5 micro-batches: compute (3 + .4)/5 = 68%, comm 70%
    assert abs(compute_cost(sched.table) - 0.68) < 1e-9
    assert abs(comm_cost(sched.table) - 0.70) < 1e-9


def test_gates_shapes_and_semantics():
    sched, _ = _sched(L=3, G=2, N=5)
    mb_of = np.repeat(np.arange(5), 2)
    g_f, g_b = gates_from_schedule(sched, mb_of)
    assert g_f.shape == (3, 10, 2) and g_b.shape == (3, 10, 2)
    t = sched.layer_group_view()
    # g_b only where p_f; g_f where p_f or p_o
    for l in range(3):
        for g in range(2):
            for b in range(10):
                op = t[l, g, mb_of[b]]
                assert float(g_b[l, b, g]) == (1.0 if op == P_F else 0.0)
                assert float(g_f[l, b, g]) == (1.0 if op != P_S else 0.0)


def test_packed_indices_cover_selected_samples():
    sched, _ = _sched(L=2, G=2, N=5)
    mb_of = np.repeat(np.arange(5), 2)
    idx, bwd, val, C_f = packed_indices(sched, mb_of)
    t = sched.layer_group_view()
    for l in range(2):
        for g in range(2):
            selected = set(np.nonzero(t[l, g, mb_of] != P_S)[0].tolist())
            got = set(idx[l, g][val[l, g] > 0].tolist())
            assert got == selected


def test_random_baseline_unbalanced_dpruning_balanced_full():
    rng = np.random.default_rng(0)
    rs = random_schedule(rng, 6, 6, 5, 3, 1)
    assert workload_variance(rs.table) > 0.0          # paper Table I
    dp = dpruning_schedule(rng.random(36), 6, 6, 5, keep_fraction=0.6)
    loads = per_device_load(dp.table)
    assert set(np.unique(loads)) <= {0.0, 5.0}        # all-or-nothing
    gs = gshard_schedule(rng, rng.random((36, 5)), 6, 6, capacity=2)
    assert (gs.table != P_S).sum() <= 6 * 6 * 2       # capacity enforced
