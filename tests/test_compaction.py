"""Compaction dispatch + fused one-pass backward.

Covers: the compacted grid's leading dim (via the trace-time on_dispatch
hook), executed-block counts under compaction (via on_backward_block), the
dispatched-bytes accounting shrinking proportionally to live slices, the
g_b <= g_f invariant check, sliding-window + padded-seq backward parity,
and end-to-end train-step equivalence of the compacted vs uncompacted
kernel paths under a real Schedule.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import d2ft_attention as d2a
from repro.kernels.ops import gated_attention
from repro.kernels.ref import gated_attention_ref

TOL = 1e-4   # fp32, interpret mode


def _mix_40po_20ps(B, H, rng):
    """The paper-budget mix: 40% p_f, 40% p_o, 20% p_s of the B*H subnets
    (deterministic counts, shuffled placement)."""
    N = B * H
    n_pf, n_po = (4 * N) // 10, (4 * N) // 10
    ops_ = np.full(N, 2)
    ops_[:n_pf] = 0
    ops_[n_pf:n_pf + n_po] = 1
    rng.shuffle(ops_)
    ops_ = ops_.reshape(B, H)
    g_f = jnp.asarray((ops_ != 2).astype(np.float32))
    g_b = jnp.asarray((ops_ == 0).astype(np.float32))
    return g_f, g_b, int((ops_ != 2).sum()), int((ops_ == 0).sum())


# ------------------------------------------------------------ invariant
def test_gb_gt_gf_rejected():
    q = jax.random.normal(jax.random.PRNGKey(0), (1, 2, 64, 16))
    g_f = jnp.asarray([[1., 0.]])
    g_b = jnp.asarray([[1., 1.]])        # backward live on a p_s head
    with pytest.raises(ValueError, match="g_b <= g_f"):
        gated_attention(q, q, q, g_f, g_b, interpret=True)


def test_undersized_live_bound_rejected():
    """A bound below the concrete live count would silently truncate the
    compaction gather — reject it up front."""
    q = jax.random.normal(jax.random.PRNGKey(0), (1, 4, 64, 16))
    g = jnp.ones((1, 4))
    with pytest.raises(ValueError, match="live_bwd=2 is below"):
        gated_attention(q, q, q, g, g, interpret=True, live_fwd=4,
                        live_bwd=2)


def test_gb_le_gf_accepted_and_shapes_checked():
    q = jax.random.normal(jax.random.PRNGKey(0), (1, 2, 64, 16))
    ok = gated_attention(q, q, q, jnp.ones((1, 2)), jnp.zeros((1, 2)),
                         interpret=True)
    assert ok.shape == q.shape
    with pytest.raises(ValueError, match="gates must be"):
        gated_attention(q, q, q, jnp.ones((2, 2)), jnp.zeros((2, 2)),
                        interpret=True)


# ------------------------------------------- compacted dispatch grids
def test_compacted_grid_leading_dim_and_parity():
    """With live bounds, the pallas_call grids lead with the bound instead
    of B*H, executed backward blocks stay exactly the live share, and
    outputs/gradients equal the uncompacted dispatch."""
    B, H, S, hd = 2, 10, 192, 16
    ks = jax.random.split(jax.random.PRNGKey(1), 4)
    q, k, v, do = (jax.random.normal(kk, (B, H, S, hd)) for kk in ks)
    g_f, g_b, live_f, live_b = _mix_40po_20ps(B, H, np.random.default_rng(3))
    assert (live_f, live_b) == (16, 8)   # 40% p_o + 20% p_s of 20 slices

    grids = {}
    blocks = {"n": 0}
    d2a.on_dispatch = lambda kind, grid: grids.__setitem__(kind, grid)
    d2a.on_backward_block = lambda: blocks.__setitem__("n", blocks["n"] + 1)
    jax.clear_caches()                   # hooks are read at trace time
    try:
        def run(live):
            lf, lb = (live_f, live_b) if live else (None, None)
            out, vjp = jax.vjp(
                lambda q, k, v: gated_attention(
                    q, k, v, g_f, g_b, interpret=True, live_fwd=lf,
                    live_bwd=lb), q, k, v)
            grads = vjp(do)
            jax.effects_barrier()
            return out, grads

        blocks["n"] = 0
        out_c, grads_c = run(live=True)
        compacted_blocks = blocks["n"]
        fwd_grid, bwd_grid = grids["fwd"], grids["bwd"]

        blocks["n"] = 0
        out_u, grads_u = run(live=False)
        assert grids["fwd"][0] == B * H and grids["bwd"][0] == B * H

        # grid leading dims shrink to the live bounds (not B*H)
        assert fwd_grid[0] == live_f and fwd_grid[1:] == grids["fwd"][1:]
        assert bwd_grid[0] == live_b and bwd_grid[1:] == grids["bwd"][1:]
        # executed backward tiles: identical live share either way
        assert compacted_blocks == blocks["n"] > 0
        # numerics identical
        np.testing.assert_allclose(np.asarray(out_c), np.asarray(out_u),
                                   atol=1e-6, rtol=1e-6)
        for name, a, b in zip(("dq", "dk", "dv"), grads_c, grads_u):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       atol=1e-6, rtol=1e-6, err_msg=name)
    finally:
        d2a.on_dispatch = None
        d2a.on_backward_block = None


def test_dispatched_bytes_shrink_proportionally():
    """The DMA model: dispatched bytes scale with the compacted slice
    count, not with B*H — the 40% p_o + 20% p_s mix streams 40% of the
    backward bytes and 80% of the forward bytes."""
    B, H, S, hd = 2, 10, 256, 64
    g_f, g_b, live_f, live_b = _mix_40po_20ps(B, H, np.random.default_rng(0))
    full_f, full_b = d2a.gated_attention_dispatched_bytes(g_f, g_b, S, hd)
    comp_f, comp_b = d2a.gated_attention_dispatched_bytes(
        g_f, g_b, S, hd, live_fwd=live_f, live_bwd=live_b)
    N = B * H
    assert comp_f / full_f == live_f / N == 0.8
    assert comp_b / full_b == live_b / N == 0.4
    # uncompacted dispatch streams every slice regardless of gates
    ones_f, ones_b = d2a.gated_attention_dispatched_bytes(
        jnp.ones((B, H)), jnp.ones((B, H)), S, hd)
    assert (ones_f, ones_b) == (full_f, full_b)


# --------------------------------- sliding window + padded seq backward
@pytest.mark.parametrize("live", [False, True])
def test_window_padded_backward_parity_and_counts(live):
    """window > 0 with a non-dividing S (257 -> padded 384): dq/dk/dv match
    the reference VJP and the fused kernel executes exactly the live-slice
    share of the window-reduced block set."""
    B, H, S, hd = 1, 4, 257, 32
    window = 64
    ks = jax.random.split(jax.random.PRNGKey(7), 4)
    q, k, v, do = (jax.random.normal(kk, (B, H, S, hd)) for kk in ks)
    g_f = jnp.asarray([[1., 1., 1., 0.]])
    g_b = jnp.asarray([[1., 0., 1., 0.]])
    lf, lb = (3, 2) if live else (None, None)

    count = {"n": 0}
    d2a.on_backward_block = lambda: count.__setitem__("n", count["n"] + 1)
    jax.clear_caches()
    try:
        out_k, vjp_k = jax.vjp(
            lambda q, k, v: gated_attention(
                q, k, v, g_f, g_b, causal=True, window=window,
                interpret=True, live_fwd=lf, live_bwd=lb), q, k, v)
        grads_k = vjp_k(do)
        jax.effects_barrier()
    finally:
        d2a.on_backward_block = None

    out_r, vjp_r = jax.vjp(
        lambda q, k, v: gated_attention_ref(q, k, v, g_f, g_b, causal=True,
                                            window=window), q, k, v)
    np.testing.assert_allclose(np.asarray(out_k), np.asarray(out_r),
                               atol=TOL, rtol=TOL)
    for name, a, b in zip(("dq", "dk", "dv"), grads_k, vjp_r(do)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=TOL,
                                   rtol=TOL, err_msg=name)

    from repro.kernels.d2ft_attention import select_blocks
    bq, bk, Sp = select_blocks(S, 128, 128)
    assert Sp == 384                     # padded, not slivered
    tiles = d2a.live_block_count(Sp, bq, bk, True, window, seq_len=S)
    n_live_b = int(np.sum(np.asarray(g_b) != 0))
    assert count["n"] == n_live_b * tiles
    # window + seq_len padding prune blocks vs full causal
    assert tiles < d2a.live_block_count(Sp, bq, bk, True, 0)


# ----------------------------------------------------------- end to end
def test_vit_step_compacted_matches_uncompacted():
    """One optimizer step under a real Schedule: compaction dispatch leaves
    the updated parameters bit-identical to the uncompacted kernel path."""
    from repro.configs.base import D2FTConfig
    from repro.core.d2ft import plan_schedule
    from repro.core.schedule import gates_from_schedule, live_slice_bounds
    from repro.data.synthetic import microbatch_assignment
    from repro.models.vit import ViTConfig, init_vit
    from repro.optim.optimizers import sgd
    from repro.train.loop import make_vit_step

    cfg = ViTConfig(n_layers=2, d_model=48, n_heads=6, d_ff=96, patch=8,
                    image_size=16, n_classes=4)
    params = init_vit(jax.random.PRNGKey(0), cfg)
    B, M = 10, 5
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.standard_normal((B, 16, 16, 3)).astype(np.float32))
    y = jnp.asarray(rng.integers(0, 4, B))
    d2 = D2FTConfig(n_microbatches=M, n_pf=2, n_po=1)
    K = cfg.n_layers * cfg.n_heads
    sched = plan_schedule(d2, rng.random((K, M)) + .1,
                          rng.random((K, M)) + .1, cfg.n_layers, cfg.n_heads)
    mb_of = microbatch_assignment(B, M)
    gates = gates_from_schedule(sched, mb_of)
    bounds = live_slice_bounds(sched, mb_of)
    assert bounds[0] < cfg.n_heads * B and bounds[1] < cfg.n_heads * B

    opt = sgd(0.05)
    out = {}
    for name, lb in (("uncompacted", None), ("compacted", bounds)):
        step = jax.jit(make_vit_step(cfg, opt, True, use_kernel=True,
                                     live_bounds=lb))
        p2, _, metrics = step(params, opt.init(params), x, y, gates)
        out[name] = (p2, float(metrics["loss"]))
    assert abs(out["compacted"][1] - out["uncompacted"][1]) < 1e-6
    diffs = jax.tree.map(lambda a, b: float(jnp.abs(a - b).max()),
                         out["compacted"][0], out["uncompacted"][0])
    assert max(jax.tree.leaves(diffs)) < 1e-6
