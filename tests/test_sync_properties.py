"""Property-based invariants for the sync plan and the device assigner
(hypothesis; skipped cleanly when it is not installed — CI installs it via
requirements.txt, see conftest.optional_hypothesis).

* ``grad_sync_plan`` covers every param leaf exactly once, whatever the
  schedule, in masked, zero and zero3 modes;
* zero-partition slices tile the axis: the shard layout is a bijection of
  the canonical element order, shards are equal-sized, runs cover every
  group exactly once;
* the zero3 partition + schedule-masked gather round-trips every leaf
  bit-exactly: reassembling the per-device shards of every gathered run
  reproduces the canonical content, elided runs are exactly the
  forward-dead ones, and the gather mask covers the scatter mask;
* the knapsack assigner respects capacities whenever they are feasible and
  places every micro-batch exactly once.
"""
import numpy as np

import jax

from conftest import optional_hypothesis

given, settings, st = optional_hypothesis()

from repro.configs.base import ModelConfig
from repro.core.assignment import assign_microbatches
from repro.core.schedule import P_F, P_O, P_S, Schedule
from repro.models.transformer import init_model
from repro.sharding.sync import (SyncSpec, _zero_layout_perm, _zero_runs,
                                 grad_sync_plan, sync_byte_report)

CFG = ModelConfig(name="prop", arch_type="dense", n_layers=2, d_model=32,
                  n_heads=4, n_kv_heads=4, d_ff=64, vocab_size=128)
L, G = 2, 4
PARAMS = init_model(jax.random.PRNGKey(0), CFG)
IS_SPEC = dict(is_leaf=lambda x: isinstance(x, SyncSpec))


@st.composite
def schedule_tables(draw):
    n_mb = draw(st.integers(1, 6))
    cells = draw(st.lists(st.sampled_from([P_F, P_O, P_S]),
                          min_size=L * G * n_mb, max_size=L * G * n_mb))
    return Schedule(np.asarray(cells, np.int8).reshape(L * G, n_mb), L, G)


@settings(max_examples=40, deadline=None)
@given(schedule_tables(), st.sampled_from(["masked", "zero", "zero3"]),
       st.sampled_from([1, 2, 4, 8]))
def test_plan_covers_every_leaf_exactly_once(sched, mode, n_shards):
    plan = grad_sync_plan(PARAMS, CFG, sched, mode=mode, n_shards=n_shards)
    specs = jax.tree.leaves(plan, **IS_SPEC)
    assert all(isinstance(s, SyncSpec) for s in specs)
    # same treedef as the params: one spec per leaf, no leaf missed
    assert jax.tree.structure(plan, **IS_SPEC) == jax.tree.structure(PARAMS)
    rep = sync_byte_report(plan, PARAMS, n_shards=n_shards)
    assert rep["n_leaves"] == len(jax.tree.leaves(PARAMS))
    assert 0.0 <= rep["fraction"] <= 1.0


@settings(max_examples=40, deadline=None)
@given(schedule_tables(), st.sampled_from([1, 2, 4, 8]),
       st.booleans())
def test_zero_partition_tiles_every_axis(sched, n_shards, elide):
    plan = grad_sync_plan(PARAMS, CFG, sched, mode="zero",
                          n_shards=n_shards, elide_gather=elide)

    def check(spec, shape):
        gs = shape[spec.axis] // len(spec.live)
        runs = _zero_runs(spec)
        # runs tile the group axis exactly once, in order
        assert [r[2] for r in runs][0] == 0
        assert all(a[3] == b[2] for a, b in zip(runs, runs[1:]))
        assert runs[-1][3] == len(spec.live)
        assert gs * len(spec.live) == shape[spec.axis]
        # the shard layout is a bijection of the canonical order
        perm = _zero_layout_perm(spec, shape[spec.axis])
        assert np.array_equal(np.sort(perm), np.arange(shape[spec.axis]))
        # equal shards: every device owns exactly 1/k of the axis
        assert shape[spec.axis] % spec.shards == 0

    def rec(p, spec):
        if isinstance(spec, SyncSpec):
            if spec.mode == "zero":
                check(spec, p.shape)
            elif spec.mode == "zero_stacked":
                for sub in spec.per_cycle:
                    check(sub, p.shape[1:])
            return
        if isinstance(spec, dict):
            for k in spec:
                rec(p[k], spec[k])
        else:
            for pi, si in zip(p, spec):
                rec(pi, si)

    rec(PARAMS, plan)


@settings(max_examples=40, deadline=None)
@given(schedule_tables(), st.sampled_from([1, 2, 4, 8]))
def test_zero3_partition_gather_roundtrips_bit_exact(sched, n_shards):
    """Host-side emulation of the zero3 dataflow on every leaf: lay the
    canonical array out in shard order (``_zero_layout_perm``, what
    ``zero_reshard`` applies), split it into the k device shards, then
    rebuild the full view the way ``zero3_materialize`` does — walking the
    shard by run offsets, concatenating the k sub-chunks of gathered runs,
    zeros for elided runs. The result must equal the canonical array with
    exactly the elided runs zeroed, bit for bit — this pins the run-offset
    arithmetic of the runtime gather against the layout permutation the
    resharder uses. Also: gather ⊇ scatter on every leaf."""
    plan = grad_sync_plan(PARAMS, CFG, sched, mode="zero3",
                          n_shards=n_shards)

    def emulate(x, spec):
        ax, k = spec.axis, spec.shards
        n = x.shape[ax]
        gs = n // len(spec.live)
        layout = np.take(x, _zero_layout_perm(spec, n), axis=ax)
        shard_len = n // k
        shards = [np.take(layout, np.arange(d * shard_len,
                                            (d + 1) * shard_len), axis=ax)
                  for d in range(k)]
        parts, off = [], 0
        expect = x.copy()
        for _, gather, s, e in _zero_runs(spec):
            plen = (e - s) * gs // k
            if gather:
                parts.append(np.concatenate(
                    [np.take(sh, np.arange(off, off + plen), axis=ax)
                     for sh in shards], axis=ax))
            else:
                shape = list(x.shape)
                shape[ax] = (e - s) * gs
                parts.append(np.zeros(shape, x.dtype))
                idx = [slice(None)] * x.ndim
                idx[ax] = slice(s * gs, e * gs)
                expect[tuple(idx)] = 0
            off += plen
        got = np.concatenate(parts, axis=ax) if len(parts) > 1 else parts[0]
        np.testing.assert_array_equal(got, expect)
        assert all(g or not l for l, g in zip(spec.live, spec.gather))

    def rec(p, spec):
        if isinstance(spec, SyncSpec):
            if spec.mode == "zero":
                emulate(np.asarray(p), spec)
            elif spec.mode == "zero_stacked":
                arr = np.asarray(p)
                for c, sub in enumerate(spec.per_cycle):
                    emulate(arr[c], sub)
            return
        if isinstance(spec, dict):
            for k in spec:
                rec(p[k], spec[k])
        else:
            for pi, si in zip(p, spec):
                rec(pi, si)

    rec(PARAMS, plan)


@settings(max_examples=20, deadline=None)
@given(st.sampled_from(["sgd", "adamw"]), st.integers(1, 97),
       st.integers(0, 2 ** 16), st.integers(1, 3))
def test_chunked_optimizer_update_bit_identical(kind, chunk, seed, steps):
    """``chunked(opt, chunk)`` must be *bit*-identical to ``opt`` — params
    and every moment — for any chunk size (divisor or not: the zero-padded
    tail chunk must not perturb anything) over multiple steps. This is the
    correctness contract of the streamed ZeRO-3 shard-resident optimizer
    sweep: chunking is a memory schedule, never a numeric change. Holds
    because sgd/adamw updates are elementwise and the update of an
    all-zeros (grad, param, moment) padding slot is zero."""
    from repro.optim.optimizers import adamw, chunked, sgd
    opt = sgd(1e-2, momentum=0.9, weight_decay=1e-3) if kind == "sgd" \
        else adamw(1e-3, weight_decay=1e-2)
    copt = chunked(opt, chunk)
    keys = jax.random.split(jax.random.PRNGKey(seed), 6)
    shapes = [(3,), (5, 7), (2, 3, 4)]
    params = {"a": [jax.random.normal(keys[i], s)
                    for i, s in enumerate(shapes)],
              "b": jax.random.normal(keys[3], (11,))}
    p_ref, s_ref = params, opt.init(params)
    p_chk, s_chk = params, copt.init(params)
    for t in range(steps):
        grads = jax.tree.map(
            lambda _, k=keys[4 + t % 2], t=t:
                jax.random.normal(jax.random.fold_in(k, t), _.shape),
            params)
        p_ref, s_ref = opt.update(grads, s_ref, p_ref)
        p_chk, s_chk = copt.update(grads, s_chk, p_chk)
    assert all(jax.tree.leaves(jax.tree.map(
        lambda x, y: bool((np.asarray(x) == np.asarray(y)).all()),
        p_ref, p_chk)))
    assert all(jax.tree.leaves(jax.tree.map(
        lambda x, y: bool((np.asarray(x) == np.asarray(y)).all()),
        s_ref, s_chk)))


@st.composite
def assignment_instances(draw):
    n_dev = draw(st.integers(1, 4))
    n_items = draw(st.integers(1, 12))
    costs = draw(st.lists(st.floats(0.0, 5.0), min_size=n_items,
                          max_size=n_items))
    return np.asarray(costs), n_dev


@settings(max_examples=40, deadline=None)
@given(assignment_instances())
def test_assigner_places_every_item_within_feasible_capacity(inst):
    costs, n_dev = inst
    # generous per-device budget: total cost fits on every single device,
    # so the LPT seed can never be forced into a violation
    cap = float(costs.sum()) + 1.0
    a = assign_microbatches(costs, n_dev, capacities=cap)
    assert a.device_of.min() >= 0 and a.device_of.max() < n_dev
    assert len(a.device_of) == len(costs)                # each item placed
    assert np.allclose(a.loads.sum(), costs.sum())       # exactly once
    assert (a.loads <= cap + 1e-9).all()


@settings(max_examples=25, deadline=None)
@given(st.integers(1, 4), st.integers(1, 4),
       st.lists(st.floats(0.0, 5.0), min_size=1, max_size=4))
def test_assigner_equal_counts(n_dev, per_dev, base_costs):
    n_items = n_dev * per_dev
    costs = np.resize(np.asarray(base_costs), n_items)
    a = assign_microbatches(costs, n_dev, equal_counts=True)
    assert (a.counts == per_dev).all()
