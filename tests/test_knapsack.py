"""Knapsack DP: optimality vs brute force (hypothesis), jax DP parity,
bi-level semantics (Eqs. 7/8), Algorithm 1 merge."""
import numpy as np
import pytest

from conftest import optional_hypothesis

given, settings, st = optional_hypothesis()

from repro.core.knapsack import (bilevel_select, brute_force, dp_knapsack,
                                 dp_knapsack_value_jax, scalarized_select)
from repro.core.schedule import P_F, P_O, P_S, merge_tables


@st.composite
def knapsack_instance(draw):
    n = draw(st.integers(1, 10))
    values = draw(st.lists(st.floats(0.0, 10.0), min_size=n, max_size=n))
    weights = draw(st.lists(st.integers(1, 6), min_size=n, max_size=n))
    cap = draw(st.integers(0, 18))
    return np.asarray(values), np.asarray(weights, float), float(cap)


@settings(max_examples=60, deadline=None)
@given(knapsack_instance())
def test_dp_matches_brute_force(inst):
    v, w, c = inst
    sel = dp_knapsack(v, w, c)
    assert w[sel].sum() <= c + 1e-9
    best, _ = brute_force(v, w, c)
    assert v[sel].sum() == pytest.approx(best, abs=1e-9)


@settings(max_examples=25, deadline=None)
@given(knapsack_instance())
def test_jax_dp_value_matches_numpy(inst):
    v, w, c = inst
    sel = dp_knapsack(v, w, c)
    jv = dp_knapsack_value_jax(v, (w * 100).astype(int), int(c * 100))
    assert float(jv) == pytest.approx(v[sel].sum(), rel=1e-5, abs=1e-5)


def test_bilevel_budget_counts():
    rng = np.random.default_rng(0)
    bw = rng.random(5) + 0.1
    fw = rng.random(5) + 0.1
    # paper setting: c_f=0.4, c_b=0.6, capacity 3 p_f + 1 p_o
    sel_pf, sel_po = bilevel_select(bw, fw, 0.4, 0.6, 3.0, 0.4)
    assert sel_pf.sum() == 3
    assert sel_po.sum() == 1


def test_merge_table_semantics():
    sel_pf = np.array([[True, False, False, True]])
    sel_po = np.array([[True, True, False, False]])
    t = merge_tables(sel_pf, sel_po)
    assert t.tolist() == [[P_F, P_O, P_S, P_F]]  # pf wins conflict


def test_scalarized_respects_budget():
    rng = np.random.default_rng(1)
    bw, fw = rng.random(6), rng.random(6)
    sel_pf, sel_po = scalarized_select(bw, fw, lam=0.2, c_f=0.4, c_b=0.6,
                                       cap_total=3.4)
    cost = sel_pf.sum() * 1.0 + sel_po.sum() * 0.4
    assert cost <= 3.4 + 1e-9
    assert not (sel_pf & sel_po).any()
