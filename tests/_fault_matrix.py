"""Subprocess body for the 8-host-device elastic fault matrix.

Run by tests/test_faults.py with a fresh interpreter (the forced host
device count must not leak into the rest of the suite — conftest pins the
main process to ONE CPU device). Four scenarios, one per degradation
mechanism of train/elastic.py, each asserting its acceptance criterion:

  (a) a 2x straggler triggers a capacity-constrained reassignment whose
      predicted makespan beats the no-mitigation assignment;
  (b) a 1-device dropout recovers onto the survivors from the last
      step-level checkpoint, and the recovered run's final params match a
      fresh survivors-only resume of the SAME checkpoint to <= 1e-6;
  (c) an injected NaN burst is skipped by the pre-sync guard and training
      stays within tolerance of the fault-free run;
  (d) dropped sync rounds past the threshold engage the lo-fi local
      fallback, which keeps training and merging.

Prints one machine-readable FAULTS_OK line on success; any assertion or
crash fails the calling test via the exit code.
"""
import os
import tempfile

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import numpy as np

import jax
import jax.numpy as jnp

from repro.configs.base import D2FTConfig, ModelConfig
from repro.data.synthetic import lm_batches
from repro.launch.faults import FaultPlan
from repro.launch.mesh import make_data_mesh
from repro.models.transformer import init_model
from repro.optim.optimizers import adamw, sgd
from repro.train.elastic import ElasticConfig, finetune_elastic

assert len(jax.devices()) == 8, jax.devices()

cfg = ModelConfig(name="faults", arch_type="dense", n_layers=4, d_model=64,
                  n_heads=4, n_kv_heads=4, d_ff=128, vocab_size=256)
d2 = D2FTConfig(n_microbatches=16, n_pf=6, n_po=4, head_groups=4)
B, S, K = 32, 16, 8
params = init_model(jax.random.PRNGKey(0), cfg)
mesh8 = make_data_mesh(K)


def fresh_batches(n):
    return list(lm_batches(0, cfg.vocab_size, batch=B, seq=S, steps=n))


def max_leaf_diff(a, b):
    return max(jax.tree.leaves(jax.tree.map(
        lambda x, y: float(jnp.abs(x - y).max()), a, b)))


def copy(tree):
    return jax.tree.map(jnp.copy, tree)


# ---- (a) straggler: capacity mitigation beats the balanced assignment ----
fp = FaultPlan(slowdowns=((3, 2.0),))
el = ElasticConfig(refresh_every=2, ckpt_every=0,
                   ckpt_dir=tempfile.mkdtemp())
_, _, log = finetune_elastic(copy(params), cfg, d2, sgd(0.1),
                             fresh_batches(5), steps=5, mesh=mesh8,
                             faults=fp, elastic=el)
refreshes = log.extras["refreshes"]
mitigated = [r["elastic"] for r in refreshes
             if r["elastic"].get("capacities") is not None]
assert mitigated, f"no capacity-mitigated refresh in {len(refreshes)}"
ratio = mitigated[-1]["mitigation_ratio"]
assert ratio < 1.0, f"mitigation did not improve the makespan: {ratio}"
# the straggler's measured unit time must have reached the EMA
assert mitigated[-1]["unit_times"][3] > 1.4, mitigated[-1]["unit_times"]

# ---- (b) dropout: in-place recovery == fresh survivors-only resume ------
fp = FaultPlan(dropout=(3, 5))
ck_dir = tempfile.mkdtemp()
el = ElasticConfig(refresh_every=4, ckpt_every=2, ckpt_dir=ck_dir)
opt = adamw(1e-3)
p_a, s_a, log_a = finetune_elastic(copy(params), cfg, d2, opt,
                                   fresh_batches(6), steps=6, mesh=mesh8,
                                   faults=fp, elastic=el)
recs = [e for e in log_a.extras["elastic"]["events"]
        if e["type"] == "dropout_recovery"]
assert len(recs) == 1, log_a.extras["elastic"]["events"]
rec = recs[0]
assert rec["n_devices"] == 4 and rec["ckpt_step"] == 2, rec
assert rec["recovery_steps"] == 1, rec
assert log_a.extras["elastic"]["n_devices"] == 4
# survivors-only run: same checkpoint, fresh loop on a 4-device mesh
el_b = ElasticConfig(refresh_every=4, ckpt_every=2,
                     ckpt_dir=tempfile.mkdtemp())
p_b, s_b, _ = finetune_elastic(copy(params), cfg, d2, opt,
                               fresh_batches(6), steps=6,
                               mesh=make_data_mesh(4), elastic=el_b,
                               resume_from=rec["ckpt"])
dropout_diff = max_leaf_diff(p_a, p_b)
assert dropout_diff <= 1e-6, \
    f"recovered run diverged from survivors-only resume: {dropout_diff}"
dropout_opt_diff = max_leaf_diff(s_a, s_b)
assert dropout_opt_diff <= 1e-6, dropout_opt_diff

# ---- (c) NaN burst: guard skips it, training stays on track -------------
fp = FaultPlan(grad_faults=((2, 1, float("nan")), (3, 6, float("inf"))))
el = ElasticConfig(refresh_every=0, ckpt_every=0,
                   ckpt_dir=tempfile.mkdtemp())
p_f, _, log_f = finetune_elastic(copy(params), cfg, d2, sgd(0.1),
                                 fresh_batches(8), steps=8, mesh=mesh8,
                                 faults=fp, elastic=el)
el = ElasticConfig(refresh_every=0, ckpt_every=0,
                   ckpt_dir=tempfile.mkdtemp())
p_c, _, log_c = finetune_elastic(copy(params), cfg, d2, sgd(0.1),
                                 fresh_batches(8), steps=8, mesh=mesh8,
                                 elastic=el)
skips = [e for e in log_f.extras["elastic"]["events"]
         if e["type"] == "guard_skip"]
assert [e["step"] for e in skips] == [2, 3], skips
assert all(e["bad_devices"] == 1.0 for e in skips), skips
assert log_f.extras["elastic"]["guard_skips"] == 2
assert all(np.isfinite(x) for x in log_f.losses), log_f.losses
assert bool(np.isfinite(np.asarray(jax.tree.leaves(p_f)[0])).all())
nan_gap = abs(log_f.losses[-1] - log_c.losses[-1])
# a skipped step is a lost update, nothing more: the faulted run's final
# loss must match the fault-free run at the same UPDATE count (the clean
# trajectory two steps earlier), and the end-of-run gap must stay a
# bounded fraction of the clean run's total progress
n_skips = len(skips)
assert log_f.losses[-1] <= log_c.losses[-1 - n_skips] + 0.05, \
    (log_f.losses, log_c.losses)
clean_drop = log_c.losses[0] - log_c.losses[-1]
assert nan_gap <= 0.6 * clean_drop, \
    f"faulted run drifted {nan_gap} vs clean progress {clean_drop}"
assert log_f.losses[-1] < log_f.losses[0], log_f.losses

# ---- (d) dropped syncs: threshold crossing engages the lo-fi mode -------
fp = FaultPlan(dropped_syncs=(1, 2))
el = ElasticConfig(refresh_every=0, ckpt_every=0, merge_every=2,
                   sync_fault_threshold=2, ckpt_dir=tempfile.mkdtemp())
p_l, _, log_l = finetune_elastic(copy(params), cfg, d2, sgd(0.1),
                                 fresh_batches(8), steps=8, mesh=mesh8,
                                 faults=fp, elastic=el)
ev = log_l.extras["elastic"]
kinds = [e["type"] for e in ev["events"]]
assert kinds.count("sync_drop") == 2 and "lofi_fallback" in kinds, kinds
assert ev["final_mode"] == "local" and ev["merges"] >= 2, ev
assert log_l.losses[-1] < log_l.losses[0], log_l.losses

print(f"FAULTS_OK mitigation_ratio={ratio:.4f} "
      f"dropout_diff={dropout_diff:.3e} "
      f"recovery_steps={rec['recovery_steps']} "
      f"nan_skips={log_f.extras['elastic']['guard_skips']} "
      f"nan_gap={nan_gap:.4f} "
      f"lofi_merges={ev['merges']}")
