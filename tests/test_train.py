"""Training substrate: optimizers, checkpoint round-trip, loss goes down,
ViT fine-tune improves accuracy, D2FT end-to-end driver."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import D2FTConfig, ModelConfig
from repro.data.synthetic import (image_batches, lm_batches, make_image_task,
                                  microbatch_assignment, split_microbatches)
from repro.models.transformer import init_model, lm_loss
from repro.models.vit import init_vit, vit_small
from repro.optim.optimizers import adamw, clip_by_global_norm, sgd
from repro.train.checkpoints import load_checkpoint, save_checkpoint
from repro.train.loop import eval_vit, finetune, finetune_vit

CFG = ModelConfig(name="t", arch_type="dense", n_layers=2, d_model=64,
                  n_heads=4, n_kv_heads=4, d_ff=128, vocab_size=64)


def test_optimizers_reduce_loss():
    def loss(p):
        return jnp.sum((p["w"] - 3.0) ** 2)
    for opt in (sgd(0.1), adamw(0.1)):
        params = {"w": jnp.zeros(4)}
        state = opt.init(params)
        for _ in range(50):
            grads = jax.grad(loss)(params)
            params, state = opt.update(grads, state, params)
        assert float(loss(params)) < 0.2


def test_clip_by_global_norm():
    g = {"a": jnp.full(4, 10.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert float(norm) == 20.0
    assert np.isclose(float(jnp.linalg.norm(clipped["a"])), 1.0)


def test_checkpoint_roundtrip():
    params = init_model(jax.random.PRNGKey(0), CFG)
    opt = adamw(1e-3)
    state = {"params": params, "opt": opt.init(params), "step": jnp.int32(7)}
    with tempfile.TemporaryDirectory() as d:
        path = os.path.join(d, "ckpt.npz")
        save_checkpoint(path, state)
        back = load_checkpoint(path)
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_llm_finetune_loss_decreases():
    params = init_model(jax.random.PRNGKey(0), CFG)
    batches = list(lm_batches(0, CFG.vocab_size, batch=8, seq=16, steps=30))
    params, _, log = finetune(params, CFG, None, sgd(0.3), batches, steps=30)
    assert np.mean(log.losses[-5:]) < np.mean(log.losses[:5]) - 0.2


def test_d2ft_finetune_driver_runs_and_learns():
    params = init_model(jax.random.PRNGKey(0), CFG)
    d2 = D2FTConfig(n_microbatches=4, n_pf=2, n_po=1, head_groups=4)
    batches = list(lm_batches(0, CFG.vocab_size, batch=8, seq=16, steps=25))
    params, _, log = finetune(params, CFG, d2, sgd(0.3), batches, steps=25)
    assert np.mean(log.losses[-5:]) < np.mean(log.losses[:5])


def test_vit_finetune_improves_accuracy():
    cfg = vit_small(n_classes=4)
    cfg = type(cfg)(n_layers=2, d_model=64, n_heads=4, d_ff=128, patch=8,
                    image_size=32, n_classes=4)
    params = init_vit(jax.random.PRNGKey(0), cfg)
    task = make_image_task(0, n_classes=4, image_size=32, noise=0.3)
    acc0 = eval_vit(params, cfg, image_batches(task, 1, 32, 5))
    params, _, _ = finetune_vit(params, cfg, sgd(0.05),
                                image_batches(task, 2, 32, 40), steps=40)
    acc1 = eval_vit(params, cfg, image_batches(task, 1, 32, 5))
    assert acc1 > acc0 + 0.3, (acc0, acc1)


def test_microbatch_helpers():
    mb = microbatch_assignment(10, 5)
    assert mb.tolist() == [0, 0, 1, 1, 2, 2, 3, 3, 4, 4]
    split = split_microbatches({"x": jnp.arange(10)}, 5)
    assert len(split) == 5 and split[3]["x"].tolist() == [6, 7]
